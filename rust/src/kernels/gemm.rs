//! Cache-blocked, register-tiled f32 GEMM with a multi-threaded row
//! driver — the one hot kernel every fc and (via im2col) conv shard runs
//! on (DESIGN.md §8).
//!
//! Structure is the classic three-level blocking (the decomposition the
//! paper's cost model assumes): the operand matrices are cut into
//! `MC × KC` panels of A and `KC × NC` panels of B, packed into
//! contiguous micro-panel strips, and multiplied by an `MR × NR`
//! register-tiled micro-kernel that keeps the C accumulator in registers
//! across the whole KC depth. Threading partitions C's rows across
//! `std::thread::scope` workers (zero external deps); each worker packs
//! its own panels, so no synchronisation happens inside a multiply.
//!
//! All functions take row-major slices and *overwrite* `c`. Shared
//! epilogues ([`bias_relu`], [`row_block_checksum`]) run as one extra
//! pass over C — the CDC parity checksum costs a panel pass, not a
//! separate full multiply.
//!
//! The micro-kernel is tier-dispatched (DESIGN.md §15): the macro loop
//! picks the scalar register tile or an explicit-SIMD one
//! ([`super::simd`]) per [`Tier`]. All tiers accumulate in the same
//! order without FMA, so their outputs are bit-identical — callers see
//! one deterministic kernel that just gets faster on wider hardware.

use super::scratch::{with_scratch, Scratch};
use super::simd::{self, Tier};

/// Rows of A per packed panel (multiple of [`MR`]).
pub const MC: usize = 64;
/// Shared (depth) dimension per packed panel.
pub const KC: usize = 256;
/// Columns of B per packed panel (multiple of [`NR`]).
pub const NC: usize = 512;
/// Micro-kernel rows (register tile height).
pub const MR: usize = 4;
/// Micro-kernel columns (register tile width, one/two SIMD lanes).
pub const NR: usize = 8;

/// Below this FLOP count (2mkn) the packed kernel's setup overhead
/// dominates and the naive loop wins.
pub(crate) const TILED_MIN_FLOPS: f64 = 2.0 * 48.0 * 48.0 * 48.0;
/// Above this FLOP count row-partitioned threading pays for the spawn.
pub const THREADED_MIN_FLOPS: f64 = 2.0 * 176.0 * 176.0 * 176.0;

fn check_dims(a: &[f32], b: &[f32], c: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "gemm: lhs length vs ({m},{k})");
    assert_eq!(b.len(), k * n, "gemm: rhs length vs ({k},{n})");
    assert_eq!(c.len(), m * n, "gemm: out length vs ({m},{n})");
}

/// Branch-free naive reference GEMM: `c = a (m,k) @ b (k,n)`, row-major.
/// The oracle the tiled/threaded kernels are property-tested against and
/// the baseline `BENCH_gemm.json` speedups are measured from.
pub fn gemm_naive(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    check_dims(a, b, c, m, k, n);
    c.fill(0.0);
    if n == 0 {
        return;
    }
    for (arow, crow) in a.chunks_exact(k.max(1)).zip(c.chunks_exact_mut(n)).take(m) {
        for (&av, brow) in arow.iter().zip(b.chunks_exact(n)) {
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Heuristic entry point: naive for tiny/degenerate shapes (the serving
/// GEMV case), single-thread tiled in the mid range, row-threaded above
/// [`THREADED_MIN_FLOPS`]. `scratch` feeds the packing panels. The
/// blocked paths run the process-wide active micro-kernel tier
/// ([`simd::select`]), so SIMD flows into the serve hot path without
/// callers changing.
pub fn gemm_auto(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    scratch: &mut Scratch,
) {
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    if n < NR || flops < TILED_MIN_FLOPS {
        gemm_naive(a, b, c, m, k, n);
    } else if flops >= THREADED_MIN_FLOPS && auto_threads() > 1 {
        gemm_threaded(a, b, c, m, k, n, auto_threads());
    } else {
        gemm_tiled_with(a, b, c, m, k, n, scratch, simd::select());
    }
}

/// Cached hardware parallelism for [`gemm_auto`] (capped at 8: the row
/// driver targets small-core edge hosts, not NUMA servers).
pub fn auto_threads() -> usize {
    static N: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *N.get_or_init(|| {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(8)
    })
}

/// Single-threaded blocked GEMM on the **scalar** micro-kernel: `c = a
/// @ b` with MC/KC/NC panel blocking, packed micro-panels, and the
/// [`MR`]`×`[`NR`] register micro-kernel. Packing buffers come from
/// `scratch` (zero steady-state allocations). This is the stable
/// baseline tier benches compare SIMD against; the auto paths use
/// [`gemm_tiled_with`] and the active tier.
pub fn gemm_tiled(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    scratch: &mut Scratch,
) {
    gemm_tiled_with(a, b, c, m, k, n, scratch, Tier::Scalar);
}

/// [`gemm_tiled`] with an explicit micro-kernel tier. Panics if the
/// hardware does not support `tier` (see [`simd::tier_supported`]); use
/// [`simd::select`] for the detected tier.
#[allow(clippy::too_many_arguments)]
pub fn gemm_tiled_with(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    scratch: &mut Scratch,
    tier: Tier,
) {
    check_dims(a, b, c, m, k, n);
    assert!(simd::tier_supported(tier), "micro-kernel tier {tier:?} unsupported here");
    c.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let mut apack = scratch.take(MC * KC);
    let mut bpack = scratch.take(KC * NC);
    let mut jc = 0;
    while jc < n {
        let nc = NC.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kc = KC.min(k - pc);
            pack_b(b, &mut bpack, pc, jc, kc, nc, n);
            let mut ic = 0;
            while ic < m {
                let mc = MC.min(m - ic);
                pack_a(a, &mut apack, ic, pc, mc, kc, k);
                macro_kernel(&apack, &bpack, c, ic, jc, mc, nc, kc, n, tier);
                ic += MC;
            }
            pc += KC;
        }
        jc += NC;
    }
    scratch.put(bpack);
    scratch.put(apack);
}

/// Single-threaded blocked GEMM on the process-wide **active SIMD
/// tier**. Returns `true` when a SIMD micro-kernel actually ran; when
/// no SIMD tier is available (or `CDC_DNN_SIMD=0`) it computes the same
/// result through the scalar tile and returns `false`. Output is
/// bit-identical to [`gemm_tiled`] either way.
pub fn gemm_simd(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    scratch: &mut Scratch,
) -> bool {
    let tier = simd::select();
    gemm_tiled_with(a, b, c, m, k, n, scratch, tier);
    tier != Tier::Scalar
}

/// Multi-threaded blocked GEMM: C's rows are partitioned into up to
/// `threads` contiguous MR-aligned bands, each computed by a scoped
/// worker running the blocked kernel on its slice of A and C (B is
/// shared read-only; workers never synchronise mid-multiply). Runs the
/// active micro-kernel tier; thread partitioning never reassociates the
/// per-element sums, so the result is bit-identical at every thread
/// count.
pub fn gemm_threaded(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    gemm_threaded_with(a, b, c, m, k, n, threads, simd::select());
}

/// [`gemm_threaded`] with an explicit micro-kernel tier.
#[allow(clippy::too_many_arguments)]
pub fn gemm_threaded_with(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
    tier: Tier,
) {
    check_dims(a, b, c, m, k, n);
    assert!(simd::tier_supported(tier), "micro-kernel tier {tier:?} unsupported here");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        c.fill(0.0);
        return;
    }
    let t = threads.max(1).min(m.div_ceil(MR));
    if t <= 1 {
        with_scratch(|sc| gemm_tiled_with(a, b, c, m, k, n, sc, tier));
        return;
    }
    let rows_per = m.div_ceil(t).div_ceil(MR) * MR;
    std::thread::scope(|s| {
        for (ci, cband) in c.chunks_mut(rows_per * n).enumerate() {
            let rows = cband.len() / n;
            let aband = &a[ci * rows_per * k..ci * rows_per * k + rows * k];
            s.spawn(move || {
                let mut sc = Scratch::new();
                gemm_tiled_with(aband, b, cband, rows, k, n, &mut sc, tier);
            });
        }
    });
}

/// Pack an `mc × kc` block of A (at `(ic, pc)`, leading dim `lda`) into
/// MR-row strips: strip `s` stores rows `[s·MR, s·MR+MR)` interleaved by
/// depth (`apack[s·MR·kc + kk·MR + i]`), zero-padded past `mc` so the
/// micro-kernel always runs the full register tile.
pub(crate) fn pack_a(
    a: &[f32],
    apack: &mut [f32],
    ic: usize,
    pc: usize,
    mc: usize,
    kc: usize,
    lda: usize,
) {
    for strip in 0..mc.div_ceil(MR) {
        let base = strip * MR * kc;
        for kk in 0..kc {
            let col = pc + kk;
            for i in 0..MR {
                let row = strip * MR + i;
                apack[base + kk * MR + i] = if row < mc {
                    a[(ic + row) * lda + col]
                } else {
                    0.0
                };
            }
        }
    }
}

/// Pack a `kc × nc` block of B (at `(pc, jc)`, leading dim `ldb`) into
/// NR-column strips: strip `t` stores columns `[t·NR, t·NR+NR)` row by
/// row (`bpack[t·NR·kc + kk·NR + j]`), zero-padded past `nc`.
pub(crate) fn pack_b(
    b: &[f32],
    bpack: &mut [f32],
    pc: usize,
    jc: usize,
    kc: usize,
    nc: usize,
    ldb: usize,
) {
    for strip in 0..nc.div_ceil(NR) {
        let base = strip * NR * kc;
        if (strip + 1) * NR <= nc {
            for kk in 0..kc {
                let src = (pc + kk) * ldb + jc + strip * NR;
                bpack[base + kk * NR..base + (kk + 1) * NR]
                    .copy_from_slice(&b[src..src + NR]);
            }
        } else {
            for kk in 0..kc {
                let src = (pc + kk) * ldb + jc + strip * NR;
                for j in 0..NR {
                    let col = strip * NR + j;
                    bpack[base + kk * NR + j] = if col < nc { b[src + j] } else { 0.0 };
                }
            }
        }
    }
}

/// Multiply one packed A panel by one packed B panel into the C block at
/// `(ic, jc)`, micro-tile by micro-tile, dispatching the micro-kernel
/// for `tier`. The match is loop-invariant, so the branch predicts
/// perfectly; callers guarantee hardware support via
/// [`simd::tier_supported`] before any SIMD tier reaches here.
#[allow(clippy::too_many_arguments)]
pub(crate) fn macro_kernel(
    apack: &[f32],
    bpack: &[f32],
    c: &mut [f32],
    ic: usize,
    jc: usize,
    mc: usize,
    nc: usize,
    kc: usize,
    ldc: usize,
    tier: Tier,
) {
    for jstrip in 0..nc.div_ceil(NR) {
        let jr = jstrip * NR;
        let nr = NR.min(nc - jr);
        let bstrip = &bpack[jstrip * NR * kc..(jstrip + 1) * NR * kc];
        for istrip in 0..mc.div_ceil(MR) {
            let ir = istrip * MR;
            let mr = MR.min(mc - ir);
            let astrip = &apack[istrip * MR * kc..(istrip + 1) * MR * kc];
            let coff = (ic + ir) * ldc + jc + jr;
            let cc = &mut c[coff..];
            match tier {
                Tier::Scalar => micro_kernel(kc, astrip, bstrip, cc, ldc, mr, nr),
                // SAFETY: every caller asserts `simd::tier_supported`
                // before dispatching a SIMD tier (detection happened at
                // runtime), and the packed strips are sized/padded to
                // full MR×NR tiles by `pack_a`/`pack_b`.
                #[cfg(target_arch = "x86_64")]
                Tier::Avx2 => unsafe {
                    simd::avx2::micro_kernel(kc, astrip, bstrip, cc, ldc, mr, nr)
                },
                #[cfg(target_arch = "aarch64")]
                Tier::Neon => unsafe {
                    simd::neon::micro_kernel(kc, astrip, bstrip, cc, ldc, mr, nr)
                },
            }
        }
    }
}

/// The register tile: accumulate `MR × NR` elements of C across the full
/// `kc` depth in local accumulators, then add the live `mr × nr` corner
/// into C. Packed strips are zero-padded, so the accumulation loop has no
/// edge branches and vectorises cleanly.
#[inline(always)]
fn micro_kernel(
    kc: usize,
    astrip: &[f32],
    bstrip: &[f32],
    c: &mut [f32],
    ldc: usize,
    mr: usize,
    nr: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    let astrip = &astrip[..kc * MR];
    let bstrip = &bstrip[..kc * NR];
    for (av, bv) in astrip.chunks_exact(MR).zip(bstrip.chunks_exact(NR)) {
        for (accrow, &ai) in acc.iter_mut().zip(av) {
            for (cv, &bj) in accrow.iter_mut().zip(bv) {
                *cv += ai * bj;
            }
        }
    }
    for (i, accrow) in acc.iter().enumerate().take(mr) {
        let crow = &mut c[i * ldc..i * ldc + nr];
        for (cv, &av) in crow.iter_mut().zip(accrow) {
            *cv += av;
        }
    }
}

/// Shared GEMM epilogue: add a per-row bias column (`bias[i]` to every
/// element of row `i`) and/or clamp at zero, in one pass over C.
pub fn bias_relu(c: &mut [f32], m: usize, n: usize, bias: Option<&[f32]>, relu: bool) {
    assert_eq!(c.len(), m * n, "bias_relu: out length vs ({m},{n})");
    if m == 0 || n == 0 {
        return;
    }
    if let Some(bias) = bias {
        assert_eq!(bias.len(), m, "bias_relu: bias length vs rows {m}");
        for (row, &bv) in c.chunks_exact_mut(n).zip(bias) {
            for v in row {
                *v += bv;
            }
        }
    }
    if relu {
        for v in c.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }
}

/// Fused CDC parity epilogue (DESIGN.md §8): fold the `m × n` result of a
/// stacked-shard GEMM into an `h × n` checksum, `out[r] = Σ_g c[g·h + r]`
/// over the `m / h` uniform row blocks. One extra pass over C replaces
/// the separate parity-weight multiply; the invariant
/// `checksum(W_stacked @ x + b_stacked) == parity_weights(W) @ x + Σb`
/// holds exactly because summation is pre-activation. The fold is
/// column-wise, so with `x` a cross-request micro-batch (`n` = batch
/// width, DESIGN.md §10) one pass yields the parity for every member —
/// parity cost per batch, not per request.
pub fn row_block_checksum(c: &[f32], m: usize, n: usize, h: usize, out: &mut [f32]) {
    assert!(h > 0 && m % h == 0, "checksum rows {h} must divide m {m}");
    assert_eq!(c.len(), m * n, "checksum: in length vs ({m},{n})");
    assert_eq!(out.len(), h * n, "checksum: out length vs ({h},{n})");
    out.fill(0.0);
    if n == 0 {
        return;
    }
    for block in c.chunks_exact(h * n) {
        for (o, &v) in out.iter_mut().zip(block) {
            *o += v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn randv(n: usize, rng: &mut Pcg32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn tiled_matches_naive_mixed_shapes() {
        let mut rng = Pcg32::seeded(3);
        let mut sc = Scratch::new();
        for &(m, k, n) in &[(1, 1, 1), (4, 8, 8), (65, 67, 63), (128, 40, 96)] {
            let a = randv(m * k, &mut rng);
            let b = randv(k * n, &mut rng);
            let mut c0 = vec![0.0; m * n];
            let mut c1 = vec![0.0; m * n];
            gemm_naive(&a, &b, &mut c0, m, k, n);
            gemm_tiled(&a, &b, &mut c1, m, k, n, &mut sc);
            assert!(diff(&c0, &c1) < 1e-4, "({m},{k},{n})");
        }
    }

    #[test]
    fn simd_tier_bitwise_matches_scalar_tiled() {
        // Whatever tier is active, gemm_simd must be bit-identical to
        // the scalar tiled kernel — mul+add ordering is part of the
        // kernel contract (DESIGN.md §15), not a tolerance question.
        let mut rng = Pcg32::seeded(9);
        let mut sc = Scratch::new();
        for &(m, k, n) in &[(4, 8, 8), (65, 300, 63), (128, 512, 96), (31, 700, 9)] {
            let a = randv(m * k, &mut rng);
            let b = randv(k * n, &mut rng);
            let mut c0 = vec![0.0; m * n];
            let mut c1 = vec![1.0; m * n];
            gemm_tiled(&a, &b, &mut c0, m, k, n, &mut sc);
            let ran_simd = gemm_simd(&a, &b, &mut c1, m, k, n, &mut sc);
            assert_eq!(c0, c1, "({m},{k},{n}) simd tier ran: {ran_simd}");
        }
    }

    #[test]
    fn gemm_overwrites_stale_output() {
        let mut sc = Scratch::new();
        let a = vec![1.0, 2.0];
        let b = vec![3.0, 4.0];
        let mut c = vec![99.0];
        gemm_tiled(&a, &b, &mut c, 1, 2, 1, &mut sc);
        assert_eq!(c, vec![11.0]);
        gemm_naive(&a, &b, &mut c, 1, 2, 1);
        assert_eq!(c, vec![11.0]);
    }

    #[test]
    fn zero_depth_yields_zero_output() {
        let mut sc = Scratch::new();
        let mut c = vec![5.0; 6];
        gemm_tiled(&[], &[], &mut c, 2, 0, 3, &mut sc);
        assert!(c.iter().all(|&v| v == 0.0));
        let mut c2 = vec![5.0; 6];
        gemm_threaded(&[], &[], &mut c2, 2, 0, 3, 4);
        assert!(c2.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn bias_relu_epilogue() {
        let mut c = vec![1.0, -2.0, 3.0, -4.0];
        bias_relu(&mut c, 2, 2, Some(&[0.5, -0.5]), true);
        assert_eq!(c, vec![1.5, 0.0, 2.5, 0.0]);
    }

    #[test]
    fn checksum_sums_row_blocks() {
        // 4 rows, h=2: out row r = c row r + c row r+2.
        let c = vec![1.0, 2.0, 3.0, 4.0, 10.0, 20.0, 30.0, 40.0];
        let mut out = vec![0.0; 4];
        row_block_checksum(&c, 4, 2, 2, &mut out);
        assert_eq!(out, vec![11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn checksum_is_columnwise_over_batched_outputs() {
        // The batched-parity invariant (DESIGN.md §10): folding a
        // (m, B) stacked output is column-for-column identical to
        // folding each member column alone — one parity pass covers the
        // whole micro-batch.
        let mut rng = Pcg32::seeded(11);
        let (m, h, b) = (12usize, 4usize, 6usize);
        let c = randv(m * b, &mut rng);
        let mut batched = vec![0.0; h * b];
        row_block_checksum(&c, m, b, h, &mut batched);
        for j in 0..b {
            let col: Vec<f32> = (0..m).map(|r| c[r * b + j]).collect();
            let mut solo = vec![0.0; h];
            row_block_checksum(&col, m, 1, h, &mut solo);
            for r in 0..h {
                assert_eq!(
                    batched[r * b + j],
                    solo[r],
                    "member {j} row {r}: batched fold must equal the solo fold"
                );
            }
        }
    }
}
