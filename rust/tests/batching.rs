//! Batch-boundary integration tests for cross-request micro-batching
//! (DESIGN.md §10), runnable with NO python-built artifacts (synthetic
//! `testkit::synth` model). The three edge cases ISSUE 4 names:
//!
//! * `batch_max = 1` is **bit-exact** with the unbatched (PR-3) serving
//!   engine — same outputs, same virtual timings, same stochastic
//!   draws;
//! * a device crash mid-batch loses **zero** requests under the CDC
//!   arm: the batched parity reconstructs every member at once;
//! * `batch_wait_ms = 0` degenerates to pass-through — a lone request
//!   is never delayed, only already-waiting backlog coalesces.

use cdc_dnn::coordinator::{Session, SessionConfig, SplitSpec, Workload};
use cdc_dnn::fleet::{FailurePlan, NetConfig};
use cdc_dnn::model::Weights;
use cdc_dnn::rng::Pcg32;
use cdc_dnn::runtime::Manifest;
use cdc_dnn::tensor::Tensor;
use cdc_dnn::testkit::synth;

/// mlp over 4 data devices: fc1 CDC split 4 ways, fc2 CDC split 2 ways.
fn cdc_cfg() -> SessionConfig {
    let mut cfg = SessionConfig::new(synth::MODEL);
    cfg.n_devices = 4;
    cfg.net = NetConfig::moderate();
    cfg.splits.insert("fc1".into(), SplitSpec::cdc(4));
    cfg.splits.insert("fc2".into(), SplitSpec::cdc(2));
    cfg.placement.insert("fc1".into(), vec![0, 1, 2, 3]);
    cfg.placement.insert("fc2".into(), vec![0, 1]);
    cfg
}

fn inputs(n: usize, seed: u64) -> Vec<Tensor> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| Tensor::randn(vec![synth::FC1_K], &mut rng)).collect()
}

/// Reference forward pass for the synthetic model.
fn oracle(root: &std::path::Path, x: &Tensor) -> Tensor {
    let m = Manifest::load(root).unwrap();
    let model = m.model(synth::MODEL).unwrap();
    let w = Weights::load(&m, model).unwrap();
    let xc = x.clone().reshape(vec![x.len(), 1]).unwrap();
    let mut h = w.w("fc1").unwrap().matmul(&xc).unwrap();
    h.add_assign(w.b("fc1").unwrap()).unwrap();
    h.relu();
    let mut out = w.w("fc2").unwrap().matmul(&h).unwrap();
    out.add_assign(w.b("fc2").unwrap()).unwrap();
    out
}

/// `batch_max = 1` must be bit-exact with the engine that predates
/// batching: identical outputs, identical virtual timings, identical
/// stochastic draws (the content-addressed order hash is unchanged at
/// width 1), even with a non-zero formation window configured.
#[test]
fn batch_max_one_is_bit_exact_with_unbatched_serving() {
    let synth = synth::build(91).unwrap();
    let run = |batch_max: usize, batch_wait_ms: f64| {
        let mut cfg = cdc_cfg();
        cfg.batch_max = batch_max;
        cfg.batch_wait_ms = batch_wait_ms;
        let mut s = Session::start(&synth.root, cfg).unwrap();
        // Intermittent drops exercise the content-addressed rng path:
        // any change to the draw stream would show up as a different
        // drop pattern.
        s.set_failure(1, FailurePlan::Intermittent(0.4)).unwrap();
        s.serve(&Workload::poisson(inputs(24, 19), 500.0, 5)).unwrap()
    };
    let unbatched = run(1, 0.0); // the PR-3 default configuration
    let gated = run(1, 37.0); // width 1: the window must never arm
    assert_eq!(unbatched.max_batch, 1);
    assert_eq!(gated.max_batch, 1);
    assert_eq!(unbatched.latency.samples(), gated.latency.samples());
    assert_eq!(unbatched.queue_wait.samples(), gated.queue_wait.samples());
    assert_eq!(unbatched.makespan_ms, gated.makespan_ms);
    assert_eq!(
        unbatched.throughput.recovered, gated.throughput.recovered,
        "stochastic draw stream must be unchanged at width 1"
    );
    assert_eq!(unbatched.traces.len(), gated.traces.len());
    for (ta, tb) in unbatched.traces.iter().zip(&gated.traces) {
        assert_eq!(ta.output, tb.output);
        assert_eq!(ta.t_done_ms, tb.t_done_ms);
    }
    for (sa, sb) in unbatched.stages.iter().zip(&gated.stages) {
        assert_eq!(sa.occupancy, sb.occupancy, "stage {}", sa.layer);
        assert_eq!(sa.served, sb.served);
        assert_eq!(sa.batches, sb.served, "width 1: one order per request");
    }
}

/// A device crash that kills whole batches loses zero requests under
/// CDC: one `(h, B)` parity subtraction reconstructs the missing shard
/// for every member, and the outputs stay exact.
#[test]
fn crashed_device_mid_batch_loses_zero_requests_under_cdc() {
    let synth = synth::build(92).unwrap();
    let mut cfg = cdc_cfg();
    cfg.batch_max = 4;
    cfg.batch_wait_ms = 5.0;
    let mut s = Session::start(&synth.root, cfg).unwrap();
    assert_eq!(s.total_devices(), 6, "4 data + fc1 parity + fc2 parity");

    // Device 2 is dead before the first request: every fc1 order —
    // batched or not — loses its shard-2 columns and must recover them
    // from the batched parity.
    s.set_failure(2, FailurePlan::PermanentAt(0)).unwrap();

    // Simultaneous arrivals back the queue up so real batches form.
    let xs = inputs(12, 29);
    let report = s.serve(&Workload::uniform(xs.clone(), 0.0)).unwrap();
    assert_eq!(report.throughput.completed, 12, "{}", report.line());
    assert!(report.failures.is_empty(), "CDC lost a batched request");
    assert_eq!(report.throughput.recovered, 12, "every request recovers");
    assert!(
        report.max_batch >= 2,
        "no batch ever formed (max_batch={}) — the crash was never mid-batch",
        report.max_batch
    );
    let fc1 = &report.stages[0];
    assert!(
        fc1.batches < fc1.served,
        "fc1 dispatched {} orders for {} requests — batching never engaged",
        fc1.batches,
        fc1.served
    );
    for t in &report.traces {
        let x = &xs[t.req as usize];
        let want = oracle(&synth.root, x);
        let diff = t.output.max_abs_diff(&want);
        assert!(diff < 1e-4, "req {}: recovered logits diverge by {diff}", t.req);
    }
}

/// `batch_wait_ms = 0` is pass-through: sparse arrivals are never held
/// back (width stays 1 and the run is bit-exact with `batch_max = 1`),
/// while simultaneous backlog still coalesces without delaying anyone.
#[test]
fn zero_wait_degenerates_to_pass_through() {
    let synth = synth::build(93).unwrap();
    let run = |batch_max: usize, gap_ms: f64| {
        let mut cfg = cdc_cfg();
        cfg.batch_max = batch_max;
        cfg.batch_wait_ms = 0.0;
        let mut s = Session::start(&synth.root, cfg).unwrap();
        s.serve(&Workload::uniform(inputs(8, 39), gap_ms)).unwrap()
    };

    // Sparse stream (gap far above any service time): wide batch_max
    // must change nothing at all.
    let wide = run(8, 5_000.0);
    let narrow = run(1, 5_000.0);
    assert_eq!(wide.max_batch, 1, "a lone request must never wait");
    assert_eq!(wide.latency.samples(), narrow.latency.samples());
    assert_eq!(wide.makespan_ms, narrow.makespan_ms);
    for (ta, tb) in wide.traces.iter().zip(&narrow.traces) {
        assert_eq!(ta.output, tb.output);
        assert_eq!(ta.t_done_ms, tb.t_done_ms);
    }

    // Backlog (all arrivals at t=0): zero wait still coalesces what is
    // already queued — and the head is dispatched at its ready instant.
    let burst = run(8, 0.0);
    assert!(
        burst.max_batch >= 2,
        "backlog should coalesce even at zero wait (max_batch={})",
        burst.max_batch
    );
    assert_eq!(burst.throughput.completed, 8);
    assert!(burst.failures.is_empty());
}

/// Batched serving produces the same answers as sequential inference —
/// batching changes layout and timing, never values.
#[test]
fn batched_outputs_match_sequential_inference() {
    let synth = synth::build(94).unwrap();
    let xs = inputs(10, 49);

    let mut seq = Session::start(&synth.root, cdc_cfg()).unwrap();
    let want: Vec<Tensor> = xs.iter().map(|x| seq.infer(x).unwrap().output).collect();

    let mut cfg = cdc_cfg();
    cfg.batch_max = 5;
    cfg.batch_wait_ms = 10.0;
    let mut batched = Session::start(&synth.root, cfg).unwrap();
    let report = batched.serve(&Workload::uniform(xs, 0.0)).unwrap();
    assert_eq!(report.throughput.completed, 10);
    assert!(report.max_batch >= 2, "batching never engaged");
    for t in &report.traces {
        let diff = t.output.max_abs_diff(&want[t.req as usize]);
        assert!(diff < 1e-5, "req {}: batched output diverges by {diff}", t.req);
    }
}
