//! The scenario executor: segments a script at its event boundaries,
//! serves the inter-event arrivals through the pipelined engine, and
//! applies each event to the live fleet (re-deploying through the
//! `partition` planner on churn). See the module docs and DESIGN.md §9
//! for the event-ordering rules.

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::coordinator::{Session, SessionConfig, SplitSpec, Workload};
use crate::error::{Error, Result};
use crate::fleet::FailurePlan;
use crate::rng::Pcg32;
use crate::runtime::manifest::{Manifest, ModelManifest};
use crate::tensor::Tensor;

use super::{Action, Scenario, ScenarioReport, SegmentReport};

/// Drives [`Scenario`] scripts over a live [`Session`].
///
/// The engine owns the session plus the deployment *template* it was
/// built from; churn events rebuild the session from the template with
/// split degrees re-clamped to what the manifest and the new fleet size
/// support (the re-partitioning path — `partition::LayerPlan` — is the
/// same one `Session::start` always uses).
pub struct ScenarioEngine {
    artifacts: PathBuf,
    model: ModelManifest,
    /// Deployment template; `n_devices` and `net` track the live fleet.
    template: SessionConfig,
    /// Desired split degrees — the ceiling churn re-partitions toward.
    target_splits: BTreeMap<String, SplitSpec>,
    session: Session,
    input_shape: Vec<usize>,
}

/// Template + fleet size → a deployable config: every target split is
/// clamped to the largest manifest-available degree that fits both the
/// target and the fleet.
fn effective_cfg(
    model: &ModelManifest,
    template: &SessionConfig,
    target_splits: &BTreeMap<String, SplitSpec>,
    n_devices: usize,
) -> Result<SessionConfig> {
    let mut cfg = template.clone();
    cfg.n_devices = n_devices;
    cfg.splits.clear();
    for (name, spec) in target_splits {
        let layer = model
            .layers
            .iter()
            .find(|l| l.name == *name)
            .ok_or_else(|| Error::Config(format!("no layer {name} in model")))?;
        let cap = spec.d.min(n_devices);
        let d = layer
            .splits
            .keys()
            .copied()
            .filter(|&d| d <= cap)
            .max()
            .ok_or_else(|| {
                Error::Config(format!(
                    "layer {name} has no split degree ≤ {cap} (available: {:?})",
                    layer.splits.keys().collect::<Vec<_>>()
                ))
            })?;
        cfg.splits
            .insert(name.clone(), SplitSpec { d, redundancy: spec.redundancy });
    }
    Ok(cfg)
}

impl ScenarioEngine {
    /// Deploy `cfg` over the artifact set at `artifacts` and wrap it for
    /// scenario execution. `cfg.splits` records the *target* degrees that
    /// churn events re-partition toward.
    pub fn new(artifacts: impl Into<PathBuf>, cfg: SessionConfig) -> Result<ScenarioEngine> {
        let artifacts = artifacts.into();
        let manifest = Manifest::load(&artifacts)?;
        let model = manifest.model(&cfg.model)?.clone();
        let target_splits = cfg.splits.clone();
        let template = cfg;
        let deploy = effective_cfg(&model, &template, &target_splits, template.n_devices)?;
        let session = Session::start(&artifacts, deploy)?;
        let input_shape = model.input_shape.clone();
        Ok(ScenarioEngine {
            artifacts,
            model,
            template,
            target_splits,
            session,
            input_shape,
        })
    }

    /// The live serving session.
    pub fn session(&self) -> &Session {
        &self.session
    }

    /// Current number of data devices (redundancy devices come on top).
    pub fn fleet_size(&self) -> usize {
        self.template.n_devices
    }

    /// Execute one scenario to quiescence and return the merged report.
    ///
    /// Event ordering (DESIGN.md §9): events apply in `at_ms` order (ties
    /// broken by script order); each inter-event segment's arrivals are
    /// generated from the scenario seed and served until every request
    /// resolves *before* the next event applies — an event therefore
    /// never interrupts a request mid-stage, it changes the regime for
    /// the requests that arrive after it. When a segment drains *past*
    /// the next scheduled boundary, the following segment starts at the
    /// drain instant (the event's effective application point is the
    /// earliest quiescent instant ≥ its scheduled time), so segment
    /// timelines never overlap and `ScenarioReport::rps` is measured
    /// against the true serialized span.
    pub fn run(&mut self, sc: &Scenario) -> Result<ScenarioReport> {
        let mut order: Vec<usize> = (0..sc.events.len()).collect();
        order.sort_by(|&a, &b| {
            sc.events[a]
                .at_ms
                .partial_cmp(&sc.events[b].at_ms)
                .unwrap_or(std::cmp::Ordering::Equal)
        });

        let mut report = ScenarioReport {
            scenario: sc.name.clone(),
            completed: 0,
            failed: 0,
            recovered: 0,
            dropped: 0,
            latency: crate::metrics::Series::new(),
            makespan_ms: 0.0,
            segments: Vec::new(),
            rebuilds: 0,
            max_batch: 1,
            policy: None,
        };
        // Apply the scenario's declared starting regime to the live
        // fleet (a no-op when the deployment template already matches,
        // as `exp::scenarios::arm_cfg` arranges). Stage `expected_ms`
        // estimates keep their deployment-time values — the adaptive
        // policy absorbs the drift (DESIGN.md §9).
        self.template.net = sc.initial_net.config();
        self.session.set_net(sc.initial_net.config())?;
        if let Some(r) = sc.device_rate {
            self.template.device_rate = r;
            for d in 0..self.session.total_devices() {
                self.session.set_device_rate(d, r)?;
            }
        }

        let mut rng = Pcg32::new(sc.seed, 0x5ce0);
        let mut rate = sc.base_rate_rps;
        let mut burst = 0usize;
        // Scheduled boundary (drives arrival-span generation) vs the
        // effective timeline instant (pushed forward when a segment
        // drains past its boundary — segments never overlap).
        let mut t0 = 0.0f64;
        let mut drain = 0.0f64;

        for &ei in &order {
            let ev = &sc.events[ei];
            let t1 = ev.at_ms.clamp(t0, sc.duration_ms);
            drain = self.run_segment(
                &mut report,
                &mut rng,
                t0.max(drain),
                t1 - t0,
                rate,
                std::mem::take(&mut burst),
                Some(ev.action.label()),
            )?;
            self.apply(&ev.action, &mut rate, &mut burst, &mut report)?;
            t0 = t1;
        }
        // Final segment: from the last event to the horizon.
        self.run_segment(
            &mut report,
            &mut rng,
            t0.max(drain),
            sc.duration_ms - t0,
            rate,
            std::mem::take(&mut burst),
            None,
        )?;
        report.policy = self.session.policy_snapshot();
        Ok(report)
    }

    /// Serve one inter-event segment: `span` ms of arrivals, admitted on
    /// the scenario timeline starting at the effective instant `t0`.
    /// Returns the instant the segment drained (`t0` if it was empty).
    #[allow(clippy::too_many_arguments)]
    fn run_segment(
        &mut self,
        report: &mut ScenarioReport,
        rng: &mut Pcg32,
        t0: f64,
        span: f64,
        rate_rps: f64,
        burst: usize,
        event: Option<String>,
    ) -> Result<f64> {
        let span = span.max(0.0);
        // Burst arrivals land at the segment's first instant; the Poisson
        // stream fills the rest of the span at the current rate.
        let mut at: Vec<f64> = vec![0.0; burst];
        if rate_rps > 0.0 && span > 0.0 {
            let per_ms = rate_rps / 1000.0;
            let mut t = rng.exponential(per_ms);
            while t < span {
                at.push(t);
                t += rng.exponential(per_ms);
            }
        }
        at.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let arrivals = at.len();
        let mut seg = SegmentReport {
            t_start_ms: t0,
            arrivals,
            completed: 0,
            failed: 0,
            recovered: 0,
            dropped: 0,
            p99_ms: 0.0,
            event,
        };
        let mut drained = t0;
        if arrivals > 0 {
            let inputs: Vec<Tensor> = (0..arrivals)
                .map(|_| Tensor::randn(self.input_shape.clone(), rng))
                .collect();
            let r = self.session.serve(&Workload::explicit(inputs, at))?;
            seg.completed = r.throughput.completed;
            seg.failed = r.throughput.failed;
            seg.recovered = r.throughput.recovered;
            seg.dropped = r.dropped;
            seg.p99_ms = r.latency.summary().p99;
            report.completed += r.throughput.completed;
            report.failed += r.throughput.failed;
            report.recovered += r.throughput.recovered;
            report.dropped += r.dropped;
            for &s in r.latency.samples() {
                report.latency.record(s);
            }
            report.max_batch = report.max_batch.max(r.max_batch);
            drained = t0 + r.makespan_ms;
            report.makespan_ms = report.makespan_ms.max(drained);
        }
        report.segments.push(seg);
        Ok(drained)
    }

    /// Apply one event to the live fleet/workload state.
    fn apply(
        &mut self,
        action: &Action,
        rate: &mut f64,
        burst: &mut usize,
        report: &mut ScenarioReport,
    ) -> Result<()> {
        match action {
            Action::Crash { device } => {
                self.session.set_failure(*device, FailurePlan::PermanentAt(0))
            }
            // On the simulator an abrupt kill is indistinguishable from a
            // permanent crash; the TCP runner turns it into a real SIGKILL.
            Action::Kill { device } => {
                self.session.set_failure(*device, FailurePlan::PermanentAt(0))
            }
            Action::Recover { device } => {
                self.session.set_failure(*device, FailurePlan::None)
            }
            Action::Flaky { device, p } => {
                self.session.set_failure(*device, FailurePlan::Intermittent(*p))
            }
            Action::Net { profile } => {
                self.template.net = profile.config();
                self.session.set_net(profile.config())
            }
            Action::Slowdown { device, factor } => {
                let slowed = self.template.device_rate * factor;
                self.session.set_device_rate(*device, slowed)
            }
            Action::Rate { rps } => {
                *rate = *rps;
                Ok(())
            }
            Action::Burst { n } => {
                *burst += n;
                Ok(())
            }
            Action::Join { n } => self.rebuild(self.template.n_devices + n, report),
            Action::Leave { n } => {
                let cur = self.template.n_devices;
                if *n >= cur {
                    return Err(Error::Config(format!(
                        "cannot shrink a {cur}-device fleet by {n}"
                    )));
                }
                self.rebuild(cur - n, report)
            }
        }
    }

    /// Churn re-deployment: re-partition every split layer for the new
    /// fleet size and start a fresh session from the template. Transient
    /// fleet state (failure plans, slowdowns, adaptive-policy windows)
    /// resets — a re-provisioned fleet starts clean; the WLAN regime is
    /// part of the template and survives.
    fn rebuild(&mut self, n_devices: usize, report: &mut ScenarioReport) -> Result<()> {
        // Explicit placements are only meaningful for the original fleet.
        self.template.placement.clear();
        self.template.n_devices = n_devices;
        let cfg = effective_cfg(&self.model, &self.template, &self.target_splits, n_devices)?;
        self.session = Session::start(&self.artifacts, cfg)?;
        report.rebuilds += 1;
        Ok(())
    }
}
