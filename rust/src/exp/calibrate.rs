//! Calibration report — the §6 anchors that pin the simulator to the
//! paper's testbed: fc-2048 = 50 ms on one RPi-class device; WiFi
//! 94.1 Mbps / 0.3 ms; Fig.-1 CDF anchors of the latency model.

use crate::error::Result;
use crate::fleet::{NetConfig, RPI_MACS_PER_MS};
use crate::json::{obj, Value};
use crate::metrics::Series;
use crate::rng::Pcg32;

use super::{print_table, ExpCtx};

/// Print + persist the calibration table.
pub fn run(ctx: &ExpCtx) -> Result<()> {
    let fc2048_ms = (2048.0 * 2048.0) / RPI_MACS_PER_MS;
    let net = NetConfig::default();
    let mut rng = Pcg32::seeded(ctx.seed);
    let mut s = Series::new();
    for _ in 0..20_000 {
        s.record(net.sample(8 * 1024, &mut rng) + 50.0);
    }
    let rows = vec![
        vec!["fc-2048 on one device".into(), format!("{fc2048_ms:.1} ms"), "50 ms".into()],
        vec!["WiFi bandwidth".into(), format!("{} Mbps", net.bandwidth_mbps), "94.1 Mbps".into()],
        vec!["client-to-client base".into(), format!("{} ms", net.base_ms), "0.3 ms".into()],
        vec![
            "response CDF @100 ms".into(),
            format!("{:.1}%", 100.0 * s.cdf_at(100.0)),
            "~34%".into(),
        ],
        vec![
            "response CDF @150 ms".into(),
            format!("{:.1}%", 100.0 * s.cdf_at(150.0)),
            "~42%".into(),
        ],
    ];
    println!("\n=== Calibration vs paper §2/§6 anchors ===");
    print_table(&["quantity", "simulator", "paper"], &rows);

    ctx.write_result(
        "calibrate",
        &obj(vec![
            ("fc2048_ms", Value::Num(fc2048_ms)),
            ("cdf_100ms", Value::Num(s.cdf_at(100.0))),
            ("cdf_150ms", Value::Num(s.cdf_at(150.0))),
        ]),
    )?;
    Ok(())
}
