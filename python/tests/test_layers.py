"""L2 layer forwards vs oracles: the Pallas-backed conv/fc path must agree
with the pure-jnp reference AND with the batched training forward — the
latter guarantees trained weights transfer exactly to the inference path."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import layers
from compile.kernels.ref import conv2d_ref, gemm_ref, maxpool_ref
from compile.model import filters_to_matrix, forward, init_params
from compile.train import batched_forward
from compile.zoo import LENET5, ZOO, layer_io_shapes

RNG = np.random.default_rng(1)


def randn(*shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


@pytest.mark.parametrize("h,w,c,k,f,stride", [
    (8, 8, 3, 4, 3, 1),
    (7, 5, 2, 3, 3, 1),
    (12, 12, 1, 6, 5, 1),
    (8, 8, 4, 4, 3, 2),
])
def test_conv2d_matches_ref(h, w, c, k, f, stride):
    x = randn(h, w, c)
    wt = randn(k, f, f, c)
    b = randn(k)
    got = layers.conv2d(wt, b, x, stride=stride, relu=True)
    want = conv2d_ref(x, wt, b, stride=stride, relu=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)


def test_conv2d_valid_padding():
    x = randn(9, 9, 2)
    wt = randn(3, 3, 3, 2)
    got = layers.conv2d(wt, None, x, padding="VALID", relu=False)
    want = conv2d_ref(x, wt, None, padding="VALID", relu=False)
    assert got.shape == (7, 7, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)


def test_fc_matches_ref():
    w, b, x = randn(12, 30), randn(12), randn(30, 1)
    got = layers.fc(w, b, x, relu=True)
    want = gemm_ref(w, x, b.reshape(-1, 1), relu=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_maxpool_matches_ref():
    x = randn(6, 6, 4)
    np.testing.assert_allclose(
        np.asarray(layers.maxpool(x)), np.asarray(maxpool_ref(x)), rtol=1e-6
    )


def test_filters_to_matrix_order_matches_im2col():
    """W·im2col(x) must equal the true conv — the feature orders of the
    filter matrix and the patch matrix have to agree."""
    x = randn(5, 5, 3)
    wt = randn(2, 3, 3, 3)
    cols, (oh, ow) = layers.im2col(x, 3, 3, 1, "SAME")
    wmat = layers.filters_to_matrix(wt)
    via_gemm = (wmat @ cols).reshape(2, oh, ow).transpose(1, 2, 0)
    want = conv2d_ref(x, wt, None, relu=False)
    np.testing.assert_allclose(np.asarray(via_gemm), np.asarray(want), rtol=1e-3, atol=1e-3)
    # numpy twin used by the weight emitter must agree with the jax one.
    np.testing.assert_allclose(filters_to_matrix(np.asarray(wt)), np.asarray(wmat))


@pytest.mark.parametrize("name", list(ZOO))
def test_zoo_shapes_propagate(name):
    model = ZOO[name]
    shapes = layer_io_shapes(model)
    assert len(shapes) == len(model.layers)
    assert shapes[-1][1] == (model.classes,)


def test_full_forward_matches_batched_forward():
    """Single-example Pallas path == batched jnp training path, so trained
    weights transfer exactly (DESIGN.md §3)."""
    params = init_params(LENET5, seed=3)
    x = randn(28, 28, 1)
    single = forward(LENET5, params, x)
    jp = {k: (jnp.asarray(w), jnp.asarray(b)) for k, (w, b) in params.items()}
    batched = batched_forward(LENET5, jp, x[None])[0]
    np.testing.assert_allclose(np.asarray(single), np.asarray(batched), rtol=1e-3, atol=1e-3)
