//! Transport-loopback bench (DESIGN.md §11): the full serving engine
//! over **real TCP worker processes** on 127.0.0.1, measuring
//! wall-clock rps / p50 / p99 — steady, and with one worker SIGKILLed
//! mid-run (the CDC arm must finish with zero lost requests, the
//! paper's invariant on real sockets). A virtual-time sim arm runs the
//! same deployment for reference.
//!
//! Workers run RPi-style emulated compute (`--rate`) so loopback
//! numbers reflect the serving machinery, not a laptop GEMM finishing
//! in microseconds; the arrival rate oversubscribes the emulated
//! capacity, so the measured rps is the saturated (stable) throughput.
//!
//! `TRANSPORT_BENCH_SMOKE=1` scales the stream down for CI;
//! `BENCH_BASELINE_ENFORCE=1` gates the headline metrics against the
//! committed seed in `rust/baselines/BENCH_transport.json`
//! (bootstrap-empty until promoted from CI artifacts).
//!
//! Run with `cargo bench --bench transport_loopback`.

use std::path::{Path, PathBuf};
use std::time::Instant;

use cdc_dnn::bench::guard_baseline;
use cdc_dnn::coordinator::{Session, SessionConfig, SplitSpec, Workload};
use cdc_dnn::json::{obj, Value};
use cdc_dnn::rng::Pcg32;
use cdc_dnn::tensor::Tensor;
use cdc_dnn::testkit::synth;
use cdc_dnn::transport::loopback::LoopbackFleet;
use cdc_dnn::transport::{TcpConfig, TransportSpec};

const SEED: u64 = 2021;
/// Emulated worker compute rate (MACs/ms): a synth fc1 shard order
/// costs ~5 ms, putting loopback service times in RPi territory.
const WORKER_RATE: f64 = 20.0;
const ARRIVAL_RPS: f64 = 120.0;

fn bench_out_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_transport.json"))
        .unwrap_or_else(|| PathBuf::from("BENCH_transport.json"))
}

/// mlp over 2 data devices, both layers parity-coded (4 devices total),
/// micro-batching on — the CDC serving arm.
fn cdc_cfg() -> SessionConfig {
    let mut cfg = SessionConfig::new(synth::MODEL);
    cfg.n_devices = 2;
    cfg.splits.insert("fc1".into(), SplitSpec::cdc(2));
    cfg.splits.insert("fc2".into(), SplitSpec::cdc(2));
    cfg.seed = SEED;
    cfg.detection_ms = 500.0;
    cfg.batch_max = 4;
    cfg.batch_wait_ms = 2.0;
    cfg
}

fn inputs(n: usize, seed: u64) -> Vec<Tensor> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| Tensor::randn(vec![synth::FC1_K], &mut rng)).collect()
}

struct ArmResult {
    completed: u64,
    failed: usize,
    recovered: u64,
    rps: f64,
    p50: f64,
    p99: f64,
    makespan_ms: f64,
    max_batch: usize,
}

fn run_arm(
    arts: &Path,
    cfg: SessionConfig,
    n: usize,
    kill: Option<(&LoopbackFleet, usize, u64)>,
) -> ArmResult {
    let mut session = Session::start(arts, cfg).expect("deploy");
    let killer = kill.map(|(fleet, victim, at_ms)| fleet.kill_after(victim, at_ms));
    let report = session
        .serve(&Workload::poisson(inputs(n, SEED), ARRIVAL_RPS, SEED))
        .expect("serve");
    if let Some(k) = killer {
        k.join().expect("chaos thread");
    }
    let s = report.latency.summary();
    ArmResult {
        completed: report.throughput.completed,
        failed: report.failures.len(),
        recovered: report.throughput.recovered,
        rps: report.rps(),
        p50: s.p50,
        p99: s.p99,
        makespan_ms: report.makespan_ms,
        max_batch: report.max_batch,
    }
}

fn main() {
    let smoke = std::env::var("TRANSPORT_BENCH_SMOKE").is_ok();
    println!(
        "transport_loopback: compute backend = {}, smoke = {smoke}",
        cdc_dnn::runtime::backend_label()
    );
    let arts = synth::build(SEED).expect("synthetic artifacts");
    let worker_bin = Path::new(env!("CARGO_BIN_EXE_cdc-dnn"));
    let n = if smoke { 100 } else { 300 };
    // Kill ~30% into the expected (saturated) makespan.
    let kill_at_ms = if smoke { 300 } else { 900 };

    let t0 = Instant::now();
    let mut rows = Vec::new();
    let mut headline: Vec<(String, f64)> = Vec::new();
    let mode = if smoke { "smoke" } else { "full" };

    // ---- arm 1: virtual-time sim reference ---------------------------
    let sim = run_arm(&arts.root, cdc_cfg(), n, None);
    println!(
        "  sim-steady:  completed={} failed={} rps={:.1} (virtual) p50={:.1}ms p99={:.1}ms",
        sim.completed, sim.failed, sim.rps, sim.p50, sim.p99
    );
    assert_eq!(sim.failed, 0, "sim CDC arm lost requests");

    // ---- arm 2: tcp-steady over a loopback worker fleet --------------
    let fleet = LoopbackFleet::spawn(Some(worker_bin), &arts.root, 4, Some(WORKER_RATE))
        .expect("spawn loopback fleet");
    let mut cfg = cdc_cfg();
    let mut tcp: TcpConfig = fleet.tcp_config();
    tcp.order_deadline_ms = 1_000.0;
    cfg.transport = TransportSpec::Tcp(tcp);
    let steady = run_arm(&arts.root, cfg, n, None);
    drop(fleet);
    println!(
        "  tcp-steady:  completed={} failed={} rps={:.1} (wall) p50={:.1}ms \
         p99={:.1}ms max_batch={}",
        steady.completed, steady.failed, steady.rps, steady.p50, steady.p99,
        steady.max_batch
    );
    assert_eq!(steady.failed, 0, "tcp CDC arm lost requests under steady load");
    assert_eq!(steady.completed, n as u64, "tcp arm must complete the stream");

    // ---- arm 3: tcp + SIGKILL one data worker mid-run ----------------
    let fleet = LoopbackFleet::spawn(Some(worker_bin), &arts.root, 4, Some(WORKER_RATE))
        .expect("spawn loopback fleet");
    let mut cfg = cdc_cfg();
    let mut tcp: TcpConfig = fleet.tcp_config();
    tcp.order_deadline_ms = 1_000.0;
    cfg.transport = TransportSpec::Tcp(tcp);
    let kill = run_arm(&arts.root, cfg, n, Some((&fleet, 1, kill_at_ms)));
    drop(fleet);
    println!(
        "  tcp-kill:    completed={} failed={} recovered={} rps={:.1} (wall) \
         p50={:.1}ms p99={:.1}ms",
        kill.completed, kill.failed, kill.recovered, kill.rps, kill.p50, kill.p99
    );
    // The acceptance invariant (ISSUE 5): killing one worker mid-run
    // loses ZERO requests on the CDC arm.
    assert_eq!(
        kill.failed, 0,
        "CDC arm lost requests after a worker SIGKILL"
    );
    assert_eq!(kill.completed, n as u64, "kill arm must complete the stream");
    assert!(
        kill.recovered > 0,
        "the kill landed after the run — no recovery was exercised"
    );

    let wall_ms = t0.elapsed().as_secs_f64() * 1000.0;
    for (label, r) in
        [("sim-steady", &sim), ("tcp-steady", &steady), ("tcp-kill", &kill)]
    {
        rows.push(obj(vec![
            ("arm", Value::Str(label.into())),
            ("requests", Value::Num(n as f64)),
            ("arrival_rps", Value::Num(ARRIVAL_RPS)),
            ("completed", Value::Num(r.completed as f64)),
            ("failed", Value::Num(r.failed as f64)),
            ("recovered", Value::Num(r.recovered as f64)),
            ("rps", Value::Num(r.rps)),
            ("p50_ms", Value::Num(r.p50)),
            ("p99_ms", Value::Num(r.p99)),
            ("makespan_ms", Value::Num(r.makespan_ms)),
            ("max_batch", Value::Num(r.max_batch as f64)),
        ]));
    }
    headline.push((format!("{mode}_tcp_steady_rps"), steady.rps));
    headline.push((format!("{mode}_tcp_kill_rps"), kill.rps));

    let doc = obj(vec![
        ("experiment", Value::Str("bench_transport_loopback".into())),
        ("backend", Value::Str(cdc_dnn::runtime::backend_label().into())),
        ("transport", Value::Str("tcp-loopback".into())),
        ("smoke", Value::Bool(smoke)),
        ("worker_rate_macs_per_ms", Value::Num(WORKER_RATE)),
        ("suite_wall_ms", Value::Num(wall_ms)),
        ("points", Value::Arr(rows)),
    ]);
    let out = bench_out_path();
    std::fs::write(&out, doc.to_string_pretty()).expect("write BENCH_transport.json");
    println!("[result] wrote {}", out.display());

    // Wall-clock rps over loopback is machine-dependent; CI seeds are
    // promoted from CI's own smoke artifacts and compare like-to-like
    // (the saturated regime keeps them stable across runs).
    guard_baseline("transport", &headline);
}
