//! Simulated IoT fleet: device threads with real PJRT compute and a
//! simulated RPi/WiFi timing model.
//!
//! Each [`Device`] is an OS thread holding its deployed tasks (artifact
//! name + its weight shard — the paper's "all weights on the SD card"
//! model) and a per-device RNG stream. On a [`WorkOrder`] it *really*
//! executes its shard through the shared PJRT compute server, then stamps
//! the completion with a **simulated** arrival time:
//!
//! ```text
//! start   = max(t_dispatch + net(request bytes), not_before)
//! arrival = start + Σ compute(tasks) + net(reply)
//! compute(task) = batch · task.macs / rate_macs_per_ms   (RPi-calibrated)
//! ```
//!
//! `batch` is the order's cross-request micro-batch width (DESIGN.md
//! §10): MACs and reply bytes scale linearly with the member count,
//! while the per-order fixed costs — the request transfer leg and the
//! reply's base latency/jitter draw — are paid once per *batch* instead
//! of once per request. `batch = 1` reproduces the classic formula
//! bit-for-bit.
//!
//! `not_before` is the coordinator-side device-occupancy ledger (see
//! `coordinator::serve`): with many requests in flight a device may hold
//! work for several of them at once, and its compute must serialise in
//! *virtual* time too. Single-shot inference always dispatches a stage
//! after the previous one completed, so the ledger never clamps there and
//! the classic formula is unchanged.
//!
//! Failures (permanent or intermittent) null the result; in virtual-time
//! mode the completion is still delivered with `t_arrival = ∞` so the
//! coordinator's policy layer sees the full arrival picture and stays
//! deterministic. This keeps the *code path* identical to a lossy network
//! while making every experiment reproducible from a seed.
//!
//! ## Content-addressed randomness
//!
//! Every stochastic draw a device makes for one [`WorkOrder`] — the
//! intermittent-failure drop decision and the per-reply WiFi jitter —
//! comes from a stream that is a pure function of `(session seed, device
//! id, first task id, input activation bits)`. No draw state survives
//! between orders, so a repeated `Pipeline::run` of the same workload
//! replays the same drop/jitter pattern bit-for-bit, and a sequence of
//! single-shot `infer` calls is draw-for-draw identical to the same
//! inputs served as one concurrency-1 workload (the activations feeding
//! each stage are the same bits either way). `FailurePlan::PermanentAt`
//! intentionally keys on the *global* request counter instead, so
//! "device dies at the k-th request of this session" keeps its meaning
//! across runs.
//!
//! The flip side of content addressing: two orders with *bit-identical*
//! inputs draw identically — `Intermittent(p)` then drops both replies
//! or neither, not independently. Feed distinct inputs (every workload
//! generator and experiment in this repo does) when statistical
//! independence across requests matters.

pub mod net;

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::kernels;
use crate::rng::Pcg32;
use crate::runtime::server::ComputeHandle;
use crate::tensor::Tensor;
pub use net::NetConfig;

/// RPi 3B compute rate, calibrated to the paper's §6 anchor: a 2048-wide
/// fc layer (2048² MACs) takes 50 ms on one device.
pub const RPI_MACS_PER_MS: f64 = (2048.0 * 2048.0) / 50.0;

/// Failure behaviour of one device (paper §2: devices become busy, lose
/// connectivity, or disappear).
#[derive(Debug, Clone, Default)]
pub enum FailurePlan {
    /// Healthy device.
    #[default]
    None,
    /// Device dies permanently at the given request index.
    PermanentAt(u64),
    /// Each task reply is independently lost with this probability
    /// (short disconnects / user interaction).
    Intermittent(f64),
}

impl FailurePlan {
    /// Does this device drop the reply for request `req`? `rng` is the
    /// order's content-addressed stream (see the module docs), so the
    /// intermittent draw never depends on how many orders ran before.
    pub fn drops(&self, req: u64, rng: &mut Pcg32) -> bool {
        match self {
            FailurePlan::None => false,
            FailurePlan::PermanentAt(at) => req >= *at,
            FailurePlan::Intermittent(p) => rng.bernoulli(*p),
        }
    }
}

/// Static description of one simulated device.
#[derive(Debug, Clone)]
pub struct DeviceConfig {
    pub id: usize,
    /// Compute rate in MACs/ms (default: RPi 3B).
    pub rate_macs_per_ms: f64,
    pub failure: FailurePlan,
}

impl DeviceConfig {
    /// A healthy RPi-class device.
    pub fn rpi(id: usize) -> DeviceConfig {
        DeviceConfig { id, rate_macs_per_ms: RPI_MACS_PER_MS, failure: FailurePlan::None }
    }
}

/// A deployed task: one shard of one layer.
#[derive(Debug, Clone)]
pub struct TaskDef {
    /// Unique id within the session.
    pub id: u64,
    /// Artifact to execute.
    pub artifact: String,
    /// This shard's weight slice (w, b) — resident on the device and
    /// shared (`Arc`) with the coordinator's failover copy: a 4096² fc
    /// shard is 64 MiB, so weights must never be deep-copied per request.
    pub w: Arc<Tensor>,
    pub b: Arc<Tensor>,
    /// Cost model inputs.
    pub macs: u64,
    pub reply_bytes: u64,
    /// Deploy-time packed weight panels (DESIGN.md §15): built once by
    /// [`TaskDef::prepare`], shared `Arc` like the weights, so the serve
    /// hot path never re-packs. `None` for shapes the blocked kernel
    /// would never take, or before `prepare` ran.
    pub packed: Option<Arc<kernels::PackedWeights>>,
    /// Int8-quantized weights for `precision = int8` fc deployments;
    /// execution uses these (plus `b`) and ignores `w`, which stays as
    /// the coordinator's f32 reference for repartitioning.
    pub quant: Option<Arc<kernels::QuantWeights>>,
}

impl TaskDef {
    /// A bare f32 task; call [`TaskDef::prepare`] to attach the
    /// deploy-time kernel state.
    pub fn new(
        id: u64,
        artifact: impl Into<String>,
        w: Arc<Tensor>,
        b: Arc<Tensor>,
        macs: u64,
        reply_bytes: u64,
    ) -> TaskDef {
        TaskDef {
            id,
            artifact: artifact.into(),
            w,
            b,
            macs,
            reply_bytes,
            packed: None,
            quant: None,
        }
    }

    /// Deploy-time kernel preparation: quantize fc shards when the
    /// deployment asks for int8, otherwise pack the weight panels once
    /// so per-call packing disappears from the hot path (only when the
    /// shape can ever take the blocked kernel — see
    /// [`kernels::PackedWeights::pays_off`]). `is_fc` comes from the
    /// layer kind: conv shards always stay f32 (their im2col GEMM still
    /// benefits from packing).
    pub fn prepare(mut self, precision: kernels::Precision, is_fc: bool) -> TaskDef {
        let dims = self.w.shape();
        let (m, k) = match dims {
            [m, k] => (*m, *k),
            _ => return self,
        };
        if precision == kernels::Precision::Int8 && is_fc {
            self.quant = Some(Arc::new(kernels::QuantWeights::quantize(self.w.data(), m, k)));
            self.packed = None;
        } else if kernels::PackedWeights::pays_off(m, k) {
            self.packed = Some(Arc::new(kernels::PackedWeights::pack(self.w.data(), m, k)));
        }
        self
    }
}

/// One layer's work for one device (may contain several tasks after a
/// failover reassignment — they execute serially, which is exactly the
/// paper's Case-Study-I slowdown mechanism).
#[derive(Debug)]
pub struct WorkOrder {
    /// Leader request id: for a batched order, the first member's id.
    /// Completions route and `FailurePlan::PermanentAt` keys on it.
    pub req: u64,
    /// Task ids to run, in order.
    pub tasks: Vec<u64>,
    /// Activation input. For a batched order this is the column
    /// concatenation of `batch` member activations, `(k, batch)`.
    pub input: Arc<Tensor>,
    /// Request-leg payload bytes (already scaled by `batch`).
    pub request_bytes: u64,
    /// Cross-request micro-batch width: how many member requests this
    /// order's input columns carry. Compute and reply bytes scale
    /// linearly with it; 1 = the classic unbatched order.
    pub batch: usize,
    /// Simulated dispatch timestamp (ms).
    pub t_dispatch_ms: f64,
    /// Virtual instant the device's compute becomes free (coordinator
    /// occupancy ledger); compute starts no earlier. 0.0 = idle device.
    pub not_before_ms: f64,
    /// Live-membership partition epoch the order was formed under
    /// (DESIGN.md §13): the serve engine discards replies tagged with an
    /// older epoch than the current partition. Always 0 on the simulator,
    /// whose membership never changes.
    pub epoch: u64,
}

/// A task completion event.
#[derive(Debug)]
pub struct Completion {
    pub req: u64,
    pub task: u64,
    pub device: usize,
    /// None when the reply was lost (failure/drop).
    pub result: Option<Tensor>,
    /// Simulated arrival time at the coordinator (ms); ∞ when lost.
    pub t_arrival_ms: f64,
}

enum ToDevice {
    Deploy(Vec<TaskDef>),
    Undeploy(Vec<u64>),
    Work(WorkOrder),
    SetFailure(FailurePlan),
    SetNet(NetConfig),
    SetRate(f64),
}

/// Handle to a running device thread.
pub struct Device {
    pub id: usize,
    tx: Sender<ToDevice>,
    join: Option<JoinHandle<()>>,
}

impl Device {
    /// Spawn a device thread.
    ///
    /// `completions` is the shared channel back to the coordinator;
    /// `compute` is the PJRT compute-server handle; `net`/`cfg` drive the
    /// timing model; `seed` makes the device's stochastic behaviour
    /// reproducible.
    pub fn spawn(
        cfg: DeviceConfig,
        net: NetConfig,
        seed: u64,
        compute: ComputeHandle,
        completions: Sender<Completion>,
    ) -> Result<Device> {
        let (tx, rx) = channel();
        let id = cfg.id;
        let join = std::thread::Builder::new()
            .name(format!("device-{id}"))
            .spawn(move || device_main(cfg, net, seed, compute, rx, completions))
            .map_err(|e| Error::Fleet(format!("spawn device {id}: {e}")))?;
        Ok(Device { id, tx, join: Some(join) })
    }

    /// Install tasks (weights included) on the device.
    pub fn deploy(&self, tasks: Vec<TaskDef>) -> Result<()> {
        self.send(ToDevice::Deploy(tasks))
    }

    /// Remove tasks from the device.
    pub fn undeploy(&self, task_ids: Vec<u64>) -> Result<()> {
        self.send(ToDevice::Undeploy(task_ids))
    }

    /// Dispatch one layer's work.
    pub fn dispatch(&self, order: WorkOrder) -> Result<()> {
        self.send(ToDevice::Work(order))
    }

    /// Change the failure plan mid-experiment (case studies flip this).
    pub fn set_failure(&self, plan: FailurePlan) -> Result<()> {
        self.send(ToDevice::SetFailure(plan))
    }

    /// Swap the device's network timing model mid-experiment (the
    /// scenario engine's WLAN-regime events). Applies to later orders.
    pub fn set_net(&self, net: NetConfig) -> Result<()> {
        self.send(ToDevice::SetNet(net))
    }

    /// Change the device's compute rate (MACs/ms) mid-experiment —
    /// heterogeneous fleets and scenario slowdown events.
    pub fn set_rate(&self, rate_macs_per_ms: f64) -> Result<()> {
        self.send(ToDevice::SetRate(rate_macs_per_ms))
    }

    fn send(&self, msg: ToDevice) -> Result<()> {
        self.tx
            .send(msg)
            .map_err(|_| Error::Fleet(format!("device {} is gone", self.id)))
    }
}

impl Drop for Device {
    fn drop(&mut self) {
        // Closing the channel ends the thread's recv loop.
        let (dead, _) = channel();
        self.tx = dead;
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// FNV-1a mix of the order identity a device's stochastic draws key on:
/// `(device, first task, input bits)`. See the module docs ("content-
/// addressed randomness") for why this replaces a persistent RNG stream.
///
/// Batched orders mix their input bits **member-major** (member 0's
/// column top to bottom, then member 1's, …) rather than in storage
/// order (the column-concatenated input is row-major, i.e. member-
/// interleaved). That keys the stream on the member contents in batch
/// order independent of layout, and makes `batch == 1` bit-identical to
/// the unbatched hash — failure replay of an unbatched session is
/// unchanged by this field existing.
///
/// Shared with `transport::worker` so a real TCP worker's intermittent
/// drop draws replay identically to the simulated device's.
pub(crate) fn order_stream(
    device: usize,
    first_task: Option<u64>,
    batch: usize,
    input: &Tensor,
) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    mix(device as u64);
    mix(first_task.unwrap_or(u64::MAX));
    let b = batch.max(1);
    let data = input.data();
    let rows = data.len() / b;
    for m in 0..b {
        for r in 0..rows {
            mix(data[r * b + m].to_bits() as u64);
        }
    }
    h
}

fn device_main(
    cfg: DeviceConfig,
    net: NetConfig,
    seed: u64,
    compute: ComputeHandle,
    rx: Receiver<ToDevice>,
    completions: Sender<Completion>,
) {
    let mut tasks: std::collections::HashMap<u64, TaskDef> = Default::default();
    let mut failure = cfg.failure.clone();
    let mut net = net;
    let mut rate = cfg.rate_macs_per_ms;
    while let Ok(msg) = rx.recv() {
        match msg {
            ToDevice::Deploy(ts) => {
                for t in ts {
                    tasks.insert(t.id, t);
                }
            }
            ToDevice::Undeploy(ids) => {
                for id in ids {
                    tasks.remove(&id);
                }
            }
            ToDevice::SetFailure(plan) => failure = plan,
            ToDevice::SetNet(n) => net = n,
            ToDevice::SetRate(r) => rate = r,
            ToDevice::Work(order) => {
                let mut rng = Pcg32::new(
                    seed,
                    order_stream(
                        cfg.id,
                        order.tasks.first().copied(),
                        order.batch,
                        &order.input,
                    ),
                );
                let dropped = failure.drops(order.req, &mut rng);
                // Request transfer happens once per order (deterministic
                // leg; congestion jitter is on the replies — see net.rs).
                // Compute cannot start before the ledger says the device
                // is free (work held for other in-flight requests).
                let mut cum_ms = net
                    .sample_request(order.request_bytes)
                    .max(order.not_before_ms - order.t_dispatch_ms);
                for task_id in &order.tasks {
                    let task = match tasks.get(task_id) {
                        Some(t) => t,
                        None => {
                            let _ = completions.send(Completion {
                                req: order.req,
                                task: *task_id,
                                device: cfg.id,
                                result: None,
                                t_arrival_ms: f64::INFINITY,
                            });
                            continue;
                        }
                    };
                    // REAL compute through PJRT (correctness), SIMULATED
                    // service time (performance model). A batched order
                    // runs one wider GEMM whose MACs and reply payload
                    // scale linearly with the member count; the fixed
                    // per-order costs (request leg, reply base latency)
                    // are paid once — that amortisation is the whole
                    // point of cross-request micro-batching.
                    let result = match &task.quant {
                        // Int8 task: the quantized weights replace w on
                        // the compute side (b rides along for the
                        // epilogue).
                        Some(q) => compute
                            .execute_prepared(
                                &task.artifact,
                                vec![task.b.clone(), order.input.clone()],
                                None,
                                Some(q.clone()),
                            )
                            .ok(),
                        None => compute
                            .execute_prepared(
                                &task.artifact,
                                vec![task.w.clone(), task.b.clone(), order.input.clone()],
                                task.packed.clone(),
                                None,
                            )
                            .ok(),
                    };
                    let batch = order.batch.max(1) as u64;
                    cum_ms += (batch * task.macs) as f64 / rate;
                    let reply_ms = net.sample(batch * task.reply_bytes, &mut rng);
                    let (result, t_arrival_ms) = if dropped || result.is_none() {
                        (None, f64::INFINITY)
                    } else {
                        (result, order.t_dispatch_ms + cum_ms + reply_ms)
                    };
                    let _ = completions.send(Completion {
                        req: order.req,
                        task: *task_id,
                        device: cfg.id,
                        result,
                        t_arrival_ms,
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failure_plans() {
        let mut rng = Pcg32::seeded(1);
        assert!(!FailurePlan::None.drops(5, &mut rng));
        let p = FailurePlan::PermanentAt(3);
        assert!(!p.drops(2, &mut rng));
        assert!(p.drops(3, &mut rng));
        assert!(p.drops(100, &mut rng));
        let i = FailurePlan::Intermittent(1.0);
        assert!(i.drops(0, &mut rng));
        let never = FailurePlan::Intermittent(0.0);
        assert!(!never.drops(0, &mut rng));
    }

    #[test]
    fn rpi_rate_matches_paper_anchor() {
        // fc-2048 on one RPi = 50 ms (paper §2/§6).
        let macs = 2048u64 * 2048;
        let ms = macs as f64 / RPI_MACS_PER_MS;
        assert!((ms - 50.0).abs() < 1e-9);
    }
}
