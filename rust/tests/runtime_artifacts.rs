//! Integration: AOT artifacts load, compile, execute, and match the
//! python-side goldens bit-for-bit-ish (f32 tolerance).
//!
//! Requires `make artifacts` to have populated ./artifacts.

use cdc_dnn::runtime::{Manifest, Runtime};
use cdc_dnn::tensor::Tensor;

fn artifacts_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Artifact-dependent tests skip (with a note) instead of failing — the
/// synthetic-manifest tests in `serve_pipeline.rs` cover the coordinator
/// stack without the python build.
fn have_artifacts() -> bool {
    cdc_dnn::testkit::artifacts_available(&artifacts_root())
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            return;
        }
    };
}

fn load() -> (Runtime, Manifest) {
    let m = Manifest::load(artifacts_root()).expect("run `make artifacts` first");
    let r = Runtime::new().expect("pjrt cpu client");
    (r, m)
}

fn golden<'a>(m: &'a Manifest, kind: &str) -> &'a cdc_dnn::json::Value {
    m.goldens
        .iter()
        .find(|g| g.get("kind").unwrap().as_str().unwrap() == kind)
        .expect(kind)
}

fn read_tensor(m: &Manifest, rel: &str, shape: Vec<usize>) -> Tensor {
    Tensor::new(shape, m.read_f32(rel).unwrap()).unwrap()
}

#[test]
fn fc_artifact_matches_golden() {
    require_artifacts!();
    let (rt, m) = load();
    let g = golden(&m, "fc");
    let name = g.get("artifact").unwrap().as_str().unwrap();
    let shapes: Vec<Vec<usize>> = g
        .get("shapes")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|s| s.as_usize_vec().unwrap())
        .collect();
    let ins = g.get("inputs").unwrap().as_arr().unwrap();
    let w = read_tensor(&m, ins[0].as_str().unwrap(), shapes[0].clone());
    let b = read_tensor(&m, ins[1].as_str().unwrap(), shapes[1].clone());
    let x = read_tensor(&m, ins[2].as_str().unwrap(), shapes[2].clone());
    let want = read_tensor(&m, g.get("output").unwrap().as_str().unwrap(), shapes[3].clone());
    let got = rt.execute(&m, name, &[&w, &b, &x]).unwrap();
    assert_eq!(got.shape(), want.shape());
    assert!(got.max_abs_diff(&want) < 1e-4, "diff={}", got.max_abs_diff(&want));
}

#[test]
fn cdc_recovery_matches_golden() {
    require_artifacts!();
    // Execute 2 surviving data shards + parity through the *artifact*, and
    // reconstruct the missing one by subtraction — the paper's §5.2 flow.
    let (rt, m) = load();
    let g = golden(&m, "cdc_fc");
    let name = g.get("artifact").unwrap().as_str().unwrap();
    let mtot = g.get("m").unwrap().as_usize().unwrap();
    let k = g.get("k").unwrap().as_usize().unwrap();
    let n_shards = g.get("n_shards").unwrap().as_usize().unwrap();
    let ms = mtot / n_shards;

    let wfull = read_tensor(&m, g.get("w_full").unwrap().as_str().unwrap(), vec![mtot, k]);
    let bfull = read_tensor(&m, g.get("b_full").unwrap().as_str().unwrap(), vec![mtot, 1]);
    let x = read_tensor(&m, g.get("x").unwrap().as_str().unwrap(), vec![k, 1]);

    // Build shard weights in rust (row slices) + parity (sum of shards).
    let mut shard_w: Vec<Tensor> = Vec::new();
    let mut shard_b: Vec<Tensor> = Vec::new();
    for s in 0..n_shards {
        let w = Tensor::new(
            vec![ms, k],
            wfull.data()[s * ms * k..(s + 1) * ms * k].to_vec(),
        )
        .unwrap();
        let b = Tensor::new(vec![ms, 1], bfull.data()[s * ms..(s + 1) * ms].to_vec()).unwrap();
        shard_w.push(w);
        shard_b.push(b);
    }
    let mut pw = Tensor::zeros(vec![ms, k]);
    let mut pb = Tensor::zeros(vec![ms, 1]);
    for (w, b) in shard_w.iter().zip(&shard_b) {
        pw.add_assign(w).unwrap();
        pb.add_assign(b).unwrap();
    }

    // Expected outputs from the python side.
    let outs = g.get("shard_outputs").unwrap().as_arr().unwrap();
    let want: Vec<Tensor> = outs
        .iter()
        .map(|o| read_tensor(&m, o.as_str().unwrap(), vec![ms, 1]))
        .collect();

    // Run every shard through the artifact; check against golden.
    let mut got: Vec<Tensor> = Vec::new();
    for i in 0..n_shards {
        let y = rt.execute(&m, name, &[&shard_w[i], &shard_b[i], &x]).unwrap();
        assert!(y.max_abs_diff(&want[i]) < 1e-4, "shard {i}");
        got.push(y);
    }
    let parity = rt.execute(&m, name, &[&pw, &pb, &x]).unwrap();
    assert!(parity.max_abs_diff(&want[n_shards]) < 1e-4, "parity");

    // Lose shard 1; recover via parity − others.
    let mut rec = parity.clone();
    rec.sub_assign(&got[0]).unwrap();
    rec.sub_assign(&got[2]).unwrap();
    assert!(
        rec.max_abs_diff(&want[1]) < 1e-3,
        "recovered diff={}",
        rec.max_abs_diff(&want[1])
    );
}

#[test]
fn conv_artifact_runs_and_shapes() {
    require_artifacts!();
    let (rt, m) = load();
    // Find any conv artifact and run it on zero inputs; shape must match.
    let meta = m
        .artifacts
        .values()
        .find(|a| matches!(a.kind, cdc_dnn::runtime::ArtifactKind::Conv))
        .expect("at least one conv artifact");
    let ins: Vec<Tensor> = meta.params.iter().map(|p| Tensor::zeros(p.clone())).collect();
    let refs: Vec<&Tensor> = ins.iter().collect();
    let out = rt.execute(&m, &meta.name, &refs).unwrap();
    assert_eq!(out.shape().len(), 3, "conv shard output is (OH, OW, K_s)");
}

#[test]
fn builder_fallback_matches_artifact() {
    require_artifacts!();
    let (rt, m) = load();
    let g = golden(&m, "fc");
    let name = g.get("artifact").unwrap().as_str().unwrap();
    let meta = m.artifact(name).unwrap();
    let (mm, kk) = (meta.params[0][0], meta.params[0][1]);
    let mut rng = cdc_dnn::rng::Pcg32::seeded(99);
    let w = Tensor::randn(vec![mm, kk], &mut rng);
    let b = Tensor::randn(vec![mm, 1], &mut rng);
    let x = Tensor::randn(vec![kk, 1], &mut rng);
    let via_artifact = rt.execute(&m, name, &[&w, &b, &x]).unwrap();
    let exe = rt.build_gemm(mm, kk, 1, true, true).unwrap();
    let via_builder = rt.run_built(&exe, &[&w, &x, &b]).unwrap();
    assert!(via_artifact.max_abs_diff(&via_builder) < 1e-4);
}
