//! Fig. 16 — straggler-mitigation performance vs fleet size.
//!
//! A fully-connected layer is output-split across d devices, plus one CDC
//! parity device used as an "anytime" substitute. Mitigation completes a
//! layer as soon as any d of d+1 results are in hand (after the waiting
//! threshold); the baseline waits for all d data shards. The paper reports
//! improvements growing with the device count, up to ~35% — more devices
//! mean a worse max-of-d tail, which is exactly what the n-of-n+1 order
//! statistic cuts.

use crate::coordinator::{Session, SessionConfig, SplitSpec};
use crate::error::Result;
use crate::json::{obj, Value};
use crate::metrics::Series;
use crate::rng::Pcg32;
use crate::tensor::Tensor;

use super::{print_table, ExpCtx};

/// Device counts swept (artifact set provides fc2048 splits for these).
pub const DEVICES: [usize; 5] = [2, 3, 4, 6, 8];

/// One sweep point.
#[derive(Debug)]
pub struct Point {
    pub d: usize,
    pub mean_no_mit: f64,
    pub mean_mit: f64,
    pub improvement: f64,
}

fn fc2048_cfg(ctx: &ExpCtx, d: usize, threshold_factor: f64) -> SessionConfig {
    let mut cfg = SessionConfig::new("fc2048");
    cfg.n_devices = d;
    cfg.seed = ctx.seed + d as u64;
    cfg.splits.insert("fc".into(), SplitSpec::cdc(d));
    cfg.threshold_factor = threshold_factor;
    // Same moderately-loaded WLAN as the case studies; under Fig. 1's
    // congested profile the n-of-n+1 cut is far larger (≈65-75%) — the
    // paper's ~35% ceiling corresponds to a calmer testbed network.
    cfg.net = crate::fleet::NetConfig::moderate();
    cfg
}

/// Run the sweep; returns the improvement curve.
pub fn run(ctx: &ExpCtx) -> Result<Vec<Point>> {
    let n = ctx.n_requests();
    let mut points = Vec::new();
    let mut rows = Vec::new();
    for d in DEVICES {
        let mut rng = Pcg32::seeded(ctx.seed ^ 0xf16);
        // Baseline: parity present but never substituted (threshold = ∞ …
        // it still recovers real failures, of which there are none here).
        let mut off = Session::start(&ctx.artifacts, fc2048_cfg(ctx, d, f64::INFINITY))?;
        // Mitigation: substitute once the expected service time has
        // elapsed (threshold_factor = 1). The paper tunes this waiting
        // threshold (§6.2); 0 would be the oracle n-of-n+1 limit, which
        // under-reports nothing and over-cuts the fast path.
        let mut on = Session::start(&ctx.artifacts, fc2048_cfg(ctx, d, 2.0))?;
        let mut s_off = Series::new();
        let mut s_on = Series::new();
        for _ in 0..n {
            let x = Tensor::randn(vec![2048], &mut rng);
            s_off.record(off.infer(&x)?.total_ms);
            s_on.record(on.infer(&x)?.total_ms);
        }
        let (m0, m1) = (s_off.summary().mean, s_on.summary().mean);
        let imp = 1.0 - m1 / m0;
        rows.push(vec![
            format!("{d}"),
            format!("{m0:.1}"),
            format!("{m1:.1}"),
            format!("{:.1}%", imp * 100.0),
        ]);
        points.push(Point { d, mean_no_mit: m0, mean_mit: m1, improvement: imp });
    }

    println!("\n=== Fig. 16: straggler mitigation vs number of devices ===");
    print_table(
        &["devices", "no-mitigation mean (ms)", "mitigation mean (ms)", "improvement"],
        &rows,
    );
    println!(
        "(paper: improvement grows with devices, up to ~35%; our WLAN model\n\
         has a heavier jitter-to-compute ratio, so the order-statistic cut\n\
         is larger — the growth-with-devices trend is the reproduced shape)"
    );

    let json_points: Vec<Value> = points
        .iter()
        .map(|p| {
            obj(vec![
                ("devices", Value::Num(p.d as f64)),
                ("no_mitigation_ms", Value::Num(p.mean_no_mit)),
                ("mitigation_ms", Value::Num(p.mean_mit)),
                ("improvement", Value::Num(p.improvement)),
            ])
        })
        .collect();
    ctx.write_result(
        "fig16",
        &obj(vec![
            ("experiment", Value::Str("fig16_straggler_sweep".into())),
            ("requests", Value::Num(n as f64)),
            ("points", Value::Arr(json_points)),
        ]),
    )?;
    Ok(points)
}
