//! Dense f32 tensor substrate for the coordinator's merge path.
//!
//! The *compute* hot path (per-device GEMMs) runs inside AOT-compiled XLA
//! executables; this module implements only what the merge point of the
//! paper needs: concatenation (output/channel splitting), elementwise
//! add/sub (input-split aggregation and CDC recovery), the deferred
//! epilogues (ReLU, max-pool, softmax) for CDC mode, and the loss-injection
//! helper for the Fig. 2 experiment.

use crate::error::{Error, Result};
use crate::rng::Pcg32;

/// Row-major dense f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Create from shape + data; checks element count.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "shape {shape:?} wants {n} elements, got {}",
                data.len()
            )));
        }
        Ok(Tensor { shape, data })
    }

    /// All-zeros tensor.
    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// I.i.d. N(0,1) tensor (tests, workload generators).
    pub fn randn(shape: Vec<usize>, rng: &mut Pcg32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: (0..n).map(|_| rng.normal() as f32).collect() }
    }

    /// Column vector from a slice.
    pub fn col(data: &[f32]) -> Tensor {
        Tensor { shape: vec![data.len(), 1], data: data.to_vec() }
    }

    /// Tensor shape.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Raw data view.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Raw data, mutable.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into raw data.
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reshape (same element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(Error::Shape(format!(
                "cannot reshape {:?} -> {shape:?}",
                self.shape
            )));
        }
        self.shape = shape;
        Ok(self)
    }

    /// Flatten to a column vector (m, 1) — the paper's `flatten` layer.
    pub fn flatten_col(self) -> Tensor {
        let n = self.data.len();
        Tensor { shape: vec![n, 1], data: self.data }
    }

    /// Elementwise in-place add. Shapes must match.
    pub fn add_assign(&mut self, other: &Tensor) -> Result<()> {
        self.zip_assign(other, |a, b| a + b)
    }

    /// Elementwise in-place subtract (CDC recovery: parity − Σ received).
    pub fn sub_assign(&mut self, other: &Tensor) -> Result<()> {
        self.zip_assign(other, |a, b| a - b)
    }

    fn zip_assign(&mut self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<()> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!(
                "elementwise op on {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a = f(*a, *b);
        }
        Ok(())
    }

    /// In-place ReLU (deferred epilogue in CDC mode).
    pub fn relu(&mut self) {
        for v in &mut self.data {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }

    /// Concatenate along axis 0 (fc output splitting merge: stack rows).
    pub fn concat0(parts: &[&Tensor]) -> Result<Tensor> {
        if parts.is_empty() {
            return Err(Error::Shape("concat0 of zero tensors".into()));
        }
        let tail = &parts[0].shape[1..];
        let mut rows = 0;
        for p in parts {
            if &p.shape[1..] != tail {
                return Err(Error::Shape(format!(
                    "concat0 tail mismatch: {:?} vs {:?}",
                    parts[0].shape, p.shape
                )));
            }
            rows += p.shape[0];
        }
        let mut shape = vec![rows];
        shape.extend_from_slice(tail);
        let mut data = Vec::with_capacity(shape.iter().product());
        for p in parts {
            data.extend_from_slice(&p.data);
        }
        Ok(Tensor { shape, data })
    }

    /// Concatenate (H, W, C) tensors along the channel axis (conv channel
    /// splitting merge, paper Fig. 8).
    pub fn concat_channels(parts: &[&Tensor]) -> Result<Tensor> {
        if parts.is_empty() {
            return Err(Error::Shape("concat_channels of zero tensors".into()));
        }
        let (h, w) = match parts[0].shape[..] {
            [h, w, _] => (h, w),
            _ => {
                return Err(Error::Shape(format!(
                    "concat_channels wants rank-3, got {:?}",
                    parts[0].shape
                )))
            }
        };
        let mut c_total = 0;
        for p in parts {
            match p.shape[..] {
                [ph, pw, pc] if ph == h && pw == w => c_total += pc,
                _ => {
                    return Err(Error::Shape(format!(
                        "concat_channels mismatch: {:?} vs {:?}",
                        parts[0].shape, p.shape
                    )))
                }
            }
        }
        let mut data = vec![0.0f32; h * w * c_total];
        for (y, row) in data.chunks_mut(c_total).enumerate() {
            let _ = y;
            let mut off = 0;
            for p in parts {
                let pc = p.shape[2];
                let src = &p.data[y * pc..(y + 1) * pc];
                row[off..off + pc].copy_from_slice(src);
                off += pc;
            }
        }
        Ok(Tensor { shape: vec![h, w, c_total], data })
    }

    /// Take the first `rows` rows (drops CDC padding rows after merge).
    pub fn take_rows(&self, rows: usize) -> Result<Tensor> {
        if self.shape.is_empty() || self.shape[0] < rows {
            return Err(Error::Shape(format!(
                "take_rows({rows}) of {:?}",
                self.shape
            )));
        }
        let stride: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = rows;
        Ok(Tensor { shape, data: self.data[..rows * stride].to_vec() })
    }

    /// Take channels [lo, hi) of an (H, W, C) tensor.
    pub fn take_channels(&self, lo: usize, hi: usize) -> Result<Tensor> {
        let (h, w, c) = match self.shape[..] {
            [h, w, c] => (h, w, c),
            _ => return Err(Error::Shape(format!("take_channels of {:?}", self.shape))),
        };
        if lo > hi || hi > c {
            return Err(Error::Shape(format!("take_channels({lo},{hi}) of C={c}")));
        }
        let mut data = Vec::with_capacity(h * w * (hi - lo));
        for px in self.data.chunks(c) {
            data.extend_from_slice(&px[lo..hi]);
        }
        Tensor::new(vec![h, w, hi - lo], data)
    }

    /// Max-pool (H, W, C) with square window/stride, VALID padding —
    /// the merge-side pool for CDC conv layers.
    pub fn maxpool(&self, size: usize, stride: usize) -> Result<Tensor> {
        let mut out = Vec::new();
        let shape = self.maxpool_into(size, stride, &mut out)?;
        Tensor::new(shape, out)
    }

    /// Output element count of [`Tensor::maxpool`] — lets scratch-arena
    /// callers take a right-sized buffer up front instead of growing one.
    pub fn maxpool_len(&self, size: usize, stride: usize) -> Result<usize> {
        let (h, w, c) = match self.shape[..] {
            [h, w, c] => (h, w, c),
            _ => return Err(Error::Shape(format!("maxpool of {:?}", self.shape))),
        };
        Ok(((h - size) / stride + 1) * ((w - size) / stride + 1) * c)
    }

    /// Max-pool into a caller-provided buffer (scratch-arena serving hot
    /// path); returns the output shape. `out` is cleared and resized.
    pub fn maxpool_into(
        &self,
        size: usize,
        stride: usize,
        out: &mut Vec<f32>,
    ) -> Result<Vec<usize>> {
        let (h, w, c) = match self.shape[..] {
            [h, w, c] => (h, w, c),
            _ => return Err(Error::Shape(format!("maxpool of {:?}", self.shape))),
        };
        let oh = (h - size) / stride + 1;
        let ow = (w - size) / stride + 1;
        out.clear();
        out.resize(oh * ow * c, f32::NEG_INFINITY);
        for oy in 0..oh {
            for ox in 0..ow {
                for dy in 0..size {
                    for dx in 0..size {
                        let iy = oy * stride + dy;
                        let ix = ox * stride + dx;
                        let src = &self.data[(iy * w + ix) * c..(iy * w + ix + 1) * c];
                        let dst = &mut out[(oy * ow + ox) * c..(oy * ow + ox + 1) * c];
                        for (d, s) in dst.iter_mut().zip(src) {
                            if *s > *d {
                                *d = *s;
                            }
                        }
                    }
                }
            }
        }
        Ok(vec![oh, ow, c])
    }

    /// Global average pool: (H, W, C) → (C, 1).
    pub fn gap(&self) -> Result<Tensor> {
        let (h, w, c) = match self.shape[..] {
            [h, w, c] => (h, w, c),
            _ => return Err(Error::Shape(format!("gap of {:?}", self.shape))),
        };
        let mut out = vec![0.0f32; c];
        for px in self.data.chunks(c) {
            for (o, v) in out.iter_mut().zip(px) {
                *o += v;
            }
        }
        let n = (h * w) as f32;
        for o in &mut out {
            *o /= n;
        }
        Tensor::new(vec![c, 1], out)
    }

    /// Numerically-stable softmax over all elements (for logits columns).
    pub fn softmax(&self) -> Tensor {
        let max = self.data.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = self.data.iter().map(|v| (v - max).exp()).collect();
        let sum: f32 = exps.iter().sum();
        Tensor { shape: self.shape.clone(), data: exps.iter().map(|e| e / sum).collect() }
    }

    /// Index of the max element (classification readout).
    pub fn argmax(&self) -> usize {
        self.data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// Zero out a random `fraction` of elements (Fig. 2 data-loss model:
    /// the granularity of loss in distributed IoT systems is whole
    /// activations, not bits).
    pub fn inject_loss(&mut self, fraction: f64, rng: &mut Pcg32) -> usize {
        let mut lost = 0;
        for v in &mut self.data {
            if rng.bernoulli(fraction) {
                *v = 0.0;
                lost += 1;
            }
        }
        lost
    }

    /// Max absolute difference vs another tensor (test helper).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// CPU GEMM: self (m,k) × rhs (k,n), lowered onto the tiled/threaded
    /// kernel layer (`kernels::gemm_auto`) — the shared hot kernel of the
    /// interpreter backend and the coordinator's fallback paths.
    pub fn matmul(&self, rhs: &Tensor) -> Result<Tensor> {
        let (m, k, n) = self.matmul_dims(rhs)?;
        let mut out = vec![0.0f32; m * n];
        crate::kernels::with_scratch(|sc| {
            crate::kernels::gemm_auto(&self.data, &rhs.data, &mut out, m, k, n, sc)
        });
        Tensor::new(vec![m, n], out)
    }

    /// Branch-free naive reference GEMM — the oracle the kernel layer is
    /// property-tested against; never on a hot path. (The old `a == 0.0`
    /// skip was removed: it mispredicts on dense data and skewed every
    /// naive-vs-tiled comparison; no caller relies on sparsity-awareness.)
    pub fn matmul_naive(&self, rhs: &Tensor) -> Result<Tensor> {
        let (m, k, n) = self.matmul_dims(rhs)?;
        let mut out = vec![0.0f32; m * n];
        crate::kernels::gemm_naive(&self.data, &rhs.data, &mut out, m, k, n);
        Tensor::new(vec![m, n], out)
    }

    fn matmul_dims(&self, rhs: &Tensor) -> Result<(usize, usize, usize)> {
        let (m, k) = match self.shape[..] {
            [m, k] => (m, k),
            _ => return Err(Error::Shape(format!("matmul lhs {:?}", self.shape))),
        };
        let (k2, n) = match rhs.shape[..] {
            [k2, n] => (k2, n),
            _ => return Err(Error::Shape(format!("matmul rhs {:?}", rhs.shape))),
        };
        if k != k2 {
            return Err(Error::Shape(format!("matmul {m}x{k} @ {k2}x{n}")));
        }
        Ok((m, k, n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], data: &[f32]) -> Tensor {
        Tensor::new(shape.to_vec(), data.to_vec()).unwrap()
    }

    #[test]
    fn new_checks_len() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn concat0_stacks_rows() {
        let a = t(&[2, 2], &[1., 2., 3., 4.]);
        let b = t(&[1, 2], &[5., 6.]);
        let c = Tensor::concat0(&[&a, &b]).unwrap();
        assert_eq!(c.shape(), &[3, 2]);
        assert_eq!(c.data(), &[1., 2., 3., 4., 5., 6.]);
        let bad = t(&[1, 3], &[0.; 3]);
        assert!(Tensor::concat0(&[&a, &bad]).is_err());
    }

    #[test]
    fn concat_channels_interleaves() {
        // 1x2 image, 1+2 channels.
        let a = t(&[1, 2, 1], &[1., 2.]);
        let b = t(&[1, 2, 2], &[10., 11., 20., 21.]);
        let c = Tensor::concat_channels(&[&a, &b]).unwrap();
        assert_eq!(c.shape(), &[1, 2, 3]);
        assert_eq!(c.data(), &[1., 10., 11., 2., 20., 21.]);
    }

    #[test]
    fn take_channels_roundtrip() {
        let x = t(&[1, 2, 3], &[1., 10., 11., 2., 20., 21.]);
        let a = x.take_channels(0, 1).unwrap();
        let b = x.take_channels(1, 3).unwrap();
        let back = Tensor::concat_channels(&[&a, &b]).unwrap();
        assert_eq!(back, x);
    }

    #[test]
    fn cdc_subtract_recovers() {
        // parity = s0 + s1 + s2; missing s1 = parity − s0 − s2.
        let s0 = t(&[2, 1], &[1., 2.]);
        let s1 = t(&[2, 1], &[3., 4.]);
        let s2 = t(&[2, 1], &[5., 6.]);
        let mut parity = Tensor::zeros(vec![2, 1]);
        for s in [&s0, &s1, &s2] {
            parity.add_assign(s).unwrap();
        }
        parity.sub_assign(&s0).unwrap();
        parity.sub_assign(&s2).unwrap();
        assert_eq!(parity, s1);
    }

    #[test]
    fn maxpool_2x2() {
        let x = t(&[2, 2, 1], &[1., 3., 2., 4.]);
        let y = x.maxpool(2, 2).unwrap();
        assert_eq!(y.shape(), &[1, 1, 1]);
        assert_eq!(y.data(), &[4.]);
    }

    #[test]
    fn softmax_and_argmax() {
        let x = t(&[3, 1], &[0., 1., 2.]);
        let s = x.softmax();
        let sum: f32 = s.data().iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert_eq!(x.argmax(), 2);
    }

    #[test]
    fn relu_clamps() {
        let mut x = t(&[2, 2], &[-1., 2., -3., 4.]);
        x.relu();
        assert_eq!(x.data(), &[0., 2., 0., 4.]);
    }

    #[test]
    fn gap_means() {
        let x = t(&[2, 2, 2], &[1., 10., 2., 20., 3., 30., 4., 40.]);
        let g = x.gap().unwrap();
        assert_eq!(g.shape(), &[2, 1]);
        assert_eq!(g.data(), &[2.5, 25.0]);
    }

    #[test]
    fn matmul_matches_hand() {
        let a = t(&[2, 3], &[1., 2., 3., 4., 5., 6.]);
        let b = t(&[3, 2], &[7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_agrees_with_naive_reference() {
        let mut rng = Pcg32::seeded(8);
        for (m, k, n) in [(1usize, 1usize, 1usize), (33, 65, 17), (70, 130, 90)] {
            let a = Tensor::randn(vec![m, k], &mut rng);
            let b = Tensor::randn(vec![k, n], &mut rng);
            let fast = a.matmul(&b).unwrap();
            let slow = a.matmul_naive(&b).unwrap();
            assert!(fast.max_abs_diff(&slow) < 1e-4, "({m},{k},{n})");
        }
    }

    #[test]
    fn inject_loss_fraction() {
        let mut rng = Pcg32::seeded(1);
        let mut x = Tensor::new(vec![10_000], vec![1.0; 10_000]).unwrap();
        let lost = x.inject_loss(0.3, &mut rng);
        assert!((lost as f64 - 3000.0).abs() < 200.0, "lost={lost}");
        let zeros = x.data().iter().filter(|v| **v == 0.0).count();
        assert_eq!(zeros, lost);
    }
}
