# AOT compiler: lower every shard function to HLO *text* artifacts.
"""``python -m compile.aot --out ../artifacts`` — the one-shot build step.

Emits, under the artifacts directory:

* ``hlo/<name>.hlo.txt``    — one HLO-text program per deduped shard shape.
  HLO *text*, not ``.serialize()``: jax ≥ 0.5 emits protos with 64-bit
  instruction ids that xla_extension 0.5.1 rejects; the text parser
  reassigns ids (see /opt/xla-example/README.md).
* ``weights/<model>.bin``   — trained/initialised per-layer weights in
  matrix form (conv filters pre-unrolled to (K, F²C)), f32 little-endian.
* ``data/test_*.bin``       — held-out synthetic-digit test set (Fig. 2).
* ``goldens/*.bin``         — random input/output pairs per artifact kind +
  full-model logit taps, consumed by rust integration tests.
* ``manifest.json``         — the index the rust runtime loads.

Weights are runtime parameters of the artifacts (not baked constants), so a
single executable serves every shard of its shape — mirroring the paper's
"all weights on each device's SD card, switch tasks by allocation file"
deployment model (§6 Task Creation & Assignment).
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile import splits
from compile.data import make_digits
from compile.zoo import ZOO, ModelDesc, layer_io_shapes

# Split counts per (model, layer name). d=1 is the whole-layer task (also
# used by Fig. 2's layer-by-layer loss injection); larger d values are what
# the paper's case studies and sweeps deploy.
FC_SPLITS: Dict[str, Dict[str, List[int]]] = {
    "fc2048": {"fc": [1, 2, 3, 4, 6, 8]},
    "alexnet": {"fc6": [1, 2, 3], "fc7": [1, 2, 3], "fc8": [1]},
    "lenet5": {"fc1": [1, 2, 4], "fc2": [1, 2, 4], "fc3": [1, 2]},
    "deepnet": {"fc1": [1, 2], "fc2": [1]},
    "vgg16": {"fc1": [1, 2], "fc2": [1, 2], "fc3": [1]},
    "c3d": {"fc6": [1, 2, 3], "fc7": [1, 2, 3], "fc8": [1]},
}
CONV_SPLITS: Dict[str, Dict[str, List[int]]] = {
    "lenet5": {"conv1": [1, 2], "conv2": [1, 2]},
    "deepnet": {"*": [1]},
    "alexnet": {"*": [1]},
    "vgg16": {"*": [1]},
    "c3d": {"*": [1]},
}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


class ArtifactSet:
    """Dedup + lower + record shard artifacts."""

    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries: Dict[str, dict] = {}
        os.makedirs(os.path.join(out_dir, "hlo"), exist_ok=True)

    def _emit(self, name: str, fn, spec, meta: dict) -> str:
        if name in self.entries:
            return name
        t0 = time.time()
        lowered = jax.jit(fn).lower(*spec)
        text = to_hlo_text(lowered)
        rel = os.path.join("hlo", f"{name}.hlo.txt")
        with open(os.path.join(self.out_dir, rel), "w") as f:
            f.write(text)
        meta = dict(meta, name=name, file=rel,
                    params=[list(s.shape) for s in spec])
        self.entries[name] = meta
        print(f"  [aot] {name}  ({time.time()-t0:.2f}s, {len(text)//1024} KiB)")
        return name

    def fc_shard(self, m_s: int, k: int, *, relu: bool) -> str:
        name = f"fc_m{m_s}_k{k}_{'relu' if relu else 'lin'}"
        fn, spec = M.fc_shard_fn(m_s, k, 1, relu=relu)
        return self._emit(name, fn, spec, {
            "kind": "fc", "m": m_s, "k": k, "n": 1, "relu": relu,
        })

    def conv_shard(self, h: int, w: int, c: int, k_s: int, f: int, s: int,
                   padding: str, *, relu: bool) -> str:
        name = (f"conv_h{h}w{w}c{c}_k{k_s}f{f}s{s}"
                f"{padding[0].lower()}_{'relu' if relu else 'lin'}")
        fn, spec = M.conv_shard_fn(h, w, c, k_s, f, s, padding,
                                   relu=relu, pool=0)
        return self._emit(name, fn, spec, {
            "kind": "conv", "h": h, "w": w, "c": c, "k": k_s, "f": f,
            "s": s, "padding": padding, "relu": relu,
        })


def write_f32(path: str, arr: np.ndarray) -> int:
    arr = np.ascontiguousarray(arr, dtype=np.float32)
    with open(path, "ab") as f:
        off = f.tell()
        f.write(arr.tobytes())
    return off


def emit_model(model: ModelDesc, params, arts: ArtifactSet, out_dir: str) -> dict:
    """Write one model's weights bin + per-layer artifact references."""
    wpath_rel = os.path.join("weights", f"{model.name}.bin")
    wpath = os.path.join(arts.out_dir, wpath_rel)
    if os.path.exists(wpath):
        os.remove(wpath)
    os.makedirs(os.path.dirname(wpath), exist_ok=True)

    layers_json = []
    fc_plan = FC_SPLITS.get(model.name, {})
    conv_plan = CONV_SPLITS.get(model.name, {})
    for layer, (inp, outp) in zip(model.layers, layer_io_shapes(model)):
        lj = layer.to_json()
        lj["input_shape"], lj["output_shape"] = list(inp), list(outp)
        if layer.kind == "fc":
            w, b = params[layer.name]
            lj["w_offset"] = write_f32(wpath, w)
            lj["b_offset"] = write_f32(wpath, b)
            lj["w_shape"] = [int(w.shape[0]), int(w.shape[1])]
            dcounts = fc_plan.get(layer.name, [1])
            lj["splits"] = {}
            for d in dcounts:
                m_s = -(-layer.m // d)
                names = {}
                if layer.relu:
                    names["relu"] = arts.fc_shard(m_s, inp[0], relu=True)
                names["lin"] = arts.fc_shard(m_s, inp[0], relu=False)
                lj["splits"][str(d)] = names
        elif layer.kind == "conv":
            w, b = params[layer.name]
            wmat = M.filters_to_matrix(w)
            lj["w_offset"] = write_f32(wpath, wmat)
            lj["b_offset"] = write_f32(wpath, b)
            lj["w_shape"] = [int(wmat.shape[0]), int(wmat.shape[1])]
            dcounts = conv_plan.get(layer.name, conv_plan.get("*", [1]))
            h, w_, c = inp
            lj["splits"] = {}
            for d in dcounts:
                k_s = -(-layer.k // d)
                names = {}
                if layer.relu:
                    names["relu"] = arts.conv_shard(
                        h, w_, c, k_s, layer.f, layer.s, layer.padding,
                        relu=True)
                names["lin"] = arts.conv_shard(
                    h, w_, c, k_s, layer.f, layer.s, layer.padding,
                    relu=False)
                lj["splits"][str(d)] = names
        layers_json.append(lj)
    mj = model.to_json()
    mj["layers"] = layers_json
    mj["weights_file"] = wpath_rel
    return mj


def emit_goldens(out_dir: str, models_json: List[dict], params_by_model,
                 rng: np.random.Generator, arts: "ArtifactSet") -> List[dict]:
    """Random input/expected-output pairs for rust integration tests."""
    # Make sure the artifacts the goldens reference exist.
    arts.fc_shard(60, 120, relu=True)
    arts.fc_shard(60, 120, relu=False)
    gdir = os.path.join(out_dir, "goldens")
    os.makedirs(gdir, exist_ok=True)
    goldens: List[dict] = []

    def dump(name: str, arr: np.ndarray) -> str:
        rel = os.path.join("goldens", name + ".bin")
        with open(os.path.join(out_dir, rel), "wb") as f:
            f.write(np.ascontiguousarray(arr, np.float32).tobytes())
        return rel

    # 1. Artifact-level goldens: fc shard + CDC round trip.
    from compile.kernels import gemm
    w = rng.normal(size=(60, 120)).astype(np.float32)
    b = rng.normal(size=(60,)).astype(np.float32)
    x = rng.normal(size=(120, 1)).astype(np.float32)
    y = np.asarray(gemm(jnp.asarray(w), jnp.asarray(x),
                        jnp.asarray(b).reshape(-1, 1), relu=True))
    goldens.append({
        "kind": "fc", "artifact": "fc_m60_k120_relu",
        "inputs": [dump("fc_w", w), dump("fc_b", b.reshape(-1, 1)),
                   dump("fc_x", x)],
        "output": dump("fc_y", y),
        "shapes": [[60, 120], [60, 1], [120, 1], [60, 1]],
    })

    # CDC: 3 data shards of a 180×120 layer + parity; all pre-activation.
    wfull = rng.normal(size=(180, 120)).astype(np.float32)
    bfull = rng.normal(size=(180,)).astype(np.float32)
    shards = splits.output_split(wfull, bfull, 3)
    parity = splits.cdc_parity_shard(shards)
    fn, _ = M.fc_shard_fn(60, 120, 1, relu=False)
    outs = [np.asarray(fn(jnp.asarray(s.w), jnp.asarray(s.b.reshape(-1, 1)),
                          jnp.asarray(x))[0])
            for s in shards + [parity]]
    goldens.append({
        "kind": "cdc_fc",
        "artifact": "fc_m60_k120_lin",
        "w_full": dump("cdc_wfull", wfull),
        "b_full": dump("cdc_bfull", bfull.reshape(-1, 1)),
        "x": dump("fc_x", x),
        "shard_outputs": [dump(f"cdc_out{i}", o) for i, o in enumerate(outs)],
        "n_shards": 3, "m": 180, "k": 120,
    })

    # 2. Full-model goldens: input → logits.
    for mj in models_json:
        model = ZOO[mj["name"]]
        if len(model.input_shape) == 1:
            xin = rng.normal(size=model.input_shape).astype(np.float32)
        else:
            h, w, c = model.input_shape
            xin, _ = make_digits(1, seed=7, size=h)
            xin = xin[0]
            if c == 3:
                xin = np.repeat(xin, 3, axis=2)
        logits = np.asarray(M.forward(model, params_by_model[model.name],
                                      jnp.asarray(xin)))
        goldens.append({
            "kind": "model", "model": model.name,
            "input": dump(f"{model.name}_in", xin),
            "logits": dump(f"{model.name}_logits", logits),
            "input_shape": list(xin.shape),
        })
    return goldens


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="smaller training + eval set for dev loops")
    ap.add_argument("--models", default="",
                    help="comma-separated subset of model names")
    args = ap.parse_args()
    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)
    t0 = time.time()

    names = [n for n in args.models.split(",") if n] or list(ZOO)
    rng = np.random.default_rng(2021)

    # --- train the Fig.-2 models, random-init the rest -------------------
    train_meta = {}
    params_by_model = {}
    for name in names:
        model = ZOO[name]
        if model.trained:
            from compile.train import train as train_fn
            n_train = 2000 if args.quick else 8000
            epochs = 2 if args.quick else (8 if name == "deepnet" else 6)
            # Deeper nets need a gentler step to escape the dead-ReLU
            # plateau (see python/tests/test_train.py).
            lr = 0.01 if name == "deepnet" else 0.05
            params, acc = train_fn(model, n_train=n_train, epochs=epochs,
                                   lr=lr, verbose=True)
            train_meta[name] = {"test_acc": acc, "n_train": n_train,
                                "epochs": epochs}
        else:
            params = M.init_params(model, seed=42)
        params_by_model[name] = params

    # --- test set for Fig. 2 ---------------------------------------------
    ddir = os.path.join(out, "data")
    os.makedirs(ddir, exist_ok=True)
    n_eval = 128 if args.quick else 512
    xt, yt = make_digits(n_eval, seed=12345)
    with open(os.path.join(ddir, "test_images.bin"), "wb") as f:
        f.write(xt.astype(np.float32).tobytes())
    with open(os.path.join(ddir, "test_labels.bin"), "wb") as f:
        f.write(yt.astype(np.int32).tobytes())

    # --- shard artifacts + weights ----------------------------------------
    arts = ArtifactSet(out)
    models_json = []
    for name in names:
        print(f"[aot] model {name}")
        models_json.append(emit_model(ZOO[name], params_by_model[name],
                                      arts, out))

    goldens = emit_goldens(out, models_json, params_by_model, rng, arts)

    manifest = {
        "version": 1,
        "generated_unix": int(time.time()),
        "training": train_meta,
        "eval_set": {"images": "data/test_images.bin",
                     "labels": "data/test_labels.bin",
                     "count": n_eval, "image_shape": [28, 28, 1]},
        "models": models_json,
        "artifacts": sorted(arts.entries.values(), key=lambda e: e["name"]),
        "goldens": goldens,
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(arts.entries)} artifacts, "
          f"{len(models_json)} models, {len(goldens)} goldens "
          f"in {time.time()-t0:.1f}s → {out}")


if __name__ == "__main__":
    main()
