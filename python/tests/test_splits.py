"""Splitting methods + CDC parity algebra (paper §4-5) at the python level.

These mirror the rust `partition`/`cdc` tests; the golden-manifest rust
integration tests keep the two implementations honest against each other.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import splits

RNG = np.random.default_rng(2)


# ---------------------------------------------------------------------------
# balanced ranges


@given(total=st.integers(1, 4000), parts=st.integers(1, 12))
@settings(max_examples=60, deadline=None)
def test_balanced_ranges_cover_contiguously(total, parts):
    r = splits.balanced_ranges(total, parts)
    assert len(r) == parts
    assert r[0][0] == 0 and r[-1][1] == total
    sizes = [hi - lo for lo, hi in r]
    assert max(sizes) - min(sizes) <= 1
    for (a, b), (c, d) in zip(r, r[1:]):
        assert b == c


def test_balanced_ranges_rejects_zero_parts():
    with pytest.raises(ValueError):
        splits.balanced_ranges(10, 0)


# ---------------------------------------------------------------------------
# output splitting + CDC


def test_output_split_reassembles():
    w = RNG.normal(size=(10, 6)).astype(np.float32)
    b = RNG.normal(size=10).astype(np.float32)
    shards = splits.output_split(w, b, 3)
    # Uniform heights with zero padding.
    assert {s.w.shape for s in shards} == {(4, 6)}
    # Real rows reassemble the full matrix.
    rows = np.concatenate([s.w[: s.rows[1] - s.rows[0]] for s in shards])
    np.testing.assert_array_equal(rows, w)


def test_parity_recovers_every_shard():
    w = RNG.normal(size=(9, 5)).astype(np.float32)
    b = RNG.normal(size=9).astype(np.float32)
    x = RNG.normal(size=(5, 1)).astype(np.float32)
    shards = splits.output_split(w, b, 3)
    parity = splits.cdc_parity_shard(shards)
    outs = [s.w @ x + s.b.reshape(-1, 1) for s in shards]
    pout = parity.w @ x + parity.b.reshape(-1, 1)
    for lose in range(3):
        rec = splits.cdc_decode(pout, [o for i, o in enumerate(outs) if i != lose])
        np.testing.assert_allclose(rec, outs[lose], rtol=1e-4, atol=1e-4)


def test_parity_requires_uniform_shards():
    w = RNG.normal(size=(10, 4)).astype(np.float32)
    shards = splits.output_split(w, None, 3, uniform=False)
    with pytest.raises(ValueError):
        splits.cdc_parity_shard(shards)


def test_parity_of_parity_rejected():
    w = RNG.normal(size=(8, 4)).astype(np.float32)
    shards = splits.output_split(w, None, 2)
    p = splits.cdc_parity_shard(shards)
    with pytest.raises(ValueError):
        splits.cdc_parity_shard(shards + [p])


def test_multi_parity_groups_fig18():
    w = RNG.normal(size=(8, 4)).astype(np.float32)
    shards = splits.output_split(w, None, 4)
    parities = splits.multi_parity_shards(shards, group_size=2)
    assert len(parities) == 2
    assert parities[0].covers == (0, 1)
    assert parities[1].covers == (2, 3)
    # Degenerate group covers everything = classic single parity.
    single = splits.multi_parity_shards(shards, group_size=4)
    assert len(single) == 1
    assert single[0].covers == (0, 1, 2, 3)


# ---------------------------------------------------------------------------
# input splitting: partial sums, and WHY it is not CDC-suitable


def test_input_split_partial_sums():
    w = RNG.normal(size=(6, 8)).astype(np.float32)
    x = RNG.normal(size=(8, 1)).astype(np.float32)
    shards = splits.input_split(w, None, 2)
    partials = [
        s.w @ x[s.cols[0] : s.cols[1]] for s in shards
    ]
    np.testing.assert_allclose(sum(partials), w @ x, rtol=1e-4, atol=1e-4)


def test_input_split_shares_no_weight_factor():
    """Paper Eq. 13-14: the two partial sums share no common factor, so a
    'parity' device would have to redo *all* the work — the suitability
    criterion in Table 1."""
    w = RNG.normal(size=(6, 8)).astype(np.float32)
    shards = splits.input_split(w, None, 2)
    # Column ranges are disjoint…
    assert shards[0].cols == (0, 4) and shards[1].cols == (4, 8)
    # …so summing shard weights is meaningless: there is no x-independent
    # combination that yields the other shard's contribution.
    assert shards[0].w.shape == shards[1].w.shape
    assert not np.allclose(shards[0].w, shards[1].w)


# ---------------------------------------------------------------------------
# Table 1


def test_table1_suitability():
    assert splits.is_cdc_suitable("fc", "output")
    assert not splits.is_cdc_suitable("fc", "input")
    assert splits.is_cdc_suitable("conv", "channel")
    assert not splits.is_cdc_suitable("conv", "spatial")
    assert not splits.is_cdc_suitable("conv", "filter")


def test_spatial_split_ranges_cover_output():
    r = splits.spatial_split_ranges((6, 7), 4)
    assert r[0][0] == 0 and r[-1][1] == 42


def test_filter_split_partials_sum_to_full():
    wmat = RNG.normal(size=(5, 12)).astype(np.float32)
    cols = RNG.normal(size=(12, 9)).astype(np.float32)
    shards = splits.filter_split(wmat, 3)
    partials = [s.w @ cols[s.cols[0] : s.cols[1]] for s in shards]
    np.testing.assert_allclose(sum(partials), wmat @ cols, rtol=1e-4, atol=1e-4)
