//! The reusable per-layer execution unit of the coordinator.
//!
//! A [`Stage`] is the static plan of one model layer: either a local
//! merge-point op (pool/flatten/gap — negligible cost, no occupancy) or a
//! distributed weighted layer with its shard→device assignment, CDC
//! parity / 2MR replica tasks, and cost model. Both the single-shot
//! `Session::infer` and the pipelined `coordinator::serve` engine drive
//! requests through the same stages: **dispatch** (fan the input out to
//! the stage's devices, updating the device-occupancy ledger) and
//! **resolve** (gathered completions → arrival policy → CDC/2MR recovery
//! → merge). Keeping dispatch/resolve free of any notion of "the current
//! request" is what lets many requests occupy different stages at once.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::cdc;
use crate::error::{Error, Result};
use crate::fleet::{Completion, NetConfig, WorkOrder};
use crate::kernels::Scratch;
use crate::partition::LayerPlan;
use crate::runtime::manifest::LayerManifest;
use crate::tensor::Tensor;
use crate::transport::Transport;

use super::policy;
use super::LayerTrace;

/// One pipeline stage: the static execution plan of one model layer.
pub struct Stage {
    pub(crate) kind: StageKind,
}

/// How the stage's layer executes.
pub(crate) enum StageKind {
    /// Merge-point op (pool/flatten/gap) — negligible cost.
    Local { layer_idx: usize },
    /// Distributed (possibly d=1) weighted layer.
    Dist(DistStage),
}

impl Stage {
    /// True for distributed (occupancy-holding) stages.
    pub fn is_distributed(&self) -> bool {
        matches!(self.kind, StageKind::Dist(_))
    }

    /// Index of the layer this stage executes.
    pub fn layer_idx(&self) -> usize {
        match &self.kind {
            StageKind::Local { layer_idx } => *layer_idx,
            StageKind::Dist(d) => d.layer_idx,
        }
    }
}

/// A distributed stage's plan and cost model.
pub(crate) struct DistStage {
    pub layer_idx: usize,
    /// The split plan (exposed via `Session::layer_plans`).
    pub plan: LayerPlan,
    /// (device, task id) per data shard.
    pub data: Vec<(usize, u64)>,
    /// CDC parity devices: (device, task id, covered shard indices).
    pub parities: Vec<(usize, u64, Vec<usize>)>,
    /// 2MR replicas: (device, task id) aligned with `data`.
    pub replicas: Vec<(usize, u64)>,
    /// Fused-activation artifact in use (non-CDC fast path)?
    pub fused_relu: bool,
    /// Expected service time (ms) for the threshold gate, at batch
    /// width 1.
    pub expected_ms: f64,
    /// Expected service-time increment (ms) per additional batch member:
    /// the payload-proportional part of `expected_ms` (compute + bytes on
    /// the wire), excluding the fixed per-order network base cost.
    pub expected_extra_ms: f64,
    /// Request-leg payload bytes per batch member.
    pub request_bytes: u64,
    /// Per-task compute cost (uniform across a layer's shards) at batch
    /// width 1 — drives the device-occupancy ledger.
    pub macs: u64,
    /// Is this stage's layer eligible for cross-request micro-batching?
    /// Only fc layers are: their activations are `(k, 1)` columns that
    /// concatenate into one wider GEMM input. Conv stages always run at
    /// batch width 1.
    pub batchable: bool,
}

/// Bookkeeping for one dispatched (stage, request) pair.
pub(crate) struct PendingStage {
    /// Completions to gather before the stage can resolve.
    pub n_expected: usize,
}

/// Outcome of resolving one stage for one request.
pub(crate) enum StageOutcome {
    /// Stage completed; the merged activation moves to the next stage.
    Done {
        t_done: f64,
        output: Tensor,
        trace: LayerTrace,
    },
    /// Unrecoverable shard loss — the request is lost at this layer.
    Lost,
}

impl DistStage {
    /// Expected service time (ms) of one order at the given batch width:
    /// the fixed per-order cost plus `batch ×` the payload-proportional
    /// part. Width 1 is exactly [`DistStage::expected_ms`].
    pub(crate) fn expected_ms_for(&self, batch: usize) -> f64 {
        self.expected_ms + batch.saturating_sub(1) as f64 * self.expected_extra_ms
    }

    /// Group this stage's tasks per device (a device with several tasks —
    /// e.g. after failover — runs them serially within one order).
    fn orders(&self) -> BTreeMap<usize, Vec<u64>> {
        let mut orders: BTreeMap<usize, Vec<u64>> = BTreeMap::new();
        let all_tasks = self
            .data
            .iter()
            .copied()
            .chain(self.parities.iter().map(|(d, t, _)| (*d, *t)))
            .chain(self.replicas.iter().copied());
        for (dev, task) in all_tasks {
            orders.entry(dev).or_default().push(task);
        }
        orders
    }

    /// Fan one order's input out to the stage's devices at entry time
    /// `t_enter` (virtual ms on the simulator, wall ms since the serve
    /// epoch over TCP), serialising compute through the per-device
    /// occupancy ledger `device_free` (busy-until, ms). `rates` is the
    /// per-device compute-rate mirror (MACs/ms) so heterogeneous fleets
    /// keep the ledger consistent with the devices' own arithmetic (the
    /// ledger/net maths only drives the *simulated* timing model; a
    /// wall-clock transport carries the fields as telemetry and lets
    /// the real devices serialise themselves).
    ///
    /// `batch` is the order's micro-batch width (DESIGN.md §10): `input`
    /// carries that many column-concatenated member activations, and
    /// compute/payload costs scale with it while the per-order fixed
    /// costs are paid once. `req` is the batch leader's request id.
    /// `epoch` tags the order with the session's current partition epoch
    /// (DESIGN.md §13) so late replies from before a live repartition
    /// are identifiable.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn dispatch(
        &self,
        transport: &dyn Transport,
        net: &NetConfig,
        rates: &[f64],
        req: u64,
        input: Arc<Tensor>,
        batch: usize,
        t_enter: f64,
        epoch: u64,
        device_free: &mut [f64],
    ) -> Result<PendingStage> {
        let orders = self.orders();
        let n_expected: usize = orders.values().map(|v| v.len()).sum();
        let request_bytes = batch as u64 * self.request_bytes;
        for (dev, tasks) in &orders {
            let not_before = device_free[*dev];
            // Mirror the device's own arithmetic: compute starts at
            // max(t_enter + request leg, not_before) and runs the order's
            // tasks back to back.
            let req_net = net.sample_request(request_bytes);
            let start = (t_enter + req_net).max(not_before);
            device_free[*dev] =
                start + (tasks.len() as u64 * batch as u64 * self.macs) as f64 / rates[*dev];
            transport.dispatch(*dev, WorkOrder {
                req,
                tasks: tasks.clone(),
                input: input.clone(),
                request_bytes,
                batch,
                t_dispatch_ms: t_enter,
                not_before_ms: not_before,
                epoch,
            })?;
        }
        Ok(PendingStage { n_expected })
    }

    /// Resolve a fully-gathered stage: decide *when* the layer completed
    /// and *how* (pure policy layer), reconstruct any missing shard from
    /// its parity group, and merge shard outputs into the layer output.
    ///
    /// For a batched stage (`batch > 1`) every shard output — and the
    /// parity — is `(h, batch)`, so one decode subtraction reconstructs
    /// the missing shard for **all** members at once and the merged
    /// output is `(m, batch)`; the straggler gate scales its expected
    /// service time to the batch width.
    ///
    /// Takes the gathered completions by value so shard outputs are
    /// *moved* into the merge (no per-shard tensor clones), and `scratch`
    /// backs the merge/pool buffers — the steady-state resolve path
    /// performs no fresh heap allocations. Consumed shard outputs are
    /// offered back through [`Transport::reclaim`] so a wall-clock
    /// transport's decode arena recycles them (the simulator declines
    /// and they return to `scratch`, bit-identically to before).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn resolve(
        &self,
        layer: &LayerManifest,
        mut by_task: BTreeMap<u64, Completion>,
        t_enter: f64,
        batch: usize,
        threshold_factor: f64,
        scratch: &mut Scratch,
        transport: &dyn Transport,
    ) -> Result<StageOutcome> {
        let data_t: Vec<f64> = self
            .data
            .iter()
            .map(|(_, t)| by_task[t].t_arrival_ms)
            .collect();
        let threshold = if threshold_factor.is_finite() {
            t_enter + threshold_factor * self.expected_ms_for(batch)
        } else {
            f64::INFINITY
        };

        // Normalise every redundancy mode into (t_ms, missing data-shard
        // indices to reconstruct, trace kind).
        let (t_ms, missing, kind) = if !self.replicas.is_empty() {
            let rep_t: Vec<f64> = self
                .replicas
                .iter()
                .map(|(_, t)| by_task[t].t_arrival_ms)
                .collect();
            match policy::resolve_2mr(&data_t, &rep_t) {
                policy::Outcome::Lost => return Ok(StageOutcome::Lost),
                o => (o.t_ms(), Vec::new(), "all_data"),
            }
        } else if !self.parities.is_empty() {
            let par_t: Vec<f64> = self
                .parities
                .iter()
                .map(|(_, t, _)| by_task[t].t_arrival_ms)
                .collect();
            let groups: Vec<Vec<usize>> =
                self.parities.iter().map(|(_, _, g)| g.clone()).collect();
            match policy::resolve_grouped(&data_t, &par_t, &groups, threshold) {
                policy::GroupedOutcome::Lost => return Ok(StageOutcome::Lost),
                policy::GroupedOutcome::Ok { t_ms, missing } => {
                    let kind = if missing.is_empty() { "all_data" } else { "recovered" };
                    (t_ms, missing, kind)
                }
            }
        } else {
            match policy::resolve(&data_t, None, f64::INFINITY) {
                policy::Outcome::Lost => return Ok(StageOutcome::Lost),
                o => (o.t_ms(), Vec::new(), "all_data"),
            }
        };

        // Trace bookkeeping before shard outputs are moved out below.
        let aux_arrivals_ms: Vec<f64> = self
            .parities
            .iter()
            .map(|(_, t, _)| by_task[t].t_arrival_ms)
            .chain(self.replicas.iter().map(|(_, t)| by_task[t].t_arrival_ms))
            .collect();

        // Materialise shard outputs by *moving* them out of the gathered
        // completions (decode the missing ones from their parity group:
        // parity − Σ received — the paper's close-to-zero subtraction).
        let mut parts: Vec<Option<Tensor>> = self
            .data
            .iter()
            .map(|(_, t)| by_task.get_mut(t).and_then(|c| c.result.take()))
            .collect();
        // 2MR: fill from the replica when the primary is lost.
        for (i, (_, rt)) in self.replicas.iter().enumerate() {
            if parts[i].is_none() {
                parts[i] = by_task.get_mut(rt).and_then(|c| c.result.take());
            }
        }
        for &mi in &missing {
            let (_, ptask, cover) = self
                .parities
                .iter()
                .find(|(_, _, g)| g.contains(&mi))
                .expect("recovered shard must be covered");
            let parity_out = by_task
                .get_mut(ptask)
                .and_then(|c| c.result.take())
                .ok_or_else(|| Error::Fleet("parity result lost".into()))?;
            let received: Vec<&Tensor> = cover
                .iter()
                .filter(|&&i| i != mi)
                .map(|&i| {
                    parts[i]
                        .as_ref()
                        .ok_or_else(|| Error::Fleet("covered shard lost".into()))
                })
                .collect::<Result<Vec<_>>>()?;
            let recovered = cdc::decode_owned(parity_out, &received)?;
            parts[mi] = Some(recovered);
        }
        let out: Vec<Tensor> = parts
            .into_iter()
            .enumerate()
            .map(|(i, p)| {
                p.ok_or_else(|| Error::Fleet(format!("shard {i} unexpectedly lost")))
            })
            .collect::<Result<Vec<_>>>()?;

        // Merge: concat with the CDC padding trim fused in, deferred
        // epilogue, pool — all on scratch-arena buffers; the consumed
        // shard outputs are recycled into the arena.
        let mut merged = if layer.kind == "fc" {
            merge_rows(&out, layer.m, scratch)?
        } else {
            merge_channels(&out, layer.k, scratch)?
        };
        for p in out {
            if let Some(buf) = transport.reclaim(p.into_data()) {
                scratch.put(buf);
            }
        }
        if layer.relu && !self.fused_relu {
            merged.relu();
        }
        if layer.kind == "conv" && layer.pool > 0 {
            let mut buf = scratch.take(merged.maxpool_len(layer.pool, layer.pool)?);
            let shape = merged.maxpool_into(layer.pool, layer.pool, &mut buf)?;
            let pooled = Tensor::new(shape, buf)?;
            scratch.put(std::mem::replace(&mut merged, pooled).into_data());
        }

        let trace = LayerTrace {
            layer: layer.name.clone(),
            t_start_ms: t_enter,
            t_done_ms: t_ms,
            outcome: kind,
            recovered_shard: missing.first().copied(),
            data_arrivals_ms: data_t,
            aux_arrivals_ms,
        };
        Ok(StageOutcome::Done { t_done: t_ms, output: merged, trace })
    }
}

/// Concatenate fc shard outputs along axis 0, keeping only the first
/// `m_keep` rows (the CDC padding trim fused into the copy), into a
/// scratch-arena buffer. Mirrors `Tensor::concat0` + `take_rows`.
fn merge_rows(parts: &[Tensor], m_keep: usize, scratch: &mut Scratch) -> Result<Tensor> {
    let first = parts
        .first()
        .ok_or_else(|| Error::Shape("merge of zero shards".into()))?;
    let tail = &first.shape()[1..];
    let stride: usize = tail.iter().product();
    let mut total = 0;
    for p in parts {
        if &p.shape()[1..] != tail {
            return Err(Error::Shape(format!(
                "merge tail mismatch: {:?} vs {:?}",
                first.shape(),
                p.shape()
            )));
        }
        total += p.shape()[0];
    }
    if total < m_keep {
        return Err(Error::Shape(format!(
            "merge of {total} rows cannot keep {m_keep}"
        )));
    }
    let mut buf = scratch.take(m_keep * stride);
    let mut row = 0;
    for p in parts {
        if row >= m_keep {
            break;
        }
        let rows = p.shape()[0].min(m_keep - row);
        buf[row * stride..(row + rows) * stride]
            .copy_from_slice(&p.data()[..rows * stride]);
        row += rows;
    }
    let mut shape = vec![m_keep];
    shape.extend_from_slice(tail);
    Tensor::new(shape, buf)
}

/// Concatenate (H, W, C_i) conv shard outputs along the channel axis,
/// keeping only the first `c_keep` channels (CDC padding trim fused in),
/// into a scratch-arena buffer. Mirrors `Tensor::concat_channels` +
/// `take_channels`.
fn merge_channels(parts: &[Tensor], c_keep: usize, scratch: &mut Scratch) -> Result<Tensor> {
    let first = parts
        .first()
        .ok_or_else(|| Error::Shape("merge of zero shards".into()))?;
    let (h, w) = match first.shape()[..] {
        [h, w, _] => (h, w),
        _ => {
            return Err(Error::Shape(format!(
                "channel merge wants rank-3, got {:?}",
                first.shape()
            )))
        }
    };
    let mut c_total = 0;
    for p in parts {
        match p.shape()[..] {
            [ph, pw, pc] if ph == h && pw == w => c_total += pc,
            _ => {
                return Err(Error::Shape(format!(
                    "channel merge mismatch: {:?} vs {:?}",
                    first.shape(),
                    p.shape()
                )))
            }
        }
    }
    if c_total < c_keep {
        return Err(Error::Shape(format!(
            "merge of {c_total} channels cannot keep {c_keep}"
        )));
    }
    let mut buf = scratch.take(h * w * c_keep);
    if c_keep > 0 {
        for (y, px) in buf.chunks_exact_mut(c_keep).enumerate() {
            let mut off = 0;
            for p in parts {
                if off >= c_keep {
                    break;
                }
                let pc = p.shape()[2];
                let take = pc.min(c_keep - off);
                px[off..off + take].copy_from_slice(&p.data()[y * pc..y * pc + take]);
                off += take;
            }
        }
    }
    Tensor::new(vec![h, w, c_keep], buf)
}

/// Apply a merge-point (local) layer — free in the timing model. The
/// consumed activation's buffer is recycled into the scratch arena
/// (flatten is a pure reshape and keeps its storage).
pub(crate) fn apply_local(
    layer: &LayerManifest,
    cur: Tensor,
    scratch: &mut Scratch,
) -> Result<Tensor> {
    match layer.kind.as_str() {
        "maxpool" => {
            let mut buf = scratch.take(cur.maxpool_len(layer.pool, layer.pool)?);
            let shape = cur.maxpool_into(layer.pool, layer.pool, &mut buf)?;
            let out = Tensor::new(shape, buf)?;
            scratch.put(cur.into_data());
            Ok(out)
        }
        "flatten" => Ok(cur.flatten_col()),
        "gap" => {
            let out = cur.gap()?;
            scratch.put(cur.into_data());
            Ok(out)
        }
        other => Err(Error::Config(format!("unexpected local layer {other}"))),
    }
}
