//! Quickstart: deploy a trained LeNet-5 across four simulated IoT devices
//! with one CDC parity device, run an inference, kill a device, and watch
//! the request survive with close-to-zero recovery latency.
//!
//! ```bash
//! make artifacts                     # once: build AOT artifacts
//! cargo run --release --example quickstart
//! ```
//!
//! This flow is doctested: the crate-level rustdoc (`rust/src/lib.rs`)
//! carries the same sequence on the synthetic artifact set, and
//! `rust/tests/examples_smoke.rs::quickstart_flow_survives_device_loss`
//! runs it on every `cargo test` — the documented commands cannot rot.

use cdc_dnn::coordinator::{Session, SessionConfig, SplitSpec};
use cdc_dnn::fleet::FailurePlan;
use cdc_dnn::model::load_eval_set;
use cdc_dnn::runtime::Manifest;

fn main() -> cdc_dnn::Result<()> {
    let artifacts = std::path::Path::new("artifacts");

    // 1. Describe the deployment: LeNet-5, fc1 output-split over all four
    //    devices, protected by one CDC parity device (paper §5).
    let mut cfg = SessionConfig::new("lenet5");
    cfg.n_devices = 4;
    cfg.splits.insert("fc1".into(), SplitSpec::cdc(4));
    // Pin the whole layers to device 0 like a paper allocation file.
    for layer in ["conv1", "conv2", "fc2", "fc3"] {
        cfg.placement.insert(layer.into(), vec![0]);
    }
    cfg.placement.insert("fc1".into(), vec![0, 1, 2, 3]);

    // 2. Start the session: spawns the device fleet, loads + compiles the
    //    AOT artifacts, distributes the weight shards, sums the parity.
    let mut session = Session::start(artifacts, cfg)?;
    println!(
        "fleet: {} devices ({} parity)",
        session.total_devices(),
        session.extra_devices
    );

    // 3. Run a real digit through the distributed model.
    let manifest = Manifest::load(artifacts)?;
    let (images, labels) = load_eval_set(&manifest)?;
    let trace = session.infer(&images[0])?;
    println!(
        "healthy: predicted {} (label {}), simulated latency {:.1} ms",
        trace.output.argmax(),
        labels[0],
        trace.total_ms
    );

    // 4. A device disappears mid-service — the parity substitutes and the
    //    answer is *identical* (recovery is an exact subtraction).
    session.set_failure(2, FailurePlan::PermanentAt(0))?;
    let trace2 = session.infer(&images[0])?;
    println!(
        "device 2 down: predicted {} (recovered={}), latency {:.1} ms — no request lost",
        trace2.output.argmax(),
        trace2.any_recovery,
        trace2.total_ms
    );
    assert_eq!(trace.output.argmax(), trace2.output.argmax());
    assert!(trace2.any_recovery);
    println!("quickstart OK");
    Ok(())
}
