//! Per-request trace spans in a fixed-size ring (DESIGN.md §16).
//!
//! Every admitted request gets one preallocated slot recording its
//! lifecycle as timestamped span events:
//!
//! ```text
//! admitted → batched(width) → dispatched(device)× → replied/reaped(device)×
//!          → recovered(shard)? → merged | failed
//! ```
//!
//! The ring holds the last [`RING_CAP`] requests. **Retention rules:**
//! a slot is reused only once its trace has *finished* (merged or
//! failed) — when the ring wraps onto a still-live trace the new
//! request's trace is dropped (counted) instead of corrupting the live
//! one, so an in-flight request's spans are never clobbered however
//! fast traffic churns. Events beyond [`EVENTS_CAP`] per request are
//! dropped (counted) rather than reallocating: in steady state the
//! ring performs **zero allocations** — slots and their event arrays
//! are preallocated at construction, event kinds are `&'static str`,
//! and recording is a short critical section on one mutex (the serve
//! loop is the only writer; the gateway HTTP thread reads on demand).
//!
//! Timestamps are dual: `t_ms` is serve-relative (the transport
//! clock, comparable across a run's spans) and `t_unix_ms` is the
//! wall clock (comparable across processes and to log lines). Export
//! is JSON (`GET /v1/traces/{id}`) or Chrome trace-event format
//! (`?format=chrome`, loadable in Perfetto / `chrome://tracing`).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::json::{obj, Value};

use super::{lock, Counter};

/// Request traces retained (ring capacity).
pub const RING_CAP: usize = 256;

/// Span events retained per request.
pub const EVENTS_CAP: usize = 64;

/// One timestamped span event.
#[derive(Debug, Clone, Copy)]
pub struct SpanEvent {
    /// Wall-clock stamp (unix epoch ms).
    pub t_unix_ms: f64,
    /// Serve-relative stamp (transport clock ms).
    pub t_ms: f64,
    /// Event kind: `admitted`, `batched`, `dispatched`, `replied`,
    /// `reaped`, `recovered`, `merged`, `failed`.
    pub kind: &'static str,
    /// Device the event concerns (−1 when not device-scoped).
    pub device: i64,
    /// Kind-specific value (batch width, recovered shard, …); 0 when
    /// unused.
    pub value: f64,
}

struct Slot {
    req: u64,
    used: bool,
    /// Started and not yet finished — the slot must not be reused.
    live: bool,
    events: Vec<SpanEvent>,
}

struct Inner {
    slots: Vec<Slot>,
    /// Next insertion index (monotonic; slot = head % RING_CAP).
    head: u64,
}

/// Fixed-size ring of per-request traces. See the module docs for the
/// retention and zero-allocation rules.
#[derive(Default)]
pub struct TraceRing {
    inner: Mutex<Inner>,
    dropped: Counter,
}

impl std::fmt::Debug for TraceRing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceRing").field("dropped", &self.dropped.get()).finish()
    }
}

impl Default for Inner {
    fn default() -> Inner {
        Inner {
            slots: (0..RING_CAP)
                .map(|_| Slot {
                    req: 0,
                    used: false,
                    live: false,
                    events: Vec::with_capacity(EVENTS_CAP),
                })
                .collect(),
            head: 0,
        }
    }
}

fn unix_now_ms() -> f64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64() * 1e3)
        .unwrap_or(0.0)
}

impl TraceRing {
    /// Empty ring with all slots preallocated.
    pub fn new() -> TraceRing {
        TraceRing::default()
    }

    /// Events dropped so far (ring wrapped onto a live trace, or a
    /// trace overflowed [`EVENTS_CAP`]).
    pub fn dropped(&self) -> u64 {
        self.dropped.get()
    }

    /// Begin a trace for `req` with its `admitted` event. If the ring
    /// wraps onto a still-live trace the new trace is dropped.
    pub fn start(&self, req: u64, t_ms: f64) {
        let mut inner = lock(&self.inner);
        let idx = (inner.head % RING_CAP as u64) as usize;
        if inner.slots[idx].live {
            self.dropped.inc();
            return;
        }
        inner.head += 1;
        let slot = &mut inner.slots[idx];
        slot.req = req;
        slot.used = true;
        slot.live = true;
        slot.events.clear();
        slot.events.push(SpanEvent {
            t_unix_ms: unix_now_ms(),
            t_ms,
            kind: "admitted",
            device: -1,
            value: 0.0,
        });
    }

    /// Append a span event to `req`'s trace (no-op if the trace was
    /// never started or already rotated out).
    pub fn event(&self, req: u64, t_ms: f64, kind: &'static str, device: i64, value: f64) {
        let mut inner = lock(&self.inner);
        let Some(slot) = inner.slots.iter_mut().find(|s| s.used && s.req == req) else {
            return;
        };
        if slot.events.len() >= EVENTS_CAP {
            drop(inner);
            self.dropped.inc();
            return;
        }
        slot.events.push(SpanEvent {
            t_unix_ms: unix_now_ms(),
            t_ms,
            kind,
            device,
            value,
        });
    }

    /// Finish `req`'s trace with a terminal `merged`, `failed`, or
    /// `dropped` event; the slot becomes reusable.
    pub fn finish(&self, req: u64, t_ms: f64, kind: &'static str) {
        self.event(req, t_ms, kind, -1, 0.0);
        let mut inner = lock(&self.inner);
        if let Some(slot) = inner.slots.iter_mut().find(|s| s.used && s.req == req) {
            slot.live = false;
        }
    }

    /// Clone `req`'s events (`None` if unknown / rotated out).
    pub fn get(&self, req: u64) -> Option<Vec<SpanEvent>> {
        let inner = lock(&self.inner);
        inner
            .slots
            .iter()
            .find(|s| s.used && s.req == req)
            .map(|s| s.events.clone())
    }

    /// Summaries of retained traces, newest first: `(req, live,
    /// start_unix_ms, duration_ms, events, outcome)`.
    #[allow(clippy::type_complexity)]
    pub fn list(&self) -> Vec<(u64, bool, f64, f64, usize, &'static str)> {
        let inner = lock(&self.inner);
        let mut rows: Vec<(u64, &Slot)> = Vec::with_capacity(RING_CAP);
        // head-1 is the newest slot; walk backwards over used slots.
        for back in 0..RING_CAP as u64 {
            if back >= inner.head {
                break;
            }
            let idx = ((inner.head - 1 - back) % RING_CAP as u64) as usize;
            let s = &inner.slots[idx];
            if s.used {
                rows.push((s.req, s));
            }
        }
        rows.into_iter()
            .map(|(req, s)| {
                let first = s.events.first().map(|e| (e.t_unix_ms, e.t_ms)).unwrap_or((0.0, 0.0));
                let last_t = s.events.last().map(|e| e.t_ms).unwrap_or(first.1);
                let outcome = s.events.last().map(|e| e.kind).unwrap_or("admitted");
                (req, s.live, first.0, last_t - first.1, s.events.len(), outcome)
            })
            .collect()
    }

    /// `GET /v1/traces` body: retained traces, newest first.
    pub fn list_json(&self) -> Value {
        let rows = self
            .list()
            .into_iter()
            .map(|(req, live, start_unix_ms, duration_ms, events, outcome)| {
                obj(vec![
                    ("req", Value::Num(req as f64)),
                    ("live", Value::Bool(live)),
                    ("start_unix_ms", num(start_unix_ms)),
                    ("duration_ms", num(duration_ms)),
                    ("events", Value::Num(events as f64)),
                    ("outcome", Value::Str(outcome.to_string())),
                ])
            })
            .collect();
        obj(vec![
            ("traces", Value::Arr(rows)),
            ("ring_capacity", Value::Num(RING_CAP as f64)),
            ("dropped", Value::Num(self.dropped() as f64)),
        ])
    }

    /// `GET /v1/traces/{id}` body: one trace's events as JSON.
    pub fn get_json(&self, req: u64) -> Option<Value> {
        let events = self.get(req)?;
        let rows = events
            .iter()
            .map(|e| {
                obj(vec![
                    ("t_unix_ms", num(e.t_unix_ms)),
                    ("t_ms", num(e.t_ms)),
                    ("kind", Value::Str(e.kind.to_string())),
                    ("device", Value::Num(e.device as f64)),
                    ("value", num(e.value)),
                ])
            })
            .collect();
        Some(obj(vec![
            ("req", Value::Num(req as f64)),
            ("events", Value::Arr(rows)),
        ]))
    }

    /// One trace in Chrome trace-event format (Perfetto /
    /// `chrome://tracing`): device spans become `X` complete events
    /// from their `dispatched` stamp to the matching `replied`/`reaped`
    /// stamp, milestones become `i` instants, and the whole request is
    /// one enclosing `X` span.
    pub fn get_chrome(&self, req: u64) -> Option<Value> {
        let events = self.get(req)?;
        Some(obj(vec![
            ("traceEvents", Value::Arr(chrome_events(req, &events))),
            ("displayTimeUnit", Value::Str("ms".to_string())),
        ]))
    }

    /// All retained traces in one Chrome trace-event document.
    pub fn chrome_all(&self) -> Value {
        let reqs: Vec<u64> = self.list().iter().map(|&(req, ..)| req).collect();
        let mut all = Vec::new();
        for req in reqs {
            if let Some(events) = self.get(req) {
                all.extend(chrome_events(req, &events));
            }
        }
        obj(vec![
            ("traceEvents", Value::Arr(all)),
            ("displayTimeUnit", Value::Str("ms".to_string())),
        ])
    }
}

fn num(v: f64) -> Value {
    if v.is_finite() {
        Value::Num(v)
    } else {
        Value::Null
    }
}

fn chrome_event(
    name: &str,
    ph: &str,
    ts_us: f64,
    dur_us: Option<f64>,
    pid: u64,
    tid: i64,
    args: Vec<(&'static str, Value)>,
) -> Value {
    let mut fields = vec![
        ("name", Value::Str(name.to_string())),
        ("ph", Value::Str(ph.to_string())),
        ("ts", num(ts_us)),
        ("pid", Value::Num(pid as f64)),
        ("tid", Value::Num(tid as f64)),
    ];
    if let Some(d) = dur_us {
        fields.push(("dur", num(d.max(0.0))));
    }
    if !args.is_empty() {
        let map: BTreeMap<String, Value> =
            args.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
        fields.push(("args", Value::Obj(map)));
    }
    obj(fields)
}

fn chrome_events(req: u64, events: &[SpanEvent]) -> Vec<Value> {
    let mut out = Vec::with_capacity(events.len() + 2);
    let Some(first) = events.first() else {
        return out;
    };
    let us = |e: &SpanEvent| e.t_unix_ms * 1e3;
    // The request as one enclosing span on tid 0.
    if let Some(last) = events.last() {
        out.push(chrome_event(
            &format!("req {req} ({})", last.kind),
            "X",
            us(first),
            Some(us(last) - us(first)),
            req,
            0,
            vec![("req", Value::Num(req as f64))],
        ));
    }
    // Device spans: dispatched(d) → replied/reaped(d); milestones as
    // instants on tid 0.
    let mut open: BTreeMap<i64, &SpanEvent> = BTreeMap::new();
    for e in events {
        match e.kind {
            "dispatched" => {
                open.insert(e.device, e);
            }
            "replied" | "reaped" => {
                let start = open.remove(&e.device);
                let t0 = start.map(us).unwrap_or_else(|| us(e));
                out.push(chrome_event(
                    &format!("device {} {}", e.device, e.kind),
                    "X",
                    t0,
                    Some(us(e) - t0),
                    req,
                    e.device + 1,
                    vec![("kind", Value::Str(e.kind.to_string()))],
                ));
            }
            kind => {
                out.push(chrome_event(
                    kind,
                    "i",
                    us(e),
                    None,
                    req,
                    0,
                    vec![("value", num(e.value)), ("device", Value::Num(e.device as f64))],
                ));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_records_in_order() {
        let ring = TraceRing::new();
        ring.start(7, 1.0);
        ring.event(7, 2.0, "batched", -1, 3.0);
        ring.event(7, 3.0, "dispatched", 0, 0.0);
        ring.event(7, 9.0, "replied", 0, 0.0);
        ring.finish(7, 10.0, "merged");
        let events = ring.get(7).unwrap();
        let kinds: Vec<&str> = events.iter().map(|e| e.kind).collect();
        assert_eq!(kinds, ["admitted", "batched", "dispatched", "replied", "merged"]);
        assert!(events.windows(2).all(|w| w[0].t_ms <= w[1].t_ms));
        let (req, live, _, dur, n, outcome) = ring.list()[0];
        assert_eq!((req, live, n, outcome), (7, false, 5, "merged"));
        assert!((dur - 9.0).abs() < 1e-9);
    }

    #[test]
    fn wraparound_never_corrupts_a_live_trace() {
        let ring = TraceRing::new();
        ring.start(0, 0.0);
        ring.event(0, 1.0, "dispatched", 3, 0.0);
        // Fill the rest of the ring and wrap back onto slot 0.
        for req in 1..=(RING_CAP as u64 + 8) {
            ring.start(req, req as f64);
            if req < RING_CAP as u64 {
                ring.finish(req, req as f64 + 1.0, "merged");
            }
        }
        // The live trace's events survived the wrap intact.
        let events = ring.get(0).unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[1].kind, "dispatched");
        assert_eq!(events[1].device, 3);
        assert!(ring.dropped() > 0, "wrapped starts must be counted");
        // Finishing frees the slot for the next wrap.
        ring.finish(0, 2.0, "merged");
        assert_eq!(ring.get(0).unwrap().last().unwrap().kind, "merged");
    }

    #[test]
    fn event_overflow_is_dropped_and_counted() {
        let ring = TraceRing::new();
        ring.start(1, 0.0);
        for i in 0..(EVENTS_CAP + 10) {
            ring.event(1, i as f64, "replied", 0, 0.0);
        }
        assert_eq!(ring.get(1).unwrap().len(), EVENTS_CAP);
        assert!(ring.dropped() >= 10);
    }

    #[test]
    fn chrome_export_pairs_device_spans() {
        let ring = TraceRing::new();
        ring.start(5, 0.0);
        ring.event(5, 1.0, "dispatched", 2, 0.0);
        ring.event(5, 4.0, "reaped", 2, 0.0);
        ring.event(5, 4.5, "recovered", -1, 1.0);
        ring.finish(5, 5.0, "merged");
        let doc = ring.get_chrome(5).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let reaped = events
            .iter()
            .find(|e| {
                e.get("name").and_then(|n| n.as_str().map(str::to_string)).ok()
                    == Some("device 2 reaped".to_string())
            })
            .expect("device span present");
        assert_eq!(reaped.get("ph").unwrap().as_str().unwrap(), "X");
        assert!(reaped.get("dur").unwrap().as_f64().unwrap() > 0.0);
        // Unknown ids export as None.
        assert!(ring.get_chrome(99).is_none());
    }
}
