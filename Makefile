# Build entry points. `make artifacts` needs the python toolchain
# (jax + the repo's compile package); everything rust-side builds and
# tests offline without it (see DESIGN.md §3/§7).

ARTIFACTS ?= rust/artifacts

.PHONY: artifacts build test bench bench-gemm bench-gemm-smoke fmt clippy

artifacts:
	cd python && python -m compile.aot --out ../$(ARTIFACTS)
	ln -sfn $(ARTIFACTS) artifacts

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

# Kernel sweep: writes the BENCH_gemm.json baseline (naive vs tiled vs
# threaded GFLOP/s). The smoke flavor is the CI kernel-regression guard.
bench-gemm:
	cargo bench --bench gemm_runtime

bench-gemm-smoke:
	GEMM_BENCH_SMOKE=1 GEMM_BENCH_ENFORCE=1 cargo bench --bench gemm_runtime

fmt:
	cargo fmt --check

clippy:
	cargo clippy -- -D warnings
