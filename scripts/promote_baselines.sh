#!/usr/bin/env bash
# Fold CI bench artifacts into the committed baseline seeds.
#
# Usage: scripts/promote_baselines.sh [ARTIFACT_DIR]
#
# Scans ARTIFACT_DIR (default: .) recursively for BENCH_*.metrics.json
# files (written by cdc_dnn::bench::guard_baseline on every bench run;
# the CI bench matrix uploads them as artifacts — download with
# `gh run download <run-id>`) and merges each file's "metrics" object
# into rust/baselines/BENCH_<name>.json: existing keys are updated, new
# keys added, and every non-"metrics" key of the seed (e.g. the
# transport seed's "note") is preserved. Plain BENCH_*.json files are
# accepted too when they are seed-shaped (carry a "metrics" object);
# bench result docs without one are skipped.
#
# The script only edits files — review `git diff rust/baselines` and
# commit. Seeds should only ever contain numbers measured on the
# enforcing CI runner class (see rust/baselines/README.md).
set -euo pipefail

root="$(cd "$(dirname "$0")/.." && pwd)"
src="${1:-.}"
exec python3 - "$src" "$root/rust/baselines" <<'PY'
import json
import pathlib
import sys

src = pathlib.Path(sys.argv[1])
dst = pathlib.Path(sys.argv[2])
if not src.is_dir():
    sys.exit(f"promote_baselines: artifact dir {src} does not exist")

# Never promote the seeds into themselves when scanning the repo root.
candidates = sorted(p for p in src.rglob("BENCH_*.json") if dst not in p.parents)
if not candidates:
    sys.exit(f"promote_baselines: no BENCH_*.json under {src}")

promoted = 0
for path in candidates:
    try:
        doc = json.loads(path.read_text())
    except ValueError as e:
        print(f"  skip {path}: unparsable ({e})")
        continue
    metrics = doc.get("metrics") if isinstance(doc, dict) else None
    if not isinstance(metrics, dict) or not metrics:
        print(f'  skip {path}: no "metrics" object (result doc, not a seed)')
        continue
    name = path.name.removesuffix(".json").removesuffix(".metrics")
    seed_path = dst / f"{name}.json"
    seed = json.loads(seed_path.read_text()) if seed_path.exists() else {}
    old = seed.get("metrics", {})
    changed = sum(1 for k, v in metrics.items() if old.get(k) != v)
    merged = dict(seed)
    merged["metrics"] = {**old, **metrics}
    seed_path.write_text(json.dumps(merged, indent=2, sort_keys=True) + "\n")
    print(f"  {seed_path}: merged {len(metrics)} keys ({changed} changed) from {path}")
    promoted += 1

if promoted == 0:
    sys.exit("promote_baselines: nothing promotable found")
print(f"promoted {promoted} file(s) — review `git diff rust/baselines` and commit")
PY
