//! Reusable buffer arena for the kernel layer and the serve hot path.
//!
//! A [`Scratch`] is a free-list of `Vec<f32>` buffers: `take(len)` hands
//! out a zeroed buffer of exactly `len` elements, reusing the smallest
//! pooled allocation whose capacity fits, and `put` returns a buffer to
//! the pool. After a short warm-up every packing panel, im2col unroll,
//! merge target, and pooled activation in steady-state serving is served
//! from the pool — the compute path performs no per-request heap
//! allocations (DESIGN.md §8 lifetime rules).
//!
//! Ownership rules:
//!
//! * `take` transfers ownership out of the arena; the caller either
//!   `put`s the buffer back or lets it escape (e.g. as a [`Tensor`]'s
//!   backing storage — recycle it later with `put(tensor.into_data())`).
//! * The pool is bounded ([`Scratch::MAX_POOLED`] buffers); `put` beyond
//!   the bound evicts the smallest pooled buffer so the hottest (largest)
//!   sizes survive.
//! * A `Scratch` is not `Sync`; each thread owns its own arena. The
//!   kernel entry points use a thread-local arena via [`with_scratch`].
//!
//! [`Tensor`]: crate::tensor::Tensor

use std::cell::RefCell;

/// A bounded free-list of reusable `f32` buffers.
#[derive(Debug)]
pub struct Scratch {
    pool: Vec<Vec<f32>>,
    takes: u64,
    reuses: u64,
}

impl Scratch {
    /// Upper bound on pooled buffers (beyond it the smallest is evicted).
    pub const MAX_POOLED: usize = 16;

    /// Empty arena.
    pub fn new() -> Scratch {
        Scratch { pool: Vec::new(), takes: 0, reuses: 0 }
    }

    /// A zeroed buffer of exactly `len` elements, reusing the best-fit
    /// pooled allocation when one exists.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        self.takes += 1;
        let mut best: Option<(usize, usize)> = None;
        for (i, b) in self.pool.iter().enumerate() {
            let cap = b.capacity();
            if cap >= len && best.is_none_or(|(_, c)| cap < c) {
                best = Some((i, cap));
            }
        }
        // No pooled buffer fits: reuse the largest anyway (it grows once
        // and then serves this size forever) rather than allocating fresh.
        if best.is_none() {
            best = self
                .pool
                .iter()
                .enumerate()
                .max_by_key(|(_, b)| b.capacity())
                .map(|(i, b)| (i, b.capacity()));
        }
        let mut buf = match best {
            Some((i, _)) => {
                self.reuses += 1;
                self.pool.swap_remove(i)
            }
            None => Vec::new(),
        };
        buf.clear();
        buf.resize(len, 0.0);
        buf
    }

    /// Return a buffer to the pool for reuse. A full pool keeps its
    /// largest buffers: the incoming buffer is dropped unless it beats
    /// the smallest pooled one (which is then evicted).
    pub fn put(&mut self, buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        if self.pool.len() >= Scratch::MAX_POOLED {
            let smallest = (0..self.pool.len())
                .min_by_key(|&i| self.pool[i].capacity())
                .expect("pool is non-empty");
            if self.pool[smallest].capacity() >= buf.capacity() {
                return;
            }
            self.pool.swap_remove(smallest);
        }
        self.pool.push(buf);
    }

    /// Total `take` calls served.
    pub fn take_count(&self) -> u64 {
        self.takes
    }

    /// `take` calls served from the pool (no fresh allocation).
    pub fn reuse_count(&self) -> u64 {
        self.reuses
    }

    /// Buffers currently pooled.
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }
}

impl Default for Scratch {
    fn default() -> Scratch {
        Scratch::new()
    }
}

thread_local! {
    static THREAD_SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::new());
}

/// Run `f` with this thread's persistent kernel arena. Nested calls (a
/// kernel invoked from inside another `with_scratch` closure) fall back
/// to a fresh arena instead of panicking on the `RefCell`.
pub fn with_scratch<R>(f: impl FnOnce(&mut Scratch) -> R) -> R {
    THREAD_SCRATCH.with(|s| match s.try_borrow_mut() {
        Ok(mut sc) => f(&mut sc),
        Err(_) => f(&mut Scratch::new()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_and_reused() {
        let mut s = Scratch::new();
        let mut b = s.take(1024);
        assert_eq!(b.len(), 1024);
        assert!(b.iter().all(|&v| v == 0.0));
        b[0] = 7.0;
        s.put(b);
        let b2 = s.take(512);
        assert_eq!(b2.len(), 512);
        assert!(b2.capacity() >= 1024, "must reuse the pooled allocation");
        assert!(b2.iter().all(|&v| v == 0.0), "reused buffer must be zeroed");
        assert_eq!(s.reuse_count(), 1);
        assert_eq!(s.take_count(), 2);
    }

    #[test]
    fn undersized_pool_buffer_is_grown_not_leaked() {
        let mut s = Scratch::new();
        let b = s.take(8);
        s.put(b);
        let big = s.take(4096);
        assert_eq!(big.len(), 4096);
        assert_eq!(s.reuse_count(), 1, "small buffer is grown in place");
        assert_eq!(s.pooled(), 0);
    }

    #[test]
    fn pool_is_bounded() {
        let mut s = Scratch::new();
        for i in 0..(Scratch::MAX_POOLED + 8) {
            s.put(vec![0.0; i + 1]);
        }
        assert!(s.pooled() <= Scratch::MAX_POOLED);
        // Eviction keeps the largest buffers.
        assert!(s.pool.iter().all(|b| b.capacity() > 8));
    }

    #[test]
    fn with_scratch_nests_without_panic() {
        let n = with_scratch(|a| {
            let outer = a.take(16);
            let inner = with_scratch(|b| b.take(16).len());
            a.put(outer);
            inner
        });
        assert_eq!(n, 16);
    }
}
