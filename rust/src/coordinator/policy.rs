//! Pure gather-resolution policies: given simulated arrival times for a
//! layer's shards, decide *when* the layer completes and *how* (all data,
//! CDC substitution, or lost). Keeping this logic pure makes the paper's
//! latency semantics property-testable independent of threads and PJRT.

/// How a distributed layer completed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Outcome {
    /// All data shards arrived; completion at the slowest data arrival.
    AllData { t_ms: f64 },
    /// Parity substituted for exactly one data shard (failure *or*
    /// straggler): completion when n of n+1 results were in hand (gated by
    /// the threshold), recovery itself is a local subtraction (§5.2).
    Recovered { t_ms: f64, missing: usize },
    /// Unrecoverable: ≥ 1 shard missing and no usable parity.
    Lost,
}

impl Outcome {
    /// Completion time; ∞ when lost.
    pub fn t_ms(&self) -> f64 {
        match self {
            Outcome::AllData { t_ms } => *t_ms,
            Outcome::Recovered { t_ms, .. } => *t_ms,
            Outcome::Lost => f64::INFINITY,
        }
    }
}

/// Resolve a layer protected by (at most) one parity shard.
///
/// * `data`: simulated arrival time per data shard (∞ = never arrived).
/// * `parity`: arrival of the parity shard, if one was deployed.
/// * `threshold_ms`: straggler-mitigation gate — parity substitution may
///   not be *initiated* before this absolute time (paper §6.2: "a device
///   waits for a particular amount of time; adjusting this waiting
///   threshold treats our method as a solution to the straggler problem").
///   `0.0` = substitute as soon as any n of n+1 results are in.
pub fn resolve(data: &[f64], parity: Option<f64>, threshold_ms: f64) -> Outcome {
    assert!(!data.is_empty());
    let t_all = data.iter().copied().fold(f64::NEG_INFINITY, f64::max);

    let Some(t_parity) = parity else {
        return if t_all.is_finite() {
            Outcome::AllData { t_ms: t_all }
        } else {
            Outcome::Lost
        };
    };

    // Completion-by-substitution: drop the slowest data shard, finish at
    // max(parity, remaining data, threshold).
    let (slowest_idx, _) = data
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    let t_rest = data
        .iter()
        .enumerate()
        .filter(|(i, _)| *i != slowest_idx)
        .map(|(_, t)| *t)
        .fold(f64::NEG_INFINITY, f64::max)
        .max(f64::NEG_INFINITY);
    let t_rest = if data.len() == 1 { 0.0 } else { t_rest };
    // Earliest instant n of n+1 results are in hand.
    let t_sub = t_parity.max(t_rest);

    if t_all.is_finite() {
        // Straggler case: substitution may not be *initiated* before the
        // threshold, so it completes at max(t_sub, threshold); waiting for
        // the slow shard completes at t_all — take whichever is earlier.
        let gated = t_sub.max(threshold_ms);
        if t_all <= gated {
            Outcome::AllData { t_ms: t_all }
        } else {
            Outcome::Recovered { t_ms: gated, missing: slowest_idx }
        }
    } else if t_sub.is_finite() {
        // Failure case: the missing shard never arrives, substitution is
        // forced. A finite threshold still gates when the coordinator
        // gives up waiting; an infinite one means "recover as soon as n
        // results are in hand" (pure fault tolerance, no mitigation).
        let t = if threshold_ms.is_finite() { t_sub.max(threshold_ms) } else { t_sub };
        Outcome::Recovered { t_ms: t, missing: slowest_idx }
    } else {
        Outcome::Lost
    }
}

/// Resolve a 2MR (double-modular-redundancy) layer: every shard has two
/// replicas; a shard is ready at the *earlier* replica, the layer at the
/// slowest shard; lost if both replicas of any shard are lost.
pub fn resolve_2mr(primary: &[f64], replica: &[f64]) -> Outcome {
    assert_eq!(primary.len(), replica.len());
    let mut t = f64::NEG_INFINITY;
    for (p, r) in primary.iter().zip(replica) {
        let shard = p.min(*r);
        if !shard.is_finite() {
            return Outcome::Lost;
        }
        t = t.max(shard);
    }
    Outcome::AllData { t_ms: t }
}

/// Result of resolving a (multi-)parity layer: possibly several shards
/// recovered — at most one per parity group (Fig. 18).
#[derive(Debug, Clone, PartialEq)]
pub enum GroupedOutcome {
    /// Layer completed at `t_ms`; `missing` lists the data shards that
    /// must be reconstructed from their group parity (empty = all data).
    Ok { t_ms: f64, missing: Vec<usize> },
    /// ≥ 2 shards missing in one group — unrecoverable.
    Lost,
}

/// Resolve a Fig.-18 multi-parity layer: `groups[g]` lists the data-shard
/// indices covered by parity `g`. Each group must independently complete;
/// the layer completes at the slowest group. The single-parity scheme of
/// §5 is the one-group special case.
pub fn resolve_grouped(
    data: &[f64],
    parities: &[f64],
    groups: &[Vec<usize>],
    threshold_ms: f64,
) -> GroupedOutcome {
    assert_eq!(parities.len(), groups.len());
    let mut t = f64::NEG_INFINITY;
    let mut missing = Vec::new();
    for (g, cover) in groups.iter().enumerate() {
        let sub: Vec<f64> = cover.iter().map(|&i| data[i]).collect();
        match resolve(&sub, Some(parities[g]), threshold_ms) {
            Outcome::Lost => return GroupedOutcome::Lost,
            Outcome::AllData { t_ms } => t = t.max(t_ms),
            Outcome::Recovered { t_ms, missing: m } => {
                t = t.max(t_ms);
                missing.push(cover[m]);
            }
        }
    }
    GroupedOutcome::Ok { t_ms: t, missing }
}

#[cfg(test)]
mod tests {
    use super::*;
    const INF: f64 = f64::INFINITY;

    #[test]
    fn all_data_fast_path() {
        assert_eq!(
            resolve(&[10.0, 20.0], Some(100.0), 0.0),
            Outcome::AllData { t_ms: 20.0 }
        );
    }

    #[test]
    fn no_parity_failure_is_lost() {
        assert_eq!(resolve(&[10.0, INF], None, 0.0), Outcome::Lost);
        assert_eq!(resolve(&[10.0, 20.0], None, 0.0), Outcome::AllData { t_ms: 20.0 });
    }

    #[test]
    fn parity_replaces_failed_shard() {
        let o = resolve(&[10.0, INF, 30.0], Some(40.0), 0.0);
        assert_eq!(o, Outcome::Recovered { t_ms: 40.0, missing: 1 });
    }

    #[test]
    fn parity_beats_straggler() {
        // Shard 0 is a 500 ms straggler; parity at 25 ms lets the layer
        // complete at 30 ms (slowest of the n fastest).
        let o = resolve(&[500.0, 20.0, 30.0], Some(25.0), 0.0);
        assert_eq!(o, Outcome::Recovered { t_ms: 30.0, missing: 0 });
    }

    #[test]
    fn threshold_gates_substitution() {
        // Same straggler, but substitution may not start before 100 ms.
        let o = resolve(&[500.0, 20.0, 30.0], Some(25.0), 100.0);
        assert_eq!(o, Outcome::Recovered { t_ms: 100.0, missing: 0 });
        // A huge threshold means we wait for all data.
        let o = resolve(&[500.0, 20.0, 30.0], Some(25.0), 1000.0);
        assert_eq!(o, Outcome::AllData { t_ms: 500.0 });
    }

    #[test]
    fn two_failures_one_parity_lost() {
        assert_eq!(resolve(&[INF, INF, 10.0], Some(5.0), 0.0), Outcome::Lost);
    }

    #[test]
    fn single_shard_with_parity() {
        // d=1 + parity: parity alone can stand in.
        let o = resolve(&[INF], Some(42.0), 0.0);
        assert_eq!(o, Outcome::Recovered { t_ms: 42.0, missing: 0 });
    }

    #[test]
    fn parity_lost_degrades_gracefully() {
        assert_eq!(
            resolve(&[10.0, 20.0], Some(INF), 0.0),
            Outcome::AllData { t_ms: 20.0 }
        );
        assert_eq!(resolve(&[10.0, INF], Some(INF), 0.0), Outcome::Lost);
    }

    #[test]
    fn two_mr_first_response_wins() {
        let o = resolve_2mr(&[100.0, 30.0], &[20.0, INF]);
        assert_eq!(o, Outcome::AllData { t_ms: 30.0 });
        assert_eq!(resolve_2mr(&[INF, 30.0], &[INF, 10.0]), Outcome::Lost);
    }

    #[test]
    fn grouped_tolerates_one_failure_per_group() {
        let groups = vec![vec![0, 1], vec![2, 3]];
        // One failure in each group — recoverable (Fig. 18 bottom).
        let o = resolve_grouped(&[INF, 10.0, 20.0, INF], &[15.0, 25.0], &groups, 0.0);
        assert_eq!(
            o,
            GroupedOutcome::Ok { t_ms: 25.0, missing: vec![0, 3] }
        );
        // Two failures in one group — lost.
        let o = resolve_grouped(&[INF, INF, 20.0, 30.0], &[15.0, 25.0], &groups, 0.0);
        assert_eq!(o, GroupedOutcome::Lost);
        // No failures: all-data, no missing.
        let o = resolve_grouped(&[1.0, 2.0, 3.0, 4.0], &[9.0, 9.0], &groups, 100.0);
        assert_eq!(o, GroupedOutcome::Ok { t_ms: 4.0, missing: vec![] });
    }
}
