//! Coverage calculus for the paper's Fig. 17: how many devices are
//! protected against a single failure, as a function of *additional*
//! redundancy devices, under 2MR-only vs the hybrid CDC+2MR.
//!
//! Model (paper §6.3): a deployment runs some layers with model
//! parallelism (n_i devices each) and the rest on single devices. One CDC
//! parity device covers *all* n_i devices of one model-parallel layer
//! (constant cost); a 2MR replica covers exactly one device (linear cost).
//! The paper's absolute percentages depend on their unpublished device
//! counts — the reproduced claim is the ordering and the growth of the gap
//! with layer width (see EXPERIMENTS.md).

/// A deployment's redundancy-relevant shape.
#[derive(Debug, Clone)]
pub struct Deployment {
    pub name: String,
    /// Devices per model-parallel layer.
    pub mp_layers: Vec<usize>,
    /// Devices running a whole (non-split) chunk of the model.
    pub single_devices: usize,
}

impl Deployment {
    /// Construct a deployment.
    pub fn new(name: &str, mp_layers: Vec<usize>, single_devices: usize) -> Deployment {
        Deployment { name: name.to_string(), mp_layers, single_devices }
    }

    /// Devices doing original (non-redundant) work.
    pub fn total_devices(&self) -> usize {
        self.mp_layers.iter().sum::<usize>() + self.single_devices
    }

    /// Coverage with `extra` devices under 2MR only: each replica covers
    /// one device.
    pub fn coverage_2mr(&self, extra: usize) -> f64 {
        let n = self.total_devices();
        (extra.min(n)) as f64 / n as f64
    }

    /// Coverage with `extra` devices under hybrid CDC+2MR: parity devices
    /// first (widest layers first — each covers a whole layer), then 2MR
    /// for the rest.
    pub fn coverage_cdc_2mr(&self, extra: usize) -> f64 {
        let n = self.total_devices();
        let mut widths = self.mp_layers.clone();
        widths.sort_unstable_by(|a, b| b.cmp(a));
        let mut covered = 0usize;
        let mut left = extra;
        for w in widths {
            if left == 0 {
                break;
            }
            covered += w;
            left -= 1;
        }
        covered += left.min(self.single_devices);
        (covered.min(n)) as f64 / n as f64
    }

    /// Extra devices for 100% single-failure coverage under each scheme:
    /// (2MR, CDC+2MR). This is the paper's "linear vs constant" headline —
    /// per model-parallel layer, CDC needs 1 extra device where 2MR needs
    /// n_i (i.e. (1 + 1/N)× vs 2× hardware).
    pub fn full_coverage_cost(&self) -> (usize, usize) {
        let two_mr = self.total_devices();
        let hybrid = self.mp_layers.len() + self.single_devices;
        (two_mr, hybrid)
    }
}

/// The four deployments of Fig. 17 (a-d): AlexNet and the multi-MP-layer
/// video models; C3D appears with 2- and 3-device MP layers (c vs d).
pub fn fig17_deployments() -> Vec<Deployment> {
    vec![
        Deployment::new("alexnet", vec![2], 3),
        Deployment::new("vgg16", vec![2, 2], 5),
        Deployment::new("c3d_2dev", vec![2, 2], 4),
        Deployment::new("c3d_3dev", vec![3, 3], 4),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdc_dominates_2mr_everywhere() {
        for dep in fig17_deployments() {
            for extra in 0..=dep.total_devices() {
                assert!(
                    dep.coverage_cdc_2mr(extra) >= dep.coverage_2mr(extra) - 1e-12,
                    "{} extra={extra}",
                    dep.name
                );
            }
        }
    }

    #[test]
    fn c3d_two_extras_cover_both_mp_layers() {
        let c3d = Deployment::new("c3d_3dev", vec![3, 3], 4);
        // 2 parity devices cover 6 of 10 devices.
        assert!((c3d.coverage_cdc_2mr(2) - 0.6).abs() < 1e-9);
        // 2MR with 2 extras covers 2 of 10.
        assert!((c3d.coverage_2mr(2) - 0.2).abs() < 1e-9);
    }

    #[test]
    fn full_coverage_is_constant_vs_linear() {
        // Widening an MP layer leaves hybrid cost constant, grows 2MR cost.
        let narrow = Deployment::new("d", vec![2], 3);
        let wide = Deployment::new("d", vec![8], 3);
        assert_eq!(narrow.full_coverage_cost().1, wide.full_coverage_cost().1);
        assert!(wide.full_coverage_cost().0 > narrow.full_coverage_cost().0);
    }

    #[test]
    fn coverage_monotone_and_saturates() {
        let dep = Deployment::new("x", vec![3, 2], 4);
        let mut prev = -1.0;
        for extra in 0..12 {
            let c = dep.coverage_cdc_2mr(extra);
            assert!(c >= prev);
            prev = c;
        }
        assert!((dep.coverage_cdc_2mr(12) - 1.0).abs() < 1e-12);
    }
}
