"""AOT pipeline tests: HLO-text emission, manifest integrity, and the
build-path helpers. Keeps the python↔rust contract honest."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile.model import fc_shard_fn
from compile.zoo import ZOO, layer_flops, layer_io_shapes


def test_to_hlo_text_emits_parseable_module():
    import jax

    fn, spec = fc_shard_fn(4, 6, 1, relu=True)
    lowered = jax.jit(fn).lower(*spec)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:60]
    assert "ROOT" in text
    # return_tuple=True: root must be a tuple for rust's to_tuple1().
    assert "(f32[4,1]" in text or "tuple" in text


def test_artifact_set_dedupes(tmp_path):
    arts = aot.ArtifactSet(str(tmp_path))
    a = arts.fc_shard(8, 16, relu=True)
    b = arts.fc_shard(8, 16, relu=True)
    c = arts.fc_shard(8, 16, relu=False)
    assert a == b
    assert c != a
    assert len(arts.entries) == 2
    assert os.path.exists(tmp_path / "hlo" / f"{a}.hlo.txt")


def test_fc_split_plan_covers_every_model():
    """Every split degree in the plan must divide work uniformly into
    ceil(m/d) shards — the shapes the rust LayerPlan will request."""
    for name, plan in aot.FC_SPLITS.items():
        model = ZOO[name]
        fc_layers = {l.name: l for l in model.layers if l.kind == "fc"}
        for lname, degrees in plan.items():
            assert lname in fc_layers, f"{name}.{lname}"
            assert 1 in degrees, "d=1 needed for Fig.2 / local pipeline"
            for d in degrees:
                assert -(-fc_layers[lname].m // d) >= 1


def test_manifest_exists_and_references_resolve():
    """Run against the built artifacts dir if present (make artifacts)."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(root, "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("artifacts not built")
    m = json.load(open(manifest_path))
    names = {a["name"] for a in m["artifacts"]}
    for a in m["artifacts"]:
        assert os.path.exists(os.path.join(root, a["file"])), a["name"]
    for model in m["models"]:
        assert os.path.exists(os.path.join(root, model["weights_file"]))
        for layer in model["layers"]:
            for arts in layer.get("splits", {}).values():
                for key in ("relu", "lin"):
                    if key in arts:
                        assert arts[key] in names, arts[key]
    for g in m["goldens"]:
        for k, v in g.items():
            if isinstance(v, str) and v.endswith(".bin"):
                assert os.path.exists(os.path.join(root, v)), v


def test_weight_offsets_are_consistent():
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(root, "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("artifacts not built")
    m = json.load(open(manifest_path))
    for model in m["models"]:
        size = os.path.getsize(os.path.join(root, model["weights_file"]))
        for layer in model["layers"]:
            if "w_offset" not in layer:
                continue
            mm, kk = layer["w_shape"]
            assert layer["w_offset"] + 4 * mm * kk <= size
            assert layer["b_offset"] + 4 * mm <= size


def test_layer_flops_positive_for_weighted_layers():
    for model in ZOO.values():
        flops = layer_flops(model)
        for layer, f in zip(model.layers, flops):
            if layer.kind in ("fc", "conv"):
                assert f > 0, f"{model.name}.{layer.name}"
            else:
                assert f == 0


def test_io_shapes_consistent_with_flatten():
    for model in ZOO.values():
        shapes = layer_io_shapes(model)
        for layer, (inp, out) in zip(model.layers, shapes):
            if layer.kind == "flatten":
                assert out[0] == int(np.prod(inp))
