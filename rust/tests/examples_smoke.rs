//! Smoke twins of the documented examples (`examples/quickstart.rs`,
//! `examples/e2e_serving.rs`): the same API flow each example header
//! documents, run here on the synthetic artifact set so CI exercises it
//! on every `cargo test` with no `make artifacts` step — the documented
//! flows can never rot. (The crate-level rustdoc carries a doctested
//! copy of the quickstart as well; the real examples additionally
//! compile on every test run via Cargo's example targets.)

use cdc_dnn::coordinator::{Pipeline, Session, SessionConfig, SplitSpec, Workload};
use cdc_dnn::fleet::{FailurePlan, NetConfig};
use cdc_dnn::model::load_eval_set;
use cdc_dnn::runtime::Manifest;
use cdc_dnn::testkit::synth;

/// `examples/quickstart.rs` flow: deploy with a CDC parity device, run an
/// inference, kill a device, and watch the request survive with an
/// *identical* answer.
#[test]
fn quickstart_flow_survives_device_loss() {
    let artifacts = synth::build(90).unwrap();

    let mut cfg = SessionConfig::new(synth::MODEL);
    cfg.n_devices = 4;
    cfg.net = NetConfig::moderate();
    cfg.splits.insert("fc1".into(), SplitSpec::cdc(4));
    cfg.placement.insert("fc1".into(), vec![0, 1, 2, 3]);
    cfg.placement.insert("fc2".into(), vec![0]);
    let mut session = Session::start(&artifacts.root, cfg).unwrap();
    assert_eq!(session.total_devices(), 5, "4 data + 1 parity");

    let manifest = Manifest::load(&artifacts.root).unwrap();
    let (images, _labels) = load_eval_set(&manifest).unwrap();
    let healthy = session.infer(&images[0]).unwrap();

    session.set_failure(2, FailurePlan::PermanentAt(0)).unwrap();
    let recovered = session.infer(&images[0]).unwrap();
    assert!(recovered.any_recovery, "parity must substitute");
    assert_eq!(
        healthy.output.argmax(),
        recovered.output.argmax(),
        "recovery must not change the answer"
    );
}

/// `examples/e2e_serving.rs` flow: serve the whole eval set through the
/// pipelined engine with a failing device — no lost requests, recoveries
/// observed, multiple requests in flight.
#[test]
fn e2e_serving_flow_pipelines_with_recovery() {
    let artifacts = synth::build(91).unwrap();

    let mut cfg = SessionConfig::new(synth::MODEL);
    cfg.n_devices = 4;
    cfg.net = NetConfig::moderate();
    cfg.threshold_factor = 1.5;
    cfg.splits.insert("fc1".into(), SplitSpec::cdc(4));
    cfg.splits.insert("fc2".into(), SplitSpec::cdc(2));
    cfg.placement.insert("fc1".into(), vec![0, 1, 2, 3]);
    cfg.placement.insert("fc2".into(), vec![2, 3]);
    let mut session = Session::start(&artifacts.root, cfg).unwrap();

    session.set_failure(3, FailurePlan::PermanentAt(0)).unwrap();

    let manifest = Manifest::load(&artifacts.root).unwrap();
    let (images, _labels) = load_eval_set(&manifest).unwrap();
    let n = images.len();
    let workload = Workload::closed(images, session.saturating_concurrency());
    let report = Pipeline::new(&mut session).run(&workload).unwrap();

    assert_eq!(report.failures.len(), 0, "CDC system must not lose requests");
    assert_eq!(report.throughput.completed as usize, n);
    assert!(report.throughput.recovered > 0, "failure must exercise recovery");
    assert!(
        report.max_concurrent_requests >= 2,
        "pipeline must keep multiple requests in flight: {}",
        report.line()
    );
}
