//! END-TO-END serving driver (DESIGN.md §5): load the *trained* LeNet-5,
//! deploy it across a six-device simulated IoT fleet (four data devices +
//! CDC parity devices), and serve the entire held-out evaluation set as
//! single-batch requests through the full stack — Pallas-authored AOT
//! artifacts executed via PJRT on real threads, WiFi-jittered timing,
//! an intermittently failing device, and straggler mitigation on.
//!
//! Reports: classification accuracy (must match the clean model — CDC
//! recovery is exact), simulated latency distribution, recovery counts,
//! lost requests (must be zero), and harness wall-clock throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_serving
//! ```

use cdc_dnn::coordinator::{Session, SessionConfig, SplitSpec};
use cdc_dnn::fleet::FailurePlan;
use cdc_dnn::metrics::Series;
use cdc_dnn::model::load_eval_set;
use cdc_dnn::runtime::Manifest;

fn main() -> cdc_dnn::Result<()> {
    let artifacts = std::path::Path::new("artifacts");
    let manifest = Manifest::load(artifacts)?;
    let (images, labels) = load_eval_set(&manifest)?;
    println!("eval set: {} synthetic digits", images.len());

    // Deployment: fc1 CDC-split over 4 devices, fc2 CDC-split over 2,
    // conv trunk pinned — 4 data devices + 2 parity devices = 6, the
    // paper's Case-Study-II scale.
    let mut cfg = SessionConfig::new("lenet5");
    cfg.n_devices = 4;
    cfg.splits.insert("fc1".into(), SplitSpec::cdc(4));
    cfg.splits.insert("fc2".into(), SplitSpec::cdc(2));
    cfg.placement.insert("conv1".into(), vec![0]);
    cfg.placement.insert("conv2".into(), vec![1]);
    cfg.placement.insert("fc1".into(), vec![0, 1, 2, 3]);
    cfg.placement.insert("fc2".into(), vec![2, 3]);
    cfg.placement.insert("fc3".into(), vec![0]);
    cfg.threshold_factor = 1.5; // straggler mitigation
    let mut session = Session::start(artifacts, cfg)?;
    println!(
        "fleet: {} devices ({} parity), WiFi-jitter timing model, \
         straggler threshold 1.5×",
        session.total_devices(),
        session.extra_devices
    );

    // Device 3 drops 20% of its replies (intermittent IoT failure).
    session.set_failure(3, FailurePlan::Intermittent(0.2))?;

    let mut lat = Series::new();
    let mut correct = 0usize;
    let mut recovered = 0usize;
    let mut lost = 0usize;
    let t0 = std::time::Instant::now();
    for (img, &label) in images.iter().zip(&labels) {
        match session.infer(img) {
            Ok(trace) => {
                lat.record(trace.total_ms);
                if trace.output.argmax() == label as usize {
                    correct += 1;
                }
                if trace.any_recovery {
                    recovered += 1;
                }
            }
            Err(_) => {
                lost += 1;
                session.drain();
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let n = images.len();
    let s = lat.summary();

    println!("\n=== end-to-end serving report ===");
    println!("requests served:     {n}");
    println!("lost requests:       {lost}  (paper claim: never loses a request)");
    println!("CDC recoveries:      {recovered}");
    println!(
        "accuracy:            {:.2}% (trained clean accuracy ≈ {:.2}%)",
        100.0 * correct as f64 / n as f64,
        100.0 * manifest
            .raw
            .get("training")
            .and_then(|t| t.get("lenet5"))
            .and_then(|t| t.get("test_acc"))
            .and_then(|v| v.as_f64())
            .unwrap_or(f64::NAN)
    );
    println!("simulated latency:   {}", s.line());
    println!("{}", lat.render_histogram(0.0, s.p99.max(100.0), 14, 36));
    println!(
        "harness wall-clock:  {wall:.1}s → {:.1} req/s through real PJRT compute",
        n as f64 / wall
    );

    assert_eq!(lost, 0, "CDC system must not lose requests");
    assert!(recovered > 0, "failure injection must exercise recovery");
    println!("e2e_serving OK");
    Ok(())
}
