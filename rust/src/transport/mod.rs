//! Transport subsystem: how the coordinator reaches its fleet
//! (DESIGN.md §11).
//!
//! Every PR-1..4 experiment executed over the **virtual-time simulator**
//! — device threads in the coordinator process stamping simulated
//! arrival times. This module puts that fleet behind a [`Transport`]
//! trait and adds a second, **real-execution** implementation:
//!
//! * [`SimTransport`] — the adapter over the in-process device-thread
//!   fleet. Dispatch/recv are the exact same channels as before, and
//!   every wall-clock hook is a no-op, so sim-mode serving is
//!   bit-identical to the pre-transport engine.
//! * [`TcpTransport`] — per-device persistent TCP connections speaking
//!   the length-prefixed [`wire`] protocol to standalone `cdc-dnn
//!   worker` processes, all multiplexed through the single [`evloop`]
//!   I/O thread (epoll/kqueue readiness, writev-coalesced sends,
//!   in-place frame decode). Completions are stamped with
//!   **wall-clock** receipt time; the loop's poll timeout doubles as
//!   the reply reaper, synthesising a lost completion (`t_arrival =
//!   ∞`) for any order still outstanding past its per-order deadline,
//!   and a connection death (worker killed mid-request) synthesises
//!   losses for everything in flight on it — so the serve engine's
//!   invariant ("every dispatched task eventually yields a
//!   completion") holds over real sockets with real process failures,
//!   while coordinator I/O threads stay O(1) in fleet width.
//!
//! The serving engine (`coordinator::serve`) is transport-generic: the
//! same pipelining, micro-batching, adaptive-policy and CDC-parity
//! machinery drives either implementation. The [`loopback`] harness
//! spawns N worker child processes on 127.0.0.1 and is what the
//! integration tests and the `transport_loopback` bench use to exercise
//! real process-kill failure injection.

pub mod evloop;
pub mod loopback;
pub mod sim;
pub mod tcp;
pub mod wire;
pub mod worker;

use crate::error::Result;
use crate::fleet::{Completion, FailurePlan, NetConfig, TaskDef, WorkOrder};

pub use sim::SimTransport;
pub use tcp::TcpTransport;

/// A change in fleet membership observed by a transport (DESIGN.md §13).
///
/// Wall-clock transports surface these from
/// [`Transport::poll_membership`]; the serve engine applies them at
/// pipeline-quiescent points (no stage mid-flight), re-partitioning the
/// model across the new active set. The simulator never emits any — sim
/// churn goes through the scenario engine's session rebuild instead, so
/// sim-mode serving stays bit-identical.
#[derive(Debug, Clone, PartialEq)]
pub enum MembershipEvent {
    /// A worker completed the `Register`/`RegisterAck` handshake and is
    /// deployable at `device` with its announced compute rate.
    Joined {
        /// Transport device slot assigned to the newcomer.
        device: usize,
        /// Compute rate the worker announced in its `Register` frame
        /// (MACs per millisecond); feeds the expected-latency model.
        macs_per_ms: f64,
    },
    /// A worker has missed enough heartbeats to be suspect but is not
    /// yet declared dead. Feeds `AdaptivePolicy` as drop-rate evidence
    /// so the straggler gate tightens *before* the device fails.
    Suspect {
        /// Transport device slot of the suspect worker.
        device: usize,
        /// Consecutive heartbeat intervals with no inbound traffic.
        missed: u32,
    },
    /// A previously suspect worker produced traffic again.
    Recovered {
        /// Transport device slot of the recovered worker.
        device: usize,
    },
    /// The worker sent `Leave`: it will finish in-flight orders but
    /// must receive no new dispatches. The coordinator re-partitions
    /// without it, then the transport closes the drained connection.
    LeaveRequested {
        /// Transport device slot of the draining worker.
        device: usize,
    },
    /// The connection died or the worker missed the dead-after
    /// heartbeat budget. Everything in flight on it was already
    /// synthesised as lost (`t_arrival = ∞`).
    Dead {
        /// Transport device slot of the dead worker.
        device: usize,
    },
}

/// How the coordinator reaches its devices. All methods take `&self`:
/// implementations synchronise internally (channels / mutexed socket
/// writers), which lets the serve loop hold immutable borrows of the
/// stage plan while dispatching and gathering.
pub trait Transport: Send {
    /// Short tag for reports ("sim" | "tcp").
    fn label(&self) -> &'static str;

    /// True when completions are stamped with wall-clock time (the
    /// serve engine then paces dispatches and gathers eagerly instead
    /// of round-synchronously).
    fn wall_clock(&self) -> bool;

    /// Milliseconds since the current serve epoch (wall-clock
    /// transports; the simulator returns 0 — its time comes from the
    /// completions themselves).
    fn now_ms(&self) -> f64 {
        0.0
    }

    /// Mark the start of a `Session::serve` run: wall-clock transports
    /// reset their epoch and clear orphaned in-flight state.
    fn begin_serve(&self) {}

    /// Block until the transport clock reaches `t_ms` (no-op for the
    /// simulator — virtual time needs no waiting).
    fn pace(&self, _t_ms: f64) {}

    /// Clamp a virtual entry timestamp to "not in the past" on the
    /// transport clock (identity for the simulator).
    fn clamp_ms(&self, t_ms: f64) -> f64 {
        t_ms
    }

    /// Number of devices this transport reaches.
    fn n_devices(&self) -> usize;

    /// Install tasks (weights included) on a device.
    fn deploy(&self, device: usize, tasks: Vec<TaskDef>) -> Result<()>;

    /// Remove tasks from a device.
    fn undeploy(&self, device: usize, task_ids: Vec<u64>) -> Result<()>;

    /// Dispatch one work order. Must never fail just because the device
    /// is dead: a dead device's tasks yield synthesised lost
    /// completions instead, exactly like the simulator's `∞` arrivals.
    fn dispatch(&self, device: usize, order: WorkOrder) -> Result<()>;

    /// Block for the next completion. Every dispatched task eventually
    /// produces exactly one (real reply, worker error, deadline
    /// timeout, or connection death).
    fn recv(&self) -> Result<Completion>;

    /// Wall-clock transports: block for the next completion, but give
    /// up once the transport clock reaches `until_ms` (`Ok(None)`) —
    /// the serve engine's wake-up for dispatches it deferred to the
    /// future. The simulator never defers, so its default blocks like
    /// [`Transport::recv`].
    fn recv_deadline(&self, _until_ms: f64) -> Result<Option<Completion>> {
        self.recv().map(Some)
    }

    /// Non-blocking completion poll (`Session::drain`).
    fn try_recv(&self) -> Option<Completion>;

    /// Offer a consumed result buffer back to the transport.
    /// `Some(buf)` = the transport has no private use for it and the
    /// caller should recycle it in its own arena (the simulator's
    /// path — bit-identical to the pre-reclaim engine). `None` = the
    /// transport kept it: the TCP transport feeds its decode arena, so
    /// Reply tensors parsed off the wire and shard outputs consumed by
    /// the serve loop cycle through one bounded pool.
    fn reclaim(&self, buf: Vec<f32>) -> Option<Vec<f32>> {
        Some(buf)
    }

    /// Swap a device's failure plan (sim: the timing model; tcp: the
    /// worker's silent-drop emulation).
    fn set_failure(&self, device: usize, plan: FailurePlan) -> Result<()>;

    /// Swap a device's network profile (sim: the timing model; tcp: the
    /// worker's artificial reply delay).
    fn set_net(&self, device: usize, net: NetConfig) -> Result<()>;

    /// Change a device's compute rate in MACs/ms (sim: the timing
    /// model; tcp: the worker's artificial compute delay).
    fn set_rate(&self, device: usize, macs_per_ms: f64) -> Result<()>;

    /// Drain queued [`MembershipEvent`]s (joins, suspicion changes,
    /// drains, deaths). The simulator's fleet is fixed, so the default
    /// returns nothing.
    fn poll_membership(&self) -> Vec<MembershipEvent> {
        Vec::new()
    }

    /// The address new workers can `Register` at, when this transport
    /// listens for joins (`None` for the simulator or a TCP transport
    /// configured without a listen socket).
    fn listen_addr(&self) -> Option<String> {
        None
    }

    /// Stop dispatching to `device` and close its connection once its
    /// in-flight orders finish — the graceful half of a `Leave`. No-op
    /// for the simulator.
    fn retire(&self, _device: usize) {}

    /// Snapshot of transport-level counters as `(name, value)` pairs in
    /// Prometheus naming style (`*_total` for monotonic counts). The
    /// serve loop mirrors these into [`crate::telemetry::Telemetry`]
    /// once per pass; the default (simulator) exposes none.
    fn counters(&self) -> Vec<(&'static str, u64)> {
        Vec::new()
    }
}

/// TCP transport parameters (the deployment file's `transport` section).
#[derive(Debug, Clone)]
pub struct TcpConfig {
    /// Worker addresses (`host:port`), one per device in device order.
    /// May list more workers than the session needs; extras stay idle.
    /// Empty + the CLI's `--transport tcp` means "spawn a loopback
    /// fleet automatically".
    pub workers: Vec<String>,
    /// Wall-clock straggler gate: an order's replies not received this
    /// many ms after dispatch are treated as lost (CDC substitutes from
    /// parity — the paper's zero-recovery-latency path, on real time).
    pub order_deadline_ms: f64,
    /// Per-connection handshake/connect timeout.
    pub connect_timeout_ms: u64,
    /// Address the coordinator listens on for live worker joins
    /// (`Register` handshakes). `Some("127.0.0.1:0")` — the default —
    /// binds an ephemeral loopback port; `None` (empty string in the
    /// deployment JSON) disables live membership entirely.
    pub listen: Option<String>,
    /// Heartbeat probe interval in milliseconds. Each tick the event
    /// loop sends `Heartbeat` to every live worker and advances the
    /// suspicion ladder for workers with no inbound traffic since the
    /// previous tick.
    pub heartbeat_ms: f64,
    /// Consecutive silent heartbeat intervals before a worker is
    /// reported [`MembershipEvent::Suspect`].
    pub suspect_after_missed: u32,
    /// Consecutive silent heartbeat intervals before a worker is
    /// declared [`MembershipEvent::Dead`] and its connection killed.
    pub dead_after_missed: u32,
}

impl Default for TcpConfig {
    fn default() -> TcpConfig {
        TcpConfig {
            workers: Vec::new(),
            order_deadline_ms: 2_000.0,
            connect_timeout_ms: 5_000,
            listen: Some("127.0.0.1:0".to_string()),
            heartbeat_ms: 250.0,
            suspect_after_missed: 2,
            dead_after_missed: 8,
        }
    }
}

/// Which transport a session deploys over (`SessionConfig::transport`).
#[derive(Debug, Clone, Default)]
pub enum TransportSpec {
    /// The in-process virtual-time simulator (the default; bit-identical
    /// to the pre-transport engine).
    #[default]
    Sim,
    /// Real execution over TCP worker processes.
    Tcp(TcpConfig),
}

impl TransportSpec {
    /// Short tag for logs/serialisation.
    pub fn mode(&self) -> &'static str {
        match self {
            TransportSpec::Sim => "sim",
            TransportSpec::Tcp(_) => "tcp",
        }
    }
}
