//! Fig. 2 — destructive accuracy drop under per-layer activation loss.
//!
//! The paper zeroes a fraction of one layer's data in LeNet-5 (a) and
//! Inception v3 (b) and shows (i) accuracy collapses for loss > 70% and
//! (ii) the deeper/more general model is *more* sensitive. We reproduce
//! with the trained `lenet5` and the deeper trained `deepnet` stand-in
//! (DESIGN.md §2), running real inference through the d=1 artifacts with
//! loss injected between layers.

use crate::error::Result;
use crate::json::{obj, Value};
use crate::model::{load_eval_set, LocalPipeline, LossInjection, Weights};
use crate::rng::Pcg32;
use crate::runtime::{Manifest, Runtime};

use super::{print_table, ExpCtx};

/// Loss fractions swept (the paper's x-axis).
pub const FRACTIONS: [f64; 8] = [0.0, 0.1, 0.3, 0.5, 0.7, 0.8, 0.9, 0.99];

/// One measured curve.
#[derive(Debug)]
pub struct Curve {
    pub model: String,
    pub layer_idx: usize,
    pub accuracy: Vec<f64>,
}

/// Run the experiment; returns the curves for tests.
pub fn run(ctx: &ExpCtx) -> Result<Vec<Curve>> {
    let manifest = Manifest::load(&ctx.artifacts)?;
    let runtime = Runtime::new()?;
    let (images, labels) = load_eval_set(&manifest)?;
    let n_eval = if ctx.quick { 64.min(images.len()) } else { images.len() };
    let images = &images[..n_eval];
    let labels = &labels[..n_eval];

    let mut curves = Vec::new();
    let mut rows = Vec::new();
    for model_name in ["lenet5", "deepnet"] {
        let Ok(model) = manifest.model(model_name) else { continue };
        let weights = Weights::load(&manifest, model)?;
        let pipe = LocalPipeline { runtime: &runtime, manifest: &manifest, model, weights: &weights };
        // Inject into the middle weighted layer (a conv for both models),
        // like the paper's per-layer loss.
        let n_weighted =
            model.layers.iter().filter(|l| l.is_weighted()).count();
        let layer_idx = n_weighted / 2;
        let mut acc = Vec::new();
        for &f in &FRACTIONS {
            let mut rng = Pcg32::new(ctx.seed, (f * 1000.0) as u64);
            let loss = if f == 0.0 {
                None
            } else {
                Some(LossInjection { layer_idx, fraction: f })
            };
            let a = pipe.accuracy(images, labels, loss, &mut rng)?;
            acc.push(a);
            rows.push(vec![
                model_name.to_string(),
                format!("{layer_idx}"),
                format!("{:.0}%", f * 100.0),
                format!("{:.1}%", a * 100.0),
            ]);
        }
        curves.push(Curve { model: model_name.into(), layer_idx, accuracy: acc });
    }

    println!("\n=== Fig. 2: accuracy under per-layer data loss ===");
    print_table(&["model", "layer", "loss", "accuracy"], &rows);

    let json_curves: Vec<Value> = curves
        .iter()
        .map(|c| {
            obj(vec![
                ("model", Value::Str(c.model.clone())),
                ("layer_idx", Value::Num(c.layer_idx as f64)),
                (
                    "fractions",
                    Value::Arr(FRACTIONS.iter().map(|&f| Value::Num(f)).collect()),
                ),
                (
                    "accuracy",
                    Value::Arr(c.accuracy.iter().map(|&a| Value::Num(a)).collect()),
                ),
            ])
        })
        .collect();
    ctx.write_result(
        "fig2",
        &obj(vec![
            ("experiment", Value::Str("fig2_accuracy_vs_loss".into())),
            ("eval_images", Value::Num(n_eval as f64)),
            ("curves", Value::Arr(json_curves)),
        ]),
    )?;
    Ok(curves)
}
