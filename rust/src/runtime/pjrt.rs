//! PJRT compute backend: load AOT HLO-text artifacts, compile once,
//! execute many. Only compiled under `--features pjrt` (requires the
//! vendored `xla` crate).
//!
//! Adapts the /opt/xla-example/load_hlo pattern: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format (xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized protos with 64-bit instruction ids).
//!
//! `PjRtClient` is `Rc`-backed (not `Send`), so all PJRT state lives on one
//! thread; the [`super::server`] submodule exposes a channel-based compute
//! server that the multi-threaded fleet simulator calls into.

use std::cell::RefCell;
use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::runtime::manifest::{ArtifactMeta, Manifest};
use crate::tensor::Tensor;

/// A compiled-executable cache over the artifact set.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    /// Cumulative PJRT execute invocations (perf accounting).
    execs: std::cell::Cell<u64>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn new() -> Result<PjrtRuntime> {
        Ok(PjrtRuntime {
            client: xla::PjRtClient::cpu()?,
            cache: RefCell::new(HashMap::new()),
            execs: std::cell::Cell::new(0),
        })
    }

    /// Number of PJRT devices (CPU: 1).
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Total execute() calls issued so far.
    pub fn exec_count(&self) -> u64 {
        self.execs.get()
    }

    /// Load + compile an HLO-text file, memoised under `key`.
    pub fn load_hlo_file(
        &self,
        key: &str,
        path: &std::path::Path,
    ) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(key) {
            return Ok(exe.clone());
        }
        let path_str = path.to_string_lossy().to_string();
        let proto = xla::HloModuleProto::from_text_file(&path_str)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(self.client.compile(&comp)?);
        self.cache.borrow_mut().insert(key.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile an artifact by name (warm-up path).
    pub fn preload(&self, manifest: &Manifest, name: &str) -> Result<()> {
        let meta = manifest.artifact(name)?;
        self.load_hlo_file(name, &manifest.path(&meta.file))?;
        Ok(())
    }

    /// Execute an artifact on (facade-validated) tensor inputs.
    pub fn execute(
        &self,
        manifest: &Manifest,
        meta: &ArtifactMeta,
        inputs: &[&Tensor],
    ) -> Result<Tensor> {
        let exe = self.load_hlo_file(&meta.name, &manifest.path(&meta.file))?;
        self.run(&exe, inputs)
    }

    fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[&Tensor],
    ) -> Result<Tensor> {
        // Use execute_b over buffers we own: the crate's literal-taking
        // `execute` shim leaks the input device buffers it creates
        // (xla_rs.cc releases them into Execute and never frees them —
        // ≈ 32 MiB/request for an fc6 shard; see EXPERIMENTS.md §Perf).
        // Buffers created here are PjRtBuffer wrappers with a real Drop.
        let buffers: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| {
                self.client
                    .buffer_from_host_buffer::<f32>(t.data(), t.shape(), None)
                    .map_err(Error::from)
            })
            .collect::<Result<Vec<_>>>()?;
        let result = exe.execute_b::<xla::PjRtBuffer>(&buffers)?;
        self.execs.set(self.execs.get() + 1);
        let lit = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = lit.to_tuple1()?;
        from_literal(&out)
    }

    /// Build a plain GEMM `w@x [+b] [relu]` via XlaBuilder.
    pub fn build_gemm(
        &self,
        m: usize,
        k: usize,
        n: usize,
        bias: bool,
        relu: bool,
    ) -> Result<xla::PjRtLoadedExecutable> {
        let b = xla::XlaBuilder::new("gemm_fallback");
        let wp = b.parameter_s(0, &xla::Shape::array::<f32>(vec![m as i64, k as i64]), "w")?;
        let xp = b.parameter_s(1, &xla::Shape::array::<f32>(vec![k as i64, n as i64]), "x")?;
        let mut out = wp.dot(&xp)?;
        if bias {
            let bp =
                b.parameter_s(2, &xla::Shape::array::<f32>(vec![m as i64, 1i64]), "b")?;
            // Broadcast (m,1) across columns.
            let bb = if n == 1 {
                bp
            } else {
                bp.broadcast_in_dim(&[m as i64, n as i64], &[0, 1])?
            };
            out = out.add_(&bb)?;
        }
        if relu {
            let zero = b.c0(0f32)?.broadcast_in_dim(&[m as i64, n as i64], &[])?;
            out = out.max(&zero)?;
        }
        let comp = out.build()?;
        Ok(self.client.compile(&comp)?)
    }

    /// Execute a built (non-artifact) executable on tensors.
    pub fn run_built(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[&Tensor],
    ) -> Result<Tensor> {
        let buffers: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| {
                self.client
                    .buffer_from_host_buffer::<f32>(t.data(), t.shape(), None)
                    .map_err(Error::from)
            })
            .collect::<Result<Vec<_>>>()?;
        let result = exe.execute_b::<xla::PjRtBuffer>(&buffers)?;
        self.execs.set(self.execs.get() + 1);
        let lit = result[0][0].to_literal_sync()?;
        from_literal(&lit)
    }
}

/// Tensor → XLA literal (f32, row-major).
pub fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(t.data()).reshape(&dims)?)
}

/// XLA literal → Tensor (must be f32 array).
pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Tensor::new(dims, data)
}
