//! Case Study I (Figs. 11-12) — AlexNet on a five-device system *without*
//! robustness: device C fails, the system pays tens of seconds of failure
//! detection, then device D executes both fc6 shards serially — a ~2.4×
//! steady-state slowdown of the affected layer path. CDC (Case Study II)
//! eliminates both effects.
//!
//! Since the serving engine landed (`coordinator::serve`), the "pipelined
//! steady-state" framing is *measured*, not proxied: each phase also runs
//! a closed-loop pipelined workload and reports requests/second, which
//! must agree with the analytic `RequestTrace::bottleneck_ms` prediction
//! (rps ≈ 1000 / mean bottleneck stage ms) — the proxy is kept as a
//! cross-check.
//!
//! Deployment (paper Fig. 11a):
//!   A: conv1-conv2   B: conv3-conv5   C: fc6/0   D: fc6/1   E: fc7, fc8

use crate::coordinator::{Session, SessionConfig, SplitSpec, Workload};
use crate::error::Result;
use crate::fleet::FailurePlan;
use crate::json::{obj, Value};
use crate::metrics::Series;
use crate::rng::Pcg32;
use crate::tensor::Tensor;

use super::ExpCtx;

/// The paper's five-device AlexNet allocation file.
pub fn alexnet_5dev(ctx: &ExpCtx) -> SessionConfig {
    let mut cfg = SessionConfig::new("alexnet");
    cfg.n_devices = 5;
    cfg.seed = ctx.seed;
    // The case-study testbed is the paper's local WLAN (measured 0.3 ms
    // RTT), not Fig. 1's congested profile.
    cfg.net = crate::fleet::NetConfig::moderate();
    cfg.splits.insert("fc6".into(), SplitSpec::plain(2));
    for (layer, dev) in [
        ("conv1", 0usize),
        ("conv2", 0),
        ("conv3", 1),
        ("conv4", 1),
        ("conv5", 1),
        ("fc7", 4),
        ("fc8", 4),
    ] {
        cfg.placement.insert(layer.into(), vec![dev]);
    }
    cfg.placement.insert("fc6".into(), vec![2, 3]);
    cfg
}

/// Random AlexNet-shaped input.
pub fn alexnet_input(rng: &mut Pcg32) -> Tensor {
    Tensor::randn(vec![32, 32, 3], rng)
}

/// One phase's pipelined-serving measurement.
#[derive(Debug, Clone, Copy)]
pub struct PipelinePoint {
    /// Measured steady-state throughput (requests/s of virtual time).
    pub measured_rps: f64,
    /// Analytic prediction from the bottleneck proxy: 1000 / mean
    /// per-request `bottleneck_ms`.
    pub predicted_rps: f64,
    /// Peak requests concurrently in flight.
    pub max_in_flight: usize,
    /// Utilization of the busiest stage.
    pub bottleneck_util: f64,
}

impl PipelinePoint {
    /// |measured − predicted| / predicted.
    pub fn relative_error(&self) -> f64 {
        (self.measured_rps - self.predicted_rps).abs() / self.predicted_rps
    }
}

/// Results of the case study.
#[derive(Debug)]
pub struct Case1 {
    pub before: Series,
    pub after: Series,
    pub detection_ms: f64,
    pub slowdown: f64,
    pub pipeline_before: PipelinePoint,
    pub pipeline_after: PipelinePoint,
}

/// Measure pipelined steady-state throughput: a closed-loop workload with
/// one request per distributed stage keeps the bottleneck stage saturated.
fn pipelined(
    session: &mut Session,
    rng: &mut Pcg32,
    n: usize,
    bottleneck: &Series,
) -> Result<PipelinePoint> {
    let inputs: Vec<Tensor> = (0..n).map(|_| alexnet_input(rng)).collect();
    let concurrency = session.saturating_concurrency();
    let report = session.serve(&Workload::closed(inputs, concurrency))?;
    let bottleneck_util = report
        .stages
        .iter()
        .map(|s| s.utilization)
        .fold(0.0, f64::max);
    Ok(PipelinePoint {
        measured_rps: report.rps(),
        predicted_rps: 1000.0 / bottleneck.summary().mean,
        max_in_flight: report.max_concurrent_requests,
        bottleneck_util,
    })
}

/// Run the experiment; returns the two latency series.
pub fn run(ctx: &ExpCtx) -> Result<Case1> {
    let cfg = alexnet_5dev(ctx);
    let detection_ms = cfg.detection_ms;
    let mut session = Session::start(&ctx.artifacts, cfg)?;
    let mut rng = Pcg32::seeded(ctx.seed ^ 0xca5e1);
    let n = ctx.n_requests();

    // Phase A: healthy system (black bars of Fig. 12).
    let mut before = Series::new();
    let mut before_stage = Series::new();
    let mut before_bottleneck = Series::new();
    for _ in 0..n {
        let t = session.infer(&alexnet_input(&mut rng))?;
        before.record(t.total_ms);
        before_stage.record(stage_ms(&t, "fc6"));
        before_bottleneck.record(t.bottleneck_ms());
    }
    // Phase A': pipelined steady state of the healthy system.
    let pipeline_before = pipelined(&mut session, &mut rng, n, &before_bottleneck)?;

    // Device C (id 2, fc6 shard 0) dies. Without CDC the system mishandles
    // requests until detection fires, then fails over to device D.
    session.set_failure(2, FailurePlan::PermanentAt(0))?;
    let mut lost = 0u64;
    if session.infer(&alexnet_input(&mut rng)).is_err() {
        lost += 1;
    }
    session.drain();
    session.failover(2, 3)?;

    // Phase B: post-recovery steady state (red bars of Fig. 12): device D
    // now executes both fc6 shards serially.
    let mut after = Series::new();
    let mut after_stage = Series::new();
    let mut after_bottleneck = Series::new();
    for _ in 0..n {
        let t = session.infer(&alexnet_input(&mut rng))?;
        after.record(t.total_ms);
        after_stage.record(stage_ms(&t, "fc6"));
        after_bottleneck.record(t.bottleneck_ms());
    }
    // Phase B': pipelined steady state after failover.
    let pipeline_after = pipelined(&mut session, &mut rng, n, &after_bottleneck)?;

    let sb = before.summary();
    let sa = after.summary();
    // The paper's 2.4× is the slowdown of the *affected path*: device D
    // absorbs device C's fc6 shard and runs both serially, so the fc6
    // stage — the deployment's heaviest — roughly doubles (2× compute +
    // the second shard's transfer), throttling the pipeline's steady
    // state.
    let slowdown = after_stage.summary().mean / before_stage.summary().mean;
    let rps_drop = pipeline_before.measured_rps / pipeline_after.measured_rps;
    println!("\n=== Case Study I: AlexNet, 5 devices, no robustness (Figs. 11-12) ===");
    println!("before failure: {}", sb.line());
    println!("{}", before.render_histogram(0.0, 800.0, 16, 40));
    println!("after failover: {}", sa.line());
    println!("{}", after.render_histogram(0.0, 800.0, 16, 40));
    println!(
        "requests mishandled during detection window: ≥{lost} \
         (detection takes ~{:.0} s)",
        detection_ms / 1000.0
    );
    println!(
        "end-to-end latency shift: {:.2}×",
        sa.mean / sb.mean
    );
    println!(
        "affected-stage (fc6) slowdown after recovery: {slowdown:.2}× (paper: ~2.4×)"
    );
    println!(
        "pipelined serving, healthy:  {:.2} rps measured vs {:.2} rps predicted \
         (Δ {:.1}%, {} in flight, bottleneck util {:.0}%)",
        pipeline_before.measured_rps,
        pipeline_before.predicted_rps,
        100.0 * pipeline_before.relative_error(),
        pipeline_before.max_in_flight,
        100.0 * pipeline_before.bottleneck_util,
    );
    println!(
        "pipelined serving, failover: {:.2} rps measured vs {:.2} rps predicted \
         (Δ {:.1}%, {} in flight, bottleneck util {:.0}%)",
        pipeline_after.measured_rps,
        pipeline_after.predicted_rps,
        100.0 * pipeline_after.relative_error(),
        pipeline_after.max_in_flight,
        100.0 * pipeline_after.bottleneck_util,
    );
    println!(
        "pipelined throughput drop after failover: {rps_drop:.2}× \
         (stage-proxy prediction: {slowdown:.2}×)"
    );

    ctx.write_result(
        "fig12_case1",
        &obj(vec![
            ("experiment", Value::Str("case1_failure_no_cdc".into())),
            ("requests_per_phase", Value::Num(n as f64)),
            ("before_mean_ms", Value::Num(sb.mean)),
            ("before_p95_ms", Value::Num(sb.p95)),
            ("after_mean_ms", Value::Num(sa.mean)),
            ("after_p95_ms", Value::Num(sa.p95)),
            ("latency_shift", Value::Num(sa.mean / sb.mean)),
            ("bottleneck_before_ms", Value::Num(before_stage.summary().mean)),
            ("bottleneck_after_ms", Value::Num(after_stage.summary().mean)),
            ("slowdown", Value::Num(slowdown)),
            ("paper_slowdown", Value::Num(2.4)),
            ("detection_ms", Value::Num(detection_ms)),
            ("lost_requests_detected", Value::Num(lost as f64)),
            ("pipelined_rps_healthy", Value::Num(pipeline_before.measured_rps)),
            ("predicted_rps_healthy", Value::Num(pipeline_before.predicted_rps)),
            (
                "pipelined_vs_predicted_healthy_err",
                Value::Num(pipeline_before.relative_error()),
            ),
            ("pipelined_rps_failover", Value::Num(pipeline_after.measured_rps)),
            ("predicted_rps_failover", Value::Num(pipeline_after.predicted_rps)),
            (
                "pipelined_vs_predicted_failover_err",
                Value::Num(pipeline_after.relative_error()),
            ),
            ("pipelined_throughput_drop", Value::Num(rps_drop)),
        ]),
    )?;
    Ok(Case1 {
        before,
        after,
        detection_ms,
        slowdown,
        pipeline_before,
        pipeline_after,
    })
}

/// Service time of one named layer within a trace (0 if absent).
fn stage_ms(trace: &crate::coordinator::RequestTrace, layer: &str) -> f64 {
    trace
        .layers
        .iter()
        .find(|l| l.layer == layer)
        .map(|l| l.t_done_ms - l.t_start_ms)
        .unwrap_or(0.0)
}
