//! Fig. 1 — arrival-time histogram of data packets in a four-device WiFi
//! IoT system computing a 2048-wide fully-connected layer.
//!
//! Paper anchors: single-device compute = 50 ms (so no packet arrives
//! earlier), ~34% of arrivals within 100 ms, ~42% within 150 ms, a long
//! heavy tail. We deploy the `fc2048` micro-model output-split over four
//! devices whose rate is scaled so one shard costs the paper's 50 ms, and
//! histogram the *per-shard* arrival times.

use crate::coordinator::{Session, SessionConfig, SplitSpec};
use crate::error::Result;
use crate::json::{arr_f64, obj, Value};
use crate::metrics::Series;
use crate::rng::Pcg32;
use crate::tensor::Tensor;

use super::ExpCtx;

/// Run the experiment; returns the arrival series for tests.
pub fn run(ctx: &ExpCtx) -> Result<Series> {
    let mut cfg = SessionConfig::new("fc2048");
    cfg.n_devices = 4;
    cfg.splits.insert("fc".into(), SplitSpec::plain(4));
    // Scale the device rate so one *shard* (2048/4 × 2048 MACs) takes the
    // paper's 50 ms — matching "no packet arrives earlier than 50 ms".
    cfg.device_rate = (512.0 * 2048.0) / 50.0;
    cfg.seed = ctx.seed;
    let mut session = Session::start(&ctx.artifacts, cfg)?;

    let mut rng = Pcg32::seeded(ctx.seed ^ 0xf161);
    let mut arrivals = Series::new();
    let n = ctx.n_requests();
    for _ in 0..n {
        let x = Tensor::randn(vec![2048], &mut rng);
        let trace = session.infer(&x)?;
        for l in &trace.layers {
            for &a in &l.data_arrivals_ms {
                // Arrival relative to the layer dispatch.
                arrivals.record(a - l.t_start_ms);
            }
        }
    }

    let s = arrivals.summary();
    println!("\n=== Fig. 1: arrival-time histogram (fc-2048, 4 devices) ===");
    println!("packets: {}", s.count);
    println!("{}", arrivals.render_histogram(0.0, 500.0, 20, 40));
    println!("summary: {}", s.line());
    let c100 = arrivals.cdf_at(100.0);
    let c150 = arrivals.cdf_at(150.0);
    println!("CDF(100 ms) = {:.1}%  (paper ≈ 34%)", 100.0 * c100);
    println!("CDF(150 ms) = {:.1}%  (paper ≈ 42%)", 100.0 * c150);
    println!("min arrival = {:.1} ms (paper: ≥ 50 ms compute floor)", s.min);

    ctx.write_result(
        "fig1",
        &obj(vec![
            ("experiment", Value::Str("fig1_arrival_histogram".into())),
            ("packets", Value::Num(s.count as f64)),
            ("cdf_100ms", Value::Num(c100)),
            ("cdf_150ms", Value::Num(c150)),
            ("paper_cdf_100ms", Value::Num(0.34)),
            ("paper_cdf_150ms", Value::Num(0.42)),
            ("min_ms", Value::Num(s.min)),
            ("p50_ms", Value::Num(s.p50)),
            ("p99_ms", Value::Num(s.p99)),
            (
                "histogram_0_500ms_20bins",
                Value::Arr(
                    arrivals
                        .histogram(0.0, 500.0, 20)
                        .iter()
                        .map(|&c| Value::Num(c as f64))
                        .collect(),
                ),
            ),
            ("samples_ms", arr_f64(arrivals.samples())),
        ]),
    )?;
    Ok(arrivals)
}
