//! The L3 coordinator: deploys a model across the fleet per an assignment
//! plan, drives inference requests through it, merges shard outputs, and
//! applies the paper's robustness machinery (CDC parity, straggler
//! substitution, 2MR, failover).
//!
//! The coordinator is layered (DESIGN.md §4-5):
//!
//! * [`policy`] — pure gather-resolution semantics (when/how a layer
//!   completes), property-tested in isolation;
//! * [`stage`] — the per-layer execution unit: dispatch → policy →
//!   CDC/2MR recovery → merge, free of any notion of "current request";
//! * [`serve`] — the pipelined multi-request engine that schedules many
//!   requests across stages in virtual time;
//! * [`Session`] — deployment + the thin single-request `infer` wrapper
//!   over the serving engine.

pub mod policy;
pub mod serve;
pub mod stage;

use std::collections::BTreeMap;
use std::sync::mpsc::channel;
use std::sync::Arc;

use crate::cdc;
use crate::error::{Error, Result};
use crate::fleet::{Device, DeviceConfig, NetConfig, TaskDef};
use crate::kernels::Scratch;
use crate::model::{shard_io_bytes, shard_macs, Weights};
use crate::partition::LayerPlan;
use crate::runtime::manifest::{Manifest, ModelManifest};
use crate::runtime::server::{ComputeHandle, ComputeServer};
use crate::tensor::Tensor;
use crate::transport::{
    MembershipEvent, SimTransport, TcpTransport, Transport, TransportSpec,
};
pub use policy::{AdaptiveConfig, AdaptivePolicy, Outcome, PolicyReport};
pub use serve::{Arrivals, Pipeline, ServeReport, StageStats, Workload};
pub use stage::Stage;
use stage::{DistStage, StageKind};

/// Redundancy mode of one distributed layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Redundancy {
    /// No redundancy: a failed shard loses the request (until failover).
    None,
    /// One CDC parity device covering all d data shards (paper §5).
    Cdc,
    /// Fig. 18: parity groups of the given size (1 failure per group).
    CdcGrouped(usize),
    /// Double modular redundancy: every shard duplicated.
    TwoMr,
}

/// Per-layer split request.
#[derive(Debug, Clone, Copy)]
pub struct SplitSpec {
    pub d: usize,
    pub redundancy: Redundancy,
}

impl SplitSpec {
    /// A plain d-way split.
    pub fn plain(d: usize) -> SplitSpec {
        SplitSpec { d, redundancy: Redundancy::None }
    }

    /// A d-way split protected by one CDC parity device.
    pub fn cdc(d: usize) -> SplitSpec {
        SplitSpec { d, redundancy: Redundancy::Cdc }
    }
}

/// Session construction parameters.
#[derive(Debug, Clone)]
pub struct SessionConfig {
    pub model: String,
    /// Weighted-layer name → split spec; layers not listed run whole
    /// (d = 1) on a single device.
    pub splits: BTreeMap<String, SplitSpec>,
    /// Number of data devices in the fleet (parity/replica devices are
    /// allocated on top, like the paper's "extra device").
    pub n_devices: usize,
    /// Straggler gate: substitution not initiated before
    /// `threshold_factor ×` the layer's expected service time. ∞ disables
    /// mitigation (pure fault tolerance).
    pub threshold_factor: f64,
    pub net: NetConfig,
    /// Device compute rate (MACs/ms); default RPi.
    pub device_rate: f64,
    pub seed: u64,
    /// Failure-detection time for the non-CDC recovery path (paper: "takes
    /// tens of seconds").
    pub detection_ms: f64,
    /// Explicit layer placement (the paper's per-device allocation file,
    /// Fig. 11/13): layer name → data-shard devices (length must equal the
    /// layer's split degree). Unplaced layers are assigned round-robin.
    pub placement: BTreeMap<String, Vec<usize>>,
    /// Adaptive CDC policy (DESIGN.md §9): when set, the straggler gate is
    /// tuned online from observed per-device completion latencies and the
    /// parity-vs-replication trade-off is surfaced in `ServeReport::
    /// policy`; `threshold_factor` above only seeds the initial gate.
    pub adaptive: Option<policy::AdaptiveConfig>,
    /// Cross-request micro-batching (DESIGN.md §10): up to this many
    /// requests waiting on the same fc stage coalesce into one batched
    /// order whose input is the column concatenation of the member
    /// activations — one wider GEMM, one parity pass, one network round
    /// per batch. `1` (the default) disables coalescing and is bit-exact
    /// with unbatched serving.
    pub batch_max: usize,
    /// How long (virtual ms) a free stage may hold its head request to
    /// let a batch fill before dispatching (bounds the latency cost of
    /// batching). `0.0` (the default) is pure pass-through: only
    /// requests already waiting when the stage frees coalesce, and a
    /// lone request is never delayed.
    pub batch_wait_ms: f64,
    /// How the session reaches its devices (DESIGN.md §11): the
    /// in-process virtual-time simulator (default, bit-identical to the
    /// pre-transport engine) or real TCP worker processes with
    /// wall-clock timing.
    pub transport: TransportSpec,
    /// Numeric precision of fc shard tasks (DESIGN.md §15): `F32`
    /// (default, bit-exact with the reference math) or `Int8`
    /// (per-row-block symmetric quantization with an i32 accumulator
    /// and a computable error bound; CDC parity is encoded in the
    /// quantized domain). conv shards always stay f32.
    pub precision: crate::kernels::Precision,
}

impl SessionConfig {
    /// Reasonable defaults around a model name.
    pub fn new(model: &str) -> SessionConfig {
        SessionConfig {
            model: model.to_string(),
            splits: BTreeMap::new(),
            n_devices: 1,
            threshold_factor: f64::INFINITY,
            net: NetConfig::default(),
            device_rate: crate::fleet::RPI_MACS_PER_MS,
            seed: 2021,
            detection_ms: 20_000.0,
            placement: BTreeMap::new(),
            adaptive: None,
            batch_max: 1,
            batch_wait_ms: 0.0,
            transport: TransportSpec::Sim,
            precision: crate::kernels::Precision::F32,
        }
    }

    /// Upper bound on the devices this config will deploy (data devices
    /// plus the redundancy devices its splits imply), assuming every
    /// split entry names a layer of the model — the loopback harness
    /// sizes its worker fleet with this before the session exists.
    pub fn planned_devices(&self) -> usize {
        let extra: usize = self
            .splits
            .values()
            .map(|s| match s.redundancy {
                Redundancy::None => 0,
                Redundancy::Cdc => 1,
                Redundancy::CdcGrouped(g) => s.d.div_ceil(g.max(1)),
                Redundancy::TwoMr => s.d,
            })
            .sum();
        self.n_devices + extra
    }
}

/// Per-layer trace of one request.
#[derive(Debug, Clone)]
pub struct LayerTrace {
    pub layer: String,
    pub t_start_ms: f64,
    pub t_done_ms: f64,
    pub outcome: &'static str,
    pub recovered_shard: Option<usize>,
    /// Simulated arrival time of each data shard (∞ = lost).
    pub data_arrivals_ms: Vec<f64>,
    /// Simulated arrival time of each parity/replica shard.
    pub aux_arrivals_ms: Vec<f64>,
}

/// Full trace of one request.
#[derive(Debug, Clone)]
pub struct RequestTrace {
    pub req: u64,
    pub output: Tensor,
    /// End-to-end latency: arrival → completion (equals completion time
    /// for a single-shot `infer`, whose request arrives at t=0).
    pub total_ms: f64,
    /// Virtual arrival instant on the serving timeline.
    pub t_arrival_ms: f64,
    /// Virtual completion instant on the serving timeline.
    pub t_done_ms: f64,
    pub layers: Vec<LayerTrace>,
    /// True if any layer used CDC substitution.
    pub any_recovery: bool,
}

impl RequestTrace {
    /// Service time of the slowest distributed stage. Under pipelined
    /// steady-state serving the request *rate* is bottleneck-limited, so
    /// the paper's Case-Study-I "2.4x slowdown" manifests as this
    /// stage time doubling when a failed device's shard is re-assigned
    /// serially onto its neighbour. `coordinator::serve` measures the
    /// pipelined rate directly; this remains the analytic cross-check
    /// (`exp::case1` asserts the two agree).
    pub fn bottleneck_ms(&self) -> f64 {
        self.layers
            .iter()
            .map(|l| l.t_done_ms - l.t_start_ms)
            .fold(0.0, f64::max)
    }
}

/// One planned task assignment awaiting deployment.
struct Pending {
    task: u64,
    device: usize,
    def: TaskDef,
}

/// Output of [`build_stages`]: the per-layer pipeline plus the task
/// deployments and the artifact preload set it implies.
struct BuiltStages {
    stages: Vec<Stage>,
    pending: Vec<Pending>,
    preload: Vec<String>,
    /// Redundancy slots consumed from the extra pool.
    extra_used: usize,
}

/// Claim the next redundancy slot from the extra pool.
fn next_extra_slot(extra_pool: &[usize], extra: &mut usize, layer: &str) -> Result<usize> {
    let slot = extra_pool.get(*extra).copied().ok_or_else(|| {
        Error::Config(format!(
            "fleet too small for {layer}'s redundancy ({} extra slots)",
            extra_pool.len()
        ))
    })?;
    *extra += 1;
    Ok(slot)
}

/// Build the per-layer execution plan over concrete device slots.
///
/// `data_pool` lists the slots data shards round-robin over and
/// `extra_pool` the slots parity/replica tasks consume in order. The
/// initial deployment passes contiguous `0..n_devices` pools; a live
/// repartition (DESIGN.md §13) passes whatever slots survived the churn
/// — slot numbers are stable for a TCP fleet member's lifetime and
/// never reused. `splits` is the effective (already clamped) split map
/// and `next_task` the persistent task-id counter: ids from before a
/// repartition are never reissued, so a stale completion can never
/// collide with a live task. Explicit placement only applies on the
/// initial build (`use_placement`) — placements name original slots
/// that churn may have retired.
#[allow(clippy::too_many_arguments)]
fn build_stages(
    cfg: &SessionConfig,
    model: &ModelManifest,
    weights: &Weights,
    splits: &BTreeMap<String, SplitSpec>,
    data_pool: &[usize],
    extra_pool: &[usize],
    use_placement: bool,
    next_task: &mut u64,
) -> Result<BuiltStages> {
    let mut stages = Vec::new();
    let mut next_data_dev = 0usize;
    let mut extra = 0usize;
    let mut pending: Vec<Pending> = Vec::new();
    let mut preload: Vec<String> = Vec::new();

    for (layer_idx, layer) in model.layers.iter().enumerate() {
        if !layer.is_weighted() {
            stages.push(Stage { kind: StageKind::Local { layer_idx } });
            continue;
        }
        let spec = splits
            .get(&layer.name)
            .copied()
            .unwrap_or(SplitSpec::plain(1));
        if spec.d > data_pool.len() {
            return Err(Error::Config(format!(
                "layer {} wants d={} > {} devices",
                layer.name,
                spec.d,
                data_pool.len()
            )));
        }
        let plan = LayerPlan::build(layer, spec.d)?;
        // CDC needs the pre-activation (lin) artifact; otherwise use
        // the fused flavor when present.
        let use_cdc = matches!(
            spec.redundancy,
            Redundancy::Cdc | Redundancy::CdcGrouped(_)
        );
        let (artifact, fused_relu) = if use_cdc || plan.artifact_relu.is_none() {
            (plan.artifact_lin.clone(), false)
        } else {
            (plan.artifact_relu.clone().unwrap(), true)
        };
        preload.push(artifact.clone());

        let macs = shard_macs(layer, spec.d);
        let (req_bytes, reply_bytes) = shard_io_bytes(layer, spec.d);
        // Deploy-time kernel prep (DESIGN.md §15) is per-task: int8
        // quantization only ever applies to fc shards.
        let is_fc = layer.kind == "fc";
        let placed = match cfg.placement.get(&layer.name).filter(|_| use_placement) {
            Some(devs) => {
                if devs.len() != spec.d {
                    return Err(Error::Config(format!(
                        "placement for {} has {} devices, split is {}",
                        layer.name,
                        devs.len(),
                        spec.d
                    )));
                }
                if let Some(bad) = devs.iter().find(|&&d| d >= cfg.n_devices) {
                    return Err(Error::Config(format!(
                        "placement for {} uses device {bad} >= n_devices {}",
                        layer.name, cfg.n_devices
                    )));
                }
                Some(devs.clone())
            }
            None => None,
        };
        let mut shard_wb: Vec<(Arc<Tensor>, Arc<Tensor>)> = Vec::new();
        let mut data = Vec::new();
        for s in &plan.shards {
            let (w, b) = plan.shard_weights(weights, s)?;
            let (w, b) = (Arc::new(w), Arc::new(b));
            let task = *next_task;
            *next_task += 1;
            let device = match &placed {
                Some(devs) => devs[s.index],
                None => {
                    let d = data_pool[next_data_dev % data_pool.len()];
                    next_data_dev += 1;
                    d
                }
            };
            pending.push(Pending {
                task,
                device,
                def: TaskDef::new(task, artifact.clone(), w.clone(), b.clone(), macs, reply_bytes)
                    .prepare(cfg.precision, is_fc),
            });
            shard_wb.push((w, b));
            data.push((device, task));
        }

        let mut parities = Vec::new();
        let mut replicas = Vec::new();
        match spec.redundancy {
            Redundancy::None => {}
            Redundancy::Cdc | Redundancy::CdcGrouped(_) => {
                let group_size = match spec.redundancy {
                    Redundancy::CdcGrouped(g) => g,
                    _ => spec.d,
                };
                let groups = cdc::parity_groups(spec.d, group_size)?;
                for cover in groups {
                    let members: Vec<(Tensor, Tensor)> = cover
                        .iter()
                        .map(|&i| {
                            let (w, b) = &shard_wb[i];
                            (w.as_ref().clone(), b.as_ref().clone())
                        })
                        .collect();
                    let (pw, pb) = cdc::parity_weights(&members)?;
                    let (pw, pb) = (Arc::new(pw), Arc::new(pb));
                    let task = *next_task;
                    *next_task += 1;
                    let device = next_extra_slot(extra_pool, &mut extra, &layer.name)?;
                    pending.push(Pending {
                        task,
                        device,
                        def: TaskDef::new(task, artifact.clone(), pw, pb, macs, reply_bytes)
                            .prepare(cfg.precision, is_fc),
                    });
                    parities.push((device, task, cover));
                }
            }
            Redundancy::TwoMr => {
                for (w, b) in shard_wb.iter() {
                    let task = *next_task;
                    *next_task += 1;
                    let device = next_extra_slot(extra_pool, &mut extra, &layer.name)?;
                    pending.push(Pending {
                        task,
                        device,
                        def: TaskDef::new(
                            task,
                            artifact.clone(),
                            w.clone(),
                            b.clone(),
                            macs,
                            reply_bytes,
                        )
                        .prepare(cfg.precision, is_fc),
                    });
                    replicas.push((device, task));
                }
            }
        }

        // Fixed per-order cost (network base latency, both legs) vs
        // the payload-proportional part (compute + bytes on the
        // wire): batching pays the former once per batch and the
        // latter once per member.
        let wire_ms =
            ((req_bytes + reply_bytes) as f64 * 8.0) / (cfg.net.bandwidth_mbps * 1000.0);
        let per_member_ms = macs as f64 / cfg.device_rate + wire_ms;
        let expected_ms = per_member_ms + 2.0 * cfg.net.base_ms;
        stages.push(Stage {
            kind: StageKind::Dist(DistStage {
                layer_idx,
                plan,
                data,
                parities,
                replicas,
                fused_relu,
                expected_ms,
                expected_extra_ms: per_member_ms,
                request_bytes: req_bytes,
                macs,
                batchable: layer.kind == "fc",
            }),
        });
    }

    Ok(BuiltStages { stages, pending, preload, extra_used: extra })
}

/// A deployed model serving session over a fleet — simulated device
/// threads or real TCP workers, per `SessionConfig::transport`.
pub struct Session {
    cfg: SessionConfig,
    model: ModelManifest,
    /// Retained model weights: a live repartition (DESIGN.md §13)
    /// re-shards them for the surviving device set.
    weights: Weights,
    /// Compute handle, kept so a repartition can re-validate/preload the
    /// artifact set its re-clamped split degrees select.
    compute: ComputeHandle,
    /// How orders reach devices and completions come back (DESIGN.md
    /// §11) — the virtual-time simulator or the TCP worker fleet.
    transport: Box<dyn Transport>,
    /// Per-layer pipeline stages, in model order.
    stages: Vec<Stage>,
    /// Task definitions kept for failover re-deployment.
    task_defs: BTreeMap<u64, TaskDef>,
    /// task id → owning device (mutated by failover).
    task_owner: BTreeMap<u64, usize>,
    /// Device slots currently in the serving set. Slots are stable for
    /// the lifetime of a fleet member and never reused: a dead or
    /// drained device's slot stays retired, a joiner gets a fresh one.
    active: Vec<usize>,
    /// Monotone live-membership partition epoch: bumped by every
    /// repartition so work orders (and their late replies) from an old
    /// partition are identifiable (DESIGN.md §13).
    partition_epoch: u64,
    /// Persistent task-id counter — ids from before a repartition are
    /// never reissued, so stale completions can't collide with live
    /// tasks.
    next_task: u64,
    next_req: u64,
    /// Devices currently considered failed by the *coordinator*.
    known_failed: Vec<usize>,
    /// Per-device effective compute rate (MACs/ms) — the dispatch-side
    /// mirror of the fleet's rates, kept in sync by `set_device_rate` so
    /// the occupancy ledger stays honest under heterogeneous fleets.
    rates: Vec<f64>,
    /// Adaptive CDC policy state (present when `cfg.adaptive` is set).
    adaptive: Option<policy::AdaptivePolicy>,
    /// Extra devices allocated beyond cfg.n_devices (parity/replicas).
    pub extra_devices: usize,
    /// Serve-path buffer arena: merge/pool/decode buffers are reused
    /// across requests, so steady-state resolution allocates nothing.
    scratch: Scratch,
    /// Live telemetry registry (DESIGN.md §16): counters, latency
    /// histograms, and the trace-span ring. `Arc`-shared with the
    /// gateway's HTTP threads, which render `/metrics` and `/v1/traces`
    /// from it without touching the session.
    telemetry: Arc<crate::telemetry::Telemetry>,
    _server: Option<ComputeServer>,
}

impl Session {
    /// Build a session with its own compute server over `artifacts_root`.
    pub fn start(
        artifacts_root: impl Into<std::path::PathBuf>,
        cfg: SessionConfig,
    ) -> Result<Session> {
        let root = artifacts_root.into();
        let server = ComputeServer::spawn(root.clone())?;
        let manifest = Manifest::load(&root)?;
        Session::start_with(manifest, server.handle(), Some(server), cfg)
    }

    /// Build a session over an existing compute server (lets experiments
    /// share one PJRT instance across many sessions).
    pub fn start_shared(
        manifest: &Manifest,
        compute: ComputeHandle,
        cfg: SessionConfig,
    ) -> Result<Session> {
        Session::start_with(manifest.clone_shallow()?, compute, None, cfg)
    }

    fn start_with(
        manifest: Manifest,
        compute: ComputeHandle,
        server: Option<ComputeServer>,
        cfg: SessionConfig,
    ) -> Result<Session> {
        // AOT PJRT executables are compiled at batch width 1; only the
        // (shape-polymorphic) interpreter can run the wider GEMMs that
        // micro-batching forms, so reject the combination up front
        // instead of feeding a (k, B) buffer to a (k, 1) executable.
        if cfg.batch_max > 1 && cfg!(feature = "pjrt") {
            return Err(Error::Config(format!(
                "batch_max={} needs the interpreter backend; pjrt artifacts \
                 are compiled at batch width 1 (DESIGN.md §10)",
                cfg.batch_max
            )));
        }
        let model = manifest.model(&cfg.model)?.clone();
        let weights = Weights::load(&manifest, &model)?;

        // ---- build the execution plan --------------------------------
        // Initial deployment: data shards round-robin over slots
        // 0..n_devices, redundancy tasks consume slots from n_devices up
        // (the paper's "extra device"). A live repartition later rebuilds
        // over whatever slots survived — same planner, different pools.
        let mut next_task = 0u64;
        let data_pool: Vec<usize> = (0..cfg.n_devices).collect();
        let extra_pool: Vec<usize> = (cfg.n_devices..cfg.planned_devices()).collect();
        let built = build_stages(
            &cfg,
            &model,
            &weights,
            &cfg.splits,
            &data_pool,
            &extra_pool,
            true,
            &mut next_task,
        )?;
        let BuiltStages { stages, pending, mut preload, extra_used: extra } = built;

        // ---- connect the fleet transport ------------------------------
        let n_total = cfg.n_devices + extra;
        let transport: Box<dyn Transport> = match &cfg.transport {
            TransportSpec::Sim => {
                let (ctx, crx) = channel();
                let mut devices = Vec::with_capacity(n_total);
                for id in 0..n_total {
                    let dcfg = DeviceConfig {
                        id,
                        rate_macs_per_ms: cfg.device_rate,
                        failure: Default::default(),
                    };
                    devices.push(Device::spawn(
                        dcfg,
                        cfg.net.clone(),
                        cfg.seed,
                        compute.clone(),
                        ctx.clone(),
                    )?);
                }
                Box::new(SimTransport::new(devices, crx, ctx))
            }
            TransportSpec::Tcp(tcp) => {
                Box::new(TcpTransport::connect(tcp, n_total, cfg.seed)?)
            }
        };

        // Warm the executable cache so compile time never pollutes
        // latency (in tcp mode this validates the artifact set the
        // coordinator planned against; workers hold their own runtime).
        preload.sort();
        preload.dedup();
        compute.preload(&preload)?;

        // ---- deploy tasks ----------------------------------------------
        let mut task_defs = BTreeMap::new();
        let mut task_owner = BTreeMap::new();
        let mut per_device: BTreeMap<usize, Vec<TaskDef>> = BTreeMap::new();
        for p in pending {
            task_defs.insert(p.task, p.def.clone());
            task_owner.insert(p.task, p.device);
            per_device.entry(p.device).or_default().push(p.def);
        }
        for (dev, defs) in per_device {
            transport.deploy(dev, defs)?;
        }

        let rates = vec![cfg.device_rate; n_total];
        let adaptive = cfg.adaptive.clone().map(|mut a| {
            // The static gate seeds the adaptive one until the window
            // has samples (∞ = "no static gate" keeps the default).
            if cfg.threshold_factor.is_finite() {
                a.initial_factor = cfg.threshold_factor;
            }
            policy::AdaptivePolicy::new(a, n_total)
        });
        Ok(Session {
            cfg,
            model,
            weights,
            compute,
            transport,
            stages,
            task_defs,
            task_owner,
            active: (0..n_total).collect(),
            partition_epoch: 0,
            next_task,
            next_req: 0,
            known_failed: Vec::new(),
            rates,
            adaptive,
            extra_devices: extra,
            scratch: Scratch::new(),
            telemetry: Arc::new(crate::telemetry::Telemetry::new()),
            _server: server,
        })
    }

    /// Total devices in the fleet (data + redundancy).
    pub fn total_devices(&self) -> usize {
        self.transport.n_devices()
    }

    /// Transport tag ("sim" | "tcp") — report attribution.
    pub fn transport_label(&self) -> &'static str {
        self.transport.label()
    }

    /// The session's telemetry registry, shareable with export surfaces
    /// (the gateway clones this `Arc` into its HTTP threads).
    pub fn telemetry(&self) -> Arc<crate::telemetry::Telemetry> {
        Arc::clone(&self.telemetry)
    }

    /// The model served by this session.
    pub fn model(&self) -> &ModelManifest {
        &self.model
    }

    /// The session's pipeline stages, in model order.
    pub fn stages(&self) -> &[Stage] {
        &self.stages
    }

    /// Number of distributed (occupancy-holding) stages.
    pub fn distributed_stage_count(&self) -> usize {
        self.stages.iter().filter(|s| s.is_distributed()).count()
    }

    /// Closed-loop concurrency that saturates the pipeline: one request
    /// per distributed stage (at least 2 so overlap is possible).
    pub fn saturating_concurrency(&self) -> usize {
        self.distributed_stage_count().max(2)
    }

    /// Split-plan introspection: (layer name, plan) for every distributed
    /// stage, in pipeline order — the ablation experiments and deployment
    /// tooling read these instead of re-deriving plans.
    pub fn layer_plans(&self) -> Vec<(&str, &LayerPlan)> {
        self.stages
            .iter()
            .filter_map(|s| match &s.kind {
                StageKind::Dist(d) => Some((
                    self.model.layers[d.layer_idx].name.as_str(),
                    &d.plan,
                )),
                StageKind::Local { .. } => None,
            })
            .collect()
    }

    /// Devices the coordinator has failed over away from.
    pub fn known_failed(&self) -> &[usize] {
        &self.known_failed
    }

    /// Inject a failure plan into a device (experiments flip this). In
    /// tcp mode the worker emulates the drops by staying silent on the
    /// affected replies.
    pub fn set_failure(&self, device: usize, plan: crate::fleet::FailurePlan) -> Result<()> {
        if device >= self.transport.n_devices() {
            return Err(Error::Config(format!("no device {device}")));
        }
        self.transport.set_failure(device, plan)
    }

    /// Re-rate one device's compute (MACs/ms) mid-session — heterogeneous
    /// RPi3/RPi4 mixes and the scenario engine's slowdown events. The
    /// device thread and the coordinator's occupancy-ledger mirror are
    /// updated together so dispatch-time estimates stay consistent with
    /// simulated completions.
    pub fn set_device_rate(&mut self, device: usize, macs_per_ms: f64) -> Result<()> {
        if macs_per_ms.is_nan() || macs_per_ms <= 0.0 {
            return Err(Error::Config(format!(
                "device rate must be positive, got {macs_per_ms}"
            )));
        }
        if device >= self.transport.n_devices() {
            return Err(Error::Config(format!("no device {device}")));
        }
        self.transport.set_rate(device, macs_per_ms)?;
        // The transport width can outgrow the mirror between a join
        // registering and the serve loop folding it in.
        if self.rates.len() <= device {
            self.rates.resize(device + 1, self.cfg.device_rate);
        }
        self.rates[device] = macs_per_ms;
        Ok(())
    }

    /// Per-device effective compute rates (MACs/ms), in device order.
    pub fn device_rates(&self) -> &[f64] {
        &self.rates
    }

    /// Swap the fleet-wide network profile mid-session (the scenario
    /// engine's `ideal → moderate → congested` WLAN regime events).
    /// Affects orders dispatched after the call; stage `expected_ms`
    /// estimates keep their deployment-time values — the adaptive policy
    /// exists precisely to absorb that drift.
    pub fn set_net(&mut self, net: NetConfig) -> Result<()> {
        for d in 0..self.transport.n_devices() {
            self.transport.set_net(d, net.clone())?;
        }
        self.cfg.net = net;
        Ok(())
    }

    /// The session's configuration (read-only).
    pub fn config(&self) -> &SessionConfig {
        &self.cfg
    }

    /// Latest adaptive-policy snapshot (None when adaptive mode is off).
    pub fn policy_snapshot(&self) -> Option<policy::PolicyReport> {
        self.adaptive.as_ref().map(|a| a.snapshot())
    }

    /// Device slots currently in the serving set (live membership —
    /// DESIGN.md §13). Slot numbers are stable and never reused, so the
    /// set is not contiguous after churn.
    pub fn active_devices(&self) -> &[usize] {
        &self.active
    }

    /// Current live-membership partition epoch (bumped by every
    /// repartition; 0 until the fleet churns).
    pub fn partition_epoch(&self) -> u64 {
        self.partition_epoch
    }

    /// The coordinator's membership listen address — where a fresh
    /// `cdc-dnn worker --join` dials in (None on the simulator or when
    /// `TcpConfig::listen` is disabled).
    pub fn membership_addr(&self) -> Option<String> {
        self.transport.listen_addr()
    }

    /// Fold queued membership events (worker joins, heartbeat deaths,
    /// graceful leaves, suspicion changes) into the serving plan. Called
    /// by the serve engine at pipeline-quiescent points — no stage holds
    /// work, so a repartition never strands an in-flight order — and
    /// harmless anywhere else events are empty (the simulator never
    /// emits any). Returns true when the device set changed (and the
    /// model was re-partitioned and re-deployed).
    pub(crate) fn apply_membership(&mut self) -> Result<bool> {
        let events = self.transport.poll_membership();
        if events.is_empty() {
            return Ok(false);
        }
        let mut changed = false;
        let mut drained: Vec<usize> = Vec::new();
        for ev in events {
            match ev {
                MembershipEvent::Joined { device, macs_per_ms } => {
                    // 0.0 = the worker didn't announce a rate; assume
                    // the fleet default.
                    let rate = if macs_per_ms > 0.0 {
                        macs_per_ms
                    } else {
                        self.cfg.device_rate
                    };
                    if self.rates.len() <= device {
                        self.rates.resize(device + 1, self.cfg.device_rate);
                    }
                    self.rates[device] = rate;
                    if let Some(a) = self.adaptive.as_mut() {
                        a.grow(device + 1);
                    }
                    if !self.active.contains(&device) {
                        self.active.push(device);
                        changed = true;
                    }
                    eprintln!(
                        "membership: device {device} joined ({} MACs/ms)",
                        rate
                    );
                }
                MembershipEvent::Dead { device } => {
                    let before = self.active.len();
                    self.active.retain(|&d| d != device);
                    if self.active.len() != before {
                        changed = true;
                        eprintln!(
                            "membership: device {device} dead (missed heartbeats / \
                             connection lost)"
                        );
                    }
                }
                MembershipEvent::LeaveRequested { device } => {
                    let before = self.active.len();
                    self.active.retain(|&d| d != device);
                    if self.active.len() != before {
                        changed = true;
                        eprintln!("membership: device {device} draining (graceful leave)");
                    }
                    drained.push(device);
                }
                MembershipEvent::Suspect { device, missed } => {
                    // Suspicion is drop-rate evidence for the adaptive
                    // policy's parity-vs-replication chooser, not yet a
                    // fleet change.
                    if let Some(a) = self.adaptive.as_mut() {
                        a.observe(device, 0.0, f64::INFINITY, 1.0);
                    }
                    eprintln!(
                        "membership: device {device} suspect ({missed} missed heartbeats)"
                    );
                }
                MembershipEvent::Recovered { device } => {
                    eprintln!("membership: device {device} recovered");
                }
            }
        }
        if changed {
            if self.active.is_empty() {
                return Err(Error::Fleet(
                    "membership: no devices left in the serving set".into(),
                ));
            }
            self.repartition()?;
        }
        // Retire drained connections only after the repartition stopped
        // assigning them work: the event loop closes each once its last
        // queued bytes flush (no in-flight orders remain — quiescence).
        for d in drained {
            self.transport.retire(d);
        }
        Ok(changed)
    }

    /// Re-partition the model over the current active device set and
    /// re-deploy (DESIGN.md §13): pick the largest data-device count the
    /// survivors support, re-clamp every target split degree to what the
    /// manifest offers at that width (the same rule the scenario
    /// engine's churn path uses), re-shard the retained weights, and
    /// stream fresh Deploy frames. Stage count and order are invariant —
    /// the layer sequence doesn't change — so the serve engine's
    /// per-stage state stays valid; only device assignments and task ids
    /// change, and the partition epoch is bumped.
    fn repartition(&mut self) -> Result<()> {
        let avail = self.active.len();
        // Choose the largest n_data whose implied redundancy still fits.
        let mut chosen: Option<(usize, BTreeMap<String, SplitSpec>)> = None;
        for n_data in (1..=self.cfg.n_devices.min(avail)).rev() {
            let mut splits = BTreeMap::new();
            let mut extras = 0usize;
            let mut feasible = true;
            for (name, spec) in &self.cfg.splits {
                let Some(layer) = self.model.layers.iter().find(|l| l.name == *name)
                else {
                    continue;
                };
                let cap = spec.d.min(n_data);
                let Some(d) = layer.splits.keys().copied().filter(|&d| d <= cap).max()
                else {
                    feasible = false;
                    break;
                };
                extras += match spec.redundancy {
                    Redundancy::None => 0,
                    Redundancy::Cdc => 1,
                    Redundancy::CdcGrouped(g) => d.div_ceil(g.max(1)),
                    Redundancy::TwoMr => d,
                };
                splits.insert(name.clone(), SplitSpec { d, redundancy: spec.redundancy });
            }
            if feasible && n_data + extras <= avail {
                chosen = Some((n_data, splits));
                break;
            }
        }
        let (n_data, splits) = chosen.ok_or_else(|| {
            Error::Fleet(format!(
                "membership: no feasible partition over {avail} device(s)"
            ))
        })?;

        // Undeploy the old plan from the survivors (best effort — a
        // device that died since the event queued just ignores it).
        let mut per_dev: BTreeMap<usize, Vec<u64>> = BTreeMap::new();
        for (&t, &d) in &self.task_owner {
            per_dev.entry(d).or_default().push(t);
        }
        for (d, ts) in per_dev {
            if self.active.contains(&d) {
                let _ = self.transport.undeploy(d, ts);
            }
        }

        // Rebuild over the surviving slots: first n_data carry data
        // shards, the rest carry redundancy.
        let mut active = self.active.clone();
        active.sort_unstable();
        let built = build_stages(
            &self.cfg,
            &self.model,
            &self.weights,
            &splits,
            &active[..n_data],
            &active[n_data..],
            false,
            &mut self.next_task,
        )?;
        debug_assert_eq!(built.stages.len(), self.stages.len());
        let mut preload = built.preload;
        preload.sort();
        preload.dedup();
        self.compute.preload(&preload)?;

        let mut task_defs = BTreeMap::new();
        let mut task_owner = BTreeMap::new();
        let mut per_device: BTreeMap<usize, Vec<TaskDef>> = BTreeMap::new();
        for p in built.pending {
            task_defs.insert(p.task, p.def.clone());
            task_owner.insert(p.task, p.device);
            per_device.entry(p.device).or_default().push(p.def);
        }
        for (dev, defs) in per_device {
            self.transport.deploy(dev, defs)?;
        }
        self.stages = built.stages;
        self.task_defs = task_defs;
        self.task_owner = task_owner;
        self.partition_epoch += 1;
        eprintln!(
            "membership: repartitioned over {avail} device(s) \
             ({n_data} data) — epoch {}",
            self.partition_epoch
        );
        Ok(())
    }

    /// Coordinator-side failover (the paper's non-CDC recovery): reassign
    /// every task of `failed` to `target`, which then executes them
    /// serially — Case Study I's ~2.4× steady-state slowdown. Returns the
    /// number of moved tasks. (Detection latency is accounted by the
    /// caller via `cfg.detection_ms`.)
    pub fn failover(&mut self, failed: usize, target: usize) -> Result<usize> {
        let moved: Vec<u64> = self
            .task_owner
            .iter()
            .filter(|(_, &d)| d == failed)
            .map(|(&t, _)| t)
            .collect();
        let defs: Vec<TaskDef> = moved
            .iter()
            .map(|t| self.task_defs[t].clone())
            .collect();
        self.transport.undeploy(failed, moved.clone())?;
        self.transport.deploy(target, defs)?;
        for t in &moved {
            self.task_owner.insert(*t, target);
        }
        for st in &mut self.stages {
            if let StageKind::Dist(d) = &mut st.kind {
                for (dev, t) in d.data.iter_mut() {
                    if moved.contains(t) {
                        *dev = target;
                    }
                }
                for (dev, t, _) in d.parities.iter_mut() {
                    if moved.contains(t) {
                        *dev = target;
                    }
                }
                for (dev, t) in d.replicas.iter_mut() {
                    if moved.contains(t) {
                        *dev = target;
                    }
                }
            }
        }
        self.known_failed.push(failed);
        Ok(moved.len())
    }

    /// Live migration (gateway `POST /v1/deployments/<model>/migrate`):
    /// move every task owned by `from` onto `to` with zero request
    /// drops. Unlike [`Session::failover`] — which tears down a device
    /// already presumed dead — migration is make-before-break: the
    /// target loads every task definition *first*, then stage routing
    /// flips, and only then is the source undeployed. FIFO frame order
    /// on the target connection guarantees its Deploy is processed
    /// before any Work the flipped stages send it, and the source keeps
    /// serving its in-flight orders until the flip, so no window exists
    /// in which a request can be lost. Callers run this at a
    /// pipeline-quiescent point (the serve loop's lifecycle hook does),
    /// which additionally means no order is in flight at all.
    pub fn migrate_tasks(&mut self, from: usize, to: usize) -> Result<usize> {
        if from == to {
            return Err(Error::Config(
                "migrate: source and target are the same device".into(),
            ));
        }
        for d in [from, to] {
            if !self.active.contains(&d) {
                return Err(Error::Fleet(format!(
                    "migrate: device {d} is not an active fleet member"
                )));
            }
        }
        let moved: Vec<u64> = self
            .task_owner
            .iter()
            .filter(|(_, &d)| d == from)
            .map(|(&t, _)| t)
            .collect();
        if moved.is_empty() {
            return Ok(0);
        }
        let defs: Vec<TaskDef> = moved
            .iter()
            .map(|t| self.task_defs[t].clone())
            .collect();
        // Make: the target holds every definition before any routing
        // change exists.
        self.transport.deploy(to, defs)?;
        // Flip: stage routing and ownership move atomically (no order is
        // dispatched between these loops — the caller holds the serve
        // loop).
        for t in &moved {
            self.task_owner.insert(*t, to);
        }
        for st in &mut self.stages {
            if let StageKind::Dist(d) = &mut st.kind {
                for (dev, t) in d.data.iter_mut() {
                    if moved.contains(t) {
                        *dev = to;
                    }
                }
                for (dev, t, _) in d.parities.iter_mut() {
                    if moved.contains(t) {
                        *dev = to;
                    }
                }
                for (dev, t) in d.replicas.iter_mut() {
                    if moved.contains(t) {
                        *dev = to;
                    }
                }
            }
        }
        // Break: best effort — the source staying loaded costs memory,
        // not correctness.
        let _ = self.transport.undeploy(from, moved.clone());
        Ok(moved.len())
    }

    /// Undeploy every task from its owner (gateway `DELETE
    /// /v1/deployments/<model>`). Stage structure and ownership maps are
    /// kept — a later deploy verb rebuilds via `repartition` — but the
    /// workers drop their shards now. Best effort per device, like the
    /// repartition path: a device that died since the event queued just
    /// ignores it.
    pub(crate) fn undeploy_all(&mut self) {
        let mut per_dev: BTreeMap<usize, Vec<u64>> = BTreeMap::new();
        for (&t, &d) in &self.task_owner {
            per_dev.entry(d).or_default().push(t);
        }
        for (d, ts) in per_dev {
            if self.active.contains(&d) {
                let _ = self.transport.undeploy(d, ts);
            }
        }
    }

    /// Run one single-batch inference through the distributed model —
    /// the single-request special case of [`Session::serve`].
    pub fn infer(&mut self, input: &Tensor) -> Result<RequestTrace> {
        let report = self.serve(&Workload::single(input.clone()))?;
        if let Some((req, layer)) = report.failures.first() {
            return Err(Error::Fleet(format!(
                "request {req} lost at layer {layer} (unrecoverable)"
            )));
        }
        report
            .traces
            .into_iter()
            .next()
            .ok_or_else(|| Error::Fleet("pipeline produced no trace".into()))
    }

    /// Drain stale completions (lost requests leave orphans behind).
    pub fn drain(&mut self) {
        while self.transport.try_recv().is_some() {}
    }
}
