//! Fig. 17 — full-model single-failure coverage: 2MR vs hybrid CDC+2MR.
//!
//! For each of the four paper deployments we sweep the number of
//! *additional* redundancy devices and report the fraction of original
//! devices protected. CDC+2MR dominates because one parity device covers a
//! whole model-parallel layer (constant cost) where 2MR covers one device
//! per replica (linear cost). The analytic curves are cross-checked by a
//! Monte-Carlo failure simulation over the same deployments.

use crate::cdc::coverage::{fig17_deployments, Deployment};
use crate::error::Result;
use crate::json::{obj, Value};
use crate::rng::Pcg32;

use super::{print_table, ExpCtx};

/// Monte-Carlo cross-check: sample a uniformly random single failure and
/// count how often the scheme masks it. Must agree with the analytic
/// coverage to sampling error.
pub fn simulate_coverage(
    dep: &Deployment,
    extra: usize,
    hybrid: bool,
    trials: usize,
    seed: u64,
) -> f64 {
    let mut rng = Pcg32::seeded(seed);
    let n = dep.total_devices();
    // Build the per-device protection map the scheme buys with `extra`.
    let mut protected = vec![false; n];
    let mut budget = extra;
    if hybrid {
        // Parity devices on the widest MP layers first.
        let mut layers: Vec<(usize, usize)> = Vec::new(); // (start, width)
        let mut start = 0;
        for &w in &dep.mp_layers {
            layers.push((start, w));
            start += w;
        }
        layers.sort_by(|a, b| b.1.cmp(&a.1));
        for (s, w) in layers {
            if budget == 0 {
                break;
            }
            for p in protected.iter_mut().skip(s).take(w) {
                *p = true;
            }
            budget -= 1;
        }
    }
    // Remaining budget: 2MR the first unprotected devices.
    for p in protected.iter_mut() {
        if budget == 0 {
            break;
        }
        if !*p {
            *p = true;
            budget -= 1;
        }
    }
    let mut masked = 0usize;
    for _ in 0..trials {
        let victim = rng.below(n);
        if protected[victim] {
            masked += 1;
        }
    }
    masked as f64 / trials as f64
}

/// Run the study; returns (deployment name, extra, 2mr, cdc+2mr) tuples.
pub fn run(ctx: &ExpCtx) -> Result<Vec<(String, usize, f64, f64)>> {
    let mut all = Vec::new();
    let mut json_deps = Vec::new();
    println!("\n=== Fig. 17: full-model coverage, 2MR vs CDC+2MR ===");
    for dep in fig17_deployments() {
        let n = dep.total_devices();
        let mut rows = Vec::new();
        let mut series = Vec::new();
        for extra in 0..=n {
            let c2 = dep.coverage_2mr(extra);
            let ch = dep.coverage_cdc_2mr(extra);
            // Monte-Carlo agreement check (quick mode skips).
            if !ctx.quick {
                let sim = simulate_coverage(&dep, extra, true, 4000, ctx.seed + extra as u64);
                debug_assert!((sim - ch).abs() < 0.05);
            }
            rows.push(vec![
                format!("{extra}"),
                format!("{:.0}%", c2 * 100.0),
                format!("{:.0}%", ch * 100.0),
            ]);
            series.push(obj(vec![
                ("extra", Value::Num(extra as f64)),
                ("coverage_2mr", Value::Num(c2)),
                ("coverage_cdc_2mr", Value::Num(ch)),
            ]));
            all.push((dep.name.clone(), extra, c2, ch));
        }
        let (full_2mr, full_hybrid) = dep.full_coverage_cost();
        println!(
            "\n{} — {} devices (MP layers: {:?}, singles: {})",
            dep.name, n, dep.mp_layers, dep.single_devices
        );
        print_table(&["extra devices", "2MR", "CDC+2MR"], &rows);
        println!(
            "full coverage: 2MR needs +{full_2mr} (linear), CDC+2MR needs \
             +{full_hybrid} (constant per MP layer — (1+1/N)× vs 2× hardware)"
        );
        json_deps.push(obj(vec![
            ("name", Value::Str(dep.name.clone())),
            ("devices", Value::Num(n as f64)),
            ("full_cost_2mr", Value::Num(full_2mr as f64)),
            ("full_cost_cdc_2mr", Value::Num(full_hybrid as f64)),
            ("series", Value::Arr(series)),
        ]));
    }
    ctx.write_result(
        "fig17",
        &obj(vec![
            ("experiment", Value::Str("fig17_coverage".into())),
            ("deployments", Value::Arr(json_deps)),
        ]),
    )?;
    Ok(all)
}
