//! Table 1 — distribution techniques suitable for CDC robustness.
//!
//! The Yes/No column is *derived*, not hard-coded: a method is suitable
//! iff it divides the weights without dividing the input (§5.3). The unit
//! and property tests in `partition` prove each row; this driver prints
//! the table and records it.

use crate::error::Result;
use crate::json::{obj, Value};
use crate::partition::SplitMethod;

use super::{print_table, ExpCtx};

/// Print + persist Table 1.
pub fn run(ctx: &ExpCtx) -> Result<Vec<(String, bool)>> {
    let yn = |b: bool| if b { "Yes" } else { "No" }.to_string();
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for m in SplitMethod::ALL {
        let p = m.props();
        rows.push(vec![
            p.layer.to_string(),
            m.name().to_string(),
            yn(p.divides_input),
            yn(p.divides_weight),
            yn(p.divides_output),
            yn(m.cdc_suitable()),
        ]);
        out.push((format!("{}/{}", p.layer, m.name()), m.cdc_suitable()));
    }
    println!("\n=== Table 1: distribution techniques suitable for robustness ===");
    print_table(
        &["layer", "method", "divides input", "divides weight", "divides output", "suitable"],
        &rows,
    );

    let json_rows: Vec<Value> = out
        .iter()
        .map(|(k, s)| {
            obj(vec![
                ("method", Value::Str(k.clone())),
                ("suitable", Value::Bool(*s)),
            ])
        })
        .collect();
    ctx.write_result(
        "table1",
        &obj(vec![
            ("experiment", Value::Str("table1_suitability".into())),
            ("rows", Value::Arr(json_rows)),
        ]),
    )?;
    Ok(out)
}
