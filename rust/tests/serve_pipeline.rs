//! Integration tests for the pipelined serving engine, runnable with NO
//! python-built artifacts: they deploy the synthetic fc-only model from
//! `testkit::synth` and drive it through the full stack (fleet threads +
//! interpreter compute + policy + CDC recovery + virtual-time scheduler).

use cdc_dnn::coordinator::{
    Pipeline, Session, SessionConfig, SplitSpec, Workload,
};
use cdc_dnn::fleet::{FailurePlan, NetConfig};
use cdc_dnn::metrics::max_overlap;
use cdc_dnn::model::Weights;
use cdc_dnn::rng::Pcg32;
use cdc_dnn::runtime::Manifest;
use cdc_dnn::tensor::Tensor;
use cdc_dnn::testkit::synth;

/// mlp on 3 devices: fc1 split over {0,1}, fc2 whole on {2}.
fn two_stage_cfg() -> SessionConfig {
    let mut cfg = SessionConfig::new(synth::MODEL);
    cfg.n_devices = 3;
    cfg.net = NetConfig::ideal();
    cfg.splits.insert("fc1".into(), SplitSpec::plain(2));
    cfg.placement.insert("fc1".into(), vec![0, 1]);
    cfg.placement.insert("fc2".into(), vec![2]);
    cfg
}

fn inputs(n: usize, seed: u64) -> Vec<Tensor> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| Tensor::randn(vec![synth::FC1_K], &mut rng)).collect()
}

/// Reference forward pass for the synthetic model.
fn oracle(root: &std::path::Path, x: &Tensor) -> Tensor {
    let m = Manifest::load(root).unwrap();
    let model = m.model(synth::MODEL).unwrap();
    let w = Weights::load(&m, model).unwrap();
    let xc = x.clone().reshape(vec![x.len(), 1]).unwrap();
    let mut h = w.w("fc1").unwrap().matmul(&xc).unwrap();
    h.add_assign(w.b("fc1").unwrap()).unwrap();
    h.relu();
    let mut out = w.w("fc2").unwrap().matmul(&h).unwrap();
    out.add_assign(w.b("fc2").unwrap()).unwrap();
    out
}

#[test]
fn pipeline_sustains_concurrent_requests() {
    let synth = synth::build(1).unwrap();
    let mut s = Session::start(&synth.root, two_stage_cfg()).unwrap();
    let report = Pipeline::new(&mut s)
        .run(&Workload::closed(inputs(8, 11), 4))
        .unwrap();

    assert_eq!(report.throughput.completed, 8);
    assert!(report.failures.is_empty());
    assert_eq!(report.traces.len(), 8);
    assert_eq!(report.stages.len(), 2, "fc1 + fc2 distributed stages");
    for st in &report.stages {
        assert_eq!(st.served, 8, "stage {} served all requests", st.layer);
        assert_eq!(st.occupancy.len(), 8);
        assert!(st.busy_ms > 0.0);
    }
    // The acceptance assertion: ≥ 2 requests in flight, read off the raw
    // stage-occupancy traces (stage intervals overlapping in time belong
    // to different requests — a stage holds one request at a time).
    let occ: Vec<_> = report.stages.iter().map(|s| &s.occupancy).collect();
    assert!(
        max_overlap(&occ) >= 2,
        "pipeline must overlap stages: {}",
        report.line()
    );
    assert!(report.max_concurrent_requests >= 2, "{}", report.line());
    assert!(report.rps() > 0.0);
    // Pipelining beats serial execution: makespan under the sum of
    // end-to-end latencies.
    let serial: f64 = report.latency.samples().iter().sum();
    assert!(report.makespan_ms < serial, "no overlap achieved");
}

#[test]
fn single_request_pipeline_matches_sequential_infer() {
    let synth = synth::build(2).unwrap();
    let xs = inputs(3, 22);

    // A: three separate single-shot infer calls.
    let mut a = Session::start(&synth.root, {
        let mut c = two_stage_cfg();
        c.net = NetConfig::moderate();
        c
    })
    .unwrap();
    let a_traces: Vec<_> = xs.iter().map(|x| a.infer(x).unwrap()).collect();

    // B: the same inputs as one concurrency-1 closed-loop workload.
    let mut b = Session::start(&synth.root, {
        let mut c = two_stage_cfg();
        c.net = NetConfig::moderate();
        c
    })
    .unwrap();
    let report = b.serve(&Workload::closed(xs.clone(), 1)).unwrap();

    assert_eq!(report.traces.len(), 3);
    assert_eq!(report.max_concurrent_requests, 1);
    for (ta, tb) in a_traces.iter().zip(&report.traces) {
        // Identical outputs (the compute path is shared)...
        assert_eq!(ta.output, tb.output);
        // ...and identical per-request timing: a concurrency-1 pipeline
        // degenerates exactly to sequential inference.
        assert!(
            (ta.total_ms - tb.total_ms).abs() < 1e-9,
            "infer {} vs pipeline {}",
            ta.total_ms,
            tb.total_ms
        );
        assert_eq!(ta.layers.len(), tb.layers.len());
        for (la, lb) in ta.layers.iter().zip(&tb.layers) {
            let da = la.t_done_ms - la.t_start_ms;
            let db = lb.t_done_ms - lb.t_start_ms;
            assert!((da - db).abs() < 1e-9, "{}: {da} vs {db}", la.layer);
        }
    }
}

#[test]
fn cdc_recovery_under_load_is_exact_and_lossless() {
    let synth = synth::build(3).unwrap();
    let mut cfg = SessionConfig::new(synth::MODEL);
    cfg.n_devices = 4;
    cfg.net = NetConfig::moderate();
    cfg.splits.insert("fc1".into(), SplitSpec::cdc(4));
    cfg.splits.insert("fc2".into(), SplitSpec::cdc(2));
    cfg.placement.insert("fc1".into(), vec![0, 1, 2, 3]);
    cfg.placement.insert("fc2".into(), vec![0, 1]);
    let mut s = Session::start(&synth.root, cfg).unwrap();
    assert_eq!(s.total_devices(), 6, "4 data + 2 parity");

    // Device 2 dies before the first request: every request must recover
    // fc1's shard 2 from the parity device, under pipelined load.
    s.set_failure(2, FailurePlan::PermanentAt(0)).unwrap();

    let xs = inputs(9, 33);
    let report = s.serve(&Workload::closed(xs.clone(), 3)).unwrap();
    assert_eq!(report.throughput.completed, 9, "{}", report.line());
    assert!(report.failures.is_empty(), "CDC must not lose requests");
    assert_eq!(report.throughput.recovered, 9, "every request recovers");
    for (x, t) in xs.iter().zip(&report.traces) {
        assert!(t.any_recovery);
        let want = oracle(&synth.root, x);
        let diff = t.output.max_abs_diff(&want);
        assert!(diff < 1e-4, "recovered logits diverge: {diff}");
    }
}

#[test]
fn serve_report_is_deterministic_in_seed_and_workload() {
    let run = || {
        let synth = synth::build(4).unwrap();
        let mut cfg = two_stage_cfg();
        cfg.net = NetConfig::moderate();
        cfg.splits.insert("fc1".into(), SplitSpec::cdc(2));
        cfg.threshold_factor = 2.0;
        let mut s = Session::start(&synth.root, cfg).unwrap();
        // An intermittently-failing device exercises the stochastic
        // recovery path.
        s.set_failure(1, FailurePlan::Intermittent(0.3)).unwrap();
        s.serve(&Workload::poisson(inputs(20, 44), 2000.0, 7)).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.latency.samples(), b.latency.samples());
    assert_eq!(a.queue_wait.samples(), b.queue_wait.samples());
    assert_eq!(a.makespan_ms, b.makespan_ms);
    assert_eq!(a.throughput.completed, b.throughput.completed);
    assert_eq!(a.throughput.recovered, b.throughput.recovered);
    assert_eq!(a.max_concurrent_requests, b.max_concurrent_requests);
    assert_eq!(a.stages.len(), b.stages.len());
    for (sa, sb) in a.stages.iter().zip(&b.stages) {
        assert_eq!(sa.occupancy, sb.occupancy, "stage {}", sa.layer);
    }
    for (ta, tb) in a.traces.iter().zip(&b.traces) {
        assert_eq!(ta.output, tb.output);
        assert_eq!(ta.t_done_ms, tb.t_done_ms);
    }
}

#[test]
fn admission_cap_bounds_the_entry_queue() {
    let synth = synth::build(5).unwrap();
    let mut s = Session::start(&synth.root, two_stage_cfg()).unwrap();
    // Five simultaneous arrivals, entry queue capped at 2: the first is
    // dispatched immediately, two wait, two balk.
    let wl = Workload::uniform(inputs(5, 55), 0.0).with_admission_cap(2);
    let report = s.serve(&wl).unwrap();
    assert_eq!(report.dropped, 2, "{}", report.line());
    assert_eq!(report.throughput.completed, 3);
    assert!(report.failures.is_empty());
    // Queue waits grow for the waiting requests.
    let qw = report.queue_wait.samples();
    assert_eq!(qw.len(), 3);
    assert!(qw[0] < 1e-12);
    assert!(qw[1] > 0.0 && qw[2] > qw[1]);
}

/// Regression (PR 3): failure draws used to come from a persistent
/// per-device RNG stream, so a second `Pipeline::run` of the same
/// workload on the same session saw a *different* intermittent-drop (and
/// reply-jitter) pattern than the first. Draws are now content-addressed
/// — a pure function of (session seed, device, task, input bits) — so
/// repeated serve() calls replay bit-for-bit.
#[test]
fn repeated_serve_runs_replay_identical_failure_patterns() {
    let synth = synth::build(8).unwrap();
    let mut cfg = two_stage_cfg();
    cfg.net = NetConfig::moderate();
    cfg.splits.insert("fc1".into(), SplitSpec::cdc(2));
    let mut s = Session::start(&synth.root, cfg).unwrap();
    s.set_failure(1, FailurePlan::Intermittent(0.7)).unwrap();

    let wl = Workload::closed(inputs(16, 66), 2);
    let a = s.serve(&wl).unwrap();
    let b = s.serve(&wl).unwrap();

    assert_eq!(a.latency.samples(), b.latency.samples(), "timing must replay");
    assert_eq!(a.throughput.completed, b.throughput.completed);
    assert_eq!(
        a.throughput.recovered, b.throughput.recovered,
        "drop pattern must replay across runs"
    );
    assert_eq!(a.makespan_ms, b.makespan_ms);
    for (ta, tb) in a.traces.iter().zip(&b.traces) {
        assert_eq!(ta.output, tb.output);
        assert_eq!(ta.any_recovery, tb.any_recovery);
    }
    // The stochastic path was actually exercised: with p=0.7 over 16
    // requests a drop-free run is a ~4e-9 event, and whatever this seed
    // draws is exactly reproducible, so this cannot flake.
    assert!(a.throughput.recovered > 0, "{}", a.line());
}

#[test]
fn layer_plans_expose_split_introspection() {
    let synth = synth::build(6).unwrap();
    let mut cfg = SessionConfig::new(synth::MODEL);
    cfg.n_devices = 4;
    cfg.net = NetConfig::ideal();
    cfg.splits.insert("fc1".into(), SplitSpec::cdc(4));
    let s = Session::start(&synth.root, cfg).unwrap();
    let plans = s.layer_plans();
    assert_eq!(plans.len(), 2);
    let (name, p1) = &plans[0];
    assert_eq!(*name, "fc1");
    assert_eq!(p1.d, 4);
    // Balanced-assignment invariant: shards cover the layer exactly.
    assert_eq!(p1.covered_rows(), synth::FC1_M);
    // Uniform (padded) shard height.
    assert!(p1.shards.iter().all(|sh| sh.height == synth::FC1_M.div_ceil(4)));
    let (name2, p2) = &plans[1];
    assert_eq!(*name2, "fc2");
    assert_eq!(p2.d, 1);
}
