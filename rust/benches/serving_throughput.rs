//! Bench target for the pipelined serving engine: wall-clock scheduler
//! overhead (virtual-time bookkeeping + dispatch/gather/resolve rounds)
//! at d=4, CDC on and off.
//!
//! Runs entirely on the synthetic artifact set (`testkit::synth`) — no
//! python/AOT build step — so it measures the *engine*, not XLA. Writes a
//! baseline record in the bench JSON format to
//! `results/bench_serving_throughput.json`.
//!
//! Run with `cargo bench --bench serving_throughput`.

use cdc_dnn::bench::Bench;
use cdc_dnn::coordinator::{Session, SessionConfig, SplitSpec, Workload};
use cdc_dnn::fleet::NetConfig;
use cdc_dnn::json::{obj, Value};
use cdc_dnn::rng::Pcg32;
use cdc_dnn::tensor::Tensor;
use cdc_dnn::testkit::synth;

const REQUESTS: usize = 64;
const CONCURRENCY: usize = 4;

fn session(root: &std::path::Path, cdc: bool) -> Session {
    let mut cfg = SessionConfig::new(synth::MODEL);
    cfg.n_devices = 4;
    cfg.net = NetConfig::ideal();
    cfg.splits.insert(
        "fc1".into(),
        if cdc { SplitSpec::cdc(4) } else { SplitSpec::plain(4) },
    );
    cfg.placement.insert("fc1".into(), vec![0, 1, 2, 3]);
    cfg.placement.insert("fc2".into(), vec![0]);
    Session::start(root, cfg).expect("synthetic session")
}

fn main() {
    println!(
        "serving_throughput: compute backend = {}",
        cdc_dnn::runtime::backend_label()
    );
    let synth = synth::build(42).expect("synthetic artifacts");
    let mut rng = Pcg32::seeded(9);
    let inputs: Vec<Tensor> = (0..REQUESTS)
        .map(|_| Tensor::randn(vec![synth::FC1_K], &mut rng))
        .collect();
    let workload = Workload::closed(inputs, CONCURRENCY);

    let mut results = Vec::new();
    let mut headline: Vec<(String, f64)> = Vec::new();
    for cdc in [false, true] {
        let mut s = session(&synth.root, cdc);
        let label = if cdc { "cdc" } else { "plain" };
        // Sanity pass: the pipeline must overlap requests and lose none.
        let report = s.serve(&workload).expect("pipeline run");
        assert_eq!(report.throughput.completed as usize, REQUESTS);
        assert!(report.max_concurrent_requests >= 2);
        println!("serve[{label}]: {}", report.line());

        let summary = Bench::new(&format!(
            "serve/pipeline_d4_{label} ({REQUESTS} reqs, c={CONCURRENCY})"
        ))
        .iters(2, 10)
        .run(|| {
            s.serve(&workload).expect("pipeline run");
        });
        let per_request_us = summary.mean * 1000.0 / REQUESTS as f64;
        let wall_rps = REQUESTS as f64 / (summary.mean / 1000.0);
        headline.push((format!("wall_rps_{label}"), wall_rps));
        println!(
            "  scheduler overhead: {per_request_us:.1} µs/request \
             ({wall_rps:.0} req/s wall-clock)"
        );
        results.push(obj(vec![
            ("bench", Value::Str(format!("serve_pipeline_d4_{label}"))),
            ("requests", Value::Num(REQUESTS as f64)),
            ("concurrency", Value::Num(CONCURRENCY as f64)),
            ("cdc", Value::Bool(cdc)),
            ("mean_ms_per_run", Value::Num(summary.mean)),
            ("p95_ms_per_run", Value::Num(summary.p95)),
            ("per_request_us", Value::Num(per_request_us)),
            ("wall_rps", Value::Num(wall_rps)),
        ]));
    }

    let doc = obj(vec![
        ("experiment", Value::Str("bench_serving_throughput".into())),
        ("backend", Value::Str(cdc_dnn::runtime::backend_label().into())),
        ("baselines", Value::Arr(results)),
    ]);
    std::fs::create_dir_all("results").expect("results dir");
    let path = "results/bench_serving_throughput.json";
    std::fs::write(path, doc.to_string_pretty()).expect("write baseline");
    println!("[result] wrote {path}");
    // Perf-trajectory guard (CI): wall-clock scheduler throughput vs the
    // committed seed (promoted from the same CI runner class).
    cdc_dnn::bench::guard_baseline("serving", &headline);
}
