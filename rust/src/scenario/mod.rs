//! Deterministic virtual-time **scenario engine** for fleet chaos
//! (DESIGN.md §9).
//!
//! The paper's headline claim — close-to-zero recovery latency under the
//! *common* IoT failure modes — only means something when those modes are
//! exercised as *time-varying* regimes, not a fixed `FailurePlan` per
//! run: devices crash and come back, fleets churn, WLANs congest and
//! clear, heterogeneous devices straggle, and traffic arrives in bursts.
//! A [`Scenario`] scripts exactly that: a list of timed [`Event`]s over a
//! virtual-time horizon, plus the arrival process that feeds the
//! pipelined serving engine (`coordinator::serve`) between them.
//!
//! The [`engine::ScenarioEngine`] executes the script **segment by
//! segment**: arrivals between two consecutive events are generated from
//! the scenario seed (Poisson at the current rate, plus any pending burst
//! spike at the segment start), served to quiescence through
//! `Session::serve` with explicit arrival instants, and then the
//! segment-ending event is applied to the fleet. Everything is seeded —
//! the same scenario replays bit-for-bit (asserted by the integration
//! tests).
//!
//! Churn events (`Join`/`Leave`) re-partition the deployment through the
//! existing `partition` planner: split degrees are re-clamped to the
//! largest manifest-available degree that fits the new fleet and the
//! model is re-deployed. See DESIGN.md §9 for the exact event-ordering
//! rules.
//!
//! ```
//! use cdc_dnn::exp::scenarios::{arm_cfg, steady, Arm};
//! use cdc_dnn::scenario::ScenarioEngine;
//! use cdc_dnn::testkit::synth;
//!
//! # fn main() -> cdc_dnn::Result<()> {
//! let artifacts = synth::build(7)?;
//! let sc = steady(7).scaled(0.25); // short steady run
//! let mut engine = ScenarioEngine::new(&artifacts.root, arm_cfg(&sc, Arm::Cdc))?;
//! let report = engine.run(&sc)?;
//! assert_eq!(report.failed, 0, "coded serving never loses a request");
//! # Ok(()) }
//! ```
#![deny(missing_docs)]

pub mod engine;

use crate::fleet::NetConfig;
use crate::metrics::Series;

pub use engine::ScenarioEngine;

/// A WLAN regime tag, mapping onto the calibrated [`NetConfig`] presets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetProfile {
    /// Zero-delay network — isolates compute effects.
    Ideal,
    /// The case-study testbed: mostly-fast local WLAN.
    Moderate,
    /// Fig. 1's congested worst case (the default profile).
    Congested,
}

impl NetProfile {
    /// The concrete network model for this regime.
    pub fn config(&self) -> NetConfig {
        match self {
            NetProfile::Ideal => NetConfig::ideal(),
            NetProfile::Moderate => NetConfig::moderate(),
            NetProfile::Congested => NetConfig::congested(),
        }
    }

    /// Human-readable tag.
    pub fn label(&self) -> &'static str {
        match self {
            NetProfile::Ideal => "ideal",
            NetProfile::Moderate => "moderate",
            NetProfile::Congested => "congested",
        }
    }
}

/// A fleet/workload mutation the engine can inject at a virtual instant.
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    /// The device dies permanently (until a `Recover`).
    Crash {
        /// Device index (data or redundancy device).
        device: usize,
    },
    /// A previously crashed/flaky device returns healthy.
    Recover {
        /// Device index.
        device: usize,
    },
    /// The device drops each reply independently with probability `p`.
    Flaky {
        /// Device index.
        device: usize,
        /// Per-reply drop probability.
        p: f64,
    },
    /// Churn: `n` devices join the fleet; split layers re-partition up to
    /// their target degree and the model is re-deployed.
    Join {
        /// Devices joining.
        n: usize,
    },
    /// Churn: `n` devices leave the fleet; split layers re-partition down
    /// to the largest degree the shrunken fleet supports.
    Leave {
        /// Devices leaving.
        n: usize,
    },
    /// Swap the fleet-wide WLAN regime.
    Net {
        /// The new regime.
        profile: NetProfile,
    },
    /// Scale one device's compute rate (0.5 ≈ an RPi3 in an RPi4 fleet).
    Slowdown {
        /// Device index.
        device: usize,
        /// Multiplier on the scenario's base device rate.
        factor: f64,
    },
    /// Change the open-loop arrival rate for subsequent segments.
    Rate {
        /// New arrival rate (requests/second).
        rps: f64,
    },
    /// Burst spike: `n` extra requests arrive at this instant, on top of
    /// the Poisson stream.
    Burst {
        /// Burst size (requests).
        n: usize,
    },
    /// Abrupt process death: the device vanishes with no recovery
    /// expected (no paired `Recover`). On the simulator this behaves
    /// like [`Action::Crash`]; over a live TCP fleet
    /// (`exp::scenarios::run_tcp`) it is a literal SIGKILL, exercising
    /// connection-death detection and the live-membership repartition
    /// path (DESIGN.md §13).
    Kill {
        /// Device index.
        device: usize,
    },
}

impl Action {
    /// Short label for tables and segment traces.
    pub fn label(&self) -> String {
        match self {
            Action::Crash { device } => format!("crash(d{device})"),
            Action::Recover { device } => format!("recover(d{device})"),
            Action::Flaky { device, p } => format!("flaky(d{device},p={p})"),
            Action::Join { n } => format!("join({n})"),
            Action::Leave { n } => format!("leave({n})"),
            Action::Net { profile } => format!("net({})", profile.label()),
            Action::Slowdown { device, factor } => {
                format!("slowdown(d{device},x{factor})")
            }
            Action::Rate { rps } => format!("rate({rps}rps)"),
            Action::Burst { n } => format!("burst({n})"),
            Action::Kill { device } => format!("kill(d{device})"),
        }
    }
}

/// One timed event of a scenario script.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    /// Virtual instant (ms from scenario start) the event applies at.
    pub at_ms: f64,
    /// What happens.
    pub action: Action,
}

/// A scripted, fully-seeded fleet-chaos scenario.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Scenario name (the catalog's key).
    pub name: String,
    /// Virtual-time horizon over which arrivals are generated (ms);
    /// serving runs past it until the last request drains.
    pub duration_ms: f64,
    /// Initial open-loop arrival rate (requests/second).
    pub base_rate_rps: f64,
    /// Seed for arrival times and request inputs.
    pub seed: u64,
    /// Timed events, applied in `at_ms` order (ties: script order).
    pub events: Vec<Event>,
    /// WLAN regime the fleet starts in.
    pub initial_net: NetProfile,
    /// Override of the per-device compute rate (MACs/ms) — `None` keeps
    /// the session default. Heterogeneity scenarios slow compute down so
    /// rate factors matter relative to the network.
    pub device_rate: Option<f64>,
}

impl Scenario {
    /// A scenario with no events (extend with [`Scenario::at`]).
    pub fn new(name: &str, duration_ms: f64, base_rate_rps: f64, seed: u64) -> Scenario {
        Scenario {
            name: name.to_string(),
            duration_ms,
            base_rate_rps,
            seed,
            events: Vec::new(),
            initial_net: NetProfile::Moderate,
            device_rate: None,
        }
    }

    /// Append a timed event (builder style).
    pub fn at(mut self, at_ms: f64, action: Action) -> Scenario {
        self.events.push(Event { at_ms, action });
        self
    }

    /// Set the initial WLAN regime (builder style).
    pub fn with_net(mut self, profile: NetProfile) -> Scenario {
        self.initial_net = profile;
        self
    }

    /// Override the per-device compute rate (builder style).
    pub fn with_device_rate(mut self, macs_per_ms: f64) -> Scenario {
        self.device_rate = Some(macs_per_ms);
        self
    }

    /// Scale the horizon and every event time by `f` (quick/smoke runs).
    pub fn scaled(mut self, f: f64) -> Scenario {
        self.duration_ms *= f;
        for e in &mut self.events {
            e.at_ms *= f;
        }
        self
    }
}

/// Per-segment summary of a scenario run (one segment per inter-event
/// span).
#[derive(Debug, Clone)]
pub struct SegmentReport {
    /// Effective segment start on the scenario timeline (ms) — pushed
    /// past the scheduled event boundary when the previous segment
    /// drained late (segments never overlap).
    pub t_start_ms: f64,
    /// Requests that arrived in the segment.
    pub arrivals: usize,
    /// Requests completed.
    pub completed: u64,
    /// Requests lost (unrecoverable shard loss).
    pub failed: u64,
    /// Requests that used CDC/replica recovery.
    pub recovered: u64,
    /// Arrivals balked by an admission cap.
    pub dropped: u64,
    /// p99 end-to-end latency within the segment (ms; 0 if empty).
    pub p99_ms: f64,
    /// Label of the event applied at the segment's end (None for the
    /// final segment).
    pub event: Option<String>,
}

/// Everything a scenario run measured, merged across segments.
#[derive(Debug)]
pub struct ScenarioReport {
    /// Scenario name.
    pub scenario: String,
    /// Requests completed across all segments.
    pub completed: u64,
    /// Requests lost across all segments.
    pub failed: u64,
    /// Requests recovered via parity/replica substitution.
    pub recovered: u64,
    /// Arrivals balked by an admission cap.
    pub dropped: u64,
    /// End-to-end latency of every completed request (ms).
    pub latency: Series,
    /// Scenario-timeline instant the last request drained (ms).
    pub makespan_ms: f64,
    /// Per-segment summaries, in order.
    pub segments: Vec<SegmentReport>,
    /// Fleet re-deployments triggered by churn events.
    pub rebuilds: usize,
    /// Widest cross-request micro-batch any segment's serving dispatched
    /// (1 when batching is off or never engaged — DESIGN.md §10).
    pub max_batch: usize,
    /// Adaptive-policy snapshot at the end of the run (None when the
    /// session runs the static straggler gate).
    pub policy: Option<crate::coordinator::PolicyReport>,
}

impl ScenarioReport {
    /// Steady-state throughput over the whole run (requests/second of
    /// virtual time).
    pub fn rps(&self) -> f64 {
        if self.makespan_ms <= 0.0 {
            0.0
        } else {
            self.completed as f64 / (self.makespan_ms / 1000.0)
        }
    }

    /// One-line summary for experiment logs.
    pub fn line(&self) -> String {
        let s = self.latency.summary();
        format!(
            "{}: served={} failed={} recovered={} dropped={} rps={:.1} \
             p50={:.1}ms p99={:.1}ms makespan={:.0}ms rebuilds={}",
            self.scenario,
            self.completed,
            self.failed,
            self.recovered,
            self.dropped,
            self.rps(),
            s.p50,
            s.p99,
            self.makespan_ms,
            self.rebuilds,
        )
    }
}
