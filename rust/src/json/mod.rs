//! Minimal JSON substrate (this environment is offline: no serde).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json` and
//! the deployment/allocation config files: objects, arrays, strings with
//! escapes, numbers (f64), booleans, null. Parsing is recursive-descent
//! with byte-offset error reporting; serialization is pretty or compact.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Parse a JSON document from a string.
    pub fn parse(s: &str) -> Result<Value> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Object field access; errors if not an object or key missing.
    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m
                .get(key)
                .ok_or_else(|| Error::Json(format!("missing key {key:?}"))),
            _ => Err(Error::Json(format!("expected object for key {key:?}"))),
        }
    }

    /// Optional object field access.
    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Expect a string.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            v => Err(Error::Json(format!("expected string, got {}", v.kind()))),
        }
    }

    /// Expect a number.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            v => Err(Error::Json(format!("expected number, got {}", v.kind()))),
        }
    }

    /// Expect an integer-valued number.
    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(Error::Json(format!("expected non-negative integer, got {n}")));
        }
        Ok(n as usize)
    }

    /// Expect a boolean.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            v => Err(Error::Json(format!("expected bool, got {}", v.kind()))),
        }
    }

    /// Expect an array.
    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            v => Err(Error::Json(format!("expected array, got {}", v.kind()))),
        }
    }

    /// Expect an object map.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            v => Err(Error::Json(format!("expected object, got {}", v.kind()))),
        }
    }

    /// Convenience: array of usize.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    fn kind(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "bool",
            Value::Num(_) => "number",
            Value::Str(_) => "string",
            Value::Arr(_) => "array",
            Value::Obj(_) => "object",
        }
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builder helpers for constructing JSON output in experiment reports.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Array-of-f64 helper.
pub fn arr_f64(xs: &[f64]) -> Value {
    Value::Arr(xs.iter().map(|x| Value::Num(*x)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        // Compute line/col for the error offset.
        let (mut line, mut col) = (1usize, 1usize);
        for &c in &self.b[..self.i.min(self.b.len())] {
            if c == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        Error::Json(format!("{msg} at line {line} col {col}"))
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }

    /// Parse exactly four hex digits starting at byte `at`. Truncated or
    /// non-hex input is a parse error — never a panic or an OOB slice,
    /// whatever bytes (including invalid UTF-8) follow the `\u`.
    fn hex4(&self, at: usize) -> Result<u32> {
        let bytes = self
            .b
            .get(at..at + 4)
            .ok_or_else(|| self.err("bad \\u escape"))?;
        let mut v = 0u32;
        for &b in bytes {
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad \\u escape"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'u') => {
                            let cp = self.hex4(self.i + 1)?;
                            // Surrogate pairs: a high surrogate must be
                            // followed by a `\u`-escaped low surrogate in
                            // range — anything else (truncation, a
                            // non-escape, a second high surrogate) is a
                            // parse error, never a panic.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i + 5) != Some(&b'\\')
                                    || self.b.get(self.i + 6) != Some(&b'u')
                                {
                                    return Err(self.err("lone surrogate"));
                                }
                                let lo = self.hex4(self.i + 7)?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("bad low surrogate"));
                                }
                                self.i += 6;
                                let joined =
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(joined)
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("bad codepoint"))?);
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("bad utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse(" -1.5e2 ").unwrap(), Value::Num(-150.0));
        assert_eq!(
            Value::parse("\"a\\nb\"").unwrap(),
            Value::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a":[1,2,{"b":false}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_usize().unwrap(), 1);
        assert!(!arr[2].get("b").unwrap().as_bool().unwrap());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"m":[1,2.5,"s",null,true],"o":{"k":-3}}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
        let v3 = Value::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn errors_have_location() {
        let e = Value::parse("{\n  \"a\": ,\n}").unwrap_err();
        let msg = format!("{e}");
        assert!(msg.contains("line 2"), "{msg}");
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Value::parse(r#""é""#).unwrap(),
            Value::Str("é".into())
        );
    }

    #[test]
    fn unicode_escape_pairs_and_bmp() {
        assert_eq!(
            Value::parse(r#""é""#).unwrap(),
            Value::Str("é".into())
        );
        // Astral codepoint via a surrogate pair (U+1F600).
        assert_eq!(
            Value::parse(r#""😀""#).unwrap(),
            Value::Str("😀".into())
        );
    }

    #[test]
    fn malformed_unicode_escapes_error_not_panic() {
        // Non-hex digits.
        assert!(Value::parse(r#""\uZZZZ""#).is_err());
        // `from_str_radix` would accept a sign; a strict hex4 must not.
        assert!(Value::parse(r#""\u+fff""#).is_err());
        // Truncated escape at end of input.
        assert!(Value::parse(r#""\u00"#).is_err());
        // High surrogate followed by a plain char (was an OOB slice
        // panic path), by a truncated escape, and by nothing at all.
        assert!(Value::parse(r#""\ud800A""#).is_err());
        assert!(Value::parse(r#""\ud800\u""#).is_err());
        assert!(Value::parse(r#""\ud800""#).is_err());
        // High surrogate followed by a non-low-surrogate escape
        // (`lo - 0xDC00` underflow in the old decoder).
        assert!(Value::parse(r#""\ud800\u0041""#).is_err());
        // Lone low surrogate is not a scalar value.
        assert!(Value::parse(r#""\ude00""#).is_err());
        // A multi-byte char straddling the 4-digit window: the old
        // `from_utf8(..).unwrap()` panicked on the split scalar.
        assert!(Value::parse("\"\\u1😀\"").is_err());
    }

    #[test]
    fn trailing_garbage_rejected() {
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("{\"a\" 1}").is_err());
    }
}
