# Build entry points. `make artifacts` needs the python toolchain
# (jax + the repo's compile package); everything rust-side builds and
# tests offline without it (see DESIGN.md §3/§7).

ARTIFACTS ?= rust/artifacts

.PHONY: artifacts build test bench fmt clippy

artifacts:
	cd python && python -m compile.aot --out ../$(ARTIFACTS)
	ln -sfn $(ARTIFACTS) artifacts

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

fmt:
	cargo fmt --check

clippy:
	cargo clippy -- -D warnings
