"""L1 Pallas kernels: blocked GEMM with fused epilogue, CDC encode/decode.

These kernels are the compute hot-spot of every per-device task in the
paper's distribution schemes (Section 5.1): a fully-connected shard is a
GEMM over a row-slice of W; a channel-split conv shard is a GEMM over a
row-slice of the unrolled filter matrix (Eq. 4); the CDC parity shard is the
*same* GEMM over offline-summed weights (Eq. 11) — which is exactly why the
paper's scheme keeps the distribution balanced.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper targets ARM
CPUs, so there is no warp/tensor-core mapping to undo; we structure the
kernel the way a TPU implementation would — a (M/bm, N/bn, K/bk) grid whose
BlockSpecs express the HBM↔VMEM schedule, f32 accumulation in the output
block across the K grid axis, and the bias+ReLU epilogue fused into the last
K step. Under ``interpret=True`` (mandatory for CPU-PJRT execution) the same
structure lowers to plain HLO, so numerics are validated end-to-end.

All kernels pad operands up to block multiples with zeros and slice the
result back, so arbitrary shapes are supported.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shapes, chosen by the §Perf sweep (EXPERIMENTS.md):
# 512×512 weight blocks with 64-wide input blocks keep the VMEM working
# set at bm·bk + bk·bn + bm·bn ≈ (1 MiB + 128 KiB + 128 KiB) · f32 ≈
# 1.3 MiB — ~2.6 MiB double-buffered, comfortably under a TPU core's
# ~16 MiB VMEM — while minimising grid steps (the dominant cost both for
# the interpret-mode validator and for TPU grid dispatch). The wrapper
# clamps each block to the operand size, so a single-batch matvec (n = 1)
# never pays for padded columns: before the clamp a 512×2048 fc shard
# cost ≈ 210 ms per execution, after it 6.7 ms (≈ 31×).
BLOCK_M = 512
BLOCK_N = 64
BLOCK_K = 512


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _pad2(a, bm: int, bn: int):
    """Zero-pad a 2-D array up to multiples of (bm, bn)."""
    m, n = a.shape
    pm, pn = _ceil_div(m, bm) * bm - m, _ceil_div(n, bn) * bn - n
    if pm or pn:
        a = jnp.pad(a, ((0, pm), (0, pn)))
    return a


def _gemm_kernel(w_ref, x_ref, b_ref, o_ref, *, nsteps_k: int, relu: bool,
                 has_bias: bool):
    """Grid = (M/bm, N/bn, K/bk); accumulate into o_ref across the K axis.

    The output block is revisited for every K step (classic Pallas matmul):
    initialise at k==0, accumulate, and run the epilogue at the last step.
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        w_ref[...], x_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k_step == nsteps_k - 1)
    def _epilogue():
        acc = o_ref[...]
        if has_bias:
            acc = acc + b_ref[...]
        if relu:
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc


@functools.partial(
    jax.jit,
    static_argnames=("relu", "block_m", "block_n", "block_k", "interpret"),
)
def gemm(w, x, bias=None, *, relu=False, block_m=BLOCK_M, block_n=BLOCK_N,
         block_k=BLOCK_K, interpret=True):
    """Blocked Pallas GEMM ``w @ x [+ bias] [relu]``.

    ``w``: (m, k) weight shard, ``x``: (k, n), ``bias``: (m, 1) or None.
    This is the single kernel every AOT shard artifact bottoms out in.
    """
    m, k = w.shape
    k2, n = x.shape
    assert k == k2, f"contraction mismatch: {w.shape} @ {x.shape}"
    # Adapt block shapes to the problem: single-batch inference is a
    # matvec (n == 1) — padding n up to a 64-wide block would compute 64
    # columns to use one (measured 64×/≈200 ms per fc-2048 shard before
    # this clamp; see EXPERIMENTS.md §Perf). On a real TPU the same logic
    # picks MXU-aligned blocks no wider than the operand.
    block_n = min(block_n, n)
    block_m = min(block_m, m)
    block_k = min(block_k, k)
    if n == 1:
        # Matvec fast path for the interpret-mode validator: grid-step
        # (while-loop + dynamic-slice) overhead dominates a GEMV, so take
        # the whole operand per step (4096² fc shard: 1375 ms → 3.9 ms,
        # EXPERIMENTS.md §Perf iteration 2). A real-TPU build would keep
        # bm×bk ≤ VMEM instead (512×2048 f32 = 4 MiB double-buffered);
        # the blocked path stays exercised by every n > 1 conv shard and
        # by the explicit-block tests.
        block_m = min(m, 8192)
        block_k = k
    has_bias = bias is not None
    if not has_bias:
        # Dummy operand keeps the kernel signature uniform; it is never read.
        bias = jnp.zeros((m, 1), dtype=w.dtype)
    assert bias.shape == (m, 1), f"bias must be (m,1), got {bias.shape}"

    wp = _pad2(w, block_m, block_k)
    xp = _pad2(x, block_k, block_n)
    bp = _pad2(bias, block_m, 1)
    gm, gn, gk = (
        wp.shape[0] // block_m,
        xp.shape[1] // block_n,
        wp.shape[1] // block_k,
    )

    out = pl.pallas_call(
        functools.partial(
            _gemm_kernel, nsteps_k=gk, relu=relu, has_bias=has_bias
        ),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_m, 1), lambda i, j, kk: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((wp.shape[0], xp.shape[1]), jnp.float32),
        interpret=interpret,
    )(wp, xp, bp)
    return out[:m, :n]


def _sum_kernel(s_ref, o_ref, *, nsteps: int):
    """Accumulate the leading axis: o += s[d] for each grid step d."""
    d = pl.program_id(1)

    @pl.when(d == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += s_ref[0]


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def cdc_encode(shards, *, block_m=BLOCK_M, interpret=True):
    """CDC parity weights = Σ_d shards[d] (paper Eq. 11), offline.

    ``shards``: (d, m_s, k) stack of per-device weight shards → (m_s, k).
    Grid walks (row-blocks, devices) so each VMEM-resident output block is
    revisited once per device — the TPU-friendly reduction order.
    """
    d, ms, k = shards.shape
    sp = jnp.pad(shards, ((0, 0), (0, _ceil_div(ms, block_m) * block_m - ms), (0, 0)))
    gm = sp.shape[1] // block_m
    out = pl.pallas_call(
        functools.partial(_sum_kernel, nsteps=d),
        grid=(gm, d),
        in_specs=[pl.BlockSpec((1, block_m, k), lambda i, dd: (dd, i, 0))],
        out_specs=pl.BlockSpec((block_m, k), lambda i, dd: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sp.shape[1], k), jnp.float32),
        interpret=interpret,
    )(sp)
    return out[:ms]


def _decode_kernel(p_ref, r_ref, o_ref, *, nrecv: int):
    """missing = parity − Σ received, blocked over rows."""
    o_ref[...] = p_ref[...] - jnp.sum(r_ref[...], axis=0)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def cdc_decode(parity_out, received, *, block_m=BLOCK_M, interpret=True):
    """Recover the missing device's output (paper §5.2).

    ``parity_out``: (m_s, n); ``received``: (d-1, m_s, n) surviving outputs.
    A single subtraction pass — this is the close-to-zero-latency recovery
    the paper contrasts with re-execution.
    """
    ms, n = parity_out.shape
    nrecv = received.shape[0]
    pad = _ceil_div(ms, block_m) * block_m - ms
    pp = jnp.pad(parity_out, ((0, pad), (0, 0)))
    rp = jnp.pad(received, ((0, 0), (0, pad), (0, 0)))
    gm = pp.shape[0] // block_m
    out = pl.pallas_call(
        functools.partial(_decode_kernel, nrecv=nrecv),
        grid=(gm,),
        in_specs=[
            pl.BlockSpec((block_m, n), lambda i: (i, 0)),
            pl.BlockSpec((nrecv, block_m, n), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pp.shape[0], n), jnp.float32),
        interpret=interpret,
    )(pp, rp)
    return out[:ms]
