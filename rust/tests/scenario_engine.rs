//! Integration tests for the scenario engine (DESIGN.md §9), runnable
//! with NO python-built artifacts: every named chaos scenario runs over
//! the synthetic `testkit::synth` model across the redundancy arms, and
//! the paper's core serving invariant is asserted for each —
//!
//! * **coded serving never loses a request**, whatever the script throws
//!   at the fleet (staggered crashes, churn re-partitioning, WLAN regime
//!   swaps, persistent stragglers, arrival bursts);
//! * **p99 degrades gracefully**: bounded within a constant factor of
//!   the no-redundancy baseline's p99 over the *same* script.

use cdc_dnn::exp::scenarios::{
    arm_cfg, catalog, churn, crash_storm, hetero_fleet, steady, Arm,
};
use cdc_dnn::scenario::ScenarioEngine;
use cdc_dnn::testkit::synth;

/// The tentpole invariant, across every named scenario.
#[test]
fn scenario_suite_cdc_never_loses_and_p99_stays_bounded() {
    let arts = synth::build(77).unwrap();
    for sc in catalog(2021) {
        let mut base_engine = ScenarioEngine::new(&arts.root, arm_cfg(&sc, Arm::None)).unwrap();
        let base = base_engine.run(&sc).unwrap();
        let mut cdc_engine = ScenarioEngine::new(&arts.root, arm_cfg(&sc, Arm::Cdc)).unwrap();
        let cdc = cdc_engine.run(&sc).unwrap();

        assert!(cdc.completed > 0, "{}: empty run", sc.name);
        assert_eq!(
            cdc.failed, 0,
            "{}: CDC lost requests — {}",
            sc.name,
            cdc.line()
        );
        // Every arrival is accounted for: completed + failed == arrivals.
        let arrivals: usize = cdc.segments.iter().map(|s| s.arrivals).sum();
        assert_eq!(cdc.completed as usize, arrivals, "{}", sc.name);

        // Graceful degradation: CDC's p99 stays within a constant factor
        // of the no-redundancy baseline's p99 over the same script. (The
        // baseline's p99 covers only the requests it managed to serve —
        // under crash windows it silently sheds the hard ones, so the
        // bound is deliberately generous.)
        let b99 = base.latency.summary().p99;
        let c99 = cdc.latency.summary().p99;
        assert!(
            c99 <= 10.0 * b99 + 500.0,
            "{}: CDC p99 {c99:.1}ms vs baseline p99 {b99:.1}ms — not bounded",
            sc.name
        );
    }
}

/// ISSUE 4 acceptance: the paper invariant survives cross-request
/// micro-batching for every named scenario — the batched CDC arm loses
/// zero requests (a failure now kills whole batches, and the batched
/// parity must reconstruct every member), its p99 stays bounded vs the
/// no-redundancy baseline, and batching genuinely engages somewhere in
/// the suite.
#[test]
fn scenario_suite_batched_cdc_never_loses_and_p99_stays_bounded() {
    let arts = synth::build(83).unwrap();
    let mut widest = 1usize;
    for sc in catalog(2021) {
        let mut base_engine = ScenarioEngine::new(&arts.root, arm_cfg(&sc, Arm::None)).unwrap();
        let base = base_engine.run(&sc).unwrap();
        let batched_cfg = arm_cfg(&sc, Arm::CdcBatched);
        let mut engine = ScenarioEngine::new(&arts.root, batched_cfg).unwrap();
        let batched = engine.run(&sc).unwrap();

        assert!(batched.completed > 0, "{}: empty run", sc.name);
        assert_eq!(
            batched.failed, 0,
            "{}: batched CDC lost requests — {}",
            sc.name,
            batched.line()
        );
        let arrivals: usize = batched.segments.iter().map(|s| s.arrivals).sum();
        assert_eq!(batched.completed as usize, arrivals, "{}", sc.name);
        let b99 = base.latency.summary().p99;
        let c99 = batched.latency.summary().p99;
        assert!(
            c99 <= 10.0 * b99 + 500.0,
            "{}: batched CDC p99 {c99:.1}ms vs baseline p99 {b99:.1}ms — not bounded",
            sc.name
        );
        widest = widest.max(batched.max_batch);
    }
    assert!(
        widest >= 2,
        "micro-batching never engaged across the whole suite (max width {widest})"
    );
}

/// Replication (2MR) also masks the crash storm — at twice the hardware.
#[test]
fn scenario_replication_arm_survives_crash_storm() {
    let arts = synth::build(78).unwrap();
    let sc = crash_storm(31);
    let mut engine = ScenarioEngine::new(&arts.root, arm_cfg(&sc, Arm::Replication)).unwrap();
    let rep = engine.run(&sc).unwrap();
    assert_eq!(rep.failed, 0, "2MR lost requests: {}", rep.line());
    assert!(rep.completed > 0);
    // The no-redundancy arm, by contrast, must lose requests while a
    // device is down — that contrast *is* the case-study story.
    let mut none_engine = ScenarioEngine::new(&arts.root, arm_cfg(&sc, Arm::None)).unwrap();
    let none = none_engine.run(&sc).unwrap();
    assert!(
        none.failed > 0,
        "crash-storm without redundancy should lose requests: {}",
        none.line()
    );
}

/// A scenario is a pure function of its script and seed.
#[test]
fn scenario_runs_are_deterministic() {
    let arts = synth::build(79).unwrap();
    let sc = crash_storm(55);
    let run = || {
        ScenarioEngine::new(&arts.root, arm_cfg(&sc, Arm::Cdc))
            .unwrap()
            .run(&sc)
            .unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.failed, b.failed);
    assert_eq!(a.recovered, b.recovered);
    assert_eq!(a.latency.samples(), b.latency.samples());
    assert_eq!(a.makespan_ms, b.makespan_ms);
    assert_eq!(a.segments.len(), b.segments.len());
    for (sa, sb) in a.segments.iter().zip(&b.segments) {
        assert_eq!(sa.arrivals, sb.arrivals);
        assert_eq!(sa.completed, sb.completed);
        assert_eq!(sa.p99_ms, sb.p99_ms);
    }
}

/// Churn re-partitions through the partition planner and recovers the
/// original degree when the fleet grows back.
#[test]
fn scenario_churn_repartitions_and_rejoins() {
    let arts = synth::build(80).unwrap();
    let sc = churn(13);
    let mut engine = ScenarioEngine::new(&arts.root, arm_cfg(&sc, Arm::Cdc)).unwrap();
    assert_eq!(engine.fleet_size(), 4);
    let report = engine.run(&sc).unwrap();
    assert_eq!(report.rebuilds, 2, "leave + join = two re-deployments");
    assert_eq!(engine.fleet_size(), 4, "fleet grew back");
    assert_eq!(report.failed, 0, "churn must not lose requests: {}", report.line());
    // After the run the live session is back at the target degrees.
    let plans = engine.session().layer_plans();
    assert_eq!(plans[0].1.d, 4, "fc1 re-partitioned back to d=4");
    assert_eq!(plans[1].1.d, 2, "fc2 back at d=2");
}

/// Slowdown events reach both the device threads and the coordinator's
/// rate-ledger mirror, starting from the scenario's declared base rate.
#[test]
fn scenario_slowdown_updates_rate_mirror() {
    let arts = synth::build(82).unwrap();
    let sc = hetero_fleet(19);
    let mut engine = ScenarioEngine::new(&arts.root, arm_cfg(&sc, Arm::Cdc)).unwrap();
    let report = engine.run(&sc).unwrap();
    assert_eq!(report.failed, 0, "{}", report.line());
    assert_eq!(engine.session().config().n_devices, 4);
    let rates = engine.session().device_rates();
    assert!((rates[1] - 3.0 * 0.4).abs() < 1e-12, "device 1 slowed: {rates:?}");
    assert!((rates[3] - 3.0 * 0.25).abs() < 1e-12, "device 3 slowed: {rates:?}");
    assert!((rates[0] - 3.0).abs() < 1e-12, "device 0 at the scenario base rate");
}

/// The adaptive policy surfaces its state on the CDC arm: the gate is
/// tuned within its clamp range and the trade-off fields are populated.
#[test]
fn scenario_adaptive_policy_reports_state() {
    let arts = synth::build(81).unwrap();
    let sc = steady(17);
    let mut engine = ScenarioEngine::new(&arts.root, arm_cfg(&sc, Arm::Cdc)).unwrap();
    let report = engine.run(&sc).unwrap();
    let p = report.policy.expect("CDC arm runs the adaptive policy");
    assert!(p.observed > 0, "policy observed no completions");
    assert!(
        (1.2..=8.0).contains(&p.threshold_factor),
        "tuned gate {} outside clamp range",
        p.threshold_factor
    );
    assert!(!p.device_windows.is_empty());
    assert!(p.device_windows.iter().any(|w| !w.is_empty()));
    // Static arms carry no policy snapshot.
    let mut none_engine = ScenarioEngine::new(&arts.root, arm_cfg(&sc, Arm::None)).unwrap();
    assert!(none_engine.run(&sc).unwrap().policy.is_none());
}
