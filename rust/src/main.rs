//! `cdc-dnn` — CLI launcher for the coded-distributed-computing DNN
//! serving system and its paper-reproduction experiments.
//!
//! ```text
//! cdc-dnn <command> [options]
//!
//! commands:
//!   fig1        arrival-time histogram (paper Fig. 1)
//!   fig2        accuracy vs per-layer data loss (Fig. 2)
//!   table1      split-method suitability table (Table 1)
//!   case1       AlexNet failure without robustness (Figs. 11-12)
//!   case2       AlexNet + CDC parity device (Figs. 13-15)
//!   fig16       straggler-mitigation sweep (Fig. 16)
//!   fig17       coverage: 2MR vs CDC+2MR (Fig. 17)
//!   fig18       multi-failure parity groups (Fig. 18)
//!   calibrate   simulator-vs-paper anchor table
//!   scenarios   fleet-chaos scenario suite (synthetic model, no artifacts)
//!   synth       materialise the synthetic artifact set at --artifacts
//!   serve       serve a deployment file (see --deployment)
//!   all         every experiment in order
//!
//! options:
//!   --artifacts DIR    AOT artifacts directory   [default: artifacts]
//!   --results DIR      result JSON directory     [default: results]
//!   --requests N       requests per series       [default: 400]
//!   --seed S           experiment seed           [default: 2021]
//!   --quick            reduced workloads (CI smoke)
//!   --deployment FILE  deployment JSON for `serve`
//! ```

use cdc_dnn::config::load_deployment;
use cdc_dnn::coordinator::Session;
use cdc_dnn::exp::{self, ExpCtx};
use cdc_dnn::metrics::Series;
use cdc_dnn::rng::Pcg32;
use cdc_dnn::tensor::Tensor;

fn usage() -> ! {
    // The module doc above is the single source of truth for help text.
    print!("{}", HELP);
    std::process::exit(2);
}

const HELP: &str = "cdc-dnn — robust distributed DNN inference with CDC\n\n\
usage: cdc-dnn <command> [--artifacts DIR] [--results DIR] [--requests N]\n\
       [--seed S] [--quick] [--deployment FILE]\n\n\
commands: fig1 fig2 table1 case1 case2 fig16 fig17 fig18 calibrate ablate\n          scenarios synth serve all\n";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
    }
    let cmd = args[0].clone();
    let mut ctx = ExpCtx::new("artifacts");
    let mut deployment: Option<String> = None;
    let mut i = 1;
    while i < args.len() {
        let need = |i: usize| {
            args.get(i + 1).cloned().unwrap_or_else(|| {
                eprintln!("missing value for {}", args[i]);
                std::process::exit(2)
            })
        };
        match args[i].as_str() {
            "--artifacts" => {
                ctx.artifacts = need(i).into();
                i += 2;
            }
            "--results" => {
                ctx.results = need(i).into();
                i += 2;
            }
            "--requests" => {
                ctx.requests = need(i).parse().unwrap_or_else(|_| {
                    eprintln!("bad --requests");
                    std::process::exit(2)
                });
                i += 2;
            }
            "--seed" => {
                ctx.seed = need(i).parse().unwrap_or_else(|_| {
                    eprintln!("bad --seed");
                    std::process::exit(2)
                });
                i += 2;
            }
            "--quick" => {
                ctx.quick = true;
                i += 1;
            }
            "--deployment" => {
                deployment = Some(need(i));
                i += 2;
            }
            "-h" | "--help" => usage(),
            other => {
                eprintln!("unknown option {other}");
                usage();
            }
        }
    }

    let result = match cmd.as_str() {
        "fig1" => exp::fig1::run(&ctx).map(|_| ()),
        "fig2" => exp::fig2::run(&ctx).map(|_| ()),
        "table1" => exp::table1::run(&ctx).map(|_| ()),
        "case1" => exp::case1::run(&ctx).map(|_| ()),
        "case2" => exp::case2::run(&ctx).map(|_| ()),
        "fig16" => exp::fig16::run(&ctx).map(|_| ()),
        "fig17" => exp::fig17::run(&ctx).map(|_| ()),
        "fig18" => exp::fig18::run(&ctx).map(|_| ()),
        "calibrate" => exp::calibrate::run(&ctx),
        "ablate" => exp::ablate::run(&ctx),
        "scenarios" => exp::scenarios::run(&ctx).map(|_| ()),
        "synth" => synth_artifacts(&ctx),
        "serve" => serve(&ctx, deployment.as_deref()),
        "all" => run_all(&ctx),
        _ => {
            eprintln!("unknown command {cmd}");
            usage();
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run_all(ctx: &ExpCtx) -> cdc_dnn::Result<()> {
    exp::calibrate::run(ctx)?;
    exp::table1::run(ctx)?;
    exp::fig1::run(ctx)?;
    exp::fig2::run(ctx)?;
    exp::case1::run(ctx)?;
    exp::case2::run(ctx)?;
    exp::fig16::run(ctx)?;
    exp::fig17::run(ctx)?;
    exp::fig18::run(ctx)?;
    exp::ablate::run(ctx)?;
    exp::scenarios::run(ctx)?;
    Ok(())
}

/// Materialise the synthetic artifact set (manifest + weights + eval
/// set, `testkit::synth`) at the `--artifacts` directory, so the binary
/// entrypoints run fully offline — the CI CLI-smoke job drives `ablate`
/// and `serve` against it.
fn synth_artifacts(ctx: &ExpCtx) -> cdc_dnn::Result<()> {
    let arts = cdc_dnn::testkit::synth::build_at(&ctx.artifacts, ctx.seed)?;
    println!(
        "wrote synthetic artifact set (model `{}`) to {}",
        cdc_dnn::testkit::synth::MODEL,
        arts.root.display()
    );
    Ok(())
}

/// Serve a deployment file: run `--requests` single-batch inferences with
/// random inputs and report the latency distribution and loss statistics.
fn serve(ctx: &ExpCtx, deployment: Option<&str>) -> cdc_dnn::Result<()> {
    let path = deployment.unwrap_or("configs/lenet5_cdc.json");
    let cfg = load_deployment(std::path::Path::new(path))?;
    println!(
        "serving {} on {} data devices (+redundancy)…",
        cfg.model, cfg.n_devices
    );
    let input_shape = {
        let manifest = cdc_dnn::runtime::Manifest::load(&ctx.artifacts)?;
        manifest.model(&cfg.model)?.input_shape.clone()
    };
    let mut session = Session::start(&ctx.artifacts, cfg)?;
    let mut rng = Pcg32::seeded(ctx.seed);
    let mut lat = Series::new();
    let mut lost = 0u64;
    let mut recovered = 0u64;
    let n = ctx.n_requests();
    let t0 = std::time::Instant::now();
    for _ in 0..n {
        let x = Tensor::randn(input_shape.clone(), &mut rng);
        match session.infer(&x) {
            Ok(t) => {
                lat.record(t.total_ms);
                if t.any_recovery {
                    recovered += 1;
                }
            }
            Err(_) => {
                lost += 1;
                session.drain();
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = lat.summary();
    println!("requests: {n}  lost: {lost}  recovered: {recovered}");
    println!("simulated latency: {}", s.line());
    println!(
        "harness wall-clock: {wall:.2}s ({:.1} req/s through real PJRT compute)",
        n as f64 / wall
    );
    Ok(())
}
