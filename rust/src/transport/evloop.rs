//! Single-thread event-loop I/O core for the TCP transport
//! (DESIGN.md §12).
//!
//! PR 5's transport spent two OS threads per worker (a blocking reader
//! plus the shared reaper's share of wakeups) — a coordinator cost that
//! grows linearly with fleet width, exactly the scaling failure the
//! paper's CDC argument is supposed to avoid. This module replaces all
//! of that with **one** thread owning every connection:
//!
//! * **Readiness, not blocking.** Sockets are nonblocking and
//!   multiplexed through hand-rolled FFI over `epoll` (Linux) or
//!   `kqueue` (macOS) — zero external crates, the same way
//!   [`super::wire`] hand-rolls its codec.
//! * **Write coalescing.** Coordinator threads never touch a socket:
//!   they encode frames into per-device queues and poke a wake pipe.
//!   Each loop iteration drains the queues and flushes every connection
//!   with a single `writev` sweep, so all frames queued in one dispatch
//!   round leave in one syscall batch instead of one `write_all` per
//!   frame.
//! * **Zero-copy decode.** Incoming bytes accumulate in one growable
//!   receive buffer per connection; frames are parsed **in place**
//!   ([`wire::decode_prefix_in`]) and Reply tensors are built in
//!   buffers taken from a shared [`Scratch`] arena, which the serve
//!   loop refills via `Transport::reclaim` — steady-state receive does
//!   no per-reply payload allocations.
//! * **Reaper as timeout.** The poll timeout is the time to the
//!   earliest outstanding deadline, so the straggler gate fires at the
//!   exact deadline with no dedicated reaper thread or polling tick.
//!
//! The PR-5 liveness invariants carry over unchanged: every dispatched
//! task yields exactly one completion (reply, reap, or connection
//! death), EOF reaps a dead worker's in-flight tasks at TCP speed, and
//! late replies for reaped tasks are dropped (their buffers recycled
//! into the arena).
//!
//! ## Live membership (DESIGN.md §13)
//!
//! The same thread also owns fleet membership. A nonblocking listener
//! (token [`LISTEN_TOKEN`]) accepts joining workers any time; an
//! accepted connection is *pending* until its `Register` frame
//! validates (magic, protocol version, compute capability), at which
//! point it gets a never-reused device slot, a `RegisterAck`, and a
//! [`MembershipEvent::Joined`] for the serve engine to re-partition
//! around. The poll timeout doubles a second time as the **heartbeat
//! tick**: every interval the loop pings each worker and advances a
//! suspicion ladder (healthy → suspect → dead) for workers with no
//! inbound traffic — any frame counts as proof of life, so a worker
//! busy streaming replies is never pinged into suspicion. `Leave`
//! starts a graceful drain: the serve engine stops dispatching and
//! [`Shared::retire`]s the slot, and the loop closes the connection
//! once its queues and in-flight orders are empty.

#[cfg(not(any(target_os = "linux", target_os = "macos")))]
compile_error!(
    "transport::evloop has poller backends for epoll (linux) and \
     kqueue (macos) only; add one for this platform"
);

use std::collections::{BTreeMap, VecDeque};
use std::ffi::{c_int, c_void};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::{AsRawFd, FromRawFd, OwnedFd, RawFd};
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::fleet::Completion;
use crate::kernels::Scratch;
use crate::tensor::Tensor;

use super::wire::{self, Frame};
use super::{MembershipEvent, TcpConfig};

/// Lock a mutex, recovering from poisoning (a panicked thread must not
/// cascade into the coordinator).
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------------
// raw syscall surface (libc-style FFI, zero external crates)
// ---------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod sys {
    use std::ffi::{c_int, c_void};

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;
    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;
    pub const EPOLL_CLOEXEC: c_int = 0o2000000;

    /// The kernel's `struct epoll_event`: packed on x86-64 (the kernel
    /// ABI), natural C layout on other architectures.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    /// `struct iovec` for scatter-gather writes.
    #[repr(C)]
    pub struct IoVec {
        pub base: *const c_void,
        pub len: usize,
    }

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            max: c_int,
            timeout: c_int,
        ) -> c_int;
        pub fn writev(fd: c_int, iov: *const IoVec, iovcnt: c_int) -> isize;
    }
}

#[cfg(target_os = "macos")]
mod sys {
    use std::ffi::{c_int, c_void};

    pub const EVFILT_READ: i16 = -1;
    pub const EVFILT_WRITE: i16 = -2;
    pub const EV_ADD: u16 = 0x0001;
    pub const EV_DELETE: u16 = 0x0002;
    pub const EV_EOF: u16 = 0x8000;
    pub const EV_ERROR: u16 = 0x4000;

    /// Darwin's `struct kevent`. Deliberately **not** shared with other
    /// BSDs: FreeBSD ≥ 12 appends `ext[4]`, a different ABI.
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct KEvent {
        pub ident: usize,
        pub filter: i16,
        pub flags: u16,
        pub fflags: u32,
        pub data: isize,
        pub udata: *mut c_void,
    }

    #[repr(C)]
    pub struct Timespec {
        pub tv_sec: i64,
        pub tv_nsec: i64,
    }

    /// `struct iovec` for scatter-gather writes.
    #[repr(C)]
    pub struct IoVec {
        pub base: *const c_void,
        pub len: usize,
    }

    extern "C" {
        pub fn kqueue() -> c_int;
        pub fn kevent(
            kq: c_int,
            changelist: *const KEvent,
            nchanges: c_int,
            eventlist: *mut KEvent,
            nevents: c_int,
            timeout: *const Timespec,
        ) -> c_int;
        pub fn writev(fd: c_int, iov: *const IoVec, iovcnt: c_int) -> isize;
    }
}

fn os_err(call: &str) -> Error {
    Error::Wire(format!("{call}: {}", std::io::Error::last_os_error()))
}

// ---------------------------------------------------------------------
// poller abstraction
// ---------------------------------------------------------------------

/// Per-fd readiness report from [`Poller::wait`].
pub(crate) struct PollEvent {
    /// The token the fd was registered with.
    pub token: u64,
    /// Bytes (or EOF) are waiting to be read.
    pub readable: bool,
    /// The socket accepts writes again.
    pub writable: bool,
    /// Error/EOF condition; treat like readable (the read reports it).
    pub hangup: bool,
}

/// Max events drained per wait call (the loop simply waits again when
/// more are pending — level-triggered registration keeps them ready).
const MAX_EVENTS: usize = 64;

/// Thin wrapper over the platform readiness syscall (epoll / kqueue).
pub(crate) struct Poller {
    fd: OwnedFd,
}

#[cfg(target_os = "linux")]
fn interest(want_write: bool) -> u32 {
    let mut ev = sys::EPOLLIN | sys::EPOLLRDHUP;
    if want_write {
        ev |= sys::EPOLLOUT;
    }
    ev
}

/// Round a duration *up* to whole milliseconds (epoll granularity): a
/// truncated timeout would wake just before a deadline and spin.
#[cfg(target_os = "linux")]
fn ceil_ms(d: Duration) -> c_int {
    let mut ms = d.as_millis();
    if Duration::from_millis(ms as u64) < d {
        ms += 1;
    }
    ms.min(i32::MAX as u128) as c_int
}

#[cfg(target_os = "linux")]
impl Poller {
    /// A fresh epoll instance (close-on-exec).
    pub fn new() -> Result<Poller> {
        let fd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(os_err("epoll_create1"));
        }
        Ok(Poller { fd: unsafe { OwnedFd::from_raw_fd(fd) } })
    }

    fn ctl(&self, op: c_int, fd: RawFd, token: u64, want_write: bool) -> Result<()> {
        let mut ev = sys::EpollEvent { events: interest(want_write), data: token };
        let rc = unsafe { sys::epoll_ctl(self.fd.as_raw_fd(), op, fd, &mut ev) };
        if rc < 0 {
            return Err(os_err("epoll_ctl"));
        }
        Ok(())
    }

    /// Register an fd for readiness events under `token`.
    pub fn add(&self, fd: RawFd, token: u64, want_write: bool) -> Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, token, want_write)
    }

    /// Toggle write interest on a registered fd.
    pub fn rearm(&self, fd: RawFd, token: u64, want_write: bool) -> Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, token, want_write)
    }

    /// Deregister an fd (best-effort; closing the fd removes it too).
    pub fn del(&self, fd: RawFd) {
        // The event argument is ignored for DEL but must be non-null on
        // pre-2.6.9 kernels.
        let mut ev = sys::EpollEvent { events: 0, data: 0 };
        let _ = unsafe { sys::epoll_ctl(self.fd.as_raw_fd(), sys::EPOLL_CTL_DEL, fd, &mut ev) };
    }

    /// Block for readiness, at most `timeout` (`None` = forever).
    /// EINTR surfaces as an empty event set.
    pub fn wait(&self, out: &mut Vec<PollEvent>, timeout: Option<Duration>) -> Result<()> {
        out.clear();
        let mut buf = [sys::EpollEvent { events: 0, data: 0 }; MAX_EVENTS];
        let tmo = match timeout {
            None => -1,
            Some(d) => ceil_ms(d),
        };
        let n = unsafe {
            sys::epoll_wait(self.fd.as_raw_fd(), buf.as_mut_ptr(), MAX_EVENTS as c_int, tmo)
        };
        if n < 0 {
            if std::io::Error::last_os_error().kind() == ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(os_err("epoll_wait"));
        }
        for ev in buf.iter().take(n as usize) {
            // Copy packed fields out by value; no references into them.
            let events = ev.events;
            let token = ev.data;
            out.push(PollEvent {
                token,
                readable: events & sys::EPOLLIN != 0,
                writable: events & sys::EPOLLOUT != 0,
                hangup: events & (sys::EPOLLERR | sys::EPOLLHUP | sys::EPOLLRDHUP) != 0,
            });
        }
        Ok(())
    }
}

#[cfg(target_os = "macos")]
impl Poller {
    /// A fresh kqueue instance.
    pub fn new() -> Result<Poller> {
        let fd = unsafe { sys::kqueue() };
        if fd < 0 {
            return Err(os_err("kqueue"));
        }
        Ok(Poller { fd: unsafe { OwnedFd::from_raw_fd(fd) } })
    }

    fn change(&self, fd: RawFd, filter: i16, flags: u16, token: u64) -> c_int {
        let ch = sys::KEvent {
            ident: fd as usize,
            filter,
            flags,
            fflags: 0,
            data: 0,
            udata: token as usize as *mut c_void,
        };
        unsafe {
            sys::kevent(self.fd.as_raw_fd(), &ch, 1, std::ptr::null_mut(), 0, std::ptr::null())
        }
    }

    /// Register an fd for readiness events under `token`.
    pub fn add(&self, fd: RawFd, token: u64, want_write: bool) -> Result<()> {
        if self.change(fd, sys::EVFILT_READ, sys::EV_ADD, token) < 0 {
            return Err(os_err("kevent add read"));
        }
        if want_write && self.change(fd, sys::EVFILT_WRITE, sys::EV_ADD, token) < 0 {
            return Err(os_err("kevent add write"));
        }
        Ok(())
    }

    /// Toggle write interest on a registered fd. `EV_ADD` on an
    /// existing filter updates it; deleting an absent write filter is
    /// an expected no-op error.
    pub fn rearm(&self, fd: RawFd, token: u64, want_write: bool) -> Result<()> {
        if want_write {
            if self.change(fd, sys::EVFILT_WRITE, sys::EV_ADD, token) < 0 {
                return Err(os_err("kevent add write"));
            }
        } else {
            let _ = self.change(fd, sys::EVFILT_WRITE, sys::EV_DELETE, token);
        }
        Ok(())
    }

    /// Deregister an fd (best-effort; closing the fd removes it too).
    pub fn del(&self, fd: RawFd) {
        let _ = self.change(fd, sys::EVFILT_READ, sys::EV_DELETE, 0);
        let _ = self.change(fd, sys::EVFILT_WRITE, sys::EV_DELETE, 0);
    }

    /// Block for readiness, at most `timeout` (`None` = forever).
    /// EINTR surfaces as an empty event set.
    pub fn wait(&self, out: &mut Vec<PollEvent>, timeout: Option<Duration>) -> Result<()> {
        out.clear();
        let zero = sys::KEvent {
            ident: 0,
            filter: 0,
            flags: 0,
            fflags: 0,
            data: 0,
            udata: std::ptr::null_mut(),
        };
        let mut buf = [zero; MAX_EVENTS];
        let ts;
        let ts_ptr = match timeout {
            None => std::ptr::null(),
            Some(d) => {
                ts = sys::Timespec {
                    tv_sec: d.as_secs() as i64,
                    tv_nsec: d.subsec_nanos() as i64,
                };
                &ts as *const sys::Timespec
            }
        };
        let n = unsafe {
            sys::kevent(
                self.fd.as_raw_fd(),
                std::ptr::null(),
                0,
                buf.as_mut_ptr(),
                MAX_EVENTS as c_int,
                ts_ptr,
            )
        };
        if n < 0 {
            if std::io::Error::last_os_error().kind() == ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(os_err("kevent wait"));
        }
        for ev in buf.iter().take(n as usize) {
            out.push(PollEvent {
                token: ev.udata as usize as u64,
                readable: ev.filter == sys::EVFILT_READ,
                writable: ev.filter == sys::EVFILT_WRITE,
                hangup: ev.flags & (sys::EV_EOF | sys::EV_ERROR) != 0,
            });
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// shared coordinator-side state
// ---------------------------------------------------------------------

/// One dispatched, not-yet-answered task.
pub(crate) struct OutTask {
    /// Device the task was dispatched to.
    pub device: usize,
    /// Wall-clock deadline after which the task is reaped as lost.
    pub deadline_ms: f64,
}

/// Liveness + in-flight bookkeeping.
pub(crate) struct State {
    /// Per-slot liveness (false once the connection died).
    pub alive: Vec<bool>,
    /// Per-slot drain flags: a retired device gets no new dispatches
    /// and its connection closes once its in-flight work finishes.
    pub retired: Vec<bool>,
    /// (req, task) → in-flight bookkeeping.
    pub outstanding: BTreeMap<(u64, u64), OutTask>,
}

/// Device slots reserved for live joins beyond the initial fleet. Slots
/// are never reused, so this also caps joins per transport lifetime —
/// a full house closes new connections at accept.
pub(crate) const JOIN_SLOTS: usize = 16;

/// Event-loop I/O and membership counters (DESIGN.md §16). All relaxed
/// `AtomicU64`s: the loop thread is the only writer, scrapers read a
/// monotonic snapshot, and no counter orders any other memory.
#[derive(Default)]
pub(crate) struct NetCounters {
    /// Payload bytes accepted by `writev` (all connections).
    pub bytes_tx: AtomicU64,
    /// Bytes pulled off sockets by the read loop.
    pub bytes_rx: AtomicU64,
    /// Whole frames fully flushed to the wire.
    pub frames_tx: AtomicU64,
    /// Whole frames decoded from the wire.
    pub frames_rx: AtomicU64,
    /// `writev` calls that moved bytes (coalescing denominator: frames
    /// per call is the batching win).
    pub writev_calls: AtomicU64,
    /// Tasks reaped past their straggler deadline.
    pub reaped_tasks: AtomicU64,
    /// Heartbeat pings queued to workers.
    pub heartbeats_sent: AtomicU64,
    /// Joiners admitted through `Register`.
    pub joins: AtomicU64,
    /// Registered connections declared dead.
    pub deaths: AtomicU64,
    /// Suspect transitions on the heartbeat ladder.
    pub suspects: AtomicU64,
    /// Graceful `Leave` requests received.
    pub leaves: AtomicU64,
}

impl NetCounters {
    /// Bump a counter (relaxed; see the struct docs).
    fn inc(field: &AtomicU64, by: u64) {
        field.fetch_add(by, Ordering::Relaxed);
    }
}

/// Everything the event loop shares with the coordinator-side handles.
pub(crate) struct Shared {
    /// Wall-clock zero of the current serve run.
    pub epoch: Mutex<Instant>,
    /// Liveness and the outstanding-task table.
    pub state: Mutex<State>,
    /// Per-slot egress queues: handles enqueue encoded frames here;
    /// the loop drains them into per-connection `writev` batches.
    /// Sized for the initial fleet plus [`JOIN_SLOTS`] headroom.
    pub outq: Vec<Mutex<VecDeque<Vec<u8>>>>,
    /// Decode arena: Reply tensors are parsed straight into pooled
    /// buffers; `Transport::reclaim` feeds consumed outputs back.
    pub arena: Mutex<Scratch>,
    /// Completion stream consumed by `Transport::recv`.
    pub tx: Sender<Completion>,
    /// Tells the loop to flush and exit.
    pub stop: AtomicBool,
    /// Session seed echoed in `RegisterAck` so a joiner's drop-emulation
    /// RNG matches the fleet's.
    pub seed: u64,
    /// Heartbeat interval in ms (`<= 0` disables health probing).
    pub heartbeat_ms: f64,
    /// Silent intervals before a worker turns [`MembershipEvent::Suspect`].
    pub suspect_after: u32,
    /// Silent intervals before a worker is declared dead.
    pub dead_after: u32,
    /// Event-loop I/O and membership counters, read by
    /// `Transport::counters` for the telemetry registry.
    pub net: NetCounters,
    /// Latest cumulative worker-counter snapshot per device slot, as
    /// piggybacked on proto ≥ 4 `HeartbeatAck`s (indexed by
    /// [`wire::WCTR_ORDERS`]-style ids). v3 workers leave zeros.
    pub worker_counters: Mutex<Vec<[u64; wire::WCTR_SLOTS]>>,
    /// Device slots assigned so far (initial fleet + admitted joiners).
    /// Written only by the event loop; read by `Transport::n_devices`.
    width: AtomicUsize,
    /// Membership changes queued for `Transport::poll_membership`.
    events: Mutex<Vec<MembershipEvent>>,
    /// Write half of the wake pipe (the loop polls the read half).
    waker: UnixStream,
}

impl Shared {
    /// Fresh shared state for `n_devices` live connections plus
    /// [`JOIN_SLOTS`] of join headroom, configured from `cfg`.
    pub fn new(
        n_devices: usize,
        seed: u64,
        cfg: &TcpConfig,
        tx: Sender<Completion>,
        waker: UnixStream,
    ) -> Shared {
        let capacity = n_devices + JOIN_SLOTS;
        Shared {
            epoch: Mutex::new(Instant::now()),
            state: Mutex::new(State {
                alive: vec![true; capacity],
                retired: vec![false; capacity],
                outstanding: BTreeMap::new(),
            }),
            outq: (0..capacity).map(|_| Mutex::new(VecDeque::new())).collect(),
            arena: Mutex::new(Scratch::new()),
            tx,
            stop: AtomicBool::new(false),
            seed,
            heartbeat_ms: cfg.heartbeat_ms,
            suspect_after: cfg.suspect_after_missed.max(1),
            dead_after: cfg.dead_after_missed.max(2),
            net: NetCounters::default(),
            worker_counters: Mutex::new(vec![[0; wire::WCTR_SLOTS]; capacity]),
            width: AtomicUsize::new(n_devices),
            events: Mutex::new(Vec::new()),
            waker,
        }
    }

    /// Device slots assigned so far (= the addressable device range).
    pub fn width(&self) -> usize {
        self.width.load(Ordering::SeqCst)
    }

    /// Claim the next never-used device slot for a joiner (`None` when
    /// the join headroom is exhausted). Event-loop thread only.
    fn alloc_slot(&self) -> Option<usize> {
        let w = self.width.load(Ordering::SeqCst);
        if w >= self.outq.len() {
            return None;
        }
        self.width.store(w + 1, Ordering::SeqCst);
        Some(w)
    }

    /// Queue a membership event for the serve engine.
    pub fn push_event(&self, ev: MembershipEvent) {
        lock(&self.events).push(ev);
    }

    /// Drain queued membership events (`Transport::poll_membership`).
    pub fn take_events(&self) -> Vec<MembershipEvent> {
        std::mem::take(&mut *lock(&self.events))
    }

    /// Flag a slot for graceful drain and nudge the loop so it can
    /// close the connection once the slot's work is finished.
    pub fn retire(&self, device: usize) {
        {
            let mut st = lock(&self.state);
            if device < st.retired.len() {
                st.retired[device] = true;
            }
        }
        self.wake();
    }

    /// Milliseconds since the serve epoch.
    pub fn now_ms(&self) -> f64 {
        lock(&self.epoch).elapsed().as_secs_f64() * 1e3
    }

    /// Queue an encoded frame for a device and wake the loop; the next
    /// flush coalesces it with every neighbour queued meanwhile.
    pub fn enqueue(&self, device: usize, frame: Vec<u8>) {
        lock(&self.outq[device]).push_back(frame);
        self.wake();
    }

    /// Wake the event loop. Nonblocking: a full pipe already guarantees
    /// a pending wake, so `WouldBlock` is success.
    pub fn wake(&self) {
        let _ = (&self.waker).write(&[1u8]);
    }

    /// Synthesise a lost completion (the wire twin of the simulator's
    /// `t_arrival = ∞` delivery).
    pub fn send_lost(&self, req: u64, task: u64, device: usize) {
        let _ = self.tx.send(Completion {
            req,
            task,
            device,
            result: None,
            t_arrival_ms: f64::INFINITY,
        });
    }

    /// Mark a device's connection dead: drop its queued frames and
    /// synthesise losses for everything outstanding on it. Idempotent;
    /// returns whether this call did the alive→dead transition (the
    /// caller decides if that deserves a [`MembershipEvent::Dead`]).
    pub fn mark_dead(&self, device: usize) -> bool {
        lock(&self.outq[device]).clear();
        let mut st = lock(&self.state);
        if !st.alive[device] {
            return false;
        }
        st.alive[device] = false;
        let dead: Vec<(u64, u64)> = st
            .outstanding
            .iter()
            .filter(|(_, o)| o.device == device)
            .map(|(&k, _)| k)
            .collect();
        for (req, task) in dead {
            st.outstanding.remove(&(req, task));
            self.send_lost(req, task, device);
        }
        true
    }
}

// ---------------------------------------------------------------------
// the event loop
// ---------------------------------------------------------------------

/// Receive-buffer growth step (also the spare-room floor per read).
const READ_CHUNK: usize = 64 * 1024;

/// Max frames batched into one `writev` call.
const MAX_IOV: usize = 64;

/// Poll-wait cap when no deadline is pending: bounds stop-flag latency
/// without a polling reaper thread.
const IDLE_TICK: Duration = Duration::from_millis(500);

/// Poller token of the wake pipe (devices use their slot index).
const WAKE_TOKEN: u64 = u64::MAX;

/// Poller token of the join listener.
const LISTEN_TOKEN: u64 = u64::MAX - 1;

/// Per-connection nonblocking I/O state machine.
struct Conn {
    stream: TcpStream,
    /// Receive window: undecoded bytes live in `rbuf[rstart..rend]`;
    /// frames are parsed in place and the window advances.
    rbuf: Vec<u8>,
    rstart: usize,
    rend: usize,
    /// Encoded frames awaiting flush, oldest first.
    wq: VecDeque<Vec<u8>>,
    /// Bytes of `wq[0]` already written (partial `writev`).
    woff: usize,
    /// Whether the poller currently watches writability.
    want_write: bool,
    /// False between accept and a valid `Register` frame: a pending
    /// joiner may speak nothing but `Register`.
    registered: bool,
    /// Inbound traffic observed since the last heartbeat tick — any
    /// frame is proof of life, not just `HeartbeatAck`.
    seen: bool,
    /// Consecutive heartbeat intervals with no inbound traffic.
    missed: u32,
    /// Whether a `Suspect` event is currently in force for this slot.
    suspect: bool,
}

impl Conn {
    fn new(stream: TcpStream, registered: bool) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            rstart: 0,
            rend: 0,
            wq: VecDeque::new(),
            woff: 0,
            want_write: false,
            registered,
            seen: false,
            missed: 0,
            suspect: false,
        }
    }
}

/// Start the event loop over connected, handshaken worker streams
/// (device order) plus an optional nonblocking join listener.
/// Registration failures surface here, before any thread exists.
pub(crate) fn spawn(
    streams: Vec<TcpStream>,
    shared: Arc<Shared>,
    wake_rx: UnixStream,
    listener: Option<TcpListener>,
) -> Result<JoinHandle<()>> {
    let poller = Poller::new()?;
    wake_rx
        .set_nonblocking(true)
        .map_err(|e| Error::Wire(format!("wake pipe: {e}")))?;
    poller.add(wake_rx.as_raw_fd(), WAKE_TOKEN, false)?;
    if let Some(l) = &listener {
        l.set_nonblocking(true)
            .map_err(|e| Error::Wire(format!("join listener: set_nonblocking: {e}")))?;
        poller.add(l.as_raw_fd(), LISTEN_TOKEN, false)?;
    }
    let capacity = shared.outq.len();
    let mut conns: Vec<Option<Conn>> = Vec::with_capacity(capacity);
    for (device, s) in streams.into_iter().enumerate() {
        s.set_nonblocking(true)
            .map_err(|e| Error::Wire(format!("device {device}: set_nonblocking: {e}")))?;
        poller.add(s.as_raw_fd(), device as u64, false)?;
        conns.push(Some(Conn::new(s, true)));
    }
    conns.resize_with(capacity, || None);
    std::thread::Builder::new()
        .name("tcp-evloop".into())
        .spawn(move || loop_main(poller, conns, shared, wake_rx, listener))
        .map_err(|e| Error::Fleet(format!("spawn tcp-evloop: {e}")))
}

fn loop_main(
    poller: Poller,
    mut conns: Vec<Option<Conn>>,
    shared: Arc<Shared>,
    wake_rx: UnixStream,
    listener: Option<TcpListener>,
) {
    let mut events: Vec<PollEvent> = Vec::with_capacity(MAX_EVENTS);
    let hb = shared.heartbeat_ms;
    let mut next_beat = if hb > 0.0 { shared.now_ms() + hb } else { f64::INFINITY };
    loop {
        // 1. Adopt frames queued by coordinator threads since the last
        //    round.
        for device in 0..conns.len() {
            let mut q = lock(&shared.outq[device]);
            if q.is_empty() {
                continue;
            }
            match conns[device].as_mut() {
                Some(c) => c.wq.extend(q.drain(..)),
                None => q.clear(), // dead device: losses already synthesised
            }
        }
        // 2. Heartbeat tick: ping live workers and advance the
        //    suspicion ladder for the silent ones. Runs before the
        //    flush so this tick's pings leave in the same writev sweep.
        let now = shared.now_ms();
        // `begin_serve` rewinds the epoch; never let the schedule point
        // more than one interval past the (possibly reset) clock.
        if next_beat > now + hb {
            next_beat = now + hb;
        }
        if now >= next_beat {
            heartbeat_tick(&poller, &mut conns, &shared);
            next_beat = now + hb;
        }
        // 3. Coalesced flush: one writev sweep per connection sends
        //    everything queued in this round together.
        for device in 0..conns.len() {
            flush_conn(&poller, &mut conns, device, &shared);
        }
        // 4. Close retired (drained-out) connections whose queues and
        //    in-flight orders are empty — the graceful half of Leave.
        close_drained(&poller, &mut conns, &shared);
        // 5. The reaper, folded in: reap overdue tasks and learn when
        //    the next deadline falls due.
        let next_deadline = reap(&shared);
        if shared.stop.load(Ordering::SeqCst) {
            teardown(&mut conns);
            return;
        }
        // 6. Sleep until readiness, a wake byte, the next deadline, or
        //    the next heartbeat tick.
        let due = next_deadline.unwrap_or(f64::INFINITY).min(next_beat);
        let timeout = if due.is_finite() {
            let ms = (due - shared.now_ms()).max(0.0);
            Duration::from_secs_f64(ms / 1e3).min(IDLE_TICK)
        } else {
            IDLE_TICK
        };
        if poller.wait(&mut events, Some(timeout)).is_err() {
            // A broken poller can't observe anything anymore: declare
            // the fleet dead so in-flight work resolves as losses
            // instead of hanging the serve loop, then exit.
            for device in 0..conns.len() {
                kill_conn(&poller, &mut conns, device, &shared);
            }
            return;
        }
        // 7. Service readiness.
        for ev in &events {
            if ev.token == WAKE_TOKEN {
                drain_wake(&wake_rx);
                continue;
            }
            if ev.token == LISTEN_TOKEN {
                if let Some(l) = &listener {
                    accept_ready(l, &poller, &mut conns, &shared);
                }
                continue;
            }
            let device = ev.token as usize;
            if device >= conns.len() {
                continue;
            }
            if ev.readable || ev.hangup {
                let alive = match conns[device].as_mut() {
                    Some(c) => read_ready(c, device, &shared),
                    None => continue,
                };
                if !alive {
                    kill_conn(&poller, &mut conns, device, &shared);
                    continue;
                }
            }
            if ev.writable {
                flush_conn(&poller, &mut conns, device, &shared);
            }
        }
    }
}

/// Accept every waiting joiner: each gets a never-reused device slot
/// and sits *pending* until its `Register` frame validates. A full
/// house (join headroom exhausted) closes the connection immediately.
fn accept_ready(
    listener: &TcpListener,
    poller: &Poller,
    conns: &mut [Option<Conn>],
    shared: &Shared,
) {
    loop {
        let stream = match listener.accept() {
            Ok((s, _peer)) => s,
            Err(e) if e.kind() == ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return,
        };
        let Some(slot) = shared.alloc_slot() else {
            // No slots left: refuse by closing (the worker sees EOF
            // where it expected RegisterAck).
            drop(stream);
            continue;
        };
        if stream.set_nodelay(true).is_err() || stream.set_nonblocking(true).is_err() {
            drop(stream);
            continue;
        }
        if poller.add(stream.as_raw_fd(), slot as u64, false).is_err() {
            drop(stream);
            continue;
        }
        conns[slot] = Some(Conn::new(stream, false));
    }
}

/// One heartbeat interval: reset the ladder for every slot that spoke
/// since the last tick, advance it for the silent ones (suspect →
/// dead), and queue a ping to everyone still live.
fn heartbeat_tick(poller: &Poller, conns: &mut Vec<Option<Conn>>, shared: &Shared) {
    let mut nonce = 0u64;
    let mut dead: Vec<usize> = Vec::new();
    for (device, slot) in conns.iter_mut().enumerate() {
        let Some(c) = slot.as_mut() else { continue };
        if c.seen {
            c.seen = false;
            c.missed = 0;
            if c.suspect {
                c.suspect = false;
                shared.push_event(MembershipEvent::Recovered { device });
            }
        } else {
            c.missed += 1;
            if c.missed >= shared.dead_after {
                // A pending joiner that never registered just goes
                // away; a registered worker's death is announced by
                // kill_conn below.
                dead.push(device);
                continue;
            }
            if c.missed >= shared.suspect_after && !c.suspect && c.registered {
                c.suspect = true;
                NetCounters::inc(&shared.net.suspects, 1);
                shared.push_event(MembershipEvent::Suspect { device, missed: c.missed });
            }
        }
        if c.registered {
            nonce = nonce.wrapping_add(1);
            NetCounters::inc(&shared.net.heartbeats_sent, 1);
            c.wq.push_back(wire::heartbeat(nonce));
        }
    }
    for device in dead {
        kill_conn(poller, conns, device, shared);
    }
}

/// Close retired connections whose work has fully drained: nothing
/// queued coordinator-side, nothing unflushed, nothing outstanding.
/// The quiet close deliberately emits no `Dead` event — the serve
/// engine already re-partitioned when it retired the slot.
fn close_drained(poller: &Poller, conns: &mut [Option<Conn>], shared: &Shared) {
    let closable: Vec<usize> = {
        let st = lock(&shared.state);
        (0..conns.len())
            .filter(|&d| {
                st.retired[d]
                    && st.alive[d]
                    && conns[d].is_some()
                    && !st.outstanding.values().any(|o| o.device == d)
            })
            .collect()
    };
    for device in closable {
        if !lock(&shared.outq[device]).is_empty() {
            continue;
        }
        let drained = conns[device].as_ref().is_some_and(|c| c.wq.is_empty());
        if !drained {
            continue;
        }
        if let Some(c) = conns[device].take() {
            poller.del(c.stream.as_raw_fd());
            let _ = c.stream.shutdown(std::net::Shutdown::Both);
        }
        lock(&shared.state).alive[device] = false;
    }
}

/// Final best-effort flush, then socket shutdown. Workers are NOT told
/// to exit — they return to their accept loop for the next session.
fn teardown(conns: &mut [Option<Conn>]) {
    for slot in conns.iter_mut() {
        if let Some(mut c) = slot.take() {
            let _ = c.stream.set_nonblocking(false);
            let _ = c.stream.set_write_timeout(Some(Duration::from_millis(250)));
            while let Some(f) = c.wq.pop_front() {
                if c.stream.write_all(&f[c.woff..]).is_err() {
                    break;
                }
                c.woff = 0;
            }
            let _ = c.stream.flush();
            let _ = c.stream.shutdown(std::net::Shutdown::Both);
        }
    }
}

/// Drop a connection: deregister, shut the socket down, mark the
/// device dead (synthesising losses for its in-flight tasks), and —
/// for a worker that had completed registration — queue a
/// [`MembershipEvent::Dead`] so the serve engine re-partitions.
fn kill_conn(poller: &Poller, conns: &mut [Option<Conn>], device: usize, shared: &Shared) {
    let registered = match conns[device].take() {
        Some(c) => {
            poller.del(c.stream.as_raw_fd());
            let _ = c.stream.shutdown(std::net::Shutdown::Both);
            c.registered
        }
        // Slot already closed locally: if it is still marked alive the
        // death happened outside the loop — treat as registered.
        None => true,
    };
    if shared.mark_dead(device) && registered {
        NetCounters::inc(&shared.net.deaths, 1);
        shared.push_event(MembershipEvent::Dead { device });
    }
}

/// Write as much queued data as the socket accepts, then keep the
/// poller's write interest exactly while bytes remain.
fn flush_conn(poller: &Poller, conns: &mut [Option<Conn>], device: usize, shared: &Shared) {
    let (res, fd, was) = match conns[device].as_mut() {
        None => return,
        Some(c) => (write_queued(c, &shared.net), c.stream.as_raw_fd(), c.want_write),
    };
    let pending = match res {
        Err(()) => {
            kill_conn(poller, conns, device, shared);
            return;
        }
        Ok(p) => p,
    };
    if pending != was {
        if let Some(c) = conns[device].as_mut() {
            c.want_write = pending;
        }
        if poller.rearm(fd, device as u64, pending).is_err() {
            kill_conn(poller, conns, device, shared);
        }
    }
}

/// Drain `c.wq` into the socket, batching up to [`MAX_IOV`] frames per
/// `writev` call. `Ok(true)` = socket full, bytes remain; `Ok(false)` =
/// queue drained; `Err` = connection dead.
fn write_queued(c: &mut Conn, net: &NetCounters) -> std::result::Result<bool, ()> {
    loop {
        if c.wq.is_empty() {
            return Ok(false);
        }
        let mut iov: Vec<sys::IoVec> = Vec::with_capacity(c.wq.len().min(MAX_IOV));
        for (i, f) in c.wq.iter().take(MAX_IOV).enumerate() {
            let off = if i == 0 { c.woff } else { 0 };
            iov.push(sys::IoVec {
                base: f[off..].as_ptr() as *const c_void,
                len: f.len() - off,
            });
        }
        let n = unsafe { sys::writev(c.stream.as_raw_fd(), iov.as_ptr(), iov.len() as c_int) };
        if n < 0 {
            match std::io::Error::last_os_error().kind() {
                ErrorKind::WouldBlock => return Ok(true),
                ErrorKind::Interrupted => continue,
                _ => return Err(()),
            }
        }
        NetCounters::inc(&net.writev_calls, 1);
        NetCounters::inc(&net.bytes_tx, n as u64);
        let mut n = n as usize;
        while n > 0 {
            let left = c.wq[0].len() - c.woff;
            if n >= left {
                c.wq.pop_front();
                c.woff = 0;
                n -= left;
                NetCounters::inc(&net.frames_tx, 1);
            } else {
                c.woff += n;
                n = 0;
            }
        }
    }
}

/// Pull everything the socket has, parsing complete frames in place.
/// Returns false when the connection is finished (EOF, error, protocol
/// violation, or malformed frame).
fn read_ready(c: &mut Conn, device: usize, shared: &Shared) -> bool {
    loop {
        let need = match parse_frames(c, device, shared) {
            Err(()) => return false,
            Ok(n) => n,
        };
        ensure_room(c, need);
        match c.stream.read(&mut c.rbuf[c.rend..]) {
            Ok(0) => return false,
            Ok(n) => {
                c.rend += n;
                NetCounters::inc(&shared.net.bytes_rx, n as u64);
                // Any inbound bytes are proof of life for the
                // heartbeat ladder — a worker busy streaming replies
                // never needs to answer pings to stay healthy.
                c.seen = true;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return false,
        }
    }
}

/// Guarantee spare capacity after `rend`: compact the window to the
/// front, then grow so the in-progress frame (`need` bytes) plus a
/// read chunk fit.
fn ensure_room(c: &mut Conn, need: usize) {
    if c.rstart > 0 {
        c.rbuf.copy_within(c.rstart..c.rend, 0);
        c.rend -= c.rstart;
        c.rstart = 0;
    }
    let want = need.max(c.rend + READ_CHUNK);
    if c.rbuf.len() < want {
        c.rbuf.resize(want, 0);
    }
}

/// Decode every complete frame in the receive window (zero copy: the
/// payload is parsed where it landed, Reply tensors go straight into
/// the arena). Returns the total length of the frame the stream is
/// mid-way through — the `ensure_room` hint.
fn parse_frames(c: &mut Conn, device: usize, shared: &Shared) -> std::result::Result<usize, ()> {
    loop {
        let parsed = {
            let avail = &c.rbuf[c.rstart..c.rend];
            let mut arena = lock(&shared.arena);
            wire::decode_prefix_in(avail, &mut arena)
        };
        let (frame, used) = match parsed {
            Ok(Some(p)) => p,
            Ok(None) => {
                let need = match wire::frame_len(&c.rbuf[c.rstart..c.rend]) {
                    Ok(Some(n)) => n,
                    _ => 5,
                };
                return Ok(need);
            }
            Err(_) => return Err(()),
        };
        c.rstart += used;
        if c.rstart == c.rend {
            c.rstart = 0;
            c.rend = 0;
        }
        NetCounters::inc(&shared.net.frames_rx, 1);
        match frame {
            Frame::Reply { req, task, result } if c.registered => {
                deliver(shared, device, req, task, result)
            }
            // Proof of life (`c.seen` was already set by the read) —
            // plus, from proto ≥ 4 workers, the piggybacked cumulative
            // counter snapshot for this device slot.
            Frame::HeartbeatAck { counters, .. } if c.registered => {
                if !counters.is_empty() {
                    let mut table = lock(&shared.worker_counters);
                    if let Some(slot) = table.get_mut(device) {
                        for (id, value) in counters {
                            // Unknown ids are skipped: workers can grow
                            // the set without a proto bump.
                            if let Some(cell) = slot.get_mut(id as usize) {
                                *cell = value;
                            }
                        }
                    }
                }
            }
            // Graceful drain: the serve engine stops dispatching,
            // re-partitions, then retires the slot; the loop closes it
            // once the in-flight work drains (`close_drained`).
            Frame::Leave if c.registered => {
                NetCounters::inc(&shared.net.leaves, 1);
                shared.push_event(MembershipEvent::LeaveRequested { device });
            }
            // A pending joiner's one legal first frame. Valid magic is
            // checked at decode; here the protocol version and compute
            // capability gate admission.
            Frame::Register { proto, macs_per_ms, capabilities } if !c.registered => {
                if !wire::proto_compatible(proto) {
                    let err = wire::proto_mismatch("joining worker", "coordinator", proto);
                    eprintln!("coordinator: rejecting join: {err}");
                    return Err(());
                }
                if capabilities & wire::CAP_COMPUTE == 0 {
                    eprintln!(
                        "coordinator: rejecting join at device {device}: worker \
                         announces no compute capability (caps {capabilities:#x})"
                    );
                    return Err(());
                }
                c.registered = true;
                NetCounters::inc(&shared.net.joins, 1);
                c.wq.push_back(wire::register_ack(device as u32, shared.seed));
                shared.push_event(MembershipEvent::Joined { device, macs_per_ms });
            }
            // Anything else — a second Register, or any verb before
            // registration — is a protocol violation.
            _ => return Err(()),
        }
    }
}

/// Route one Reply to the completion channel — or drop it (recycling
/// its buffer) when the task was already reaped.
fn deliver(shared: &Shared, device: usize, req: u64, task: u64, result: Option<Tensor>) {
    let now = shared.now_ms();
    let known = lock(&shared.state).outstanding.remove(&(req, task)).is_some();
    if !known {
        // Late reply after a reap: the loss was already delivered, and
        // a second completion would break exactly-once accounting.
        if let Some(t) = result {
            lock(&shared.arena).put(t.into_data());
        }
        return;
    }
    let t_arrival_ms = if result.is_none() { f64::INFINITY } else { now };
    let _ = shared.tx.send(Completion { req, task, device, result, t_arrival_ms });
}

/// Swallow pending wake bytes (their only job was ending the wait).
fn drain_wake(mut wake_rx: &UnixStream) {
    let mut buf = [0u8; 64];
    while matches!(wake_rx.read(&mut buf), Ok(n) if n > 0) {}
}

/// Synthesise losses for tasks past their wall-clock deadline and
/// report the earliest remaining deadline (the poll-timeout source).
fn reap(shared: &Shared) -> Option<f64> {
    let now = shared.now_ms();
    let mut next = None;
    let expired: Vec<(u64, u64, usize)> = {
        let mut st = lock(&shared.state);
        let keys: Vec<(u64, u64, usize)> = st
            .outstanding
            .iter()
            .filter(|(_, o)| o.deadline_ms <= now)
            .map(|(&(req, task), o)| (req, task, o.device))
            .collect();
        for &(req, task, _) in &keys {
            st.outstanding.remove(&(req, task));
        }
        for o in st.outstanding.values() {
            next = Some(o.deadline_ms.min(next.unwrap_or(f64::INFINITY)));
        }
        keys
    };
    if !expired.is_empty() {
        NetCounters::inc(&shared.net.reaped_tasks, expired.len() as u64);
    }
    for (req, task, device) in expired {
        shared.send_lost(req, task, device);
    }
    next
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poller_sees_readiness_and_timeouts() {
        let poller = Poller::new().unwrap();
        let (a, b) = UnixStream::pair().unwrap();
        a.set_nonblocking(true).unwrap();
        b.set_nonblocking(true).unwrap();
        poller.add(b.as_raw_fd(), 7, false).unwrap();
        let mut events = Vec::new();
        // Nothing to read yet: the wait times out empty.
        poller.wait(&mut events, Some(Duration::from_millis(1))).unwrap();
        assert!(events.is_empty());
        (&a).write_all(&[9u8]).unwrap();
        poller.wait(&mut events, Some(Duration::from_millis(1_000))).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 7);
        assert!(events[0].readable);
        // Write interest: an idle socket is immediately writable.
        poller.rearm(b.as_raw_fd(), 7, true).unwrap();
        poller.wait(&mut events, Some(Duration::from_millis(1_000))).unwrap();
        assert!(events.iter().any(|e| e.writable));
        poller.del(b.as_raw_fd());
    }

    #[test]
    fn peer_close_surfaces_as_hangup_or_readable() {
        let poller = Poller::new().unwrap();
        let (a, b) = UnixStream::pair().unwrap();
        b.set_nonblocking(true).unwrap();
        poller.add(b.as_raw_fd(), 1, false).unwrap();
        drop(a);
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(1_000))).unwrap();
        assert!(events.iter().any(|e| e.hangup || e.readable));
    }

    #[test]
    fn writev_writes_across_iovecs() {
        let (a, b) = UnixStream::pair().unwrap();
        let bufs = [vec![1u8, 2], vec![3u8, 4, 5]];
        let iov: Vec<sys::IoVec> = bufs
            .iter()
            .map(|v| sys::IoVec { base: v.as_ptr() as *const c_void, len: v.len() })
            .collect();
        let n = unsafe { sys::writev(a.as_raw_fd(), iov.as_ptr(), iov.len() as c_int) };
        assert_eq!(n, 5);
        let mut got = [0u8; 5];
        (&b).read_exact(&mut got).unwrap();
        assert_eq!(got, [1, 2, 3, 4, 5]);
    }
}
