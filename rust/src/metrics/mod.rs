//! Streaming latency metrics: histograms, percentiles, ASCII rendering.
//!
//! Every experiment in the paper reports arrival-time / end-to-end latency
//! *distributions* (Figs. 1, 12, 14, 15), so the harness keeps full sample
//! vectors (experiments are small enough) plus log-bucketed histograms for
//! rendering, and a `Summary` with the standard percentiles.

use std::fmt::Write as _;

/// A collected latency series (milliseconds).
#[derive(Debug, Clone, Default)]
pub struct Series {
    samples: Vec<f64>,
}

impl Series {
    /// Empty series.
    pub fn new() -> Series {
        Series::default()
    }

    /// Record one sample (ms).
    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True if no samples.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Raw samples.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Summary statistics of the series.
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples)
    }

    /// Fraction of samples ≤ x (empirical CDF — Fig. 1's "34% within
    /// 100 ms" style anchors).
    pub fn cdf_at(&self, x: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let n = self.samples.iter().filter(|&&v| v <= x).count();
        n as f64 / self.samples.len() as f64
    }

    /// Fixed-width histogram over [lo, hi) with `bins` buckets;
    /// returns bucket counts (values outside clamp to first/last).
    pub fn histogram(&self, lo: f64, hi: f64, bins: usize) -> Vec<usize> {
        let mut counts = vec![0usize; bins];
        if self.samples.is_empty() || hi <= lo {
            return counts;
        }
        let w = (hi - lo) / bins as f64;
        for &s in &self.samples {
            let idx = (((s - lo) / w).floor() as i64).clamp(0, bins as i64 - 1);
            counts[idx as usize] += 1;
        }
        counts
    }

    /// Render an ASCII histogram like the paper's latency figures.
    pub fn render_histogram(&self, lo: f64, hi: f64, bins: usize, width: usize) -> String {
        let counts = self.histogram(lo, hi, bins);
        let max = counts.iter().copied().max().unwrap_or(1).max(1);
        let w = (hi - lo) / bins as f64;
        let mut out = String::new();
        for (i, c) in counts.iter().enumerate() {
            let bar = "#".repeat(c * width / max);
            let pct = 100.0 * *c as f64 / self.samples.len().max(1) as f64;
            let _ = writeln!(
                out,
                "{:>8.1}-{:<8.1} |{:<w$}| {:>5} ({pct:>5.1}%)",
                lo + i as f64 * w,
                lo + (i + 1) as f64 * w,
                bar,
                c,
                w = width,
            );
        }
        out
    }
}

/// Percentile summary of a sample set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute from raw samples.
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary {
                count: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                p50: 0.0,
                p95: 0.0,
                p99: 0.0,
                max: 0.0,
            };
        }
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            count: n,
            mean,
            std: var.sqrt(),
            min: s[0],
            p50: percentile_sorted(&s, 0.50),
            p95: percentile_sorted(&s, 0.95),
            p99: percentile_sorted(&s, 0.99),
            max: s[n - 1],
        }
    }

    /// One-line report string.
    pub fn line(&self) -> String {
        format!(
            "n={} mean={:.2} std={:.2} min={:.2} p50={:.2} p95={:.2} p99={:.2} max={:.2}",
            self.count, self.mean, self.std, self.min, self.p50, self.p95, self.p99, self.max
        )
    }
}

/// Percentile of an already-sorted slice (linear interpolation).
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

/// Busy intervals on the virtual timeline — per-stage occupancy traces of
/// the pipelined serving engine (DESIGN.md §5). Each `(start, end)` pair
/// records one request occupying one stage.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Intervals {
    items: Vec<(f64, f64)>,
}

impl Intervals {
    /// Empty interval set.
    pub fn new() -> Intervals {
        Intervals::default()
    }

    /// Record one `[start, end)` busy interval (clamps inverted input).
    pub fn push(&mut self, start: f64, end: f64) {
        self.items.push((start, end.max(start)));
    }

    /// Number of intervals.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// True if no intervals recorded.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Raw intervals in insertion order.
    pub fn items(&self) -> &[(f64, f64)] {
        &self.items
    }

    /// Total busy time (intervals within one stage never overlap, so a
    /// plain sum is exact there; overlapping sets give summed duration).
    pub fn busy_ms(&self) -> f64 {
        self.items.iter().map(|(s, e)| e - s).sum()
    }

    /// Fraction of a horizon spent busy.
    pub fn utilization(&self, horizon_ms: f64) -> f64 {
        if horizon_ms <= 0.0 {
            0.0
        } else {
            self.busy_ms() / horizon_ms
        }
    }
}

/// Maximum number of simultaneously-active intervals across all sets
/// (sweep line; an interval ending exactly when another starts does not
/// overlap it). This is how "≥ 2 requests in flight" is asserted from
/// stage-occupancy traces.
pub fn max_overlap(sets: &[&Intervals]) -> usize {
    // Event: (time, +1 start / -1 end); ends sort before starts at ties.
    let mut events: Vec<(f64, i32)> = Vec::new();
    for set in sets {
        for &(s, e) in set.items() {
            if e > s {
                events.push((s, 1));
                events.push((e, -1));
            }
        }
    }
    events.sort_by(|a, b| {
        a.0.partial_cmp(&b.0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.1.cmp(&b.1))
    });
    let mut cur = 0i32;
    let mut max = 0i32;
    for (_, d) in events {
        cur += d;
        if cur > max {
            max = cur;
        }
    }
    max.max(0) as usize
}

/// Throughput counter over simulated or wall time.
#[derive(Debug, Default, Clone)]
pub struct Throughput {
    pub completed: u64,
    pub failed: u64,
    pub recovered: u64,
    pub total_ms: f64,
}

impl Throughput {
    /// Requests/second given accumulated time.
    pub fn rps(&self) -> f64 {
        if self.total_ms <= 0.0 {
            0.0
        } else {
            self.completed as f64 / (self.total_ms / 1000.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.count, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!((s.p95 - 95.05).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
    }

    #[test]
    fn cdf_anchors() {
        let mut s = Series::new();
        for v in [50.0, 80.0, 120.0, 200.0] {
            s.record(v);
        }
        assert_eq!(s.cdf_at(100.0), 0.5);
        assert_eq!(s.cdf_at(49.0), 0.0);
        assert_eq!(s.cdf_at(200.0), 1.0);
    }

    #[test]
    fn histogram_buckets_and_clamping() {
        let mut s = Series::new();
        for v in [-5.0, 0.0, 9.9, 10.0, 19.9, 25.0] {
            s.record(v);
        }
        let h = s.histogram(0.0, 20.0, 2);
        assert_eq!(h, vec![3, 3]); // -5 clamps low, 25 clamps high
    }

    #[test]
    fn empty_series_safe() {
        let s = Series::new();
        assert_eq!(s.summary().count, 0);
        assert_eq!(s.cdf_at(1.0), 0.0);
        assert_eq!(s.histogram(0.0, 1.0, 4), vec![0; 4]);
    }

    #[test]
    fn intervals_busy_and_utilization() {
        let mut iv = Intervals::new();
        iv.push(0.0, 10.0);
        iv.push(20.0, 25.0);
        assert_eq!(iv.len(), 2);
        assert!((iv.busy_ms() - 15.0).abs() < 1e-12);
        assert!((iv.utilization(30.0) - 0.5).abs() < 1e-12);
        assert_eq!(iv.utilization(0.0), 0.0);
    }

    #[test]
    fn max_overlap_counts_concurrency() {
        let mut a = Intervals::new();
        a.push(0.0, 10.0);
        a.push(10.0, 20.0); // back-to-back: no self-overlap
        let mut b = Intervals::new();
        b.push(5.0, 15.0);
        assert_eq!(max_overlap(&[&a]), 1);
        assert_eq!(max_overlap(&[&a, &b]), 2);
        let mut c = Intervals::new();
        c.push(9.0, 11.0);
        assert_eq!(max_overlap(&[&a, &b, &c]), 3);
        assert_eq!(max_overlap(&[&Intervals::new()]), 0);
    }

    #[test]
    fn render_contains_counts() {
        let mut s = Series::new();
        for _ in 0..10 {
            s.record(5.0);
        }
        let r = s.render_histogram(0.0, 10.0, 2, 20);
        assert!(r.contains("10"), "{r}");
    }
}
