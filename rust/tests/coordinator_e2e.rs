//! End-to-end coordinator tests: the distributed pipeline (fleet threads +
//! PJRT artifacts + merge/recovery) must agree with the python full-model
//! golden logits — with and without failures, under every redundancy mode.

use cdc_dnn::coordinator::{Redundancy, Session, SessionConfig, SplitSpec};
use cdc_dnn::fleet::{FailurePlan, NetConfig};
use cdc_dnn::runtime::Manifest;
use cdc_dnn::tensor::Tensor;

fn artifacts_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Artifact-dependent tests skip (with a note) instead of failing — the
/// synthetic-manifest tests in `serve_pipeline.rs` cover the coordinator
/// stack without the python build.
fn have_artifacts() -> bool {
    cdc_dnn::testkit::artifacts_available(&artifacts_root())
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            return;
        }
    };
}

fn golden_model_io(name: &str) -> (Tensor, Tensor) {
    let m = Manifest::load(artifacts_root()).unwrap();
    let g = m
        .goldens
        .iter()
        .find(|g| {
            g.get("kind").unwrap().as_str().unwrap() == "model"
                && g.get("model").unwrap().as_str().unwrap() == name
        })
        .expect("model golden");
    let shape = g.get("input_shape").unwrap().as_usize_vec().unwrap();
    let input = Tensor::new(
        shape,
        m.read_f32(g.get("input").unwrap().as_str().unwrap()).unwrap(),
    )
    .unwrap();
    let logits_raw = m.read_f32(g.get("logits").unwrap().as_str().unwrap()).unwrap();
    let logits = Tensor::new(vec![logits_raw.len(), 1], logits_raw).unwrap();
    (input, logits)
}

fn lenet_cfg(n_devices: usize) -> SessionConfig {
    let mut cfg = SessionConfig::new("lenet5");
    cfg.n_devices = n_devices;
    cfg.net = NetConfig::ideal();
    cfg
}

#[test]
fn single_device_matches_python_golden() {
    require_artifacts!();
    let (input, want) = golden_model_io("lenet5");
    let mut s = Session::start(artifacts_root(), lenet_cfg(1)).unwrap();
    let trace = s.infer(&input).unwrap();
    assert!(
        trace.output.max_abs_diff(&want) < 1e-3,
        "diff={}",
        trace.output.max_abs_diff(&want)
    );
    assert!(!trace.any_recovery);
}

#[test]
fn distributed_split_matches_golden() {
    require_artifacts!();
    let (input, want) = golden_model_io("lenet5");
    let mut cfg = lenet_cfg(4);
    cfg.splits.insert("conv2".into(), SplitSpec::plain(2));
    cfg.splits.insert("fc1".into(), SplitSpec::plain(4));
    cfg.splits.insert("fc2".into(), SplitSpec::plain(2));
    let mut s = Session::start(artifacts_root(), cfg).unwrap();
    let trace = s.infer(&input).unwrap();
    assert!(
        trace.output.max_abs_diff(&want) < 1e-3,
        "diff={}",
        trace.output.max_abs_diff(&want)
    );
}

#[test]
fn cdc_split_matches_golden_without_failure() {
    require_artifacts!();
    let (input, want) = golden_model_io("lenet5");
    let mut cfg = lenet_cfg(4);
    cfg.splits.insert("fc1".into(), SplitSpec::cdc(4));
    cfg.splits.insert("fc2".into(), SplitSpec::cdc(2));
    let mut s = Session::start(artifacts_root(), cfg).unwrap();
    assert_eq!(s.total_devices(), 6, "4 data + 2 parity");
    let trace = s.infer(&input).unwrap();
    assert!(trace.output.max_abs_diff(&want) < 1e-3);
}

#[test]
fn cdc_recovers_exact_logits_under_failure() {
    require_artifacts!();
    let (input, want) = golden_model_io("lenet5");
    let mut cfg = lenet_cfg(4);
    cfg.splits.insert("fc1".into(), SplitSpec::cdc(4));
    // Paper-style allocation file: whole layers pinned to device 0, the
    // split layer spread over all four devices.
    for l in ["conv1", "conv2", "fc2", "fc3"] {
        cfg.placement.insert(l.into(), vec![0]);
    }
    cfg.placement.insert("fc1".into(), vec![0, 1, 2, 3]);
    let mut s = Session::start(artifacts_root(), cfg).unwrap();

    // Kill the device owning only fc1's shard 1.
    s.set_failure(1, FailurePlan::PermanentAt(0)).unwrap();
    let trace = s.infer(&input).unwrap();
    assert!(trace.any_recovery, "parity substitution must kick in");
    assert!(
        trace.output.max_abs_diff(&want) < 1e-3,
        "recovered logits diverge: {}",
        trace.output.max_abs_diff(&want)
    );
    let fc1 = trace.layers.iter().find(|l| l.layer == "fc1").unwrap();
    assert_eq!(fc1.outcome, "recovered");
}

#[test]
fn plain_split_loses_request_on_failure() {
    require_artifacts!();
    let (input, _) = golden_model_io("lenet5");
    let mut cfg = lenet_cfg(2);
    cfg.splits.insert("fc1".into(), SplitSpec::plain(2));
    let mut s = Session::start(artifacts_root(), cfg).unwrap();
    s.set_failure(1, FailurePlan::PermanentAt(0)).unwrap();
    let err = s.infer(&input).unwrap_err();
    assert!(format!("{err}").contains("lost"), "{err}");
}

#[test]
fn failover_restores_service_after_loss() {
    require_artifacts!();
    let (input, want) = golden_model_io("lenet5");
    let mut cfg = lenet_cfg(2);
    cfg.splits.insert("fc1".into(), SplitSpec::plain(2));
    let mut s = Session::start(artifacts_root(), cfg).unwrap();
    s.set_failure(1, FailurePlan::PermanentAt(0)).unwrap();
    assert!(s.infer(&input).is_err());
    s.drain();
    // Coordinator detects + reassigns device 1's tasks to device 0.
    let moved = s.failover(1, 0).unwrap();
    assert!(moved > 0);
    let trace = s.infer(&input).unwrap();
    assert!(trace.output.max_abs_diff(&want) < 1e-3);
}

#[test]
fn two_mr_tolerates_one_failure() {
    require_artifacts!();
    let (input, want) = golden_model_io("lenet5");
    let mut cfg = lenet_cfg(2);
    cfg.splits.insert(
        "fc1".into(),
        SplitSpec { d: 2, redundancy: Redundancy::TwoMr },
    );
    for l in ["conv1", "conv2", "fc2", "fc3"] {
        cfg.placement.insert(l.into(), vec![1]);
    }
    cfg.placement.insert("fc1".into(), vec![0, 1]);
    let mut s = Session::start(artifacts_root(), cfg).unwrap();
    assert_eq!(s.total_devices(), 4, "2 data + 2 replicas");
    // Device 0 hosts only fc1 shard 0; its replica lives on device 2.
    s.set_failure(0, FailurePlan::PermanentAt(0)).unwrap();
    let trace = s.infer(&input).unwrap();
    assert!(trace.output.max_abs_diff(&want) < 1e-3);
}

#[test]
fn grouped_parity_tolerates_one_failure_per_group() {
    require_artifacts!();
    let (input, want) = golden_model_io("lenet5");
    let mut cfg = lenet_cfg(4);
    cfg.splits.insert(
        "fc1".into(),
        SplitSpec { d: 4, redundancy: Redundancy::CdcGrouped(2) },
    );
    for l in ["conv1", "conv2", "fc2", "fc3"] {
        cfg.placement.insert(l.into(), vec![1]);
    }
    cfg.placement.insert("fc1".into(), vec![0, 1, 2, 3]);
    let mut s = Session::start(artifacts_root(), cfg).unwrap();
    assert_eq!(s.total_devices(), 6, "4 data + 2 group parities");
    // One failure in each group: devices 0 (group A) and 2 (group B).
    s.set_failure(0, FailurePlan::PermanentAt(0)).unwrap();
    s.set_failure(2, FailurePlan::PermanentAt(0)).unwrap();
    let trace = s.infer(&input).unwrap();
    assert!(trace.any_recovery);
    assert!(trace.output.max_abs_diff(&want) < 1e-3);
}

#[test]
fn fc2048_microbenchmark_model_runs() {
    require_artifacts!();
    let m = Manifest::load(artifacts_root()).unwrap();
    if !m.models.contains_key("fc2048") {
        return; // quick artifact sets may omit it
    }
    let mut cfg = SessionConfig::new("fc2048");
    cfg.n_devices = 4;
    cfg.net = NetConfig::ideal();
    cfg.splits.insert("fc".into(), SplitSpec::cdc(4));
    let mut s = Session::start(artifacts_root(), cfg).unwrap();
    let mut rng = cdc_dnn::rng::Pcg32::seeded(3);
    let x = Tensor::randn(vec![2048], &mut rng);
    let t = s.infer(&x).unwrap();
    assert_eq!(t.output.shape(), &[2048, 1]);
    // Ideal network: latency = shard compute = (2048/4)*2048 MACs @ RPi.
    let expect = (512.0 * 2048.0) / cdc_dnn::fleet::RPI_MACS_PER_MS;
    assert!(
        (t.total_ms - expect).abs() < 1.0,
        "latency {} vs expected {expect}",
        t.total_ms
    );
}
