//! Channel-based compute server: the one thread that owns PJRT state.
//!
//! `PjRtClient` is not `Send`, so the multi-threaded fleet simulator cannot
//! share executables directly. Instead a dedicated server thread owns the
//! [`Runtime`] + [`Manifest`] and serves execute requests over an mpsc
//! channel; device threads hold cheap cloneable [`ComputeHandle`]s.
//!
//! Serialising the *wall-clock* compute does not distort experiments: the
//! fleet's timing model is simulated (each device's service time is derived
//! from the layer cost model + its compute rate), so PJRT throughput only
//! affects how fast experiments run, not what they measure. The perf pass
//! (EXPERIMENTS.md §Perf) benchmarks this server's dispatch overhead.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::kernels::{PackedWeights, QuantWeights};
use crate::runtime::{Manifest, Runtime};
use crate::tensor::Tensor;

enum Request {
    Execute {
        artifact: String,
        inputs: Vec<Arc<Tensor>>,
        /// Deploy-time packed weight panels (DESIGN.md §15) — forwarded
        /// to the runtime so the hot path skips per-call packing.
        packed: Option<Arc<PackedWeights>>,
        /// Int8 weights for quantized tasks; when set, `inputs` is
        /// `[b, x]` and the f32 weight tensor is absent.
        quant: Option<Arc<QuantWeights>>,
        reply: Sender<std::result::Result<Tensor, String>>,
    },
    Preload {
        artifacts: Vec<String>,
        reply: Sender<std::result::Result<(), String>>,
    },
}

/// Cloneable handle to the compute server thread.
#[derive(Clone)]
pub struct ComputeHandle {
    tx: Sender<Request>,
    execs: Arc<AtomicU64>,
}

impl ComputeHandle {
    /// Execute an artifact by name; blocks until the result is ready.
    /// Inputs are `Arc`-shared: no tensor payload is copied to enqueue.
    pub fn execute(&self, artifact: &str, inputs: Vec<Arc<Tensor>>) -> Result<Tensor> {
        self.execute_prepared(artifact, inputs, None, None)
    }

    /// [`ComputeHandle::execute`] carrying a task's deploy-time kernel
    /// state (DESIGN.md §15): pre-packed weight panels and/or int8
    /// weights, both `Arc`-shared like the inputs. For a quantized task
    /// `inputs` is `[b, x]` — the f32 weight tensor stays coordinator-
    /// side.
    pub fn execute_prepared(
        &self,
        artifact: &str,
        inputs: Vec<Arc<Tensor>>,
        packed: Option<Arc<PackedWeights>>,
        quant: Option<Arc<QuantWeights>>,
    ) -> Result<Tensor> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Execute { artifact: artifact.to_string(), inputs, packed, quant, reply })
            .map_err(|_| Error::Fleet("compute server is gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Fleet("compute server dropped reply".into()))?
            .map_err(Error::Xla)
    }

    /// Pre-compile a set of artifacts (deploy-time warm-up, keeps compile
    /// time out of latency measurements).
    pub fn preload(&self, artifacts: &[String]) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Preload { artifacts: artifacts.to_vec(), reply })
            .map_err(|_| Error::Fleet("compute server is gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Fleet("compute server dropped reply".into()))?
            .map_err(Error::Xla)
    }

    /// Total PJRT executions served.
    pub fn exec_count(&self) -> u64 {
        self.execs.load(Ordering::Relaxed)
    }
}

/// The running compute server (join handle + its public handle).
pub struct ComputeServer {
    handle: ComputeHandle,
    join: Option<JoinHandle<()>>,
}

impl ComputeServer {
    /// Spawn the server thread over an artifacts directory.
    ///
    /// The Runtime and Manifest are constructed *on* the server thread
    /// (PJRT state must not cross threads); construction errors are
    /// reported through the first recv.
    pub fn spawn(artifacts_root: impl Into<std::path::PathBuf>) -> Result<ComputeServer> {
        let root = artifacts_root.into();
        let (tx, rx) = channel::<Request>();
        let execs = Arc::new(AtomicU64::new(0));
        let execs2 = execs.clone();
        let (init_tx, init_rx) = channel::<std::result::Result<(), String>>();
        let join = std::thread::Builder::new()
            .name("pjrt-compute".into())
            .spawn(move || serve(root, rx, execs2, init_tx))
            .map_err(|e| Error::Fleet(format!("spawn compute server: {e}")))?;
        init_rx
            .recv()
            .map_err(|_| Error::Fleet("compute server died during init".into()))?
            .map_err(Error::Xla)?;
        Ok(ComputeServer { handle: ComputeHandle { tx, execs }, join: Some(join) })
    }

    /// A cloneable handle for device threads.
    pub fn handle(&self) -> ComputeHandle {
        self.handle.clone()
    }
}

impl Drop for ComputeServer {
    fn drop(&mut self) {
        // Close our handle's sender by replacing it, then join.
        let (dead_tx, _) = channel();
        self.handle.tx = dead_tx;
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn serve(
    root: std::path::PathBuf,
    rx: Receiver<Request>,
    execs: Arc<AtomicU64>,
    init_tx: Sender<std::result::Result<(), String>>,
) {
    let runtime = match Runtime::new() {
        Ok(r) => r,
        Err(e) => {
            let _ = init_tx.send(Err(format!("pjrt init: {e}")));
            return;
        }
    };
    let manifest = match Manifest::load(&root) {
        Ok(m) => m,
        Err(e) => {
            let _ = init_tx.send(Err(format!("manifest: {e}")));
            return;
        }
    };
    let _ = init_tx.send(Ok(()));
    while let Ok(req) = rx.recv() {
        match req {
            Request::Execute { artifact, inputs, packed, quant, reply } => {
                let refs: Vec<&Tensor> = inputs.iter().map(|a| a.as_ref()).collect();
                let res = runtime
                    .execute_prepared(
                        &manifest,
                        &artifact,
                        &refs,
                        packed.as_deref(),
                        quant.as_deref(),
                    )
                    .map_err(|e| e.to_string());
                execs.store(runtime.exec_count(), Ordering::Relaxed);
                let _ = reply.send(res);
            }
            Request::Preload { artifacts, reply } => {
                let mut res = Ok(());
                for a in &artifacts {
                    if let Err(e) = runtime.preload(&manifest, a) {
                        res = Err(format!("{a}: {e}"));
                        break;
                    }
                }
                let _ = reply.send(res);
            }
        }
    }
}
