//! END-TO-END serving driver (DESIGN.md §5): load the *trained* LeNet-5,
//! deploy it across a six-device simulated IoT fleet (four data devices +
//! CDC parity devices), and serve the entire held-out evaluation set
//! through the **pipelined serving engine** — many requests in flight at
//! once across the distributed stages, Pallas-authored AOT artifacts
//! executed on real threads, WiFi-jittered timing, an intermittently
//! failing device, and straggler mitigation on.
//!
//! Reports: classification accuracy (must match the clean model — CDC
//! recovery is exact), measured pipelined throughput (rps), end-to-end
//! latency percentiles, per-stage utilization, recovery counts, lost
//! requests (must be zero), and harness wall-clock throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_serving
//! ```
//!
//! This flow is smoke-tested on every `cargo test` (no artifacts
//! needed): `rust/tests/examples_smoke.rs::
//! e2e_serving_flow_pipelines_with_recovery` runs the same deployment
//! shape on the synthetic model — the documented flow cannot rot.

use cdc_dnn::coordinator::{Pipeline, Session, SessionConfig, SplitSpec, Workload};
use cdc_dnn::fleet::FailurePlan;
use cdc_dnn::model::load_eval_set;
use cdc_dnn::runtime::Manifest;

fn main() -> cdc_dnn::Result<()> {
    let artifacts = std::path::Path::new("artifacts");
    let manifest = Manifest::load(artifacts)?;
    let (images, labels) = load_eval_set(&manifest)?;
    println!("eval set: {} synthetic digits", images.len());

    // Deployment: fc1 CDC-split over 4 devices, fc2 CDC-split over 2,
    // conv trunk pinned — 4 data devices + 2 parity devices = 6, the
    // paper's Case-Study-II scale.
    let mut cfg = SessionConfig::new("lenet5");
    cfg.n_devices = 4;
    cfg.splits.insert("fc1".into(), SplitSpec::cdc(4));
    cfg.splits.insert("fc2".into(), SplitSpec::cdc(2));
    cfg.placement.insert("conv1".into(), vec![0]);
    cfg.placement.insert("conv2".into(), vec![1]);
    cfg.placement.insert("fc1".into(), vec![0, 1, 2, 3]);
    cfg.placement.insert("fc2".into(), vec![2, 3]);
    cfg.threshold_factor = 1.5; // straggler mitigation
    let mut session = Session::start(artifacts, cfg)?;
    println!(
        "fleet: {} devices ({} parity), WiFi-jitter timing model, \
         straggler threshold 1.5×, compute backend: {}",
        session.total_devices(),
        session.extra_devices,
        cdc_dnn::runtime::backend_label()
    );

    // Device 3 drops 20% of its replies (intermittent IoT failure).
    session.set_failure(3, FailurePlan::Intermittent(0.2))?;

    // Serve the whole eval set through the pipeline: closed loop with one
    // request per distributed stage keeps every stage busy.
    let workload = Workload::closed(images.clone(), session.saturating_concurrency());
    let t0 = std::time::Instant::now();
    let report = Pipeline::new(&mut session).run(&workload)?;
    let wall = t0.elapsed().as_secs_f64();

    let n = images.len();
    // Match traces to labels by request id (this session is fresh, so
    // req == eval-set index) — a positional zip would misalign every
    // pair after a lost request.
    let correct = report
        .traces
        .iter()
        .filter(|t| t.output.argmax() == labels[t.req as usize] as usize)
        .count();
    let s = report.latency.summary();

    println!("\n=== end-to-end pipelined serving report ===");
    println!("requests served:     {}", report.throughput.completed);
    println!(
        "lost requests:       {}  (paper claim: never loses a request)",
        report.failures.len()
    );
    println!("CDC recoveries:      {}", report.throughput.recovered);
    println!(
        "accuracy:            {:.2}% (trained clean accuracy ≈ {:.2}%)",
        100.0 * correct as f64 / n as f64,
        100.0 * manifest
            .raw
            .get("training")
            .and_then(|t| t.get("lenet5"))
            .and_then(|t| t.get("test_acc"))
            .and_then(|v| v.as_f64())
            .unwrap_or(f64::NAN)
    );
    println!(
        "pipelined throughput: {:.1} req/s over {:.0} ms of virtual time \
         (peak {} in flight)",
        report.rps(),
        report.makespan_ms,
        report.max_concurrent_requests
    );
    println!("e2e latency:         {}", s.line());
    println!("queue wait:          {}", report.queue_wait.summary().line());
    println!("{}", report.latency.render_histogram(0.0, s.p99.max(100.0), 14, 36));
    println!("per-stage utilization:");
    for st in &report.stages {
        println!(
            "  {:<8} served={:<4} busy={:>8.1}ms util={:>5.1}%",
            st.layer,
            st.served,
            st.busy_ms,
            100.0 * st.utilization
        );
    }
    println!(
        "harness wall-clock:  {wall:.1}s → {:.1} req/s through real compute",
        n as f64 / wall
    );

    assert_eq!(report.failures.len(), 0, "CDC system must not lose requests");
    assert!(report.throughput.recovered > 0, "failure injection must exercise recovery");
    assert!(
        report.max_concurrent_requests >= 2,
        "pipeline must keep multiple requests in flight"
    );
    println!("e2e_serving OK");
    Ok(())
}
