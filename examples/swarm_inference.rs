//! Swarm inference: the paper's 12-Raspberry-Pi upper bound — a C3D-class
//! video model whose two big fc layers are each split three ways (Fig.
//! 17d's deployment), protected by grouped CDC parities, surviving
//! *multiple* simultaneous failures (Fig. 18).
//!
//! ```bash
//! cargo run --release --example swarm_inference
//! ```

use cdc_dnn::coordinator::{Redundancy, Session, SessionConfig, SplitSpec};
use cdc_dnn::fleet::FailurePlan;
use cdc_dnn::rng::Pcg32;
use cdc_dnn::tensor::Tensor;

fn main() -> cdc_dnn::Result<()> {
    let mut cfg = SessionConfig::new("c3d");
    cfg.n_devices = 10;
    // fc6 and fc7 split 3 ways each (paper Fig. 17d); fc6 gets grouped
    // parities (two groups → tolerates one failure per group, Fig. 18).
    cfg.splits.insert(
        "fc6".into(),
        SplitSpec { d: 3, redundancy: Redundancy::CdcGrouped(2) },
    );
    cfg.splits.insert("fc7".into(), SplitSpec::cdc(3));
    // Conv trunk spread across the remaining devices.
    for (layer, dev) in [
        ("conv1", 0usize),
        ("conv2", 1),
        ("conv3a", 2),
        ("conv3b", 3),
        ("conv4a", 2),
        ("conv4b", 3),
        ("fc8", 0),
    ] {
        cfg.placement.insert(layer.into(), vec![dev]);
    }
    cfg.placement.insert("fc6".into(), vec![4, 5, 6]);
    cfg.placement.insert("fc7".into(), vec![7, 8, 9]);
    let mut session = Session::start("artifacts", cfg)?;
    println!(
        "swarm: {} devices total ({} redundancy devices) — paper's 12-Pi scale",
        session.total_devices(),
        session.extra_devices
    );

    let mut rng = Pcg32::seeded(42);
    let clip = Tensor::randn(vec![32, 32, 3], &mut rng);
    let healthy = session.infer(&clip)?;
    println!(
        "healthy: class {} in {:.1} ms (simulated)",
        healthy.output.argmax(),
        healthy.total_ms
    );

    // Two simultaneous failures: one fc6 shard (group A) and one fc7 shard.
    session.set_failure(4, FailurePlan::PermanentAt(0))?;
    session.set_failure(8, FailurePlan::PermanentAt(0))?;
    let wounded = session.infer(&clip)?;
    println!(
        "two devices down: class {} in {:.1} ms, recovery used: {}",
        wounded.output.argmax(),
        wounded.total_ms,
        wounded.any_recovery
    );
    assert_eq!(healthy.output.argmax(), wounded.output.argmax());
    assert!(wounded.any_recovery);

    // A third failure in the *same* fc6 group is not recoverable — that is
    // the Fig. 18 boundary ("Hamming-style coverage" is future work).
    session.set_failure(5, FailurePlan::PermanentAt(0))?;
    match session.infer(&clip) {
        Err(e) => println!("third correlated failure (expected loss): {e}"),
        Ok(_) => panic!("two failures in one parity group cannot be recovered"),
    }
    println!("swarm_inference OK");
    Ok(())
}
