//! `cdc-dnn` — CLI launcher for the coded-distributed-computing DNN
//! serving system and its paper-reproduction experiments.
//!
//! ```text
//! cdc-dnn <command> [options]
//!
//! commands:
//!   fig1        arrival-time histogram (paper Fig. 1)
//!   fig2        accuracy vs per-layer data loss (Fig. 2)
//!   table1      split-method suitability table (Table 1)
//!   case1       AlexNet failure without robustness (Figs. 11-12)
//!   case2       AlexNet + CDC parity device (Figs. 13-15)
//!   fig16       straggler-mitigation sweep (Fig. 16)
//!   fig17       coverage: 2MR vs CDC+2MR (Fig. 17)
//!   fig18       multi-failure parity groups (Fig. 18)
//!   calibrate   simulator-vs-paper anchor table
//!   scenarios   fleet-chaos scenario suite (synthetic model, no artifacts)
//!   synth       materialise the synthetic artifact set at --artifacts
//!   serve       serve a deployment file (see --deployment / --transport)
//!   gateway     serve behind the HTTP/1.1 front door (DESIGN.md §14)
//!   worker      run a standalone TCP shard-compute worker (DESIGN.md §11)
//!   all         every experiment in order
//!
//! options:
//!   --artifacts DIR    AOT artifacts directory   [default: artifacts]
//!   --results DIR      result JSON directory     [default: results]
//!   --requests N       requests per series       [default: 400]
//!   --seed S           experiment seed           [default: 2021]
//!   --quick            reduced workloads (CI smoke)
//!   --deployment FILE  deployment JSON for `serve`
//!
//! serve options:
//!   --transport M      sim | tcp (overrides the deployment file)
//!   --precision P      f32 | int8 fc-shard precision (overrides the
//!                      deployment file; DESIGN.md §15)
//!   --workers LIST     comma-separated worker host:port list (tcp);
//!                      empty in tcp mode spawns a loopback fleet
//!   --rate-rps R       Poisson arrival rate       [default: 50]
//!   --chaos-kill-ms T  loopback only: SIGKILL one worker T ms into the run
//!   --chaos-join-ms T  loopback only: a fresh worker dials the live
//!                      coordinator's membership port T ms into the run
//!   --expect-no-loss   exit non-zero if any request is lost/balked
//!
//! gateway options (plus the serve options above):
//!   --http ADDR        HTTP bind address [default: deployment `gateway`
//!                      section, else 127.0.0.1:0]
//!   --serve-ms T       shut the gateway down after T ms (default: run
//!                      until POST /v1/shutdown)
//!   --rate-rps R       also drive synthetic paced traffic through the
//!                      same pipeline (omit for external requests only)
//!
//! scenarios options:
//!   --transport M      sim (default) | tcp: replay the chaos suite over a
//!                      real loopback worker fleet (wall clock, CDC arm)
//!   --expect-no-loss   exit non-zero if any tcp scenario loses a request
//!
//! worker options:
//!   --listen ADDR      bind address               [default: 127.0.0.1:0]
//!   --join ADDR        dial a live coordinator's membership port and
//!                      Register instead of listening (DESIGN.md §13)
//!   --leave-after-ms T with --join: announce a graceful Leave T ms after
//!                      joining (drain, then exit)
//!   --net PROFILE      artificial reply delay: ideal|moderate|congested
//!   --rate MACS_PER_MS artificial compute rate (RPi ≈ 83886)
//! ```

use cdc_dnn::config::load_deployment;
use cdc_dnn::coordinator::{Session, Workload};
use cdc_dnn::exp::{self, ExpCtx};
use cdc_dnn::fleet::NetConfig;
use cdc_dnn::rng::Pcg32;
use cdc_dnn::tensor::Tensor;
use cdc_dnn::transport::{loopback, worker, TcpConfig, TransportSpec};

fn usage() -> ! {
    // The module doc above is the single source of truth for help text.
    print!("{}", HELP);
    std::process::exit(2);
}

const HELP: &str = "cdc-dnn — robust distributed DNN inference with CDC\n\n\
usage: cdc-dnn <command> [--artifacts DIR] [--results DIR] [--requests N]\n\
       [--seed S] [--quick] [--deployment FILE] [--transport sim|tcp]\n\
       [--workers H:P,..] [--rate-rps R] [--chaos-kill-ms T]\n\
       [--chaos-join-ms T] [--expect-no-loss] [--listen ADDR] [--join ADDR]\n\
       [--leave-after-ms T] [--net PROFILE] [--rate R] [--http ADDR]\n\
       [--serve-ms T] [--precision f32|int8]\n\n\
commands: fig1 fig2 table1 case1 case2 fig16 fig17 fig18 calibrate ablate\n          scenarios synth serve gateway worker all\n";

/// serve/worker options beyond the shared ExpCtx ones.
#[derive(Default)]
struct CliOpts {
    deployment: Option<String>,
    transport: Option<String>,
    workers: Option<String>,
    rate_rps: Option<f64>,
    chaos_kill_ms: Option<u64>,
    chaos_join_ms: Option<u64>,
    expect_no_loss: bool,
    listen: Option<String>,
    join: Option<String>,
    leave_after_ms: Option<u64>,
    net: Option<String>,
    rate: Option<f64>,
    http: Option<String>,
    serve_ms: Option<u64>,
    precision: Option<String>,
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
    }
    let cmd = args[0].clone();
    let mut ctx = ExpCtx::new("artifacts");
    let mut opts = CliOpts::default();
    let mut i = 1;
    while i < args.len() {
        let need = |i: usize| {
            args.get(i + 1).cloned().unwrap_or_else(|| {
                eprintln!("missing value for {}", args[i]);
                std::process::exit(2)
            })
        };
        match args[i].as_str() {
            "--artifacts" => {
                ctx.artifacts = need(i).into();
                i += 2;
            }
            "--results" => {
                ctx.results = need(i).into();
                i += 2;
            }
            "--requests" => {
                ctx.requests = need(i).parse().unwrap_or_else(|_| {
                    eprintln!("bad --requests");
                    std::process::exit(2)
                });
                i += 2;
            }
            "--seed" => {
                ctx.seed = need(i).parse().unwrap_or_else(|_| {
                    eprintln!("bad --seed");
                    std::process::exit(2)
                });
                i += 2;
            }
            "--quick" => {
                ctx.quick = true;
                i += 1;
            }
            "--deployment" => {
                opts.deployment = Some(need(i));
                i += 2;
            }
            "--transport" => {
                opts.transport = Some(need(i));
                i += 2;
            }
            "--workers" => {
                opts.workers = Some(need(i));
                i += 2;
            }
            "--rate-rps" => {
                opts.rate_rps = Some(need(i).parse().unwrap_or_else(|_| {
                    eprintln!("bad --rate-rps");
                    std::process::exit(2)
                }));
                i += 2;
            }
            "--chaos-kill-ms" => {
                opts.chaos_kill_ms = Some(need(i).parse().unwrap_or_else(|_| {
                    eprintln!("bad --chaos-kill-ms");
                    std::process::exit(2)
                }));
                i += 2;
            }
            "--chaos-join-ms" => {
                opts.chaos_join_ms = Some(need(i).parse().unwrap_or_else(|_| {
                    eprintln!("bad --chaos-join-ms");
                    std::process::exit(2)
                }));
                i += 2;
            }
            "--expect-no-loss" => {
                opts.expect_no_loss = true;
                i += 1;
            }
            "--listen" => {
                opts.listen = Some(need(i));
                i += 2;
            }
            "--join" => {
                opts.join = Some(need(i));
                i += 2;
            }
            "--leave-after-ms" => {
                opts.leave_after_ms = Some(need(i).parse().unwrap_or_else(|_| {
                    eprintln!("bad --leave-after-ms");
                    std::process::exit(2)
                }));
                i += 2;
            }
            "--net" => {
                opts.net = Some(need(i));
                i += 2;
            }
            "--rate" => {
                opts.rate = Some(need(i).parse().unwrap_or_else(|_| {
                    eprintln!("bad --rate");
                    std::process::exit(2)
                }));
                i += 2;
            }
            "--http" => {
                opts.http = Some(need(i));
                i += 2;
            }
            "--serve-ms" => {
                opts.serve_ms = Some(need(i).parse().unwrap_or_else(|_| {
                    eprintln!("bad --serve-ms");
                    std::process::exit(2)
                }));
                i += 2;
            }
            "--precision" => {
                opts.precision = Some(need(i));
                i += 2;
            }
            "-h" | "--help" => usage(),
            other => {
                eprintln!("unknown option {other}");
                usage();
            }
        }
    }

    let result = match cmd.as_str() {
        "fig1" => exp::fig1::run(&ctx).map(|_| ()),
        "fig2" => exp::fig2::run(&ctx).map(|_| ()),
        "table1" => exp::table1::run(&ctx).map(|_| ()),
        "case1" => exp::case1::run(&ctx).map(|_| ()),
        "case2" => exp::case2::run(&ctx).map(|_| ()),
        "fig16" => exp::fig16::run(&ctx).map(|_| ()),
        "fig17" => exp::fig17::run(&ctx).map(|_| ()),
        "fig18" => exp::fig18::run(&ctx).map(|_| ()),
        "calibrate" => exp::calibrate::run(&ctx),
        "ablate" => exp::ablate::run(&ctx),
        "scenarios" => match opts.transport.as_deref() {
            None | Some("sim") => exp::scenarios::run(&ctx).map(|_| ()),
            Some("tcp") => exp::scenarios::run_tcp(&ctx, opts.expect_no_loss),
            Some(other) => Err(cdc_dnn::Error::Config(format!(
                "unknown --transport {other:?} (want sim | tcp)"
            ))),
        },
        "synth" => synth_artifacts(&ctx),
        "serve" => serve(&ctx, &opts),
        "gateway" => gateway(&ctx, &opts),
        "worker" => run_worker(&ctx, &opts),
        "all" => run_all(&ctx),
        _ => {
            eprintln!("unknown command {cmd}");
            usage();
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run_all(ctx: &ExpCtx) -> cdc_dnn::Result<()> {
    exp::calibrate::run(ctx)?;
    exp::table1::run(ctx)?;
    exp::fig1::run(ctx)?;
    exp::fig2::run(ctx)?;
    exp::case1::run(ctx)?;
    exp::case2::run(ctx)?;
    exp::fig16::run(ctx)?;
    exp::fig17::run(ctx)?;
    exp::fig18::run(ctx)?;
    exp::ablate::run(ctx)?;
    exp::scenarios::run(ctx)?;
    Ok(())
}

/// Materialise the synthetic artifact set (manifest + weights + eval
/// set, `testkit::synth`) at the `--artifacts` directory, so the binary
/// entrypoints run fully offline — the CI CLI-smoke job drives `ablate`
/// and `serve` against it.
fn synth_artifacts(ctx: &ExpCtx) -> cdc_dnn::Result<()> {
    let arts = cdc_dnn::testkit::synth::build_at(&ctx.artifacts, ctx.seed)?;
    println!(
        "wrote synthetic artifact set (model `{}`) to {}",
        cdc_dnn::testkit::synth::MODEL,
        arts.root.display()
    );
    Ok(())
}

/// Serve a deployment file: drive a Poisson arrival stream through the
/// pipelined engine (`Session::serve`) and report throughput + latency.
/// `--transport tcp` runs the same session over real TCP worker
/// processes — spawning a loopback fleet when no `--workers` are given —
/// with wall-clock timing; `--transport sim` (default) keeps the
/// virtual-time simulator.
fn serve(ctx: &ExpCtx, opts: &CliOpts) -> cdc_dnn::Result<()> {
    let deployment = opts.deployment.as_deref();
    let path = deployment.unwrap_or("configs/lenet5_cdc.json");
    let mut cfg = load_deployment(std::path::Path::new(path))?;

    // --transport / --workers override the deployment file.
    match opts.transport.as_deref() {
        None => {}
        Some("sim") => cfg.transport = TransportSpec::Sim,
        Some("tcp") => {
            if !matches!(cfg.transport, TransportSpec::Tcp(_)) {
                cfg.transport = TransportSpec::Tcp(TcpConfig::default());
            }
        }
        Some(other) => {
            return Err(cdc_dnn::Error::Config(format!(
                "unknown --transport {other:?} (want sim | tcp)"
            )))
        }
    }
    if let Some(p) = opts.precision.as_deref() {
        cfg.precision = cdc_dnn::kernels::Precision::parse(p)?;
    }
    if let Some(list) = opts.workers.as_deref() {
        // Listing worker addresses is an unambiguous request for real
        // execution: silently simulating against them would be a trap.
        if opts.transport.as_deref() == Some("sim") {
            return Err(cdc_dnn::Error::Config(
                "--workers conflicts with --transport sim (worker \
                 addresses mean tcp)"
                    .into(),
            ));
        }
        if !matches!(cfg.transport, TransportSpec::Tcp(_)) {
            cfg.transport = TransportSpec::Tcp(TcpConfig::default());
        }
        if let TransportSpec::Tcp(tcp) = &mut cfg.transport {
            tcp.workers = list
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect();
        }
    }

    // tcp with no worker addresses: spawn a loopback fleet of child
    // worker processes (this binary, `worker` subcommand), one per
    // planned device. Held until the report is printed.
    let mut fleet: Option<loopback::LoopbackFleet> = None;
    if let TransportSpec::Tcp(tcp) = &mut cfg.transport {
        if tcp.workers.is_empty() {
            let n = cfg.planned_devices();
            println!("spawning {n} loopback workers…");
            let f = loopback::LoopbackFleet::spawn(None, &ctx.artifacts, n, None)?;
            tcp.workers = f.addrs();
            fleet = Some(f);
        }
    }

    println!(
        "serving {} on {} data devices (+redundancy) over {}…",
        cfg.model,
        cfg.n_devices,
        cfg.transport.mode()
    );
    let input_shape = {
        let manifest = cdc_dnn::runtime::Manifest::load(&ctx.artifacts)?;
        manifest.model(&cfg.model)?.input_shape.clone()
    };
    let seed = ctx.seed;
    let mut session = Session::start(&ctx.artifacts, cfg)?;
    if let Some(addr) = session.membership_addr() {
        println!("membership: workers may join at {addr} (cdc-dnn worker --join {addr} …)");
    }

    // Chaos timers run against the fleet while the coordinator blocks
    // in `Session::serve`; their handles are joined before the fleet
    // drops so no timer touches a reaped child.
    let fleet = std::sync::Arc::new(std::sync::Mutex::new(fleet));
    let mut chaos: Vec<std::thread::JoinHandle<()>> = Vec::new();

    // Chaos injection (loopback only): SIGKILL one worker mid-run; the
    // CDC arm must lose nothing.
    if let Some(t) = opts.chaos_kill_ms {
        let guard = fleet.lock().unwrap_or_else(|e| e.into_inner());
        match guard.as_ref() {
            Some(f) => {
                let victim = if f.len() > 1 { 1 } else { 0 };
                println!("chaos: killing loopback worker {victim} at t+{t}ms");
                chaos.push(f.kill_after(victim, t));
            }
            None => {
                return Err(cdc_dnn::Error::Config(
                    "--chaos-kill-ms needs a spawned loopback fleet \
                     (tcp transport without --workers)"
                        .into(),
                ))
            }
        }
    }

    // Chaos join (loopback only): a fresh worker dials the live
    // coordinator's membership port mid-run and is folded into the
    // serving plan at the next quiescent point (DESIGN.md §13).
    if let Some(t) = opts.chaos_join_ms {
        let addr = session.membership_addr().ok_or_else(|| {
            cdc_dnn::Error::Config(
                "--chaos-join-ms needs a tcp session with a membership \
                 listener (transport.listen)"
                    .into(),
            )
        })?;
        if fleet.lock().unwrap_or_else(|e| e.into_inner()).is_none() {
            return Err(cdc_dnn::Error::Config(
                "--chaos-join-ms needs a spawned loopback fleet \
                 (tcp transport without --workers)"
                    .into(),
            ));
        }
        println!("chaos: worker joins {addr} at t+{t}ms");
        let fleet = fleet.clone();
        let artifacts = ctx.artifacts.clone();
        chaos.push(std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(t));
            let mut guard = fleet.lock().unwrap_or_else(|e| e.into_inner());
            if let Some(f) = guard.as_mut() {
                if let Err(e) = f.spawn_joiner(None, &artifacts, &addr, None, None) {
                    eprintln!("chaos: join failed: {e}");
                }
            }
        }));
    }

    let n = ctx.n_requests();
    let mut rng = Pcg32::seeded(seed);
    let inputs: Vec<Tensor> = (0..n)
        .map(|_| Tensor::randn(input_shape.clone(), &mut rng))
        .collect();
    let rate = opts.rate_rps.unwrap_or(50.0);
    let t0 = std::time::Instant::now();
    let report = session.serve(&Workload::poisson(inputs, rate, seed))?;
    let wall = t0.elapsed().as_secs_f64();

    let clock = if session.transport_label() == "tcp" {
        "wall"
    } else {
        "virtual"
    };
    println!(
        "transport={} arrivals=poisson@{rate}rps",
        session.transport_label()
    );
    println!("{}", report.line());
    println!("{clock}-clock latency: {}", latency_line(&report.latency_hist));
    println!(
        "{clock}-clock throughput: {:.1} rps (harness wall total {wall:.2}s)",
        report.rps()
    );
    let lost = report.failures.len() as u64 + report.dropped;
    if opts.expect_no_loss && lost > 0 {
        return Err(cdc_dnn::Error::Fleet(format!(
            "--expect-no-loss: {} lost, {} balked",
            report.failures.len(),
            report.dropped
        )));
    }
    // Synchronise with the chaos timers before tearing down so no
    // timer races the fleet's Drop (which kills and reaps children).
    for h in chaos {
        let _ = h.join();
    }
    drop(session); // disconnect before the fleet reaps its children
    drop(fleet);
    Ok(())
}

/// Serve a deployment behind the HTTP/1.1 gateway (DESIGN.md §14):
/// external `POST /v1/infer` requests are admitted into the same
/// micro-batching pipeline as the (optional) synthetic paced stream,
/// and the fleet control plane (membership, stats, policy, deployment
/// lifecycle) answers on GET/POST/DELETE endpoints. Wall-clock (tcp)
/// transports only.
fn gateway(ctx: &ExpCtx, opts: &CliOpts) -> cdc_dnn::Result<()> {
    use cdc_dnn::gateway::{GatewayCmd, GatewayBridge, GatewayServer, ServerCtx};

    let path = opts
        .deployment
        .as_deref()
        .unwrap_or("configs/mlp_loopback.json");
    let mut cfg = load_deployment(std::path::Path::new(path))?;

    match opts.transport.as_deref() {
        // The gateway implies tcp: external clients need a real clock.
        None | Some("tcp") => {
            if !matches!(cfg.transport, TransportSpec::Tcp(_)) {
                cfg.transport = TransportSpec::Tcp(TcpConfig::default());
            }
        }
        Some(other) => {
            return Err(cdc_dnn::Error::Config(format!(
                "the gateway serves wall-clock only: --transport {other:?} \
                 (want tcp)"
            )))
        }
    }
    if let Some(p) = opts.precision.as_deref() {
        cfg.precision = cdc_dnn::kernels::Precision::parse(p)?;
    }
    if let Some(list) = opts.workers.as_deref() {
        if let TransportSpec::Tcp(tcp) = &mut cfg.transport {
            tcp.workers = list
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect();
        }
    }

    // HTTP listener settings: the deployment file's optional `gateway`
    // section, then the --http override.
    let mut gw_cfg = cdc_dnn::config::load_gateway(std::path::Path::new(path))?
        .unwrap_or_default();
    if let Some(h) = &opts.http {
        gw_cfg.listen = h.clone();
    }

    // tcp with no worker addresses: spawn a loopback fleet, as `serve`.
    let mut fleet: Option<loopback::LoopbackFleet> = None;
    if let TransportSpec::Tcp(tcp) = &mut cfg.transport {
        if tcp.workers.is_empty() {
            let n = cfg.planned_devices();
            println!("spawning {n} loopback workers…");
            let f = loopback::LoopbackFleet::spawn(None, &ctx.artifacts, n, None)?;
            tcp.workers = f.addrs();
            fleet = Some(f);
        }
    }

    let model = cfg.model.clone();
    let input_shape = {
        let manifest = cdc_dnn::runtime::Manifest::load(&ctx.artifacts)?;
        manifest.model(&model)?.input_shape.clone()
    };
    let input_len: usize = input_shape.iter().product();
    let seed = ctx.seed;
    let mut session = Session::start(&ctx.artifacts, cfg)?;
    if let Some(addr) = session.membership_addr() {
        println!(
            "membership: workers may join at {addr} (cdc-dnn worker --join {addr} …)"
        );
    }

    let (cmd_tx, cmd_rx) = std::sync::mpsc::channel::<GatewayCmd>();
    let server = GatewayServer::start(
        &gw_cfg,
        ServerCtx {
            model: model.clone(),
            input_len,
            telemetry: session.telemetry(),
        },
        cmd_tx.clone(),
    )?;
    println!(
        "gateway: serving {model} at {} (POST /v1/infer, GET /v1/fleet \
         /v1/stats /v1/policy /v1/deployments /v1/traces /metrics, \
         POST /v1/shutdown; dashboard at /)",
        server.url()
    );
    // Machine-parseable line for harnesses (CI smoke greps for it).
    println!("GATEWAY_URL {}", server.url());

    let fleet = std::sync::Arc::new(std::sync::Mutex::new(fleet));
    let mut timers: Vec<std::thread::JoinHandle<()>> = Vec::new();

    if let Some(t) = opts.serve_ms {
        // Watchdog: detached on purpose — joining it would stall exit
        // for the full timeout when an HTTP shutdown lands first. Its
        // late send on a dead channel is harmless.
        let tx = cmd_tx.clone();
        std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(t));
            let _ = tx.send(GatewayCmd::Shutdown { resp: None });
        });
    }
    if let Some(t) = opts.chaos_kill_ms {
        let guard = fleet.lock().unwrap_or_else(|e| e.into_inner());
        match guard.as_ref() {
            Some(f) => {
                let victim = if f.len() > 1 { 1 } else { 0 };
                println!("chaos: killing loopback worker {victim} at t+{t}ms");
                timers.push(f.kill_after(victim, t));
            }
            None => {
                return Err(cdc_dnn::Error::Config(
                    "--chaos-kill-ms needs a spawned loopback fleet \
                     (tcp transport without --workers)"
                        .into(),
                ))
            }
        }
    }
    drop(cmd_tx); // remaining senders: HTTP thread + timer

    // Optional synthetic paced stream through the same pipeline; without
    // --rate-rps the gateway serves external requests only.
    let workload = match opts.rate_rps {
        Some(rate) => {
            let n = ctx.n_requests();
            let mut rng = Pcg32::seeded(seed);
            let inputs: Vec<Tensor> = (0..n)
                .map(|_| Tensor::randn(input_shape.clone(), &mut rng))
                .collect();
            println!("paced stream: {n} requests, poisson@{rate}rps");
            Workload::poisson(inputs, rate, seed)
        }
        None => Workload::poisson(Vec::new(), 1.0, seed),
    };

    let bridge = GatewayBridge { rx: cmd_rx };
    let t0 = std::time::Instant::now();
    let report = session.serve_gateway(&workload, &bridge)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("{}", report.line());
    println!("wall-clock latency: {}", latency_line(&report.latency_hist));
    println!(
        "wall-clock throughput: {:.1} rps (harness wall total {wall:.2}s)",
        report.rps()
    );
    let lost = report.failures.len() as u64 + report.dropped;
    if opts.expect_no_loss && lost > 0 {
        return Err(cdc_dnn::Error::Fleet(format!(
            "--expect-no-loss: {} lost, {} balked",
            report.failures.len(),
            report.dropped
        )));
    }
    for h in timers {
        let _ = h.join();
    }
    drop(server); // stop accepting before the backend goes away
    drop(session); // disconnect before the fleet reaps its children
    drop(fleet);
    Ok(())
}

/// Render the report's latency percentiles from the telemetry histogram
/// — the same estimator behind `GET /metrics` and `GET /v1/stats`
/// (DESIGN.md §16), so the CLI report and the live surfaces agree.
fn latency_line(h: &cdc_dnn::telemetry::Histogram) -> String {
    format!(
        "n={} mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms max={:.2}ms",
        h.count(),
        h.mean_ms(),
        h.quantile(0.50),
        h.quantile(0.95),
        h.quantile(0.99),
        h.max_ms()
    )
}

/// Run a standalone TCP shard-compute worker until killed (or told to
/// shut down over the wire).
fn run_worker(ctx: &ExpCtx, opts: &CliOpts) -> cdc_dnn::Result<()> {
    let mut w = worker::WorkerOptions::new(&ctx.artifacts);
    if let Some(l) = &opts.listen {
        w.listen = l.clone();
    }
    w.join = opts.join.clone();
    w.leave_after_ms = opts.leave_after_ms;
    if w.leave_after_ms.is_some() && w.join.is_none() {
        return Err(cdc_dnn::Error::Config(
            "--leave-after-ms only applies with --join".into(),
        ));
    }
    w.net = match opts.net.as_deref() {
        None => None,
        Some("ideal") => Some(NetConfig::ideal()),
        Some("moderate") => Some(NetConfig::moderate()),
        Some("congested") => Some(NetConfig::congested()),
        Some(other) => {
            return Err(cdc_dnn::Error::Config(format!(
                "unknown --net profile {other:?} (want ideal | moderate | congested)"
            )))
        }
    };
    w.rate_macs_per_ms = opts.rate;
    worker::run(&w)
}
