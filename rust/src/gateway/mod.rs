//! HTTP/1.1 serving gateway: the front door that turns the wall-clock CDC
//! pipeline into a service external clients can actually call.
//!
//! Three pieces, all zero-dependency:
//!
//! * [`http`] — the hand-rolled request parser / response encoder, with the
//!   same hardening discipline as `transport::wire` (pre-allocation caps,
//!   typed errors, never a panic on attacker bytes).
//! * [`server`] — a nonblocking accept/read/write event loop on the shared
//!   `transport::evloop` readiness core (`Poller`), one thread for every client
//!   connection. Parsed requests are routed into [`GatewayCmd`] values and
//!   sent over an mpsc channel into the live serve loop; replies come back
//!   over a per-server channel and a `UnixStream` waker.
//! * The serve-loop side ([`crate::coordinator::Session::serve_gateway`]) —
//!   drains the command channel every scheduling tick, admits external
//!   `POST /v1/infer` requests into the SAME micro-batching window as paced
//!   synthetic traffic, answers fleet/stats/policy reads inline, and defers
//!   lifecycle verbs (deploy / undeploy / migrate) to pipeline-quiescent
//!   points so they can never tear a batch in half.
//!
//! The gateway is only legal on a wall-clock transport: the simulated
//! timeline has no real "now" for an external socket to live on, and
//! keeping the gateway out of sim mode preserves sim bit-identity.

use std::io::Write as _;
use std::os::unix::net::UnixStream;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use crate::json::Value;
use crate::tensor::Tensor;

pub mod http;
pub mod server;

pub use server::{GatewayServer, ServerCtx};

/// Gateway listener settings (optional `gateway` section of a deployment
/// config; see `config::deployment_from_json`).
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// Bind address; port 0 picks an ephemeral port.
    pub listen: String,
    /// Cap on a single decoded request body, bytes (413 beyond it).
    pub max_body_bytes: usize,
    /// How long a routed request may wait on the pipeline before the
    /// connection gets a 504 and is closed.
    pub request_timeout_ms: u64,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            listen: "127.0.0.1:0".to_string(),
            max_body_bytes: 1 << 20,
            request_timeout_ms: 10_000,
        }
    }
}

/// A reply from the serve loop back to the HTTP event loop: which
/// connection + request it answers, and the JSON payload.
#[derive(Debug)]
pub struct HttpReply {
    pub conn: u64,
    pub seq: u64,
    pub status: u16,
    pub body: Value,
}

/// Reply handle embedded in every [`GatewayCmd`]. Sending never blocks and
/// never fails loudly: if the HTTP side is gone the reply is dropped, which
/// is exactly what a closed connection deserves.
#[derive(Debug, Clone)]
pub struct Responder {
    conn: u64,
    seq: u64,
    tx: Sender<HttpReply>,
    waker: Arc<UnixStream>,
}

impl Responder {
    pub(crate) fn new(
        conn: u64,
        seq: u64,
        tx: Sender<HttpReply>,
        waker: Arc<UnixStream>,
    ) -> Responder {
        Responder { conn, seq, tx, waker }
    }

    /// Deliver a JSON reply and kick the HTTP event loop awake.
    pub fn send(&self, status: u16, body: Value) {
        let _ = self.tx.send(HttpReply {
            conn: self.conn,
            seq: self.seq,
            status,
            body,
        });
        let _ = (&*self.waker).write(&[1u8]);
    }
}

/// Commands the HTTP front end injects into the live serve loop.
#[derive(Debug)]
pub enum GatewayCmd {
    /// `POST /v1/infer`: admit a real request into the pipeline alongside
    /// paced traffic. The reply carries logits once the request resolves.
    Infer { input: Tensor, resp: Responder },
    /// `GET /v1/fleet`: live membership + device rates + churn epoch.
    Fleet { resp: Responder },
    /// `GET /v1/stats`: serving metrics so far (bench-style).
    Stats { resp: Responder },
    /// `GET /v1/policy`: adaptive-redundancy `PolicyReport` snapshot.
    Policy { resp: Responder },
    /// `GET /v1/deployments`: model lifecycle state.
    Deployments { resp: Responder },
    /// `POST /v1/deployments`: (re)deploy the session's model.
    Deploy { model: String, resp: Responder },
    /// `DELETE /v1/deployments/<model>`: undeploy; infer turns 503.
    Undeploy { model: String, resp: Responder },
    /// `POST /v1/deployments/<model>/migrate`: move every task owned by
    /// `from` onto `to`, make-before-break, with zero request drops.
    Migrate { model: String, from: usize, to: usize, resp: Responder },
    /// `POST /v1/shutdown` (or CLI `--serve-ms` timer): finish in-flight
    /// work, answer every parked client, then return from serve.
    Shutdown { resp: Option<Responder> },
}

/// The serve loop's end of the gateway: a receiver it drains every tick.
pub struct GatewayBridge {
    pub rx: Receiver<GatewayCmd>,
}

/// Shorthand for the `{"error": ...}` payload shape every non-200 uses.
pub fn error_body(msg: impl Into<String>) -> Value {
    crate::json::obj(vec![("error", Value::Str(msg.into()))])
}
