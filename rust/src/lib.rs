//! # cdc-dnn — Robust distributed DNN inference with Coded Distributed Computing
//!
//! Reproduction of Hadidi, Cao & Kim, *"Creating Robust Deep Neural
//! Networks With Coded Distributed Computing for IoT Systems"* (2021).
//!
//! The crate is the L3 coordinator of a three-layer stack (see DESIGN.md):
//! JAX/Pallas author the per-device GEMM programs at build time; this crate
//! loads the AOT artifacts via PJRT, distributes single-batch inference
//! across a (simulated) IoT fleet with the paper's model-parallel splitting
//! methods, and makes the system robust to device failure/stragglers with
//! one extra *coded* device per layer whose weights are the offline sum of
//! the data shards — recovery is a local subtraction, cost is constant in
//! fleet size.

pub mod cdc;
pub mod coordinator;
pub mod bench;
pub mod config;
pub mod error;
pub mod exp;
pub mod fleet;
pub mod json;
pub mod kernels;
pub mod model;
pub mod partition;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod testkit;
pub mod tensor;

pub use error::{Error, Result};
pub use tensor::Tensor;
