# L2: the paper's compute graphs in JAX, calling the L1 Pallas kernels.
"""Full-model forwards, parameter init, and the per-device shard functions
that ``aot.py`` lowers to HLO artifacts.

CDC epilogue placement: the parity device computes Σ_d (W_d x + b_d), which
is linear — so recovery by subtraction is only valid on *pre-activation*
outputs. Shard artifacts therefore come in two flavors:

* ``relu=True``  — non-CDC fast path; activation (and pool) fused on-device.
* ``relu=False`` — CDC mode; devices ship pre-activation outputs and the
  merge point (rust ``tensor`` module) applies σ/pool after concat or after
  CDC recovery. The paper notes this freedom explicitly for channel
  splitting ("before or after activation function", §4).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from compile import layers
from compile.zoo import ModelDesc, layer_io_shapes


# ---------------------------------------------------------------------------
# Parameters


def init_params(model: ModelDesc, seed: int = 0) -> Dict[str, Tuple[np.ndarray, np.ndarray]]:
    """He-init conv (K,F,F,C) / fc (m,k) weights + zero biases per layer."""
    rng = np.random.default_rng(seed)
    params: Dict[str, Tuple[np.ndarray, np.ndarray]] = {}
    for layer, (inp, _out) in zip(model.layers, layer_io_shapes(model)):
        if layer.kind == "conv":
            c = inp[-1]
            fan_in = layer.f * layer.f * c
            w = rng.normal(0, np.sqrt(2.0 / fan_in),
                           size=(layer.k, layer.f, layer.f, c)).astype(np.float32)
            b = np.zeros(layer.k, np.float32)
        elif layer.kind == "fc":
            k = inp[0]
            w = rng.normal(0, np.sqrt(2.0 / k), size=(layer.m, k)).astype(np.float32)
            b = np.zeros(layer.m, np.float32)
        else:
            continue
        params[layer.name] = (w, b)
    return params


# ---------------------------------------------------------------------------
# Full-model forward (training, goldens, python-side oracle for rust e2e)


def forward(model: ModelDesc, params, x, *, interpret=True, taps=False):
    """Run the full graph on one input. ``x``: (H,W,C) or (k,) for fc models.

    With ``taps=True`` also returns the post-layer activations in graph
    order — used to cross-check the rust pipeline layer by layer.
    """
    acts = []
    cur = x if x.ndim > 1 else x.reshape(-1, 1)
    for layer in model.layers:
        if layer.kind == "conv":
            w, b = params[layer.name]
            cur = layers.conv2d(jnp.asarray(w), jnp.asarray(b), cur,
                                stride=layer.s, padding=layer.padding,
                                relu=layer.relu, interpret=interpret)
            if layer.pool:
                cur = layers.maxpool(cur, layer.pool, layer.pool)
        elif layer.kind == "maxpool":
            cur = layers.maxpool(cur, layer.pool, layer.pool)
        elif layer.kind == "flatten":
            cur = cur.reshape(-1, 1)
        elif layer.kind == "gap":
            cur = layers.avgpool_global(cur).reshape(-1, 1)
        elif layer.kind == "fc":
            w, b = params[layer.name]
            cur = layers.fc(jnp.asarray(w), jnp.asarray(b), cur,
                            relu=layer.relu, interpret=interpret)
        if taps:
            acts.append(cur)
    logits = cur.reshape(-1)
    return (logits, acts) if taps else logits


# ---------------------------------------------------------------------------
# Shard functions — what aot.py lowers. Weights are runtime *parameters*
# (not baked constants) so one executable serves every shard of that shape:
# the paper's "all weights on every device's SD card" task-switching model.


def fc_shard_fn(m_s: int, k: int, n: int, *, relu: bool):
    """Shard of an fc layer under output splitting (or the CDC parity —
    same shape, summed weights): (w, b, x) → w@x + b [relu]."""

    def fn(w, b, x):
        return (layers.fc(w, b.reshape(-1), x, relu=relu),)

    spec = (
        jax.ShapeDtypeStruct((m_s, k), jnp.float32),
        jax.ShapeDtypeStruct((m_s, 1), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
    )
    return fn, spec


def conv_shard_fn(h: int, w_: int, c: int, k_s: int, f: int, stride: int,
                  padding: str, *, relu: bool, pool: int):
    """Shard of a conv layer under channel splitting: the device holds a
    row-slice (its filters) of the unrolled filter matrix and the *full*
    input; emits its slice of the output depth (paper Fig. 8).

    (wmat (k_s, f²c), b (k_s,1), x (h,w,c)) → (oh', ow', k_s); pool only in
    the non-CDC flavor (pool is nonlinear, so CDC shards defer it).
    """

    def fn(wmat, b, x):
        cols, (oh, ow) = layers.im2col(x, f, f, stride, padding)
        out = layers.gemm(wmat, cols, b, relu=relu)
        out = out.reshape(k_s, oh, ow).transpose(1, 2, 0)
        if pool:
            out = layers.maxpool(out, pool, pool)
        return (out,)

    spec = (
        jax.ShapeDtypeStruct((k_s, f * f * c), jnp.float32),
        jax.ShapeDtypeStruct((k_s, 1), jnp.float32),
        jax.ShapeDtypeStruct((h, w_, c), jnp.float32),
    )
    return fn, spec


def maxpool_fn(h: int, w_: int, c: int, size: int):
    """Standalone pool artifact (merge-side pool for CDC conv layers)."""

    def fn(x):
        return (layers.maxpool(x, size, size),)

    return fn, (jax.ShapeDtypeStruct((h, w_, c), jnp.float32),)


def filters_to_matrix(w: np.ndarray) -> np.ndarray:
    """numpy twin of layers.filters_to_matrix for weight preparation."""
    k, fh, fw, c = w.shape
    return np.ascontiguousarray(w.transpose(0, 3, 1, 2).reshape(k, c * fh * fw))
