"""Model zoo: layer-graph descriptors for the DNNs used in the paper's
evaluation, scaled to CPU-runnable sizes (DESIGN.md §2 substitutions).

* ``lenet5``   — exact LeNet-5 structure (trained; Fig. 2a).
* ``deepnet``  — deeper CNN standing in for Inception v3 (trained; Fig. 2b:
                 the claim reproduced is the *ordering* — deeper/more general
                 models are more sensitive to activation loss).
* ``alexnet``  — AlexNet-class structure (case studies I/II, Fig. 11-15).
* ``vgg16``    — VGG16-class structure (coverage study, Fig. 17).
* ``c3d``      — C3D-class structure with two large fc layers (Fig. 17c/d,
                 the two-model-parallel-layer deployment).
* ``fc2048``   — the single 2048-wide fc micro-model of Fig. 1 / §6.

Descriptors are the single source of truth: ``aot.py`` serialises them into
``artifacts/manifest.json`` and the rust ``model`` module loads them from
there, so the two languages cannot drift.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LayerDesc:
    """One layer of a model graph (kinds: conv, fc, maxpool, flatten, gap)."""

    name: str
    kind: str
    # conv: filters k, size f, stride s; fc: out_features m
    k: int = 0
    f: int = 0
    s: int = 1
    m: int = 0
    relu: bool = True
    padding: str = "SAME"
    pool: int = 0  # maxpool window/stride

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        return d


@dataclasses.dataclass(frozen=True)
class ModelDesc:
    name: str
    input_shape: Tuple[int, ...]  # (H, W, C) or (K,) for pure-fc models
    layers: Tuple[LayerDesc, ...]
    classes: int
    trained: bool = False

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "input_shape": list(self.input_shape),
            "classes": self.classes,
            "trained": self.trained,
            "layers": [l.to_json() for l in self.layers],
        }


def conv(name, k, f, s=1, pool=0, relu=True, padding="SAME"):
    return LayerDesc(name, "conv", k=k, f=f, s=s, pool=pool, relu=relu,
                     padding=padding)


def fc(name, m, relu=True):
    return LayerDesc(name, "fc", m=m, relu=relu)


def maxpool(name, size=2):
    return LayerDesc(name, "maxpool", pool=size)


def flatten(name="flatten"):
    return LayerDesc(name, "flatten")


def gap(name="gap"):
    return LayerDesc(name, "gap")


LENET5 = ModelDesc(
    "lenet5",
    (28, 28, 1),
    (
        conv("conv1", k=6, f=5, pool=2),
        conv("conv2", k=16, f=5, pool=2),
        flatten(),
        fc("fc1", 120),
        fc("fc2", 84),
        fc("fc3", 10, relu=False),
    ),
    classes=10,
    trained=True,
)

DEEPNET = ModelDesc(
    "deepnet",
    (28, 28, 1),
    (
        conv("conv1a", k=16, f=3),
        conv("conv1b", k=16, f=3, pool=2),
        conv("conv2a", k=32, f=3),
        conv("conv2b", k=32, f=3, pool=2),
        conv("conv3a", k=48, f=3),
        conv("conv3b", k=48, f=3),
        gap(),
        fc("fc1", 64),
        fc("fc2", 10, relu=False),
    ),
    classes=10,
    trained=True,
)

# AlexNet-class: conv trunk scaled for CPU, but fc6/fc7 kept *RPi-heavy*
# (fc6 = 4096×4096 ≈ 16.8M MACs ≈ 200 ms on an RPi) so the case studies'
# failover/straggler effects are compute-dominant like the paper's real
# AlexNet (whose fc6 is 38M MACs) rather than drowned in WiFi jitter.
ALEXNET = ModelDesc(
    "alexnet",
    (32, 32, 3),
    (
        conv("conv1", k=16, f=5, pool=2),
        conv("conv2", k=32, f=5, pool=2),
        conv("conv3", k=48, f=3),
        conv("conv4", k=48, f=3),
        conv("conv5", k=64, f=3),
        flatten(),
        fc("fc6", 4096),
        fc("fc7", 1024),
        fc("fc8", 10, relu=False),
    ),
    classes=10,
)

VGG16 = ModelDesc(
    "vgg16",
    (32, 32, 3),
    (
        conv("conv1_1", k=8, f=3),
        conv("conv1_2", k=8, f=3, pool=2),
        conv("conv2_1", k=16, f=3),
        conv("conv2_2", k=16, f=3, pool=2),
        conv("conv3_1", k=32, f=3),
        conv("conv3_2", k=32, f=3),
        conv("conv3_3", k=32, f=3, pool=2),
        conv("conv4_1", k=64, f=3),
        conv("conv4_2", k=64, f=3),
        conv("conv4_3", k=64, f=3, pool=2),
        conv("conv5_1", k=64, f=3),
        conv("conv5_2", k=64, f=3),
        conv("conv5_3", k=64, f=3, pool=2),
        flatten(),
        fc("fc1", 256),
        fc("fc2", 256),
        fc("fc3", 10, relu=False),
    ),
    classes=10,
)

# C3D stand-in: the coverage study (Fig. 17c/d) only needs its *shape* —
# a conv trunk plus two large fc layers that are distributed with model
# parallelism. 3D convs are collapsed to 2D (DESIGN.md §2).
C3D = ModelDesc(
    "c3d",
    (32, 32, 3),
    (
        conv("conv1", k=16, f=3, pool=2),
        conv("conv2", k=32, f=3, pool=2),
        conv("conv3a", k=48, f=3),
        conv("conv3b", k=48, f=3, pool=2),
        conv("conv4a", k=64, f=3),
        conv("conv4b", k=64, f=3, pool=2),
        flatten(),
        fc("fc6", 512),
        fc("fc7", 512),
        fc("fc8", 10, relu=False),
    ),
    classes=10,
)

# Fig. 1 / §6 anchor: a single fully-connected layer "of size 2048".
FC2048 = ModelDesc(
    "fc2048",
    (2048,),
    (fc("fc", 2048, relu=True),),
    classes=2048,
)

ZOO = {m.name: m for m in (LENET5, DEEPNET, ALEXNET, VGG16, C3D, FC2048)}


def layer_io_shapes(model: ModelDesc) -> List[Tuple[Tuple[int, ...], Tuple[int, ...]]]:
    """Propagate shapes through the graph; returns [(in_shape, out_shape)]."""
    shapes = []
    cur: Tuple[int, ...] = model.input_shape
    for layer in model.layers:
        inp = cur
        if layer.kind == "conv":
            h, w, _c = cur
            if layer.padding == "SAME":
                oh, ow = -(-h // layer.s), -(-w // layer.s)
            else:
                oh = (h - layer.f) // layer.s + 1
                ow = (w - layer.f) // layer.s + 1
            cur = (oh, ow, layer.k)
            if layer.pool:
                cur = (cur[0] // layer.pool, cur[1] // layer.pool, layer.k)
        elif layer.kind == "maxpool":
            h, w, c = cur
            cur = (h // layer.pool, w // layer.pool, c)
        elif layer.kind == "flatten":
            n = 1
            for d in cur:
                n *= d
            cur = (n,)
        elif layer.kind == "gap":
            cur = (cur[-1],)
        elif layer.kind == "fc":
            cur = (layer.m,)
        else:  # pragma: no cover
            raise ValueError(f"unknown layer kind {layer.kind}")
        shapes.append((inp, cur))
    return shapes


def layer_flops(model: ModelDesc) -> List[int]:
    """MAC count per layer — the cost model used for balanced assignment
    and for the fleet simulator's compute-time scaling."""
    out = []
    for layer, (inp, outp) in zip(model.layers, layer_io_shapes(model)):
        if layer.kind == "conv":
            oh, ow = (outp[0] * layer.pool, outp[1] * layer.pool) if layer.pool else outp[:2]
            out.append(layer.k * layer.f * layer.f * inp[-1] * oh * ow)
        elif layer.kind == "fc":
            out.append(layer.m * inp[0])
        else:
            out.append(0)
    return out
