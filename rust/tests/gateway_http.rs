//! Gateway HTTP parser hardening (ISSUE 8): a seeded mutation-fuzz
//! battery in the `transport_wire.rs` corpus style. Start from a corpus
//! of well-formed requests (request lines, header blocks, fixed-length
//! and chunked bodies), apply random mutations — bit flips, byte
//! overwrites, truncations, garbage extensions — and require that every
//! mutant parses to `Complete`, `Partial`, or a *typed* `HttpError`.
//! Never a panic, and never an attacker-sized allocation (the parser
//! rejects oversized declarations before reserving memory).

use cdc_dnn::gateway::http::{self, Parsed};
use cdc_dnn::rng::Pcg32;

/// Well-formed seeds covering every parser path: simple GET, POST with
/// Content-Length, chunked POST (multi-chunk), many-header GET, DELETE,
/// HTTP/1.0 with explicit keep-alive, and a pipelined pair.
fn corpus() -> Vec<Vec<u8>> {
    let mut c: Vec<Vec<u8>> = vec![
        b"GET /v1/healthz HTTP/1.1\r\nHost: x\r\n\r\n".to_vec(),
        b"POST /v1/infer HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\nContent-Length: 18\r\n\r\n{\"input\":[1,2,3]}\n".to_vec(),
        b"POST /v1/infer HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n7\r\n{\"input\r\nA\r\n\":[1,2,3]}\r\n0\r\n\r\n".to_vec(),
        b"DELETE /v1/deployments/mlp HTTP/1.1\r\nHost: x\r\n\r\n".to_vec(),
        b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n".to_vec(),
        b"POST /v1/shutdown HTTP/1.1\r\nContent-Length: 0\r\nConnection: close\r\n\r\n".to_vec(),
    ];
    // Many-header request (still under MAX_HEADERS).
    let mut many = b"GET /v1/stats HTTP/1.1\r\n".to_vec();
    for i in 0..40 {
        many.extend_from_slice(format!("X-H{i}: v{i}\r\n").as_bytes());
    }
    many.extend_from_slice(b"\r\n");
    c.push(many);
    // Pipelined pair in one buffer.
    let mut pair = c[0].clone();
    pair.extend_from_slice(&c[3]);
    c.push(pair);
    c
}

const MAX_BODY: usize = 1 << 20;

/// The property every input — however mangled — must satisfy.
fn assert_never_panics(bytes: &[u8]) {
    match http::parse_request(bytes, MAX_BODY) {
        Ok(Parsed::Complete { consumed, .. }) => {
            assert!(consumed <= bytes.len(), "consumed past the buffer");
            assert!(consumed > 0, "complete request consumed nothing");
        }
        Ok(Parsed::Partial) => {}
        Err(e) => {
            assert!(
                (400..=599).contains(&e.status),
                "error status {} outside 4xx/5xx",
                e.status
            );
            assert!(!e.msg.is_empty(), "typed error with empty message");
        }
    }
}

fn mutate(rng: &mut Pcg32, seed: &[u8]) -> Vec<u8> {
    let mut m = seed.to_vec();
    for _ in 0..(1 + rng.below(4)) {
        match rng.below(4) {
            // Bit flip.
            0 if !m.is_empty() => {
                let i = rng.below(m.len());
                m[i] ^= 1 << rng.below(8);
            }
            // Byte overwrite (full range, including CR/LF/NUL).
            1 if !m.is_empty() => {
                let i = rng.below(m.len());
                m[i] = rng.below(256) as u8;
            }
            // Truncate.
            2 if !m.is_empty() => {
                let i = rng.below(m.len());
                m.truncate(i);
            }
            // Extend with garbage.
            _ => {
                for _ in 0..(1 + rng.below(8)) {
                    m.push(rng.below(256) as u8);
                }
            }
        }
    }
    m
}

#[test]
fn corpus_parses_clean() {
    for (i, seed) in corpus().iter().enumerate() {
        match http::parse_request(seed, MAX_BODY) {
            Ok(Parsed::Complete { .. }) => {}
            other => panic!("corpus[{i}] did not parse: {other:?}"),
        }
    }
    // Every strict prefix of a valid request is Partial or a typed error
    // (it can never be Complete: the seed is exactly one request).
    let seed = &corpus()[1];
    for cut in 0..seed.len() {
        match http::parse_request(&seed[..cut], MAX_BODY) {
            Ok(Parsed::Partial) | Err(_) => {}
            Ok(Parsed::Complete { .. }) => {
                panic!("prefix of length {cut} parsed as complete")
            }
        }
    }
}

#[test]
fn mutation_fuzz_2000_mutants_no_panics() {
    let corpus = corpus();
    let mut rng = Pcg32::seeded(0x6a7e);
    for round in 0..2000u32 {
        let seed = &corpus[(round as usize) % corpus.len()];
        let mutant = mutate(&mut rng, seed);
        assert_never_panics(&mutant);
    }
}

#[test]
fn pure_garbage_never_panics() {
    let mut rng = Pcg32::seeded(0xbad);
    for _ in 0..500 {
        let len = rng.below(256);
        let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        assert_never_panics(&bytes);
    }
}

#[test]
fn adversarial_declarations_bounded() {
    // A gigantic Content-Length must be rejected as 413 *before* any
    // body-sized allocation happens — the test would OOM otherwise.
    let huge = b"POST / HTTP/1.1\r\nContent-Length: 999999999999\r\n\r\n";
    let e = match http::parse_request(huge, MAX_BODY) {
        Err(e) => e,
        other => panic!("{other:?}"),
    };
    assert!(e.status == 413 || e.status == 400, "{e}");

    // Ditto for an absurd chunk-size declaration.
    let chunk = b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\nffffffff\r\n";
    match http::parse_request(chunk, MAX_BODY) {
        Err(e) => assert_eq!(e.status, 413, "{e}"),
        Ok(Parsed::Partial) => panic!("oversized chunk not rejected"),
        other => panic!("{other:?}"),
    }

    // Header flood: more than MAX_HEADERS distinct headers is a 431.
    let mut flood = b"GET / HTTP/1.1\r\n".to_vec();
    for i in 0..(http::MAX_HEADERS + 1) {
        flood.extend_from_slice(format!("X-{i}: y\r\n").as_bytes());
    }
    flood.extend_from_slice(b"\r\n");
    match http::parse_request(&flood, MAX_BODY) {
        Err(e) => assert_eq!(e.status, 431, "{e}"),
        other => panic!("{other:?}"),
    }

    // Smuggling: Content-Length together with Transfer-Encoding is 400.
    let smuggle =
        b"POST / HTTP/1.1\r\nContent-Length: 3\r\nTransfer-Encoding: chunked\r\n\r\n";
    match http::parse_request(smuggle, MAX_BODY) {
        Err(e) => assert_eq!(e.status, 400, "{e}"),
        other => panic!("{other:?}"),
    }
}
