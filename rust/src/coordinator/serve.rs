//! Pipelined multi-request serving engine (DESIGN.md §5).
//!
//! The paper frames its robustness results in terms of *pipelined
//! steady-state serving*: each distributed stage holds one request at a
//! time, so with S stages up to S requests are in flight and the request
//! rate is limited by the slowest stage — which is exactly why Case Study
//! I's failover (one device running two fc6 shards serially) manifests as
//! a ~2.4× throughput hit. This module makes that pipeline real instead
//! of proxying it through `RequestTrace::bottleneck_ms`.
//!
//! ## Model
//!
//! An event-driven scheduler over **virtual time**: requests are admitted
//! from a [`Workload`] (open-loop Poisson/uniform/explicit arrivals or a
//! closed-loop concurrency window), queue FIFO in front of each
//! distributed [`Stage`](super::stage::Stage), and occupy a stage
//! exclusively from dispatch to resolution. Back-pressure is structural —
//! a request cannot enter stage *s* while its predecessor holds it, so
//! head-of-line blocking propagates upstream into the admission queue
//! (whose depth an optional `admission_cap` bounds by balking arrivals).
//! Devices shared by several stages serialise their compute through the
//! per-device occupancy ledger (`fleet::WorkOrder::not_before_ms`).
//!
//! Scheduling decisions depend only on virtual timestamps, never on
//! wall-clock arrival order of thread completions, so a seed + workload
//! determines the whole [`ServeReport`] bit-for-bit. Real PJRT (or
//! interpreter) compute still runs for every shard of every request —
//! outputs are exact, only time is simulated.
//!
//! One approximation: when two stages share a device, the ledger orders
//! their compute by dispatch order (sorted by virtual entry time within a
//! scheduling round); dispatches from different rounds can be ledger-
//! ordered against virtual-time order by at most one stage service.
//!
//! ## Cross-request micro-batching (DESIGN.md §10)
//!
//! With `SessionConfig::batch_max > 1`, a free fc stage coalesces up to
//! that many queued requests into **one** batched order: the input is
//! the column concatenation of the member activations, every device
//! runs one wider GEMM, and the CDC parity covers the whole batch in a
//! single pass, so the per-order fixed costs (dispatch, request leg,
//! reply base latency, parity resolution) amortise across the members.
//! `batch_wait_ms` bounds how long a stage may hold its head request
//! waiting for the batch to fill; `0` is pure pass-through. Batch
//! membership is decided when the stage frees (round granularity) — a
//! request that becomes ready inside another batch's window but after
//! its formation waits for the next order. `batch_max = 1` is bit-exact
//! with the unbatched engine, and a lost batched stage loses (and
//! accounts) every member.
//!
//! ## Wall-clock transports (DESIGN.md §11)
//!
//! The same scheduler drives real TCP worker fleets. Three hooks — all
//! no-ops on the simulator, so sim-mode scheduling stays bit-identical
//! — adapt it to a clock that actually advances:
//!
//! * entry times are clamped to "not in the past" on the transport
//!   clock (`Transport::clamp_ms`);
//! * a dispatch whose entry time lies in the future (an open-loop
//!   arrival not yet due, or an unfilled batch window) is **deferred**
//!   while other stages hold work — the gather phase wakes at its due
//!   time (`Transport::recv_deadline`) — and only **sleeps**
//!   (`Transport::pace`) when nothing is in flight, so pacing never
//!   head-of-line blocks resolution;
//! * completions are gathered **eagerly**: the engine resolves a stage
//!   as soon as *that stage's* completions are in, instead of waiting
//!   for every busy stage (which is free in virtual time but would
//!   lock-step a real pipeline).
//!
//! Losses need no special path: the transport synthesises `∞`-stamped
//! completions for deadline-overrun or connection-death tasks, so the
//! policy/CDC layers below see exactly the simulator's shapes.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::fleet::Completion;
use crate::gateway::{error_body, GatewayBridge, GatewayCmd, Responder};
use crate::json::{self, Value};
use crate::kernels::Scratch;
use crate::metrics::{self, Intervals, Series, Throughput};
use crate::rng::Pcg32;
use crate::runtime::manifest::ModelManifest;
use crate::tensor::Tensor;

use super::stage::{Stage, StageKind, StageOutcome};
use super::{RequestTrace, Session};

/// Arrival process of a workload.
#[derive(Debug, Clone)]
pub enum Arrivals {
    /// Open loop: i.i.d. exponential inter-arrival times at `rate_rps`
    /// requests/second (the classic Poisson arrival stream).
    Poisson { rate_rps: f64 },
    /// Open loop: fixed inter-arrival gap in ms (0 = all at t=0).
    Uniform { gap_ms: f64 },
    /// Open loop: explicit arrival instants (ms), one per input in
    /// non-decreasing order — the scenario engine's segment streams
    /// (Poisson tails with burst spikes spliced in).
    Explicit { at_ms: Vec<f64> },
    /// Closed loop: `concurrency` requests outstanding; each completion
    /// (or loss) admits the next.
    Closed { concurrency: usize },
}

/// A serving workload: inputs plus how they arrive.
#[derive(Debug, Clone)]
pub struct Workload {
    pub inputs: Vec<Tensor>,
    pub arrivals: Arrivals,
    /// Seed for the arrival process (open-loop Poisson).
    pub seed: u64,
    /// Open-loop only: max requests waiting for the entry stage; an
    /// arrival finding the queue full balks (is dropped), bounding
    /// queueing delay under overload.
    pub admission_cap: Option<usize>,
}

impl Workload {
    /// Closed-loop workload with a fixed concurrency window.
    pub fn closed(inputs: Vec<Tensor>, concurrency: usize) -> Workload {
        Workload {
            inputs,
            arrivals: Arrivals::Closed { concurrency: concurrency.max(1) },
            seed: 0,
            admission_cap: None,
        }
    }

    /// Open-loop Poisson workload at `rate_rps` requests/second.
    pub fn poisson(inputs: Vec<Tensor>, rate_rps: f64, seed: u64) -> Workload {
        Workload {
            inputs,
            arrivals: Arrivals::Poisson { rate_rps },
            seed,
            admission_cap: None,
        }
    }

    /// Open-loop workload with fixed inter-arrival gap (ms).
    pub fn uniform(inputs: Vec<Tensor>, gap_ms: f64) -> Workload {
        Workload {
            inputs,
            arrivals: Arrivals::Uniform { gap_ms },
            seed: 0,
            admission_cap: None,
        }
    }

    /// One request, admitted at t=0 — `Session::infer`'s workload.
    pub fn single(input: Tensor) -> Workload {
        Workload::closed(vec![input], 1)
    }

    /// Open-loop workload with explicit arrival instants (ms), one per
    /// input. Instants should be non-decreasing: admission order is input
    /// order.
    pub fn explicit(inputs: Vec<Tensor>, at_ms: Vec<f64>) -> Workload {
        Workload {
            inputs,
            arrivals: Arrivals::Explicit { at_ms },
            seed: 0,
            admission_cap: None,
        }
    }

    /// Bound the entry-stage queue (open loop).
    pub fn with_admission_cap(mut self, cap: usize) -> Workload {
        self.admission_cap = Some(cap);
        self
    }
}

/// Per-stage serving statistics.
#[derive(Debug, Clone)]
pub struct StageStats {
    pub layer: String,
    /// Requests this stage served to completion.
    pub served: usize,
    /// Batched orders this stage dispatched (== `served` when
    /// micro-batching is off; smaller when batches formed).
    pub batches: usize,
    /// Total virtual time the stage was occupied.
    pub busy_ms: f64,
    /// busy_ms / makespan.
    pub utilization: f64,
    /// The raw occupancy trace (one interval per batched order held).
    pub occupancy: Intervals,
}

/// Everything a pipeline run measured.
#[derive(Debug)]
pub struct ServeReport {
    /// Completed requests in completion order (outputs are exact).
    pub traces: Vec<RequestTrace>,
    /// Lost requests: (request id, layer it was lost at).
    pub failures: Vec<(u64, String)>,
    /// Open-loop arrivals that balked at a full admission queue.
    pub dropped: u64,
    /// End-to-end latency per completed request (arrival → done).
    pub latency: Series,
    /// The same latencies folded into a [`telemetry::Histogram`] — the
    /// estimator behind `GET /metrics` and `GET /v1/stats`, so report
    /// percentiles and the live surfaces share one computation
    /// (DESIGN.md §16).
    ///
    /// [`telemetry::Histogram`]: crate::telemetry::Histogram
    pub latency_hist: crate::telemetry::Histogram,
    /// Service latency (first dispatch → done, excludes queue wait).
    pub service: Series,
    /// Admission-queue wait (arrival → first dispatch).
    pub queue_wait: Series,
    /// completed/failed/recovered counters over the makespan.
    pub throughput: Throughput,
    /// Virtual time from t=0 to the last completion/give-up.
    pub makespan_ms: f64,
    /// Per-distributed-stage statistics, in pipeline order.
    pub stages: Vec<StageStats>,
    /// Peak number of requests simultaneously holding stages.
    pub max_concurrent_requests: usize,
    /// Peak number of simultaneously-busy stages.
    pub max_concurrent_stages: usize,
    /// Widest cross-request micro-batch any stage dispatched (1 when
    /// batching is off or never engaged — DESIGN.md §10).
    pub max_batch: usize,
    /// Adaptive-policy snapshot at the end of the run (None when the
    /// session runs the static straggler gate) — the tuned gate factor,
    /// observed drop rate, and the parity-vs-replication recommendation.
    pub policy: Option<super::policy::PolicyReport>,
    /// SIMD micro-kernel tier the coordinator-side interpreter ran on
    /// (`avx2` / `neon` / `scalar`, DESIGN.md §15) — attribution so a
    /// recorded number can always be traced to the kernel that made it.
    pub kernel_tier: &'static str,
    /// Numeric precision of the fc shard tasks (`f32` / `int8`).
    pub precision: &'static str,
}

impl ServeReport {
    /// Measured steady-state throughput (requests/second of virtual time).
    pub fn rps(&self) -> f64 {
        self.throughput.rps()
    }

    /// One-line summary for experiment logs.
    pub fn line(&self) -> String {
        format!(
            "served={} failed={} dropped={} recovered={} rps={:.2} \
             makespan={:.0}ms max_in_flight={} tier={} precision={}",
            self.throughput.completed,
            self.throughput.failed,
            self.dropped,
            self.throughput.recovered,
            self.rps(),
            self.makespan_ms,
            self.max_concurrent_requests,
            self.kernel_tier,
            self.precision,
        )
    }
}

/// Handle for driving a session's serving pipeline.
pub struct Pipeline<'a> {
    session: &'a mut Session,
}

impl<'a> Pipeline<'a> {
    /// Wrap a deployed session.
    pub fn new(session: &'a mut Session) -> Pipeline<'a> {
        Pipeline { session }
    }

    /// Run a workload through the pipeline; see [`Session::serve`].
    pub fn run(&mut self, workload: &Workload) -> Result<ServeReport> {
        self.session.serve(workload)
    }
}

/// One request's progress through the pipeline.
struct InFlight {
    req: u64,
    t_arrival: f64,
    /// NaN until the first distributed dispatch.
    t_first_start: f64,
    t_ready: f64,
    stage_idx: usize,
    /// Current activation, `Arc`-shared with in-flight device work so a
    /// stage dispatch never copies the tensor payload.
    cur: Arc<Tensor>,
    layers: Vec<super::LayerTrace>,
    any_recovery: bool,
}

/// Take the activation out of its `Arc` without copying when uniquely
/// owned — the common case, since device threads drop their handle as
/// soon as the shard executes.
fn take_owned(cur: &mut Arc<Tensor>) -> Tensor {
    let arc = std::mem::replace(cur, Arc::new(Tensor::zeros(vec![0])));
    Arc::try_unwrap(arc).unwrap_or_else(|shared| shared.as_ref().clone())
}

/// A dispatched (stage, batch) pair awaiting completions. `members`
/// lists the in-flight requests riding the order, in queue order; the
/// first is the batch leader whose request id completions route by.
struct BusyStage {
    members: Vec<usize>,
    /// The column-concatenated batch input (width > 1 only), kept so its
    /// scratch buffer can be reclaimed at resolve time — by then the
    /// devices have usually dropped their handles.
    batched_input: Option<Arc<Tensor>>,
    t_enter: f64,
    n_expected: usize,
    /// Partition epoch the order was dispatched under (DESIGN.md §13).
    /// Completions are only folded in while the epoch is current —
    /// membership repartitions happen at quiescent points, so this is a
    /// belt-and-braces guard against a late reply from an old partition
    /// corrupting a fresh stage's gather set.
    epoch: u64,
    got: BTreeMap<u64, Completion>,
}

/// Column-concatenate member activations into one batched GEMM input:
/// `B` rank-2 `(k, 1)` columns become one row-major `(k, B)` matrix
/// whose column `j` is member `j`. The buffer comes from the scratch
/// arena and is reclaimed into it when the order resolves (best effort:
/// a device thread still holding its handle lets the buffer free
/// normally instead).
fn concat_columns(members: &[&Tensor], scratch: &mut Scratch) -> Result<Tensor> {
    let first = members
        .first()
        .ok_or_else(|| Error::Config("batch of zero members".into()))?;
    let k = match first.shape()[..] {
        [k, 1] => k,
        _ => {
            return Err(Error::Shape(format!(
                "batch member must be a (k, 1) column, got {:?}",
                first.shape()
            )))
        }
    };
    let b = members.len();
    let mut buf = scratch.take(k * b);
    for (j, m) in members.iter().enumerate() {
        if m.shape() != [k, 1] {
            return Err(Error::Shape(format!(
                "batch member shape {:?} vs leader (k={k}, 1)",
                m.shape()
            )));
        }
        for (r, &v) in m.data().iter().enumerate() {
            buf[r * b + j] = v;
        }
    }
    Tensor::new(vec![k, b], buf)
}

/// Split a batched `(m, B)` stage output back into its `B` per-member
/// `(m, 1)` columns (scratch-backed); the batched buffer is recycled.
fn split_columns(batched: Tensor, b: usize, scratch: &mut Scratch) -> Result<Vec<Tensor>> {
    let m = match batched.shape()[..] {
        [m, bb] if bb == b => m,
        _ => {
            return Err(Error::Shape(format!(
                "batched output {:?} vs batch width {b}",
                batched.shape()
            )))
        }
    };
    let data = batched.data();
    let mut out = Vec::with_capacity(b);
    for j in 0..b {
        let mut buf = scratch.take(m);
        for (r, slot) in buf.iter_mut().enumerate() {
            *slot = data[r * b + j];
        }
        out.push(Tensor::new(vec![m, 1], buf)?);
    }
    scratch.put(batched.into_data());
    Ok(out)
}

fn reshape_input(model: &ModelManifest, input: &Tensor) -> Result<Tensor> {
    if model.input_shape.len() == 1 {
        input.clone().reshape(vec![input.len(), 1])
    } else {
        Ok(input.clone())
    }
}

/// Run `fl` through consecutive local (free) stages; true when the
/// request ran off the end of the pipeline (finished).
fn advance_locals(
    stages: &[Stage],
    model: &ModelManifest,
    fl: &mut InFlight,
    scratch: &mut Scratch,
) -> Result<bool> {
    while fl.stage_idx < stages.len() {
        match &stages[fl.stage_idx].kind {
            StageKind::Local { layer_idx } => {
                let layer = &model.layers[*layer_idx];
                let cur = take_owned(&mut fl.cur);
                fl.cur = Arc::new(super::stage::apply_local(layer, cur, scratch)?);
                fl.stage_idx += 1;
            }
            StageKind::Dist(_) => return Ok(false),
        }
    }
    Ok(true)
}

impl Session {
    /// Drive a whole workload through the distributed model with many
    /// requests in flight; returns measured throughput, latency
    /// percentiles, and per-stage occupancy. `Session::infer` is the
    /// single-request special case of this engine.
    pub fn serve(&mut self, workload: &Workload) -> Result<ServeReport> {
        // Detach the serve-path arena from `self` so stage resolution can
        // borrow it mutably alongside `self.stages`; restore it on every
        // exit path (an error mid-run must not drop the warmed pool).
        self.transport.begin_serve();
        let mut scratch = std::mem::take(&mut self.scratch);
        let result = self.serve_inner(workload, None, &mut scratch);
        self.scratch = scratch;
        result
    }

    /// Like [`Session::serve`], but with a live HTTP gateway attached
    /// (DESIGN.md §14): external `POST /v1/infer` requests are admitted
    /// into the *same* micro-batching window as the workload's paced
    /// traffic, fleet/stats/policy reads answer inline from the running
    /// loop, and lifecycle verbs (deploy / undeploy / migrate) execute at
    /// pipeline-quiescent points — the same instants membership changes
    /// fold in, so they can never tear a batch in half. Returns once a
    /// shutdown command has been received and the pipeline has drained.
    ///
    /// Wall-clock transports only: the simulated timeline has no real
    /// "now" for an external socket to live on, and refusing sim here
    /// keeps sim-mode scheduling bit-identical by construction.
    pub fn serve_gateway(
        &mut self,
        workload: &Workload,
        gw: &GatewayBridge,
    ) -> Result<ServeReport> {
        if !self.transport.wall_clock() {
            return Err(Error::Config(
                "the gateway requires a wall-clock transport (tcp): \
                 the simulator has no real timeline for external clients"
                    .into(),
            ));
        }
        self.transport.begin_serve();
        let mut scratch = std::mem::take(&mut self.scratch);
        let result = self.serve_inner(workload, Some(gw), &mut scratch);
        self.scratch = scratch;
        result
    }

    fn serve_inner(
        &mut self,
        workload: &Workload,
        gateway: Option<&GatewayBridge>,
        scratch: &mut Scratch,
    ) -> Result<ServeReport> {
        let total = workload.inputs.len();
        let n_stages = self.stages.len();
        let first_dist = self.stages.iter().position(|s| s.is_distributed());
        // Wall-clock transports pace dispatches and gather eagerly; the
        // simulator keeps its round-synchronous virtual-time gather
        // (bit-identical to the pre-transport engine).
        let wall = self.transport.wall_clock();
        // Telemetry registry (DESIGN.md §16), `Arc`-shared with the
        // gateway's HTTP thread. Recording is relaxed-atomic or one
        // short mutex hold — it never influences scheduling decisions,
        // so sim-mode determinism is untouched.
        let tel = Arc::clone(&self.telemetry);

        let first_req = self.next_req;
        self.next_req += total as u64;

        // Open-loop arrival schedule (closed loop assigns arrivals at
        // admission time).
        let open_arrivals: Vec<f64> = match workload.arrivals {
            Arrivals::Poisson { rate_rps } => {
                let mut rng = Pcg32::new(workload.seed, 0x4a1);
                let per_ms = (rate_rps / 1000.0).max(1e-12);
                let mut t = 0.0;
                (0..total)
                    .map(|_| {
                        t += rng.exponential(per_ms);
                        t
                    })
                    .collect()
            }
            Arrivals::Uniform { gap_ms } => {
                (0..total).map(|i| i as f64 * gap_ms).collect()
            }
            Arrivals::Explicit { ref at_ms } => {
                if at_ms.len() != total {
                    return Err(Error::Config(format!(
                        "explicit arrivals: {} instants for {} inputs",
                        at_ms.len(),
                        total
                    )));
                }
                at_ms.clone()
            }
            Arrivals::Closed { .. } => Vec::new(),
        };
        let closed_c = match workload.arrivals {
            Arrivals::Closed { concurrency } => Some(concurrency.max(1)),
            _ => None,
        };

        // ---- scheduler state -----------------------------------------
        let mut inflight: Vec<InFlight> = Vec::with_capacity(total);
        let mut stage_queue: Vec<VecDeque<usize>> = vec![VecDeque::new(); n_stages];
        let mut stage_free = vec![0.0f64; n_stages];
        let mut stage_busy: Vec<Option<BusyStage>> =
            (0..n_stages).map(|_| None).collect();
        let mut req_to_stage: BTreeMap<u64, usize> = BTreeMap::new();
        let mut device_free = vec![0.0f64; self.transport.n_devices()];
        // (arrival, first-start) of started requests, admission-cap rule.
        let mut starts: Vec<(f64, f64)> = Vec::new();

        // ---- report accumulators -------------------------------------
        let mut traces: Vec<RequestTrace> = Vec::new();
        let mut failures: Vec<(u64, String)> = Vec::new();
        let mut dropped = 0u64;
        let mut latency = Series::new();
        let mut service = Series::new();
        let mut queue_wait = Series::new();
        let mut tp = Throughput::default();
        let mut occupancy: Vec<Intervals> = vec![Intervals::new(); n_stages];
        let mut served = vec![0usize; n_stages];
        let mut batches = vec![0usize; n_stages];
        let mut max_batch = 1usize;
        let mut req_intervals = Intervals::new();
        let mut makespan = 0.0f64;

        // ---- admissions ----------------------------------------------
        let mut pending_admissions: VecDeque<(usize, f64)> = VecDeque::new();
        let mut next_admit;
        match closed_c {
            Some(c) => {
                let initial = c.min(total);
                for idx in 0..initial {
                    pending_admissions.push_back((idx, 0.0));
                }
                next_admit = initial;
            }
            None => {
                for (idx, &a) in open_arrivals.iter().enumerate() {
                    pending_admissions.push_back((idx, a));
                }
                next_admit = total;
            }
        }

        // ---- gateway state (DESIGN.md §14) ---------------------------
        // Reply handles for external in-flight requests, keyed by request
        // id; presence marks a request as external (admission-cap exempt,
        // no trace retained — its output leaves over HTTP).
        let mut ext_replies: BTreeMap<u64, Responder> = BTreeMap::new();
        // Lifecycle verbs wait here for the next quiescent point.
        let mut pending_ctl: VecDeque<GatewayCmd> = VecDeque::new();
        // Commands picked up by the idle wait, handled next loop top.
        let mut queued_cmds: VecDeque<GatewayCmd> = VecDeque::new();
        // Without a gateway the engine "shuts down" when work runs out,
        // exactly as before; with one, only an explicit shutdown (or the
        // command channel dying) lets the loop exit.
        let mut shutdown = gateway.is_none();
        let mut deployed = true;
        // How long the gather phase may block while a gateway is
        // attached: bounds external-admission latency under load.
        const GATEWAY_POLL_MS: f64 = 5.0;
        // Idle tick with a gateway attached: bounds how stale membership
        // folding can get while no traffic flows.
        const GATEWAY_IDLE_MS: f64 = 25.0;

        loop {
            // ---- telemetry mirror (DESIGN.md §16) --------------------
            // Once per pass: transport-owned counters (bytes, frames,
            // reaper fires, piggybacked worker counters) and the live
            // gauges become visible to `GET /metrics` without the HTTP
            // thread ever reaching into the transport.
            tel.set_shared_counters(&self.transport.counters());
            tel.fleet_devices.set(self.transport.n_devices() as u64);
            tel.fleet_alive.set(self.active.len() as u64);
            let in_system = stage_queue.iter().map(VecDeque::len).sum::<usize>()
                + stage_busy.iter().flatten().map(|b| b.members.len()).sum::<usize>();
            tel.inflight.set(in_system as u64);

            // ---- gateway commands (DESIGN.md §14) --------------------
            // External admissions and reads are handled the moment they
            // are seen; lifecycle verbs wait for the quiescent point
            // below. `queued_cmds` holds commands the idle wait caught.
            if let Some(gw) = gateway {
                loop {
                    let cmd = match queued_cmds.pop_front() {
                        Some(c) => c,
                        None => match gw.rx.try_recv() {
                            Ok(c) => c,
                            Err(std::sync::mpsc::TryRecvError::Empty) => break,
                            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                                shutdown = true;
                                break;
                            }
                        },
                    };
                    match cmd {
                        GatewayCmd::Infer { input, resp } => {
                            if shutdown || !deployed {
                                let why = if shutdown {
                                    "gateway is shutting down".to_string()
                                } else {
                                    format!(
                                        "model {} is not deployed",
                                        self.cfg.model
                                    )
                                };
                                resp.send(503, error_body(why));
                                continue;
                            }
                            // Admit now, on the transport clock, into the
                            // same queues (and micro-batch windows) the
                            // paced workload uses.
                            let arrival = self.transport.clamp_ms(0.0);
                            let req = self.next_req;
                            self.next_req += 1;
                            let cur = match reshape_input(&self.model, &input) {
                                Ok(t) => Arc::new(t),
                                Err(e) => {
                                    resp.send(
                                        400,
                                        error_body(format!("bad input: {e}")),
                                    );
                                    continue;
                                }
                            };
                            tel.requests_total.inc();
                            tel.traces.start(req, arrival);
                            let mut fl = InFlight {
                                req,
                                t_arrival: arrival,
                                t_first_start: f64::NAN,
                                t_ready: arrival,
                                stage_idx: 0,
                                cur,
                                layers: Vec::new(),
                                any_recovery: false,
                            };
                            if advance_locals(
                                &self.stages,
                                &self.model,
                                &mut fl,
                                scratch,
                            )? {
                                // No distributed stage: answer at once.
                                let out = take_owned(&mut fl.cur);
                                resp.send(200, infer_reply(req, &out, 0.0, false));
                                scratch.put(out.into_data());
                                latency.record(0.0);
                                service.record(0.0);
                                queue_wait.record(0.0);
                                makespan = makespan.max(arrival);
                                tp.completed += 1;
                                tel.completed_total.inc();
                                tel.latency_ms.record(0.0);
                                tel.traces.finish(req, arrival, "merged");
                                continue;
                            }
                            let s = fl.stage_idx;
                            let i = inflight.len();
                            inflight.push(fl);
                            stage_queue[s].push_back(i);
                            ext_replies.insert(req, resp);
                        }
                        GatewayCmd::Stats { resp } => {
                            let now = self.transport.now_ms();
                            let in_flight = stage_busy
                                .iter()
                                .flatten()
                                .map(|b| b.members.len())
                                .sum::<usize>()
                                + stage_queue.iter().map(VecDeque::len).sum::<usize>();
                            let stage_rows: Vec<Value> = (0..n_stages)
                                .filter(|&s| self.stages[s].is_distributed())
                                .map(|s| {
                                    json::obj(vec![
                                        (
                                            "layer",
                                            Value::Str(
                                                self.model.layers
                                                    [self.stages[s].layer_idx()]
                                                .name
                                                .clone(),
                                            ),
                                        ),
                                        ("served", num(served[s] as f64)),
                                        ("batches", num(batches[s] as f64)),
                                        ("busy_ms", num(occupancy[s].busy_ms())),
                                        (
                                            "utilization",
                                            num(occupancy[s].utilization(now)),
                                        ),
                                    ])
                                })
                                .collect();
                            let rps = if now > 0.0 {
                                tp.completed as f64 * 1000.0 / now
                            } else {
                                0.0
                            };
                            resp.send(
                                200,
                                json::obj(vec![
                                    ("completed", num(tp.completed as f64)),
                                    ("failed", num(tp.failed as f64)),
                                    ("recovered", num(tp.recovered as f64)),
                                    ("dropped", num(dropped as f64)),
                                    ("in_flight", num(in_flight as f64)),
                                    ("elapsed_ms", num(now)),
                                    ("rps", num(rps)),
                                    ("max_batch", num(max_batch as f64)),
                                    // Percentiles come from the shared
                                    // telemetry histogram — the same
                                    // estimator `GET /metrics` and the
                                    // end-of-run report use, so the two
                                    // surfaces can never disagree.
                                    ("latency_ms", tel.latency_json()),
                                    ("stages", Value::Arr(stage_rows)),
                                ]),
                            );
                        }
                        GatewayCmd::Fleet { resp } => resp.send(200, self.fleet_json()),
                        GatewayCmd::Policy { resp } => {
                            resp.send(200, self.policy_json())
                        }
                        GatewayCmd::Deployments { resp } => {
                            resp.send(200, self.deployments_json(deployed))
                        }
                        GatewayCmd::Shutdown { resp } => {
                            shutdown = true;
                            if let Some(r) = resp {
                                r.send(
                                    200,
                                    json::obj(vec![("ok", Value::Bool(true))]),
                                );
                            }
                        }
                        ctl @ (GatewayCmd::Deploy { .. }
                        | GatewayCmd::Undeploy { .. }
                        | GatewayCmd::Migrate { .. }) => pending_ctl.push_back(ctl),
                    }
                }
            }

            // ---- membership (wall clock only; DESIGN.md §13) ---------
            // Worker joins, heartbeat deaths, and graceful leaves fold
            // into the plan only at pipeline-quiescent instants — no
            // stage holds work, so a repartition never strands an
            // in-flight order. The simulator never emits events, keeping
            // sim scheduling bit-identical.
            if wall && stage_busy.iter().all(|b| b.is_none()) {
                self.apply_membership()?;
                // Lifecycle verbs (deploy / undeploy / migrate) execute
                // at the same quiescent points as membership: no order is
                // in flight, so they can never tear a batch (DESIGN.md
                // §14).
                while let Some(cmd) = pending_ctl.pop_front() {
                    self.apply_lifecycle(cmd, &mut deployed);
                }
                let width = self.transport.n_devices();
                if device_free.len() < width {
                    device_free.resize(width, 0.0);
                }
            }

            // ---- admit -----------------------------------------------
            while let Some((idx, arrival)) = pending_admissions.pop_front() {
                let cur = Arc::new(reshape_input(&self.model, &workload.inputs[idx])?);
                let mut fl = InFlight {
                    req: first_req + idx as u64,
                    t_arrival: arrival,
                    t_first_start: f64::NAN,
                    t_ready: arrival,
                    stage_idx: 0,
                    cur,
                    layers: Vec::new(),
                    any_recovery: false,
                };
                tel.requests_total.inc();
                tel.traces.start(fl.req, arrival);
                if advance_locals(&self.stages, &self.model, &mut fl, scratch)? {
                    // Degenerate model with no distributed stage:
                    // completes at its arrival instant.
                    let trace = RequestTrace {
                        req: fl.req,
                        output: take_owned(&mut fl.cur),
                        total_ms: 0.0,
                        t_arrival_ms: arrival,
                        t_done_ms: arrival,
                        layers: fl.layers,
                        any_recovery: false,
                    };
                    latency.record(0.0);
                    service.record(0.0);
                    queue_wait.record(0.0);
                    makespan = makespan.max(arrival);
                    tp.completed += 1;
                    tel.completed_total.inc();
                    tel.latency_ms.record(0.0);
                    tel.traces.finish(fl.req, arrival, "merged");
                    traces.push(trace);
                    if closed_c.is_some() && next_admit < total {
                        pending_admissions.push_back((next_admit, arrival));
                        next_admit += 1;
                    }
                    continue;
                }
                let s = fl.stage_idx;
                let i = inflight.len();
                inflight.push(fl);
                stage_queue[s].push_back(i);
            }

            // ---- dispatch every free stage with waiting request(s) ---
            // Batch formation (DESIGN.md §10): a free fc stage coalesces
            // up to `batch_max` queued requests into one order. The head
            // request fixes the window start t0 = max(ready, stage_free);
            // followers whose ready time falls within `batch_wait_ms` of
            // t0 join (FIFO order, identical activation shape). A filled
            // batch dispatches the instant its last member is ready; an
            // unfilled one dispatches when the window timer expires (the
            // coordinator cannot know no more arrivals are coming).
            // batch_wait_ms = 0 is pass-through: only already-waiting
            // backlog coalesces and a lone request is never delayed.
            let batch_cap = self.cfg.batch_max.max(1);
            let batch_wait = self.cfg.batch_wait_ms.max(0.0);
            let mut cands: Vec<(f64, usize, Vec<usize>)> = Vec::new();
            for s in 0..n_stages {
                if stage_busy[s].is_some() {
                    continue;
                }
                // Undeployed (gateway lifecycle): requests wait in their
                // queues — never dispatched, never dropped — until a
                // deploy verb restores the plan.
                if !deployed {
                    continue;
                }
                let StageKind::Dist(ds) = &self.stages[s].kind else {
                    continue;
                };
                // Balk rule: an open-loop arrival that found the entry
                // queue at the cap never enters the system. Applied as
                // each queued request is considered, exactly as before
                // batching existed.
                let balks = |i: usize, starts: &[(f64, f64)]| {
                    if Some(s) != first_dist || closed_c.is_some() {
                        return false;
                    }
                    // External (gateway) requests never balk: the
                    // admission cap governs the synthetic open loop.
                    if ext_replies.contains_key(&inflight[i].req) {
                        return false;
                    }
                    let Some(cap) = workload.admission_cap else { return false };
                    let arr = inflight[i].t_arrival;
                    starts.iter().rev().take_while(|(_, st)| *st > arr).count() >= cap
                };
                let head = loop {
                    let Some(&i) = stage_queue[s].front() else { break None };
                    if balks(i, &starts) {
                        stage_queue[s].pop_front();
                        dropped += 1;
                        tel.traces.finish(
                            inflight[i].req,
                            inflight[i].t_arrival,
                            "dropped",
                        );
                        continue;
                    }
                    break Some(i);
                };
                let Some(head) = head else { continue };
                stage_queue[s].pop_front();
                // Wall-clock: a stage resolved in the past still enters
                // "now" at the earliest (clamp is identity on the sim).
                let t0 = self
                    .transport
                    .clamp_ms(inflight[head].t_ready.max(stage_free[s]));
                let mut members = vec![head];
                let mut t_enter = t0;
                let cap = if ds.batchable { batch_cap } else { 1 };
                if cap > 1 {
                    let head_shape = inflight[head].cur.shape().to_vec();
                    let batchable_shape = head_shape.len() == 2 && head_shape[1] == 1;
                    let window = t0 + batch_wait;
                    while batchable_shape && members.len() < cap {
                        let Some(&j) = stage_queue[s].front() else { break };
                        if balks(j, &starts) {
                            stage_queue[s].pop_front();
                            dropped += 1;
                            tel.traces.finish(
                                inflight[j].req,
                                inflight[j].t_arrival,
                                "dropped",
                            );
                            continue;
                        }
                        if inflight[j].t_ready > window
                            || inflight[j].cur.shape() != head_shape.as_slice()
                        {
                            break;
                        }
                        stage_queue[s].pop_front();
                        t_enter = t_enter.max(inflight[j].t_ready);
                        members.push(j);
                    }
                    if batchable_shape && members.len() < cap && batch_wait > 0.0 {
                        // The timer was armed and expired unfilled.
                        t_enter = window;
                    }
                }
                cands.push((t_enter, s, members));
            }
            // Dispatch in virtual-entry-time order so the device ledger
            // serialises shared devices causally (ties: later stages —
            // i.e. older requests — first).
            cands.sort_by(|a, b| {
                a.0.partial_cmp(&b.0)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(b.1.cmp(&a.1))
            });
            // Wall clock only: dispatching a future-dated order (an
            // arrival not yet due, an unfilled batch window) while other
            // stages hold work would sleep *before* the gather phase and
            // head-of-line block their resolution. Defer such orders to
            // a later round instead; the gather below wakes at
            // `next_due` to dispatch them on time. With nothing in
            // flight, sleeping (pace) is the only thing left to do.
            let mut next_due = f64::INFINITY;
            for (t_enter, s, members) in cands {
                // With a gateway attached, future-dated orders are ALWAYS
                // deferred (never slept on via `pace`): a sleeping serve
                // loop could not admit the external request that just
                // arrived. The idle wait in the done-block takes pacing's
                // place.
                if wall
                    && t_enter > self.transport.now_ms()
                    && (gateway.is_some() || stage_busy.iter().any(|b| b.is_some()))
                {
                    next_due = next_due.min(t_enter);
                    for &m in members.iter().rev() {
                        stage_queue[s].push_front(m);
                    }
                    continue;
                }
                let StageKind::Dist(ds) = &self.stages[s].kind else {
                    unreachable!("only distributed stages are dispatched")
                };
                // Width 1 shares the member's activation Arc (no copy —
                // the unbatched fast path is untouched); wider batches
                // column-concatenate into a scratch-backed matrix.
                let input = if members.len() == 1 {
                    inflight[members[0]].cur.clone()
                } else {
                    let cols: Vec<&Tensor> =
                        members.iter().map(|&i| inflight[i].cur.as_ref()).collect();
                    Arc::new(concat_columns(&cols, scratch)?)
                };
                let leader = inflight[members[0]].req;
                // Wall-clock: an order formed for a future instant (an
                // arrival not yet due, or an expired-by-design batch
                // window) really waits until then before hitting the
                // wire. No-op on the simulator and for past instants.
                self.transport.pace(t_enter);
                let pending = ds.dispatch(
                    self.transport.as_ref(),
                    &self.cfg.net,
                    &self.rates,
                    leader,
                    input.clone(),
                    members.len(),
                    t_enter,
                    self.partition_epoch,
                    &mut device_free,
                )?;
                for &i in &members {
                    if inflight[i].t_first_start.is_nan() {
                        inflight[i].t_first_start = t_enter;
                        starts.push((inflight[i].t_arrival, t_enter));
                    }
                }
                tel.batches_total.inc();
                tel.batched_requests_total.add(members.len() as u64);
                tel.batch_width.record(members.len() as f64);
                tel.dispatch_orders_total
                    .add((ds.data.len() + ds.parities.len()) as u64);
                // Trace spans: every member records the batch it joined;
                // per-device dispatch spans ride the leader's trace (the
                // request id completions route by), pairing with the
                // replied/reaped stamps the gather loop records.
                for &i in &members {
                    tel.traces.event(
                        inflight[i].req,
                        t_enter,
                        "batched",
                        -1,
                        members.len() as f64,
                    );
                }
                for &(d, _) in &ds.data {
                    tel.traces.event(leader, t_enter, "dispatched", d as i64, 0.0);
                }
                for p in &ds.parities {
                    tel.traces.event(leader, t_enter, "dispatched", p.0 as i64, 0.0);
                }
                req_to_stage.insert(leader, s);
                let batched_input = if members.len() > 1 { Some(input) } else { None };
                stage_busy[s] = Some(BusyStage {
                    members,
                    batched_input,
                    t_enter,
                    n_expected: pending.n_expected,
                    epoch: self.partition_epoch,
                    got: BTreeMap::new(),
                });
            }

            // With a gateway attached, bound how long the gather phase
            // may block while stages hold work, so commands arriving
            // mid-burst are admitted within a few ms.
            if gateway.is_some() && stage_busy.iter().any(|b| b.is_some()) {
                next_due = next_due.min(self.transport.now_ms() + GATEWAY_POLL_MS);
            }

            // ---- done? ----------------------------------------------
            if stage_busy.iter().all(|b| b.is_none()) {
                let Some(gw) = gateway else { break };
                if shutdown && !deployed {
                    // Shutting down with the model undeployed: queued
                    // work can never dispatch — fail it out now instead
                    // of waiting forever.
                    for q in stage_queue.iter_mut() {
                        while let Some(i) = q.pop_front() {
                            let req = inflight[i].req;
                            if let Some(r) = ext_replies.remove(&req) {
                                r.send(
                                    503,
                                    error_body(
                                        "shutting down with the model undeployed",
                                    ),
                                );
                                failures.push((req, "undeployed".to_string()));
                                tp.failed += 1;
                                tel.failed_total.inc();
                                tel.traces.finish(
                                    req,
                                    self.transport.now_ms(),
                                    "failed",
                                );
                            } else {
                                dropped += 1;
                                tel.traces.finish(
                                    req,
                                    self.transport.now_ms(),
                                    "dropped",
                                );
                            }
                        }
                    }
                    dropped += pending_admissions.len() as u64;
                    pending_admissions.clear();
                }
                let queued = !pending_admissions.is_empty()
                    || stage_queue.iter().any(|q| !q.is_empty());
                if shutdown
                    && !queued
                    && pending_ctl.is_empty()
                    && queued_cmds.is_empty()
                {
                    break;
                }
                // Idle: block until the next deferred order is due or a
                // command arrives (bounded tick keeps membership fresh).
                let now = self.transport.now_ms();
                let wait_ms = if next_due.is_finite() {
                    (next_due - now).clamp(0.0, GATEWAY_IDLE_MS)
                } else {
                    GATEWAY_IDLE_MS
                };
                let wait = std::time::Duration::from_micros((wait_ms * 1000.0) as u64);
                match gw.rx.recv_timeout(wait) {
                    Ok(c) => queued_cmds.push_back(c),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                        // The HTTP server is gone: no more external work
                        // can arrive. Drain what's queued, then exit.
                        shutdown = true;
                        if !queued && pending_ctl.is_empty() && queued_cmds.is_empty()
                        {
                            break;
                        }
                        // recv_timeout returns instantly on a dead
                        // channel; sleep for real so waiting on deferred
                        // future-dated orders doesn't spin (wall-clock
                        // transports only reach this path).
                        std::thread::sleep(wait);
                    }
                }
                continue;
            }

            // ---- gather outstanding completions ----------------------
            // Virtual time: gather *everything* before resolving (free —
            // scheduling reads only the stamped timestamps; exactly the
            // pre-transport behaviour). Wall clock: gather only until
            // some stage is fully in, then resolve it — waiting for all
            // busy stages would lock-step a real pipeline.
            let mut remaining: usize = stage_busy
                .iter()
                .flatten()
                .map(|b| b.n_expected - b.got.len())
                .sum();
            while remaining > 0 {
                if wall
                    && stage_busy
                        .iter()
                        .flatten()
                        .any(|b| b.got.len() >= b.n_expected)
                {
                    break;
                }
                let c = if wall && next_due.is_finite() {
                    match self.transport.recv_deadline(next_due)? {
                        Some(c) => c,
                        // A deferred dispatch is due: break to the
                        // resolve/dispatch phases (incomplete stages
                        // stay busy and gather again next round).
                        None => break,
                    }
                } else {
                    self.transport.recv()?
                };
                if let Some(&s) = req_to_stage.get(&c.req) {
                    if let Some(b) = stage_busy[s].as_mut() {
                        let (req, device, t_arr) = (c.req, c.device, c.t_arrival_ms);
                        // Stale-epoch replies (from before a live
                        // repartition) are discarded, never gathered.
                        if b.epoch == self.partition_epoch
                            && b.got.insert(c.task, c).is_none()
                        {
                            remaining -= 1;
                            if t_arr.is_finite() {
                                tel.replies_total.inc();
                                tel.traces.event(req, t_arr, "replied", device as i64, 0.0);
                            } else {
                                // ∞-stamped: the reaper (or a dead
                                // connection) synthesised this loss.
                                tel.reaped_tasks_total.inc();
                                tel.traces.event(
                                    req,
                                    self.transport.now_ms(),
                                    "reaped",
                                    device as i64,
                                    0.0,
                                );
                            }
                        }
                    }
                }
                // Unknown request ids are orphans of previously-lost
                // requests; ignore them like `drain` does.
            }

            // ---- resolve every fully-gathered stage ------------------
            for s in 0..n_stages {
                let Some(b) = stage_busy[s].take() else { continue };
                if b.got.len() < b.n_expected {
                    // Wall-clock eager gather: this stage is still
                    // waiting on devices — leave it busy.
                    stage_busy[s] = Some(b);
                    continue;
                }
                let StageKind::Dist(ds) = &self.stages[s].kind else {
                    unreachable!("only distributed stages hold work")
                };
                let layer = &self.model.layers[ds.layer_idx];
                let batch = b.members.len();
                let leader = inflight[b.members[0]].req;
                req_to_stage.remove(&leader);
                // Adaptive mode replaces the static straggler gate with
                // the policy's current (latency-tracked) factor. On a
                // wall-clock transport the resolve-time gate is disabled
                // (∞): it would compare real arrival stamps against the
                // *simulated* cost model and misclassify healthy replies
                // as stragglers — there, the straggler gate is the
                // transport's order deadline (reaped replies arrive as
                // ∞; DESIGN.md §11).
                let threshold_factor = if wall {
                    f64::INFINITY
                } else {
                    self.adaptive
                        .as_ref()
                        .map(|a| a.threshold_factor())
                        .unwrap_or(self.cfg.threshold_factor)
                };
                let expected_ms = ds.expected_ms_for(batch);
                // Feed every gathered completion (∞ = lost reply) into
                // the adaptive policy *before* resolution, so Lost stages
                // — the double-loss regime the parity-vs-replication
                // chooser exists for — feed the drop-rate estimate too.
                // A batched reply carries `batch` member latencies, so
                // the windows receive one observation per member.
                if let Some(a) = self.adaptive.as_mut() {
                    for c in b.got.values() {
                        a.observe_batch(
                            c.device,
                            b.t_enter,
                            c.t_arrival_ms,
                            expected_ms,
                            batch,
                        );
                    }
                }
                let resolved = ds.resolve(
                    layer,
                    b.got,
                    b.t_enter,
                    batch,
                    threshold_factor,
                    scratch,
                    self.transport.as_ref(),
                )?;
                // Dispatch accounting is outcome-independent: a lost
                // order was still a dispatched batch of this width.
                batches[s] += 1;
                max_batch = max_batch.max(batch);
                // Reclaim the batched-input buffer now that every device
                // reply is in (best effort — see BusyStage).
                if let Some(arc) = b.batched_input {
                    if let Ok(t) = Arc::try_unwrap(arc) {
                        scratch.put(t.into_data());
                    }
                }
                match resolved {
                    StageOutcome::Done { t_done, output, trace } => {
                        // Wall clock: the stage is free *now* — a loss
                        // learned from the deadline reaper (or the gap
                        // between receipt and resolution) is real
                        // elapsed time the pure timestamp policy cannot
                        // see. Identity on the simulator.
                        let t_done = self.transport.clamp_ms(t_done);
                        stage_free[s] = t_done;
                        occupancy[s].push(b.t_enter, t_done);
                        served[s] += batch;
                        if trace.outcome == "recovered" {
                            // The paper's claim, observable live: parity
                            // substituted for the lost shard set with no
                            // retry round (DESIGN.md §16).
                            tel.recoveries_total.inc();
                            tel.traces.event(leader, t_done, "recovered", -1, 1.0);
                        }
                        // A batched output is the column concatenation of
                        // the member outputs; split it back so each
                        // member advances independently (and may join a
                        // different batch at the next stage).
                        let outputs = if batch == 1 {
                            vec![output]
                        } else {
                            split_columns(output, batch, scratch)?
                        };
                        for (&mi, out_m) in b.members.iter().zip(outputs) {
                            let fl = &mut inflight[mi];
                            fl.any_recovery |= trace.outcome == "recovered";
                            fl.layers.push(trace.clone());
                            // Recycle the consumed activation into the
                            // arena (unique once the devices dropped
                            // their handles).
                            let old = std::mem::replace(&mut fl.cur, Arc::new(out_m));
                            if let Ok(t) = Arc::try_unwrap(old) {
                                scratch.put(t.into_data());
                            }
                            fl.t_ready = t_done;
                            fl.stage_idx = s + 1;
                            if advance_locals(&self.stages, &self.model, fl, scratch)? {
                                let done_t = fl.t_ready;
                                if let Some(r) = ext_replies.remove(&fl.req) {
                                    // External (gateway) request: the
                                    // logits leave over HTTP; no trace is
                                    // retained (a long-lived gateway must
                                    // not accumulate outputs), but every
                                    // serving metric records it.
                                    let out = take_owned(&mut fl.cur);
                                    let lat = done_t - fl.t_arrival;
                                    r.send(
                                        200,
                                        infer_reply(fl.req, &out, lat, fl.any_recovery),
                                    );
                                    scratch.put(out.into_data());
                                    latency.record(lat);
                                    service.record(done_t - fl.t_first_start);
                                    queue_wait.record(fl.t_first_start - fl.t_arrival);
                                    req_intervals.push(fl.t_first_start, done_t);
                                    makespan = makespan.max(done_t);
                                    tp.completed += 1;
                                    if fl.any_recovery {
                                        tp.recovered += 1;
                                    }
                                    tel.completed_total.inc();
                                    tel.latency_ms.record(lat);
                                    tel.traces.finish(fl.req, done_t, "merged");
                                    fl.layers.clear();
                                    continue;
                                }
                                let trace = RequestTrace {
                                    req: fl.req,
                                    output: take_owned(&mut fl.cur),
                                    total_ms: done_t - fl.t_arrival,
                                    t_arrival_ms: fl.t_arrival,
                                    t_done_ms: done_t,
                                    layers: std::mem::take(&mut fl.layers),
                                    any_recovery: fl.any_recovery,
                                };
                                latency.record(trace.total_ms);
                                service.record(done_t - fl.t_first_start);
                                queue_wait.record(fl.t_first_start - fl.t_arrival);
                                req_intervals.push(fl.t_first_start, done_t);
                                makespan = makespan.max(done_t);
                                tp.completed += 1;
                                if trace.any_recovery {
                                    tp.recovered += 1;
                                }
                                tel.completed_total.inc();
                                tel.latency_ms.record(trace.total_ms);
                                tel.traces.finish(trace.req, done_t, "merged");
                                traces.push(trace);
                                if closed_c.is_some() && next_admit < total {
                                    pending_admissions.push_back((next_admit, done_t));
                                    next_admit += 1;
                                }
                            } else {
                                stage_queue[fl.stage_idx].push_back(mi);
                            }
                        }
                    }
                    StageOutcome::Lost => {
                        // The coordinator notices the loss only after the
                        // failure-detection window; the stage is blocked
                        // until then (the paper's "tens of seconds").
                        // Every member of a lost batch is lost — the
                        // no-request-loss accounting must charge all of
                        // them, and the closed loop re-admits one new
                        // request per lost member.
                        let t_free = b.t_enter + self.cfg.detection_ms;
                        stage_free[s] = t_free;
                        occupancy[s].push(b.t_enter, t_free);
                        makespan = makespan.max(t_free);
                        for &mi in &b.members {
                            let req = inflight[mi].req;
                            let ext = ext_replies.remove(&req);
                            if let Some(r) = &ext {
                                // A lost external request is an honest
                                // 502: the pipeline exhausted every
                                // recovery path for this batch.
                                r.send(
                                    502,
                                    error_body(format!(
                                        "request lost at layer {} \
                                         (redundancy exhausted)",
                                        layer.name
                                    )),
                                );
                            }
                            failures.push((req, layer.name.clone()));
                            tp.failed += 1;
                            tel.failed_total.inc();
                            tel.traces.finish(req, t_free, "failed");
                            if ext.is_none() && closed_c.is_some() && next_admit < total
                            {
                                pending_admissions.push_back((next_admit, t_free));
                                next_admit += 1;
                            }
                        }
                    }
                }
            }
        }

        // ---- report ---------------------------------------------------
        tp.total_ms = makespan;
        let stages: Vec<StageStats> = self
            .stages
            .iter()
            .enumerate()
            .filter(|(_, st)| st.is_distributed())
            .map(|(s, st)| StageStats {
                layer: self.model.layers[st.layer_idx()].name.clone(),
                served: served[s],
                batches: batches[s],
                busy_ms: occupancy[s].busy_ms(),
                utilization: occupancy[s].utilization(makespan),
                occupancy: occupancy[s].clone(),
            })
            .collect();
        let occ_refs: Vec<&Intervals> = occupancy.iter().collect();
        let max_concurrent_stages = metrics::max_overlap(&occ_refs);
        let max_concurrent_requests = metrics::max_overlap(&[&req_intervals]);
        // This run's latencies only (the registry histogram is
        // cumulative across a session's serve calls).
        let latency_hist = crate::telemetry::Histogram::new();
        for &sample in latency.samples() {
            latency_hist.record(sample);
        }
        Ok(ServeReport {
            traces,
            failures,
            dropped,
            latency,
            latency_hist,
            service,
            queue_wait,
            throughput: tp,
            makespan_ms: makespan,
            stages,
            max_concurrent_requests,
            max_concurrent_stages,
            max_batch,
            policy: self.adaptive.as_ref().map(|a| a.snapshot()),
            kernel_tier: crate::kernels::active_tier(),
            precision: self.cfg.precision.label(),
        })
    }

    /// `GET /v1/fleet` payload: live membership, device rates, epoch.
    fn fleet_json(&self) -> Value {
        let active: Vec<Value> =
            self.active.iter().map(|&d| num(d as f64)).collect();
        let failed: Vec<Value> =
            self.known_failed.iter().map(|&d| num(d as f64)).collect();
        json::obj(vec![
            ("transport", Value::Str(self.transport_label().to_string())),
            ("partition_epoch", num(self.partition_epoch as f64)),
            ("total_devices", num(self.transport.n_devices() as f64)),
            ("active", Value::Arr(active)),
            ("known_failed", Value::Arr(failed)),
            ("rates", json::arr_f64(self.device_rates())),
            (
                "membership_addr",
                match self.membership_addr() {
                    Some(a) => Value::Str(a),
                    None => Value::Null,
                },
            ),
        ])
    }

    /// `GET /v1/policy` payload: the adaptive `PolicyReport` snapshot,
    /// or the static gate when adaptation is off.
    fn policy_json(&self) -> Value {
        match self.policy_snapshot() {
            None => json::obj(vec![
                ("adaptive", Value::Bool(false)),
                ("threshold_factor", num(self.cfg.threshold_factor)),
            ]),
            Some(p) => json::obj(vec![
                ("adaptive", Value::Bool(true)),
                ("threshold_factor", num(p.threshold_factor)),
                ("observed", num(p.observed as f64)),
                ("drops", num(p.drops as f64)),
                ("drop_rate", num(p.drop_rate)),
                ("stragglers", num(p.stragglers as f64)),
                ("recommended", Value::Str(redundancy_tag(p.recommended))),
            ]),
        }
    }

    /// `GET /v1/deployments` payload (this session serves one model).
    fn deployments_json(&self, deployed: bool) -> Value {
        Value::Arr(vec![json::obj(vec![
            ("model", Value::Str(self.cfg.model.clone())),
            ("deployed", Value::Bool(deployed)),
            ("n_devices", num(self.cfg.n_devices as f64)),
            ("active", num(self.active.len() as f64)),
            ("partition_epoch", num(self.partition_epoch as f64)),
            ("tasks", num(self.task_owner.len() as f64)),
        ])])
    }

    /// Execute one lifecycle verb at a pipeline-quiescent point and
    /// answer its responder. Infallible by design: every failure becomes
    /// an HTTP error payload instead of tearing down the serve loop.
    fn apply_lifecycle(&mut self, cmd: GatewayCmd, deployed: &mut bool) {
        match cmd {
            GatewayCmd::Undeploy { model, resp } => {
                if model != self.cfg.model {
                    resp.send(404, error_body(format!("no deployment named {model}")));
                    return;
                }
                if *deployed {
                    self.undeploy_all();
                    *deployed = false;
                }
                resp.send(
                    200,
                    json::obj(vec![
                        ("ok", Value::Bool(true)),
                        ("model", Value::Str(model)),
                        ("deployed", Value::Bool(false)),
                    ]),
                );
            }
            GatewayCmd::Deploy { model, resp } => {
                if model != self.cfg.model {
                    resp.send(
                        404,
                        error_body(format!(
                            "this session serves only model {}",
                            self.cfg.model
                        )),
                    );
                    return;
                }
                if !*deployed {
                    if let Err(e) = self.repartition() {
                        resp.send(500, error_body(format!("deploy failed: {e}")));
                        return;
                    }
                    *deployed = true;
                }
                resp.send(
                    200,
                    json::obj(vec![
                        ("ok", Value::Bool(true)),
                        ("model", Value::Str(model)),
                        ("deployed", Value::Bool(true)),
                        ("partition_epoch", num(self.partition_epoch as f64)),
                    ]),
                );
            }
            GatewayCmd::Migrate { model, from, to, resp } => {
                if model != self.cfg.model {
                    resp.send(404, error_body(format!("no deployment named {model}")));
                    return;
                }
                if !*deployed {
                    resp.send(503, error_body(format!("model {model} is not deployed")));
                    return;
                }
                match self.migrate_tasks(from, to) {
                    Ok(moved) => resp.send(
                        200,
                        json::obj(vec![
                            ("ok", Value::Bool(true)),
                            ("moved", num(moved as f64)),
                            ("from", num(from as f64)),
                            ("to", num(to as f64)),
                            ("partition_epoch", num(self.partition_epoch as f64)),
                        ]),
                    ),
                    Err(e) => resp.send(400, error_body(format!("migrate failed: {e}"))),
                }
            }
            // Only lifecycle verbs are ever queued to this hook.
            _ => {}
        }
    }
}

/// JSON number that degrades to `null` instead of emitting non-finite
/// literals the grammar forbids.
fn num(v: f64) -> Value {
    if v.is_finite() {
        Value::Num(v)
    } else {
        Value::Null
    }
}

/// `POST /v1/infer` success payload: logits + provenance.
fn infer_reply(req: u64, out: &Tensor, latency_ms: f64, recovered: bool) -> Value {
    let logits: Vec<f64> = out.data().iter().map(|&x| f64::from(x)).collect();
    json::obj(vec![
        ("req", num(req as f64)),
        ("logits", json::arr_f64(&logits)),
        ("argmax", num(out.argmax() as f64)),
        ("latency_ms", num(latency_ms)),
        ("recovered", Value::Bool(recovered)),
    ])
}

/// Same tag grammar the config files use ("none" | "cdc" | "cdc:<g>" | "2mr").
fn redundancy_tag(r: super::Redundancy) -> String {
    match r {
        super::Redundancy::None => "none".to_string(),
        super::Redundancy::Cdc => "cdc".to_string(),
        super::Redundancy::CdcGrouped(g) => format!("cdc:{g}"),
        super::Redundancy::TwoMr => "2mr".to_string(),
    }
}
