//! Interpreter compute backend: executes shard artifacts directly from
//! their manifest metadata with the in-tree kernel layer.
//!
//! The AOT artifacts implement exactly two program shapes (see
//! `python/compile/model.py`):
//!
//! * `fc_shard`:  `(w (m,k), b (m,1), x (k,n)) → w@x + b [relu]`
//! * `conv_shard`: `(w (k_s, f²c), b (k_s,1), x (h,w,c)) →
//!   gemm(w, im2col(x)) + b [relu]` reshaped to `(oh, ow, k_s)`
//!
//! so a faithful CPU interpreter needs only a GEMM and an `im2col` that
//! mirror `python/compile/kernels/ref.py` (same padding arithmetic, same
//! patch unroll order). Both program shapes are lowered onto the shared
//! tiled GEMM of `crate::kernels` (DESIGN.md §8): conv becomes
//! im2col + the same hot kernel fc uses, with bias/ReLU applied as a
//! fused epilogue pass, and every intermediate (the im2col unroll, the
//! pre-transpose GEMM output, the packing panels) lives in the compute
//! thread's persistent [`Scratch`](crate::kernels::Scratch) arena — the
//! steady-state serving compute path allocates only the escaping output
//! tensor. The `pjrt` feature swaps in the compiled path with identical
//! semantics.

use std::cell::Cell;

use crate::error::{Error, Result};
use crate::kernels;
use crate::runtime::manifest::{ArtifactKind, ArtifactMeta};
use crate::runtime::GemmExec;
use crate::tensor::Tensor;

/// Stateless-ish interpreter (only an exec counter).
pub struct InterpRuntime {
    execs: Cell<u64>,
}

impl Default for InterpRuntime {
    fn default() -> Self {
        InterpRuntime::new()
    }
}

impl InterpRuntime {
    /// Create an interpreter backend.
    pub fn new() -> InterpRuntime {
        InterpRuntime { execs: Cell::new(0) }
    }

    /// Total execute() calls served.
    pub fn exec_count(&self) -> u64 {
        self.execs.get()
    }

    /// Execute an artifact by metadata. Inputs are pre-validated against
    /// `meta.params` by the facade.
    pub fn execute(&self, meta: &ArtifactMeta, inputs: &[&Tensor]) -> Result<Tensor> {
        self.execute_packed(meta, inputs, None)
    }

    /// [`InterpRuntime::execute`] with optional pre-packed weight panels
    /// (DESIGN.md §15): the blocked GEMM reads `packed` instead of
    /// packing `inputs[0]` per call. The panels must have been packed
    /// from the same weight matrix — dims are checked, content equality
    /// is the deploy path's contract.
    pub fn execute_packed(
        &self,
        meta: &ArtifactMeta,
        inputs: &[&Tensor],
        packed: Option<&kernels::PackedWeights>,
    ) -> Result<Tensor> {
        self.execs.set(self.execs.get() + 1);
        match meta.kind {
            ArtifactKind::Fc => fc_shard(inputs[0], inputs[1], inputs[2], meta.relu, packed),
            ArtifactKind::Conv => {
                let geom = meta.geom.as_ref().ok_or_else(|| {
                    Error::Artifact(format!(
                        "conv artifact {} carries no geometry (f/s/padding); \
                         rebuild artifacts with compile/aot.py or use the \
                         pjrt backend",
                        meta.name
                    ))
                })?;
                conv_shard(
                    inputs[0],
                    inputs[1],
                    inputs[2],
                    geom.f,
                    geom.s,
                    &geom.padding,
                    meta.relu,
                    packed,
                )
            }
        }
    }

    /// Execute an int8-quantized fc shard: `dequant(qw @ quant(x)) + b
    /// [relu]` (kind/shape validation happens in the facade's
    /// `check_quant_inputs`).
    pub fn execute_quant(
        &self,
        meta: &ArtifactMeta,
        qw: &kernels::QuantWeights,
        b: &Tensor,
        x: &Tensor,
    ) -> Result<Tensor> {
        self.execs.set(self.execs.get() + 1);
        let (m, _k) = qw.dims();
        let n = x.shape()[1];
        let mut out = vec![0.0f32; m * n];
        kernels::qgemm(qw, x.data(), &mut out, n, Some(b.data()), meta.relu);
        Tensor::new(vec![m, n], out)
    }

    /// Execute a built GEMM spec `(w, x[, b])`, counting the execution.
    pub fn run_gemm(&self, spec: &GemmExec, inputs: &[&Tensor]) -> Result<Tensor> {
        self.execs.set(self.execs.get() + 1);
        InterpRuntime::run_gemm_spec(spec, inputs)
    }

    /// Execute a built GEMM spec without touching any backend state.
    pub fn run_gemm_spec(spec: &GemmExec, inputs: &[&Tensor]) -> Result<Tensor> {
        let want = if spec.bias { 3 } else { 2 };
        if inputs.len() != want {
            return Err(Error::Shape(format!(
                "gemm fallback: expected {want} inputs, got {}",
                inputs.len()
            )));
        }
        let (w, x) = (inputs[0], inputs[1]);
        if w.shape() != [spec.m, spec.k] || x.shape() != [spec.k, spec.n] {
            return Err(Error::Shape(format!(
                "gemm fallback: w {:?} x {:?} vs spec ({},{})x({},{})",
                w.shape(),
                x.shape(),
                spec.m,
                spec.k,
                spec.k,
                spec.n
            )));
        }
        let mut out = vec![0.0f32; spec.m * spec.n];
        kernels::with_scratch(|sc| {
            kernels::gemm_auto(w.data(), x.data(), &mut out, spec.m, spec.k, spec.n, sc)
        });
        if spec.bias {
            let b = inputs[2];
            if b.shape() != [spec.m, 1] {
                return Err(Error::Shape(format!(
                    "gemm fallback: bias {:?} vs spec rows {}",
                    b.shape(),
                    spec.m
                )));
            }
            kernels::bias_relu(&mut out, spec.m, spec.n, Some(b.data()), spec.relu);
        } else {
            kernels::bias_relu(&mut out, spec.m, spec.n, None, spec.relu);
        }
        Tensor::new(vec![spec.m, spec.n], out)
    }
}

/// fc shard: `w@x + b [relu]` with the bias column broadcast over n.
/// With `packed`, the blocked GEMM reads the deploy-time panels and
/// skips per-call packing (dims must match `w`; mismatches fall back to
/// the on-line path rather than erroring, so stale panels can never
/// corrupt a result).
fn fc_shard(
    w: &Tensor,
    b: &Tensor,
    x: &Tensor,
    relu: bool,
    packed: Option<&kernels::PackedWeights>,
) -> Result<Tensor> {
    let (m, k) = dims2(w, "fc weights")?;
    let (k2, n) = dims2(x, "fc input")?;
    if k != k2 {
        return Err(Error::Shape(format!("fc shard {m}x{k} @ {k2}x{n}")));
    }
    if b.shape() != [m, 1] {
        return Err(Error::Shape(format!(
            "bias shape {:?} vs output rows {m}",
            b.shape()
        )));
    }
    let mut out = vec![0.0f32; m * n];
    kernels::with_scratch(|sc| match packed {
        Some(pw) if pw.dims() == (m, k) => {
            kernels::gemm_prepacked_auto(pw, w.data(), x.data(), &mut out, n, sc)
        }
        _ => kernels::gemm_auto(w.data(), x.data(), &mut out, m, k, n, sc),
    });
    kernels::bias_relu(&mut out, m, n, Some(b.data()), relu);
    Tensor::new(vec![m, n], out)
}

fn dims2(t: &Tensor, what: &str) -> Result<(usize, usize)> {
    match t.shape()[..] {
        [a, b] => Ok((a, b)),
        _ => Err(Error::Shape(format!("{what}: want rank-2, got {:?}", t.shape()))),
    }
}

/// conv shard: im2col + the shared tiled GEMM + reshape/transpose to
/// `(oh, ow, k_s)`, mirroring `conv_shard_fn` in `python/compile/model.py`.
/// All intermediates come from the thread's scratch arena. `packed`
/// skips the per-call packing of `w` exactly as in [`fc_shard`].
#[allow(clippy::too_many_arguments)]
fn conv_shard(
    w: &Tensor,
    b: &Tensor,
    x: &Tensor,
    f: usize,
    stride: usize,
    padding: &str,
    relu: bool,
    packed: Option<&kernels::PackedWeights>,
) -> Result<Tensor> {
    let (ks, wk) = dims2(w, "conv weights")?;
    let (h, wid, c) = match x.shape()[..] {
        [h, wid, c] => (h, wid, c),
        _ => return Err(Error::Shape(format!("conv input {:?}", x.shape()))),
    };
    if wk != f * f * c {
        return Err(Error::Shape(format!(
            "conv weights {ks}x{wk} vs filter {f}²·{c}"
        )));
    }
    if b.shape() != [ks, 1] {
        return Err(Error::Shape(format!(
            "bias shape {:?} vs output channels {ks}",
            b.shape()
        )));
    }
    let (oh, ow, pad_top, pad_left) = conv_geom(h, wid, f, stride, padding)?;
    let rows = f * f * c;
    let n_cols = oh * ow;
    kernels::with_scratch(|sc| {
        let mut cols = sc.take(rows * n_cols);
        fill_im2col(x.data(), h, wid, c, f, stride, pad_top, pad_left, oh, ow, &mut cols);
        let mut out = sc.take(ks * n_cols);
        match packed {
            Some(pw) if pw.dims() == (ks, rows) => {
                kernels::gemm_prepacked_auto(pw, w.data(), &cols, &mut out, n_cols, sc)
            }
            _ => kernels::gemm_auto(w.data(), &cols, &mut out, ks, rows, n_cols, sc),
        }
        kernels::bias_relu(&mut out, ks, n_cols, Some(b.data()), relu);
        // (k_s, oh*ow) row-major → (oh, ow, k_s) row-major.
        let mut data = vec![0.0f32; n_cols * ks];
        for (ch, src) in out.chunks_exact(n_cols.max(1)).enumerate().take(ks) {
            for (p, &v) in src.iter().enumerate() {
                data[p * ks + ch] = v;
            }
        }
        sc.put(out);
        sc.put(cols);
        Tensor::new(vec![oh, ow, ks], data)
    })
}

/// Output geometry of a conv shard: `(oh, ow, pad_top, pad_left)`. SAME
/// padding splits `floor/ceil` like `jnp.pad` in the reference (`ph/2`
/// on top, the remainder below).
fn conv_geom(
    h: usize,
    w: usize,
    f: usize,
    stride: usize,
    padding: &str,
) -> Result<(usize, usize, usize, usize)> {
    if stride == 0 || f == 0 {
        return Err(Error::Shape("im2col: zero filter/stride".into()));
    }
    match padding {
        "SAME" => {
            let oh = h.div_ceil(stride);
            let ow = w.div_ceil(stride);
            let ph = ((oh - 1) * stride + f).saturating_sub(h);
            let pw = ((ow - 1) * stride + f).saturating_sub(w);
            Ok((oh, ow, ph / 2, pw / 2))
        }
        "VALID" => {
            if h < f || w < f {
                return Err(Error::Shape(format!(
                    "im2col VALID: input {h}x{w} smaller than filter {f}"
                )));
            }
            Ok(((h - f) / stride + 1, (w - f) / stride + 1, 0, 0))
        }
        other => Err(Error::Config(format!("unknown padding {other:?}"))),
    }
}

/// Patch-unroll inner loop: write the `(F²C, OH·OW)` im2col matrix into a
/// pre-zeroed buffer. Column `j` holds the receptive field of output
/// pixel `j`, flattened in `(di, dj, channel)` order.
#[allow(clippy::too_many_arguments)]
fn fill_im2col(
    xd: &[f32],
    h: usize,
    w: usize,
    c: usize,
    f: usize,
    stride: usize,
    pad_top: usize,
    pad_left: usize,
    oh: usize,
    ow: usize,
    data: &mut [f32],
) {
    let n_cols = oh * ow;
    for oy in 0..oh {
        for ox in 0..ow {
            let p = oy * ow + ox;
            for di in 0..f {
                let iy = (oy * stride + di) as isize - pad_top as isize;
                if iy < 0 || iy >= h as isize {
                    continue; // zero padding
                }
                for dj in 0..f {
                    let ix = (ox * stride + dj) as isize - pad_left as isize;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let src = (iy as usize * w + ix as usize) * c;
                    let rbase = (di * f + dj) * c;
                    for ch in 0..c {
                        data[(rbase + ch) * n_cols + p] = xd[src + ch];
                    }
                }
            }
        }
    }
}

/// Patch unroll (paper Fig. 4): `(H, W, C) → (F²C, OH·OW)` as a fresh
/// tensor — the allocation-free serving path uses [`fill_im2col`] through
/// `conv_shard`; this wrapper serves tests and tooling.
pub fn im2col(
    x: &Tensor,
    f: usize,
    stride: usize,
    padding: &str,
) -> Result<(Tensor, usize, usize)> {
    let (h, w, c) = match x.shape()[..] {
        [h, w, c] => (h, w, c),
        _ => return Err(Error::Shape(format!("im2col of {:?}", x.shape()))),
    };
    let (oh, ow, pad_top, pad_left) = conv_geom(h, w, f, stride, padding)?;
    let rows = f * f * c;
    let n_cols = oh * ow;
    let mut data = vec![0.0f32; rows * n_cols];
    fill_im2col(x.data(), h, w, c, f, stride, pad_top, pad_left, oh, ow, &mut data);
    Ok((Tensor::new(vec![rows, n_cols], data)?, oh, ow))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    /// Direct (naive) convolution oracle for the im2col+GEMM path.
    fn conv_naive(
        x: &Tensor,
        wmat: &Tensor, // (k, f*f*c)
        b: &Tensor,
        f: usize,
        stride: usize,
        same: bool,
    ) -> Tensor {
        let (h, w, c) = (x.shape()[0], x.shape()[1], x.shape()[2]);
        let k = wmat.shape()[0];
        let (oh, ow, pt, pl) = if same {
            let oh = h.div_ceil(stride);
            let ow = w.div_ceil(stride);
            let ph = ((oh - 1) * stride + f).saturating_sub(h);
            let pw = ((ow - 1) * stride + f).saturating_sub(w);
            (oh, ow, ph / 2, pw / 2)
        } else {
            ((h - f) / stride + 1, (w - f) / stride + 1, 0, 0)
        };
        let mut out = vec![0.0f32; oh * ow * k];
        for oy in 0..oh {
            for ox in 0..ow {
                for kk in 0..k {
                    let mut acc = b.data()[kk];
                    for di in 0..f {
                        for dj in 0..f {
                            let iy = (oy * stride + di) as isize - pt as isize;
                            let ix = (ox * stride + dj) as isize - pl as isize;
                            if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                                continue;
                            }
                            for ch in 0..c {
                                let xv = x.data()[(iy as usize * w + ix as usize) * c + ch];
                                let wv = wmat.data()[kk * (f * f * c) + (di * f + dj) * c + ch];
                                acc += xv * wv;
                            }
                        }
                    }
                    out[(oy * ow + ox) * k + kk] = acc;
                }
            }
        }
        Tensor::new(vec![oh, ow, k], out).unwrap()
    }

    #[test]
    fn im2col_identity_filter() {
        // f=1, stride=1: columns are just the pixels.
        let x = Tensor::new(vec![2, 2, 1], vec![1., 2., 3., 4.]).unwrap();
        let (cols, oh, ow) = im2col(&x, 1, 1, "SAME").unwrap();
        assert_eq!((oh, ow), (2, 2));
        assert_eq!(cols.shape(), &[1, 4]);
        assert_eq!(cols.data(), &[1., 2., 3., 4.]);
    }

    #[test]
    fn conv_matches_naive_same_and_valid() {
        let mut rng = Pcg32::seeded(21);
        for (h, w, c, k, f, s, same) in [
            (5usize, 5usize, 2usize, 3usize, 3usize, 1usize, true),
            (6, 6, 1, 2, 3, 2, true),
            (6, 5, 2, 2, 2, 1, false),
            (7, 7, 3, 4, 5, 2, true),
        ] {
            let x = Tensor::randn(vec![h, w, c], &mut rng);
            let wm = Tensor::randn(vec![k, f * f * c], &mut rng);
            let b = Tensor::randn(vec![k, 1], &mut rng);
            let got = conv_shard(
                &wm,
                &b,
                &x,
                f,
                s,
                if same { "SAME" } else { "VALID" },
                false,
                None,
            )
            .unwrap();
            let want = conv_naive(&x, &wm, &b, f, s, same);
            assert_eq!(got.shape(), want.shape(), "h{h}w{w}c{c}k{k}f{f}s{s}");
            assert!(
                got.max_abs_diff(&want) < 1e-4,
                "h{h}w{w}c{c}k{k}f{f}s{s}: diff {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn fc_shard_bias_and_relu() {
        let w = Tensor::new(vec![2, 2], vec![1., 0., 0., -1.]).unwrap();
        let b = Tensor::new(vec![2, 1], vec![0.5, 0.5]).unwrap();
        let x = Tensor::new(vec![2, 1], vec![1., 2.]).unwrap();
        let lin = fc_shard(&w, &b, &x, false, None).unwrap();
        assert_eq!(lin.data(), &[1.5, -1.5]);
        let act = fc_shard(&w, &b, &x, true, None).unwrap();
        assert_eq!(act.data(), &[1.5, 0.0]);
    }

    #[test]
    fn fc_shard_matches_tensor_matmul_large() {
        // The lowered kernel path must agree with the reference math on a
        // shard big enough to exercise tiling.
        let mut rng = Pcg32::seeded(33);
        let w = Tensor::randn(vec![96, 130], &mut rng);
        let b = Tensor::randn(vec![96, 1], &mut rng);
        let x = Tensor::randn(vec![130, 9], &mut rng);
        let got = fc_shard(&w, &b, &x, true, None).unwrap();
        let mut want = w.matmul_naive(&x).unwrap();
        for (i, row) in want.data_mut().chunks_mut(9).enumerate() {
            for v in row.iter_mut() {
                *v = (*v + b.data()[i]).max(0.0);
            }
        }
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn fc_shard_packed_is_bitwise_identical() {
        // Deploy-time packed panels must change nothing about the
        // result — including on batched inputs and on GEMV shapes that
        // fall back to the naive path.
        let mut rng = Pcg32::seeded(34);
        for (m, k, n) in [(96usize, 130usize, 9usize), (120, 400, 1), (64, 512, 16)] {
            let w = Tensor::randn(vec![m, k], &mut rng);
            let b = Tensor::randn(vec![m, 1], &mut rng);
            let x = Tensor::randn(vec![k, n], &mut rng);
            let pw = kernels::PackedWeights::pack(w.data(), m, k);
            let plain = fc_shard(&w, &b, &x, true, None).unwrap();
            let packed = fc_shard(&w, &b, &x, true, Some(&pw)).unwrap();
            assert_eq!(plain.data(), packed.data(), "({m},{k},{n})");
        }
        // Mismatched panels (stale deploy state) fall back, not corrupt.
        let w = Tensor::randn(vec![8, 8], &mut rng);
        let b = Tensor::randn(vec![8, 1], &mut rng);
        let x = Tensor::randn(vec![8, 1], &mut rng);
        let wrong = kernels::PackedWeights::pack(&[0.0; 6], 2, 3);
        let plain = fc_shard(&w, &b, &x, false, None).unwrap();
        let got = fc_shard(&w, &b, &x, false, Some(&wrong)).unwrap();
        assert_eq!(plain.data(), got.data());
    }

    #[test]
    fn conv_shard_packed_is_bitwise_identical() {
        let mut rng = Pcg32::seeded(35);
        let (h, w, c, k, f, s) = (14usize, 14usize, 6usize, 16usize, 5usize, 1usize);
        let x = Tensor::randn(vec![h, w, c], &mut rng);
        let wm = Tensor::randn(vec![k, f * f * c], &mut rng);
        let b = Tensor::randn(vec![k, 1], &mut rng);
        let pw = kernels::PackedWeights::pack(wm.data(), k, f * f * c);
        let plain = conv_shard(&wm, &b, &x, f, s, "SAME", true, None).unwrap();
        let packed = conv_shard(&wm, &b, &x, f, s, "SAME", true, Some(&pw)).unwrap();
        assert_eq!(plain.data(), packed.data());
    }

    #[test]
    fn gemm_spec_validates_shapes() {
        let spec = GemmExec {
            m: 2,
            k: 3,
            n: 1,
            bias: false,
            relu: false,
            #[cfg(feature = "pjrt")]
            exe: None,
        };
        let w = Tensor::zeros(vec![2, 3]);
        let x = Tensor::zeros(vec![3, 1]);
        assert!(InterpRuntime::run_gemm_spec(&spec, &[&w, &x]).is_ok());
        let bad = Tensor::zeros(vec![4, 1]);
        assert!(InterpRuntime::run_gemm_spec(&spec, &[&w, &bad]).is_err());
    }
}
