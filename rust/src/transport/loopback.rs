//! Loopback worker fleet: N `cdc-dnn worker` **child processes** on
//! 127.0.0.1, for driving the full serving engine over real sockets
//! with real process-kill failure injection.
//!
//! Each worker is spawned with an ephemeral port and its bound address
//! parsed from the `cdc-dnn worker listening on …` stdout line. The
//! children are wrapped in `Arc<Mutex<Child>>` so a chaos timer thread
//! ([`LoopbackFleet::kill_after`]) can SIGKILL one mid-run while the
//! coordinator blocks in `Session::serve` — the TCP transport's event
//! loop sees the connection die (EOF/hangup readiness) and synthesises
//! the losses CDC then recovers from. Dropping the fleet kills and
//! reaps every child.

use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, ChildStdout, Command, Stdio};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::error::{Error, Result};

use super::worker::LISTENING_PREFIX;
use super::TcpConfig;

/// One spawned worker child process.
pub struct LoopbackWorker {
    child: Arc<Mutex<Child>>,
    /// The worker's bound `host:port`.
    pub addr: String,
    /// Kept open so the child's stdout pipe never blocks it.
    _stdout: Option<BufReader<ChildStdout>>,
}

/// A fleet of loopback worker processes.
pub struct LoopbackFleet {
    workers: Vec<LoopbackWorker>,
}

/// Resolve the worker binary: `CDC_DNN_WORKER_BIN` if set (integration
/// tests and benches point it — or the `bin` argument — at
/// `CARGO_BIN_EXE_cdc-dnn`), else the current executable (the `cdc-dnn`
/// binary spawning its own loopback fleet).
pub fn default_worker_bin() -> Result<PathBuf> {
    if let Ok(p) = std::env::var("CDC_DNN_WORKER_BIN") {
        return Ok(PathBuf::from(p));
    }
    std::env::current_exe().map_err(|e| Error::io("current_exe", e))
}

impl LoopbackFleet {
    /// Spawn `n` workers of `bin` (None = [`default_worker_bin`]) over
    /// the artifact set at `artifacts`. Optional `rate` enables
    /// RPi-style compute emulation (MACs/ms) on every worker.
    pub fn spawn(
        bin: Option<&Path>,
        artifacts: &Path,
        n: usize,
        rate_macs_per_ms: Option<f64>,
    ) -> Result<LoopbackFleet> {
        let default_bin;
        let bin = match bin {
            Some(b) => b,
            None => {
                default_bin = default_worker_bin()?;
                &default_bin
            }
        };
        // Build the fleet incrementally so an error mid-spawn drops the
        // partial fleet — Drop kills and reaps every child spawned so
        // far (no orphan worker processes on failure).
        let mut fleet = LoopbackFleet { workers: Vec::with_capacity(n) };
        for i in 0..n {
            let mut cmd = Command::new(bin);
            cmd.arg("worker")
                .arg("--listen")
                .arg("127.0.0.1:0")
                .arg("--artifacts")
                .arg(artifacts)
                .stdin(Stdio::null())
                .stdout(Stdio::piped())
                .stderr(Stdio::inherit());
            if let Some(r) = rate_macs_per_ms {
                cmd.arg("--rate").arg(format!("{r}"));
            }
            let mut child = cmd
                .spawn()
                .map_err(|e| Error::Fleet(format!("spawn worker {i} ({}): {e}", bin.display())))?;
            let stdout = child
                .stdout
                .take()
                .ok_or_else(|| Error::Fleet(format!("worker {i}: no stdout pipe")))?;
            let mut reader = BufReader::new(stdout);
            let addr = match read_listen_line(&mut reader) {
                Ok(a) => a,
                Err(e) => {
                    let _ = child.kill();
                    let _ = child.wait();
                    return Err(e);
                }
            };
            fleet.workers.push(LoopbackWorker {
                child: Arc::new(Mutex::new(child)),
                addr,
                _stdout: Some(reader),
            });
        }
        Ok(fleet)
    }

    /// Number of workers (alive or killed).
    pub fn len(&self) -> usize {
        self.workers.len()
    }

    /// True when the fleet is empty.
    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    /// Worker addresses in spawn (= device) order.
    pub fn addrs(&self) -> Vec<String> {
        self.workers.iter().map(|w| w.addr.clone()).collect()
    }

    /// A [`TcpConfig`] pointing at this fleet (default deadlines).
    pub fn tcp_config(&self) -> TcpConfig {
        TcpConfig { workers: self.addrs(), ..TcpConfig::default() }
    }

    /// Spawn one extra worker in **join mode**: instead of binding a
    /// listener, it dials `coordinator_addr` (a live coordinator's
    /// membership port) and `Register`s mid-session. The child is
    /// owned by this fleet like any other worker (killable, reaped on
    /// drop). Optional `leave_after_ms` makes it announce a graceful
    /// `Leave` that long after joining. Returns the fleet index of the
    /// new worker.
    pub fn spawn_joiner(
        &mut self,
        bin: Option<&Path>,
        artifacts: &Path,
        coordinator_addr: &str,
        rate_macs_per_ms: Option<f64>,
        leave_after_ms: Option<u64>,
    ) -> Result<usize> {
        let default_bin;
        let bin = match bin {
            Some(b) => b,
            None => {
                default_bin = default_worker_bin()?;
                &default_bin
            }
        };
        let mut cmd = Command::new(bin);
        cmd.arg("worker")
            .arg("--join")
            .arg(coordinator_addr)
            .arg("--artifacts")
            .arg(artifacts)
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::inherit());
        if let Some(r) = rate_macs_per_ms {
            cmd.arg("--rate").arg(format!("{r}"));
        }
        if let Some(ms) = leave_after_ms {
            cmd.arg("--leave-after-ms").arg(format!("{ms}"));
        }
        let child = cmd.spawn().map_err(|e| {
            Error::Fleet(format!("spawn joining worker ({}): {e}", bin.display()))
        })?;
        self.workers.push(LoopbackWorker {
            child: Arc::new(Mutex::new(child)),
            addr: format!("joined:{coordinator_addr}"),
            _stdout: None,
        });
        Ok(self.workers.len() - 1)
    }

    /// SIGKILL worker `i` now (and reap it).
    pub fn kill(&self, i: usize) -> Result<()> {
        let w = self
            .workers
            .get(i)
            .ok_or_else(|| Error::Config(format!("no worker {i}")))?;
        let mut child = w.child.lock().unwrap_or_else(|e| e.into_inner());
        child
            .kill()
            .map_err(|e| Error::Fleet(format!("kill worker {i}: {e}")))?;
        let _ = child.wait();
        Ok(())
    }

    /// SIGKILL worker `i` from a timer thread after `delay_ms` — the
    /// chaos injector used while the coordinator blocks in
    /// `Session::serve`. Join the handle to synchronise.
    pub fn kill_after(&self, i: usize, delay_ms: u64) -> std::thread::JoinHandle<()> {
        let child = self.workers[i].child.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(delay_ms));
            let mut c = child.lock().unwrap_or_else(|e| e.into_inner());
            if c.kill().is_ok() {
                let _ = c.wait();
            }
        })
    }
}

impl Drop for LoopbackFleet {
    fn drop(&mut self) {
        for w in &self.workers {
            let mut c = w.child.lock().unwrap_or_else(|e| e.into_inner());
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// Read stdout lines until the worker announces its bound address.
fn read_listen_line(reader: &mut BufReader<ChildStdout>) -> Result<String> {
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader
            .read_line(&mut line)
            .map_err(|e| Error::io("worker stdout", e))?;
        if n == 0 {
            return Err(Error::Fleet(
                "worker exited before announcing its address".into(),
            ));
        }
        if let Some(addr) = line.trim_end().strip_prefix(LISTENING_PREFIX) {
            return Ok(addr.to_string());
        }
    }
}
