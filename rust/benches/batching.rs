//! Cross-request micro-batching bench (DESIGN.md §10): sweeps batch
//! width × arrival rate over the **steady** scenario (the suite's
//! control script — moderate WLAN, Poisson arrivals, no chaos) on the
//! CDC arm, and records virtual-time serving quality per point to
//! repo-root `BENCH_batching.json`.
//!
//! What the sweep shows: per-order overhead (dispatch, request leg,
//! reply base latency + jitter draw, parity resolution) is paid once per
//! *batch* instead of once per request, so under backlog the measured
//! rps grows with the batch width while compute scales linearly — the
//! amortisation the ROADMAP's "heavy traffic" north star needs. Two
//! invariants are enforced on every run:
//!
//! * **no request loss**: every point runs parity-coded CDC and must
//!   complete all arrivals (batching must not break the paper
//!   invariant);
//! * **batching pays**: at the steady scenario's base rate,
//!   `batch_max = 4` must beat the unbatched baseline's rps.
//!
//! `BATCHING_BENCH_SMOKE=1` scales the horizons down for CI;
//! `BENCH_BASELINE_ENFORCE=1` additionally gates the headline metrics
//! against the committed seed in `rust/baselines/BENCH_batching.json`
//! (see `cdc_dnn::bench::guard_baseline`).
//!
//! Run with `cargo bench --bench batching`.

use std::path::{Path, PathBuf};
use std::time::Instant;

use cdc_dnn::bench::guard_baseline;
use cdc_dnn::exp::scenarios::{arm_cfg, steady, Arm, BATCHED_ARM_WAIT_MS};
use cdc_dnn::json::{obj, Value};
use cdc_dnn::scenario::ScenarioEngine;
use cdc_dnn::testkit::synth;

/// Batch widths swept (1 = the unbatched PR-3 engine, bit-exact).
const WIDTHS: [usize; 4] = [1, 2, 4, 8];
/// Arrival rates swept (rps); the middle one is the steady scenario's
/// base rate.
const RATES: [f64; 3] = [25.0, 50.0, 100.0];
const SEED: u64 = 2021;

fn bench_out_path() -> PathBuf {
    // Benches run with cwd = the `rust` package; the baseline lives at
    // the repo root next to ROADMAP.md.
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_batching.json"))
        .unwrap_or_else(|| PathBuf::from("BENCH_batching.json"))
}

fn main() {
    let smoke = std::env::var("BATCHING_BENCH_SMOKE").is_ok();
    println!(
        "batching: compute backend = {}, smoke = {smoke}",
        cdc_dnn::runtime::backend_label()
    );
    let arts = synth::build(SEED).expect("synthetic artifacts");
    let scale = if smoke { 0.5 } else { 1.0 };

    let mut rows = Vec::new();
    // Peak rps across the swept arrival rates, by batch width — the
    // acceptance comparison and the baseline-guard headline metrics.
    // (At light load every width is arrival-limited and the formation
    // window only costs latency; the throughput claim is about the
    // saturated regime, which the peak captures.)
    let mut peak_rps: Vec<(usize, f64)> = WIDTHS.iter().map(|&w| (w, 0.0)).collect();
    let t0 = Instant::now();
    for &rate in &RATES {
        for &width in &WIDTHS {
            let mut sc = steady(SEED).scaled(scale);
            sc.base_rate_rps = rate;
            sc.name = format!("steady@{rate}rps");
            let wait_ms = if width > 1 { BATCHED_ARM_WAIT_MS } else { 0.0 };
            let mut cfg = arm_cfg(&sc, Arm::Cdc);
            cfg.batch_max = width;
            cfg.batch_wait_ms = wait_ms;
            let mut engine = ScenarioEngine::new(&arts.root, cfg).expect("deploy");
            let report = engine.run(&sc).expect("steady scenario run");
            let s = report.latency.summary();
            println!(
                "  rate={rate:>5.0}rps batch_max={width}: {} (max_batch={})",
                report.line(),
                report.max_batch
            );
            assert_eq!(
                report.failed, 0,
                "CDC arm lost requests at rate={rate} batch_max={width}: {}",
                report.line()
            );
            if width == 1 {
                assert_eq!(
                    report.max_batch, 1,
                    "batch_max=1 must never form a wider batch"
                );
            }
            for slot in peak_rps.iter_mut().filter(|(w, _)| *w == width) {
                slot.1 = slot.1.max(report.rps());
            }
            rows.push(obj(vec![
                ("rate_rps", Value::Num(rate)),
                ("batch_max", Value::Num(width as f64)),
                ("batch_wait_ms", Value::Num(wait_ms)),
                ("completed", Value::Num(report.completed as f64)),
                ("failed", Value::Num(report.failed as f64)),
                ("recovered", Value::Num(report.recovered as f64)),
                ("rps", Value::Num(report.rps())),
                ("p50_ms", Value::Num(s.p50)),
                ("p99_ms", Value::Num(s.p99)),
                ("makespan_ms", Value::Num(report.makespan_ms)),
                ("max_batch", Value::Num(report.max_batch as f64)),
            ]));
        }
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1000.0;

    // The acceptance invariant (ISSUE 4): batch_max >= 4 beats the
    // unbatched baseline's sustainable throughput under the steady
    // scenario.
    let rps_of = |w: usize| {
        peak_rps
            .iter()
            .find(|(width, _)| *width == w)
            .map(|(_, r)| *r)
            .expect("peak point measured")
    };
    let (b1, b4) = (rps_of(1), rps_of(4));
    println!(
        "steady scenario peak: unbatched {b1:.1} rps vs batch_max=4 {b4:.1} rps \
         ({:.2}x)",
        b4 / b1
    );
    assert!(
        b4 > b1,
        "micro-batching regression: batch_max=4 ({b4:.2} rps peak) does not \
         beat the unbatched baseline ({b1:.2} rps peak) under the steady \
         scenario"
    );

    let doc = obj(vec![
        ("experiment", Value::Str("bench_batching".into())),
        ("backend", Value::Str(cdc_dnn::runtime::backend_label().into())),
        ("smoke", Value::Bool(smoke)),
        ("suite_wall_ms", Value::Num(wall_ms)),
        ("points", Value::Arr(rows)),
    ]);
    let out = bench_out_path();
    std::fs::write(&out, doc.to_string_pretty()).expect("write BENCH_batching.json");
    println!("[result] wrote {}", out.display());

    // Perf-trajectory guard: virtual-time rps is deterministic in the
    // seed, so these are stable metrics across machines. Smoke runs use
    // scaled horizons (different numbers), so the keys carry the mode —
    // CI seeds are promoted from smoke artifacts and compare
    // smoke-to-smoke.
    let mode = if smoke { "smoke" } else { "full" };
    let mut metrics: Vec<(String, f64)> = Vec::new();
    for (w, r) in &peak_rps {
        metrics.push((format!("{mode}_steady_peak_rps_b{w}"), *r));
    }
    metrics.push((format!("{mode}_steady_peak_speedup_b4"), b4 / b1));
    guard_baseline("batching", &metrics);
}
