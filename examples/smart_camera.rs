//! Smart-camera scenario: the paper's motivating IoT deployment — a
//! camera node streams frames into a cluster of idle devices running an
//! AlexNet-class recogniser with a channel-split convolution layer *and*
//! a CDC-protected fully-connected layer, under realistic WiFi jitter and
//! intermittent connectivity loss.
//!
//! Shows: conv channel splitting (Fig. 8), CDC on fc (Eq. 11), and that
//! intermittent reply drops (a device "borrowed" by its user, paper §2)
//! never lose a frame.
//!
//! ```bash
//! cargo run --release --example smart_camera
//! ```

use cdc_dnn::coordinator::{Session, SessionConfig, SplitSpec};
use cdc_dnn::fleet::FailurePlan;
use cdc_dnn::metrics::Series;
use cdc_dnn::rng::Pcg32;
use cdc_dnn::tensor::Tensor;

fn main() -> cdc_dnn::Result<()> {
    let mut cfg = SessionConfig::new("lenet5");
    cfg.n_devices = 4;
    // conv2 channel-split two ways with CDC; fc1 split over 4 with CDC.
    cfg.splits.insert("conv2".into(), SplitSpec::cdc(2));
    cfg.splits.insert("fc1".into(), SplitSpec::cdc(4));
    cfg.placement.insert("conv1".into(), vec![0]);
    cfg.placement.insert("conv2".into(), vec![1, 2]);
    cfg.placement.insert("fc1".into(), vec![0, 1, 2, 3]);
    cfg.placement.insert("fc2".into(), vec![3]);
    cfg.placement.insert("fc3".into(), vec![3]);
    cfg.threshold_factor = 1.5; // straggler mitigation on
    let mut session = Session::start("artifacts", cfg)?;
    println!(
        "smart camera fleet: {} devices ({} parity)",
        session.total_devices(),
        session.extra_devices
    );

    // Device 2 only answers 70% of the time — it's someone's tablet.
    session.set_failure(2, FailurePlan::Intermittent(0.3))?;

    let mut rng = Pcg32::seeded(7);
    let mut lat = Series::new();
    let mut recovered = 0;
    let frames = 60;
    for _ in 0..frames {
        let frame = Tensor::randn(vec![28, 28, 1], &mut rng);
        let trace = session.infer(&frame)?;
        lat.record(trace.total_ms);
        if trace.any_recovery {
            recovered += 1;
        }
    }
    let s = lat.summary();
    println!("frames: {frames}, recovered via CDC: {recovered}, lost: 0");
    println!("simulated frame latency: {}", s.line());
    println!("{}", lat.render_histogram(0.0, s.p99.max(100.0), 12, 36));
    assert!(recovered > 0, "intermittent drops must exercise recovery");
    println!("smart_camera OK");
    Ok(())
}
