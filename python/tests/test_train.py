"""Training-path tests: synthetic corpus sanity and learnability."""

import numpy as np

from compile.data import make_digits
from compile.train import accuracy, batched_forward, train
from compile.zoo import LENET5


def test_corpus_is_deterministic_and_labeled():
    x1, y1 = make_digits(64, seed=5)
    x2, y2 = make_digits(64, seed=5)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert x1.shape == (64, 28, 28, 1)
    assert x1.min() >= 0.0 and x1.max() <= 1.0
    assert set(np.unique(y1)).issubset(set(range(10)))


def test_corpus_varies_with_seed():
    x1, _ = make_digits(16, seed=1)
    x2, _ = make_digits(16, seed=2)
    assert not np.allclose(x1, x2)


def test_untrained_net_is_chance_level():
    import jax.numpy as jnp

    from compile.model import init_params

    params = {
        k: (jnp.asarray(w), jnp.asarray(b))
        for k, (w, b) in init_params(LENET5, seed=0).items()
    }
    xt, yt = make_digits(256, seed=9)
    acc = accuracy(LENET5, params, xt, yt)
    assert acc < 0.35, f"untrained accuracy suspiciously high: {acc}"


def test_lenet_learns_the_corpus():
    # A short run must already beat chance decisively (full training in
    # `make artifacts` reaches >99%).
    _params, acc = train(LENET5, n_train=2500, n_test=256, epochs=3, verbose=False)
    assert acc > 0.45, f"lenet failed to learn: {acc}"
