"""Synthetic digit corpus (MNIST stand-in, DESIGN.md §2 substitutions).

The paper's Fig. 2 measures accuracy degradation of *trained* nets under
activation loss; any corpus the nets genuinely learn reproduces the effect.
We render 28×28 digit images from 5×7 bitmap glyphs with random placement,
scale, brightness, and additive noise — hard enough that an untrained net is
at 10% and a trained LeNet-5 reaches >95%.
"""

from __future__ import annotations

import numpy as np

# 5x7 bitmap font for digits 0-9 (rows top→bottom, '#' = on).
_GLYPHS = {
    0: ["#####", "#...#", "#...#", "#...#", "#...#", "#...#", "#####"],
    1: ["..#..", ".##..", "..#..", "..#..", "..#..", "..#..", "#####"],
    2: ["#####", "....#", "....#", "#####", "#....", "#....", "#####"],
    3: ["#####", "....#", "....#", "#####", "....#", "....#", "#####"],
    4: ["#...#", "#...#", "#...#", "#####", "....#", "....#", "....#"],
    5: ["#####", "#....", "#....", "#####", "....#", "....#", "#####"],
    6: ["#####", "#....", "#....", "#####", "#...#", "#...#", "#####"],
    7: ["#####", "....#", "...#.", "..#..", "..#..", ".#...", ".#..."],
    8: ["#####", "#...#", "#...#", "#####", "#...#", "#...#", "#####"],
    9: ["#####", "#...#", "#...#", "#####", "....#", "....#", "#####"],
}


def _glyph_array(d: int) -> np.ndarray:
    return np.array(
        [[1.0 if ch == "#" else 0.0 for ch in row] for row in _GLYPHS[d]],
        dtype=np.float32,
    )


def _upscale(img: np.ndarray, sy: int, sx: int) -> np.ndarray:
    return np.repeat(np.repeat(img, sy, axis=0), sx, axis=1)


def make_digits(n: int, seed: int = 0, size: int = 28):
    """Generate ``n`` labelled digit images, shape (n, size, size, 1)."""
    rng = np.random.default_rng(seed)
    xs = np.zeros((n, size, size, 1), np.float32)
    ys = rng.integers(0, 10, size=n)
    for i, d in enumerate(ys):
        g = _glyph_array(int(d))
        sy = int(rng.integers(2, 4))  # vertical scale 2-3 → 14-21 px tall
        sx = int(rng.integers(2, 5))  # horizontal scale 2-4 → 10-20 px wide
        img = _upscale(g, sy, sx)
        h, w = img.shape
        oy = int(rng.integers(0, size - h + 1))
        ox = int(rng.integers(0, size - w + 1))
        canvas = np.zeros((size, size), np.float32)
        brightness = rng.uniform(0.6, 1.0)
        canvas[oy : oy + h, ox : ox + w] = img * brightness
        canvas += rng.normal(0, 0.08, size=(size, size)).astype(np.float32)
        xs[i, :, :, 0] = np.clip(canvas, 0.0, 1.0)
    return xs, ys.astype(np.int32)
