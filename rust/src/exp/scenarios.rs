//! Scenario suite — scripted fleet-chaos runs over the serving pipeline
//! (DESIGN.md §9, ROADMAP "handles as many scenarios as you can
//! imagine").
//!
//! Six named scenarios cover the paper's §2 failure taxonomy as
//! *time-varying* regimes: `steady` (control), `crash-storm` (staggered
//! permanent failures + an intermittent phase), `churn` (devices
//! leave/join with re-partitioning), `congested-wlan` (Fig. 1's WLAN
//! regime sweeping in and out), `hetero-fleet` (RPi3/RPi4-style rate
//! mixes that turn devices into persistent stragglers), and `burst`
//! (arrival spikes on top of the Poisson stream). Every scenario runs
//! across four redundancy **arms** — no redundancy, replication (2MR),
//! parity-coded CDC with the adaptive policy, and CDC with
//! cross-request micro-batching (`cdc-b4`, DESIGN.md §10) — and the
//! driver records per-arm rps/p50/p99 to `results/scenarios.json`.
//!
//! The suite deploys the synthetic `testkit::synth` model, so — unlike
//! the figure reproductions — it needs no AOT artifact build: it
//! measures the serving engine, the recovery machinery, and the adaptive
//! policy, not XLA. The paper-invariant ("coded serving never loses a
//! request, p99 degrades gracefully") is asserted for every scenario by
//! `rust/tests/scenario_engine.rs` and re-checked by
//! `benches/scenario_suite.rs`.

use crate::coordinator::{AdaptiveConfig, Redundancy, SessionConfig, SplitSpec};
use crate::error::Result;
use crate::json::{obj, Value};
use crate::scenario::{Action, NetProfile, Scenario, ScenarioEngine, ScenarioReport};
use crate::testkit::synth;

use super::{print_table, ExpCtx};

/// A redundancy arm of the suite.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arm {
    /// No redundancy: a failed shard loses the request.
    None,
    /// Replication (2MR): every shard duplicated.
    Replication,
    /// Parity-coded CDC with the adaptive policy on.
    Cdc,
    /// CDC + cross-request micro-batching (`batch_max` =
    /// [`BATCHED_ARM_WIDTH`], DESIGN.md §10): the paper invariant must
    /// survive a device failure killing a whole batch.
    CdcBatched,
}

/// Micro-batch width of the [`Arm::CdcBatched`] arm.
pub const BATCHED_ARM_WIDTH: usize = 4;
/// Batch-formation window (virtual ms) of the [`Arm::CdcBatched`] arm.
pub const BATCHED_ARM_WAIT_MS: f64 = 4.0;

impl Arm {
    /// All arms, table order.
    pub const ALL: [Arm; 4] = [Arm::None, Arm::Replication, Arm::Cdc, Arm::CdcBatched];

    /// Tag used in tables and JSON.
    pub fn label(self) -> &'static str {
        match self {
            Arm::None => "none",
            Arm::Replication => "2mr",
            Arm::Cdc => "cdc",
            Arm::CdcBatched => "cdc-b4",
        }
    }

    /// Arms that run parity-coded CDC — the no-lost-request invariant
    /// applies to these.
    pub fn is_cdc(self) -> bool {
        matches!(self, Arm::Cdc | Arm::CdcBatched)
    }

    fn redundancy(self) -> Redundancy {
        match self {
            Arm::None => Redundancy::None,
            Arm::Replication => Redundancy::TwoMr,
            Arm::Cdc | Arm::CdcBatched => Redundancy::Cdc,
        }
    }
}

/// The deployment template one (scenario, arm) pair runs on: the
/// synthetic MLP, fc1 target-split 4 ways and fc2 2 ways over four data
/// devices, redundancy per the arm, a fast failure-detection window (the
/// chaos scripts flip failures every few hundred virtual ms), the
/// adaptive policy on the CDC arms, and micro-batching on `cdc-b4`.
pub fn arm_cfg(sc: &Scenario, arm: Arm) -> SessionConfig {
    let mut cfg = SessionConfig::new(synth::MODEL);
    cfg.n_devices = 4;
    cfg.seed = sc.seed;
    cfg.net = sc.initial_net.config();
    if let Some(r) = sc.device_rate {
        cfg.device_rate = r;
    }
    cfg.detection_ms = 250.0;
    cfg.threshold_factor = 2.0;
    cfg.splits
        .insert("fc1".into(), SplitSpec { d: 4, redundancy: arm.redundancy() });
    cfg.splits
        .insert("fc2".into(), SplitSpec { d: 2, redundancy: arm.redundancy() });
    if arm.is_cdc() {
        cfg.adaptive = Some(AdaptiveConfig::default());
    }
    if arm == Arm::CdcBatched {
        cfg.batch_max = BATCHED_ARM_WIDTH;
        cfg.batch_wait_ms = BATCHED_ARM_WAIT_MS;
    }
    cfg
}

/// Control run: no events, moderate WLAN.
pub fn steady(seed: u64) -> Scenario {
    Scenario::new("steady", 800.0, 50.0, seed)
}

/// Staggered permanent failures with recovery windows, then an
/// intermittent (flaky-reply) phase. At most one fc1 device is unhealthy
/// at a time — the single-parity tolerance the paper's scheme promises
/// to mask.
pub fn crash_storm(seed: u64) -> Scenario {
    Scenario::new("crash-storm", 1000.0, 50.0, seed)
        .at(200.0, Action::Crash { device: 2 })
        .at(400.0, Action::Recover { device: 2 })
        .at(450.0, Action::Crash { device: 3 })
        .at(650.0, Action::Recover { device: 3 })
        .at(700.0, Action::Flaky { device: 1, p: 0.3 })
        .at(900.0, Action::Recover { device: 1 })
}

/// Fleet churn: two devices leave (splits re-partition 4 → 2 via the
/// partition planner), then rejoin (back to 4).
pub fn churn(seed: u64) -> Scenario {
    Scenario::new("churn", 900.0, 40.0, seed)
        .at(300.0, Action::Leave { n: 2 })
        .at(600.0, Action::Join { n: 2 })
}

/// WLAN regime sweep: the Fig.-1 congested profile rolls in over a
/// moderate network and clears again.
pub fn congested_wlan(seed: u64) -> Scenario {
    Scenario::new("congested-wlan", 900.0, 40.0, seed)
        .at(250.0, Action::Net { profile: NetProfile::Congested })
        .at(600.0, Action::Net { profile: NetProfile::Moderate })
}

/// Heterogeneous fleet on an ideal network with compute slowed so rate
/// differences dominate: one device drops to 0.4×, later another to
/// 0.25× — persistent stragglers the gate + parity substitution absorb.
pub fn hetero_fleet(seed: u64) -> Scenario {
    Scenario::new("hetero-fleet", 800.0, 40.0, seed)
        .with_net(NetProfile::Ideal)
        .with_device_rate(3.0) // fc1 shard ≈ 20 ms: compute dominates
        .at(1.0, Action::Slowdown { device: 1, factor: 0.4 })
        .at(400.0, Action::Slowdown { device: 3, factor: 0.25 })
}

/// Arrival-spike scenario: two 25-request bursts on a 30 rps base
/// stream, plus a rate step in between.
pub fn burst(seed: u64) -> Scenario {
    Scenario::new("burst", 900.0, 30.0, seed)
        .at(300.0, Action::Burst { n: 25 })
        .at(450.0, Action::Rate { rps: 60.0 })
        .at(600.0, Action::Burst { n: 25 })
        .at(650.0, Action::Rate { rps: 30.0 })
}

/// Every named scenario, suite order.
pub fn catalog(seed: u64) -> Vec<Scenario> {
    vec![
        steady(seed),
        crash_storm(seed),
        churn(seed),
        congested_wlan(seed),
        hetero_fleet(seed),
        burst(seed),
    ]
}

/// One (scenario, arm) measurement.
#[derive(Debug)]
pub struct SuitePoint {
    /// Scenario name.
    pub scenario: String,
    /// Redundancy arm.
    pub arm: Arm,
    /// The merged scenario report.
    pub report: ScenarioReport,
}

/// Run the full suite; prints the per-arm table, writes
/// `results/scenarios.json`, and returns the points for tests.
pub fn run(ctx: &ExpCtx) -> Result<Vec<SuitePoint>> {
    let arts = synth::build(ctx.seed)?;
    let scale = if ctx.quick { 0.5 } else { 1.0 };
    let mut points = Vec::new();
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();

    println!("\n=== Scenario suite (synthetic model, virtual time) ===");
    for sc in catalog(ctx.seed) {
        let sc = sc.scaled(scale);
        for arm in Arm::ALL {
            let mut engine = ScenarioEngine::new(&arts.root, arm_cfg(&sc, arm))?;
            let report = engine.run(&sc)?;
            let s = report.latency.summary();
            rows.push(vec![
                sc.name.clone(),
                arm.label().into(),
                format!("{}", report.completed),
                format!("{}", report.failed),
                format!("{}", report.recovered),
                format!("{:.1}", report.rps()),
                format!("{:.1}", s.p50),
                format!("{:.1}", s.p99),
            ]);
            let mut fields = vec![
                ("scenario", Value::Str(sc.name.clone())),
                ("arm", Value::Str(arm.label().into())),
                ("completed", Value::Num(report.completed as f64)),
                ("failed", Value::Num(report.failed as f64)),
                ("recovered", Value::Num(report.recovered as f64)),
                ("dropped", Value::Num(report.dropped as f64)),
                ("rps", Value::Num(report.rps())),
                ("p50_ms", Value::Num(s.p50)),
                ("p99_ms", Value::Num(s.p99)),
                ("makespan_ms", Value::Num(report.makespan_ms)),
                ("rebuilds", Value::Num(report.rebuilds as f64)),
                ("max_batch", Value::Num(report.max_batch as f64)),
            ];
            if let Some(p) = &report.policy {
                fields.push((
                    "policy",
                    obj(vec![
                        ("threshold_factor", Value::Num(p.threshold_factor)),
                        ("drop_rate", Value::Num(p.drop_rate)),
                        ("stragglers", Value::Num(p.stragglers as f64)),
                        (
                            "recommended",
                            Value::Str(
                                match p.recommended {
                                    Redundancy::TwoMr => "2mr",
                                    _ => "cdc",
                                }
                                .into(),
                            ),
                        ),
                    ]),
                ));
            }
            json_rows.push(obj(fields));
            points.push(SuitePoint { scenario: sc.name.clone(), arm, report });
        }
    }

    print_table(
        &["scenario", "arm", "served", "lost", "recovered", "rps", "p50 ms", "p99 ms"],
        &rows,
    );
    println!(
        "(CDC arm: adaptive straggler gate + parity substitution — the\n\
         no-lost-request invariant across every scenario is asserted by\n\
         `cargo test -q scenario`)"
    );

    ctx.write_result(
        "scenarios",
        &obj(vec![
            ("experiment", Value::Str("scenario_suite".into())),
            ("backend", Value::Str(crate::runtime::backend_label().into())),
            ("scale", Value::Num(scale)),
            ("points", Value::Arr(json_rows)),
        ]),
    )?;
    Ok(points)
}
