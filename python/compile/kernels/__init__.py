"""L1 kernels: Pallas blocked GEMM + CDC encode/decode, and jnp oracles."""

from compile.kernels.gemm import cdc_decode, cdc_encode, gemm  # noqa: F401
from compile.kernels import ref  # noqa: F401
