"""Build-time training of the Fig.-2 models on the synthetic digit corpus.

Training uses a plain-jnp *batched* forward (``jax.lax`` convolutions) for
speed; the Pallas/im2col inference path is numerically cross-checked against
this forward by the pytest suite, so the trained weights transfer exactly.
Runs once inside ``make artifacts`` (seconds-to-minutes on CPU) and never at
runtime.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from compile.data import make_digits
from compile.model import init_params
from compile.zoo import ModelDesc


def batched_forward(model: ModelDesc, params, xb):
    """(B,H,W,C) (or (B,k)) → (B, classes) logits, pure jnp, train-time only."""
    cur = xb
    for layer in model.layers:
        if layer.kind == "conv":
            w, b = params[layer.name]
            # w: (K,F,F,C) → HWIO
            out = jax.lax.conv_general_dilated(
                cur,
                jnp.transpose(w, (1, 2, 3, 0)),
                window_strides=(layer.s, layer.s),
                padding=layer.padding,
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            ) + b.reshape(1, 1, 1, -1)
            if layer.relu:
                out = jnp.maximum(out, 0.0)
            cur = out
            if layer.pool:
                cur = jax.lax.reduce_window(
                    cur, -jnp.inf, jax.lax.max,
                    (1, layer.pool, layer.pool, 1),
                    (1, layer.pool, layer.pool, 1), "VALID",
                )
        elif layer.kind == "maxpool":
            cur = jax.lax.reduce_window(
                cur, -jnp.inf, jax.lax.max,
                (1, layer.pool, layer.pool, 1),
                (1, layer.pool, layer.pool, 1), "VALID",
            )
        elif layer.kind == "flatten":
            cur = cur.reshape(cur.shape[0], -1)
        elif layer.kind == "gap":
            cur = jnp.mean(cur, axis=(1, 2))
        elif layer.kind == "fc":
            w, b = params[layer.name]
            if layer.relu:
                cur = jnp.maximum(cur @ w.T + b, 0.0)
            else:
                cur = cur @ w.T + b
    return cur


def _loss(model: ModelDesc, params, xb, yb):
    logits = batched_forward(model, params, xb)
    logz = jax.nn.log_softmax(logits)
    return -jnp.mean(logz[jnp.arange(xb.shape[0]), yb])


def train(model: ModelDesc, *, n_train=6000, n_test=1024, epochs=4,
          batch=64, lr=0.05, seed=0, verbose=True) -> Tuple[Dict, float]:
    """SGD-with-momentum training; returns (params, test_accuracy)."""
    xs, ys = make_digits(n_train, seed=seed)
    xt, yt = make_digits(n_test, seed=seed + 1)
    params = {k: (jnp.asarray(w), jnp.asarray(b))
              for k, (w, b) in init_params(model, seed=seed).items()}
    vel = jax.tree_util.tree_map(jnp.zeros_like, params)

    grad_fn = jax.jit(jax.value_and_grad(lambda p, xb, yb: _loss(model, p, xb, yb)))

    @jax.jit
    def step(params, vel, xb, yb):
        loss, g = grad_fn(params, xb, yb)
        vel = jax.tree_util.tree_map(lambda v, gg: 0.9 * v - lr * gg, vel, g)
        params = jax.tree_util.tree_map(lambda p, v: p + v, params, vel)
        return params, vel, loss

    rng = np.random.default_rng(seed)
    nsteps = n_train // batch
    for ep in range(epochs):
        order = rng.permutation(n_train)
        tot = 0.0
        for i in range(nsteps):
            idx = order[i * batch : (i + 1) * batch]
            xb = jnp.asarray(xs[idx])
            if model.input_shape == (xs.shape[1] * xs.shape[2],):
                xb = xb.reshape(batch, -1)
            params, vel, loss = step(params, vel, xb, jnp.asarray(ys[idx]))
            tot += float(loss)
        acc = accuracy(model, params, xt, yt)
        if verbose:
            print(f"[train:{model.name}] epoch {ep+1}/{epochs} "
                  f"loss={tot/nsteps:.4f} test_acc={acc:.4f}")
    np_params = {k: (np.asarray(w), np.asarray(b)) for k, (w, b) in params.items()}
    return np_params, accuracy(model, params, xt, yt)


def accuracy(model: ModelDesc, params, xt, yt, batch=256) -> float:
    correct = 0
    fwd = jax.jit(lambda xb: batched_forward(model, params, xb))
    for i in range(0, len(xt), batch):
        xb = jnp.asarray(xt[i : i + batch])
        pred = np.asarray(jnp.argmax(fwd(xb), axis=1))
        correct += int((pred == yt[i : i + batch]).sum())
    return correct / len(xt)
