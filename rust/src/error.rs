//! Crate-wide error type.

use thiserror::Error;

/// Errors surfaced by the cdc-dnn library.
#[derive(Error, Debug)]
pub enum Error {
    /// Malformed or missing artifact manifest / weights / goldens.
    #[error("artifact error: {0}")]
    Artifact(String),
    /// JSON parse error (line/col best-effort).
    #[error("json error: {0}")]
    Json(String),
    /// Shape mismatch in tensor ops or executor inputs.
    #[error("shape error: {0}")]
    Shape(String),
    /// Underlying XLA/PJRT failure.
    #[error("xla error: {0}")]
    Xla(String),
    /// Invalid deployment / partition configuration.
    #[error("config error: {0}")]
    Config(String),
    /// Fleet communication failure (device hung up, channel closed).
    #[error("fleet error: {0}")]
    Fleet(String),
    /// IO error with path context.
    #[error("io error: {path}: {source}")]
    Io {
        path: String,
        #[source]
        source: std::io::Error,
    },
}

impl Error {
    /// Wrap an io::Error with the offending path.
    pub fn io(path: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
