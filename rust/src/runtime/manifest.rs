//! Typed view of `artifacts/manifest.json`, the contract between the
//! python build path (`compile/aot.py`) and the rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::json::Value;

/// Artifact kind — which shard function a program implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// fc shard: gemm (m,k)×(k,1) + bias [+ relu].
    Fc,
    /// conv channel-split shard: im2col + gemm over (h,w,c) input.
    Conv,
}

/// Convolution geometry of a conv shard artifact — enough for the
/// interpreter backend to reproduce the program without its HLO file
/// (`compile/aot.py` records these alongside the shapes).
#[derive(Debug, Clone)]
pub struct ConvGeom {
    /// Square filter size.
    pub f: usize,
    /// Stride.
    pub s: usize,
    /// "SAME" | "VALID".
    pub padding: String,
}

/// One AOT-compiled HLO program.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: ArtifactKind,
    pub relu: bool,
    /// Parameter shapes in call order (weights, bias, input).
    pub params: Vec<Vec<usize>>,
    /// Conv-only geometry (None for fc artifacts or pre-geometry
    /// manifests, which then require the pjrt backend).
    pub geom: Option<ConvGeom>,
}

/// The two epilogue flavors an (layer, split-degree) pair may ship with.
#[derive(Debug, Clone)]
pub struct SplitArtifacts {
    /// Fused-activation artifact (non-CDC fast path); absent for layers
    /// without activation and for final logits layers.
    pub relu: Option<String>,
    /// Pre-activation artifact (CDC mode; activation applied at merge).
    pub lin: String,
}

/// One layer of a model as recorded in the manifest.
#[derive(Debug, Clone)]
pub struct LayerManifest {
    pub name: String,
    pub kind: String, // conv | fc | maxpool | flatten | gap
    pub k: usize,
    pub f: usize,
    pub s: usize,
    pub m: usize,
    pub relu: bool,
    pub padding: String,
    pub pool: usize,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    /// Byte offsets into the model weights file (fc/conv only).
    pub w_offset: Option<usize>,
    pub b_offset: Option<usize>,
    /// Weight matrix shape (m, k) — conv filters pre-unrolled.
    pub w_shape: Option<(usize, usize)>,
    /// split-degree → artifact names.
    pub splits: BTreeMap<usize, SplitArtifacts>,
}

impl LayerManifest {
    /// True for the compute layers that get distributed.
    pub fn is_weighted(&self) -> bool {
        matches!(self.kind.as_str(), "fc" | "conv")
    }

    /// Output height of one shard when split `d` ways (rows for fc,
    /// channels for conv): uniform ceil division with zero padding.
    pub fn shard_height(&self, d: usize) -> usize {
        let total = if self.kind == "fc" { self.m } else { self.k };
        total.div_ceil(d)
    }
}

/// One model deployment description.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub classes: usize,
    pub trained: bool,
    pub layers: Vec<LayerManifest>,
    pub weights_file: String,
}

/// Held-out evaluation set for Fig. 2.
#[derive(Debug, Clone)]
pub struct EvalSet {
    pub images: String,
    pub labels: String,
    pub count: usize,
    pub image_shape: Vec<usize>,
}

/// The parsed manifest plus its root directory.
#[derive(Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub models: BTreeMap<String, ModelManifest>,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
    pub eval_set: EvalSet,
    pub goldens: Vec<Value>,
    pub raw: Value,
}

impl Manifest {
    /// Load and validate `<root>/manifest.json`.
    pub fn load(root: impl AsRef<Path>) -> Result<Manifest> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        let raw = Value::parse(&text)?;

        let mut artifacts = BTreeMap::new();
        for a in raw.get("artifacts")?.as_arr()? {
            let name = a.get("name")?.as_str()?.to_string();
            let kind = match a.get("kind")?.as_str()? {
                "fc" => ArtifactKind::Fc,
                "conv" => ArtifactKind::Conv,
                other => {
                    return Err(Error::Artifact(format!("unknown artifact kind {other}")))
                }
            };
            let params = a
                .get("params")?
                .as_arr()?
                .iter()
                .map(|p| p.as_usize_vec())
                .collect::<Result<Vec<_>>>()?;
            let geom = if kind == ArtifactKind::Conv {
                match (a.opt("f"), a.opt("s"), a.opt("padding")) {
                    (Some(f), Some(s), Some(p)) => Some(ConvGeom {
                        f: f.as_usize()?,
                        s: s.as_usize()?,
                        padding: p.as_str()?.to_string(),
                    }),
                    _ => None,
                }
            } else {
                None
            };
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name,
                    file: a.get("file")?.as_str()?.to_string(),
                    kind,
                    relu: a.get("relu")?.as_bool()?,
                    params,
                    geom,
                },
            );
        }

        let mut models = BTreeMap::new();
        for m in raw.get("models")?.as_arr()? {
            let model = parse_model(m)?;
            // Validate artifact references.
            for layer in &model.layers {
                for arts in layer.splits.values() {
                    for name in arts.relu.iter().chain(std::iter::once(&arts.lin)) {
                        if !artifacts.contains_key(name) {
                            return Err(Error::Artifact(format!(
                                "model {} layer {} references unknown artifact {name}",
                                model.name, layer.name
                            )));
                        }
                    }
                }
            }
            models.insert(model.name.clone(), model);
        }

        let ev = raw.get("eval_set")?;
        let eval_set = EvalSet {
            images: ev.get("images")?.as_str()?.to_string(),
            labels: ev.get("labels")?.as_str()?.to_string(),
            count: ev.get("count")?.as_usize()?,
            image_shape: ev.get("image_shape")?.as_usize_vec()?,
        };

        let goldens = raw.get("goldens")?.as_arr()?.to_vec();
        Ok(Manifest { root, models, artifacts, eval_set, goldens, raw })
    }

    /// Model lookup with a helpful error.
    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .get(name)
            .ok_or_else(|| Error::Config(format!("unknown model {name:?}")))
    }

    /// Artifact lookup with a helpful error.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("unknown artifact {name:?}")))
    }

    /// Absolute path of a manifest-relative file.
    pub fn path(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }

    /// Read a raw little-endian f32 file (weights, goldens, eval images).
    pub fn read_f32(&self, rel: &str) -> Result<Vec<f32>> {
        let path = self.path(rel);
        let bytes = std::fs::read(&path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        if bytes.len() % 4 != 0 {
            return Err(Error::Artifact(format!("{rel}: length not multiple of 4")));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Cheap logical clone for sessions sharing a compute server: re-reads
    /// the manifest from disk (the JSON is small).
    pub fn clone_shallow(&self) -> Result<Manifest> {
        Manifest::load(&self.root)
    }

    /// Read a raw little-endian i32 file (labels).
    pub fn read_i32(&self, rel: &str) -> Result<Vec<i32>> {
        let path = self.path(rel);
        let bytes = std::fs::read(&path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

fn parse_model(m: &Value) -> Result<ModelManifest> {
    let mut layers = Vec::new();
    for l in m.get("layers")?.as_arr()? {
        let mut splits = BTreeMap::new();
        if let Some(sp) = l.opt("splits") {
            for (d, v) in sp.as_obj()? {
                let d: usize = d
                    .parse()
                    .map_err(|_| Error::Json(format!("bad split degree {d:?}")))?;
                splits.insert(
                    d,
                    SplitArtifacts {
                        relu: v.opt("relu").map(|r| r.as_str().map(str::to_string)).transpose()?,
                        lin: v.get("lin")?.as_str()?.to_string(),
                    },
                );
            }
        }
        layers.push(LayerManifest {
            name: l.get("name")?.as_str()?.to_string(),
            kind: l.get("kind")?.as_str()?.to_string(),
            k: l.get("k")?.as_usize()?,
            f: l.get("f")?.as_usize()?,
            s: l.get("s")?.as_usize()?,
            m: l.get("m")?.as_usize()?,
            relu: l.get("relu")?.as_bool()?,
            padding: l.get("padding")?.as_str()?.to_string(),
            pool: l.get("pool")?.as_usize()?,
            input_shape: l.get("input_shape")?.as_usize_vec()?,
            output_shape: l.get("output_shape")?.as_usize_vec()?,
            w_offset: l.opt("w_offset").map(|v| v.as_usize()).transpose()?,
            b_offset: l.opt("b_offset").map(|v| v.as_usize()).transpose()?,
            w_shape: match l.opt("w_shape") {
                Some(v) => {
                    let d = v.as_usize_vec()?;
                    Some((d[0], d[1]))
                }
                None => None,
            },
            splits,
        });
    }
    Ok(ModelManifest {
        name: m.get("name")?.as_str()?.to_string(),
        input_shape: m.get("input_shape")?.as_usize_vec()?,
        classes: m.get("classes")?.as_usize()?,
        trained: m.get("trained")?.as_bool()?,
        layers,
        weights_file: m.get("weights_file")?.as_str()?.to_string(),
    })
}
