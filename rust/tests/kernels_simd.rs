//! SIMD dispatch-tier properties (DESIGN.md §15). Whatever micro-kernel
//! tier runtime detection selects (AVX2 on x86_64, NEON on aarch64,
//! scalar otherwise), results must be **bit-identical** to the scalar
//! register tile on every shape — mul+add ordering is part of the kernel
//! contract, not a tolerance question — and bit-identical to the naive
//! triple loop whenever the depth fits one K panel (`k <= KC`, so panel
//! accumulation never reorders the sum). The packed-weight and threaded
//! drivers inherit the same contract. On a scalar-only host the SIMD
//! assertions degenerate to scalar == scalar and still run; the CI
//! aarch64 job executes this file under QEMU so the NEON tile is proven,
//! and the x86_64 runners prove AVX2.
//!
//! Also here: the int8 quantized-CDC property — reconstructing a lost
//! shard's output from the quantized parity task stays within the sum of
//! the members' computable error bounds of the f32 oracle.

use cdc_dnn::kernels::{self, simd, PackedWeights, QuantWeights, Scratch, Tier, KC};
use cdc_dnn::rng::Pcg32;
use cdc_dnn::testkit;

/// Unit dims, primes, off-tile sizes, strip remainders, empty dims, and
/// a zero-depth multiply (c must come back exactly zero).
const EDGE_SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 7, 1),
    (7, 1, 3),
    (1, 64, 9),
    (13, 17, 11),
    (31, 31, 31),
    (64, 64, 64),
    (65, 67, 63),
    (129, 96, 33),
    (4, 256, 8),
    (257, 19, 130),
    (3, 300, 2),
    (5, 0, 7),
    (0, 3, 4),
    (6, 9, 0),
];

fn randv(n: usize, rng: &mut Pcg32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

/// Index of the first bitwise mismatch, if any — f32 equality here is
/// `to_bits`, so -0.0 vs 0.0 or a 1-ulp drift fails loudly.
fn first_bit_diff(a: &[f32], b: &[f32]) -> Option<usize> {
    a.iter().zip(b).position(|(x, y)| x.to_bits() != y.to_bits())
}

fn note_tier() -> Tier {
    let tier = simd::select();
    if tier == Tier::Scalar {
        eprintln!("note: no SIMD tier on this host — asserting scalar == scalar");
    }
    tier
}

#[test]
fn active_tier_is_bitwise_identical_to_scalar_tile() {
    let tier = note_tier();
    let mut rng = Pcg32::seeded(1501);
    let mut sc = Scratch::new();
    for &(m, k, n) in EDGE_SHAPES {
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let mut want = vec![0.0f32; m * n];
        let mut got = vec![0.0f32; m * n];
        kernels::gemm_tiled_with(&a, &b, &mut want, m, k, n, &mut sc, Tier::Scalar);
        kernels::gemm_tiled_with(&a, &b, &mut got, m, k, n, &mut sc, tier);
        assert_eq!(first_bit_diff(&got, &want), None, "{} vs scalar ({m},{k},{n})", tier.label());
    }
}

#[test]
fn active_tier_is_bitwise_identical_to_naive_within_one_k_panel() {
    // One K panel means the blocked path accumulates each c element in
    // the same scalar order as the naive loop — so for k <= KC the
    // entire ladder (naive / tiled / simd) must agree to the bit.
    let tier = note_tier();
    let mut rng = Pcg32::seeded(1502);
    let mut sc = Scratch::new();
    for &(m, k, n) in EDGE_SHAPES.iter().filter(|&&(_, k, _)| k <= KC) {
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let mut want = vec![0.0f32; m * n];
        let mut got = vec![0.0f32; m * n];
        kernels::gemm_naive(&a, &b, &mut want, m, k, n);
        kernels::gemm_tiled_with(&a, &b, &mut got, m, k, n, &mut sc, tier);
        assert_eq!(first_bit_diff(&got, &want), None, "{} vs naive ({m},{k},{n})", tier.label());
    }
}

#[test]
fn threaded_driver_is_bitwise_identical_across_thread_counts() {
    // Row partitioning must never change any element's accumulation
    // order: every thread count produces the single-threaded bits.
    let tier = note_tier();
    let mut rng = Pcg32::seeded(1503);
    let mut sc = Scratch::new();
    for &threads in &[1usize, 2, 3, 8] {
        for &(m, k, n) in EDGE_SHAPES {
            let a = randv(m * k, &mut rng);
            let b = randv(k * n, &mut rng);
            let mut want = vec![0.0f32; m * n];
            let mut got = vec![0.0f32; m * n];
            kernels::gemm_tiled_with(&a, &b, &mut want, m, k, n, &mut sc, tier);
            kernels::gemm_threaded_with(&a, &b, &mut got, m, k, n, threads, tier);
            assert_eq!(
                first_bit_diff(&got, &want),
                None,
                "threaded t={threads} {} ({m},{k},{n})",
                tier.label()
            );
        }
    }
}

#[test]
fn prepacked_weights_are_bitwise_identical_to_on_the_fly_packing() {
    // Deploy-time packing rearranges storage, not arithmetic: the
    // prepacked single-thread and threaded paths must reproduce the
    // exact bits of packing A on the fly, on and off the tile grid.
    let tier = note_tier();
    let mut rng = Pcg32::seeded(1504);
    let mut sc = Scratch::new();
    for &(m, k, n) in &[(4usize, 8usize, 8usize), (64, 64, 64), (65, 300, 63), (129, 96, 33)] {
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let pw = PackedWeights::pack(&a, m, k);
        assert_eq!(pw.dims(), (m, k));
        let mut want = vec![0.0f32; m * n];
        let mut got = vec![0.0f32; m * n];
        kernels::gemm_tiled_with(&a, &b, &mut want, m, k, n, &mut sc, tier);
        kernels::gemm_prepacked(&pw, &b, &mut got, n, &mut sc, tier);
        assert_eq!(first_bit_diff(&got, &want), None, "prepacked ({m},{k},{n})");
        let mut thr = vec![0.0f32; m * n];
        kernels::gemm_prepacked_threaded(&pw, &b, &mut thr, n, 3, tier);
        assert_eq!(first_bit_diff(&thr, &want), None, "prepacked threaded ({m},{k},{n})");
    }
}

#[test]
fn quantized_cdc_reconstruction_stays_within_summed_error_bounds() {
    // The int8 deployment quantizes the CDC parity task's weights (the
    // f32 row-sum of the group) exactly like the data shards, so a lost
    // shard's output is recovered as `parity_out - Σ received` entirely
    // in the dequantized domain. Property: that recovery differs from
    // the lost shard's f32 oracle by at most the sum of every group
    // member's computable quantization bound (DESIGN.md §15) — each
    // term of the subtraction contributes its own bound, nothing more.
    testkit::forall(
        0x51d8,
        40,
        |rng| {
            let g = 2 + rng.below(3); // data shards in the CDC group
            let m = 1 + rng.below(24); // rows per shard
            let k = 1 + rng.below(64);
            let n = 1 + rng.below(4);
            let shards: Vec<Vec<f32>> = (0..g).map(|_| randv(m * k, rng)).collect();
            let x = randv(k * n, rng);
            let lost = rng.below(g);
            (g, m, k, n, shards, x, lost)
        },
        |(g, m, k, n, shards, x, lost)| {
            let (m, k, n) = (*m, *k, *n);
            // Coordinator side: parity weights are the f32 sum of the
            // group, quantized like any other shard.
            let mut parity = vec![0.0f32; m * k];
            for w in shards {
                for (p, &v) in parity.iter_mut().zip(w) {
                    *p += v;
                }
            }
            let qs: Vec<QuantWeights> =
                shards.iter().map(|w| QuantWeights::quantize(w, m, k)).collect();
            let qp = QuantWeights::quantize(&parity, m, k);

            // Worker side: every surviving task runs the int8 kernel.
            let mut outs = vec![vec![0.0f32; m * n]; *g];
            for (o, q) in outs.iter_mut().zip(&qs) {
                kernels::qgemm(q, x, o, n, None, false);
            }
            let mut pout = vec![0.0f32; m * n];
            kernels::qgemm(&qp, x, &mut pout, n, None, false);

            // Recovery of the lost shard, and its f32 oracle.
            let mut rec = pout;
            for (i, o) in outs.iter().enumerate() {
                if i != *lost {
                    for (r, &v) in rec.iter_mut().zip(o) {
                        *r -= v;
                    }
                }
            }
            let mut oracle = vec![0.0f32; m * n];
            kernels::gemm_naive(&shards[*lost], x, &mut oracle, m, k, n);

            // Summed bound: one term per task in the subtraction chain.
            let mut bound = kernels::error_bound(&qp, x, n);
            for (i, q) in qs.iter().enumerate() {
                if i != *lost {
                    for (b, v) in bound.iter_mut().zip(kernels::error_bound(q, x, n)) {
                        *b += v;
                    }
                }
            }
            for idx in 0..m * n {
                let err = (rec[idx] - oracle[idx]).abs();
                if err > bound[idx] + 1e-4 {
                    return Err(format!(
                        "g={g} ({m},{k},{n}) lost={lost} elem {idx}: \
                         err {err} > bound {}",
                        bound[idx]
                    ));
                }
            }
            Ok(())
        },
    );
}
