//! Loopback TCP-transport integration tests (ISSUE 5): the full
//! PR-1..4 serving engine — pipelining, micro-batching, CDC parity
//! decode — over **real sockets** to real `cdc-dnn worker` child
//! processes, including a SIGKILL mid-run that the CDC arm must absorb
//! with zero lost requests and oracle-matching logits.
//!
//! Workers are this crate's own binary (`CARGO_BIN_EXE_cdc-dnn`,
//! provided by cargo for integration tests), so no external setup is
//! needed.

use std::path::Path;

use cdc_dnn::coordinator::{Session, SessionConfig, SplitSpec, Workload};
use cdc_dnn::model::Weights;
use cdc_dnn::rng::Pcg32;
use cdc_dnn::runtime::Manifest;
use cdc_dnn::tensor::Tensor;
use cdc_dnn::testkit::synth;
use cdc_dnn::transport::loopback::LoopbackFleet;
use cdc_dnn::transport::{TcpConfig, TransportSpec};

fn worker_bin() -> &'static Path {
    Path::new(env!("CARGO_BIN_EXE_cdc-dnn"))
}

/// mlp over 2 data devices, both layers parity-coded: 4 total devices
/// (2 data + 2 parity) — one worker process each.
fn base_cfg() -> SessionConfig {
    let mut cfg = SessionConfig::new(synth::MODEL);
    cfg.n_devices = 2;
    cfg.splits.insert("fc1".into(), SplitSpec::cdc(2));
    cfg.splits.insert("fc2".into(), SplitSpec::cdc(2));
    cfg.detection_ms = 200.0;
    cfg
}

fn tcp_cfg(fleet: &LoopbackFleet, order_deadline_ms: f64) -> SessionConfig {
    let mut cfg = base_cfg();
    let mut tcp: TcpConfig = fleet.tcp_config();
    tcp.order_deadline_ms = order_deadline_ms;
    cfg.transport = TransportSpec::Tcp(tcp);
    cfg
}

fn inputs(n: usize, seed: u64) -> Vec<Tensor> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| Tensor::randn(vec![synth::FC1_K], &mut rng)).collect()
}

/// Local single-node forward pass (no fleet at all) — the logits
/// reference both transports must match.
fn oracle(root: &Path, x: &Tensor) -> Tensor {
    let m = Manifest::load(root).unwrap();
    let model = m.model(synth::MODEL).unwrap();
    let w = Weights::load(&m, model).unwrap();
    let xc = x.clone().reshape(vec![x.len(), 1]).unwrap();
    let mut h = w.w("fc1").unwrap().matmul(&xc).unwrap();
    h.add_assign(w.b("fc1").unwrap()).unwrap();
    h.relu();
    let mut out = w.w("fc2").unwrap().matmul(&h).unwrap();
    out.add_assign(w.b("fc2").unwrap()).unwrap();
    out
}

#[test]
fn tcp_serve_matches_local_single_node_run() {
    let arts = synth::build(71).unwrap();
    let fleet = LoopbackFleet::spawn(Some(worker_bin()), &arts.root, 4, None).unwrap();
    let mut session = Session::start(&arts.root, tcp_cfg(&fleet, 2_000.0)).unwrap();
    assert_eq!(session.total_devices(), 4, "2 data + 2 parity");
    assert_eq!(session.transport_label(), "tcp");

    let xs = inputs(6, 710);
    let report = session.serve(&Workload::closed(xs.clone(), 2)).unwrap();
    assert_eq!(report.throughput.completed, 6, "{}", report.line());
    assert!(report.failures.is_empty(), "{}", report.line());
    assert!(report.makespan_ms > 0.0, "wall-clock makespan must advance");
    for t in &report.traces {
        let x = &xs[t.req as usize];
        let want = oracle(&arts.root, x);
        let diff = t.output.max_abs_diff(&want);
        assert!(diff < 1e-4, "req {}: tcp logits diverge by {diff}", t.req);
        assert_eq!(t.output.argmax(), want.argmax(), "req {}", t.req);
    }
}

/// The acceptance test: a steady open-loop stream over ≥4 loopback
/// worker processes, one worker SIGKILLed mid-run, **zero** lost
/// requests on the CDC arm, logits matching the local single-node run —
/// with cross-request micro-batching enabled so a killed worker can
/// take out whole batched orders (which parity then reconstructs for
/// every member at once).
#[test]
fn sigkill_mid_run_loses_nothing_under_cdc() {
    let arts = synth::build(72).unwrap();
    // Emulated RPi-ish compute (~5 ms per shard order) stretches the
    // run to ~1 s of wall clock so the kill lands mid-serving, and
    // makes backlog (hence batching) actually form.
    let fleet = LoopbackFleet::spawn(Some(worker_bin()), &arts.root, 4, Some(20.0)).unwrap();
    let mut cfg = tcp_cfg(&fleet, 1_000.0);
    cfg.batch_max = 4;
    cfg.batch_wait_ms = 2.0;
    let mut session = Session::start(&arts.root, cfg).unwrap();

    // Worker 1 = data device 1 (round-robin places fc1 shard 1 and fc2
    // shard 1 there; parities sit on workers 2 and 3). SIGKILL it while
    // the stream is in flight.
    let n = 120;
    let xs = inputs(n, 720);
    let killer = fleet.kill_after(1, 250);
    let report = session.serve(&Workload::uniform(xs.clone(), 6.0)).unwrap();
    killer.join().unwrap();

    assert_eq!(
        report.throughput.completed, n as u64,
        "CDC arm lost requests: {}",
        report.line()
    );
    assert!(report.failures.is_empty(), "{}", report.line());
    assert_eq!(report.dropped, 0);
    assert!(
        report.throughput.recovered > 0,
        "the kill landed after the run finished — recovery never engaged: {}",
        report.line()
    );
    for t in &report.traces {
        let x = &xs[t.req as usize];
        let want = oracle(&arts.root, x);
        let diff = t.output.max_abs_diff(&want);
        assert!(
            diff < 1e-4,
            "req {}: logits diverge by {diff} (recovered={})",
            t.req,
            t.any_recovery
        );
        assert_eq!(t.output.argmax(), want.argmax(), "req {}", t.req);
    }
    // Wall-clock report sanity: rps and percentiles are real-time.
    assert!(report.rps() > 0.0);
    assert!(report.latency.summary().p99 >= report.latency.summary().p50);
}

/// Live membership (DESIGN.md §13): a fresh worker dials the
/// coordinator's membership listener and `Register`s while an open-loop
/// stream is in flight; later an original worker is SIGKILLed, forcing
/// a repartition that promotes surviving slots (including the joiner)
/// into the serving plan. Zero requests may be lost, and every output —
/// before the join, between join and kill, and after the kill — must
/// match the local single-node oracle.
#[test]
fn live_join_mid_stream_survives_kill_and_matches_oracle() {
    let arts = synth::build(74).unwrap();
    // Emulated compute (~5 ms per shard) stretches the stream so the
    // join and the kill both land mid-serving.
    let fleet = LoopbackFleet::spawn(Some(worker_bin()), &arts.root, 4, Some(20.0)).unwrap();
    let mut session = Session::start(&arts.root, tcp_cfg(&fleet, 1_000.0)).unwrap();
    let addr = session.membership_addr().expect("membership listener on by default");
    assert_eq!(session.partition_epoch(), 0);
    assert_eq!(session.active_devices().to_vec(), vec![0, 1, 2, 3]);

    let root = arts.root.clone();
    let fleet = std::sync::Arc::new(std::sync::Mutex::new(fleet));
    let joiner = {
        let fleet = std::sync::Arc::clone(&fleet);
        std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(150));
            let mut f = fleet.lock().unwrap_or_else(|e| e.into_inner());
            f.spawn_joiner(Some(worker_bin()), &root, &addr, Some(20.0), None)
                .expect("joiner spawn");
        })
    };
    let killer = {
        let fleet = std::sync::Arc::clone(&fleet);
        std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(650));
            let f = fleet.lock().unwrap_or_else(|e| e.into_inner());
            f.kill(1).expect("kill worker 1");
        })
    };

    let n = 120;
    let xs = inputs(n, 740);
    let report = session.serve(&Workload::uniform(xs.clone(), 8.0)).unwrap();
    joiner.join().unwrap();
    killer.join().unwrap();

    assert_eq!(
        report.throughput.completed, n as u64,
        "churn lost requests: {}",
        report.line()
    );
    assert!(report.failures.is_empty(), "{}", report.line());
    assert_eq!(report.dropped, 0);
    // Join and death each forced a live repartition; slot 1 is gone,
    // slot 4 (the joiner) is in, and slot numbers were never reused.
    assert!(
        session.partition_epoch() >= 2,
        "expected ≥ 2 repartitions (join + death), got {}",
        session.partition_epoch()
    );
    assert_eq!(session.active_devices().to_vec(), vec![0, 2, 3, 4]);
    for t in &report.traces {
        let want = oracle(&arts.root, &xs[t.req as usize]);
        let diff = t.output.max_abs_diff(&want);
        assert!(diff < 1e-4, "req {}: logits diverge by {diff}", t.req);
        assert_eq!(t.output.argmax(), want.argmax(), "req {}", t.req);
    }
}

/// Graceful drain (DESIGN.md §13): a joiner that announces `Leave`
/// mid-stream finishes its in-flight orders, the coordinator
/// repartitions back to the original fleet, and nothing is lost.
#[test]
fn graceful_leave_drains_without_loss() {
    let arts = synth::build(75).unwrap();
    let fleet = LoopbackFleet::spawn(Some(worker_bin()), &arts.root, 4, Some(20.0)).unwrap();
    let mut session = Session::start(&arts.root, tcp_cfg(&fleet, 1_000.0)).unwrap();
    let addr = session.membership_addr().unwrap();

    let root = arts.root.clone();
    let fleet = std::sync::Arc::new(std::sync::Mutex::new(fleet));
    let joiner = {
        let fleet = std::sync::Arc::clone(&fleet);
        std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(50));
            let mut f = fleet.lock().unwrap_or_else(|e| e.into_inner());
            // Joins ~50 ms in, announces a graceful Leave 300 ms later.
            f.spawn_joiner(Some(worker_bin()), &root, &addr, Some(20.0), Some(300))
                .expect("joiner spawn");
        })
    };

    let n = 120;
    let xs = inputs(n, 750);
    let report = session.serve(&Workload::uniform(xs.clone(), 8.0)).unwrap();
    joiner.join().unwrap();

    assert_eq!(report.throughput.completed, n as u64, "{}", report.line());
    assert!(report.failures.is_empty(), "{}", report.line());
    assert!(
        session.partition_epoch() >= 2,
        "expected ≥ 2 repartitions (join + drain), got {}",
        session.partition_epoch()
    );
    assert_eq!(
        session.active_devices().to_vec(),
        vec![0, 1, 2, 3],
        "the drained joiner must be out of the active set"
    );
    for t in &report.traces {
        let want = oracle(&arts.root, &xs[t.req as usize]);
        assert!(t.output.max_abs_diff(&want) < 1e-4, "req {}", t.req);
    }
}

/// A worker that silently drops replies (the wire twin of the
/// simulator's `Intermittent` plan) is caught by the wall-clock
/// deadline reaper, and CDC recovers the order.
#[test]
fn deadline_reaper_recovers_silent_drops() {
    let arts = synth::build(73).unwrap();
    let fleet = LoopbackFleet::spawn(Some(worker_bin()), &arts.root, 4, None).unwrap();
    // Short deadline so reaped stragglers don't stall the test.
    let mut session = Session::start(&arts.root, tcp_cfg(&fleet, 150.0)).unwrap();
    // Device 0 drops every reply from request 0 on: both layers' shard 0
    // must be reconstructed from parity, every request, forever.
    session
        .set_failure(0, cdc_dnn::fleet::FailurePlan::PermanentAt(0))
        .unwrap();

    let xs = inputs(4, 730);
    let report = session.serve(&Workload::closed(xs.clone(), 1)).unwrap();
    assert_eq!(report.throughput.completed, 4, "{}", report.line());
    assert!(report.failures.is_empty(), "{}", report.line());
    assert_eq!(report.throughput.recovered, 4, "every request recovers");
    for t in &report.traces {
        let want = oracle(&arts.root, &xs[t.req as usize]);
        assert!(t.any_recovery);
        assert!(t.output.max_abs_diff(&want) < 1e-4);
    }
    // Each request waited out the deadline at least once per layer.
    assert!(report.latency.summary().p50 >= 150.0, "{}", report.line());
}
