//! Artifact runtime: load the AOT artifact manifest and execute shard
//! programs through one of two interchangeable compute backends.
//!
//! * **interpreter** (default, always available): executes fc/conv shard
//!   semantics directly from the manifest's [`ArtifactMeta`] with the
//!   in-tree [`Tensor`] ops — no native dependencies, bit-compatible with
//!   the reference math in `python/compile/kernels/ref.py`. This keeps the
//!   whole repo buildable and testable offline (see DESIGN.md §3).
//! * **PJRT** (`--features pjrt`): the original path — load AOT HLO-text
//!   artifacts, compile once via `PjRtClient::cpu()`, execute many. Needs
//!   the vendored `xla` crate (`xla_extension` 0.5.1) added to Cargo.toml.
//!
//! Both backends sit behind the channel-based [`server`] (PJRT state is
//! not `Send`, and the fleet simulator is multi-threaded), so the rest of
//! the system is backend-agnostic.

pub mod interp;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod server;

use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::kernels::{PackedWeights, QuantWeights};
use crate::tensor::Tensor;
pub use manifest::{ArtifactKind, ArtifactMeta, ConvGeom, Manifest, ModelManifest};

/// A compiled (or interpreted) plain GEMM `w@x [+b] [relu]` — fallback
/// used by tests and by shapes outside the artifact set. Input order for
/// [`Runtime::run_built`] is `(w, x[, b])`.
pub struct GemmExec {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub bias: bool,
    pub relu: bool,
    #[cfg(feature = "pjrt")]
    exe: Option<xla::PjRtLoadedExecutable>,
}

enum Backend {
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::PjrtRuntime),
    Interp(interp::InterpRuntime),
}

/// Short label naming the default compute backend + kernel flavor, for
/// baseline attribution in benches/examples (`BENCH_*.json` records must
/// say which backend produced their numbers). The interpreter runs on
/// the tiled kernel layer (DESIGN.md §8) with the runtime-detected SIMD
/// micro-kernel tier (§15): `interp-avx2` / `interp-neon` when a SIMD
/// tile is active, `interp-tiled` on the scalar fallback.
pub fn backend_label() -> &'static str {
    if cfg!(feature = "pjrt") {
        "pjrt"
    } else {
        match crate::kernels::active_tier() {
            "avx2" => "interp-avx2",
            "neon" => "interp-neon",
            _ => "interp-tiled",
        }
    }
}

/// Backend-dispatching executable cache over the artifact set.
pub struct Runtime {
    backend: Backend,
}

impl Runtime {
    /// Create a runtime on the preferred backend (PJRT when the feature
    /// is enabled, the interpreter otherwise).
    pub fn new() -> Result<Runtime> {
        #[cfg(feature = "pjrt")]
        {
            Ok(Runtime { backend: Backend::Pjrt(pjrt::PjrtRuntime::new()?) })
        }
        #[cfg(not(feature = "pjrt"))]
        {
            Ok(Runtime { backend: Backend::Interp(interp::InterpRuntime::new()) })
        }
    }

    /// Force the interpreter backend (useful for cross-checks under the
    /// `pjrt` feature; identical to `new()` without it).
    pub fn new_interpreter() -> Runtime {
        Runtime { backend: Backend::Interp(interp::InterpRuntime::new()) }
    }

    /// Human-readable backend name.
    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => "pjrt",
            Backend::Interp(_) => "interpreter",
        }
    }

    /// Number of compute devices (PJRT CPU: 1; interpreter: 1).
    pub fn device_count(&self) -> usize {
        match &self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => rt.device_count(),
            Backend::Interp(_) => 1,
        }
    }

    /// Total execute() calls issued so far.
    pub fn exec_count(&self) -> u64 {
        match &self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => rt.exec_count(),
            Backend::Interp(rt) => rt.exec_count(),
        }
    }

    /// Pre-compile an artifact by name (deploy-time warm-up, keeps
    /// compile time out of latency measurements). The interpreter only
    /// validates that the artifact exists.
    pub fn preload(&self, manifest: &Manifest, name: &str) -> Result<()> {
        match &self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => rt.preload(manifest, name),
            Backend::Interp(_) => manifest.artifact(name).map(|_| ()),
        }
    }

    /// Execute an artifact on tensor inputs; returns the single output.
    ///
    /// Input order matches the artifact's `params` (weights, bias, input).
    pub fn execute(
        &self,
        manifest: &Manifest,
        name: &str,
        inputs: &[&Tensor],
    ) -> Result<Tensor> {
        self.execute_prepared(manifest, name, inputs, None, None)
    }

    /// [`Runtime::execute`] with a task's deploy-time kernel state
    /// (DESIGN.md §15).
    ///
    /// * `packed`: pre-packed weight panels — the interpreter's blocked
    ///   GEMM reads panels from the arena instead of packing per call
    ///   (ignored by PJRT, which holds its own compiled form). Inputs
    ///   are the usual `(w, b, x)`; `w` stays the naive-path fallback.
    /// * `quant`: int8 weights — inputs shrink to `(b, x)`, the GEMM
    ///   runs in the quantized domain with an i32 accumulator and a
    ///   dequantize epilogue. fc artifacts only, interpreter only.
    pub fn execute_prepared(
        &self,
        manifest: &Manifest,
        name: &str,
        inputs: &[&Tensor],
        packed: Option<&PackedWeights>,
        quant: Option<&QuantWeights>,
    ) -> Result<Tensor> {
        let meta = manifest.artifact(name)?;
        if let Some(q) = quant {
            check_quant_inputs(meta, q, inputs)?;
            return match &self.backend {
                #[cfg(feature = "pjrt")]
                Backend::Pjrt(_) => Err(Error::Config(
                    "int8 precision requires the interpreter backend".into(),
                )),
                Backend::Interp(rt) => rt.execute_quant(meta, q, inputs[0], inputs[1]),
            };
        }
        check_inputs(meta, inputs)?;
        match &self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => rt.execute(manifest, meta, inputs),
            Backend::Interp(rt) => rt.execute_packed(meta, inputs, packed),
        }
    }

    /// Execute with wall-clock timing (perf harness). Warm-up (compile)
    /// happens outside the timed section.
    pub fn execute_timed(
        &self,
        manifest: &Manifest,
        name: &str,
        inputs: &[&Tensor],
    ) -> Result<(Tensor, Duration)> {
        self.preload(manifest, name)?;
        let t0 = Instant::now();
        let out = self.execute(manifest, name, inputs)?;
        Ok((out, t0.elapsed()))
    }

    /// Build a plain GEMM `w@x [+b] [relu]`. The *model* shards always
    /// come from AOT artifacts; see DESIGN.md §3.
    pub fn build_gemm(
        &self,
        m: usize,
        k: usize,
        n: usize,
        bias: bool,
        relu: bool,
    ) -> Result<GemmExec> {
        match &self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => Ok(GemmExec {
                m,
                k,
                n,
                bias,
                relu,
                exe: Some(rt.build_gemm(m, k, n, bias, relu)?),
            }),
            Backend::Interp(_) => Ok(GemmExec {
                m,
                k,
                n,
                bias,
                relu,
                #[cfg(feature = "pjrt")]
                exe: None,
            }),
        }
    }

    /// Execute a built (non-artifact) GEMM on tensors `(w, x[, b])`.
    pub fn run_built(&self, exe: &GemmExec, inputs: &[&Tensor]) -> Result<Tensor> {
        match &self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => match &exe.exe {
                Some(e) => rt.run_built(e, inputs),
                None => interp::InterpRuntime::run_gemm_spec(exe, inputs),
            },
            Backend::Interp(rt) => rt.run_gemm(exe, inputs),
        }
    }
}

/// Validate a quantized task's inputs `(b, x)` against the artifact
/// spec: the int8 weights stand in for `params[0]`, so their dims must
/// match the weight spec, and the activation keeps the fc
/// column-polymorphism of [`check_inputs`].
fn check_quant_inputs(meta: &ArtifactMeta, q: &QuantWeights, inputs: &[&Tensor]) -> Result<()> {
    if meta.kind != ArtifactKind::Fc {
        return Err(Error::Config(format!(
            "{}: int8 precision only applies to fc shards",
            meta.name
        )));
    }
    if meta.params.len() != 3 || inputs.len() != 2 {
        return Err(Error::Shape(format!(
            "{}: quantized task expects (b, x) against a (w, b, x) artifact; \
             got {} inputs for {} params",
            meta.name,
            inputs.len(),
            meta.params.len()
        )));
    }
    let (m, k) = q.dims();
    if meta.params[0] != [m, k] {
        return Err(Error::Shape(format!(
            "{}: int8 weights ({m},{k}) != artifact spec {:?}",
            meta.name, meta.params[0]
        )));
    }
    let b = inputs[0];
    if b.shape() != &meta.params[1][..] {
        return Err(Error::Shape(format!(
            "{}: bias shape {:?} != artifact spec {:?}",
            meta.name,
            b.shape(),
            meta.params[1]
        )));
    }
    let x = inputs[1];
    let spec = &meta.params[2];
    let batched_ok = spec.len() == 2
        && spec[1] == 1
        && x.shape().len() == 2
        && x.shape()[0] == spec[0]
        && x.shape()[1] >= 1;
    if !batched_ok {
        return Err(Error::Shape(format!(
            "{}: activation shape {:?} != artifact spec {:?}",
            meta.name,
            x.shape(),
            spec
        )));
    }
    Ok(())
}

/// Validate tensor inputs against an artifact's parameter spec.
///
/// fc shard programs are **column-polymorphic**: the activation input
/// (the last parameter, spec `(k, 1)`) may carry any batch width `B ≥ 1`
/// instead — the interpreter executes the wider GEMM `w @ (k, B)`
/// directly, which is how cross-request micro-batching (DESIGN.md §10)
/// runs one order for many requests. (AOT PJRT artifacts are compiled at
/// width 1, so batched serving on the `pjrt` feature needs artifacts
/// built at the batch width; the default interpreter backend has no such
/// constraint.)
fn check_inputs(meta: &ArtifactMeta, inputs: &[&Tensor]) -> Result<()> {
    if inputs.len() != meta.params.len() {
        return Err(Error::Shape(format!(
            "{}: expected {} inputs, got {}",
            meta.name,
            meta.params.len(),
            inputs.len()
        )));
    }
    for (i, (t, spec)) in inputs.iter().zip(&meta.params).enumerate() {
        let fc_batched_activation = meta.kind == ArtifactKind::Fc
            && i == meta.params.len() - 1
            && spec.len() == 2
            && spec[1] == 1
            && t.shape().len() == 2
            && t.shape()[0] == spec[0]
            && t.shape()[1] >= 1;
        if fc_batched_activation {
            continue;
        }
        if t.shape() != &spec[..] {
            return Err(Error::Shape(format!(
                "{}: input {i} shape {:?} != artifact spec {:?}",
                meta.name,
                t.shape(),
                spec
            )));
        }
    }
    Ok(())
}
