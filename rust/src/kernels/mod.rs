//! High-performance compute kernel layer (DESIGN.md §8).
//!
//! The paper's CDC overhead claims are all *ratios against a GEMM*: the
//! parity encode, the recovery subtraction, and the straggler gate only
//! read as "close to zero" when the underlying matrix multiply is as
//! fast as the host allows. This module is that baseline: a cache-blocked,
//! register-tiled f32 [`gemm`] with a scoped-thread row driver, the
//! shared epilogues (bias/ReLU and the fused CDC parity checksum), and
//! the [`Scratch`] buffer arena that makes the steady-state serving
//! compute path allocation-free. The interpreter backend
//! (`runtime::interp`), `Tensor::matmul`, and the coordinator's merge
//! path are all lowered onto it; later SIMD/PJRT backends plug in at the
//! same seam.

pub mod gemm;
pub mod scratch;

pub use gemm::{
    auto_threads, bias_relu, gemm_auto, gemm_naive, gemm_threaded, gemm_tiled,
    row_block_checksum, KC, MC, MR, NC, NR,
};
pub use scratch::{with_scratch, Scratch};
