//! Deployment configuration files — the paper's per-system "task
//! allocation file" (§6: "for each number of available devices, a single
//! task allocation file is loaded to all devices"; on failure "the system
//! uses another pre-defined distribution file with fewer devices").
//!
//! JSON on disk ⇄ [`SessionConfig`] in memory, including the failover
//! variants referenced by name.

use std::collections::BTreeMap;

use crate::coordinator::{Redundancy, SessionConfig, SplitSpec};
use crate::error::{Error, Result};
use crate::fleet::NetConfig;
use crate::gateway::GatewayConfig;
use crate::json::{obj, Value};
use crate::transport::{TcpConfig, TransportSpec};

/// Parse a redundancy tag ("none" | "cdc" | "cdc:<group>" | "2mr").
pub fn parse_redundancy(s: &str) -> Result<Redundancy> {
    if let Some(g) = s.strip_prefix("cdc:") {
        let g: usize = g
            .parse()
            .map_err(|_| Error::Config(format!("bad group size in {s:?}")))?;
        return Ok(Redundancy::CdcGrouped(g));
    }
    match s {
        "none" => Ok(Redundancy::None),
        "cdc" => Ok(Redundancy::Cdc),
        "2mr" => Ok(Redundancy::TwoMr),
        _ => Err(Error::Config(format!("unknown redundancy {s:?}"))),
    }
}

fn redundancy_tag(r: Redundancy) -> String {
    match r {
        Redundancy::None => "none".into(),
        Redundancy::Cdc => "cdc".into(),
        Redundancy::CdcGrouped(g) => format!("cdc:{g}"),
        Redundancy::TwoMr => "2mr".into(),
    }
}

/// Load a deployment file into a SessionConfig.
pub fn load_deployment(path: &std::path::Path) -> Result<SessionConfig> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::io(path.display().to_string(), e))?;
    deployment_from_json(&Value::parse(&text)?)
}

/// Parse a deployment JSON value.
pub fn deployment_from_json(v: &Value) -> Result<SessionConfig> {
    let mut cfg = SessionConfig::new(v.get("model")?.as_str()?);
    cfg.n_devices = v.get("n_devices")?.as_usize()?;
    if let Some(t) = v.opt("threshold_factor") {
        cfg.threshold_factor = t.as_f64()?;
    }
    if let Some(s) = v.opt("seed") {
        cfg.seed = s.as_usize()? as u64;
    }
    if let Some(d) = v.opt("detection_ms") {
        cfg.detection_ms = d.as_f64()?;
    }
    if let Some(r) = v.opt("device_rate_macs_per_ms") {
        cfg.device_rate = r.as_f64()?;
    }
    if let Some(a) = v.opt("adaptive") {
        if a.as_bool()? {
            cfg.adaptive = Some(crate::coordinator::AdaptiveConfig::default());
        }
    }
    if let Some(b) = v.opt("batch_max") {
        cfg.batch_max = b.as_usize()?.max(1);
    }
    if let Some(w) = v.opt("batch_wait_ms") {
        cfg.batch_wait_ms = w.as_f64()?.max(0.0);
    }
    if let Some(n) = v.opt("net") {
        let mut net = NetConfig::default();
        if n.as_str().ok() == Some("ideal") {
            net = NetConfig::ideal();
        } else {
            let o = n.as_obj()?;
            let set = |k: &str, dst: &mut f64| -> Result<()> {
                if let Some(x) = o.get(k) {
                    *dst = x.as_f64()?;
                }
                Ok(())
            };
            set("base_ms", &mut net.base_ms)?;
            set("bandwidth_mbps", &mut net.bandwidth_mbps)?;
            set("p_fast", &mut net.p_fast)?;
            set("lognorm_mu", &mut net.lognorm_mu)?;
            set("lognorm_sigma", &mut net.lognorm_sigma)?;
            set("pareto_xm", &mut net.pareto_xm)?;
            set("pareto_alpha", &mut net.pareto_alpha)?;
            set("max_ms", &mut net.max_ms)?;
        }
        cfg.net = net;
    }
    if let Some(splits) = v.opt("splits") {
        for (layer, spec) in splits.as_obj()? {
            let d = spec.get("d")?.as_usize()?;
            let red = match spec.opt("redundancy") {
                Some(r) => parse_redundancy(r.as_str()?)?,
                None => Redundancy::None,
            };
            cfg.splits.insert(layer.clone(), SplitSpec { d, redundancy: red });
        }
    }
    if let Some(pl) = v.opt("placement") {
        for (layer, devs) in pl.as_obj()? {
            cfg.placement.insert(layer.clone(), devs.as_usize_vec()?);
        }
    }
    if let Some(t) = v.opt("transport") {
        cfg.transport = transport_from_json(t)?;
    }
    if let Some(p) = v.opt("precision") {
        cfg.precision = crate::kernels::Precision::parse(p.as_str()?)?;
    }
    Ok(cfg)
}

/// Parse the deployment file's `transport` section: the string `"sim"`,
/// or an object `{"mode": "sim" | "tcp", "workers": [...], ...}`.
pub fn transport_from_json(v: &Value) -> Result<TransportSpec> {
    if v.as_str().ok() == Some("sim") {
        return Ok(TransportSpec::Sim);
    }
    let mode = v.get("mode")?.as_str()?;
    match mode {
        "sim" => Ok(TransportSpec::Sim),
        "tcp" => {
            let mut tcp = TcpConfig::default();
            if let Some(ws) = v.opt("workers") {
                tcp.workers = ws
                    .as_arr()?
                    .iter()
                    .map(|w| w.as_str().map(str::to_string))
                    .collect::<Result<Vec<_>>>()?;
            }
            if let Some(d) = v.opt("order_deadline_ms") {
                tcp.order_deadline_ms = d.as_f64()?;
            }
            if let Some(c) = v.opt("connect_timeout_ms") {
                tcp.connect_timeout_ms = c.as_usize()? as u64;
            }
            if v.opt("reaper_tick_ms").is_some() {
                // Dead since the reaper folded into the event loop's
                // poll timeout; warn once instead of failing old files.
                static WARN_ONCE: std::sync::Once = std::sync::Once::new();
                WARN_ONCE.call_once(|| {
                    eprintln!(
                        "warning: transport.reaper_tick_ms is obsolete and \
                         ignored (deadlines are reaped by the event loop's \
                         poll timeout); remove it from the deployment file"
                    );
                });
            }
            if let Some(l) = v.opt("listen") {
                let addr = l.as_str()?;
                tcp.listen =
                    if addr.is_empty() { None } else { Some(addr.to_string()) };
            }
            if let Some(h) = v.opt("heartbeat_ms") {
                tcp.heartbeat_ms = h.as_f64()?;
            }
            if let Some(s) = v.opt("suspect_after_missed") {
                tcp.suspect_after_missed = s.as_usize()? as u32;
            }
            if let Some(d) = v.opt("dead_after_missed") {
                tcp.dead_after_missed = d.as_usize()? as u32;
            }
            Ok(TransportSpec::Tcp(tcp))
        }
        other => Err(Error::Config(format!("unknown transport mode {other:?}"))),
    }
}

/// Serialise a transport spec back to the deployment-file shape.
pub fn transport_to_json(spec: &TransportSpec) -> Value {
    match spec {
        TransportSpec::Sim => obj(vec![("mode", Value::Str("sim".into()))]),
        TransportSpec::Tcp(tcp) => obj(vec![
            ("mode", Value::Str("tcp".into())),
            (
                "workers",
                Value::Arr(tcp.workers.iter().map(|w| Value::Str(w.clone())).collect()),
            ),
            ("order_deadline_ms", Value::Num(tcp.order_deadline_ms)),
            ("connect_timeout_ms", Value::Num(tcp.connect_timeout_ms as f64)),
            (
                "listen",
                Value::Str(tcp.listen.clone().unwrap_or_default()),
            ),
            ("heartbeat_ms", Value::Num(tcp.heartbeat_ms)),
            (
                "suspect_after_missed",
                Value::Num(tcp.suspect_after_missed as f64),
            ),
            ("dead_after_missed", Value::Num(tcp.dead_after_missed as f64)),
        ]),
    }
}

/// Parse the deployment file's optional `gateway` section:
/// `{"listen": "127.0.0.1:0", "max_body_bytes": N, "request_timeout_ms": N}`
/// (every key optional; defaults from [`GatewayConfig::default`]).
pub fn gateway_from_json(v: &Value) -> Result<GatewayConfig> {
    let mut gw = GatewayConfig::default();
    if let Some(l) = v.opt("listen") {
        gw.listen = l.as_str()?.to_string();
    }
    if let Some(b) = v.opt("max_body_bytes") {
        gw.max_body_bytes = b.as_usize()?;
    }
    if let Some(t) = v.opt("request_timeout_ms") {
        gw.request_timeout_ms = t.as_usize()? as u64;
    }
    Ok(gw)
}

/// Serialise a gateway config back to the deployment-file shape.
pub fn gateway_to_json(gw: &GatewayConfig) -> Value {
    obj(vec![
        ("listen", Value::Str(gw.listen.clone())),
        ("max_body_bytes", Value::Num(gw.max_body_bytes as f64)),
        ("request_timeout_ms", Value::Num(gw.request_timeout_ms as f64)),
    ])
}

/// Read the optional `gateway` section out of a deployment file
/// (`Ok(None)` when the file has none). The section lives beside the
/// session keys rather than inside [`SessionConfig`]: the gateway fronts
/// a session, it is not part of the distribution plan.
pub fn load_gateway(path: &std::path::Path) -> Result<Option<GatewayConfig>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::io(path.display().to_string(), e))?;
    let v = Value::parse(&text)?;
    match v.opt("gateway") {
        Some(g) => Ok(Some(gateway_from_json(g)?)),
        None => Ok(None),
    }
}

/// Serialise a SessionConfig back to the deployment-file JSON shape.
pub fn deployment_to_json(cfg: &SessionConfig) -> Value {
    let splits: BTreeMap<String, Value> = cfg
        .splits
        .iter()
        .map(|(k, s)| {
            (
                k.clone(),
                obj(vec![
                    ("d", Value::Num(s.d as f64)),
                    ("redundancy", Value::Str(redundancy_tag(s.redundancy))),
                ]),
            )
        })
        .collect();
    let placement: BTreeMap<String, Value> = cfg
        .placement
        .iter()
        .map(|(k, devs)| {
            (
                k.clone(),
                Value::Arr(devs.iter().map(|&d| Value::Num(d as f64)).collect()),
            )
        })
        .collect();
    obj(vec![
        ("model", Value::Str(cfg.model.clone())),
        ("n_devices", Value::Num(cfg.n_devices as f64)),
        ("threshold_factor", Value::Num(cfg.threshold_factor)),
        ("seed", Value::Num(cfg.seed as f64)),
        ("detection_ms", Value::Num(cfg.detection_ms)),
        ("device_rate_macs_per_ms", Value::Num(cfg.device_rate)),
        ("adaptive", Value::Bool(cfg.adaptive.is_some())),
        ("batch_max", Value::Num(cfg.batch_max as f64)),
        ("batch_wait_ms", Value::Num(cfg.batch_wait_ms)),
        ("precision", Value::Str(cfg.precision.label().to_string())),
        ("transport", transport_to_json(&cfg.transport)),
        ("splits", Value::Obj(splits)),
        ("placement", Value::Obj(placement)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_deployment() {
        let mut cfg = SessionConfig::new("lenet5");
        cfg.n_devices = 4;
        cfg.splits.insert("fc1".into(), SplitSpec::cdc(4));
        cfg.splits.insert(
            "fc2".into(),
            SplitSpec { d: 2, redundancy: Redundancy::CdcGrouped(1) },
        );
        cfg.placement.insert("fc1".into(), vec![0, 1, 2, 3]);
        cfg.batch_max = 4;
        cfg.batch_wait_ms = 2.5;
        cfg.precision = crate::kernels::Precision::Int8;
        let json = deployment_to_json(&cfg);
        let back = deployment_from_json(&json).unwrap();
        assert_eq!(back.model, "lenet5");
        assert_eq!(back.n_devices, 4);
        assert_eq!(back.batch_max, 4);
        assert!((back.batch_wait_ms - 2.5).abs() < 1e-12);
        assert_eq!(back.precision, crate::kernels::Precision::Int8);
        assert_eq!(back.splits["fc1"].d, 4);
        assert_eq!(back.splits["fc1"].redundancy, Redundancy::Cdc);
        assert_eq!(back.splits["fc2"].redundancy, Redundancy::CdcGrouped(1));
        assert_eq!(back.placement["fc1"], vec![0, 1, 2, 3]);
    }

    #[test]
    fn roundtrip_gateway_section() {
        let gw = GatewayConfig {
            listen: "127.0.0.1:8080".to_string(),
            max_body_bytes: 4096,
            request_timeout_ms: 2500,
        };
        let back = gateway_from_json(&gateway_to_json(&gw)).unwrap();
        assert_eq!(back.listen, "127.0.0.1:8080");
        assert_eq!(back.max_body_bytes, 4096);
        assert_eq!(back.request_timeout_ms, 2500);
        // Every key optional: an empty section is all defaults.
        let dflt = gateway_from_json(&obj(vec![])).unwrap();
        assert_eq!(dflt.listen, GatewayConfig::default().listen);
        assert_eq!(dflt.max_body_bytes, GatewayConfig::default().max_body_bytes);
    }

    #[test]
    fn redundancy_tags() {
        assert_eq!(parse_redundancy("cdc").unwrap(), Redundancy::Cdc);
        assert_eq!(parse_redundancy("cdc:3").unwrap(), Redundancy::CdcGrouped(3));
        assert_eq!(parse_redundancy("2mr").unwrap(), Redundancy::TwoMr);
        assert_eq!(parse_redundancy("none").unwrap(), Redundancy::None);
        assert!(parse_redundancy("bogus").is_err());
        assert!(parse_redundancy("cdc:x").is_err());
    }

    #[test]
    fn roundtrip_tcp_transport() {
        let mut cfg = SessionConfig::new("mlp");
        cfg.n_devices = 2;
        cfg.transport = TransportSpec::Tcp(TcpConfig {
            workers: vec!["127.0.0.1:7070".into(), "127.0.0.1:7071".into()],
            order_deadline_ms: 750.0,
            connect_timeout_ms: 1234,
            listen: None,
            heartbeat_ms: 125.0,
            suspect_after_missed: 3,
            dead_after_missed: 9,
        });
        let back = deployment_from_json(&deployment_to_json(&cfg)).unwrap();
        match back.transport {
            TransportSpec::Tcp(t) => {
                assert_eq!(t.workers, vec!["127.0.0.1:7070", "127.0.0.1:7071"]);
                assert!((t.order_deadline_ms - 750.0).abs() < 1e-12);
                assert_eq!(t.connect_timeout_ms, 1234);
                assert_eq!(t.listen, None);
                assert!((t.heartbeat_ms - 125.0).abs() < 1e-12);
                assert_eq!(t.suspect_after_missed, 3);
                assert_eq!(t.dead_after_missed, 9);
            }
            other => panic!("expected tcp transport, got {other:?}"),
        }
        // Old deployment files carrying the dead reaper knob still parse
        // (the key is warned about and ignored), and `listen` defaults on.
        let v = Value::parse(
            r#"{"model":"mlp","n_devices":1,
                "transport":{"mode":"tcp","reaper_tick_ms":5}}"#,
        )
        .unwrap();
        match deployment_from_json(&v).unwrap().transport {
            TransportSpec::Tcp(t) => {
                assert_eq!(t.listen.as_deref(), Some("127.0.0.1:0"));
            }
            other => panic!("expected tcp transport, got {other:?}"),
        }
        // The string shorthand and the default both mean sim.
        let v = Value::parse(
            r#"{"model":"mlp","n_devices":1,"transport":"sim"}"#,
        )
        .unwrap();
        assert!(matches!(
            deployment_from_json(&v).unwrap().transport,
            TransportSpec::Sim
        ));
        assert!(matches!(
            deployment_from_json(
                &Value::parse(r#"{"model":"mlp","n_devices":1}"#).unwrap()
            )
            .unwrap()
            .transport,
            TransportSpec::Sim
        ));
    }

    #[test]
    fn shipped_configs_carry_no_deprecated_keys() {
        // The hard-deprecated `reaper_tick_ms` no-op must stay scrubbed
        // from every example deployment we ship (old user files still
        // parse with a one-time warning, tested above) — and every
        // shipped file must actually load.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
        let mut checked = 0;
        for entry in std::fs::read_dir(&dir).unwrap() {
            let path = entry.unwrap().path();
            if path.extension().and_then(|e| e.to_str()) != Some("json") {
                continue;
            }
            let text = std::fs::read_to_string(&path).unwrap();
            assert!(
                !text.contains("reaper_tick_ms"),
                "{} still ships the deprecated reaper_tick_ms knob",
                path.display()
            );
            load_deployment(&path)
                .unwrap_or_else(|e| panic!("{} does not load: {e}", path.display()));
            checked += 1;
        }
        assert!(checked >= 2, "expected shipped configs in {}", dir.display());
    }

    #[test]
    fn ideal_net_tag() {
        let v = Value::parse(
            r#"{"model":"lenet5","n_devices":2,"net":"ideal"}"#,
        )
        .unwrap();
        let cfg = deployment_from_json(&v).unwrap();
        assert_eq!(cfg.net.base_ms, 0.0);
    }
}
