//! Coded distributed computing: parity construction, recovery, multi-
//! failure schemes, and the coverage calculus of the paper's Fig. 17.

pub mod coverage;

use crate::error::{Error, Result};
use crate::kernels;
use crate::tensor::Tensor;

/// Parity weights for a set of uniform-height shards (paper Eq. 11):
/// the elementwise sum, computed offline, input-independent.
pub fn parity_weights(shards: &[(Tensor, Tensor)]) -> Result<(Tensor, Tensor)> {
    let (w0, b0) = shards.first().ok_or_else(|| {
        Error::Config("parity over zero shards".into())
    })?;
    let mut pw = w0.clone();
    let mut pb = b0.clone();
    for (w, b) in &shards[1..] {
        pw.add_assign(w)?;
        pb.add_assign(b)?;
    }
    Ok((pw, pb))
}

/// Fused CDC encode (DESIGN.md §8): run ONE tiled GEMM over the
/// vertically stacked shard weights `w_stacked (d·h, k)` and fold the
/// parity output out of the result with the row-block checksum epilogue —
/// the checksum shard costs one extra pass over the output panel, not a
/// separate full parity-weight multiply. Returns the `d` pre-activation
/// shard outputs `(h, n)` and the parity output, which equals
/// `parity_weights(shards).0 @ x + Σ b` exactly (the invariant the
/// decode subtraction relies on; summation happens pre-activation).
pub fn fused_shard_outputs(
    w_stacked: &Tensor,
    b_stacked: &Tensor,
    x: &Tensor,
    d: usize,
) -> Result<(Vec<Tensor>, Tensor)> {
    let (mt, k) = match w_stacked.shape()[..] {
        [m, k] => (m, k),
        _ => {
            return Err(Error::Shape(format!(
                "fused encode weights {:?}",
                w_stacked.shape()
            )))
        }
    };
    let (k2, n) = match x.shape()[..] {
        [k2, n] => (k2, n),
        _ => return Err(Error::Shape(format!("fused encode input {:?}", x.shape()))),
    };
    if k != k2 {
        return Err(Error::Shape(format!("fused encode {mt}x{k} @ {k2}x{n}")));
    }
    if d == 0 || mt % d != 0 {
        return Err(Error::Config(format!(
            "fused encode: {d} shards must divide {mt} rows uniformly"
        )));
    }
    if b_stacked.shape() != [mt, 1] {
        return Err(Error::Shape(format!(
            "fused encode bias {:?} vs rows {mt}",
            b_stacked.shape()
        )));
    }
    let h = mt / d;
    let mut out = vec![0.0f32; mt * n];
    kernels::with_scratch(|sc| {
        kernels::gemm_auto(w_stacked.data(), x.data(), &mut out, mt, k, n, sc)
    });
    kernels::bias_relu(&mut out, mt, n, Some(b_stacked.data()), false);
    let mut parity = vec![0.0f32; h * n];
    kernels::row_block_checksum(&out, mt, n, h, &mut parity);
    let shards = (0..d)
        .map(|i| Tensor::new(vec![h, n], out[i * h * n..(i + 1) * h * n].to_vec()))
        .collect::<Result<Vec<_>>>()?;
    Ok((shards, Tensor::new(vec![h, n], parity)?))
}

/// Recover the single missing shard output: parity − Σ received (§5.2).
/// `received` are the surviving data-shard outputs covered by this parity.
/// Shapes are element-wise, so a batched `(h, B)` parity reconstructs the
/// missing shard for **all** B batch members in the one subtraction —
/// the per-batch recovery invariant the batched serving engine relies on
/// (DESIGN.md §10).
pub fn decode(parity_out: &Tensor, received: &[&Tensor]) -> Result<Tensor> {
    decode_owned(parity_out.clone(), received)
}

/// [`decode`] that consumes the parity output in place of cloning it —
/// the serve hot path's allocation-free recovery subtraction.
pub fn decode_owned(mut parity_out: Tensor, received: &[&Tensor]) -> Result<Tensor> {
    for r in received {
        parity_out.sub_assign(r)?;
    }
    Ok(parity_out)
}

/// Fig. 18 multi-failure scheme: parity *groups*. Each parity device sums
/// a contiguous group of ≤ `group_size` data shards; the system tolerates
/// one failure per group. `group_size == n` degenerates to single parity.
///
/// Returns the cover sets (shard indices per parity device).
pub fn parity_groups(n_shards: usize, group_size: usize) -> Result<Vec<Vec<usize>>> {
    if group_size == 0 || n_shards == 0 {
        return Err(Error::Config("parity_groups: empty".into()));
    }
    let n_groups = n_shards.div_ceil(group_size);
    let ranges = crate::partition::balanced_ranges(n_shards, n_groups);
    Ok(ranges
        .into_iter()
        .map(|(lo, hi)| (lo..hi).collect())
        .collect())
}

/// Number of simultaneous failures the group scheme provably tolerates:
/// one per group (the paper's "partial error correction" note — two
/// failures in one group are not recoverable without Hamming-style codes).
pub fn tolerated_failures(groups: &[Vec<usize>]) -> usize {
    groups.len()
}

/// Can this failure set be recovered by the group scheme?
pub fn recoverable(groups: &[Vec<usize>], failed: &[usize]) -> bool {
    groups.iter().all(|g| g.iter().filter(|s| failed.contains(s)).count() <= 1)
        && failed
            .iter()
            .all(|f| groups.iter().any(|g| g.contains(f)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn parity_then_decode_roundtrip() {
        let mut rng = Pcg32::seeded(4);
        let shards: Vec<(Tensor, Tensor)> = (0..4)
            .map(|_| {
                (
                    Tensor::randn(vec![8, 5], &mut rng),
                    Tensor::randn(vec![8, 1], &mut rng),
                )
            })
            .collect();
        let x = Tensor::randn(vec![5, 1], &mut rng);
        let outs: Vec<Tensor> = shards
            .iter()
            .map(|(w, b)| {
                let mut y = w.matmul(&x).unwrap();
                y.add_assign(b).unwrap();
                y
            })
            .collect();
        let (pw, pb) = parity_weights(&shards).unwrap();
        let mut parity_out = pw.matmul(&x).unwrap();
        parity_out.add_assign(&pb).unwrap();

        // Lose shard 2.
        let received: Vec<&Tensor> = [&outs[0], &outs[1], &outs[3]].to_vec();
        let rec = decode(&parity_out, &received).unwrap();
        assert!(rec.max_abs_diff(&outs[2]) < 1e-4);
    }

    #[test]
    fn fused_epilogue_matches_separate_parity_gemm() {
        // The fused checksum epilogue must produce bit-for-bit the same
        // recovery algebra as the offline parity-weight multiply (within
        // f32 reassociation noise).
        let mut rng = Pcg32::seeded(17);
        let (d, h, k, n) = (4usize, 16usize, 40usize, 3usize);
        let shards: Vec<(Tensor, Tensor)> = (0..d)
            .map(|_| {
                (
                    Tensor::randn(vec![h, k], &mut rng),
                    Tensor::randn(vec![h, 1], &mut rng),
                )
            })
            .collect();
        let x = Tensor::randn(vec![k, n], &mut rng);
        let wrefs: Vec<&Tensor> = shards.iter().map(|(w, _)| w).collect();
        let brefs: Vec<&Tensor> = shards.iter().map(|(_, b)| b).collect();
        let w_stacked = Tensor::concat0(&wrefs).unwrap();
        let b_stacked = Tensor::concat0(&brefs).unwrap();

        let (outs, parity_fused) =
            fused_shard_outputs(&w_stacked, &b_stacked, &x, d).unwrap();

        let (pw, pb) = parity_weights(&shards).unwrap();
        let mut parity_sep = pw.matmul(&x).unwrap();
        for (i, row) in parity_sep.data_mut().chunks_mut(n).enumerate() {
            for v in row.iter_mut() {
                *v += pb.data()[i];
            }
        }
        assert!(parity_fused.max_abs_diff(&parity_sep) < 1e-4);

        // Shard outputs are the plain per-shard GEMMs, and the checksum
        // decodes a missing one.
        for (i, (w, b)) in shards.iter().enumerate() {
            let mut y = w.matmul(&x).unwrap();
            y.add_assign(b).unwrap();
            assert!(outs[i].max_abs_diff(&y) < 1e-4, "shard {i}");
        }
        let received: Vec<&Tensor> = [&outs[0], &outs[2], &outs[3]].to_vec();
        let rec = decode(&parity_fused, &received).unwrap();
        assert!(rec.max_abs_diff(&outs[1]) < 1e-3);
    }

    #[test]
    fn batched_parity_invariant_recovers_every_member() {
        // The serving engine's batched orders run one GEMM over the
        // column-concatenated member activations (k, B); the parity
        // invariant must hold column-wise, and one decode subtraction
        // must reconstruct the missing shard for ALL members at once.
        let mut rng = Pcg32::seeded(23);
        let (d, h, k, batch) = (4usize, 8usize, 12usize, 5usize);
        let shards: Vec<(Tensor, Tensor)> = (0..d)
            .map(|_| {
                (
                    Tensor::randn(vec![h, k], &mut rng),
                    Tensor::randn(vec![h, 1], &mut rng),
                )
            })
            .collect();
        // Batched input = column concat of `batch` member columns.
        let members: Vec<Tensor> =
            (0..batch).map(|_| Tensor::randn(vec![k, 1], &mut rng)).collect();
        let mut xb = vec![0.0f32; k * batch];
        for (j, m) in members.iter().enumerate() {
            for r in 0..k {
                xb[r * batch + j] = m.data()[r];
            }
        }
        let x = Tensor::new(vec![k, batch], xb).unwrap();

        let wrefs: Vec<&Tensor> = shards.iter().map(|(w, _)| w).collect();
        let brefs: Vec<&Tensor> = shards.iter().map(|(_, b)| b).collect();
        let w_stacked = Tensor::concat0(&wrefs).unwrap();
        let b_stacked = Tensor::concat0(&brefs).unwrap();
        let (outs, parity) = fused_shard_outputs(&w_stacked, &b_stacked, &x, d).unwrap();

        // Lose shard 1: the single batched subtraction recovers it.
        let received: Vec<&Tensor> = [&outs[0], &outs[2], &outs[3]].to_vec();
        let rec = decode(&parity, &received).unwrap();
        assert_eq!(rec.shape(), &[h, batch]);
        assert!(rec.max_abs_diff(&outs[1]) < 1e-3);

        // Column j of every shard/recovered output equals the unbatched
        // run on member j alone — batching changes layout, not values.
        for (j, m) in members.iter().enumerate() {
            let (solo, _) = fused_shard_outputs(&w_stacked, &b_stacked, m, d).unwrap();
            for (si, s) in outs.iter().enumerate() {
                for r in 0..h {
                    let batched_v = s.data()[r * batch + j];
                    let solo_v = solo[si].data()[r];
                    assert!(
                        (batched_v - solo_v).abs() < 1e-4,
                        "shard {si} member {j} row {r}: {batched_v} vs {solo_v}"
                    );
                }
            }
        }
    }

    #[test]
    fn groups_cover_all_shards_once() {
        let g = parity_groups(7, 3).unwrap();
        assert_eq!(g.len(), 3);
        let mut all: Vec<usize> = g.concat();
        all.sort_unstable();
        assert_eq!(all, (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn recoverability_semantics() {
        let g = parity_groups(4, 2).unwrap(); // [[0,1],[2,3]]
        assert!(recoverable(&g, &[]));
        assert!(recoverable(&g, &[0]));
        assert!(recoverable(&g, &[0, 2])); // one per group
        assert!(!recoverable(&g, &[0, 1])); // two in one group
        assert!(recoverable(&g, &[1, 3]));
    }

    #[test]
    fn single_group_is_classic_cdc() {
        let g = parity_groups(5, 5).unwrap();
        assert_eq!(g, vec![vec![0, 1, 2, 3, 4]]);
        assert_eq!(tolerated_failures(&g), 1);
    }
}
