//! Case Study II (Figs. 13-15) — the same AlexNet deployment plus one CDC
//! parity device covering the fc6 split. Under a device failure the system
//! keeps serving with *no* slowdown and *no* lost requests; during normal
//! operation the extra device doubles as straggler mitigation (Figs.
//! 14-15), tightening the latency distribution.

use crate::coordinator::{Session, SessionConfig, SplitSpec};
use crate::error::Result;
use crate::fleet::FailurePlan;
use crate::json::{obj, Value};
use crate::metrics::Series;
use crate::rng::Pcg32;

use super::case1::{alexnet_5dev, alexnet_input};
use super::ExpCtx;

/// The six-device allocation: case-1's five devices + a parity for fc6.
pub fn alexnet_6dev(ctx: &ExpCtx, threshold_factor: f64) -> SessionConfig {
    let mut cfg = alexnet_5dev(ctx);
    cfg.splits.insert("fc6".into(), SplitSpec::cdc(2));
    cfg.threshold_factor = threshold_factor;
    cfg
}

/// Results of the case study.
#[derive(Debug)]
pub struct Case2 {
    pub healthy: Series,
    pub failed: Series,
    pub no_mitigation: Series,
    pub with_mitigation: Series,
    pub lost_requests: u64,
    pub recovered_requests: u64,
}

/// Run the experiment.
pub fn run(ctx: &ExpCtx) -> Result<Case2> {
    let n = ctx.n_requests();
    let mut rng = Pcg32::seeded(ctx.seed ^ 0xca5e2);

    // --- robustness: failure causes no slowdown and loses nothing -------
    let mut session = Session::start(&ctx.artifacts, alexnet_6dev(ctx, f64::INFINITY))?;
    assert_eq!(session.total_devices(), 6);
    let mut healthy = Series::new();
    for _ in 0..n {
        healthy.record(session.infer(&alexnet_input(&mut rng))?.total_ms);
    }
    session.set_failure(2, FailurePlan::PermanentAt(0))?;
    let mut failed = Series::new();
    let mut lost = 0u64;
    let mut recovered = 0u64;
    for _ in 0..n {
        match session.infer(&alexnet_input(&mut rng)) {
            Ok(t) => {
                failed.record(t.total_ms);
                if t.any_recovery {
                    recovered += 1;
                }
            }
            Err(_) => lost += 1,
        }
    }

    // --- straggler mitigation on the healthy system (Figs. 14-15) -------
    let mut s_off = Session::start(&ctx.artifacts, alexnet_6dev(ctx, f64::INFINITY))?;
    let mut s_on = Session::start(&ctx.artifacts, alexnet_6dev(ctx, 1.5))?;
    let mut no_mit = Series::new();
    let mut with_mit = Series::new();
    for _ in 0..n {
        let x = alexnet_input(&mut rng);
        no_mit.record(s_off.infer(&x)?.total_ms);
        with_mit.record(s_on.infer(&x)?.total_ms);
    }

    let (sh, sf) = (healthy.summary(), failed.summary());
    let (s0, s1) = (no_mit.summary(), with_mit.summary());
    println!("\n=== Case Study II: AlexNet + CDC parity device (Figs. 13-15) ===");
    println!("healthy:        {}", sh.line());
    println!("device C down:  {}", sf.line());
    println!(
        "lost requests with CDC: {lost} (paper: zero); recovered: {recovered}/{n}"
    );
    println!(
        "slowdown under failure: {:.2}× (paper: none)",
        sf.mean / sh.mean
    );
    println!("\nno straggler mitigation (Fig. 14): {}", s0.line());
    println!("{}", no_mit.render_histogram(0.0, 800.0, 16, 40));
    println!("with straggler mitigation (Fig. 15): {}", s1.line());
    println!("{}", with_mit.render_histogram(0.0, 800.0, 16, 40));
    println!(
        "mitigation improvement: mean {:.1}%, p95 {:.1}%",
        100.0 * (1.0 - s1.mean / s0.mean),
        100.0 * (1.0 - s1.p95 / s0.p95)
    );

    ctx.write_result(
        "fig13_15_case2",
        &obj(vec![
            ("experiment", Value::Str("case2_cdc".into())),
            ("requests_per_phase", Value::Num(n as f64)),
            ("healthy_mean_ms", Value::Num(sh.mean)),
            ("failed_mean_ms", Value::Num(sf.mean)),
            ("failure_slowdown", Value::Num(sf.mean / sh.mean)),
            ("lost_requests", Value::Num(lost as f64)),
            ("recovered_requests", Value::Num(recovered as f64)),
            ("no_mitigation_mean_ms", Value::Num(s0.mean)),
            ("with_mitigation_mean_ms", Value::Num(s1.mean)),
            ("no_mitigation_p95_ms", Value::Num(s0.p95)),
            ("with_mitigation_p95_ms", Value::Num(s1.p95)),
            (
                "mitigation_mean_improvement",
                Value::Num(1.0 - s1.mean / s0.mean),
            ),
        ]),
    )?;
    Ok(Case2 {
        healthy,
        failed,
        no_mitigation: no_mit,
        with_mitigation: with_mit,
        lost_requests: lost,
        recovered_requests: recovered,
    })
}
