"""L2 layer forward functions, built on the L1 Pallas GEMM kernel.

Each forward mirrors Section 3 of the paper: fully-connected layers are a
direct GEMM (Eq. 3); convolution layers are transformed to GEMM via patch
unrolling (Fig. 4 / Eq. 4) so that *every* compute-heavy layer bottoms out
in the same kernel — which is what lets the CDC scheme live at the library
(GEMM) level, below the user's program.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import gemm


def im2col(x, fh: int, fw: int, stride: int = 1, padding: str = "SAME"):
    """Unroll (H, W, C) input into the (F²C, OH·OW) patch matrix of Fig. 4.

    Uses ``conv_general_dilated_patches`` so the unroll lowers to a single
    HLO convolution — cheap on any PJRT backend. Feature order is C-major
    then fh, fw (JAX's patch order); the filter matrix in :func:`conv2d`
    is flattened in the matching order.
    """
    h, w, c = x.shape
    patches = jax.lax.conv_general_dilated_patches(
        x[None],  # add batch
        filter_shape=(fh, fw),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )[0]  # (OH, OW, C*fh*fw)
    oh, ow, f2c = patches.shape
    return patches.reshape(oh * ow, f2c).T, (oh, ow)


def filters_to_matrix(w):
    """(K, F, F, C) filters → (K, F²C) matrix, feature order matching im2col.

    JAX's dilated-patches order features as (C, fh, fw), so transpose the
    filter accordingly before flattening.
    """
    k, fh, fw, c = w.shape
    return w.transpose(0, 3, 1, 2).reshape(k, c * fh * fw)


def fc(w, b, x, *, relu=True, interpret=True):
    """Fully-connected layer (Eq. 3): σ(Wx + b); ``x``: (k, n) column(s)."""
    bias = b.reshape(-1, 1) if b is not None else None
    return gemm(w, x, bias, relu=relu, interpret=interpret)


def conv2d(w, b, x, *, stride=1, padding="SAME", relu=True, interpret=True):
    """Convolution layer via im2col + GEMM (Eq. 4). Returns (OH, OW, K)."""
    k = w.shape[0]
    cols, (oh, ow) = im2col(x, w.shape[1], w.shape[2], stride, padding)
    wmat = filters_to_matrix(w)
    bias = b.reshape(-1, 1) if b is not None else None
    out = gemm(wmat, cols, bias, relu=relu, interpret=interpret)  # (K, OH·OW)
    return out.reshape(k, oh, ow).transpose(1, 2, 0)


def maxpool(x, size=2, stride=2):
    """Max-pool (VALID) — grouped with its parent layer per paper §3."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(size, size, 1),
        window_strides=(stride, stride, 1),
        padding="VALID",
    )


def avgpool_global(x):
    """Global average pool: (H, W, C) → (C,)."""
    return jnp.mean(x, axis=(0, 1))


def softmax(logits):
    """Numerically-stable softmax over the leading axis of (m, 1)."""
    z = logits - jnp.max(logits, axis=0, keepdims=True)
    e = jnp.exp(z)
    return e / jnp.sum(e, axis=0, keepdims=True)
