//! Case Study I (Figs. 11-12) — AlexNet on a five-device system *without*
//! robustness: device C fails, the system pays tens of seconds of failure
//! detection, then device D executes both fc6 shards serially — a ~2.4×
//! steady-state slowdown of the affected layer path. CDC (Case Study II)
//! eliminates both effects.
//!
//! Deployment (paper Fig. 11a):
//!   A: conv1-conv2   B: conv3-conv5   C: fc6/0   D: fc6/1   E: fc7, fc8

use crate::coordinator::{Session, SessionConfig, SplitSpec};
use crate::error::Result;
use crate::fleet::FailurePlan;
use crate::json::{obj, Value};
use crate::metrics::Series;
use crate::rng::Pcg32;
use crate::tensor::Tensor;

use super::ExpCtx;

/// The paper's five-device AlexNet allocation file.
pub fn alexnet_5dev(ctx: &ExpCtx) -> SessionConfig {
    let mut cfg = SessionConfig::new("alexnet");
    cfg.n_devices = 5;
    cfg.seed = ctx.seed;
    // The case-study testbed is the paper's local WLAN (measured 0.3 ms
    // RTT), not Fig. 1's congested profile.
    cfg.net = crate::fleet::NetConfig::moderate();
    cfg.splits.insert("fc6".into(), SplitSpec::plain(2));
    for (layer, dev) in [
        ("conv1", 0usize),
        ("conv2", 0),
        ("conv3", 1),
        ("conv4", 1),
        ("conv5", 1),
        ("fc7", 4),
        ("fc8", 4),
    ] {
        cfg.placement.insert(layer.into(), vec![dev]);
    }
    cfg.placement.insert("fc6".into(), vec![2, 3]);
    cfg
}

/// Random AlexNet-shaped input.
pub fn alexnet_input(rng: &mut Pcg32) -> Tensor {
    Tensor::randn(vec![32, 32, 3], rng)
}

/// Results of the case study.
#[derive(Debug)]
pub struct Case1 {
    pub before: Series,
    pub after: Series,
    pub detection_ms: f64,
    pub slowdown: f64,
}

/// Run the experiment; returns the two latency series.
pub fn run(ctx: &ExpCtx) -> Result<Case1> {
    let cfg = alexnet_5dev(ctx);
    let detection_ms = cfg.detection_ms;
    let mut session = Session::start(&ctx.artifacts, cfg)?;
    let mut rng = Pcg32::seeded(ctx.seed ^ 0xca5e1);
    let n = ctx.n_requests();

    // Phase A: healthy system (black bars of Fig. 12).
    let mut before = Series::new();
    let mut before_stage = Series::new();
    for _ in 0..n {
        let t = session.infer(&alexnet_input(&mut rng))?;
        before.record(t.total_ms);
        before_stage.record(stage_ms(&t, "fc6"));
    }

    // Device C (id 2, fc6 shard 0) dies. Without CDC the system mishandles
    // requests until detection fires, then fails over to device D.
    session.set_failure(2, FailurePlan::PermanentAt(0))?;
    let mut lost = 0u64;
    if session.infer(&alexnet_input(&mut rng)).is_err() {
        lost += 1;
    }
    session.drain();
    session.failover(2, 3)?;

    // Phase B: post-recovery steady state (red bars of Fig. 12): device D
    // now executes both fc6 shards serially.
    let mut after = Series::new();
    let mut after_stage = Series::new();
    for _ in 0..n {
        let t = session.infer(&alexnet_input(&mut rng))?;
        after.record(t.total_ms);
        after_stage.record(stage_ms(&t, "fc6"));
    }

    let sb = before.summary();
    let sa = after.summary();
    // The paper's 2.4× is the slowdown of the *affected path*: device D
    // absorbs device C's fc6 shard and runs both serially, so the fc6
    // stage — the deployment's heaviest — roughly doubles (2× compute +
    // the second shard's transfer), throttling the pipeline's steady
    // state.
    let slowdown = after_stage.summary().mean / before_stage.summary().mean;
    println!("\n=== Case Study I: AlexNet, 5 devices, no robustness (Figs. 11-12) ===");
    println!("before failure: {}", sb.line());
    println!("{}", before.render_histogram(0.0, 800.0, 16, 40));
    println!("after failover: {}", sa.line());
    println!("{}", after.render_histogram(0.0, 800.0, 16, 40));
    println!(
        "requests mishandled during detection window: ≥{lost} \
         (detection takes ~{:.0} s)",
        detection_ms / 1000.0
    );
    println!(
        "end-to-end latency shift: {:.2}×",
        sa.mean / sb.mean
    );
    println!(
        "affected-stage (fc6) slowdown after recovery: {slowdown:.2}× (paper: ~2.4×)"
    );

    ctx.write_result(
        "fig12_case1",
        &obj(vec![
            ("experiment", Value::Str("case1_failure_no_cdc".into())),
            ("requests_per_phase", Value::Num(n as f64)),
            ("before_mean_ms", Value::Num(sb.mean)),
            ("before_p95_ms", Value::Num(sb.p95)),
            ("after_mean_ms", Value::Num(sa.mean)),
            ("after_p95_ms", Value::Num(sa.p95)),
            ("latency_shift", Value::Num(sa.mean / sb.mean)),
            ("bottleneck_before_ms", Value::Num(before_stage.summary().mean)),
            ("bottleneck_after_ms", Value::Num(after_stage.summary().mean)),
            ("slowdown", Value::Num(slowdown)),
            ("paper_slowdown", Value::Num(2.4)),
            ("detection_ms", Value::Num(detection_ms)),
            ("lost_requests_detected", Value::Num(lost as f64)),
        ]),
    )?;
    Ok(Case1 { before, after, detection_ms, slowdown })
}

/// Service time of one named layer within a trace (0 if absent).
fn stage_ms(trace: &crate::coordinator::RequestTrace, layer: &str) -> f64 {
    trace
        .layers
        .iter()
        .find(|l| l.layer == layer)
        .map(|l| l.t_done_ms - l.t_start_ms)
        .unwrap_or(0.0)
}
