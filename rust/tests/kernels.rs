//! Kernel-layer correctness: property-style parity of the tiled and
//! threaded GEMMs (and the im2col conv lowering) against the branch-free
//! naive reference, over edge shapes — unit dimensions, primes, sizes
//! not divisible by the register tile — and thread counts 1–4.

use cdc_dnn::kernels::{self, Scratch};
use cdc_dnn::rng::Pcg32;
use cdc_dnn::runtime::interp;
use cdc_dnn::tensor::Tensor;

/// m/k/n of 1, primes, off-tile sizes, and a tall/skinny serving shape.
const EDGE_SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 7, 1),
    (7, 1, 3),
    (1, 64, 9),
    (13, 17, 11),
    (31, 31, 31),
    (64, 64, 64),
    (65, 67, 63),
    (129, 96, 33),
    (4, 256, 8),
    (257, 19, 130),
    (3, 300, 2),
];

fn randv(n: usize, rng: &mut Pcg32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn tiled_matches_naive_on_edge_shapes() {
    let mut rng = Pcg32::seeded(101);
    let mut sc = Scratch::new();
    for &(m, k, n) in EDGE_SHAPES {
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let mut want = vec![0.0f32; m * n];
        let mut got = vec![0.0f32; m * n];
        kernels::gemm_naive(&a, &b, &mut want, m, k, n);
        kernels::gemm_tiled(&a, &b, &mut got, m, k, n, &mut sc);
        let d = max_abs_diff(&got, &want);
        assert!(d < 1e-4, "tiled ({m},{k},{n}): diff {d}");
    }
}

#[test]
fn threaded_matches_naive_across_thread_counts() {
    let mut rng = Pcg32::seeded(102);
    for threads in 1..=4usize {
        for &(m, k, n) in EDGE_SHAPES {
            let a = randv(m * k, &mut rng);
            let b = randv(k * n, &mut rng);
            let mut want = vec![0.0f32; m * n];
            let mut got = vec![0.0f32; m * n];
            kernels::gemm_naive(&a, &b, &mut want, m, k, n);
            kernels::gemm_threaded(&a, &b, &mut got, m, k, n, threads);
            let d = max_abs_diff(&got, &want);
            assert!(d < 1e-4, "threaded t={threads} ({m},{k},{n}): diff {d}");
        }
    }
}

#[test]
fn auto_dispatch_matches_naive() {
    // gemm_auto crosses all three dispatch regimes; results must agree.
    let mut rng = Pcg32::seeded(103);
    let mut sc = Scratch::new();
    for &(m, k, n) in &[(3usize, 5usize, 2usize), (64, 64, 64), (200, 180, 190)] {
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let mut want = vec![0.0f32; m * n];
        let mut got = vec![0.0f32; m * n];
        kernels::gemm_naive(&a, &b, &mut want, m, k, n);
        kernels::gemm_auto(&a, &b, &mut got, m, k, n, &mut sc);
        let d = max_abs_diff(&got, &want);
        assert!(d < 1e-3, "auto ({m},{k},{n}): diff {d}");
    }
}

#[test]
fn zero_depth_and_degenerate_shapes() {
    let mut sc = Scratch::new();
    // k = 0: a well-formed empty contraction, output must be all zeros.
    let mut c = vec![9.0f32; 6];
    kernels::gemm_tiled(&[], &[], &mut c, 2, 0, 3, &mut sc);
    assert!(c.iter().all(|&v| v == 0.0));
    let mut c = vec![9.0f32; 6];
    kernels::gemm_threaded(&[], &[], &mut c, 2, 0, 3, 4);
    assert!(c.iter().all(|&v| v == 0.0));
    // m = 0 / n = 0: empty outputs, no panic.
    let mut empty: Vec<f32> = Vec::new();
    kernels::gemm_tiled(&[], &[1.0, 2.0], &mut empty, 0, 2, 1, &mut sc);
    kernels::gemm_tiled(&[1.0, 2.0], &[], &mut empty, 1, 2, 0, &mut sc);
}

#[test]
fn im2col_conv_lowering_matches_direct_convolution() {
    // The interpreter's conv path is im2col + the shared GEMM; check the
    // whole lowering against direct convolution over edge geometries
    // (prime spatial sizes, stride > filter, SAME and VALID).
    let mut rng = Pcg32::seeded(104);
    for &(h, w, c, k, f, s, same) in &[
        (5usize, 7usize, 3usize, 2usize, 3usize, 1usize, true),
        (11, 11, 1, 5, 3, 2, true),
        (9, 6, 2, 3, 2, 2, false),
        (13, 13, 4, 7, 5, 3, true),
    ] {
        let x = Tensor::randn(vec![h, w, c], &mut rng);
        let wm = Tensor::randn(vec![k, f * f * c], &mut rng);
        let padding = if same { "SAME" } else { "VALID" };
        let (cols, oh, ow) = interp::im2col(&x, f, s, padding).unwrap();
        let y = wm.matmul(&cols).unwrap();
        let yref = wm.matmul_naive(&cols).unwrap();
        assert_eq!(y.shape(), &[k, oh * ow]);
        assert!(
            y.max_abs_diff(&yref) < 1e-4,
            "conv gemm h{h}w{w}c{c}k{k}f{f}s{s}"
        );
        // Direct convolution oracle on a single output pixel (center).
        let (oy, ox) = (oh / 2, ow / 2);
        let col = oy * ow + ox;
        for kk in 0..k {
            let mut acc = 0.0f32;
            for r in 0..f * f * c {
                acc += wm.data()[kk * f * f * c + r] * cols.data()[r * (oh * ow) + col];
            }
            let got = y.data()[kk * (oh * ow) + col];
            assert!(
                (got - acc).abs() < 1e-3,
                "pixel oracle h{h}w{w} kk{kk}: {got} vs {acc}"
            );
        }
    }
}

#[test]
fn fused_checksum_equals_stacked_row_sum() {
    let mut rng = Pcg32::seeded(105);
    let (m, n, h) = (24usize, 5usize, 6usize);
    let c = randv(m * n, &mut rng);
    let mut out = vec![0.0f32; h * n];
    kernels::row_block_checksum(&c, m, n, h, &mut out);
    for r in 0..h {
        for j in 0..n {
            let mut want = 0.0f32;
            let mut g = 0;
            while g < m / h {
                want += c[(g * h + r) * n + j];
                g += 1;
            }
            assert!((out[r * n + j] - want).abs() < 1e-5, "({r},{j})");
        }
    }
}

#[test]
fn scratch_arena_reuses_buffers_across_takes() {
    let mut sc = Scratch::new();
    // Simulate the steady-state serve loop: take/put the same sizes.
    for round in 0..8 {
        let a = sc.take(4096);
        let b = sc.take(1024);
        sc.put(a);
        sc.put(b);
        if round > 0 {
            // After warm-up every take must be served from the pool.
            assert_eq!(
                sc.take_count() - sc.reuse_count(),
                2,
                "steady state must not allocate (round {round})"
            );
        }
    }
    assert!(sc.reuse_count() >= 14);
}

#[test]
fn tensor_matmul_is_kernel_backed_and_consistent() {
    let mut rng = Pcg32::seeded(106);
    let a = Tensor::randn(vec![97, 53], &mut rng);
    let b = Tensor::randn(vec![53, 41], &mut rng);
    let fast = a.matmul(&b).unwrap();
    let slow = a.matmul_naive(&b).unwrap();
    assert_eq!(fast.shape(), &[97, 41]);
    assert!(fast.max_abs_diff(&slow) < 1e-4);
}
