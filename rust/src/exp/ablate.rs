//! Ablations for the design choices DESIGN.md §6 calls out:
//!
//! 1. **Decode placement** — recover the missing shard by rust-side
//!    subtraction (shipped design) vs re-executing the missing GEMM
//!    locally vs the paper's vanilla re-dispatch (weights reload + input
//!    re-request + remote compute, costed by the fleet timing model).
//! 2. **CDC overhead without failure** — what the extra parity device
//!    costs a healthy system (answer: nothing on the critical path; it
//!    can only help via substitution).
//! 3. **Grouped-parity granularity** — tolerance vs added devices as the
//!    group size shrinks (the Fig. 18 trade dial).

use std::time::Instant;

use crate::cdc;
use crate::coordinator::{Redundancy, Session, SessionConfig, SplitSpec};
use crate::error::Result;
use crate::fleet::{NetConfig, RPI_MACS_PER_MS};
use crate::json::{obj, Value};
use crate::metrics::Series;
use crate::rng::Pcg32;
use crate::runtime::{Manifest, Runtime};
use crate::tensor::Tensor;
use crate::testkit::synth;

use super::{print_table, ExpCtx};

fn fc_cfg(ctx: &ExpCtx, red: Redundancy, threshold: f64) -> SessionConfig {
    let mut cfg = SessionConfig::new("fc2048");
    cfg.n_devices = 4;
    cfg.seed = ctx.seed;
    cfg.net = NetConfig::moderate();
    cfg.threshold_factor = threshold;
    cfg.splits.insert("fc".into(), SplitSpec { d: 4, redundancy: red });
    cfg
}

/// The offline twin of [`fc_cfg`]: the synthetic MLP with its fc1 layer
/// split 4 ways — same topology (4 data shards + parity), synthetic
/// weights. Used when no AOT artifact build is present so `cdc-dnn
/// ablate` runs everywhere (the CI CLI-smoke job drives it this way).
fn synth_cfg(ctx: &ExpCtx, red: Redundancy, threshold: f64) -> SessionConfig {
    let mut cfg = SessionConfig::new(synth::MODEL);
    cfg.n_devices = 4;
    cfg.seed = ctx.seed;
    cfg.net = NetConfig::moderate();
    cfg.threshold_factor = threshold;
    cfg.splits.insert("fc1".into(), SplitSpec { d: 4, redundancy: red });
    cfg
}

/// Run all three ablations. With an AOT artifact build the measured
/// flavor matches the paper's fc-2048 testbed; without one everything
/// degrades gracefully to the synthetic model / the built-GEMM fallback
/// (same code paths, smaller shapes) instead of erroring out.
pub fn run(ctx: &ExpCtx) -> Result<()> {
    // AOT artifacts present iff the manifest loads and carries the
    // paper's fc-2048 shard program.
    let aot: Option<Manifest> = Manifest::load(&ctx.artifacts)
        .ok()
        .filter(|m| m.artifacts.contains_key("fc_m512_k2048_lin"));
    let flavor = if aot.is_some() {
        "AOT fc-2048"
    } else {
        "offline synthetic"
    };
    println!("\n=== Ablations (DESIGN.md §6) — {flavor} flavor ===");

    // ---- 1. decode placement -----------------------------------------
    let runtime = Runtime::new()?;
    let mut rng = Pcg32::seeded(ctx.seed);
    let ms = 512usize;
    let parity = Tensor::randn(vec![ms, 1], &mut rng);
    let others: Vec<Tensor> = (0..3).map(|_| Tensor::randn(vec![ms, 1], &mut rng)).collect();
    let refs: Vec<&Tensor> = others.iter().collect();
    let t0 = Instant::now();
    let iters = 2000;
    for _ in 0..iters {
        std::hint::black_box(cdc::decode(&parity, &refs)?);
    }
    let decode_us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;

    let w = Tensor::randn(vec![ms, 2048], &mut rng);
    let b = Tensor::randn(vec![ms, 1], &mut rng);
    let x = Tensor::randn(vec![2048, 1], &mut rng);
    let reexec_us = if let Some(manifest) = &aot {
        runtime.execute(manifest, "fc_m512_k2048_lin", &[&w, &b, &x])?;
        let t0 = Instant::now();
        for _ in 0..50 {
            runtime.execute(manifest, "fc_m512_k2048_lin", &[&w, &b, &x])?;
        }
        t0.elapsed().as_secs_f64() * 1e6 / 50.0
    } else {
        // No artifact set: the builder fallback runs the identical GEMM
        // shape through the same backend.
        let exe = runtime.build_gemm(ms, 2048, 1, true, false)?;
        runtime.run_built(&exe, &[&w, &x, &b])?;
        let t0 = Instant::now();
        for _ in 0..50 {
            runtime.run_built(&exe, &[&w, &x, &b])?;
        }
        t0.elapsed().as_secs_f64() * 1e6 / 50.0
    };

    // Vanilla re-dispatch cost under the simulated fleet (paper §5.2's
    // description: load weights, re-request input, compute remotely).
    let net = NetConfig::moderate();
    let mut nrng = Pcg32::seeded(ctx.seed + 1);
    let mut vanilla = Series::new();
    for _ in 0..2000 {
        let t = net.sample_request((2048 * 4) as u64)
            + (512.0 * 2048.0) / RPI_MACS_PER_MS
            + net.sample((512 * 4) as u64, &mut nrng);
        vanilla.record(t);
    }
    println!("\nablation 1 — recovery mechanism (fc-2048 shard, 4-way):");
    print_table(
        &["mechanism", "cost"],
        &[
            vec!["CDC decode (rust subtraction)".into(), format!("{decode_us:.1} µs")],
            vec!["local re-execution (PJRT GEMM)".into(), format!("{reexec_us:.1} µs")],
            vec![
                "vanilla re-dispatch (simulated RPi+WLAN)".into(),
                format!("{:.0} ms (mean)", vanilla.summary().mean),
            ],
        ],
    );

    // ---- 2. CDC overhead without failure ------------------------------
    let n = ctx.n_requests();
    // AOT: the paper's fc-2048 over 4 RPi-class devices. Offline: the
    // synthetic MLP's fc1 with the same split topology — reusing a
    // synthetic set already materialised at --artifacts (the CLI smoke
    // job puts one there with `cdc-dnn synth`), else building a
    // throwaway one.
    let offline = aot.is_none();
    let (arts_root, input_len) = if offline {
        let reuse = Manifest::load(&ctx.artifacts).is_ok_and(|m| m.model(synth::MODEL).is_ok());
        let root = if reuse {
            ctx.artifacts.clone()
        } else {
            synth::build(ctx.seed)?.root
        };
        (root, synth::FC1_K)
    } else {
        (ctx.artifacts.clone(), 2048)
    };
    let cfg_of = |red, thr| {
        if offline {
            synth_cfg(ctx, red, thr)
        } else {
            fc_cfg(ctx, red, thr)
        }
    };
    let mut plain = Session::start(&arts_root, cfg_of(Redundancy::None, f64::INFINITY))?;
    let mut coded = Session::start(&arts_root, cfg_of(Redundancy::Cdc, f64::INFINITY))?;

    // Split-plan introspection (Session::layer_plans): show what the
    // coded deployment actually placed, and sanity-check the balanced-
    // assignment invariant the plans are built on.
    println!("\ndeployed split plans (coded session):");
    let mut plan_rows = Vec::new();
    for (layer, plan) in coded.layer_plans() {
        plan_rows.push(vec![
            layer.to_string(),
            plan.method.name().to_string(),
            format!("{}", plan.d),
            format!("{}", plan.shards.first().map(|s| s.height).unwrap_or(0)),
            format!("{}", plan.covered_rows()),
            plan.artifact_lin.clone(),
        ]);
    }
    print_table(
        &["layer", "method", "d", "shard height", "rows covered", "artifact"],
        &plan_rows,
    );
    let mut s_plain = Series::new();
    let mut s_coded = Series::new();
    let mut xrng = Pcg32::seeded(ctx.seed ^ 0xab1a);
    for _ in 0..n {
        let x = Tensor::randn(vec![input_len], &mut xrng);
        s_plain.record(plain.infer(&x)?.total_ms);
        s_coded.record(coded.infer(&x)?.total_ms);
    }
    println!("\nablation 2 — healthy-system cost of the parity device:");
    println!("  plain d=4:     {}", s_plain.summary().line());
    println!("  cdc d=4+1:     {}", s_coded.summary().line());
    println!(
        "  overhead: {:.1}% (parity is off the critical path; it can only substitute)",
        100.0 * (s_coded.summary().mean / s_plain.summary().mean - 1.0)
    );

    // ---- 3. parity-group granularity ----------------------------------
    println!("\nablation 3 — group size vs devices vs tolerance (d = 8 shards):");
    let mut rows = Vec::new();
    for gsize in [8usize, 4, 2, 1] {
        let groups = cdc::parity_groups(8, gsize)?;
        rows.push(vec![
            format!("{gsize}"),
            format!("{}", groups.len()),
            format!("{}", cdc::tolerated_failures(&groups)),
            format!("{:.0}%", 100.0 * groups.len() as f64 / 8.0),
        ]);
    }
    print_table(
        &["group size", "parity devices", "guaranteed failures tolerated", "extra hardware"],
        &rows,
    );

    ctx.write_result(
        "ablations",
        &obj(vec![
            ("flavor", Value::Str(flavor.into())),
            ("decode_us", Value::Num(decode_us)),
            ("reexec_us", Value::Num(reexec_us)),
            ("vanilla_ms", Value::Num(vanilla.summary().mean)),
            ("healthy_plain_ms", Value::Num(s_plain.summary().mean)),
            ("healthy_cdc_ms", Value::Num(s_coded.summary().mean)),
        ]),
    )?;
    Ok(())
}
