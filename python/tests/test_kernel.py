# pytest: kernel vs ref allclose — the CORE L1 correctness signal.
"""Pallas kernels vs pure-jnp oracles, including hypothesis shape sweeps."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cdc_decode, cdc_encode, gemm
from compile.kernels.ref import (
    cdc_decode_ref,
    cdc_encode_ref,
    conv2d_ref,
    gemm_ref,
    im2col_ref,
    maxpool_ref,
)

RNG = np.random.default_rng(0)


def randn(*shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------------------
# GEMM kernel


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),
        (5, 7, 1),
        (64, 64, 64),
        (65, 63, 2),
        (130, 70, 3),
        (512, 2048, 1),
        (100, 150, 784),
    ],
)
def test_gemm_matches_ref(m, k, n):
    w, x = randn(m, k), randn(k, n)
    np.testing.assert_allclose(
        np.asarray(gemm(w, x)), np.asarray(gemm_ref(w, x)), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("relu", [False, True])
def test_gemm_bias_relu_epilogue(relu):
    w, x, b = randn(33, 17), randn(17, 5), randn(33, 1)
    got = gemm(w, x, b, relu=relu)
    want = gemm_ref(w, x, b, relu=relu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
    if relu:
        assert float(jnp.min(got)) >= 0.0


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 8),
    bm=st.sampled_from([8, 32, 64]),
    bk=st.sampled_from([8, 32, 64]),
    bn=st.sampled_from([1, 8, 64]),
    relu=st.booleans(),
)
def test_gemm_hypothesis_blocks(m, k, n, bm, bk, bn, relu):
    """The blocked path must be exact for arbitrary shape/block combos —
    this is the TPU-BlockSpec structure the matvec fast path bypasses."""
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    w = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(m, 1)), jnp.float32)
    got = gemm(w, x, b, relu=relu, block_m=bm, block_k=bk, block_n=bn)
    want = gemm_ref(w, x, b, relu=relu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(
    d=st.integers(2, 6),
    m=st.integers(1, 40),
    k=st.integers(1, 40),
)
def test_cdc_encode_decode_roundtrip_hypothesis(d, m, k):
    rng = np.random.default_rng(d * 997 + m * 31 + k)
    shards = jnp.asarray(rng.normal(size=(d, m, k)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(k, 1)), jnp.float32)
    parity_w = cdc_encode(shards)
    np.testing.assert_allclose(
        np.asarray(parity_w), np.asarray(cdc_encode_ref(shards)), rtol=1e-4, atol=1e-4
    )
    # End-to-end CDC algebra: parity output recovers any missing shard.
    outs = jnp.einsum("dmk,kn->dmn", shards, x)
    parity_out = parity_w @ x
    lose = int(rng.integers(d))
    received = jnp.stack([outs[i] for i in range(d) if i != lose])
    rec = cdc_decode(parity_out, received)
    np.testing.assert_allclose(
        np.asarray(rec), np.asarray(outs[lose]), rtol=1e-3, atol=1e-3
    )


def test_cdc_decode_matches_ref():
    p = randn(40, 3)
    r = randn(4, 40, 3)
    np.testing.assert_allclose(
        np.asarray(cdc_decode(p, r)),
        np.asarray(cdc_decode_ref(p, r)),
        rtol=1e-5,
        atol=1e-5,
    )


# ---------------------------------------------------------------------------
# Reference-layer self-consistency (the oracles themselves)


def test_im2col_matches_manual_conv():
    x = randn(6, 5, 2)
    w = randn(3, 3, 3, 2)  # K=3 filters of 3x3x2
    out = conv2d_ref(x, w, padding="SAME")
    assert out.shape == (6, 5, 3)
    cols = im2col_ref(x, 3, 3, padding="SAME")
    wmat = np.asarray(w).reshape(3, -1)
    np.testing.assert_allclose(
        np.asarray(out).transpose(2, 0, 1).reshape(3, -1),
        wmat @ np.asarray(cols),
        rtol=1e-4,
        atol=1e-4,
    )


def test_maxpool_ref_basic():
    x = jnp.arange(16.0, dtype=jnp.float32).reshape(4, 4, 1)
    y = maxpool_ref(x, 2, 2)
    np.testing.assert_allclose(np.asarray(y)[..., 0], [[5, 7], [13, 15]])
