//! The virtual-time transport: an adapter over the in-process
//! device-thread fleet (`fleet::Device`).
//!
//! This is the exact dispatch/recv machinery the coordinator used
//! before the transport trait existed — the same device threads, the
//! same completion channel — moved behind [`Transport`]. Every
//! wall-clock hook is the trait's no-op default, so a sim-mode session
//! schedules, draws and merges **bit-identically** to the PR-4 engine
//! (the serve-pipeline and batching determinism tests are the guard).

use std::sync::mpsc::{Receiver, Sender};

use crate::error::{Error, Result};
use crate::fleet::{Completion, Device, FailurePlan, NetConfig, TaskDef, WorkOrder};

use super::Transport;

/// Virtual-time transport over in-process device threads.
pub struct SimTransport {
    devices: Vec<Device>,
    rx: Receiver<Completion>,
    /// Keeps the channel open even if every device thread exits.
    _tx: Sender<Completion>,
}

impl SimTransport {
    /// Wrap a spawned fleet and its completion channel.
    pub fn new(
        devices: Vec<Device>,
        rx: Receiver<Completion>,
        tx: Sender<Completion>,
    ) -> SimTransport {
        SimTransport { devices, rx, _tx: tx }
    }

    fn device(&self, id: usize) -> Result<&Device> {
        self.devices
            .get(id)
            .ok_or_else(|| Error::Config(format!("no device {id}")))
    }
}

impl Transport for SimTransport {
    fn label(&self) -> &'static str {
        "sim"
    }

    fn wall_clock(&self) -> bool {
        false
    }

    fn n_devices(&self) -> usize {
        self.devices.len()
    }

    fn deploy(&self, device: usize, tasks: Vec<TaskDef>) -> Result<()> {
        self.device(device)?.deploy(tasks)
    }

    fn undeploy(&self, device: usize, task_ids: Vec<u64>) -> Result<()> {
        self.device(device)?.undeploy(task_ids)
    }

    fn dispatch(&self, device: usize, order: WorkOrder) -> Result<()> {
        self.device(device)?.dispatch(order)
    }

    fn recv(&self) -> Result<Completion> {
        self.rx
            .recv()
            .map_err(|_| Error::Fleet("completion channel closed".into()))
    }

    fn try_recv(&self) -> Option<Completion> {
        self.rx.try_recv().ok()
    }

    fn set_failure(&self, device: usize, plan: FailurePlan) -> Result<()> {
        self.device(device)?.set_failure(plan)
    }

    fn set_net(&self, device: usize, net: NetConfig) -> Result<()> {
        self.device(device)?.set_net(net)
    }

    fn set_rate(&self, device: usize, macs_per_ms: f64) -> Result<()> {
        self.device(device)?.set_rate(macs_per_ms)
    }
}
