//! Gateway end-to-end tests (ISSUE 8): real HTTP clients over real
//! sockets, a real loopback worker fleet behind the serve loop, and the
//! single-node forward pass as the logits oracle.
//!
//! - `gateway_serves_oracle_exact_logits_alongside_paced_traffic`:
//!   N concurrent client threads POST /v1/infer while a paced synthetic
//!   stream runs through the same micro-batching pipeline; every reply
//!   is bit-close to the oracle and nothing is lost on either path.
//! - `gateway_survives_sigkill_with_oracle_exact_replies`: SIGKILL a
//!   data worker mid-POSTs; the CDC arm answers every client 200 with
//!   oracle-matching logits.
//! - `gateway_lifecycle_migrate_undeploy_deploy`: migrate a device's
//!   tasks make-before-break (infers before/after both exact), then
//!   undeploy (infer turns 503) and redeploy (200 again).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::mpsc;

use cdc_dnn::coordinator::{Session, SessionConfig, SplitSpec, Workload};
use cdc_dnn::gateway::{GatewayBridge, GatewayCmd, GatewayConfig, GatewayServer, ServerCtx};
use cdc_dnn::json::Value;
use cdc_dnn::model::Weights;
use cdc_dnn::rng::Pcg32;
use cdc_dnn::runtime::Manifest;
use cdc_dnn::tensor::Tensor;
use cdc_dnn::testkit::synth;
use cdc_dnn::transport::loopback::LoopbackFleet;
use cdc_dnn::transport::{TcpConfig, TransportSpec};

fn worker_bin() -> &'static Path {
    Path::new(env!("CARGO_BIN_EXE_cdc-dnn"))
}

fn base_cfg(fleet: &LoopbackFleet) -> SessionConfig {
    let mut cfg = SessionConfig::new(synth::MODEL);
    cfg.n_devices = 2;
    cfg.splits.insert("fc1".into(), SplitSpec::cdc(2));
    cfg.splits.insert("fc2".into(), SplitSpec::cdc(2));
    cfg.detection_ms = 200.0;
    cfg.batch_max = 4;
    cfg.batch_wait_ms = 2.0;
    let mut tcp: TcpConfig = fleet.tcp_config();
    tcp.order_deadline_ms = 1_000.0;
    cfg.transport = TransportSpec::Tcp(tcp);
    cfg
}

fn inputs(n: usize, seed: u64) -> Vec<Tensor> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| Tensor::randn(vec![synth::FC1_K], &mut rng)).collect()
}

/// Local single-node forward pass — the logits reference.
fn oracle(root: &Path, x: &Tensor) -> Tensor {
    let m = Manifest::load(root).unwrap();
    let model = m.model(synth::MODEL).unwrap();
    let w = Weights::load(&m, model).unwrap();
    let xc = x.clone().reshape(vec![x.len(), 1]).unwrap();
    let mut h = w.w("fc1").unwrap().matmul(&xc).unwrap();
    h.add_assign(w.b("fc1").unwrap()).unwrap();
    h.relu();
    let mut out = w.w("fc2").unwrap().matmul(&h).unwrap();
    out.add_assign(w.b("fc2").unwrap()).unwrap();
    out
}

/// One-shot HTTP client: raw socket, `Connection: close`, blocking read
/// to EOF. Returns (status, raw body text).
fn http_text(addr: &str, method: &str, path: &str, body: Option<&str>) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect gateway");
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: gw\r\nConnection: close\r\n\
         Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).expect("send request");
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).expect("read reply");
    let text = String::from_utf8(raw).expect("utf-8 reply");
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("no status line in {text:?}"));
    let body = text
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_else(|| panic!("no body in {text:?}"));
    (status, body)
}

/// [`http_text`] with the body parsed as JSON.
fn http(addr: &str, method: &str, path: &str, body: Option<&str>) -> (u16, Value) {
    let (status, text) = http_text(addr, method, path, body);
    let v = Value::parse(&text)
        .unwrap_or_else(|e| panic!("bad JSON body {text:?}: {e}"));
    (status, v)
}

/// Value of an unlabeled sample in Prometheus exposition text.
fn prom_value(text: &str, name: &str) -> f64 {
    text.lines()
        .find_map(|l| l.strip_prefix(name).and_then(|rest| rest.strip_prefix(' ')))
        .unwrap_or_else(|| panic!("{name} not in exposition:\n{text}"))
        .trim()
        .parse()
        .unwrap()
}

fn infer_body(x: &Tensor) -> String {
    let vals: Vec<String> =
        x.data().iter().map(|&v| format!("{}", f64::from(v))).collect();
    format!("{{\"input\":[{}]}}", vals.join(","))
}

fn assert_logits_match(root: &Path, x: &Tensor, reply: &Value) {
    let logits: Vec<f32> = reply
        .get("logits")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect();
    let want = oracle(root, x);
    assert_eq!(logits.len(), want.len(), "logit count");
    let diff = logits
        .iter()
        .zip(want.data())
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0f64, f64::max);
    assert!(diff < 1e-4, "gateway logits diverge by {diff}");
    let argmax = reply.get("argmax").unwrap().as_f64().unwrap() as usize;
    assert_eq!(argmax, want.argmax(), "argmax");
}

/// Start the HTTP front door + command channel for a running test. The
/// gateway shares the session's telemetry registry, exactly as the CLI
/// wires it, so `/metrics` and `/v1/traces` see serve-loop activity.
fn start_gateway(session: &Session) -> (GatewayServer, GatewayBridge, String) {
    let (tx, rx) = mpsc::channel::<GatewayCmd>();
    let server = GatewayServer::start(
        &GatewayConfig::default(),
        ServerCtx {
            model: synth::MODEL.to_string(),
            input_len: synth::FC1_K,
            telemetry: session.telemetry(),
        },
        tx,
    )
    .unwrap();
    let addr = server.addr().to_string();
    (server, GatewayBridge { rx }, addr)
}

#[test]
fn gateway_serves_oracle_exact_logits_alongside_paced_traffic() {
    let arts = synth::build(81).unwrap();
    let fleet =
        LoopbackFleet::spawn(Some(worker_bin()), &arts.root, 4, Some(20.0)).unwrap();
    let mut session = Session::start(&arts.root, base_cfg(&fleet)).unwrap();
    let (server, bridge, addr) = start_gateway(&session);

    // 6 client threads × 4 POSTs interleave with a 40-request paced
    // stream through the same pipeline.
    const CLIENTS: usize = 6;
    const PER_CLIENT: usize = 4;
    let ext_inputs = inputs(CLIENTS * PER_CLIENT, 811);
    let mut clients = Vec::new();
    for c in 0..CLIENTS {
        let addr = addr.clone();
        let xs: Vec<Tensor> =
            ext_inputs[c * PER_CLIENT..(c + 1) * PER_CLIENT].to_vec();
        clients.push(std::thread::spawn(move || {
            let mut replies = Vec::new();
            for x in &xs {
                let (status, v) = http(&addr, "POST", "/v1/infer", Some(&infer_body(x)));
                assert_eq!(status, 200, "infer failed: {v:?}");
                replies.push(v);
            }
            replies
        }));
    }

    // Control-plane reads answer inline while traffic flows; a
    // controller thread joins the clients then shuts the gateway down.
    let ctrl_addr = addr.clone();
    let controller = std::thread::spawn(move || {
        let (st, v) = http(&ctrl_addr, "GET", "/v1/healthz", None);
        assert_eq!(st, 200, "{v:?}");
        assert_eq!(v.get("model").unwrap().as_str().unwrap(), synth::MODEL);
        let (st, v) = http(&ctrl_addr, "GET", "/v1/fleet", None);
        assert_eq!(st, 200, "{v:?}");
        assert_eq!(v.get("total_devices").unwrap().as_usize().unwrap(), 4);
        let (st, v) = http(&ctrl_addr, "GET", "/v1/policy", None);
        assert_eq!(st, 200, "{v:?}");
        let (st, v) = http(&ctrl_addr, "GET", "/v1/deployments", None);
        assert_eq!(st, 200, "{v:?}");
        assert!(v.as_arr().unwrap()[0].get("deployed").unwrap().as_bool().unwrap());
        let (st, v) = http(&ctrl_addr, "GET", "/v1/stats", None);
        assert_eq!(st, 200, "{v:?}");
        // Stats percentiles come from the shared telemetry histogram.
        assert!(v.get("latency_ms").unwrap().get("p99_ms").is_ok(), "{v:?}");
        let (st, page) = http_text(&ctrl_addr, "GET", "/", None);
        assert_eq!(st, 200);
        assert!(page.contains("<!DOCTYPE html>"), "dashboard did not render");
        let (st, _) = http(&ctrl_addr, "GET", "/v1/nope", None);
        assert_eq!(st, 404);
    });

    let shut_addr = addr.clone();
    let shutter = std::thread::spawn(move || {
        // Replies and handles come back to the main thread via join.
        (clients.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>(), {
            let (st, v) = http(&shut_addr, "POST", "/v1/shutdown", None);
            assert_eq!(st, 200, "{v:?}");
        })
    });

    let paced = inputs(40, 812);
    let report =
        session.serve_gateway(&Workload::uniform(paced, 6.0), &bridge).unwrap();

    let (client_replies, ()) = shutter.join().unwrap();
    controller.join().unwrap();
    drop(server);

    // Nothing lost on either path; every external reply is oracle-exact.
    assert!(report.failures.is_empty(), "{}", report.line());
    assert_eq!(report.dropped, 0, "{}", report.line());
    assert_eq!(
        report.throughput.completed,
        (40 + CLIENTS * PER_CLIENT) as u64,
        "{}",
        report.line()
    );
    // Paced traces keep their outputs; external requests leave via HTTP
    // only (a long-lived gateway must not accumulate logits).
    assert_eq!(report.traces.len(), 40);
    for (c, replies) in client_replies.iter().enumerate() {
        for (k, v) in replies.iter().enumerate() {
            assert_logits_match(&arts.root, &ext_inputs[c * PER_CLIENT + k], v);
        }
    }
}

#[test]
fn gateway_survives_sigkill_with_oracle_exact_replies() {
    let arts = synth::build(82).unwrap();
    let fleet =
        LoopbackFleet::spawn(Some(worker_bin()), &arts.root, 4, Some(20.0)).unwrap();
    let mut session = Session::start(&arts.root, base_cfg(&fleet)).unwrap();
    let (server, bridge, addr) = start_gateway(&session);

    // Worker 1 owns data shards of both layers; kill it mid-POSTs. The
    // emulated ~5 ms/shard compute keeps the stream alive well past the
    // kill instant (4 clients × 8 sequential round-trips ≫ 150 ms).
    let killer = fleet.kill_after(1, 150);

    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 8;
    let ext_inputs = inputs(CLIENTS * PER_CLIENT, 821);
    let mut clients = Vec::new();
    for c in 0..CLIENTS {
        let addr = addr.clone();
        let xs: Vec<Tensor> =
            ext_inputs[c * PER_CLIENT..(c + 1) * PER_CLIENT].to_vec();
        clients.push(std::thread::spawn(move || {
            let mut replies = Vec::new();
            for x in &xs {
                let (status, v) = http(&addr, "POST", "/v1/infer", Some(&infer_body(x)));
                assert_eq!(status, 200, "infer failed during chaos: {v:?}");
                replies.push(v);
            }
            replies
        }));
    }
    let shut_addr = addr.clone();
    let shutter = std::thread::spawn(move || {
        let replies: Vec<_> = clients.into_iter().map(|h| h.join().unwrap()).collect();
        let (st, _) = http(&shut_addr, "POST", "/v1/shutdown", None);
        assert_eq!(st, 200);
        replies
    });

    let report = session
        .serve_gateway(&Workload::uniform(Vec::new(), 0.0), &bridge)
        .unwrap();
    let client_replies = shutter.join().unwrap();
    killer.join().unwrap();

    // Telemetry over the same chaos run, scraped through the still-live
    // HTTP thread: /metrics must show the recoveries and the latency
    // series, and some retained trace must carry a reaped device span
    // followed by a recovery event (ISSUE 10 acceptance).
    let (st, metrics) = http_text(&addr, "GET", "/metrics", None);
    assert_eq!(st, 200);
    assert!(metrics.contains("# TYPE cdc_requests_total counter"), "{metrics}");
    assert!(metrics.contains("# TYPE cdc_request_latency_ms histogram"), "{metrics}");
    assert!(
        prom_value(&metrics, "cdc_recoveries_total") > 0.0,
        "kill landed but /metrics shows no recoveries:\n{metrics}"
    );
    assert!(
        prom_value(&metrics, "cdc_request_latency_ms_count")
            >= (CLIENTS * PER_CLIENT) as f64,
        "latency histogram missed requests:\n{metrics}"
    );
    assert!(prom_value(&metrics, "gateway_http_requests_total") > 0.0, "{metrics}");

    let (st, list) = http(&addr, "GET", "/v1/traces", None);
    assert_eq!(st, 200, "{list:?}");
    let rows = list.get("traces").unwrap().as_arr().unwrap().to_vec();
    assert_eq!(rows.len(), CLIENTS * PER_CLIENT, "{list:?}");
    let mut saw_reaped_then_recovered = false;
    for row in &rows {
        let req = row.get("req").unwrap().as_usize().unwrap() as u64;
        let (st, detail) = http(&addr, "GET", &format!("/v1/traces/{req}"), None);
        assert_eq!(st, 200, "{detail:?}");
        let kinds: Vec<String> = detail
            .get("events")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|e| e.get("kind").unwrap().as_str().unwrap().to_string())
            .collect();
        if let Some(i) = kinds.iter().position(|k| k == "reaped") {
            if kinds[i..].iter().any(|k| k == "recovered") {
                saw_reaped_then_recovered = true;
            }
        }
    }
    assert!(
        saw_reaped_then_recovered,
        "no retained trace shows a reaped span followed by a recovery"
    );

    // Both Chrome exports are loadable trace-event documents.
    let (st, chrome) = http(&addr, "GET", "/v1/traces?format=chrome", None);
    assert_eq!(st, 200);
    assert!(!chrome.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
    let (st, _) = http(&addr, "GET", "/v1/traces/999999", None);
    assert_eq!(st, 404, "unknown trace id must 404");

    drop(server);

    assert!(report.failures.is_empty(), "chaos lost requests: {}", report.line());
    assert_eq!(
        report.throughput.completed,
        (CLIENTS * PER_CLIENT) as u64,
        "{}",
        report.line()
    );
    assert!(
        report.throughput.recovered > 0,
        "kill landed but nothing used parity: {}",
        report.line()
    );
    for (c, replies) in client_replies.iter().enumerate() {
        for (k, v) in replies.iter().enumerate() {
            assert_logits_match(&arts.root, &ext_inputs[c * PER_CLIENT + k], v);
        }
    }
}

#[test]
fn gateway_lifecycle_migrate_undeploy_deploy() {
    let arts = synth::build(83).unwrap();
    let fleet =
        LoopbackFleet::spawn(Some(worker_bin()), &arts.root, 4, None).unwrap();
    let mut session = Session::start(&arts.root, base_cfg(&fleet)).unwrap();
    let (server, bridge, addr) = start_gateway(&session);
    let root = arts.root.clone();
    let xs = inputs(4, 831);

    let controller = std::thread::spawn(move || {
        // Baseline infer.
        let (st, v) = http(&addr, "POST", "/v1/infer", Some(&infer_body(&xs[0])));
        assert_eq!(st, 200, "{v:?}");
        assert_logits_match(&root, &xs[0], &v);

        // Migrate device 0's tasks onto device 2 (make-before-break) and
        // infer again — still oracle-exact, nothing dropped.
        let path = format!("/v1/deployments/{}/migrate", synth::MODEL);
        let (st, v) = http(&addr, "POST", &path, Some("{\"from\":0,\"to\":2}"));
        assert_eq!(st, 200, "migrate failed: {v:?}");
        assert!(v.get("moved").unwrap().as_usize().unwrap() > 0);
        let (st, v) = http(&addr, "POST", "/v1/infer", Some(&infer_body(&xs[1])));
        assert_eq!(st, 200, "{v:?}");
        assert_logits_match(&root, &xs[1], &v);

        // Migrating to the same device is a clean 400, not a wedge.
        let (st, _) = http(&addr, "POST", &path, Some("{\"from\":2,\"to\":2}"));
        assert_eq!(st, 400);

        // Undeploy: infer turns 503 (typed, not a hang or a drop).
        let del = format!("/v1/deployments/{}", synth::MODEL);
        let (st, v) = http(&addr, "DELETE", &del, None);
        assert_eq!(st, 200, "{v:?}");
        let (st, v) = http(&addr, "POST", "/v1/infer", Some(&infer_body(&xs[2])));
        assert_eq!(st, 503, "undeployed infer must 503: {v:?}");
        let (st, v) = http(&addr, "GET", "/v1/deployments", None);
        assert_eq!(st, 200);
        assert!(!v.as_arr().unwrap()[0].get("deployed").unwrap().as_bool().unwrap());

        // Redeploy and serve again.
        let body = format!("{{\"model\":\"{}\"}}", synth::MODEL);
        let (st, v) = http(&addr, "POST", "/v1/deployments", Some(&body));
        assert_eq!(st, 200, "redeploy failed: {v:?}");
        let (st, v) = http(&addr, "POST", "/v1/infer", Some(&infer_body(&xs[3])));
        assert_eq!(st, 200, "{v:?}");
        assert_logits_match(&root, &xs[3], &v);

        // Unknown model on lifecycle endpoints is a 404.
        let (st, _) = http(&addr, "DELETE", "/v1/deployments/nope", None);
        assert_eq!(st, 404);

        let (st, _) = http(&addr, "POST", "/v1/shutdown", None);
        assert_eq!(st, 200);
    });

    let report = session
        .serve_gateway(&Workload::uniform(Vec::new(), 0.0), &bridge)
        .unwrap();
    controller.join().unwrap();
    drop(server);

    assert!(report.failures.is_empty(), "{}", report.line());
    assert_eq!(report.throughput.completed, 3, "{}", report.line());
    drop(session);
    drop(fleet);
}
