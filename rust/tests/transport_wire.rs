//! Wire-codec hardening tests (ISSUE 5): round-trip property tests over
//! every frame type, plus adversarial inputs — truncated, oversized, and
//! garbage frames must come back as `Error` values, never panics or
//! attacker-sized allocations.

use std::io::Cursor;
use std::sync::Arc;

use cdc_dnn::fleet::{FailurePlan, NetConfig, TaskDef};
use cdc_dnn::kernels::Precision;
use cdc_dnn::rng::Pcg32;
use cdc_dnn::tensor::Tensor;
use cdc_dnn::testkit;
use cdc_dnn::transport::wire::{self, Frame};

fn roundtrip(bytes: &[u8]) -> Frame {
    let mut c = Cursor::new(bytes.to_vec());
    let f = wire::read_frame(&mut c).expect("decode").expect("one frame");
    // The whole frame must be consumed.
    assert_eq!(c.position() as usize, bytes.len());
    f
}

#[test]
fn handshake_frames_roundtrip() {
    match roundtrip(&wire::hello(0xdead_beef, 7)) {
        Frame::Hello { proto, seed, device } => {
            assert_eq!(proto, wire::PROTO_VERSION);
            assert_eq!(seed, 0xdead_beef);
            assert_eq!(device, 7);
        }
        other => panic!("{other:?}"),
    }
    assert!(matches!(
        roundtrip(&wire::hello_ack()),
        Frame::HelloAck { proto } if proto == wire::PROTO_VERSION
    ));
    assert!(matches!(roundtrip(&wire::shutdown()), Frame::Shutdown));
}

#[test]
fn control_frames_roundtrip() {
    match roundtrip(&wire::undeploy(&[3, 1, 4, 1, 5])) {
        Frame::Undeploy { ids } => assert_eq!(ids, vec![3, 1, 4, 1, 5]),
        other => panic!("{other:?}"),
    }
    match roundtrip(&wire::set_failure(&FailurePlan::PermanentAt(42))) {
        Frame::SetFailure { plan: FailurePlan::PermanentAt(42) } => {}
        other => panic!("{other:?}"),
    }
    match roundtrip(&wire::set_failure(&FailurePlan::Intermittent(0.25))) {
        Frame::SetFailure { plan: FailurePlan::Intermittent(p) } => {
            assert!((p - 0.25).abs() < 1e-12)
        }
        other => panic!("{other:?}"),
    }
    match roundtrip(&wire::set_net(true, &NetConfig::moderate())) {
        Frame::SetNet { enabled: true, net } => {
            let m = NetConfig::moderate();
            assert_eq!(net.base_ms, m.base_ms);
            assert_eq!(net.p_fast, m.p_fast);
            assert_eq!(net.max_ms, m.max_ms);
        }
        other => panic!("{other:?}"),
    }
    match roundtrip(&wire::set_rate(1234.5)) {
        Frame::SetRate { macs_per_ms } => assert_eq!(macs_per_ms, 1234.5),
        other => panic!("{other:?}"),
    }
}

#[test]
fn membership_frames_roundtrip() {
    match roundtrip(&wire::register(812.5, wire::CAP_COMPUTE)) {
        Frame::Register { proto, macs_per_ms, capabilities } => {
            assert_eq!(proto, wire::PROTO_VERSION);
            assert_eq!(macs_per_ms, 812.5);
            assert_eq!(capabilities, wire::CAP_COMPUTE);
        }
        other => panic!("{other:?}"),
    }
    // An unannounced rate (0.0) survives the trip — the coordinator
    // substitutes its configured default on admission.
    assert!(matches!(
        roundtrip(&wire::register(0.0, wire::CAP_COMPUTE)),
        Frame::Register { macs_per_ms, .. } if macs_per_ms == 0.0
    ));
    match roundtrip(&wire::register_ack(9, 0xfeed_f00d)) {
        Frame::RegisterAck { proto, device, seed } => {
            assert_eq!(proto, wire::PROTO_VERSION);
            assert_eq!(device, 9);
            assert_eq!(seed, 0xfeed_f00d);
        }
        other => panic!("{other:?}"),
    }
    assert!(matches!(
        roundtrip(&wire::heartbeat(41)),
        Frame::Heartbeat { nonce: 41 }
    ));
    // Bare (proto-3 shape) ack: decodes with zero counters.
    match roundtrip(&wire::heartbeat_ack(41)) {
        Frame::HeartbeatAck { nonce: 41, counters } => assert!(counters.is_empty()),
        other => panic!("{other:?}"),
    }
    // v4 ack with piggybacked worker counters round-trips exactly.
    let ctrs = [
        (wire::WCTR_ORDERS, 12u64),
        (wire::WCTR_REPLIES, 34),
        (wire::WCTR_DROPPED, 0),
        (wire::WCTR_EXEC_ERRORS, u64::MAX),
    ];
    match roundtrip(&wire::heartbeat_ack_with_counters(42, &ctrs)) {
        Frame::HeartbeatAck { nonce: 42, counters } => assert_eq!(counters, ctrs.to_vec()),
        other => panic!("{other:?}"),
    }
    assert!(matches!(roundtrip(&wire::leave()), Frame::Leave));
}

/// The v3↔v4 negotiation window: both versions are accepted, anything
/// outside the window is not, and an ack claiming more counters than
/// the wire cap is rejected as hostile input.
#[test]
fn proto_window_and_counter_cap() {
    assert!(wire::proto_compatible(wire::MIN_PROTO_VERSION));
    assert!(wire::proto_compatible(wire::PROTO_VERSION));
    assert!(!wire::proto_compatible(wire::MIN_PROTO_VERSION - 1));
    assert!(!wire::proto_compatible(wire::PROTO_VERSION + 1));

    // Patch a valid 1-counter ack to claim 200 counters.
    let mut frame = wire::heartbeat_ack_with_counters(7, &[(wire::WCTR_ORDERS, 1)]);
    frame[5 + 8] = 200; // count byte sits right after kind+len+nonce
    let err = wire::read_frame(&mut Cursor::new(frame)).unwrap_err();
    assert!(err.to_string().contains("cap"), "{err}");
}

/// The protocol-mismatch diagnostic names both sides and both versions —
/// the operator-facing message a stale worker binary produces when it
/// dials a newer coordinator (ISSUE 7 satellite).
#[test]
fn proto_mismatch_diagnostic_names_both_sides() {
    let err = wire::proto_mismatch("worker 127.0.0.1:9000", "coordinator", 1);
    let msg = err.to_string();
    assert!(msg.contains("worker 127.0.0.1:9000"), "{msg}");
    assert!(msg.contains("coordinator"), "{msg}");
    assert!(msg.contains("protocol 1"), "{msg}");
    assert!(
        msg.contains(&wire::PROTO_VERSION.to_string()),
        "expected version missing: {msg}"
    );
    assert!(matches!(err, cdc_dnn::error::Error::Wire(_)));
}

/// Property: Work / Reply / Deploy frames round-trip bit-exactly over
/// random shapes, ids and payload values (including negative zero and
/// subnormals from the normal draw).
#[test]
fn payload_frames_roundtrip_property() {
    testkit::forall(
        0x11ce,
        60,
        |rng| {
            let k = 1 + rng.below(24);
            let b = 1 + rng.below(4);
            let input = Tensor::randn(vec![k, b], rng);
            let w = Tensor::randn(vec![1 + rng.below(8), k], rng);
            let bias = Tensor::randn(vec![w.shape()[0], 1], rng);
            let req = rng.next_u64();
            let tasks: Vec<u64> = (0..1 + rng.below(5)).map(|_| rng.next_u64()).collect();
            (req, tasks, b, input, w, bias)
        },
        |(req, tasks, b, input, w, bias)| {
            // Work
            match roundtrip(&wire::work(*req, tasks, *b, input)) {
                Frame::Work { req: r, tasks: t, batch, input: i } => {
                    if r != *req || &t != tasks || batch as usize != *b || &i != input {
                        return Err("work roundtrip mismatch".into());
                    }
                }
                other => return Err(format!("work decoded as {other:?}")),
            }
            // Reply (present and lost)
            match roundtrip(&wire::reply(*req, tasks[0], Some(input))) {
                Frame::Reply { req: r, task, result: Some(t) } => {
                    if r != *req || task != tasks[0] || &t != input {
                        return Err("reply roundtrip mismatch".into());
                    }
                }
                other => return Err(format!("reply decoded as {other:?}")),
            }
            match roundtrip(&wire::reply(*req, tasks[0], None)) {
                Frame::Reply { result: None, .. } => {}
                other => return Err(format!("lost reply decoded as {other:?}")),
            }
            // Deploy (f32 precision byte 0)
            let def = TaskDef::new(
                tasks[0],
                format!("fc_m{}_k{}_lin", w.shape()[0], w.shape()[1]),
                Arc::new(w.clone()),
                Arc::new(bias.clone()),
                *req % 1_000_000,
                *req % 4096,
            );
            match roundtrip(&wire::deploy(&[def.clone()])) {
                Frame::Deploy { tasks: ts } => {
                    let t = &ts[0];
                    if t.id != def.id
                        || t.artifact != def.artifact
                        || t.macs != def.macs
                        || t.reply_bytes != def.reply_bytes
                        || t.w.as_ref() != Some(w)
                        || t.quant.is_some()
                        || &t.b != bias
                    {
                        return Err("deploy roundtrip mismatch".into());
                    }
                }
                other => return Err(format!("deploy decoded as {other:?}")),
            }
            // Deploy (int8 precision byte 1): the quantized form must
            // survive the wire bit-for-bit — scales and i8 data both.
            let qdef = def.clone().prepare(Precision::Int8, true);
            let q = qdef.quant.as_ref().expect("2-d fc task quantizes").clone();
            match roundtrip(&wire::deploy(&[qdef])) {
                Frame::Deploy { tasks: ts } => {
                    let t = &ts[0];
                    if t.w.is_some() || t.quant.as_ref() != Some(q.as_ref()) || &t.b != bias {
                        return Err("quantized deploy roundtrip mismatch".into());
                    }
                }
                other => return Err(format!("quantized deploy decoded as {other:?}")),
            }
            Ok(())
        },
    );
}

#[test]
fn clean_eof_is_none_truncation_is_error() {
    // Empty stream: clean EOF.
    let mut c = Cursor::new(Vec::<u8>::new());
    assert!(wire::read_frame(&mut c).unwrap().is_none());

    // Every proper prefix of a valid frame must error (EOF mid-frame or
    // truncated payload), never panic, never hang.
    let frame = wire::work(9, &[1, 2], 1, &Tensor::col(&[1.0, 2.0, 3.0]));
    for cut in 1..frame.len() {
        let mut c = Cursor::new(frame[..cut].to_vec());
        assert!(
            wire::read_frame(&mut c).is_err(),
            "prefix of {cut}/{} bytes decoded",
            frame.len()
        );
    }
}

#[test]
fn oversized_length_prefix_is_rejected_before_allocation() {
    // kind + u32::MAX length: must fail on the cap check, not attempt a
    // 4 GiB allocation or read.
    let mut bytes = vec![0x05u8];
    bytes.extend_from_slice(&u32::MAX.to_le_bytes());
    bytes.extend_from_slice(&[0u8; 64]);
    let err = wire::read_frame(&mut Cursor::new(bytes)).unwrap_err();
    assert!(err.to_string().contains("exceeds cap"), "{err}");
}

#[test]
fn hostile_tensor_and_count_headers_are_rejected() {
    // A Work frame claiming a 2^32-ish element tensor: the declared dims
    // overflow the element cap long before any allocation.
    let mut payload = Vec::new();
    payload.extend_from_slice(&7u64.to_le_bytes()); // req
    payload.extend_from_slice(&1u32.to_le_bytes()); // 1 task
    payload.extend_from_slice(&1u64.to_le_bytes());
    payload.extend_from_slice(&1u32.to_le_bytes()); // batch
    payload.push(2); // rank 2
    payload.extend_from_slice(&0xffff_ffffu32.to_le_bytes());
    payload.extend_from_slice(&0xffff_ffffu32.to_le_bytes());
    let mut frame = vec![0x05u8];
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(&payload);
    let err = wire::read_frame(&mut Cursor::new(frame)).unwrap_err();
    assert!(err.to_string().contains("exceeds cap"), "{err}");

    // An Undeploy frame claiming 2^31 ids in a 12-byte payload: the
    // count is cross-checked against the bytes actually present.
    let mut payload = Vec::new();
    payload.extend_from_slice(&0x8000_0000u32.to_le_bytes());
    payload.extend_from_slice(&[0u8; 8]);
    let mut frame = vec![0x04u8];
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(&payload);
    assert!(wire::read_frame(&mut Cursor::new(frame)).is_err());
}

#[test]
fn garbage_never_panics() {
    let mut rng = Pcg32::seeded(0xbad);
    for _ in 0..200 {
        let n = rng.below(96);
        let bytes: Vec<u8> = (0..n).map(|_| (rng.next_u32() & 0xff) as u8).collect();
        // Any outcome but a panic/hang is acceptable; a full garbage
        // header usually fails the kind/cap/bounds checks.
        let _ = wire::read_frame(&mut Cursor::new(bytes));
    }
}

/// Build one valid encoded frame of every wire kind — the corpus the
/// mutation fuzzer perturbs.
fn corpus() -> Vec<Vec<u8>> {
    let t = Tensor::col(&[1.0, -2.5, 3.25, 0.0]);
    let def = TaskDef::new(
        11,
        "fc_m4_k4_lin",
        Arc::new(Tensor::randn(vec![4, 4], &mut Pcg32::seeded(1))),
        Arc::new(Tensor::col(&[0.0, 0.0, 0.0, 0.0])),
        16,
        16,
    );
    let qdef = def.clone().prepare(Precision::Int8, true);
    vec![
        wire::hello(0xfeed, 3),
        wire::hello_ack(),
        wire::deploy(&[def]),
        wire::deploy(&[qdef]),
        wire::undeploy(&[11, 12]),
        wire::work(7, &[11], 2, &t),
        wire::reply(7, 11, Some(&t)),
        wire::reply(7, 11, None),
        wire::set_failure(&FailurePlan::Intermittent(0.5)),
        wire::set_net(true, &NetConfig::moderate()),
        wire::set_rate(250.0),
        wire::shutdown(),
        wire::register(640.0, wire::CAP_COMPUTE),
        wire::register_ack(6, 0xabad_cafe),
        wire::heartbeat(3),
        wire::heartbeat_ack(3),
        wire::heartbeat_ack_with_counters(4, &[(wire::WCTR_ORDERS, 9), (wire::WCTR_REPLIES, 8)]),
        wire::leave(),
    ]
}

/// Deterministic mutation fuzz (ISSUE 6): flip, truncate, and extend
/// random bytes of valid frames; every mutant must decode to `Ok` or
/// `Error::Wire` — never a panic, a hang, or an attacker-sized
/// allocation. `read_frame` is only exercised when the (possibly
/// mutated) length prefix stays small: unlike the slice decoders it
/// must allocate the declared payload up front, and this test's budget
/// is panics, not gigabyte allocations under the 256 MiB cap.
#[test]
fn mutated_frames_never_panic() {
    let corpus = corpus();
    let mut rng = Pcg32::seeded(0x5eed_f822);
    for iter in 0..2000 {
        let mut bytes = corpus[rng.below(corpus.len())].clone();
        // 1-4 mutations per round.
        for _ in 0..1 + rng.below(4) {
            match rng.below(4) {
                0 => {
                    // Flip one bit.
                    let i = rng.below(bytes.len());
                    bytes[i] ^= 1 << rng.below(8);
                }
                1 => {
                    // Overwrite one byte.
                    let i = rng.below(bytes.len());
                    bytes[i] = (rng.next_u32() & 0xff) as u8;
                }
                2 => {
                    // Truncate.
                    bytes.truncate(rng.below(bytes.len() + 1));
                    if bytes.is_empty() {
                        bytes.push((rng.next_u32() & 0xff) as u8);
                    }
                }
                _ => {
                    // Extend with garbage.
                    for _ in 0..1 + rng.below(8) {
                        bytes.push((rng.next_u32() & 0xff) as u8);
                    }
                }
            }
        }
        // Slice decoder: allocation is bounded by the bytes actually
        // present, so every mutant is fair game.
        match wire::decode_prefix(&bytes) {
            Ok(Some((_, used))) => assert!(
                used <= bytes.len(),
                "iter {iter}: consumed {used} of {} bytes",
                bytes.len()
            ),
            Ok(None) => {} // incomplete frame — needs more bytes
            Err(cdc_dnn::error::Error::Wire(_)) => {}
            Err(e) => panic!("iter {iter}: non-wire error {e}"),
        }
        // Stream decoder: gate on the declared length so a mutated
        // prefix can't demand a huge up-front payload allocation.
        let declared = (bytes.len() >= 5)
            .then(|| u32::from_le_bytes([bytes[1], bytes[2], bytes[3], bytes[4]]));
        if declared.is_some_and(|len| len <= 1 << 20) {
            match wire::read_frame(&mut Cursor::new(bytes)) {
                Ok(_) => {}
                Err(cdc_dnn::error::Error::Wire(_)) => {}
                Err(e) => panic!("iter {iter}: non-wire error {e}"),
            }
        }
    }
}

/// The event loop's incremental decoder: complete frames come off the
/// front of a receive buffer one at a time, a partial tail reports
/// `None` until the missing bytes arrive.
#[test]
fn decode_prefix_walks_concatenated_frames() {
    let a = wire::set_rate(9.5);
    let b = wire::reply(3, 4, Some(&Tensor::col(&[1.0, 2.0])));
    let c = wire::shutdown();
    let mut buf = Vec::new();
    buf.extend_from_slice(&a);
    buf.extend_from_slice(&b);
    buf.extend_from_slice(&c[..c.len() - 1]); // partial third frame

    let (f1, used1) = wire::decode_prefix(&buf).unwrap().unwrap();
    assert!(matches!(f1, Frame::SetRate { macs_per_ms } if macs_per_ms == 9.5));
    assert_eq!(used1, a.len());

    let (f2, used2) = wire::decode_prefix(&buf[used1..]).unwrap().unwrap();
    assert!(matches!(f2, Frame::Reply { req: 3, task: 4, result: Some(_) }));
    assert_eq!(used2, b.len());

    // The tail is one byte short of a complete frame: not an error —
    // the event loop keeps it buffered and reads more.
    assert!(wire::decode_prefix(&buf[used1 + used2..]).unwrap().is_none());
    buf.extend_from_slice(&c[c.len() - 1..]);
    let (f3, _) = wire::decode_prefix(&buf[used1 + used2..]).unwrap().unwrap();
    assert!(matches!(f3, Frame::Shutdown));
}

#[test]
fn trailing_payload_bytes_are_rejected() {
    let mut frame = wire::set_rate(1.0);
    // Grow the payload by one byte and patch the length.
    frame.push(0);
    let len = (frame.len() - 5) as u32;
    frame[1..5].copy_from_slice(&len.to_le_bytes());
    let err = wire::read_frame(&mut Cursor::new(frame)).unwrap_err();
    assert!(err.to_string().contains("trailing"), "{err}");
}
