//! Micro-benchmarks of the compute hot path.
//!
//! Part 1 (always runs, no artifacts needed): the kernel-layer sweep —
//! naive vs tiled vs SIMD vs tiled+threaded GEMM across the acceptance
//! 256³ multiply, LeNet-5 shard shapes (conv layers as their im2col
//! GEMMs), and non-square fc shard shapes. The SIMD arm runs the
//! runtime-detected micro-kernel tier (AVX2/NEON, DESIGN.md §15); its
//! records carry the tier label so a promoted number is always
//! attributable. Writes the `BENCH_gemm.json` baseline (GFLOP/s +
//! speedups) at the repo root so the perf trajectory is tracked across
//! PRs. `GEMM_BENCH_SMOKE=1` shrinks iteration counts for CI;
//! `GEMM_BENCH_ENFORCE=1` fails the run if the dispatch ladder inverts
//! on the 256³ multiply — `simd ≥ tiled ≥ naive` in GFLOP/s (the simd
//! leg only when a SIMD tier is actually active).
//!
//! Part 2: the fused CDC parity epilogue vs a separate parity GEMM.
//!
//! Part 3 (skips without `make artifacts`): artifact execution through
//! the active backend, plus the coordinator-side merge ops (CDC decode
//! must be "close-to-zero" next to a shard execution). Every section
//! reports which backend produced its numbers.

use std::path::{Path, PathBuf};

use cdc_dnn::bench::Bench;
use cdc_dnn::cdc;
use cdc_dnn::json::{obj, Value};
use cdc_dnn::kernels::{self, Scratch};
use cdc_dnn::rng::Pcg32;
use cdc_dnn::runtime::{self, Manifest, Runtime};
use cdc_dnn::tensor::Tensor;

struct ShapeCase {
    name: &'static str,
    m: usize,
    k: usize,
    n: usize,
}

/// Acceptance shape first, then LeNet-5 layers as GEMMs (conv via
/// im2col), then the paper's fc-2048 shard — square and batched.
const SHAPES: &[ShapeCase] = &[
    ShapeCase { name: "gemm_256", m: 256, k: 256, n: 256 },
    ShapeCase { name: "lenet_conv1_im2col", m: 6, k: 25, n: 784 },
    ShapeCase { name: "lenet_conv2_im2col", m: 16, k: 150, n: 100 },
    ShapeCase { name: "lenet_fc1_gemv", m: 120, k: 400, n: 1 },
    ShapeCase { name: "fc2048_shard_d4_gemv", m: 512, k: 2048, n: 1 },
    ShapeCase { name: "fc2048_shard_d4_b32", m: 512, k: 2048, n: 32 },
];

fn gflops(m: usize, k: usize, n: usize, mean_ms: f64) -> f64 {
    if mean_ms <= 0.0 {
        return f64::INFINITY;
    }
    2.0 * m as f64 * k as f64 * n as f64 / 1e9 / (mean_ms / 1e3)
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

fn bench_out_path() -> PathBuf {
    // Benches run with cwd = the `rust` package; the baseline lives at
    // the repo root next to ROADMAP.md.
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_gemm.json"))
        .unwrap_or_else(|| PathBuf::from("BENCH_gemm.json"))
}

fn kernel_sweep(smoke: bool, enforce: bool) {
    let (warm, iters) = if smoke { (1, 3) } else { (3, 15) };
    let threads = kernels::auto_threads();
    let tier = kernels::active_tier();
    let simd_on = kernels::simd_available();
    println!(
        "== kernel sweep (naive vs tiled vs simd[{tier}] vs tiled+threaded, \
         {threads} threads, smoke={smoke}) =="
    );
    let mut rng = Pcg32::seeded(1);
    let mut records: Vec<Value> = Vec::new();
    let mut acc256: Option<(f64, f64, f64, f64)> = None;
    for s in SHAPES {
        let a = Tensor::randn(vec![s.m, s.k], &mut rng);
        let b = Tensor::randn(vec![s.k, s.n], &mut rng);
        let mut c = vec![0.0f32; s.m * s.n];
        let mut cref = vec![0.0f32; s.m * s.n];
        let mut sc = Scratch::new();

        // Correctness gate before timing anything.
        kernels::gemm_naive(a.data(), b.data(), &mut cref, s.m, s.k, s.n);
        kernels::gemm_tiled(a.data(), b.data(), &mut c, s.m, s.k, s.n, &mut sc);
        let tol = 1e-5 * s.k.max(16) as f32;
        let d = max_abs_diff(&c, &cref);
        assert!(d < tol, "{}: tiled diverges from naive by {d}", s.name);
        kernels::gemm_simd(a.data(), b.data(), &mut c, s.m, s.k, s.n, &mut sc);
        let d = max_abs_diff(&c, &cref);
        assert!(d < tol, "{}: simd[{tier}] diverges from naive by {d}", s.name);
        kernels::gemm_threaded(a.data(), b.data(), &mut c, s.m, s.k, s.n, threads);
        let d = max_abs_diff(&c, &cref);
        assert!(d < tol, "{}: threaded diverges from naive by {d}", s.name);

        let naive = Bench::new(&format!("gemm/naive/{}", s.name))
            .iters(warm, iters)
            .run(|| {
                kernels::gemm_naive(a.data(), b.data(), &mut c, s.m, s.k, s.n);
            });
        let tiled = Bench::new(&format!("gemm/tiled/{}", s.name))
            .iters(warm, iters)
            .run(|| {
                kernels::gemm_tiled(a.data(), b.data(), &mut c, s.m, s.k, s.n, &mut sc);
            });
        let simd = Bench::new(&format!("gemm/simd[{tier}]/{}", s.name))
            .iters(warm, iters)
            .run(|| {
                kernels::gemm_simd(a.data(), b.data(), &mut c, s.m, s.k, s.n, &mut sc);
            });
        let threaded = Bench::new(&format!("gemm/threaded/{}", s.name))
            .iters(warm, iters)
            .run(|| {
                kernels::gemm_threaded(a.data(), b.data(), &mut c, s.m, s.k, s.n, threads);
            });

        let gn = gflops(s.m, s.k, s.n, naive.mean);
        let gt = gflops(s.m, s.k, s.n, tiled.mean);
        let gs = gflops(s.m, s.k, s.n, simd.mean);
        let gth = gflops(s.m, s.k, s.n, threaded.mean);
        println!(
            "  {:<22} naive {gn:>6.2} GF/s | tiled {gt:>6.2} ({:.2}x) | \
             simd {gs:>6.2} ({:.2}x) | +threads {gth:>6.2} ({:.2}x)",
            s.name,
            gt / gn,
            gs / gn,
            gth / gn
        );
        records.push(obj(vec![
            ("shape", Value::Str(s.name.into())),
            ("m", Value::Num(s.m as f64)),
            ("k", Value::Num(s.k as f64)),
            ("n", Value::Num(s.n as f64)),
            ("kernel_tier", Value::Str(tier.into())),
            ("naive_gflops", Value::Num(gn)),
            ("tiled_gflops", Value::Num(gt)),
            ("simd_gflops", Value::Num(gs)),
            ("threaded_gflops", Value::Num(gth)),
            ("tiled_speedup", Value::Num(gt / gn)),
            ("simd_speedup", Value::Num(gs / gn)),
            ("threaded_speedup", Value::Num(gth / gn)),
        ]));
        if s.m == 256 && s.k == 256 && s.n == 256 {
            acc256 = Some((gn, gt, gs, gth));
        }
    }

    let doc = obj(vec![
        ("bench", Value::Str("gemm_kernels".into())),
        ("backend", Value::Str(runtime::backend_label().into())),
        ("kernel_tier", Value::Str(tier.into())),
        ("threads", Value::Num(threads as f64)),
        ("smoke", Value::Bool(smoke)),
        ("results", Value::Arr(records)),
    ]);
    let out = bench_out_path();
    std::fs::write(&out, doc.to_string_pretty()).expect("write BENCH_gemm.json");
    println!("[result] wrote {}", out.display());

    if let Some((gn, gt, gs, gth)) = acc256 {
        println!(
            "acceptance 256^3: tiled {:.2}x, simd[{tier}] {:.2}x, \
             tiled+threaded {:.2}x vs naive (targets: >=2x single-thread, \
             simd >= tiled, >=4x threaded)",
            gt / gn,
            gs / gn,
            gth / gn
        );
        if enforce {
            // The dispatch-ladder gate (smoke included): each rung of
            // `gemm_auto`'s escalation must actually be a speedup on the
            // acceptance shape, or the ladder is misordered.
            assert!(
                gt >= gn,
                "kernel regression: tiled ({gt:.2} GF/s) slower than naive \
                 ({gn:.2} GF/s) on the 256^3 multiply"
            );
            if simd_on {
                assert!(
                    gs >= gt,
                    "kernel regression: simd[{tier}] ({gs:.2} GF/s) slower \
                     than scalar tiled ({gt:.2} GF/s) on the 256^3 multiply"
                );
            }
        }
        // Perf-trajectory guard (CI): GFLOP/s on the acceptance shape vs
        // the committed seed. Wall-clock metrics vary by host, so the
        // seed is promoted from the same CI runner class's artifacts
        // (scripts/promote_baselines.sh).
        cdc_dnn::bench::guard_baseline(
            "gemm",
            &[
                ("gemm256_tiled_gflops".to_string(), gt),
                ("gemm256_simd_gflops".to_string(), gs),
                ("gemm256_threaded_gflops".to_string(), gth),
                ("gemm256_tiled_speedup".to_string(), gt / gn),
                ("gemm256_simd_speedup".to_string(), gs / gn),
            ],
        );
    }
}

fn fused_parity_bench(smoke: bool) {
    println!("== CDC parity encode: fused epilogue vs separate GEMM ==");
    let (warm, iters) = if smoke { (1, 3) } else { (5, 30) };
    let mut rng = Pcg32::seeded(2);
    let (d, h, k) = (4usize, 128usize, 512usize);
    let shards: Vec<(Tensor, Tensor)> = (0..d)
        .map(|_| {
            (
                Tensor::randn(vec![h, k], &mut rng),
                Tensor::randn(vec![h, 1], &mut rng),
            )
        })
        .collect();
    let wrefs: Vec<&Tensor> = shards.iter().map(|(w, _)| w).collect();
    let brefs: Vec<&Tensor> = shards.iter().map(|(_, b)| b).collect();
    let w_stacked = Tensor::concat0(&wrefs).unwrap();
    let b_stacked = Tensor::concat0(&brefs).unwrap();
    let x = Tensor::randn(vec![k, 8], &mut rng);

    Bench::new("cdc/fused_parity_epilogue (d=4, 128x512)")
        .iters(warm, iters)
        .run(|| {
            cdc::fused_shard_outputs(&w_stacked, &b_stacked, &x, d).unwrap();
        });
    Bench::new("cdc/separate_parity_gemm (d=4, 128x512)")
        .iters(warm, iters)
        .run(|| {
            // Shard GEMMs plus a full extra parity-weight multiply.
            for (w, b) in &shards {
                let mut y = w.matmul(&x).unwrap();
                for (i, row) in y.data_mut().chunks_mut(8).enumerate() {
                    for v in row.iter_mut() {
                        *v += b.data()[i];
                    }
                }
            }
            let (pw, pb) = cdc::parity_weights(&shards).unwrap();
            let mut p = pw.matmul(&x).unwrap();
            for (i, row) in p.data_mut().chunks_mut(8).enumerate() {
                for v in row.iter_mut() {
                    *v += pb.data()[i];
                }
            }
        });
}

fn artifact_and_merge_benches(smoke: bool) {
    let backend = runtime::backend_label();
    let mut rng = Pcg32::seeded(3);
    let (warm, iters) = if smoke { (1, 5) } else { (10, 100) };

    if cdc_dnn::testkit::artifacts_available(Path::new("artifacts")) {
        println!("== artifact execution (backend: {backend}) ==");
        let manifest = Manifest::load("artifacts").expect("run `make artifacts`");
        let runtime = Runtime::new().expect("backend init");

        // fc-2048 shard (the paper's §6 anchor task), 4-way split.
        if manifest.artifacts.contains_key("fc_m512_k2048_lin") {
            let w = Tensor::randn(vec![512, 2048], &mut rng);
            let b = Tensor::randn(vec![512, 1], &mut rng);
            let x = Tensor::randn(vec![2048, 1], &mut rng);
            runtime.execute(&manifest, "fc_m512_k2048_lin", &[&w, &b, &x]).unwrap();
            Bench::new(&format!("exec[{backend}]/fc2048_shard_d4 (512x2048)"))
                .iters(warm, iters)
                .run(|| {
                    runtime
                        .execute(&manifest, "fc_m512_k2048_lin", &[&w, &b, &x])
                        .unwrap();
                });
            let exe = runtime.build_gemm(512, 2048, 1, true, false).unwrap();
            Bench::new(&format!("exec[{backend}]/fc2048_builder_fallback"))
                .iters(warm, iters)
                .run(|| {
                    runtime.run_built(&exe, &[&w, &x, &b]).unwrap();
                });
        }

        // LeNet conv shard.
        if let Some(meta) = manifest
            .artifacts
            .values()
            .find(|a| a.name.starts_with("conv_h14w14c6_k16"))
            .cloned()
        {
            let ins: Vec<Tensor> = meta
                .params
                .iter()
                .map(|p| Tensor::randn(p.clone(), &mut rng))
                .collect();
            let refs: Vec<&Tensor> = ins.iter().collect();
            runtime.execute(&manifest, &meta.name, &refs).unwrap();
            Bench::new(&format!("exec[{backend}]/lenet_conv2_shard"))
                .iters(warm, iters)
                .run(|| {
                    runtime.execute(&manifest, &meta.name, &refs).unwrap();
                });
        }
    } else {
        println!(
            "[skip] AOT artifacts absent — artifact execution section skipped \
             (would run on backend: {backend})"
        );
    }

    // Merge-path ops: the "close-to-zero" recovery claim. Backend-free
    // coordinator math, always runs.
    println!("== merge path (coordinator-side, backend-independent) ==");
    let parity = Tensor::randn(vec![512, 1], &mut rng);
    let received: Vec<Tensor> =
        (0..3).map(|_| Tensor::randn(vec![512, 1], &mut rng)).collect();
    let refs: Vec<&Tensor> = received.iter().collect();
    Bench::new("merge/cdc_decode_512 (recovery subtraction)")
        .iters(warm, iters * 10)
        .run(|| {
            cdc::decode(&parity, &refs).unwrap();
        });

    let parts: Vec<Tensor> =
        (0..4).map(|_| Tensor::randn(vec![512, 1], &mut rng)).collect();
    let prefs: Vec<&Tensor> = parts.iter().collect();
    Bench::new("merge/concat0_4x512").iters(warm, iters * 10).run(|| {
        Tensor::concat0(&prefs).unwrap().take_rows(2048).unwrap();
    });

    let conv_parts: Vec<Tensor> =
        (0..2).map(|_| Tensor::randn(vec![28, 28, 8], &mut rng)).collect();
    let crefs: Vec<&Tensor> = conv_parts.iter().collect();
    Bench::new("merge/concat_channels+pool 28x28x16")
        .iters(warm, iters * 10)
        .run(|| {
            let cat = Tensor::concat_channels(&crefs).unwrap();
            cat.maxpool(2, 2).unwrap();
        });
}

fn main() {
    let smoke = std::env::var("GEMM_BENCH_SMOKE").is_ok();
    let enforce = std::env::var("GEMM_BENCH_ENFORCE").is_ok();
    kernel_sweep(smoke, enforce);
    fused_parity_bench(smoke);
    artifact_and_merge_benches(smoke);
}
