//! Property tests (via the in-tree `testkit` substrate) over the
//! coordinator's pure invariants: gather-policy semantics, CDC algebra,
//! partition balance, coverage monotonicity, and JSON round-trips.

use cdc_dnn::cdc;
use cdc_dnn::cdc::coverage::Deployment;
use cdc_dnn::coordinator::policy::{self, GroupedOutcome, Outcome};
use cdc_dnn::json::Value;
use cdc_dnn::partition::balanced_ranges;
use cdc_dnn::rng::Pcg32;
use cdc_dnn::tensor::Tensor;
use cdc_dnn::testkit::{forall, gen};

/// CDC algebra: for random shard weights/inputs, losing ANY single shard
/// is exactly recoverable from the parity (to f32 tolerance).
#[test]
fn prop_cdc_recovers_any_single_shard() {
    forall(
        0xc0de,
        60,
        |rng| {
            let d = gen::usize_in(rng, 1, 6);
            let m = gen::usize_in(rng, 1, 24);
            let k = gen::usize_in(rng, 1, 24);
            let shards: Vec<(Tensor, Tensor)> = (0..d)
                .map(|_| {
                    (
                        Tensor::randn(vec![m, k], rng),
                        Tensor::randn(vec![m, 1], rng),
                    )
                })
                .collect();
            let x = Tensor::randn(vec![k, 1], rng);
            let lose = rng.below(d);
            (shards, x, lose)
        },
        |(shards, x, lose)| {
            let outs: Vec<Tensor> = shards
                .iter()
                .map(|(w, b)| {
                    let mut y = w.matmul(x).unwrap();
                    y.add_assign(b).unwrap();
                    y
                })
                .collect();
            let (pw, pb) = cdc::parity_weights(shards).unwrap();
            let mut parity = pw.matmul(x).unwrap();
            parity.add_assign(&pb).unwrap();
            let received: Vec<&Tensor> = outs
                .iter()
                .enumerate()
                .filter(|(i, _)| i != lose)
                .map(|(_, t)| t)
                .collect();
            let rec = cdc::decode(&parity, &received).unwrap();
            let diff = rec.max_abs_diff(&outs[*lose]);
            if diff < 1e-3 {
                Ok(())
            } else {
                Err(format!("recovery diff {diff}"))
            }
        },
    );
}

/// Policy: with a parity shard, the layer NEVER completes later than the
/// no-parity baseline, and never earlier than the d-th fastest arrival.
#[test]
fn prop_policy_parity_never_hurts() {
    forall(
        0x9a7e,
        400,
        |rng| {
            let n = gen::usize_in(rng, 1, 8);
            let n_inf = rng.below(2.min(n + 1));
            let data = gen::arrivals(rng, n, n_inf);
            let parity = rng.range(1.0, 1000.0);
            let threshold = if rng.bernoulli(0.3) {
                f64::INFINITY
            } else {
                rng.range(0.0, 500.0)
            };
            (data, parity, threshold)
        },
        |(data, parity, threshold)| {
            let with = policy::resolve(data, Some(*parity), *threshold);
            let without = policy::resolve(data, None, f64::INFINITY);
            // Lower bound: can't finish before d-th smallest arrival of
            // the d+1 available results.
            let mut all: Vec<f64> = data.clone();
            all.push(*parity);
            all.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let kth = all[data.len() - 1];
            match (with, without) {
                (Outcome::Lost, Outcome::Lost) => Ok(()),
                (Outcome::Lost, _) => Err("parity made things worse".into()),
                (o, Outcome::Lost) => {
                    if o.t_ms().is_finite() {
                        Ok(())
                    } else {
                        Err("recovered but infinite time".into())
                    }
                }
                (o, base) => {
                    if o.t_ms() <= base.t_ms() + 1e-9 && o.t_ms() >= kth - 1e-9 {
                        Ok(())
                    } else {
                        Err(format!(
                            "with={} base={} kth={kth}",
                            o.t_ms(),
                            base.t_ms()
                        ))
                    }
                }
            }
        },
    );
}

/// Policy: mitigation latency is monotone in the threshold — a lower
/// waiting threshold never yields a *later* completion (paper §6.2).
#[test]
fn prop_policy_threshold_monotone() {
    forall(
        0x7472,
        400,
        |rng| {
            let n = gen::usize_in(rng, 2, 8);
            let data = gen::arrivals(rng, n, 0);
            let parity = rng.range(1.0, 1000.0);
            let t1 = rng.range(0.0, 800.0);
            let t2 = t1 + rng.range(0.0, 400.0);
            (data, parity, t1, t2)
        },
        |(data, parity, t1, t2)| {
            let lo = policy::resolve(data, Some(*parity), *t1).t_ms();
            let hi = policy::resolve(data, Some(*parity), *t2).t_ms();
            if lo <= hi + 1e-9 {
                Ok(())
            } else {
                Err(format!("t({t1})={lo} > t({t2})={hi}"))
            }
        },
    );
}

/// Grouped parity: a failure pattern is recoverable iff every group has
/// at most one failure — and then resolve_grouped agrees with the static
/// `cdc::recoverable` predicate.
#[test]
fn prop_grouped_matches_recoverable_predicate() {
    forall(
        0x6e0d,
        300,
        |rng| {
            let n = gen::usize_in(rng, 2, 9);
            let gsize = gen::usize_in(rng, 1, n);
            let n_fail = rng.below(n + 1).min(4);
            let data = gen::arrivals(rng, n, n_fail);
            (n, gsize, data)
        },
        |(n, gsize, data)| {
            let groups = cdc::parity_groups(*n, *gsize).unwrap();
            let parities: Vec<f64> = groups.iter().map(|_| 10.0).collect();
            let failed: Vec<usize> = data
                .iter()
                .enumerate()
                .filter(|(_, t)| t.is_infinite())
                .map(|(i, _)| i)
                .collect();
            let want = cdc::recoverable(&groups, &failed);
            let got = !matches!(
                policy::resolve_grouped(data, &parities, &groups, 0.0),
                GroupedOutcome::Lost
            );
            if want == got {
                Ok(())
            } else {
                Err(format!("predicate={want} policy={got} failed={failed:?}"))
            }
        },
    );
}

/// Partition: balanced ranges always cover [0, total) contiguously with
/// sizes differing by ≤ 1 — the paper's balanced-assignment requirement.
#[test]
fn prop_balanced_ranges() {
    forall(
        0xba1a,
        500,
        |rng| {
            let total = gen::usize_in(rng, 1, 5000);
            let parts = gen::usize_in(rng, 1, 16);
            (total, parts)
        },
        |(total, parts)| {
            let r = balanced_ranges(*total, *parts);
            if r.len() != *parts {
                return Err("wrong part count".into());
            }
            if r[0].0 != 0 || r.last().unwrap().1 != *total {
                return Err("doesn't cover".into());
            }
            for w in r.windows(2) {
                if w[0].1 != w[1].0 {
                    return Err("not contiguous".into());
                }
            }
            let sizes: Vec<usize> = r.iter().map(|(a, b)| b - a).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            if mx - mn <= 1 {
                Ok(())
            } else {
                Err(format!("imbalanced: {sizes:?}"))
            }
        },
    );
}

/// Coverage: hybrid CDC+2MR dominates 2MR for every deployment shape and
/// budget, and both are monotone in the budget.
#[test]
fn prop_coverage_domination() {
    forall(
        0xc07e,
        300,
        |rng| {
            let n_mp = rng.below(4);
            let mp: Vec<usize> = (0..n_mp).map(|_| gen::usize_in(rng, 2, 8)).collect();
            let singles = rng.below(8);
            (mp, singles.max(1))
        },
        |(mp, singles)| {
            let dep = Deployment::new("p", mp.clone(), *singles);
            let n = dep.total_devices();
            let mut prev2 = -1.0;
            let mut prevh = -1.0;
            for extra in 0..=n + 2 {
                let c2 = dep.coverage_2mr(extra);
                let ch = dep.coverage_cdc_2mr(extra);
                if ch + 1e-12 < c2 {
                    return Err(format!("2MR beat hybrid at extra={extra}"));
                }
                if c2 < prev2 - 1e-12 || ch < prevh - 1e-12 {
                    return Err("coverage not monotone".into());
                }
                prev2 = c2;
                prevh = ch;
            }
            Ok(())
        },
    );
}

/// JSON: parse(serialize(v)) == v for random JSON trees.
#[test]
fn prop_json_roundtrip() {
    fn random_value(rng: &mut Pcg32, depth: usize) -> Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.bernoulli(0.5)),
            // Use representable-exact values to avoid float formatting noise.
            2 => Value::Num((rng.below(1_000_000) as f64) / 64.0),
            3 => {
                let n = rng.below(8);
                Value::Str(
                    (0..n)
                        .map(|_| {
                            let c = rng.below(96) as u8 + 32;
                            c as char
                        })
                        .collect(),
                )
            }
            4 => Value::Arr(
                (0..rng.below(4)).map(|_| random_value(rng, depth - 1)).collect(),
            ),
            _ => Value::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), random_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall(
        0x150f,
        300,
        |rng| random_value(rng, 3),
        |v| {
            let s = v.to_string_compact();
            let back = Value::parse(&s).map_err(|e| format!("{e} in {s}"))?;
            if &back == v {
                Ok(())
            } else {
                Err(format!("roundtrip mismatch: {s}"))
            }
        },
    );
}
