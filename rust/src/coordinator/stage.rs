//! The reusable per-layer execution unit of the coordinator.
//!
//! A [`Stage`] is the static plan of one model layer: either a local
//! merge-point op (pool/flatten/gap — negligible cost, no occupancy) or a
//! distributed weighted layer with its shard→device assignment, CDC
//! parity / 2MR replica tasks, and cost model. Both the single-shot
//! `Session::infer` and the pipelined `coordinator::serve` engine drive
//! requests through the same stages: **dispatch** (fan the input out to
//! the stage's devices, updating the device-occupancy ledger) and
//! **resolve** (gathered completions → arrival policy → CDC/2MR recovery
//! → merge). Keeping dispatch/resolve free of any notion of "the current
//! request" is what lets many requests occupy different stages at once.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::cdc;
use crate::error::{Error, Result};
use crate::fleet::{Completion, Device, NetConfig, WorkOrder};
use crate::partition::LayerPlan;
use crate::runtime::manifest::LayerManifest;
use crate::tensor::Tensor;

use super::policy;
use super::LayerTrace;

/// One pipeline stage: the static execution plan of one model layer.
pub struct Stage {
    pub(crate) kind: StageKind,
}

/// How the stage's layer executes.
pub(crate) enum StageKind {
    /// Merge-point op (pool/flatten/gap) — negligible cost.
    Local { layer_idx: usize },
    /// Distributed (possibly d=1) weighted layer.
    Dist(DistStage),
}

impl Stage {
    /// True for distributed (occupancy-holding) stages.
    pub fn is_distributed(&self) -> bool {
        matches!(self.kind, StageKind::Dist(_))
    }

    /// Index of the layer this stage executes.
    pub fn layer_idx(&self) -> usize {
        match &self.kind {
            StageKind::Local { layer_idx } => *layer_idx,
            StageKind::Dist(d) => d.layer_idx,
        }
    }
}

/// A distributed stage's plan and cost model.
pub(crate) struct DistStage {
    pub layer_idx: usize,
    /// The split plan (exposed via `Session::layer_plans`).
    pub plan: LayerPlan,
    /// (device, task id) per data shard.
    pub data: Vec<(usize, u64)>,
    /// CDC parity devices: (device, task id, covered shard indices).
    pub parities: Vec<(usize, u64, Vec<usize>)>,
    /// 2MR replicas: (device, task id) aligned with `data`.
    pub replicas: Vec<(usize, u64)>,
    /// Fused-activation artifact in use (non-CDC fast path)?
    pub fused_relu: bool,
    /// Expected service time (ms) for the threshold gate.
    pub expected_ms: f64,
    pub request_bytes: u64,
    /// Per-task compute cost (uniform across a layer's shards) — drives
    /// the device-occupancy ledger.
    pub macs: u64,
}

/// Bookkeeping for one dispatched (stage, request) pair.
pub(crate) struct PendingStage {
    /// Completions to gather before the stage can resolve.
    pub n_expected: usize,
}

/// Outcome of resolving one stage for one request.
pub(crate) enum StageOutcome {
    /// Stage completed; the merged activation moves to the next stage.
    Done {
        t_done: f64,
        output: Tensor,
        trace: LayerTrace,
    },
    /// Unrecoverable shard loss — the request is lost at this layer.
    Lost,
}

impl DistStage {
    /// Group this stage's tasks per device (a device with several tasks —
    /// e.g. after failover — runs them serially within one order).
    fn orders(&self) -> BTreeMap<usize, Vec<u64>> {
        let mut orders: BTreeMap<usize, Vec<u64>> = BTreeMap::new();
        let all_tasks = self
            .data
            .iter()
            .copied()
            .chain(self.parities.iter().map(|(d, t, _)| (*d, *t)))
            .chain(self.replicas.iter().copied());
        for (dev, task) in all_tasks {
            orders.entry(dev).or_default().push(task);
        }
        orders
    }

    /// Fan one request's input out to the stage's devices at virtual time
    /// `t_enter`, serialising compute through the per-device occupancy
    /// ledger `device_free` (busy-until, ms).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn dispatch(
        &self,
        devices: &[Device],
        net: &NetConfig,
        rate_macs_per_ms: f64,
        req: u64,
        input: Arc<Tensor>,
        t_enter: f64,
        device_free: &mut [f64],
    ) -> Result<PendingStage> {
        let orders = self.orders();
        let n_expected: usize = orders.values().map(|v| v.len()).sum();
        for (dev, tasks) in &orders {
            let not_before = device_free[*dev];
            // Mirror the device's own arithmetic: compute starts at
            // max(t_enter + request leg, not_before) and runs the order's
            // tasks back to back.
            let req_net = net.sample_request(self.request_bytes);
            let start = (t_enter + req_net).max(not_before);
            device_free[*dev] =
                start + (tasks.len() as u64 * self.macs) as f64 / rate_macs_per_ms;
            devices[*dev].dispatch(WorkOrder {
                req,
                tasks: tasks.clone(),
                input: input.clone(),
                request_bytes: self.request_bytes,
                t_dispatch_ms: t_enter,
                not_before_ms: not_before,
            })?;
        }
        Ok(PendingStage { n_expected })
    }

    /// Resolve a fully-gathered stage: decide *when* the layer completed
    /// and *how* (pure policy layer), reconstruct any missing shard from
    /// its parity group, and merge shard outputs into the layer output.
    pub(crate) fn resolve(
        &self,
        layer: &LayerManifest,
        by_task: &BTreeMap<u64, Completion>,
        t_enter: f64,
        threshold_factor: f64,
    ) -> Result<StageOutcome> {
        let data_t: Vec<f64> = self
            .data
            .iter()
            .map(|(_, t)| by_task[t].t_arrival_ms)
            .collect();
        let threshold = if threshold_factor.is_finite() {
            t_enter + threshold_factor * self.expected_ms
        } else {
            f64::INFINITY
        };

        // Normalise every redundancy mode into (t_ms, missing data-shard
        // indices to reconstruct, trace kind).
        let (t_ms, missing, kind) = if !self.replicas.is_empty() {
            let rep_t: Vec<f64> = self
                .replicas
                .iter()
                .map(|(_, t)| by_task[t].t_arrival_ms)
                .collect();
            match policy::resolve_2mr(&data_t, &rep_t) {
                policy::Outcome::Lost => return Ok(StageOutcome::Lost),
                o => (o.t_ms(), Vec::new(), "all_data"),
            }
        } else if !self.parities.is_empty() {
            let par_t: Vec<f64> = self
                .parities
                .iter()
                .map(|(_, t, _)| by_task[t].t_arrival_ms)
                .collect();
            let groups: Vec<Vec<usize>> =
                self.parities.iter().map(|(_, _, g)| g.clone()).collect();
            match policy::resolve_grouped(&data_t, &par_t, &groups, threshold) {
                policy::GroupedOutcome::Lost => return Ok(StageOutcome::Lost),
                policy::GroupedOutcome::Ok { t_ms, missing } => {
                    let kind = if missing.is_empty() { "all_data" } else { "recovered" };
                    (t_ms, missing, kind)
                }
            }
        } else {
            match policy::resolve(&data_t, None, f64::INFINITY) {
                policy::Outcome::Lost => return Ok(StageOutcome::Lost),
                o => (o.t_ms(), Vec::new(), "all_data"),
            }
        };

        // Materialise shard outputs (decode the missing ones from their
        // parity group: parity − Σ received — the paper's
        // close-to-zero-latency subtraction).
        let mut parts: Vec<Option<Tensor>> = self
            .data
            .iter()
            .map(|(_, t)| by_task[t].result.clone())
            .collect();
        // 2MR: fill from the replica when the primary is lost.
        for (i, (_, rt)) in self.replicas.iter().enumerate() {
            if parts[i].is_none() {
                parts[i] = by_task[rt].result.clone();
            }
        }
        for &mi in &missing {
            let (_, ptask, cover) = self
                .parities
                .iter()
                .find(|(_, _, g)| g.contains(&mi))
                .expect("recovered shard must be covered");
            let parity_out = by_task[ptask]
                .result
                .clone()
                .ok_or_else(|| Error::Fleet("parity result lost".into()))?;
            let received: Vec<Tensor> = cover
                .iter()
                .filter(|&&i| i != mi)
                .map(|&i| {
                    parts[i]
                        .clone()
                        .ok_or_else(|| Error::Fleet("covered shard lost".into()))
                })
                .collect::<Result<Vec<_>>>()?;
            let refs: Vec<&Tensor> = received.iter().collect();
            parts[mi] = Some(cdc::decode(&parity_out, &refs)?);
        }
        let out: Vec<Tensor> = parts
            .into_iter()
            .enumerate()
            .map(|(i, p)| {
                p.ok_or_else(|| Error::Fleet(format!("shard {i} unexpectedly lost")))
            })
            .collect::<Result<Vec<_>>>()?;

        // Merge: concat + trim padding + deferred epilogue.
        let refs: Vec<&Tensor> = out.iter().collect();
        let mut merged = if layer.kind == "fc" {
            Tensor::concat0(&refs)?.take_rows(layer.m)?
        } else {
            let cat = Tensor::concat_channels(&refs)?;
            cat.take_channels(0, layer.k)?
        };
        if layer.relu && !self.fused_relu {
            merged.relu();
        }
        if layer.kind == "conv" && layer.pool > 0 {
            merged = merged.maxpool(layer.pool, layer.pool)?;
        }

        let trace = LayerTrace {
            layer: layer.name.clone(),
            t_start_ms: t_enter,
            t_done_ms: t_ms,
            outcome: kind,
            recovered_shard: missing.first().copied(),
            data_arrivals_ms: data_t,
            aux_arrivals_ms: self
                .parities
                .iter()
                .map(|(_, t, _)| by_task[t].t_arrival_ms)
                .chain(self.replicas.iter().map(|(_, t)| by_task[t].t_arrival_ms))
                .collect(),
        };
        Ok(StageOutcome::Done { t_done: t_ms, output: merged, trace })
    }
}

/// Apply a merge-point (local) layer — free in the timing model.
pub(crate) fn apply_local(layer: &LayerManifest, cur: Tensor) -> Result<Tensor> {
    match layer.kind.as_str() {
        "maxpool" => cur.maxpool(layer.pool, layer.pool),
        "flatten" => Ok(cur.flatten_col()),
        "gap" => cur.gap(),
        other => Err(Error::Config(format!("unexpected local layer {other}"))),
    }
}
