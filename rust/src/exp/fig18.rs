//! Fig. 18 — tolerating multiple failures with overlapping partial-sum
//! parity groups.
//!
//! Three fc2048 setups in increasing tolerance: no parity, one parity over
//! all four shards (§5 scheme: 1 failure), and two parities over groups of
//! two (the paper's last setup: up to 2 failures, one per group — "almost
//! complete" coverage; two failures in one group need Hamming-style codes).
//! We inject every failure pattern and measure the fraction of requests
//! served.

use crate::coordinator::{Redundancy, Session, SessionConfig, SplitSpec};
use crate::error::Result;
use crate::fleet::FailurePlan;
use crate::json::{obj, Value};
use crate::rng::Pcg32;
use crate::tensor::Tensor;

use super::{print_table, ExpCtx};

/// One measured setup.
#[derive(Debug)]
pub struct Setup {
    pub label: &'static str,
    pub redundancy: Redundancy,
    /// survived[f] = fraction of requests served with f injected failures
    /// (averaged over failure patterns).
    pub survived: Vec<f64>,
}

fn cfg_for(ctx: &ExpCtx, red: Redundancy) -> SessionConfig {
    let mut cfg = SessionConfig::new("fc2048");
    cfg.n_devices = 4;
    cfg.seed = ctx.seed;
    cfg.splits.insert("fc".into(), SplitSpec { d: 4, redundancy: red });
    cfg
}

/// All k-subsets of 0..n (n is tiny here).
fn subsets(n: usize, k: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    let mut cur = Vec::new();
    fn rec(start: usize, n: usize, k: usize, cur: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if cur.len() == k {
            out.push(cur.clone());
            return;
        }
        for i in start..n {
            cur.push(i);
            rec(i + 1, n, k, cur, out);
            cur.pop();
        }
    }
    rec(0, n, k, &mut cur, &mut out);
    out
}

/// Run the study.
pub fn run(ctx: &ExpCtx) -> Result<Vec<Setup>> {
    let setups = [
        ("no parity", Redundancy::None),
        ("1 parity (all shards)", Redundancy::Cdc),
        ("2 parities (groups of 2)", Redundancy::CdcGrouped(2)),
    ];
    let reqs_per_pattern = if ctx.quick { 3 } else { 10 };
    let mut results = Vec::new();
    let mut rows = Vec::new();
    for (label, red) in setups {
        let mut survived = Vec::new();
        for f in 0..=2usize {
            let patterns = subsets(4, f);
            let mut ok = 0usize;
            let mut total = 0usize;
            for pat in &patterns {
                let mut session = Session::start(&ctx.artifacts, cfg_for(ctx, red))?;
                for &dev in pat {
                    session.set_failure(dev, FailurePlan::PermanentAt(0))?;
                }
                let mut rng = Pcg32::seeded(ctx.seed ^ (f as u64) << 8);
                for _ in 0..reqs_per_pattern {
                    total += 1;
                    let x = Tensor::randn(vec![2048], &mut rng);
                    match session.infer(&x) {
                        Ok(_) => ok += 1,
                        Err(_) => session.drain(),
                    }
                }
            }
            survived.push(ok as f64 / total as f64);
        }
        rows.push(vec![
            label.to_string(),
            format!("{:.0}%", survived[0] * 100.0),
            format!("{:.0}%", survived[1] * 100.0),
            format!("{:.0}%", survived[2] * 100.0),
        ]);
        results.push(Setup { label, redundancy: red, survived });
    }

    println!("\n=== Fig. 18: tolerating multiple failures (fc2048, 4 shards) ===");
    print_table(&["setup", "0 failures", "1 failure", "2 failures"], &rows);
    println!(
        "(paper: grouped parities tolerate one failure per group — partial \
         coverage of 2 failures; full 2-failure correction needs \
         Hamming-style codes)"
    );

    let json: Vec<Value> = results
        .iter()
        .map(|s| {
            obj(vec![
                ("setup", Value::Str(s.label.into())),
                (
                    "survived",
                    Value::Arr(s.survived.iter().map(|&v| Value::Num(v)).collect()),
                ),
            ])
        })
        .collect();
    ctx.write_result(
        "fig18",
        &obj(vec![
            ("experiment", Value::Str("fig18_multi_failure".into())),
            ("setups", Value::Arr(json)),
        ]),
    )?;
    Ok(results)
}
