//! Length-prefixed binary wire protocol for the TCP transport.
//!
//! Every message is one **frame**:
//!
//! ```text
//! [kind: u8] [len: u32 LE] [payload: len bytes]
//! ```
//!
//! Payload fields are little-endian scalars, UTF-8 strings with a u16
//! length prefix, and tensors as `rank:u8, dims:u32×rank, data:f32-LE`.
//! The codec is hand-rolled (zero external deps) and **hardened**:
//! every read is bounds-checked against the declared payload, frame
//! lengths are capped at [`MAX_FRAME_LEN`] *before* any allocation,
//! tensor element counts are capped at [`MAX_TENSOR_ELEMS`] and must
//! exactly match the bytes on the wire, and trailing payload bytes are
//! rejected. Malformed input of any shape produces an [`Error::Wire`]
//! value — never a panic, never an attacker-sized allocation.
//!
//! Frame kinds (coordinator → worker unless noted):
//!
//! | kind | frame       | payload                                        |
//! |------|-------------|------------------------------------------------|
//! | 0x01 | Hello       | magic u32, proto u16, seed u64, device u32     |
//! | 0x02 | HelloAck    | proto u16 (worker → coordinator)               |
//! | 0x03 | Deploy      | n u32, n × task(id, artifact, macs, reply_bytes, precision u8, weights, b) |
//! | 0x04 | Undeploy    | n u32, n × id u64                              |
//! | 0x05 | Work        | req u64, n u32, n × task u64, batch u32, input |
//! | 0x06 | SetFailure  | tag u8 (+ u64 / f64)                           |
//! | 0x07 | SetNet      | enabled u8, 8 × f64 NetConfig fields           |
//! | 0x08 | SetRate     | macs_per_ms f64                                |
//! | 0x09 | Shutdown    | (empty)                                        |
//! | 0x0A | Reply       | req u64, task u64, ok u8 [, tensor] (worker →) |
//! | 0x0B | Register    | magic u32, proto u16, macs_per_ms f64, caps u32 (worker →) |
//! | 0x0C | RegisterAck | proto u16, device u32, seed u64                |
//! | 0x0D | Heartbeat   | nonce u64                                      |
//! | 0x0E | HeartbeatAck| nonce u64 [, n u8, n × (id u8, value u64)] (worker →) |
//! | 0x0F | Leave       | (empty) (worker → coordinator)                 |
//!
//! Kinds 0x0B–0x0F are the live-membership verbs (DESIGN.md §13):
//! `Register`/`RegisterAck` let a fresh worker dial the coordinator's
//! listen port and join the fleet mid-session, `Heartbeat`/
//! `HeartbeatAck` drive the suspicion ladder, and `Leave` asks for a
//! graceful drain.
//!
//! A Deploy task's `weights` field depends on its precision byte
//! (DESIGN.md §15): `0` (f32) carries the weight tensor; `1` (int8)
//! carries `rows u32, cols u32, rows.div_ceil(4) × scale f32,
//! rows×cols × i8` — the quantized form ships directly, about 4×
//! smaller on the wire, and the worker executes it as-is. Packed f32
//! panels are **never** on the wire: their layout is arch-local, so
//! each worker rebuilds them from the f32 tensor at Deploy receipt.

use std::io::{Read, Write};

use crate::error::{Error, Result};
use crate::fleet::{FailurePlan, NetConfig, TaskDef};
use crate::kernels::{QuantWeights, Scratch, QBLOCK_ROWS};
use crate::tensor::Tensor;

/// Protocol version; bumped on any wire-format change. The handshake
/// rejects a peer outside [`MIN_PROTO_VERSION`]`..=`[`PROTO_VERSION`] —
/// see [`proto_mismatch`] for the diagnostic it must produce. Version 2
/// added the live-membership verbs (Register/RegisterAck/Heartbeat/
/// HeartbeatAck/Leave); version 3 added the per-task precision byte to
/// Deploy (int8 weight shards ship quantized); version 4 lets a worker
/// piggyback telemetry counters on `HeartbeatAck` (DESIGN.md §16).
pub const PROTO_VERSION: u16 = 4;

/// Oldest peer protocol this build still speaks. v4 only *adds* an
/// optional trailing counters payload to `HeartbeatAck`, so a v3 peer
/// is negotiated down cleanly: a v4 coordinator accepts v3 workers
/// (their bare acks decode as zero counters), and a v4 worker talking
/// to a v3 coordinator simply never appends the counters.
pub const MIN_PROTO_VERSION: u16 = 3;

/// Whether a peer's announced protocol version is one this build
/// speaks ([`MIN_PROTO_VERSION`]`..=`[`PROTO_VERSION`]). Every
/// handshake site (Hello/HelloAck/Register/RegisterAck) gates on this
/// and remembers the peer's version for downgrade decisions.
pub fn proto_compatible(peer: u16) -> bool {
    (MIN_PROTO_VERSION..=PROTO_VERSION).contains(&peer)
}

/// Handshake magic ("CDCW" little-endian).
pub const MAGIC: u32 = 0x5743_4443;

/// Hard cap on one frame's payload (256 MiB) — enforced before any
/// allocation, so a hostile length prefix cannot balloon memory. Sized
/// for one task's weight shard (the coordinator deploys one task per
/// frame): a whole unsplit 4096×9216 fc layer is ~151 MiB.
pub const MAX_FRAME_LEN: u32 = 256 * 1024 * 1024;

/// Hard cap on one wire tensor's element count (64M f32 = 256 MiB).
pub const MAX_TENSOR_ELEMS: u64 = 1 << 26;

/// Max tensor rank on the wire.
pub const MAX_TENSOR_RANK: u8 = 8;

/// Max tasks in one Deploy/Undeploy/Work frame.
pub const MAX_TASKS: u32 = 65_536;

const K_HELLO: u8 = 0x01;
const K_HELLO_ACK: u8 = 0x02;
const K_DEPLOY: u8 = 0x03;
const K_UNDEPLOY: u8 = 0x04;
const K_WORK: u8 = 0x05;
const K_SET_FAILURE: u8 = 0x06;
const K_SET_NET: u8 = 0x07;
const K_SET_RATE: u8 = 0x08;
const K_SHUTDOWN: u8 = 0x09;
const K_REPLY: u8 = 0x0a;
const K_REGISTER: u8 = 0x0b;
const K_REGISTER_ACK: u8 = 0x0c;
const K_HEARTBEAT: u8 = 0x0d;
const K_HEARTBEAT_ACK: u8 = 0x0e;
const K_LEAVE: u8 = 0x0f;

/// Capability bit: the worker runs shard compute (always set today;
/// reserved bits let future workers advertise e.g. batching or
/// quantised kernels without a proto bump).
pub const CAP_COMPUTE: u32 = 1;

/// First-class protocol-version mismatch diagnostic: every handshake
/// site (coordinator checking a worker's `Register`/`HelloAck`, worker
/// checking a coordinator's `Hello`/`RegisterAck`) reports through
/// this one constructor so the error names both sides and both
/// versions instead of surfacing as a generic frame error.
pub fn proto_mismatch(peer: &str, local: &str, peer_proto: u16) -> Error {
    Error::Wire(format!(
        "{peer} speaks protocol {peer_proto}, {local} expects \
         {MIN_PROTO_VERSION}..={PROTO_VERSION} — rebuild the older side \
         (the wire format changes with the protocol version)"
    ))
}

/// Worker counter ids piggybacked on `HeartbeatAck` (proto ≥ 4). Ids
/// unknown to the coordinator are skipped, so workers can grow the set
/// without a proto bump.
pub const WCTR_ORDERS: u8 = 0;
/// Work-order replies the worker actually sent.
pub const WCTR_REPLIES: u8 = 1;
/// Replies suppressed by the emulated failure plan (silent drops).
pub const WCTR_DROPPED: u8 = 2;
/// Worker-side execution failures (unknown task / shape error).
pub const WCTR_EXEC_ERRORS: u8 = 3;
/// Number of defined worker counter ids (coordinator-side table size).
pub const WCTR_SLOTS: usize = 4;

/// Cap on counters in one `HeartbeatAck` (hostile-input guard, far
/// above [`WCTR_SLOTS`]).
pub const MAX_ACK_COUNTERS: u32 = 64;

/// One deployed task as carried by a Deploy frame (the on-wire twin of
/// [`TaskDef`], with owned weight payloads). Exactly one of `w` /
/// `quant` is set, per the task's precision byte.
#[derive(Debug, Clone)]
pub struct WireTask {
    /// Session-unique task id.
    pub id: u64,
    /// Artifact name the worker executes for this task.
    pub artifact: String,
    /// Cost-model MACs per batch member (drives worker-side emulation).
    pub macs: u64,
    /// Reply payload bytes per batch member (drives emulation).
    pub reply_bytes: u64,
    /// f32 weight shard (precision byte 0).
    pub w: Option<Tensor>,
    /// Int8 weight shard (precision byte 1) — ships quantized, the
    /// worker executes it in the quantized domain (DESIGN.md §15).
    pub quant: Option<QuantWeights>,
    /// Bias shard (always f32).
    pub b: Tensor,
}

/// A decoded frame (owned payload).
#[derive(Debug, Clone)]
pub enum Frame {
    /// Coordinator handshake: session seed + the device id this
    /// connection plays in the fleet.
    Hello {
        /// Protocol version of the coordinator.
        proto: u16,
        /// Session seed (drives the worker's content-addressed draws).
        seed: u64,
        /// Device id assigned to this worker.
        device: u32,
    },
    /// Worker handshake reply.
    HelloAck {
        /// Protocol version of the worker.
        proto: u16,
    },
    /// Install tasks (weights included) on the worker.
    Deploy {
        /// Tasks to install (id collisions overwrite).
        tasks: Vec<WireTask>,
    },
    /// Remove tasks from the worker.
    Undeploy {
        /// Task ids to remove.
        ids: Vec<u64>,
    },
    /// Execute one work order (the wire twin of `fleet::WorkOrder`).
    Work {
        /// Batch-leader request id.
        req: u64,
        /// Task ids to run, in order.
        tasks: Vec<u64>,
        /// Cross-request micro-batch width carried by `input`.
        batch: u32,
        /// Activation input, `(k, batch)` column-concatenated.
        input: Tensor,
    },
    /// Swap the worker's failure plan (drop emulation).
    SetFailure {
        /// The plan; `Intermittent`/`PermanentAt` make the worker stay
        /// silent on affected replies (real-loss semantics).
        plan: FailurePlan,
    },
    /// Enable/disable worker-side artificial reply delay.
    SetNet {
        /// When false, the profile is cleared (no artificial delay).
        enabled: bool,
        /// Delay profile sampled per reply when enabled.
        net: NetConfig,
    },
    /// Artificial compute-rate emulation (MACs/ms); non-finite or ≤ 0
    /// disables it.
    SetRate {
        /// Emulated device rate.
        macs_per_ms: f64,
    },
    /// Ask the worker process to exit cleanly.
    Shutdown,
    /// One task's result (worker → coordinator). `result: None` means
    /// the worker failed to execute (unknown task / shape error).
    Reply {
        /// Request id echoed from the Work frame.
        req: u64,
        /// Task id echoed from the Work frame.
        task: u64,
        /// The shard output, absent on worker-side failure.
        result: Option<Tensor>,
    },
    /// Membership handshake (worker → coordinator): a fresh worker
    /// dialled the coordinator's listen port and asks to join the
    /// fleet.
    Register {
        /// Protocol version of the joining worker.
        proto: u16,
        /// Announced compute rate (MACs/ms); ≤ 0 or non-finite means
        /// unannounced (the coordinator assumes its configured default).
        macs_per_ms: f64,
        /// Capability bitmask ([`CAP_COMPUTE`] | reserved).
        capabilities: u32,
    },
    /// Membership handshake reply: the coordinator admitted the worker.
    RegisterAck {
        /// Protocol version of the coordinator.
        proto: u16,
        /// Device id the joiner now plays in the fleet.
        device: u32,
        /// Session seed (drives the worker's content-addressed draws).
        seed: u64,
    },
    /// Liveness probe (coordinator → worker), multiplexed on the event
    /// loop's poll timeout.
    Heartbeat {
        /// Echo token (monotonic beat counter).
        nonce: u64,
    },
    /// Liveness probe reply (worker → coordinator). Any inbound frame
    /// counts as proof of life; the ack exists so an otherwise-idle
    /// worker still answers within the suspicion window.
    HeartbeatAck {
        /// The probed nonce, echoed.
        nonce: u64,
        /// Piggybacked worker telemetry (proto ≥ 4): cumulative
        /// `(counter id, value)` pairs ([`WCTR_ORDERS`] …). Empty from
        /// v3 workers, or from v4 workers talking to a v3 coordinator.
        counters: Vec<(u8, u64)>,
    },
    /// Graceful-drain request (worker → coordinator): finish what is in
    /// flight, stop dispatching to this device, re-partition, then
    /// close the connection.
    Leave,
}

// ---------------------------------------------------------------------
// encoding
// ---------------------------------------------------------------------

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn frame(kind: u8) -> Enc {
        // kind + length placeholder; patched in finish().
        Enc { buf: vec![kind, 0, 0, 0, 0] }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        let bytes = s.as_bytes();
        // Always-on: a silently truncated length prefix would corrupt
        // every following byte of the frame.
        assert!(bytes.len() <= u16::MAX as usize, "wire string too long");
        self.u16(bytes.len() as u16);
        self.buf.extend_from_slice(bytes);
    }

    fn tensor(&mut self, t: &Tensor) {
        let shape = t.shape();
        assert!(
            shape.len() <= MAX_TENSOR_RANK as usize,
            "wire tensor rank {} exceeds cap",
            shape.len()
        );
        self.u8(shape.len() as u8);
        for &d in shape {
            self.u32(d as u32);
        }
        for &v in t.data() {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn qweights(&mut self, q: &QuantWeights) {
        let (m, k) = q.dims();
        let elems = (m as u64).saturating_mul(k as u64);
        // Same always-on guard as `tensor`: the encoder must never
        // produce what the decoder rejects.
        assert!(elems <= MAX_TENSOR_ELEMS, "wire int8 weights of {elems} elements exceed cap");
        self.u32(m as u32);
        self.u32(k as u32);
        for &s in q.scales() {
            self.f32(s);
        }
        // i8 → u8 is a bit-level reinterpretation, not a value cast.
        self.buf.extend(q.data().iter().map(|&v| v as u8));
    }

    fn finish(mut self) -> Vec<u8> {
        let len = self.buf.len() - 5;
        // Always-on: an encoder producing what the decoder rejects would
        // kill the connection with a misleading symptom (and ≥ 4 GiB
        // would wrap the u32 prefix, corrupting the stream). Callers
        // shipping user-sized payloads (deploy) pre-check and surface a
        // proper Error before encoding.
        assert!(
            len as u64 <= MAX_FRAME_LEN as u64,
            "encoded frame of {len} bytes exceeds the wire cap {MAX_FRAME_LEN}"
        );
        self.buf[1..5].copy_from_slice(&(len as u32).to_le_bytes());
        self.buf
    }
}

/// Encode a Hello handshake frame.
pub fn hello(seed: u64, device: u32) -> Vec<u8> {
    let mut e = Enc::frame(K_HELLO);
    e.u32(MAGIC);
    e.u16(PROTO_VERSION);
    e.u64(seed);
    e.u32(device);
    e.finish()
}

/// Encode a HelloAck handshake reply.
pub fn hello_ack() -> Vec<u8> {
    let mut e = Enc::frame(K_HELLO_ACK);
    e.u16(PROTO_VERSION);
    e.finish()
}

/// Encode a Deploy frame from coordinator-side task definitions (the
/// `Arc`'d weight shards are serialised by value). A quantized task
/// ships its int8 form (precision byte 1) instead of the f32 tensor;
/// packed panels are arch-local and never serialised.
pub fn deploy(tasks: &[TaskDef]) -> Vec<u8> {
    let mut e = Enc::frame(K_DEPLOY);
    e.u32(tasks.len() as u32);
    for t in tasks {
        e.u64(t.id);
        e.str(&t.artifact);
        e.u64(t.macs);
        e.u64(t.reply_bytes);
        match &t.quant {
            Some(q) => {
                e.u8(1);
                e.qweights(q);
            }
            None => {
                e.u8(0);
                e.tensor(t.w.as_ref());
            }
        }
        e.tensor(t.b.as_ref());
    }
    e.finish()
}

/// Encode an Undeploy frame.
pub fn undeploy(ids: &[u64]) -> Vec<u8> {
    let mut e = Enc::frame(K_UNDEPLOY);
    e.u32(ids.len() as u32);
    for &id in ids {
        e.u64(id);
    }
    e.finish()
}

/// Encode a Work frame (the input tensor is borrowed — dispatch never
/// clones the activation payload to serialise it).
pub fn work(req: u64, tasks: &[u64], batch: usize, input: &Tensor) -> Vec<u8> {
    let mut e = Enc::frame(K_WORK);
    e.u64(req);
    e.u32(tasks.len() as u32);
    for &t in tasks {
        e.u64(t);
    }
    e.u32(batch.max(1) as u32);
    e.tensor(input);
    e.finish()
}

/// Encode a SetFailure frame.
pub fn set_failure(plan: &FailurePlan) -> Vec<u8> {
    let mut e = Enc::frame(K_SET_FAILURE);
    match plan {
        FailurePlan::None => e.u8(0),
        FailurePlan::PermanentAt(at) => {
            e.u8(1);
            e.u64(*at);
        }
        FailurePlan::Intermittent(p) => {
            e.u8(2);
            e.f64(*p);
        }
    }
    e.finish()
}

/// Encode a SetNet frame.
pub fn set_net(enabled: bool, net: &NetConfig) -> Vec<u8> {
    let mut e = Enc::frame(K_SET_NET);
    e.u8(enabled as u8);
    e.f64(net.base_ms);
    e.f64(net.bandwidth_mbps);
    e.f64(net.p_fast);
    e.f64(net.lognorm_mu);
    e.f64(net.lognorm_sigma);
    e.f64(net.pareto_xm);
    e.f64(net.pareto_alpha);
    e.f64(net.max_ms);
    e.finish()
}

/// Encode a SetRate frame.
pub fn set_rate(macs_per_ms: f64) -> Vec<u8> {
    let mut e = Enc::frame(K_SET_RATE);
    e.f64(macs_per_ms);
    e.finish()
}

/// Encode a Shutdown frame.
pub fn shutdown() -> Vec<u8> {
    Enc::frame(K_SHUTDOWN).finish()
}

/// Encode a Reply frame (`None` = worker-side execution failure).
pub fn reply(req: u64, task: u64, result: Option<&Tensor>) -> Vec<u8> {
    let mut e = Enc::frame(K_REPLY);
    e.u64(req);
    e.u64(task);
    match result {
        Some(t) => {
            e.u8(1);
            e.tensor(t);
        }
        None => e.u8(0),
    }
    e.finish()
}

/// Encode a Register membership-handshake frame (worker →
/// coordinator).
pub fn register(macs_per_ms: f64, capabilities: u32) -> Vec<u8> {
    let mut e = Enc::frame(K_REGISTER);
    e.u32(MAGIC);
    e.u16(PROTO_VERSION);
    e.f64(macs_per_ms);
    e.u32(capabilities);
    e.finish()
}

/// Encode a RegisterAck admission reply.
pub fn register_ack(device: u32, seed: u64) -> Vec<u8> {
    let mut e = Enc::frame(K_REGISTER_ACK);
    e.u16(PROTO_VERSION);
    e.u32(device);
    e.u64(seed);
    e.finish()
}

/// Encode a Heartbeat probe.
pub fn heartbeat(nonce: u64) -> Vec<u8> {
    let mut e = Enc::frame(K_HEARTBEAT);
    e.u64(nonce);
    e.finish()
}

/// Encode a bare HeartbeatAck reply (the proto-3 shape, still what a
/// v4 worker sends to a v3 coordinator).
pub fn heartbeat_ack(nonce: u64) -> Vec<u8> {
    let mut e = Enc::frame(K_HEARTBEAT_ACK);
    e.u64(nonce);
    e.finish()
}

/// Encode a HeartbeatAck carrying piggybacked worker counters
/// (proto ≥ 4): cumulative `(id, value)` pairs after the nonce.
pub fn heartbeat_ack_with_counters(nonce: u64, counters: &[(u8, u64)]) -> Vec<u8> {
    assert!(
        counters.len() <= MAX_ACK_COUNTERS as usize,
        "heartbeat ack counter set exceeds the wire cap"
    );
    let mut e = Enc::frame(K_HEARTBEAT_ACK);
    e.u64(nonce);
    e.u8(counters.len() as u8);
    for &(id, value) in counters {
        e.u8(id);
        e.u64(value);
    }
    e.finish()
}

/// Encode a Leave (graceful-drain) frame.
pub fn leave() -> Vec<u8> {
    Enc::frame(K_LEAVE).finish()
}

// ---------------------------------------------------------------------
// decoding
// ---------------------------------------------------------------------

struct Dec<'a, 's> {
    buf: &'a [u8],
    pos: usize,
    /// When present, tensor data is built in buffers taken from this
    /// arena instead of fresh allocations (the event loop's zero-copy
    /// receive path — see `transport::evloop`).
    arena: Option<&'s mut Scratch>,
}

impl<'a, 's> Dec<'a, 's> {
    fn new(buf: &'a [u8]) -> Dec<'a, 's> {
        Dec { buf, pos: 0, arena: None }
    }

    fn new_in(buf: &'a [u8], arena: &'s mut Scratch) -> Dec<'a, 's> {
        Dec { buf, pos: 0, arena: Some(arena) }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::Wire(format!(
                "truncated payload: wanted {n} bytes, {} left",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    // The `try_into().unwrap()`s below cannot panic: `take(n)` either
    // returns exactly `n` bytes or an `Error::Wire`, so the slice→array
    // conversion length always matches. Peer input reaches only the
    // length-checked `take` path.
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u16()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| Error::Wire("non-UTF-8 string on the wire".into()))
    }

    fn tensor(&mut self) -> Result<Tensor> {
        let rank = self.u8()?;
        if rank > MAX_TENSOR_RANK {
            return Err(Error::Wire(format!("tensor rank {rank} exceeds cap")));
        }
        let mut shape = Vec::with_capacity(rank as usize);
        let mut elems: u64 = 1;
        for _ in 0..rank {
            let d = self.u32()? as u64;
            elems = elems.saturating_mul(d);
            if elems > MAX_TENSOR_ELEMS {
                return Err(Error::Wire(format!(
                    "tensor of ≥ {elems} elements exceeds cap {MAX_TENSOR_ELEMS}"
                )));
            }
            shape.push(d as usize);
        }
        let n = elems as usize;
        // Verify the bytes exist on the wire *before* allocating.
        let bytes = self.take(n * 4)?;
        let mut data = match self.arena.as_deref_mut() {
            Some(a) => a.take(n),
            None => vec![0.0; n],
        };
        for (dst, src) in data.iter_mut().zip(bytes.chunks_exact(4)) {
            *dst = f32::from_le_bytes(src.try_into().unwrap());
        }
        Tensor::new(shape, data)
            .map_err(|e| Error::Wire(format!("tensor on the wire: {e}")))
    }

    /// Decode an int8 weight block (`rows u32, cols u32, scales, i8
    /// data`). All caps run before any allocation, mirroring `tensor`.
    fn qweights(&mut self) -> Result<QuantWeights> {
        let m = self.u32()? as usize;
        let k = self.u32()? as usize;
        let elems = (m as u64).saturating_mul(k as u64);
        if elems > MAX_TENSOR_ELEMS {
            return Err(Error::Wire(format!(
                "int8 weights of ≥ {elems} elements exceed cap {MAX_TENSOR_ELEMS}"
            )));
        }
        let n_scales = m.div_ceil(QBLOCK_ROWS);
        // Verify every byte exists on the wire *before* allocating.
        let scale_bytes = self.take(n_scales * 4)?;
        let data_bytes = self.take(elems as usize)?;
        let scales: Vec<f32> = scale_bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let data: Vec<i8> = data_bytes.iter().map(|&b| b as i8).collect();
        QuantWeights::from_parts(m, k, data, scales)
            .map_err(|e| Error::Wire(format!("int8 weights on the wire: {e}")))
    }

    /// Read a `u32` element count, bounds-checked against both an
    /// explicit cap and the bytes actually present (`min_elem_bytes`
    /// per element), before any allocation.
    fn count(&mut self, cap: u32, min_elem_bytes: usize) -> Result<usize> {
        let n = self.u32()?;
        if n > cap {
            return Err(Error::Wire(format!("count {n} exceeds cap {cap}")));
        }
        let need = (n as usize).saturating_mul(min_elem_bytes);
        if self.remaining() < need {
            return Err(Error::Wire(format!(
                "count {n} needs ≥ {need} bytes, {} left",
                self.remaining()
            )));
        }
        Ok(n as usize)
    }

    fn finish(&self) -> Result<()> {
        if self.remaining() != 0 {
            return Err(Error::Wire(format!(
                "{} trailing bytes after payload",
                self.remaining()
            )));
        }
        Ok(())
    }
}

/// Decode one frame from its kind byte and payload.
pub fn decode(kind: u8, payload: &[u8]) -> Result<Frame> {
    decode_with(Dec::new(payload), kind)
}

/// Like [`decode`], but tensor payloads are built in buffers taken
/// from `arena` — the event loop's zero-copy receive path (the serve
/// engine returns consumed buffers through `Transport::reclaim`).
pub fn decode_in(kind: u8, payload: &[u8], arena: &mut Scratch) -> Result<Frame> {
    decode_with(Dec::new_in(payload, arena), kind)
}

fn decode_with(mut d: Dec<'_, '_>, kind: u8) -> Result<Frame> {
    let frame = match kind {
        K_HELLO => {
            let magic = d.u32()?;
            if magic != MAGIC {
                return Err(Error::Wire(format!("bad handshake magic {magic:#x}")));
            }
            Frame::Hello { proto: d.u16()?, seed: d.u64()?, device: d.u32()? }
        }
        K_HELLO_ACK => Frame::HelloAck { proto: d.u16()? },
        K_DEPLOY => {
            // Each task is ≥ 8+2+8+8 + precision byte + 2×(1 byte rank).
            let n = d.count(MAX_TASKS, 28)?;
            let mut tasks = Vec::with_capacity(n);
            for _ in 0..n {
                let id = d.u64()?;
                let artifact = d.str()?;
                let macs = d.u64()?;
                let reply_bytes = d.u64()?;
                let (w, quant) = match d.u8()? {
                    0 => (Some(d.tensor()?), None),
                    1 => (None, Some(d.qweights()?)),
                    t => return Err(Error::Wire(format!("unknown task precision tag {t}"))),
                };
                tasks.push(WireTask {
                    id,
                    artifact,
                    macs,
                    reply_bytes,
                    w,
                    quant,
                    b: d.tensor()?,
                });
            }
            Frame::Deploy { tasks }
        }
        K_UNDEPLOY => {
            let n = d.count(MAX_TASKS, 8)?;
            let mut ids = Vec::with_capacity(n);
            for _ in 0..n {
                ids.push(d.u64()?);
            }
            Frame::Undeploy { ids }
        }
        K_WORK => {
            let req = d.u64()?;
            let n = d.count(MAX_TASKS, 8)?;
            let mut tasks = Vec::with_capacity(n);
            for _ in 0..n {
                tasks.push(d.u64()?);
            }
            let batch = d.u32()?;
            if batch == 0 || batch > MAX_TASKS {
                return Err(Error::Wire(format!("bad batch width {batch}")));
            }
            Frame::Work { req, tasks, batch, input: d.tensor()? }
        }
        K_SET_FAILURE => {
            let plan = match d.u8()? {
                0 => FailurePlan::None,
                1 => FailurePlan::PermanentAt(d.u64()?),
                2 => {
                    let p = d.f64()?;
                    if !(0.0..=1.0).contains(&p) {
                        return Err(Error::Wire(format!("bad drop probability {p}")));
                    }
                    FailurePlan::Intermittent(p)
                }
                t => return Err(Error::Wire(format!("unknown failure tag {t}"))),
            };
            Frame::SetFailure { plan }
        }
        K_SET_NET => {
            let enabled = d.u8()? != 0;
            let net = NetConfig {
                base_ms: d.f64()?,
                bandwidth_mbps: d.f64()?,
                p_fast: d.f64()?,
                lognorm_mu: d.f64()?,
                lognorm_sigma: d.f64()?,
                pareto_xm: d.f64()?,
                pareto_alpha: d.f64()?,
                max_ms: d.f64()?,
            };
            Frame::SetNet { enabled, net }
        }
        K_SET_RATE => Frame::SetRate { macs_per_ms: d.f64()? },
        K_SHUTDOWN => Frame::Shutdown,
        K_REPLY => {
            let req = d.u64()?;
            let task = d.u64()?;
            let result = match d.u8()? {
                0 => None,
                1 => Some(d.tensor()?),
                t => return Err(Error::Wire(format!("unknown reply tag {t}"))),
            };
            Frame::Reply { req, task, result }
        }
        K_REGISTER => {
            let magic = d.u32()?;
            if magic != MAGIC {
                return Err(Error::Wire(format!("bad handshake magic {magic:#x}")));
            }
            Frame::Register {
                proto: d.u16()?,
                macs_per_ms: d.f64()?,
                capabilities: d.u32()?,
            }
        }
        K_REGISTER_ACK => Frame::RegisterAck {
            proto: d.u16()?,
            device: d.u32()?,
            seed: d.u64()?,
        },
        K_HEARTBEAT => Frame::Heartbeat { nonce: d.u64()? },
        K_HEARTBEAT_ACK => {
            let nonce = d.u64()?;
            // Proto-version negotiation lives in the payload shape: a
            // v3 ack ends at the nonce, a v4 ack appends the counter
            // set. One decoder accepts both (DESIGN.md §16).
            let mut counters = Vec::new();
            if d.remaining() > 0 {
                let n = d.u8()?;
                if u32::from(n) > MAX_ACK_COUNTERS {
                    return Err(Error::Wire(format!(
                        "heartbeat ack carries {n} counters, cap {MAX_ACK_COUNTERS}"
                    )));
                }
                counters.reserve(n as usize);
                for _ in 0..n {
                    counters.push((d.u8()?, d.u64()?));
                }
            }
            Frame::HeartbeatAck { nonce, counters }
        }
        K_LEAVE => Frame::Leave,
        k => return Err(Error::Wire(format!("unknown frame kind {k:#x}"))),
    };
    d.finish()?;
    Ok(frame)
}

/// Total encoded length (header + payload) of the frame starting at
/// `buf[0]`, or `Ok(None)` while the 5-byte header is still partial.
/// The cap check runs here, as soon as the header is present, so a
/// hostile length prefix is rejected before any buffering policy acts
/// on it.
pub fn frame_len(buf: &[u8]) -> Result<Option<usize>> {
    if buf.len() < 5 {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[1..5].try_into().unwrap());
    if len > MAX_FRAME_LEN {
        return Err(Error::Wire(format!(
            "frame length {len} exceeds cap {MAX_FRAME_LEN}"
        )));
    }
    Ok(Some(5 + len as usize))
}

/// Decode one frame from the front of `buf` without consuming a
/// stream: `Ok(None)` means the frame's bytes have not all arrived
/// yet; `Ok(Some((frame, used)))` parsed exactly `used` bytes. This is
/// the incremental (receive-buffer) twin of [`read_frame`].
pub fn decode_prefix(buf: &[u8]) -> Result<Option<(Frame, usize)>> {
    match frame_len(buf)? {
        Some(total) if buf.len() >= total => {
            Ok(Some((decode(buf[0], &buf[5..total])?, total)))
        }
        _ => Ok(None),
    }
}

/// [`decode_prefix`] with arena-backed tensor decode (the zero-copy
/// receive path).
pub fn decode_prefix_in(buf: &[u8], arena: &mut Scratch) -> Result<Option<(Frame, usize)>> {
    match frame_len(buf)? {
        Some(total) if buf.len() >= total => {
            Ok(Some((decode_in(buf[0], &buf[5..total], arena)?, total)))
        }
        _ => Ok(None),
    }
}

/// Read one frame from a stream. Returns `Ok(None)` on a clean EOF at a
/// frame boundary; EOF mid-frame, an oversized length prefix, or any
/// malformed payload is an [`Error::Wire`].
pub fn read_frame(r: &mut impl Read) -> Result<Option<Frame>> {
    let mut head = [0u8; 5];
    let mut got = 0;
    while got < head.len() {
        match r.read(&mut head[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None); // clean EOF between frames
                }
                return Err(Error::Wire("EOF inside frame header".into()));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(Error::Wire(format!("read frame header: {e}"))),
        }
    }
    let kind = head[0];
    let len = u32::from_le_bytes(head[1..5].try_into().unwrap());
    if len > MAX_FRAME_LEN {
        return Err(Error::Wire(format!(
            "frame length {len} exceeds cap {MAX_FRAME_LEN}"
        )));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)
        .map_err(|e| Error::Wire(format!("read frame payload ({len} bytes): {e}")))?;
    decode(kind, &payload).map(Some)
}

/// Write one pre-encoded frame to a stream.
pub fn write_frame(w: &mut impl Write, frame_bytes: &[u8]) -> Result<()> {
    w.write_all(frame_bytes)
        .and_then(|_| w.flush())
        .map_err(|e| Error::Wire(format!("write frame: {e}")))
}
