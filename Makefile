# Build entry points. `make artifacts` needs the python toolchain
# (jax + the repo's compile package); everything rust-side builds and
# tests offline without it (see DESIGN.md §3/§7).

ARTIFACTS ?= rust/artifacts

.PHONY: artifacts build test bench bench-gemm bench-gemm-smoke \
        bench-scenarios bench-scenarios-smoke bench-batching \
        bench-batching-smoke bench-transport bench-transport-smoke \
        promote-baselines worker-demo gateway-demo doc fmt clippy

artifacts:
	cd python && python -m compile.aot --out ../$(ARTIFACTS)
	ln -sfn $(ARTIFACTS) artifacts

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

# Kernel sweep: writes the BENCH_gemm.json baseline (naive vs tiled vs
# threaded GFLOP/s). The smoke flavor is the CI kernel-regression guard.
bench-gemm:
	cargo bench --bench gemm_runtime

bench-gemm-smoke:
	GEMM_BENCH_SMOKE=1 GEMM_BENCH_ENFORCE=1 cargo bench --bench gemm_runtime

# Fleet-chaos scenario suite: writes the BENCH_scenarios.json baseline
# (per-scenario rps/p50/p99 for the none/2mr/cdc arms). The smoke flavor
# is the CI robustness-regression guard.
bench-scenarios:
	cargo bench --bench scenario_suite

bench-scenarios-smoke:
	SCENARIO_BENCH_SMOKE=1 cargo bench --bench scenario_suite

# Cross-request micro-batching sweep (DESIGN.md §10): writes the
# BENCH_batching.json baseline (rps per batch width x arrival rate over
# the steady scenario) and fails if batch_max=4 stops beating the
# unbatched engine.
bench-batching:
	cargo bench --bench batching

bench-batching-smoke:
	BATCHING_BENCH_SMOKE=1 cargo bench --bench batching

# Real-TCP loopback serving (DESIGN.md §11–12): spawns worker child
# processes, sweeps fleet widths {4, 16, 64} (asserting O(1)
# coordinator I/O threads across the sweep), SIGKILLs one worker
# mid-run, and writes BENCH_transport.json. The smoke flavor ({4, 16})
# is the CI robustness guard.
bench-transport:
	cargo bench --bench transport_loopback

bench-transport-smoke:
	TRANSPORT_BENCH_SMOKE=1 cargo bench --bench transport_loopback

# Fold downloaded CI bench artifacts (BENCH_*.metrics.json, from the
# bench matrix's uploads) into the committed perf-trajectory seeds under
# rust/baselines/ — then review the diff and commit
# (rust/baselines/README.md). ARTIFACT_DIR defaults to the repo root,
# which also picks up a fresh local bench run.
ARTIFACT_DIR ?= .
promote-baselines:
	scripts/promote_baselines.sh $(ARTIFACT_DIR)

# Start one standalone TCP worker on a fixed port over the synthetic
# artifact set — half of the README's two-terminal quickstart.
worker-demo:
	cargo build --release
	./target/release/cdc-dnn synth --artifacts synth-arts --seed 7
	./target/release/cdc-dnn worker --artifacts synth-arts --listen 127.0.0.1:7070

# HTTP/1.1 serving gateway over an auto-spawned loopback worker fleet
# (DESIGN.md §14): prints GATEWAY_URL, then serves POST /v1/infer and
# the fleet control plane until POST /v1/shutdown (curl quickstart in
# the README).
gateway-demo:
	cargo build --release
	./target/release/cdc-dnn synth --artifacts synth-arts --seed 7
	./target/release/cdc-dnn gateway --artifacts synth-arts \
		--deployment rust/configs/mlp_loopback.json --http 127.0.0.1:8080

# Rustdoc for the whole crate; CI runs this with -D warnings.
doc:
	cargo doc --no-deps

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings
