//! Explicit-SIMD micro-kernels for the blocked GEMM (DESIGN.md §15).
//!
//! One `std::arch` micro-kernel per architecture — AVX2 on x86_64, NEON
//! on aarch64 — each a drop-in replacement for the scalar
//! [`MR`]`×`[`NR`] register tile in `gemm.rs`. Both deliberately use
//! separate multiply + add (never FMA) and accumulate in the same
//! k-ascending order as the scalar micro-kernel, so every SIMD tier is
//! **bit-for-bit identical** to the scalar tiled kernel on every shape
//! (and to the naive oracle whenever the depth fits a single K panel,
//! `k ≤ KC`). That determinism is what lets CDC parity decode by exact
//! subtraction regardless of which tier a device ran.
//!
//! Tier selection is a runtime decision made once per process
//! ([`select`]): `is_x86_feature_detected!("avx2")` on x86_64, NEON
//! (baseline on `aarch64-unknown-linux-gnu`) on aarch64, scalar
//! everywhere else. Setting `CDC_DNN_SIMD=0` (or `off`) forces the
//! scalar tier — the kill switch for A/B runs and for debugging the
//! unsafe blocks.

use std::sync::OnceLock;

use super::gemm::{MR, NR};

// The micro-kernels below hard-code the 4×8 register tile.
const _: () = assert!(MR == 4 && NR == 8, "SIMD micro-kernels assume a 4x8 tile");

/// Which micro-kernel the macro loop dispatches to. `Scalar` is always
/// available; the SIMD variants only exist on their architecture and are
/// only ever constructed after a runtime feature check.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Portable scalar register tile (the PR-2 kernel).
    Scalar,
    /// 8-lane AVX2 tile (x86_64, runtime-detected).
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// 2×4-lane NEON tile (aarch64 baseline).
    #[cfg(target_arch = "aarch64")]
    Neon,
}

impl Tier {
    /// Short label for bench/report attribution.
    pub fn label(self) -> &'static str {
        match self {
            Tier::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            Tier::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            Tier::Neon => "neon",
        }
    }
}

/// True when the `CDC_DNN_SIMD` environment kill-switch disables SIMD.
fn simd_disabled_by_env() -> bool {
    match std::env::var("CDC_DNN_SIMD") {
        Ok(v) => {
            let v = v.trim().to_ascii_lowercase();
            v == "0" || v == "off" || v == "false"
        }
        Err(_) => false,
    }
}

fn detect() -> Tier {
    if simd_disabled_by_env() {
        return Tier::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Tier::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Tier::Neon;
        }
    }
    Tier::Scalar
}

/// The process-wide active tier: detected once, cached. Everything on
/// the serve hot path ([`super::gemm_auto`], the prepacked driver) uses
/// this; benches and tests may pass an explicit [`Tier`] instead.
pub fn select() -> Tier {
    static TIER: OnceLock<Tier> = OnceLock::new();
    *TIER.get_or_init(detect)
}

/// True when a SIMD tier (not `Scalar`) is active.
pub fn simd_available() -> bool {
    select() != Tier::Scalar
}

/// True when the *hardware* supports `tier`, ignoring the environment
/// kill-switch. The tier-explicit GEMM entry points assert this before
/// dispatching into an `unsafe` micro-kernel, so a hand-constructed
/// [`Tier`] can never execute instructions the CPU lacks.
pub fn tier_supported(tier: Tier) -> bool {
    match tier {
        Tier::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => std::arch::is_aarch64_feature_detected!("neon"),
    }
}

/// Label of the active tier: `"avx2"`, `"neon"` or `"scalar"`.
pub fn active_tier() -> &'static str {
    select().label()
}

/// AVX2 micro-kernel: 4 rows × one 8-lane `__m256` accumulator each.
#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2 {
    use super::{MR, NR};
    use std::arch::x86_64::*;

    /// Multiply one packed A strip by one packed B strip and add the
    /// live `mr × nr` corner into C, exactly like the scalar
    /// micro-kernel (same k order, mul+add — no FMA — so results are
    /// bit-identical).
    ///
    /// # Safety
    /// The caller must have verified AVX2 support at runtime. Slice
    /// bounds are asserted here; all loads go through `loadu` so no
    /// alignment is required.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn micro_kernel(
        kc: usize,
        astrip: &[f32],
        bstrip: &[f32],
        c: &mut [f32],
        ldc: usize,
        mr: usize,
        nr: usize,
    ) {
        assert!(astrip.len() >= kc * MR && bstrip.len() >= kc * NR);
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut acc2 = _mm256_setzero_ps();
        let mut acc3 = _mm256_setzero_ps();
        let mut ap = astrip.as_ptr();
        let mut bp = bstrip.as_ptr();
        for _ in 0..kc {
            let bv = _mm256_loadu_ps(bp);
            acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_set1_ps(*ap), bv));
            acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(_mm256_set1_ps(*ap.add(1)), bv));
            acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(_mm256_set1_ps(*ap.add(2)), bv));
            acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(_mm256_set1_ps(*ap.add(3)), bv));
            ap = ap.add(MR);
            bp = bp.add(NR);
        }
        let mut tile = [[0.0f32; NR]; MR];
        _mm256_storeu_ps(tile[0].as_mut_ptr(), acc0);
        _mm256_storeu_ps(tile[1].as_mut_ptr(), acc1);
        _mm256_storeu_ps(tile[2].as_mut_ptr(), acc2);
        _mm256_storeu_ps(tile[3].as_mut_ptr(), acc3);
        for (i, trow) in tile.iter().enumerate().take(mr) {
            let crow = &mut c[i * ldc..i * ldc + nr];
            for (cv, &av) in crow.iter_mut().zip(trow) {
                *cv += av;
            }
        }
    }
}

/// NEON micro-kernel: 4 rows × two 4-lane `float32x4_t` accumulators.
#[cfg(target_arch = "aarch64")]
pub(crate) mod neon {
    use super::{MR, NR};
    use std::arch::aarch64::*;

    /// NEON twin of the AVX2 kernel: same accumulation order, separate
    /// `vmulq`/`vaddq` (no `vfmaq`), bit-identical to the scalar tile.
    ///
    /// # Safety
    /// NEON is baseline on `aarch64-unknown-linux-gnu`, but the caller
    /// still routes through runtime detection. Slice bounds are
    /// asserted here; `vld1q`/`vst1q` are unaligned-safe.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn micro_kernel(
        kc: usize,
        astrip: &[f32],
        bstrip: &[f32],
        c: &mut [f32],
        ldc: usize,
        mr: usize,
        nr: usize,
    ) {
        assert!(astrip.len() >= kc * MR && bstrip.len() >= kc * NR);
        let mut acc: [[float32x4_t; 2]; MR] = [[vdupq_n_f32(0.0); 2]; MR];
        let mut ap = astrip.as_ptr();
        let mut bp = bstrip.as_ptr();
        for _ in 0..kc {
            let b0 = vld1q_f32(bp);
            let b1 = vld1q_f32(bp.add(4));
            for (i, arow) in acc.iter_mut().enumerate() {
                let av = vdupq_n_f32(*ap.add(i));
                arow[0] = vaddq_f32(arow[0], vmulq_f32(av, b0));
                arow[1] = vaddq_f32(arow[1], vmulq_f32(av, b1));
            }
            ap = ap.add(MR);
            bp = bp.add(NR);
        }
        let mut tile = [[0.0f32; NR]; MR];
        for (trow, arow) in tile.iter_mut().zip(&acc) {
            vst1q_f32(trow.as_mut_ptr(), arow[0]);
            vst1q_f32(trow.as_mut_ptr().add(4), arow[1]);
        }
        for (i, trow) in tile.iter().enumerate().take(mr) {
            let crow = &mut c[i * ldc..i * ldc + nr];
            for (cv, &av) in crow.iter_mut().zip(trow) {
                *cv += av;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_labels_are_stable() {
        assert_eq!(Tier::Scalar.label(), "scalar");
        let t = select();
        assert!(matches!(t.label(), "scalar" | "avx2" | "neon"));
        assert_eq!(simd_available(), t != Tier::Scalar);
        assert_eq!(active_tier(), t.label());
    }
}
