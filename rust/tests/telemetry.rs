//! Telemetry integration tests (ISSUE 10): the metrics registry, the
//! trace-span ring, and the Prometheus exposition exercised through
//! real serve runs rather than unit fixtures.
//!
//! - `prometheus_exposition_is_conformant_after_serve`: a sim-transport
//!   run, then a strict structural walk of the exposition text — HELP /
//!   TYPE precede samples, histogram buckets are monotone and agree
//!   with `_count`, every sample line parses.
//! - `trace_ring_wraparound_keeps_recent_spans_intact`: more requests
//!   than the ring holds; old slots are overwritten, retained spans are
//!   complete and uncorrupted, nothing is counted as dropped.
//! - `sigkill_serve_traces_show_reaped_then_recovery`: a real loopback
//!   fleet with a SIGKILL mid-stream; the registry counts the reap and
//!   the recovery, and a retained span shows them in order.

use std::collections::HashMap;
use std::path::Path;

use cdc_dnn::coordinator::{Session, SessionConfig, SplitSpec, Workload};
use cdc_dnn::json::Value;
use cdc_dnn::rng::Pcg32;
use cdc_dnn::tensor::Tensor;
use cdc_dnn::testkit::synth;
use cdc_dnn::transport::loopback::LoopbackFleet;
use cdc_dnn::transport::{TcpConfig, TransportSpec};

fn worker_bin() -> &'static Path {
    Path::new(env!("CARGO_BIN_EXE_cdc-dnn"))
}

/// mlp over 2 data devices, both layers parity-coded (sim transport).
fn sim_cfg() -> SessionConfig {
    let mut cfg = SessionConfig::new(synth::MODEL);
    cfg.n_devices = 2;
    cfg.splits.insert("fc1".into(), SplitSpec::cdc(2));
    cfg.splits.insert("fc2".into(), SplitSpec::cdc(2));
    cfg
}

fn inputs(n: usize, seed: u64) -> Vec<Tensor> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| Tensor::randn(vec![synth::FC1_K], &mut rng)).collect()
}

/// Parse one exposition sample line into (series-with-labels, value).
fn parse_sample(line: &str) -> (String, f64) {
    let sp = line.rfind(' ').unwrap_or_else(|| panic!("bad sample line {line:?}"));
    let v: f64 = line[sp + 1..]
        .parse()
        .unwrap_or_else(|e| panic!("bad value in {line:?}: {e}"));
    (line[..sp].to_string(), v)
}

#[test]
fn prometheus_exposition_is_conformant_after_serve() {
    let arts = synth::build(101).unwrap();
    let mut session = Session::start(&arts.root, sim_cfg()).unwrap();
    let n = 24;
    let report = session.serve(&Workload::closed(inputs(n, 1010), 3)).unwrap();
    assert_eq!(report.throughput.completed, n as u64, "{}", report.line());

    let tel = session.telemetry();
    let text = tel.render_prometheus();

    // Structural walk: every metric's HELP and TYPE lines come before
    // its samples, every sample parses, no NaN/inf leaks.
    let mut typed: HashMap<String, String> = HashMap::new();
    let mut samples: Vec<(String, f64)> = Vec::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let (name, kind) = rest.split_once(' ').unwrap();
            assert!(
                matches!(kind, "counter" | "gauge" | "histogram"),
                "unknown TYPE {kind} for {name}"
            );
            typed.insert(name.to_string(), kind.to_string());
        } else if line.starts_with("# HELP ") {
            continue;
        } else {
            assert!(!line.starts_with('#'), "unknown comment line {line:?}");
            let (series, v) = parse_sample(line);
            assert!(v.is_finite(), "non-finite sample {line:?}");
            let base = series.split('{').next().unwrap();
            let metric = base
                .strip_suffix("_bucket")
                .or_else(|| base.strip_suffix("_sum"))
                .or_else(|| base.strip_suffix("_count"))
                .filter(|m| typed.get(*m).map(String::as_str) == Some("histogram"))
                .unwrap_or(base);
            assert!(
                typed.contains_key(metric),
                "sample {series} has no preceding TYPE line"
            );
            samples.push((series, v));
        }
    }
    let val = |name: &str| -> f64 {
        samples
            .iter()
            .find(|(s, _)| s == name)
            .unwrap_or_else(|| panic!("{name} missing from exposition"))
            .1
    };

    // Registry counters agree with the run.
    assert_eq!(val("cdc_requests_total"), n as f64);
    assert_eq!(val("cdc_completed_total"), n as f64);
    assert_eq!(val("cdc_failed_total"), 0.0);
    assert_eq!(val("trace_spans_dropped_total"), 0.0);
    assert_eq!(val("cdc_request_latency_ms_count"), n as f64);
    assert!(val("cdc_batches_total") > 0.0);

    // Histogram conformance: cumulative buckets are monotone
    // nondecreasing, and the +Inf bucket equals _count.
    for h in ["cdc_request_latency_ms", "cdc_batch_width"] {
        let buckets: Vec<f64> = samples
            .iter()
            .filter(|(s, _)| s.starts_with(&format!("{h}_bucket{{")))
            .map(|&(_, v)| v)
            .collect();
        assert!(!buckets.is_empty(), "{h} emitted no buckets");
        for w in buckets.windows(2) {
            assert!(w[1] >= w[0], "{h} buckets not monotone: {buckets:?}");
        }
        assert_eq!(
            *buckets.last().unwrap(),
            val(&format!("{h}_count")),
            "{h}: le=\"+Inf\" must equal _count"
        );
        assert!(val(&format!("{h}_sum")) >= 0.0);
    }

    // Satellite (a): the report's percentiles come from the same
    // histogram estimator as the live surfaces.
    assert_eq!(report.latency_hist.count() as f64, val("cdc_request_latency_ms_count"));
    let p50 = report.latency_hist.quantile(0.50);
    let p99 = report.latency_hist.quantile(0.99);
    assert!(p50 <= p99, "p50 {p50} > p99 {p99}");
    assert!(p99 <= report.latency_hist.max_ms());
}

#[test]
fn trace_ring_wraparound_keeps_recent_spans_intact() {
    let arts = synth::build(102).unwrap();
    let mut session = Session::start(&arts.root, sim_cfg()).unwrap();
    // More requests than the ring retains (capacity 256): the oldest
    // finished spans are overwritten, never the live ones.
    let n = 300;
    let report = session.serve(&Workload::closed(inputs(n, 1020), 4)).unwrap();
    assert_eq!(report.throughput.completed, n as u64, "{}", report.line());

    let tel = session.telemetry();
    assert_eq!(tel.requests_total.get(), n as u64);
    assert_eq!(tel.completed_total.get(), n as u64);
    // Overwriting a *finished* slot is retention policy, not data loss.
    assert_eq!(tel.traces.dropped(), 0);

    let list = tel.traces.list_json();
    let rows = list.get("traces").unwrap().as_arr().unwrap().to_vec();
    let cap = list.get("ring_capacity").unwrap().as_usize().unwrap();
    assert_eq!(rows.len(), cap, "ring must be full after {n} > {cap} requests");
    for row in &rows {
        let req = row.get("req").unwrap().as_usize().unwrap();
        assert!(
            req >= n - cap,
            "req {req} should have been overwritten by a newer span"
        );
        assert!(!row.get("live").unwrap().as_bool().unwrap(), "req {req} never finished");
        assert_eq!(row.get("outcome").unwrap().as_str().unwrap(), "merged");

        // The retained span is complete: admitted first, merged last,
        // monotone pipeline stamps in between.
        let detail = tel.traces.get_json(req as u64).unwrap();
        let events = detail.get("events").unwrap().as_arr().unwrap().to_vec();
        assert!(events.len() >= 2, "req {req}: {detail:?}");
        let kind = |e: &Value| e.get("kind").unwrap().as_str().unwrap().to_string();
        assert_eq!(kind(&events[0]), "admitted");
        assert_eq!(kind(events.last().unwrap()), "merged");
        let mut last_t = f64::NEG_INFINITY;
        for e in &events {
            let t = e.get("t_ms").unwrap().as_f64().unwrap();
            assert!(t >= last_t, "req {req}: event stamps regress: {detail:?}");
            last_t = t;
        }
    }
    // A scrolled-out id reads as absent, not as someone else's span.
    assert!(tel.traces.get_json(0).is_none());
}

#[test]
fn sigkill_serve_traces_show_reaped_then_recovery() {
    let arts = synth::build(103).unwrap();
    // Emulated ~5 ms/shard compute stretches the stream so the kill
    // lands mid-serving (same harness as transport_loopback).
    let fleet =
        LoopbackFleet::spawn(Some(worker_bin()), &arts.root, 4, Some(20.0)).unwrap();
    let mut cfg = sim_cfg();
    cfg.detection_ms = 200.0;
    cfg.batch_max = 4;
    cfg.batch_wait_ms = 2.0;
    let mut tcp: TcpConfig = fleet.tcp_config();
    tcp.order_deadline_ms = 1_000.0;
    cfg.transport = TransportSpec::Tcp(tcp);
    let mut session = Session::start(&arts.root, cfg).unwrap();

    let n = 120;
    let killer = fleet.kill_after(1, 250);
    let report = session.serve(&Workload::uniform(inputs(n, 1030), 6.0)).unwrap();
    killer.join().unwrap();
    assert_eq!(report.throughput.completed, n as u64, "{}", report.line());
    assert!(report.throughput.recovered > 0, "{}", report.line());

    let tel = session.telemetry();
    // The registry saw the whole story: every request admitted and
    // completed, at least one task reaped, at least one CDC recovery,
    // and the piggybacked worker counters made it home over heartbeats.
    assert_eq!(tel.requests_total.get(), n as u64);
    assert_eq!(tel.completed_total.get(), n as u64);
    assert_eq!(tel.failed_total.get(), 0);
    assert!(tel.reaped_tasks_total.get() > 0, "kill left no reaped tasks");
    assert!(tel.recoveries_total.get() > 0, "kill left no recoveries");
    assert_eq!(tel.recoveries_total.get(), report.throughput.recovered);
    let shared: std::collections::HashMap<&str, u64> =
        tel.shared_counters().into_iter().collect();
    assert!(
        shared.get("worker_replies_total").copied().unwrap_or(0) > 0,
        "worker counters never piggybacked on heartbeat acks: {shared:?}"
    );
    assert!(shared.get("net_rx_frames_total").copied().unwrap_or(0) > 0, "{shared:?}");

    // Some retained span must record the reap on a device lane and the
    // recovery after it — the ISSUE 10 acceptance shape.
    let rows = tel.traces.list_json().get("traces").unwrap().as_arr().unwrap().to_vec();
    let mut saw = false;
    for row in &rows {
        let req = row.get("req").unwrap().as_usize().unwrap() as u64;
        let detail = tel.traces.get_json(req).unwrap();
        let kinds: Vec<String> = detail
            .get("events")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|e| e.get("kind").unwrap().as_str().unwrap().to_string())
            .collect();
        if let Some(i) = kinds.iter().position(|k| k == "reaped") {
            if kinds[i..].iter().any(|k| k == "recovered") {
                saw = true;
            }
        }
    }
    assert!(saw, "no retained span shows reaped followed by recovered");

    // Chrome export over the same ring: a complete-span event per
    // dispatched/replied (or reaped) device pair.
    let chrome = tel.traces.chrome_all();
    let events = chrome.get("traceEvents").unwrap().as_arr().unwrap().to_vec();
    assert!(!events.is_empty());
    assert!(events.iter().any(|e| {
        e.get("ph").unwrap().as_str().unwrap() == "X"
            && e.get("dur").and_then(|d| d.as_f64()).is_ok()
    }));
}
