//! Hand-rolled HTTP/1.1 request parser + response encoder for the serving
//! gateway. Same hardening discipline as `transport::wire`: every length is
//! validated BEFORE any allocation sized by it, malformed input is a typed
//! [`HttpError`] (which maps to a status code) and never a panic, and a
//! buffer that merely hasn't finished arriving yet is [`Parsed::Partial`],
//! not an error.
//!
//! Scope is deliberately the subset the gateway needs: request line +
//! headers + body (Content-Length or chunked), keep-alive semantics for
//! HTTP/1.0 and 1.1. No obs-folding, no trailers, no extensions — those
//! are rejected with a typed 4xx/5xx so a client is told exactly why.

use std::fmt;

/// Hard cap on the request line + header block, bytes (431 beyond this).
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Hard cap on the number of header fields (431 beyond this).
pub const MAX_HEADERS: usize = 64;
/// Hard cap on the request-target length (414 beyond this).
pub const MAX_TARGET_BYTES: usize = 2048;
/// Longest accepted chunk-size line (hex digits + CRLF).
const MAX_CHUNK_LINE: usize = 18;

/// Typed parse failure: an HTTP status plus a human-readable reason.
/// Connections that produce one get the status as a response and close.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpError {
    pub status: u16,
    pub msg: String,
}

impl fmt::Display for HttpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} {}", self.status, self.msg)
    }
}

fn err(status: u16, msg: impl Into<String>) -> HttpError {
    HttpError { status, msg: msg.into() }
}

/// A fully parsed request. Header names are lowercased; values are
/// whitespace-trimmed. `body` is the decoded payload (chunked bodies are
/// already de-chunked).
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub target: String,
    pub http11: bool,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// Whether the connection should stay open after the response,
    /// combining the HTTP-version default with any `Connection` header.
    pub keep_alive: bool,
}

impl Request {
    /// First header value with the given (lowercase) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }
}

/// Outcome of [`parse_request`] on a receive buffer.
#[derive(Debug)]
pub enum Parsed {
    /// One complete request, consuming `consumed` bytes of the buffer.
    Complete { req: Request, consumed: usize },
    /// Not enough bytes yet — read more and call again.
    Partial,
}

fn find_byte(hay: &[u8], needle: u8) -> Option<usize> {
    hay.iter().position(|&b| b == needle)
}

fn is_tchar(b: u8) -> bool {
    // RFC 7230 token chars, minus nothing we care to allow beyond them.
    b.is_ascii_alphanumeric()
        || matches!(
            b,
            b'!' | b'#'
                | b'$'
                | b'%'
                | b'&'
                | b'\''
                | b'*'
                | b'+'
                | b'-'
                | b'.'
                | b'^'
                | b'_'
                | b'`'
                | b'|'
                | b'~'
        )
}

/// Incremental parse of at most one request from the front of `buf`.
/// `max_body` caps the decoded body size (413 beyond it); the cap is
/// checked against declared lengths BEFORE any body allocation.
pub fn parse_request(buf: &[u8], max_body: usize) -> Result<Parsed, HttpError> {
    // ---- split off the head (request line + headers + blank line) ----
    let mut pos = 0usize;
    let mut lines: Vec<&[u8]> = Vec::new();
    let head_end = loop {
        let Some(nl) = find_byte(&buf[pos..], b'\n') else {
            if buf.len() >= MAX_HEAD_BYTES {
                return Err(err(431, "header block exceeds 16 KiB"));
            }
            return Ok(Parsed::Partial);
        };
        let line_end = pos + nl;
        let next = line_end + 1;
        if next > MAX_HEAD_BYTES {
            return Err(err(431, "header block exceeds 16 KiB"));
        }
        let mut line = &buf[pos..line_end];
        if line.last() == Some(&b'\r') {
            line = &line[..line.len() - 1];
        }
        if line.is_empty() {
            break next;
        }
        if lines.len() >= MAX_HEADERS + 1 {
            return Err(err(431, "too many header fields"));
        }
        lines.push(line);
        pos = next;
    };

    let Some((&request_line, header_lines)) = lines.split_first() else {
        return Err(err(400, "empty request"));
    };

    // ---- request line ----
    let mut parts = request_line.split(|&b| b == b' ');
    let (m, t, v) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v), None) if !m.is_empty() && !t.is_empty() => (m, t, v),
        _ => return Err(err(400, "malformed request line")),
    };
    if m.len() > 16 || !m.iter().all(|&b| is_tchar(b)) {
        return Err(err(400, "malformed method"));
    }
    if t.len() > MAX_TARGET_BYTES {
        return Err(err(414, "request target too long"));
    }
    if t[0] != b'/' || !t.iter().all(|&b| (0x21..=0x7e).contains(&b)) {
        return Err(err(400, "malformed request target"));
    }
    let http11 = match v {
        b"HTTP/1.1" => true,
        b"HTTP/1.0" => false,
        _ => return Err(err(505, "only HTTP/1.0 and HTTP/1.1 are supported")),
    };
    let method = String::from_utf8_lossy(m).into_owned();
    let target = String::from_utf8_lossy(t).into_owned();

    // ---- header fields ----
    let mut headers: Vec<(String, String)> = Vec::with_capacity(header_lines.len());
    for &line in header_lines {
        if line[0] == b' ' || line[0] == b'\t' {
            return Err(err(400, "obsolete header line folding is not supported"));
        }
        let Some(colon) = find_byte(line, b':') else {
            return Err(err(400, "header field without ':'"));
        };
        let (name, value) = (&line[..colon], &line[colon + 1..]);
        if name.is_empty() || !name.iter().all(|&b| is_tchar(b)) {
            return Err(err(400, "malformed header name"));
        }
        if !value.iter().all(|&b| b == b'\t' || (0x20..=0x7e).contains(&b)) {
            return Err(err(400, "control byte in header value"));
        }
        let name = String::from_utf8_lossy(name).to_lowercase();
        let value = String::from_utf8_lossy(value).trim().to_string();
        headers.push((name, value));
    }

    // ---- framing: Content-Length xor Transfer-Encoding: chunked ----
    let mut content_length: Option<usize> = None;
    for (n, v) in &headers {
        if n == "content-length" {
            if v.is_empty() || v.len() > 12 || !v.bytes().all(|b| b.is_ascii_digit()) {
                return Err(err(400, "malformed Content-Length"));
            }
            let cl: usize =
                v.parse().map_err(|_| err(400, "malformed Content-Length"))?;
            if let Some(prev) = content_length {
                if prev != cl {
                    return Err(err(400, "conflicting Content-Length fields"));
                }
            }
            content_length = Some(cl);
        }
    }
    let chunked = match headers.iter().find(|(n, _)| n == "transfer-encoding") {
        None => false,
        Some((_, v)) if v.eq_ignore_ascii_case("chunked") => {
            if content_length.is_some() {
                // Request-smuggling shape: refuse outright.
                return Err(err(400, "both Content-Length and Transfer-Encoding"));
            }
            true
        }
        Some(_) => return Err(err(501, "unsupported Transfer-Encoding")),
    };

    // ---- body ----
    let (body, consumed) = if chunked {
        match parse_chunked(&buf[head_end..], max_body)? {
            None => return Ok(Parsed::Partial),
            Some((body, used)) => (body, head_end + used),
        }
    } else {
        let cl = content_length.unwrap_or(0);
        if cl > max_body {
            return Err(err(413, format!("body exceeds {max_body} byte cap")));
        }
        if buf.len() - head_end < cl {
            return Ok(Parsed::Partial);
        }
        (buf[head_end..head_end + cl].to_vec(), head_end + cl)
    };

    // ---- keep-alive ----
    let mut keep_alive = http11;
    if let Some(c) = headers.iter().find(|(n, _)| n == "connection").map(|(_, v)| v) {
        let c = c.to_lowercase();
        if c.split(',').any(|t| t.trim() == "close") {
            keep_alive = false;
        } else if c.split(',').any(|t| t.trim() == "keep-alive") {
            keep_alive = true;
        }
    }

    let req = Request { method, target, http11, headers, body, keep_alive };
    Ok(Parsed::Complete { req, consumed })
}

/// Decode a chunked body from `buf`. Returns `None` when more bytes are
/// needed, `Some((body, consumed))` on a complete body. The running total
/// is capped at `max_body` before each chunk is copied.
fn parse_chunked(
    buf: &[u8],
    max_body: usize,
) -> Result<Option<(Vec<u8>, usize)>, HttpError> {
    let mut p = 0usize;
    let mut body: Vec<u8> = Vec::new();
    loop {
        // Chunk-size line: 1..=8 hex digits, no extensions.
        let Some(nl) = find_byte(&buf[p..], b'\n') else {
            if buf.len() - p > MAX_CHUNK_LINE {
                return Err(err(400, "malformed chunk size line"));
            }
            return Ok(None);
        };
        if nl > MAX_CHUNK_LINE {
            return Err(err(400, "malformed chunk size line"));
        }
        let mut line = &buf[p..p + nl];
        if line.last() == Some(&b'\r') {
            line = &line[..line.len() - 1];
        }
        if line.is_empty() || line.len() > 8 || !line.iter().all(u8::is_ascii_hexdigit)
        {
            return Err(err(400, "malformed chunk size (extensions unsupported)"));
        }
        let mut size = 0usize;
        for &b in line {
            let d = (b as char).to_digit(16).unwrap_or(0) as usize;
            size = size * 16 + d;
        }
        if body.len() + size > max_body {
            return Err(err(413, format!("chunked body exceeds {max_body} byte cap")));
        }
        p += nl + 1;

        if size == 0 {
            // Terminator: an immediate blank line. Anything else would be
            // a trailer section, which we do not accept.
            match buf.get(p) {
                None => return Ok(None),
                Some(b'\n') => return Ok(Some((body, p + 1))),
                Some(b'\r') => match buf.get(p + 1) {
                    None => return Ok(None),
                    Some(b'\n') => return Ok(Some((body, p + 2))),
                    Some(_) => return Err(err(400, "trailers are not supported")),
                },
                Some(_) => return Err(err(400, "trailers are not supported")),
            }
        }

        // Chunk data + its terminating CRLF (LF tolerated).
        if buf.len() - p < size {
            return Ok(None);
        }
        body.extend_from_slice(&buf[p..p + size]);
        p += size;
        match buf.get(p) {
            None => return Ok(None),
            Some(b'\n') => p += 1,
            Some(b'\r') => match buf.get(p + 1) {
                None => return Ok(None),
                Some(b'\n') => p += 2,
                Some(_) => return Err(err(400, "chunk data not CRLF-terminated")),
            },
            Some(_) => return Err(err(400, "chunk data not CRLF-terminated")),
        }
    }
}

/// Canonical reason phrase for the statuses the gateway emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        414 => "URI Too Long",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        505 => "HTTP Version Not Supported",
        _ => "Response",
    }
}

/// Encode a response with an explicit Content-Length (never chunked).
pub fn response(
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> Vec<u8> {
    let mut out = Vec::with_capacity(128 + body.len());
    out.extend_from_slice(
        format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
            status,
            reason(status),
            content_type,
            body.len(),
            if keep_alive { "keep-alive" } else { "close" },
        )
        .as_bytes(),
    );
    out.extend_from_slice(body);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn complete(buf: &[u8]) -> (Request, usize) {
        match parse_request(buf, 1 << 20).expect("parse") {
            Parsed::Complete { req, consumed } => (req, consumed),
            Parsed::Partial => panic!("unexpected partial"),
        }
    }

    fn status_of(buf: &[u8], max_body: usize) -> u16 {
        match parse_request(buf, max_body) {
            Err(e) => e.status,
            Ok(p) => panic!("expected error, got {p:?}"),
        }
    }

    #[test]
    fn simple_get() {
        let (req, used) = complete(b"GET /v1/healthz HTTP/1.1\r\nHost: x\r\n\r\n");
        assert_eq!(req.method, "GET");
        assert_eq!(req.target, "/v1/healthz");
        assert!(req.http11 && req.keep_alive);
        assert_eq!(req.header("host"), Some("x"));
        assert!(req.body.is_empty());
        assert_eq!(used, 37);
    }

    #[test]
    fn post_with_content_length_and_pipelining() {
        let buf =
            b"POST /v1/infer HTTP/1.1\r\nContent-Length: 5\r\n\r\nhelloGET / HTTP/1.1\r\n\r\n";
        let (req, used) = complete(buf);
        assert_eq!(req.body, b"hello");
        // The second pipelined request must be left in the buffer.
        let (req2, _) = complete(&buf[used..]);
        assert_eq!(req2.method, "GET");
    }

    #[test]
    fn chunked_body_decodes() {
        let buf = b"POST /v1/infer HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n4\r\nwiki\r\n5\r\npedia\r\n0\r\n\r\n";
        let (req, used) = complete(buf);
        assert_eq!(req.body, b"wikipedia");
        assert_eq!(used, buf.len());
    }

    #[test]
    fn partial_then_complete() {
        let full = b"POST /x HTTP/1.1\r\nContent-Length: 3\r\n\r\nabc";
        for cut in 0..full.len() {
            match parse_request(&full[..cut], 1 << 20).expect("no error on prefix") {
                Parsed::Partial => {}
                Parsed::Complete { .. } => panic!("complete at cut {cut}"),
            }
        }
        let (req, _) = complete(full);
        assert_eq!(req.body, b"abc");
    }

    #[test]
    fn keep_alive_defaults_and_overrides() {
        let (req, _) = complete(b"GET / HTTP/1.0\r\n\r\n");
        assert!(!req.keep_alive);
        let (req, _) = complete(b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n");
        assert!(req.keep_alive);
        let (req, _) = complete(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n");
        assert!(!req.keep_alive);
    }

    #[test]
    fn typed_errors_not_panics() {
        assert_eq!(status_of(b"\r\n\r\n", 1 << 20), 400);
        assert_eq!(status_of(b"GET\r\n\r\n", 1 << 20), 400);
        assert_eq!(status_of(b"GET / HTTP/2.0\r\n\r\n", 1 << 20), 505);
        assert_eq!(status_of(b"GET x HTTP/1.1\r\n\r\n", 1 << 20), 400);
        assert_eq!(status_of(b"GET / HTTP/1.1\r\nBad\r\n\r\n", 1 << 20), 400);
        assert_eq!(
            status_of(b"POST / HTTP/1.1\r\nContent-Length: 9999999999999\r\n\r\n", 64),
            400
        );
        assert_eq!(
            status_of(b"POST / HTTP/1.1\r\nContent-Length: 100\r\n\r\n", 64),
            413
        );
        assert_eq!(
            status_of(
                b"POST / HTTP/1.1\r\nContent-Length: 3\r\nTransfer-Encoding: chunked\r\n\r\n",
                1 << 20
            ),
            400
        );
        assert_eq!(
            status_of(b"POST / HTTP/1.1\r\nTransfer-Encoding: gzip\r\n\r\n", 1 << 20),
            501
        );
        assert_eq!(
            status_of(
                b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\nff;ext=1\r\n",
                1 << 20
            ),
            400
        );
    }

    #[test]
    fn header_flood_is_431_before_allocation() {
        // A single oversized header block must be refused at the cap.
        let mut buf = b"GET / HTTP/1.1\r\n".to_vec();
        for i in 0..4000 {
            buf.extend_from_slice(format!("X-H{i}: v\r\n").as_bytes());
        }
        buf.extend_from_slice(b"\r\n");
        assert_eq!(status_of(&buf, 1 << 20), 431);
        // And an unterminated head that already exceeds the cap, too.
        let flood = vec![b'A'; MAX_HEAD_BYTES + 1];
        assert_eq!(status_of(&flood, 1 << 20), 431);
    }

    #[test]
    fn chunked_cap_is_checked_before_copy() {
        let buf = b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\nffffffff\r\n";
        assert_eq!(status_of(buf, 1 << 20), 413);
    }

    #[test]
    fn response_roundtrips_through_parser_shape() {
        let r = response(200, "application/json", b"{\"ok\":true}", true);
        let s = String::from_utf8(r).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Length: 11\r\n"));
        assert!(s.ends_with("{\"ok\":true}"));
    }
}
