//! Bench target for Fig. 12 (Case Study I) and Fig. 13 (Case Study II):
//! end-to-end request latency of the AlexNet deployment before failure,
//! after non-CDC failover (expected ≈ 2.4× on the affected path), and
//! under CDC with a failed device (expected ≈ 1×) — plus the recovery
//! *mechanism* cost itself (decode vs re-execution), the paper's
//! "close-to-zero vs restart-everything" comparison.
//!
//! Run with `cargo bench --bench fig12_recovery` after `make artifacts`.

use cdc_dnn::bench::Bench;
use cdc_dnn::cdc;
use cdc_dnn::coordinator::{Session, SessionConfig, SplitSpec};
use cdc_dnn::fleet::{FailurePlan, NetConfig};
use cdc_dnn::rng::Pcg32;
use cdc_dnn::runtime::{Manifest, Runtime};
use cdc_dnn::tensor::Tensor;

fn alexnet_cfg(cdc_on: bool) -> SessionConfig {
    let mut cfg = SessionConfig::new("alexnet");
    cfg.n_devices = 5;
    cfg.net = NetConfig::ideal(); // isolate compute/recovery effects
    cfg.splits.insert(
        "fc6".into(),
        if cdc_on { SplitSpec::cdc(2) } else { SplitSpec::plain(2) },
    );
    for (layer, dev) in [
        ("conv1", 0usize),
        ("conv2", 0),
        ("conv3", 1),
        ("conv4", 1),
        ("conv5", 1),
        ("fc7", 4),
        ("fc8", 4),
    ] {
        cfg.placement.insert(layer.into(), vec![dev]);
    }
    cfg.placement.insert("fc6".into(), vec![2, 3]);
    cfg
}

fn main() {
    let backend = cdc_dnn::runtime::backend_label();
    if !cdc_dnn::testkit::artifacts_available(std::path::Path::new("artifacts")) {
        println!(
            "[skip] fig12_recovery: AOT artifacts absent (would run on \
             backend: {backend})"
        );
        return;
    }
    println!("fig12_recovery: compute backend = {backend}");
    let mut rng = Pcg32::seeded(5);
    let x = Tensor::randn(vec![32, 32, 3], &mut rng);

    // Healthy baseline.
    let mut s = Session::start("artifacts", alexnet_cfg(false)).unwrap();
    s.infer(&x).unwrap();
    Bench::new("case1/healthy_request_wallclock").iters(5, 30).run(|| {
        s.infer(&x).unwrap();
    });
    let healthy_sim = s.infer(&x).unwrap().total_ms;

    // Post-failover: device 3 runs both fc6 shards serially.
    s.set_failure(2, FailurePlan::PermanentAt(0)).unwrap();
    let _ = s.infer(&x);
    s.drain();
    s.failover(2, 3).unwrap();
    let failover_sim = s.infer(&x).unwrap().total_ms;
    Bench::new("case1/failover_request_wallclock").iters(5, 30).run(|| {
        s.infer(&x).unwrap();
    });

    // CDC under failure: no slowdown, no loss.
    let mut sc = Session::start("artifacts", alexnet_cfg(true)).unwrap();
    sc.set_failure(2, FailurePlan::PermanentAt(0)).unwrap();
    let cdc_sim = sc.infer(&x).unwrap().total_ms;
    Bench::new("case2/cdc_failed_device_wallclock").iters(5, 30).run(|| {
        sc.infer(&x).unwrap();
    });

    println!(
        "\nsimulated request latency: healthy={healthy_sim:.1}ms \
         failover={failover_sim:.1}ms ({:.2}x, paper ~2.4x on the affected \
         path) cdc_under_failure={cdc_sim:.1}ms ({:.2}x, paper ~1x)",
        failover_sim / healthy_sim,
        cdc_sim / healthy_sim
    );

    // Recovery mechanism: CDC subtraction vs vanilla re-execution of the
    // missing shard (load weights + GEMM) — §5.2's second benefit.
    let manifest = Manifest::load("artifacts").unwrap();
    let runtime = Runtime::new().unwrap();
    let m = 128usize;
    let parity = Tensor::randn(vec![m, 1], &mut rng);
    let other = Tensor::randn(vec![m, 1], &mut rng);
    Bench::new("recovery/cdc_decode (local subtraction)")
        .iters(100, 1000)
        .run(|| {
            cdc::decode(&parity, &[&other]).unwrap();
        });
    if manifest.artifacts.contains_key("fc_m128_k256_lin") {
        let w = Tensor::randn(vec![128, 256], &mut rng);
        let b = Tensor::randn(vec![128, 1], &mut rng);
        let xi = Tensor::randn(vec![256, 1], &mut rng);
        runtime.execute(&manifest, "fc_m128_k256_lin", &[&w, &b, &xi]).unwrap();
        Bench::new("recovery/vanilla_reexecution (GEMM)").run(|| {
            runtime
                .execute(&manifest, "fc_m128_k256_lin", &[&w, &b, &xi])
                .unwrap();
        });
    } else {
        // Builder fallback when the exact artifact is absent.
        let exe = runtime.build_gemm(128, 256, 1, true, false).unwrap();
        let w = Tensor::randn(vec![128, 256], &mut rng);
        let b = Tensor::randn(vec![128, 1], &mut rng);
        let xi = Tensor::randn(vec![256, 1], &mut rng);
        runtime.run_built(&exe, &[&w, &xi, &b]).unwrap();
        Bench::new("recovery/vanilla_reexecution (GEMM, builder)").run(|| {
            runtime.run_built(&exe, &[&w, &xi, &b]).unwrap();
        });
    }
}
