//! Live telemetry: lock-light metrics registry + per-request trace
//! spans + export rendering (DESIGN.md §16).
//!
//! The serving stack already *proves* the paper's zero-latency-recovery
//! claim after a run (`ServeReport`); this module makes it observable
//! while a fleet is live, without adding locks to the hot path:
//!
//! * [`Counter`] / [`Gauge`] are single `AtomicU64`s updated with
//!   `Ordering::Relaxed` — monotonic event counts need no ordering
//!   relative to other memory, and a scrape that reads mid-update sees
//!   a value that was true a moment ago (exactly what Prometheus
//!   semantics require).
//! * [`Histogram`] is a fixed array of atomic log-spaced buckets with
//!   `merge`, `quantile`, and a Prometheus-exposition snapshot. One
//!   `record` is a handful of relaxed atomic adds — no allocation, no
//!   lock, no sort.
//! * [`trace::TraceRing`] keeps the last [`trace::RING_CAP`] requests'
//!   span events in preallocated slots (zero allocation in steady
//!   state); see [`trace`] for the lifecycle.
//! * [`Telemetry`] is the registry the serve loop, gateway server, and
//!   transport all share (`Arc`), and [`Telemetry::render_prometheus`]
//!   is the hand-rolled `GET /metrics` text — no NaN/Inf ever leaks
//!   into the exposition (the same non-finite rule the JSON control
//!   plane applies via its `num()` helper).
//!
//! Transport-internal counters (bytes, frames, writev rounds, reaper
//! fires, membership transitions, worker counters piggybacked on
//! `HeartbeatAck`) live in transport-owned atomics; the serve loop
//! mirrors them into the registry every pass via
//! [`Telemetry::set_shared_counters`], so `GET /metrics` served from
//! the gateway's HTTP thread never has to reach into the transport.

pub mod trace;

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::json::{obj, Value};

pub use trace::{SpanEvent, TraceRing};

/// Monotonic event counter (relaxed atomics: scrape-consistent, never
/// decreasing, no hot-path synchronisation).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Zeroed counter.
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    /// Add one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value (fleet width, in-flight count).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// Zeroed gauge.
    pub fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    /// Overwrite the value.
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Number of log-spaced histogram buckets.
pub const HIST_BUCKETS: usize = 64;

/// Upper bound of bucket 0 in ms; bucket `i` covers
/// `(bound(i-1), bound(i)]` with `bound(i) = HIST_BASE_MS × √2ⁱ`, so 64
/// buckets span 0.01 ms … ≈8.4 hours at ~±19% relative resolution.
pub const HIST_BASE_MS: f64 = 0.01;

const HIST_GROWTH: f64 = std::f64::consts::SQRT_2;

/// Upper bound (ms) of bucket `i`.
pub fn bucket_bound_ms(i: usize) -> f64 {
    HIST_BASE_MS * HIST_GROWTH.powi(i as i32)
}

fn bucket_index(v_ms: f64) -> usize {
    if !(v_ms > HIST_BASE_MS) {
        // ≤ base, zero, negative, or NaN all land in the first bucket.
        return 0;
    }
    let idx = ((v_ms / HIST_BASE_MS).ln() / HIST_GROWTH.ln()).ceil();
    if idx.is_finite() {
        (idx as usize).min(HIST_BUCKETS - 1)
    } else {
        HIST_BUCKETS - 1
    }
}

/// Sentinel stored in the min tracker while a histogram is empty.
const MIN_EMPTY: u64 = u64::MAX;

/// Lock-free log-bucketed latency histogram (milliseconds).
///
/// `record` is a few relaxed atomic RMWs; `quantile` walks the 64
/// buckets with linear interpolation inside the selected bucket and
/// clamps to the observed min/max, so a single-sample histogram
/// reports that exact sample at every quantile. `merge` folds another
/// histogram in bucket-wise — the property `merge(a,b).quantile ≈`
/// quantile of the concatenated samples holds to bucket resolution.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    /// Sum in integer microseconds (lock-free f64 sums need a CAS loop;
    /// µs resolution is far below bucket resolution anyway).
    sum_us: AtomicU64,
    min_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            min_us: AtomicU64::new(MIN_EMPTY),
            max_us: AtomicU64::new(0),
        }
    }

    /// Record one sample (ms). Non-finite and negative samples clamp
    /// to 0 (they still count — a lost stamp must not skew quantiles
    /// upward by vanishing).
    pub fn record(&self, v_ms: f64) {
        let v = if v_ms.is_finite() && v_ms > 0.0 { v_ms } else { 0.0 };
        let us = (v * 1e3).round().min(u64::MAX as f64 / 2.0) as u64;
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.min_us.fetch_min(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Fold `other`'s samples into `self`, bucket-wise.
    pub fn merge(&self, other: &Histogram) {
        for (dst, src) in self.buckets.iter().zip(other.buckets.iter()) {
            let n = src.load(Ordering::Relaxed);
            if n > 0 {
                dst.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_us.fetch_add(other.sum_us.load(Ordering::Relaxed), Ordering::Relaxed);
        self.min_us.fetch_min(other.min_us.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max_us.fetch_max(other.max_us.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all samples (ms).
    pub fn sum_ms(&self) -> f64 {
        self.sum_us.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// Mean sample (ms); 0 when empty.
    pub fn mean_ms(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_ms() / n as f64
        }
    }

    /// Smallest recorded sample (ms); 0 when empty.
    pub fn min_ms(&self) -> f64 {
        match self.min_us.load(Ordering::Relaxed) {
            MIN_EMPTY => 0.0,
            us => us as f64 / 1e3,
        }
    }

    /// Largest recorded sample (ms); 0 when empty.
    pub fn max_ms(&self) -> f64 {
        self.max_us.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// Quantile estimate (ms) at `q ∈ [0, 1]`: linear interpolation
    /// within the selected log bucket, clamped to the observed
    /// min/max. Empty histograms report 0.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let q = if q.is_finite() { q.clamp(0.0, 1.0) } else { 0.0 };
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        let mut est = bucket_bound_ms(HIST_BUCKETS - 1);
        for (i, b) in self.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n == 0 {
                continue;
            }
            if cum + n >= target {
                let lower = if i == 0 { 0.0 } else { bucket_bound_ms(i - 1) };
                let upper = bucket_bound_ms(i);
                let frac = (target - cum) as f64 / n as f64;
                est = lower + (upper - lower) * frac;
                break;
            }
            cum += n;
        }
        est.clamp(self.min_ms(), self.max_ms())
    }

    /// Cumulative bucket counts paired with their `le` upper bounds —
    /// the Prometheus histogram series shape.
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut cum = 0u64;
        self.buckets
            .iter()
            .enumerate()
            .map(|(i, b)| {
                cum += b.load(Ordering::Relaxed);
                (bucket_bound_ms(i), cum)
            })
            .collect()
    }
}

/// The shared registry: every counter, gauge, and histogram the serving
/// stack exposes, plus the trace ring. One instance per [`Session`],
/// shared (`Arc`) with the gateway's HTTP thread for `GET /metrics` and
/// `GET /v1/traces`.
///
/// [`Session`]: crate::coordinator::Session
#[derive(Debug, Default)]
pub struct Telemetry {
    /// Requests admitted into the pipeline (paced workload + gateway).
    pub requests_total: Counter,
    /// Requests completed with an output.
    pub completed_total: Counter,
    /// Requests failed (a needed shard set was unrecoverable).
    pub failed_total: Counter,
    /// CDC parity recoveries performed (one per recovered layer-stage).
    pub recoveries_total: Counter,
    /// Shard tasks reaped by the straggler gate / connection death
    /// (observed as `t_arrival = ∞` completions in the gather loop).
    pub reaped_tasks_total: Counter,
    /// Shard replies gathered with data.
    pub replies_total: Counter,
    /// Micro-batches formed.
    pub batches_total: Counter,
    /// Requests that entered a batch (`Σ` batch widths).
    pub batched_requests_total: Counter,
    /// Per-device work orders dispatched.
    pub dispatch_orders_total: Counter,
    /// HTTP requests routed by the gateway server.
    pub gateway_requests_total: Counter,
    /// HTTP responses with status ≥ 400.
    pub gateway_errors_total: Counter,
    /// Requests in flight right now.
    pub inflight: Gauge,
    /// Device slots assigned (data + parity + joiners).
    pub fleet_devices: Gauge,
    /// Device slots currently alive.
    pub fleet_alive: Gauge,
    /// End-to-end request latency (admission → merged output).
    pub latency_ms: Histogram,
    /// Micro-batch width distribution.
    pub batch_width: Histogram,
    /// Per-request trace spans (`GET /v1/traces`).
    pub traces: TraceRing,
    /// Transport-owned counters mirrored in by the serve loop each pass
    /// (`Transport::counters`): bytes/frames/writev, reaper fires,
    /// membership transitions, piggybacked worker counters.
    shared: Mutex<BTreeMap<&'static str, u64>>,
}

/// `(name, help)` for every registry counter, in exposition order.
const COUNTER_HELP: &[(&str, &str)] = &[
    ("cdc_requests_total", "Requests admitted into the serving pipeline"),
    ("cdc_completed_total", "Requests completed with an output"),
    ("cdc_failed_total", "Requests failed (shard set unrecoverable)"),
    ("cdc_recoveries_total", "CDC parity recoveries performed"),
    ("cdc_reaped_tasks_total", "Shard tasks reaped (straggler gate or device death)"),
    ("cdc_replies_total", "Shard replies gathered with data"),
    ("cdc_batches_total", "Micro-batches formed"),
    ("cdc_batched_requests_total", "Requests that entered a micro-batch"),
    ("cdc_dispatch_orders_total", "Per-device work orders dispatched"),
    ("gateway_http_requests_total", "HTTP requests routed by the gateway"),
    ("gateway_http_errors_total", "HTTP responses with status >= 400"),
    ("trace_spans_dropped_total", "Trace events dropped by the span ring"),
];

impl Telemetry {
    /// Fresh registry with an empty trace ring.
    pub fn new() -> Telemetry {
        Telemetry::default()
    }

    /// Mirror transport-owned counters into the registry (called by
    /// the serve loop once per pass; sources are monotonic atomics, so
    /// the mirrored values are monotonic too).
    pub fn set_shared_counters(&self, counters: &[(&'static str, u64)]) {
        let mut shared = lock(&self.shared);
        for &(name, v) in counters {
            shared.insert(name, v);
        }
    }

    /// Snapshot of the mirrored transport counters.
    pub fn shared_counters(&self) -> Vec<(&'static str, u64)> {
        lock(&self.shared).iter().map(|(&k, &v)| (k, v)).collect()
    }

    fn counter_values(&self) -> [u64; 12] {
        [
            self.requests_total.get(),
            self.completed_total.get(),
            self.failed_total.get(),
            self.recoveries_total.get(),
            self.reaped_tasks_total.get(),
            self.replies_total.get(),
            self.batches_total.get(),
            self.batched_requests_total.get(),
            self.dispatch_orders_total.get(),
            self.gateway_requests_total.get(),
            self.gateway_errors_total.get(),
            self.traces.dropped(),
        ]
    }

    /// Render the whole registry in Prometheus text exposition format
    /// (version 0.0.4). Hand-rolled, zero deps; every emitted sample
    /// value is finite (the control plane's `num()` rule: a non-finite
    /// value is replaced by 0 rather than leaking `NaN`/`inf` into a
    /// scraper).
    pub fn render_prometheus(&self) -> String {
        let mut out = String::with_capacity(4096);
        for (&(name, help), value) in COUNTER_HELP.iter().zip(self.counter_values()) {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {value}");
        }
        for (name, help, value) in [
            ("cdc_inflight_requests", "Requests in flight", self.inflight.get()),
            ("fleet_devices_total", "Device slots assigned", self.fleet_devices.get()),
            ("fleet_devices_alive", "Device slots alive", self.fleet_alive.get()),
        ] {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {value}");
        }
        // Transport counters carry their own names (already suffixed
        // `_total`); all are monotonic event counts.
        for (name, value) in self.shared_counters() {
            let _ = writeln!(out, "# HELP {name} Transport counter (see DESIGN.md \u{a7}16)");
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {value}");
        }
        render_histogram(
            &mut out,
            "cdc_request_latency_ms",
            "End-to-end request latency (ms)",
            &self.latency_ms,
        );
        render_histogram(&mut out, "cdc_batch_width", "Micro-batch width", &self.batch_width);
        out
    }

    /// The live-stats JSON block shared by `GET /v1/stats` and the
    /// end-of-run report: percentiles come from [`Histogram::quantile`]
    /// so the live endpoint and the bench output can never disagree.
    pub fn latency_json(&self) -> Value {
        let h = &self.latency_ms;
        obj(vec![
            ("count", finite_num(h.count() as f64)),
            ("mean_ms", finite_num(h.mean_ms())),
            ("min_ms", finite_num(h.min_ms())),
            ("p50_ms", finite_num(h.quantile(0.50))),
            ("p95_ms", finite_num(h.quantile(0.95))),
            ("p99_ms", finite_num(h.quantile(0.99))),
            ("max_ms", finite_num(h.max_ms())),
        ])
    }
}

/// `Value::Num`, with the control plane's non-finite rule applied
/// (NaN/Inf → `null` is the JSON rule; for metrics we emit 0 so sums
/// stay numeric).
fn finite_num(v: f64) -> Value {
    if v.is_finite() {
        Value::Num(v)
    } else {
        Value::Num(0.0)
    }
}

/// Format a bucket bound as a Prometheus `le` label value: plain
/// decimal, never scientific notation, never non-finite.
fn format_le(bound: f64) -> String {
    if bound >= 100.0 {
        format!("{bound:.1}")
    } else {
        format!("{bound:.5}")
    }
}

fn render_histogram(out: &mut String, name: &str, help: &str, h: &Histogram) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    for (bound, cum) in h.cumulative() {
        let _ = writeln!(out, "{name}_bucket{{le=\"{}\"}} {cum}", format_le(bound));
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count());
    let sum = h.sum_ms();
    let sum = if sum.is_finite() { sum } else { 0.0 };
    let _ = writeln!(out, "{name}_sum {sum:.3}");
    let _ = writeln!(out, "{name}_count {}", h.count());
}

pub(crate) fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.set(17);
        g.set(3);
        assert_eq!(g.get(), 3);
    }

    #[test]
    fn empty_histogram_is_all_zeros() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean_ms(), 0.0);
        assert_eq!(h.min_ms(), 0.0);
        assert_eq!(h.max_ms(), 0.0);
    }

    #[test]
    fn single_sample_quantiles_are_exact() {
        let h = Histogram::new();
        h.record(12.5);
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert!((h.quantile(q) - 12.5).abs() < 1e-9, "q={q}");
        }
        assert!((h.mean_ms() - 12.5).abs() < 1e-3);
    }

    #[test]
    fn quantiles_are_monotone_and_bucket_accurate() {
        let h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64);
        }
        let (p50, p95, p99) = (h.quantile(0.5), h.quantile(0.95), h.quantile(0.99));
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // Log buckets: each estimate within one √2 growth factor.
        assert!(p50 >= 500.0 / HIST_GROWTH && p50 <= 500.0 * HIST_GROWTH, "{p50}");
        assert!(p99 >= 990.0 / HIST_GROWTH && p99 <= 990.0 * HIST_GROWTH, "{p99}");
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn merge_equals_combined_recording() {
        let (a, b, both) = (Histogram::new(), Histogram::new(), Histogram::new());
        for i in 1..=50 {
            a.record(i as f64);
            both.record(i as f64);
        }
        for i in 51..=100 {
            b.record(i as f64 * 10.0);
            both.record(i as f64 * 10.0);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert!((a.sum_ms() - both.sum_ms()).abs() < 1e-6);
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert!((a.quantile(q) - both.quantile(q)).abs() < 1e-9, "q={q}");
        }
        assert_eq!(a.min_ms(), both.min_ms());
        assert_eq!(a.max_ms(), both.max_ms());
    }

    #[test]
    fn pathological_samples_stay_finite() {
        let h = Histogram::new();
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -3.0, 0.0, 1e12] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        for q in [0.0, 0.5, 1.0] {
            assert!(h.quantile(q).is_finite());
        }
        assert!(h.sum_ms().is_finite());
    }

    #[test]
    fn prometheus_exposition_parses_and_is_finite() {
        let t = Telemetry::new();
        t.requests_total.add(7);
        t.latency_ms.record(3.25);
        t.latency_ms.record(40.0);
        t.batch_width.record(4.0);
        t.inflight.set(2);
        t.set_shared_counters(&[("net_tx_bytes_total", 1234)]);
        let text = t.render_prometheus();
        let mut samples = 0;
        for line in text.lines() {
            if line.starts_with('#') {
                assert!(
                    line.starts_with("# HELP ") || line.starts_with("# TYPE "),
                    "bad comment line: {line}"
                );
                continue;
            }
            // `name{labels} value` or `name value`.
            let (name, value) = line.rsplit_once(' ').expect(line);
            assert!(!name.is_empty(), "{line}");
            let v: f64 = value.parse().unwrap_or_else(|_| panic!("bad value: {line}"));
            assert!(v.is_finite(), "non-finite sample leaked: {line}");
            samples += 1;
        }
        assert!(samples > 20, "{samples} samples:\n{text}");
        assert!(text.contains("cdc_requests_total 7"), "{text}");
        assert!(text.contains("net_tx_bytes_total 1234"), "{text}");
        assert!(text.contains("cdc_request_latency_ms_bucket{le=\"+Inf\"} 2"), "{text}");
    }

    #[test]
    fn le_labels_are_unique_and_increasing() {
        let mut prev = 0.0;
        let mut seen = std::collections::BTreeSet::new();
        for i in 0..HIST_BUCKETS {
            let b = bucket_bound_ms(i);
            assert!(b > prev, "bucket {i} bound {b} <= {prev}");
            prev = b;
            assert!(seen.insert(format_le(b)), "duplicate le label {}", format_le(b));
        }
    }

    #[test]
    fn latency_json_matches_histogram() {
        let t = Telemetry::new();
        t.latency_ms.record(10.0);
        let j = t.latency_json();
        assert_eq!(j.get("count").unwrap().as_f64().unwrap(), 1.0);
        assert!((j.get("p99_ms").unwrap().as_f64().unwrap() - 10.0).abs() < 1e-9);
    }
}
