//! The L3 coordinator: deploys a model across the fleet per an assignment
//! plan, drives single-batch inference requests through it, merges shard
//! outputs, and applies the paper's robustness machinery (CDC parity,
//! straggler substitution, 2MR, failover).

pub mod policy;

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::cdc;
use crate::error::{Error, Result};
use crate::fleet::{Completion, Device, DeviceConfig, NetConfig, TaskDef, WorkOrder};
use crate::model::{shard_io_bytes, shard_macs, Weights};
use crate::partition::LayerPlan;
use crate::runtime::manifest::{LayerManifest, Manifest, ModelManifest};
use crate::runtime::server::{ComputeHandle, ComputeServer};
use crate::tensor::Tensor;
pub use policy::Outcome;

/// Redundancy mode of one distributed layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Redundancy {
    /// No redundancy: a failed shard loses the request (until failover).
    None,
    /// One CDC parity device covering all d data shards (paper §5).
    Cdc,
    /// Fig. 18: parity groups of the given size (1 failure per group).
    CdcGrouped(usize),
    /// Double modular redundancy: every shard duplicated.
    TwoMr,
}

/// Per-layer split request.
#[derive(Debug, Clone, Copy)]
pub struct SplitSpec {
    pub d: usize,
    pub redundancy: Redundancy,
}

impl SplitSpec {
    /// A plain d-way split.
    pub fn plain(d: usize) -> SplitSpec {
        SplitSpec { d, redundancy: Redundancy::None }
    }

    /// A d-way split protected by one CDC parity device.
    pub fn cdc(d: usize) -> SplitSpec {
        SplitSpec { d, redundancy: Redundancy::Cdc }
    }
}

/// Session construction parameters.
#[derive(Debug, Clone)]
pub struct SessionConfig {
    pub model: String,
    /// Weighted-layer name → split spec; layers not listed run whole
    /// (d = 1) on a single device.
    pub splits: BTreeMap<String, SplitSpec>,
    /// Number of data devices in the fleet (parity/replica devices are
    /// allocated on top, like the paper's "extra device").
    pub n_devices: usize,
    /// Straggler gate: substitution not initiated before
    /// `threshold_factor ×` the layer's expected service time. ∞ disables
    /// mitigation (pure fault tolerance).
    pub threshold_factor: f64,
    pub net: NetConfig,
    /// Device compute rate (MACs/ms); default RPi.
    pub device_rate: f64,
    pub seed: u64,
    /// Failure-detection time for the non-CDC recovery path (paper: "takes
    /// tens of seconds").
    pub detection_ms: f64,
    /// Explicit layer placement (the paper's per-device allocation file,
    /// Fig. 11/13): layer name → data-shard devices (length must equal the
    /// layer's split degree). Unplaced layers are assigned round-robin.
    pub placement: BTreeMap<String, Vec<usize>>,
}

impl SessionConfig {
    /// Reasonable defaults around a model name.
    pub fn new(model: &str) -> SessionConfig {
        SessionConfig {
            model: model.to_string(),
            splits: BTreeMap::new(),
            n_devices: 1,
            threshold_factor: f64::INFINITY,
            net: NetConfig::default(),
            device_rate: crate::fleet::RPI_MACS_PER_MS,
            seed: 2021,
            detection_ms: 20_000.0,
            placement: BTreeMap::new(),
        }
    }
}

/// How one layer executes.
enum Exec {
    /// Merge-point op (pool/flatten/gap) — negligible cost.
    Local(usize),
    /// Distributed (possibly d=1) weighted layer.
    Shards {
        layer_idx: usize,
        /// The split plan (kept for introspection/ablations).
        #[allow(dead_code)]
        plan: LayerPlan,
        /// (device, task id) per data shard.
        data: Vec<(usize, u64)>,
        /// CDC parity devices: (device, task id, covered shard indices).
        parities: Vec<(usize, u64, Vec<usize>)>,
        /// 2MR replicas: (device, task id) aligned with `data`.
        replicas: Vec<(usize, u64)>,
        /// Fused-activation artifact in use (non-CDC fast path)?
        fused_relu: bool,
        /// Expected service time (ms) for the threshold gate.
        expected_ms: f64,
        request_bytes: u64,
    },
}

/// Per-layer trace of one request.
#[derive(Debug, Clone)]
pub struct LayerTrace {
    pub layer: String,
    pub t_start_ms: f64,
    pub t_done_ms: f64,
    pub outcome: &'static str,
    pub recovered_shard: Option<usize>,
    /// Simulated arrival time of each data shard (∞ = lost).
    pub data_arrivals_ms: Vec<f64>,
    /// Simulated arrival time of each parity/replica shard.
    pub aux_arrivals_ms: Vec<f64>,
}

/// Full trace of one request.
#[derive(Debug, Clone)]
pub struct RequestTrace {
    pub req: u64,
    pub output: Tensor,
    pub total_ms: f64,
    pub layers: Vec<LayerTrace>,
    /// True if any layer used CDC substitution.
    pub any_recovery: bool,
}

impl RequestTrace {
    /// Service time of the slowest distributed stage. Under pipelined
    /// steady-state serving the request *rate* is bottleneck-limited, so
    /// the paper's Case-Study-I "2.4x slowdown" manifests as this
    /// stage time doubling when a failed device's shard is re-assigned
    /// serially onto its neighbour.
    pub fn bottleneck_ms(&self) -> f64 {
        self.layers
            .iter()
            .map(|l| l.t_done_ms - l.t_start_ms)
            .fold(0.0, f64::max)
    }
}

/// A deployed model serving session over a simulated fleet.
pub struct Session {
    cfg: SessionConfig,
    model: ModelManifest,
    devices: Vec<Device>,
    exec: Vec<Exec>,
    /// Task definitions kept for failover re-deployment.
    task_defs: BTreeMap<u64, TaskDef>,
    /// task id → owning device (mutated by failover).
    task_owner: BTreeMap<u64, usize>,
    completions: Receiver<Completion>,
    _completions_tx: Sender<Completion>,
    next_req: u64,
    /// Devices currently considered failed by the *coordinator*.
    known_failed: Vec<usize>,
    /// Extra devices allocated beyond cfg.n_devices (parity/replicas).
    pub extra_devices: usize,
    _server: Option<ComputeServer>,
}

impl Session {
    /// Build a session with its own compute server over `artifacts_root`.
    pub fn start(
        artifacts_root: impl Into<std::path::PathBuf>,
        cfg: SessionConfig,
    ) -> Result<Session> {
        let root = artifacts_root.into();
        let server = ComputeServer::spawn(root.clone())?;
        let manifest = Manifest::load(&root)?;
        Session::start_with(manifest, server.handle(), Some(server), cfg)
    }

    /// Build a session over an existing compute server (lets experiments
    /// share one PJRT instance across many sessions).
    pub fn start_shared(
        manifest: &Manifest,
        compute: ComputeHandle,
        cfg: SessionConfig,
    ) -> Result<Session> {
        Session::start_with(manifest.clone_shallow()?, compute, None, cfg)
    }

    fn start_with(
        manifest: Manifest,
        compute: ComputeHandle,
        server: Option<ComputeServer>,
        cfg: SessionConfig,
    ) -> Result<Session> {
        let model = manifest.model(&cfg.model)?.clone();
        let weights = Weights::load(&manifest, &model)?;

        // ---- build the execution plan --------------------------------
        let mut exec = Vec::new();
        let mut next_task = 0u64;
        let mut next_data_dev = 0usize;
        let mut extra = 0usize;
        struct Pending {
            task: u64,
            device: usize,
            def: TaskDef,
        }
        let mut pending: Vec<Pending> = Vec::new();
        let mut preload: Vec<String> = Vec::new();

        for (layer_idx, layer) in model.layers.iter().enumerate() {
            if !layer.is_weighted() {
                exec.push(Exec::Local(layer_idx));
                continue;
            }
            let spec = cfg
                .splits
                .get(&layer.name)
                .copied()
                .unwrap_or(SplitSpec::plain(1));
            if spec.d > cfg.n_devices {
                return Err(Error::Config(format!(
                    "layer {} wants d={} > {} devices",
                    layer.name, spec.d, cfg.n_devices
                )));
            }
            let plan = LayerPlan::build(layer, spec.d)?;
            // CDC needs the pre-activation (lin) artifact; otherwise use
            // the fused flavor when present.
            let use_cdc = matches!(
                spec.redundancy,
                Redundancy::Cdc | Redundancy::CdcGrouped(_)
            );
            let (artifact, fused_relu) = if use_cdc || plan.artifact_relu.is_none() {
                (plan.artifact_lin.clone(), false)
            } else {
                (plan.artifact_relu.clone().unwrap(), true)
            };
            preload.push(artifact.clone());

            let macs = shard_macs(layer, spec.d);
            let (req_bytes, reply_bytes) = shard_io_bytes(layer, spec.d);
            let placed = match cfg.placement.get(&layer.name) {
                Some(devs) => {
                    if devs.len() != spec.d {
                        return Err(Error::Config(format!(
                            "placement for {} has {} devices, split is {}",
                            layer.name,
                            devs.len(),
                            spec.d
                        )));
                    }
                    if let Some(bad) = devs.iter().find(|&&d| d >= cfg.n_devices) {
                        return Err(Error::Config(format!(
                            "placement for {} uses device {bad} >= n_devices {}",
                            layer.name, cfg.n_devices
                        )));
                    }
                    Some(devs.clone())
                }
                None => None,
            };
            let mut shard_wb: Vec<(Arc<Tensor>, Arc<Tensor>)> = Vec::new();
            let mut data = Vec::new();
            for s in &plan.shards {
                let (w, b) = plan.shard_weights(&weights, s)?;
                let (w, b) = (Arc::new(w), Arc::new(b));
                let task = next_task;
                next_task += 1;
                let device = match &placed {
                    Some(devs) => devs[s.index],
                    None => {
                        let d = next_data_dev % cfg.n_devices;
                        next_data_dev += 1;
                        d
                    }
                };
                pending.push(Pending {
                    task,
                    device,
                    def: TaskDef {
                        id: task,
                        artifact: artifact.clone(),
                        w: w.clone(),
                        b: b.clone(),
                        macs,
                        reply_bytes,
                    },
                });
                shard_wb.push((w, b));
                data.push((device, task));
            }

            let mut parities = Vec::new();
            let mut replicas = Vec::new();
            match spec.redundancy {
                Redundancy::None => {}
                Redundancy::Cdc | Redundancy::CdcGrouped(_) => {
                    let group_size = match spec.redundancy {
                        Redundancy::CdcGrouped(g) => g,
                        _ => spec.d,
                    };
                    let groups = cdc::parity_groups(spec.d, group_size)?;
                    for cover in groups {
                        let members: Vec<(Tensor, Tensor)> = cover
                            .iter()
                            .map(|&i| {
                                let (w, b) = &shard_wb[i];
                                (w.as_ref().clone(), b.as_ref().clone())
                            })
                            .collect();
                        let (pw, pb) = cdc::parity_weights(&members)?;
                        let (pw, pb) = (Arc::new(pw), Arc::new(pb));
                        let task = next_task;
                        next_task += 1;
                        let device = cfg.n_devices + extra;
                        extra += 1;
                        pending.push(Pending {
                            task,
                            device,
                            def: TaskDef {
                                id: task,
                                artifact: artifact.clone(),
                                w: pw,
                                b: pb,
                                macs,
                                reply_bytes,
                            },
                        });
                        parities.push((device, task, cover));
                    }
                }
                Redundancy::TwoMr => {
                    for (i, (w, b)) in shard_wb.iter().enumerate() {
                        let task = next_task;
                        next_task += 1;
                        let device = cfg.n_devices + extra;
                        extra += 1;
                        pending.push(Pending {
                            task,
                            device,
                            def: TaskDef {
                                id: task,
                                artifact: artifact.clone(),
                                w: w.clone(),
                                b: b.clone(),
                                macs,
                                reply_bytes,
                            },
                        });
                        let _ = i;
                        replicas.push((device, task));
                    }
                }
            }

            let net_ms = 2.0 * cfg.net.base_ms
                + ((req_bytes + reply_bytes) as f64 * 8.0)
                    / (cfg.net.bandwidth_mbps * 1000.0);
            let expected_ms = macs as f64 / cfg.device_rate + net_ms;
            exec.push(Exec::Shards {
                layer_idx,
                plan,
                data,
                parities,
                replicas,
                fused_relu,
                expected_ms,
                request_bytes: req_bytes,
            });
        }

        // ---- spawn the fleet ------------------------------------------
        let n_total = cfg.n_devices + extra;
        let (ctx, crx) = channel();
        let mut devices = Vec::with_capacity(n_total);
        for id in 0..n_total {
            let dcfg = DeviceConfig {
                id,
                rate_macs_per_ms: cfg.device_rate,
                failure: Default::default(),
            };
            devices.push(Device::spawn(
                dcfg,
                cfg.net.clone(),
                cfg.seed,
                compute.clone(),
                ctx.clone(),
            )?);
        }

        // Warm the executable cache so compile time never pollutes latency.
        preload.sort();
        preload.dedup();
        compute.preload(&preload)?;

        // ---- deploy tasks ----------------------------------------------
        let mut task_defs = BTreeMap::new();
        let mut task_owner = BTreeMap::new();
        let mut per_device: BTreeMap<usize, Vec<TaskDef>> = BTreeMap::new();
        for p in pending {
            task_defs.insert(p.task, p.def.clone());
            task_owner.insert(p.task, p.device);
            per_device.entry(p.device).or_default().push(p.def);
        }
        for (dev, defs) in per_device {
            devices[dev].deploy(defs)?;
        }

        Ok(Session {
            cfg,
            model,
            devices,
            exec,
            task_defs,
            task_owner,
            completions: crx,
            _completions_tx: ctx,
            next_req: 0,
            known_failed: Vec::new(),
            extra_devices: extra,
            _server: server,
        })
    }

    /// Total devices in the fleet (data + redundancy).
    pub fn total_devices(&self) -> usize {
        self.devices.len()
    }

    /// The model served by this session.
    pub fn model(&self) -> &ModelManifest {
        &self.model
    }

    /// Inject a failure plan into a device (experiments flip this).
    pub fn set_failure(&self, device: usize, plan: crate::fleet::FailurePlan) -> Result<()> {
        self.devices
            .get(device)
            .ok_or_else(|| Error::Config(format!("no device {device}")))?
            .set_failure(plan)
    }

    /// Coordinator-side failover (the paper's non-CDC recovery): reassign
    /// every task of `failed` to `target`, which then executes them
    /// serially — Case Study I's ~2.4× steady-state slowdown. Returns the
    /// number of moved tasks. (Detection latency is accounted by the
    /// caller via `cfg.detection_ms`.)
    pub fn failover(&mut self, failed: usize, target: usize) -> Result<usize> {
        let moved: Vec<u64> = self
            .task_owner
            .iter()
            .filter(|(_, &d)| d == failed)
            .map(|(&t, _)| t)
            .collect();
        let defs: Vec<TaskDef> = moved
            .iter()
            .map(|t| self.task_defs[t].clone())
            .collect();
        self.devices[failed].undeploy(moved.clone())?;
        self.devices[target].deploy(defs)?;
        for t in &moved {
            self.task_owner.insert(*t, target);
        }
        for e in &mut self.exec {
            if let Exec::Shards { data, parities, replicas, .. } = e {
                for (d, t) in data.iter_mut() {
                    if moved.contains(t) {
                        *d = target;
                    }
                }
                for (d, t, _) in parities.iter_mut() {
                    if moved.contains(t) {
                        *d = target;
                    }
                }
                for (d, t) in replicas.iter_mut() {
                    if moved.contains(t) {
                        *d = target;
                    }
                }
            }
        }
        self.known_failed.push(failed);
        Ok(moved.len())
    }

    /// Run one single-batch inference through the distributed model.
    pub fn infer(&mut self, input: &Tensor) -> Result<RequestTrace> {
        let req = self.next_req;
        self.next_req += 1;
        let mut t_now = 0.0f64;
        let mut traces = Vec::new();
        let mut any_recovery = false;

        let mut cur = if self.model.input_shape.len() == 1 {
            input.clone().reshape(vec![input.len(), 1])?
        } else {
            input.clone()
        };

        // Local clones to avoid borrowing `self` across the loop.
        for ei in 0..self.exec.len() {
            match &self.exec[ei] {
                Exec::Local(layer_idx) => {
                    let layer = &self.model.layers[*layer_idx];
                    cur = apply_local(layer, cur)?;
                }
                Exec::Shards {
                    layer_idx,
                    plan: _,
                    data,
                    parities,
                    replicas,
                    fused_relu,
                    expected_ms,
                    request_bytes,
                } => {
                    let layer = &self.model.layers[*layer_idx];
                    let t_start = t_now;

                    // ---- dispatch: group tasks per device (a device with
                    // several tasks — e.g. after failover — runs serially).
                    let mut orders: BTreeMap<usize, Vec<u64>> = BTreeMap::new();
                    let all_tasks = data
                        .iter()
                        .copied()
                        .chain(parities.iter().map(|(d, t, _)| (*d, *t)))
                        .chain(replicas.iter().copied());
                    for (dev, task) in all_tasks {
                        orders.entry(dev).or_default().push(task);
                    }
                    let n_expected: usize =
                        orders.values().map(|v| v.len()).sum();
                    let shared_input = Arc::new(cur.clone());
                    for (dev, tasks) in &orders {
                        self.devices[*dev].dispatch(WorkOrder {
                            req,
                            tasks: tasks.clone(),
                            input: shared_input.clone(),
                            request_bytes: *request_bytes,
                            t_dispatch_ms: t_now,
                        })?;
                    }

                    // ---- gather all completions for this layer.
                    let mut by_task: BTreeMap<u64, Completion> = BTreeMap::new();
                    while by_task.len() < n_expected {
                        let c = self.completions.recv().map_err(|_| {
                            Error::Fleet("completion channel closed".into())
                        })?;
                        if c.req == req {
                            by_task.insert(c.task, c);
                        }
                    }

                    // ---- resolve the outcome via the pure policy layer.
                    let data_t: Vec<f64> = data
                        .iter()
                        .map(|(_, t)| by_task[t].t_arrival_ms)
                        .collect();
                    let threshold = if self.cfg.threshold_factor.is_finite() {
                        t_now + self.cfg.threshold_factor * expected_ms
                    } else {
                        f64::INFINITY
                    };
                    // Normalise every redundancy mode into (t_ms, missing
                    // data-shard indices to reconstruct, trace kind).
                    let lost = |layer: &LayerManifest| {
                        Error::Fleet(format!(
                            "request {req} lost at layer {} (unrecoverable)",
                            layer.name
                        ))
                    };
                    let (t_ms, missing, kind) = if !replicas.is_empty() {
                        let rep_t: Vec<f64> = replicas
                            .iter()
                            .map(|(_, t)| by_task[t].t_arrival_ms)
                            .collect();
                        match policy::resolve_2mr(&data_t, &rep_t) {
                            policy::Outcome::Lost => return Err(lost(layer)),
                            o => (o.t_ms(), Vec::new(), "all_data"),
                        }
                    } else if !parities.is_empty() {
                        let par_t: Vec<f64> = parities
                            .iter()
                            .map(|(_, t, _)| by_task[t].t_arrival_ms)
                            .collect();
                        let groups: Vec<Vec<usize>> =
                            parities.iter().map(|(_, _, g)| g.clone()).collect();
                        match policy::resolve_grouped(&data_t, &par_t, &groups, threshold)
                        {
                            policy::GroupedOutcome::Lost => return Err(lost(layer)),
                            policy::GroupedOutcome::Ok { t_ms, missing } => {
                                let kind =
                                    if missing.is_empty() { "all_data" } else { "recovered" };
                                (t_ms, missing, kind)
                            }
                        }
                    } else {
                        match policy::resolve(&data_t, None, f64::INFINITY) {
                            policy::Outcome::Lost => return Err(lost(layer)),
                            o => (o.t_ms(), Vec::new(), "all_data"),
                        }
                    };
                    if !missing.is_empty() {
                        any_recovery = true;
                    }

                    // ---- materialise shard outputs (decode the missing
                    // ones from their parity group: parity − Σ received —
                    // the paper's close-to-zero-latency subtraction).
                    let mut parts: Vec<Option<Tensor>> = data
                        .iter()
                        .map(|(_, t)| by_task[t].result.clone())
                        .collect();
                    // 2MR: fill from the replica when the primary is lost.
                    for (i, (_, rt)) in replicas.iter().enumerate() {
                        if parts[i].is_none() {
                            parts[i] = by_task[rt].result.clone();
                        }
                    }
                    for &mi in &missing {
                        let (_, ptask, cover) = parities
                            .iter()
                            .find(|(_, _, g)| g.contains(&mi))
                            .expect("recovered shard must be covered");
                        let parity_out = by_task[ptask]
                            .result
                            .clone()
                            .ok_or_else(|| Error::Fleet("parity result lost".into()))?;
                        let received: Vec<Tensor> = cover
                            .iter()
                            .filter(|&&i| i != mi)
                            .map(|&i| {
                                parts[i].clone().ok_or_else(|| {
                                    Error::Fleet("covered shard lost".into())
                                })
                            })
                            .collect::<Result<Vec<_>>>()?;
                        let refs: Vec<&Tensor> = received.iter().collect();
                        parts[mi] = Some(cdc::decode(&parity_out, &refs)?);
                    }
                    let out: Vec<Tensor> = parts
                        .into_iter()
                        .enumerate()
                        .map(|(i, p)| {
                            p.ok_or_else(|| {
                                Error::Fleet(format!("shard {i} unexpectedly lost"))
                            })
                        })
                        .collect::<Result<Vec<_>>>()?;
                    t_now = t_ms;
                    let missing_first = missing.first().copied();

                    // Merge: concat + trim padding + deferred epilogue.
                    let refs: Vec<&Tensor> = out.iter().collect();
                    let mut merged = if layer.kind == "fc" {
                        Tensor::concat0(&refs)?.take_rows(layer.m)?
                    } else {
                        let cat = Tensor::concat_channels(&refs)?;
                        cat.take_channels(0, layer.k)?
                    };
                    if layer.relu && !fused_relu {
                        merged.relu();
                    }
                    if layer.kind == "conv" && layer.pool > 0 {
                        merged = merged.maxpool(layer.pool, layer.pool)?;
                    }
                    cur = merged;

                    traces.push(LayerTrace {
                        layer: layer.name.clone(),
                        t_start_ms: t_start,
                        t_done_ms: t_now,
                        outcome: kind,
                        recovered_shard: missing_first,
                        data_arrivals_ms: data_t.clone(),
                        aux_arrivals_ms: parities
                            .iter()
                            .map(|(_, t, _)| by_task[t].t_arrival_ms)
                            .chain(replicas.iter().map(|(_, t)| by_task[t].t_arrival_ms))
                            .collect(),
                    });
                }
            }
        }

        Ok(RequestTrace {
            req,
            output: cur,
            total_ms: t_now,
            layers: traces,
            any_recovery,
        })
    }

    /// Drain stale completions (lost requests leave orphans behind).
    pub fn drain(&mut self) {
        while self.completions.try_recv().is_ok() {}
    }
}

fn apply_local(layer: &LayerManifest, cur: Tensor) -> Result<Tensor> {
    match layer.kind.as_str() {
        "maxpool" => cur.maxpool(layer.pool, layer.pool),
        "flatten" => Ok(cur.flatten_col()),
        "gap" => cur.gap(),
        other => Err(Error::Config(format!("unexpected local layer {other}"))),
    }
}

impl Manifest {
    /// Cheap logical clone for sessions sharing a compute server: re-reads
    /// the manifest from disk (the JSON is small).
    pub fn clone_shallow(&self) -> Result<Manifest> {
        Manifest::load(&self.root)
    }
}
