//! Int8-quantized GEMM with CDC parity in the quantized domain
//! (DESIGN.md §15).
//!
//! Weights are quantized symmetrically per [`QBLOCK_ROWS`]-row block
//! (`scale = maxabs / 127`, round-to-nearest), activations per tensor,
//! products accumulate in `i32`, and the epilogue dequantizes
//! (`scale_block · scale_x · acc`) before bias/ReLU — so the quantized
//! path slots in wherever the f32 fc shard ran, at a quarter of the
//! weight bytes.
//!
//! The CDC story survives quantization because the error is *bounded
//! and computable*: with `w = s_w·q_w + e_w` (`|e_w| ≤ s_w/2`) and
//! `x = s_x·q_x + e_x` (`|e_x| ≤ s_x/2`), each output element differs
//! from the f32 oracle by at most
//! `Σ_k (s_w/2·|x_k| + s_x/2·|s_w·q_w|)` — every term known exactly
//! from the quantized operands ([`error_bound`]). Parity weights are
//! the f32 shard sum quantized once ([`QuantWeights::quantize`] of
//! `cdc::parity_weights`), and reconstruction by subtraction lands
//! within the *sum* of the member bounds of the f32 oracle — the
//! invariant `tests/kernels_simd.rs` proves under injected shard loss.
//! That is the arXiv 2411.01579 numerical-stability condition
//! specialised to sum parity.

use crate::error::{Error, Result};

/// Rows sharing one weight scale (matches the register tile height, so
/// a future int8 micro-kernel can hoist one scale per strip).
pub const QBLOCK_ROWS: usize = 4;

/// Per-deployment numeric precision knob (config `precision`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// Full f32 kernels (the default).
    #[default]
    F32,
    /// Int8 weights + activations for fc shards, i32 accumulation,
    /// dequantize epilogue; conv shards stay f32.
    Int8,
}

impl Precision {
    /// Config / report tag.
    pub fn label(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }

    /// Parse a config tag.
    pub fn parse(tag: &str) -> Result<Precision> {
        match tag {
            "f32" => Ok(Precision::F32),
            "int8" => Ok(Precision::Int8),
            other => Err(Error::Config(format!(
                "unknown precision {other:?} (expected \"f32\" or \"int8\")"
            ))),
        }
    }
}

/// An `m × k` weight matrix quantized to int8 with symmetric
/// per-row-block scales.
#[derive(Clone, PartialEq)]
pub struct QuantWeights {
    m: usize,
    k: usize,
    data: Vec<i8>,
    scales: Vec<f32>,
}

impl std::fmt::Debug for QuantWeights {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("QuantWeights")
            .field("m", &self.m)
            .field("k", &self.k)
            .field("blocks", &self.scales.len())
            .finish()
    }
}

/// Symmetric round-to-nearest quantization of one value at scale `s`.
fn quantize_one(v: f32, s: f32) -> i8 {
    if s <= 0.0 {
        return 0;
    }
    (v / s).round().clamp(-127.0, 127.0) as i8
}

impl QuantWeights {
    /// Quantize a row-major `m × k` f32 matrix. Each
    /// [`QBLOCK_ROWS`]-row block gets `scale = maxabs / 127` (0 when
    /// the block is all zero — those rows dequantize to exact zeros).
    pub fn quantize(w: &[f32], m: usize, k: usize) -> QuantWeights {
        assert_eq!(w.len(), m * k, "QuantWeights: weight length vs ({m},{k})");
        let n_blocks = m.div_ceil(QBLOCK_ROWS);
        let mut scales = Vec::with_capacity(n_blocks);
        for blk in 0..n_blocks {
            let lo = blk * QBLOCK_ROWS * k;
            let hi = ((blk + 1) * QBLOCK_ROWS * k).min(m * k);
            let maxabs = w[lo..hi].iter().fold(0.0f32, |acc, &v| acc.max(v.abs()));
            scales.push(maxabs / 127.0);
        }
        let data = w
            .iter()
            .enumerate()
            .map(|(idx, &v)| quantize_one(v, scales[idx / k / QBLOCK_ROWS]))
            .collect();
        QuantWeights { m, k, data, scales }
    }

    /// Rebuild from wire-decoded parts (rows, depth, int8 data, one
    /// scale per row block). Validates lengths so a hostile frame can
    /// never build an inconsistent value.
    pub fn from_parts(m: usize, k: usize, data: Vec<i8>, scales: Vec<f32>) -> Result<QuantWeights> {
        if data.len() != m * k {
            return Err(Error::Config(format!(
                "QuantWeights: data length {} vs ({m},{k})",
                data.len()
            )));
        }
        if scales.len() != m.div_ceil(QBLOCK_ROWS) {
            return Err(Error::Config(format!(
                "QuantWeights: {} scales for {m} rows (block {QBLOCK_ROWS})",
                scales.len()
            )));
        }
        if scales.iter().any(|s| !s.is_finite() || *s < 0.0) {
            return Err(Error::Config("QuantWeights: scale not finite/non-negative".into()));
        }
        Ok(QuantWeights { m, k, data, scales })
    }

    /// (rows, depth).
    pub fn dims(&self) -> (usize, usize) {
        (self.m, self.k)
    }

    /// Raw int8 values, row-major.
    pub fn data(&self) -> &[i8] {
        &self.data
    }

    /// Per-row-block scales (`m.div_ceil(QBLOCK_ROWS)` of them).
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// The scale applied to row `i`.
    pub fn row_scale(&self, i: usize) -> f32 {
        self.scales[i / QBLOCK_ROWS]
    }

    /// Payload size in bytes (data + scales) — the wire/deploy cost.
    pub fn bytes(&self) -> usize {
        self.data.len() + self.scales.len() * std::mem::size_of::<f32>()
    }

    /// The exact f32 matrix this quantization represents
    /// (`s_w · q_w`) — used by the error model and tests.
    pub fn dequantize(&self) -> Vec<f32> {
        self.data
            .iter()
            .enumerate()
            .map(|(idx, &q)| q as f32 * self.scales[idx / self.k / QBLOCK_ROWS])
            .collect()
    }
}

/// Symmetric per-tensor activation quantization: `(q_x, s_x)` with
/// `s_x = maxabs / 127`.
pub fn quantize_activation(x: &[f32]) -> (Vec<i8>, f32) {
    let maxabs = x.iter().fold(0.0f32, |acc, &v| acc.max(v.abs()));
    let s = maxabs / 127.0;
    (x.iter().map(|&v| quantize_one(v, s)).collect(), s)
}

/// Int8 GEMM with fused dequantize + bias + ReLU epilogue:
/// `c[i,j] = relu( row_scale(i)·s_x · Σ_k q_w[i,k]·q_x[k,j] + bias[i] )`.
/// Activations are quantized here (per call, per tensor); products
/// accumulate in `i32` — exact for any depth the deploy caps allow
/// (`k · 127² ≪ i32::MAX`).
pub fn qgemm(
    qw: &QuantWeights,
    x: &[f32],
    c: &mut [f32],
    n: usize,
    bias: Option<&[f32]>,
    relu: bool,
) {
    let (m, k) = qw.dims();
    assert_eq!(x.len(), k * n, "qgemm: rhs length vs ({k},{n})");
    assert_eq!(c.len(), m * n, "qgemm: out length vs ({m},{n})");
    if let Some(b) = bias {
        assert_eq!(b.len(), m, "qgemm: bias length vs rows {m}");
    }
    if m == 0 || n == 0 {
        return;
    }
    let (qx, sx) = quantize_activation(x);
    let mut acc = vec![0i32; n];
    for i in 0..m {
        acc.fill(0);
        let wrow = &qw.data[i * k..(i + 1) * k];
        for (kk, &wv) in wrow.iter().enumerate() {
            if wv == 0 {
                continue;
            }
            let wv = wv as i32;
            let xrow = &qx[kk * n..(kk + 1) * n];
            for (av, &xv) in acc.iter_mut().zip(xrow) {
                *av += wv * xv as i32;
            }
        }
        let s = qw.row_scale(i) * sx;
        let bv = bias.map_or(0.0, |b| b[i]);
        for (cv, &av) in c[i * n..(i + 1) * n].iter_mut().zip(&acc) {
            let mut v = s * av as f32 + bv;
            if relu && v < 0.0 {
                v = 0.0;
            }
            *cv = v;
        }
    }
}

/// Per-element upper bound on `|f32_oracle − qgemm|` (pre-activation),
/// as an `m × n` row-major matrix:
/// `bound[i,j] = s_w(i)/2 · Σ_k |x[k,j]|  +  s_x/2 · Σ_k |s_w(i)·q_w[i,k]|`.
/// Both terms are computed exactly from the quantized operands; the
/// bound is what the quantized-CDC reconstruction tests sum per lost
/// shard.
pub fn error_bound(qw: &QuantWeights, x: &[f32], n: usize) -> Vec<f32> {
    let (m, k) = qw.dims();
    assert_eq!(x.len(), k * n, "error_bound: rhs length vs ({k},{n})");
    let sx = x.iter().fold(0.0f32, |acc, &v| acc.max(v.abs())) / 127.0;
    // Σ_k |x[k,j]| per column.
    let mut colabs = vec![0.0f32; n];
    for xrow in x.chunks_exact(n.max(1)).take(k) {
        for (cacc, &v) in colabs.iter_mut().zip(xrow) {
            *cacc += v.abs();
        }
    }
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let sw = qw.row_scale(i);
        let rowabs: f32 = qw.data[i * k..(i + 1) * k]
            .iter()
            .map(|&q| (q as f32 * sw).abs())
            .sum();
        for (o, &ca) in out[i * n..(i + 1) * n].iter_mut().zip(&colabs) {
            *o = sw / 2.0 * ca + sx / 2.0 * rowabs;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemm_naive;
    use crate::rng::Pcg32;

    fn randv(n: usize, rng: &mut Pcg32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn precision_tags_roundtrip() {
        assert_eq!(Precision::parse("f32").unwrap(), Precision::F32);
        assert_eq!(Precision::parse("int8").unwrap(), Precision::Int8);
        assert_eq!(Precision::Int8.label(), "int8");
        assert_eq!(Precision::default(), Precision::F32);
        assert!(Precision::parse("fp16").is_err());
    }

    #[test]
    fn quantization_error_is_within_half_scale() {
        let mut rng = Pcg32::seeded(31);
        let (m, k) = (13, 40);
        let w = randv(m * k, &mut rng);
        let qw = QuantWeights::quantize(&w, m, k);
        let wd = qw.dequantize();
        for (i, (&orig, &deq)) in w.iter().zip(&wd).enumerate() {
            let s = qw.row_scale(i / k);
            assert!((orig - deq).abs() <= s / 2.0 + 1e-7, "element {i}: |{orig} - {deq}| > {s}/2");
        }
    }

    #[test]
    fn qgemm_stays_within_error_bound_of_f32_oracle() {
        let mut rng = Pcg32::seeded(32);
        for &(m, k, n) in &[(1, 1, 1), (7, 19, 3), (64, 128, 8), (33, 257, 5)] {
            let w = randv(m * k, &mut rng);
            let x = randv(k * n, &mut rng);
            let qw = QuantWeights::quantize(&w, m, k);
            let mut oracle = vec![0.0; m * n];
            gemm_naive(&w, &x, &mut oracle, m, k, n);
            let mut got = vec![0.0; m * n];
            qgemm(&qw, &x, &mut got, n, None, false);
            let bound = error_bound(&qw, &x, n);
            for idx in 0..m * n {
                let err = (oracle[idx] - got[idx]).abs();
                assert!(
                    err <= bound[idx] + 1e-5,
                    "({m},{k},{n}) elem {idx}: err {err} > bound {}",
                    bound[idx]
                );
            }
        }
    }

    #[test]
    fn qgemm_epilogue_applies_bias_and_relu() {
        let w = vec![1.0, 0.0, 0.0, -1.0];
        let qw = QuantWeights::quantize(&w, 2, 2);
        let x = vec![2.0, 3.0];
        let bias = vec![0.5, -0.5];
        let mut lin = vec![0.0; 2];
        qgemm(&qw, &x, &mut lin, 1, Some(&bias), false);
        assert!((lin[0] - 2.5).abs() < 0.1 && (lin[1] + 3.5).abs() < 0.1, "{lin:?}");
        let mut act = vec![0.0; 2];
        qgemm(&qw, &x, &mut act, 1, Some(&bias), true);
        assert!(act[0] > 0.0 && act[1] == 0.0, "{act:?}");
    }

    #[test]
    fn zero_weights_quantize_to_exact_zero() {
        let qw = QuantWeights::quantize(&[0.0; 12], 3, 4);
        assert!(qw.scales().iter().all(|&s| s == 0.0));
        let mut c = vec![9.0; 3];
        qgemm(&qw, &[1.0, 2.0, 3.0, 4.0], &mut c, 1, None, false);
        assert_eq!(c, vec![0.0; 3]);
    }

    #[test]
    fn from_parts_validates() {
        assert!(QuantWeights::from_parts(2, 2, vec![0; 4], vec![0.1]).is_ok());
        assert!(QuantWeights::from_parts(2, 2, vec![0; 3], vec![0.1]).is_err());
        assert!(QuantWeights::from_parts(2, 2, vec![0; 4], vec![0.1, 0.2]).is_err());
        assert!(QuantWeights::from_parts(2, 2, vec![0; 4], vec![f32::NAN]).is_err());
        assert!(QuantWeights::from_parts(5, 1, vec![0; 5], vec![0.1, 0.2]).is_ok());
    }
}
