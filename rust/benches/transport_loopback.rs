//! Transport-loopback bench (DESIGN.md §11–12): the full serving engine
//! over **real TCP worker processes** on 127.0.0.1, measuring
//! wall-clock rps / p50 / p99 — steady at fleet widths {4, 16, 64},
//! and with one worker SIGKILLed mid-run (the CDC arm must finish with
//! zero lost requests, the paper's invariant on real sockets). A
//! virtual-time sim arm runs the same deployment for reference.
//!
//! The width sweep shards the wide synth model (two 434-high fc layers)
//! across `width − 2` data devices plus parity, and asserts the
//! event-loop property the sweep exists for: the coordinator's I/O
//! thread count is **O(1) in fleet width** — the process thread count,
//! sampled with every fleet connected, is identical at width 4 and
//! width 64.
//!
//! Workers run RPi-style emulated compute (`--rate`) so loopback
//! numbers reflect the serving machinery, not a laptop GEMM finishing
//! in microseconds; the arrival rate oversubscribes the emulated
//! capacity, so the measured rps is the saturated (stable) throughput.
//! Sweep worker rates are scaled per width so a shard order costs ~3 ms
//! at every width — per-width rps is then comparable and bounded by the
//! same emulated device capacity, not by shard size.
//!
//! `TRANSPORT_BENCH_SMOKE=1` scales the stream down and sweeps
//! {4, 16} for CI; `BENCH_BASELINE_ENFORCE=1` gates the headline
//! metrics against the committed seed in
//! `rust/baselines/BENCH_transport.json`.
//!
//! Run with `cargo bench --bench transport_loopback`.

use std::path::{Path, PathBuf};
use std::time::Instant;

use cdc_dnn::bench::guard_baseline;
use cdc_dnn::coordinator::{Session, SessionConfig, SplitSpec, Workload};
use cdc_dnn::json::{obj, Value};
use cdc_dnn::rng::Pcg32;
use cdc_dnn::tensor::Tensor;
use cdc_dnn::testkit::synth;
use cdc_dnn::transport::loopback::LoopbackFleet;
use cdc_dnn::transport::{TcpConfig, TcpTransport, TransportSpec};

const SEED: u64 = 2021;
/// Emulated worker compute rate for the narrow model (MACs/ms): a synth
/// fc1 shard order costs ~5 ms, putting loopback service times in RPi
/// territory.
const WORKER_RATE: f64 = 20.0;
const ARRIVAL_RPS: f64 = 120.0;
/// Sweep arrival rate: oversubscribes the ~3 ms emulated shard service
/// time at every width, so the sweep measures saturated throughput.
const SWEEP_RPS: f64 = 400.0;
/// Target emulated cost of one (unbatched) fc2 shard order in the
/// width sweep, whatever the width.
const SWEEP_SHARD_MS: f64 = 3.0;

fn bench_out_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_transport.json"))
        .unwrap_or_else(|| PathBuf::from("BENCH_transport.json"))
}

/// mlp over 2 data devices, both layers parity-coded (4 devices total),
/// micro-batching on — the CDC serving arm.
fn cdc_cfg() -> SessionConfig {
    let mut cfg = SessionConfig::new(synth::MODEL);
    cfg.n_devices = 2;
    cfg.splits.insert("fc1".into(), SplitSpec::cdc(2));
    cfg.splits.insert("fc2".into(), SplitSpec::cdc(2));
    cfg.seed = SEED;
    cfg.detection_ms = 500.0;
    cfg.batch_max = 4;
    cfg.batch_wait_ms = 2.0;
    cfg
}

/// mlp_wide over `width − 2` data devices, both layers parity-coded
/// (`width` workers total) — one point of the fleet-width sweep.
fn wide_cfg(width: usize) -> SessionConfig {
    let d = width - 2;
    let mut cfg = SessionConfig::new(synth::WIDE_MODEL);
    cfg.n_devices = d;
    cfg.splits.insert("fc1".into(), SplitSpec::cdc(d));
    cfg.splits.insert("fc2".into(), SplitSpec::cdc(d));
    cfg.seed = SEED;
    cfg.detection_ms = 500.0;
    cfg.batch_max = 4;
    cfg.batch_wait_ms = 2.0;
    cfg
}

/// Per-width worker rate (MACs/ms) that prices the width's fc2 shard —
/// the dominant order — at [`SWEEP_SHARD_MS`].
fn sweep_rate(width: usize) -> f64 {
    let shard_macs = (synth::WIDE_M / (width - 2)) * synth::WIDE_M;
    shard_macs as f64 / SWEEP_SHARD_MS
}

fn inputs(n: usize, k: usize, seed: u64) -> Vec<Tensor> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| Tensor::randn(vec![k], &mut rng)).collect()
}

/// Total threads of this process (`/proc/self/status`); `None` off
/// Linux. Sampled with a fleet connected, this is the O(1)-I/O-thread
/// probe: the count must not grow with fleet width.
#[cfg(target_os = "linux")]
fn process_threads() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
}

#[cfg(not(target_os = "linux"))]
fn process_threads() -> Option<usize> {
    None
}

struct ArmResult {
    completed: u64,
    failed: usize,
    recovered: u64,
    rps: f64,
    p50: f64,
    p99: f64,
    makespan_ms: f64,
    max_batch: usize,
    /// Process thread count right after deploy (fleet connected).
    threads: Option<usize>,
}

fn run_arm(
    arts: &Path,
    cfg: SessionConfig,
    k: usize,
    n: usize,
    rps: f64,
    kill: Option<(&LoopbackFleet, usize, u64)>,
) -> ArmResult {
    let mut session = Session::start(arts, cfg).expect("deploy");
    let threads = process_threads();
    let killer = kill.map(|(fleet, victim, at_ms)| fleet.kill_after(victim, at_ms));
    let report = session
        .serve(&Workload::poisson(inputs(n, k, SEED), rps, SEED))
        .expect("serve");
    if let Some(kh) = killer {
        kh.join().expect("chaos thread");
    }
    let s = report.latency.summary();
    ArmResult {
        completed: report.throughput.completed,
        failed: report.failures.len(),
        recovered: report.throughput.recovered,
        rps: report.rps(),
        p50: s.p50,
        p99: s.p99,
        makespan_ms: report.makespan_ms,
        max_batch: report.max_batch,
        threads,
    }
}

fn arm_row(label: &str, n: usize, arrival: f64, width: usize, r: &ArmResult) -> Value {
    obj(vec![
        ("arm", Value::Str(label.into())),
        ("width", Value::Num(width as f64)),
        ("requests", Value::Num(n as f64)),
        ("arrival_rps", Value::Num(arrival)),
        ("completed", Value::Num(r.completed as f64)),
        ("failed", Value::Num(r.failed as f64)),
        ("recovered", Value::Num(r.recovered as f64)),
        ("rps", Value::Num(r.rps)),
        ("p50_ms", Value::Num(r.p50)),
        ("p99_ms", Value::Num(r.p99)),
        ("makespan_ms", Value::Num(r.makespan_ms)),
        ("max_batch", Value::Num(r.max_batch as f64)),
        (
            "process_threads",
            r.threads.map(|t| Value::Num(t as f64)).unwrap_or(Value::Null),
        ),
    ])
}

fn main() {
    let smoke = std::env::var("TRANSPORT_BENCH_SMOKE").is_ok();
    println!(
        "transport_loopback: compute backend = {}, smoke = {smoke}",
        cdc_dnn::runtime::backend_label()
    );
    // The O(1) property is structural before it is measured: the
    // transport runs exactly one I/O thread by construction.
    assert_eq!(TcpTransport::IO_THREADS, 1);

    let arts = synth::build(SEED).expect("synthetic artifacts");
    let wide_arts = synth::build_wide(SEED).expect("wide synthetic artifacts");
    let worker_bin = Path::new(env!("CARGO_BIN_EXE_cdc-dnn"));
    let n = if smoke { 100 } else { 300 };
    let sweep_n = if smoke { 80 } else { 240 };
    let widths: &[usize] = if smoke { &[4, 16] } else { &[4, 16, 64] };
    // Kill ~30% into the expected (saturated) makespan.
    let kill_at_ms = if smoke { 300 } else { 900 };

    let t0 = Instant::now();
    let mut rows = Vec::new();
    let mut headline: Vec<(String, f64)> = Vec::new();
    let mode = if smoke { "smoke" } else { "full" };

    // ---- arm 1: virtual-time sim reference ---------------------------
    let sim = run_arm(&arts.root, cdc_cfg(), synth::FC1_K, n, ARRIVAL_RPS, None);
    println!(
        "  sim-steady:  completed={} failed={} rps={:.1} (virtual) p50={:.1}ms p99={:.1}ms",
        sim.completed, sim.failed, sim.rps, sim.p50, sim.p99
    );
    assert_eq!(sim.failed, 0, "sim CDC arm lost requests");
    rows.push(arm_row("sim-steady", n, ARRIVAL_RPS, 4, &sim));

    // ---- arm 2: tcp fleet-width sweep over the wide model ------------
    let mut sweep_threads: Vec<(usize, usize)> = Vec::new();
    for &width in widths {
        let fleet = LoopbackFleet::spawn(
            Some(worker_bin),
            &wide_arts.root,
            width,
            Some(sweep_rate(width)),
        )
        .expect("spawn loopback fleet");
        let mut cfg = wide_cfg(width);
        let mut tcp: TcpConfig = fleet.tcp_config();
        tcp.order_deadline_ms = 1_000.0;
        cfg.transport = TransportSpec::Tcp(tcp);
        let r = run_arm(&wide_arts.root, cfg, synth::WIDE_K, sweep_n, SWEEP_RPS, None);
        drop(fleet);
        println!(
            "  tcp-w{width:<3}:    completed={} failed={} rps={:.1} (wall) p50={:.1}ms \
             p99={:.1}ms threads={:?}",
            r.completed, r.failed, r.rps, r.p50, r.p99, r.threads
        );
        assert_eq!(r.failed, 0, "width-{width} CDC arm lost requests");
        assert_eq!(r.completed, sweep_n as u64, "width-{width} arm must complete");
        if let Some(t) = r.threads {
            sweep_threads.push((width, t));
        }
        headline.push((format!("{mode}_tcp_w{width}_rps"), r.rps));
        rows.push(arm_row(&format!("tcp-w{width}"), sweep_n, SWEEP_RPS, width, &r));
    }
    // The tentpole property: coordinator thread count does not grow
    // with fleet width — one event loop owns every connection.
    if let (Some(first), Some(last)) = (sweep_threads.first(), sweep_threads.last()) {
        assert_eq!(
            first.1, last.1,
            "coordinator thread count grew with fleet width: {sweep_threads:?}"
        );
    }

    // ---- arm 3: tcp + SIGKILL one data worker mid-run ----------------
    let fleet = LoopbackFleet::spawn(Some(worker_bin), &arts.root, 4, Some(WORKER_RATE))
        .expect("spawn loopback fleet");
    let mut cfg = cdc_cfg();
    let mut tcp: TcpConfig = fleet.tcp_config();
    tcp.order_deadline_ms = 1_000.0;
    cfg.transport = TransportSpec::Tcp(tcp);
    let kill = run_arm(
        &arts.root,
        cfg,
        synth::FC1_K,
        n,
        ARRIVAL_RPS,
        Some((&fleet, 1, kill_at_ms)),
    );
    drop(fleet);
    println!(
        "  tcp-kill:    completed={} failed={} recovered={} rps={:.1} (wall) \
         p50={:.1}ms p99={:.1}ms",
        kill.completed, kill.failed, kill.recovered, kill.rps, kill.p50, kill.p99
    );
    // The acceptance invariant (ISSUE 5): killing one worker mid-run
    // loses ZERO requests on the CDC arm.
    assert_eq!(
        kill.failed, 0,
        "CDC arm lost requests after a worker SIGKILL"
    );
    assert_eq!(kill.completed, n as u64, "kill arm must complete the stream");
    assert!(
        kill.recovered > 0,
        "the kill landed after the run — no recovery was exercised"
    );
    rows.push(arm_row("tcp-kill", n, ARRIVAL_RPS, 4, &kill));
    headline.push((format!("{mode}_tcp_kill_rps"), kill.rps));

    let wall_ms = t0.elapsed().as_secs_f64() * 1000.0;
    let doc = obj(vec![
        ("experiment", Value::Str("bench_transport_loopback".into())),
        ("backend", Value::Str(cdc_dnn::runtime::backend_label().into())),
        ("transport", Value::Str("tcp-loopback".into())),
        ("smoke", Value::Bool(smoke)),
        ("worker_rate_macs_per_ms", Value::Num(WORKER_RATE)),
        ("sweep_shard_ms", Value::Num(SWEEP_SHARD_MS)),
        (
            "sweep_widths",
            Value::Arr(widths.iter().map(|&w| Value::Num(w as f64)).collect()),
        ),
        ("io_threads", Value::Num(TcpTransport::IO_THREADS as f64)),
        ("suite_wall_ms", Value::Num(wall_ms)),
        ("points", Value::Arr(rows)),
    ]);
    let out = bench_out_path();
    std::fs::write(&out, doc.to_string_pretty()).expect("write BENCH_transport.json");
    println!("[result] wrote {}", out.display());

    // Wall-clock rps over loopback is machine-dependent; CI seeds are
    // promoted from CI's own smoke artifacts and compare like-to-like
    // (the saturated regime keeps them stable across runs).
    guard_baseline("transport", &headline);
}
