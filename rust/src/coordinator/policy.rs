//! Pure gather-resolution policies: given simulated arrival times for a
//! layer's shards, decide *when* the layer completes and *how* (all data,
//! CDC substitution, or lost). Keeping this logic pure makes the paper's
//! latency semantics property-testable independent of threads and PJRT.
//!
//! The module also hosts the **adaptive CDC policy** ([`AdaptivePolicy`],
//! DESIGN.md §9): an online tuner that watches the per-device completion
//! latencies the serving engine observes, trails the straggler-gate
//! factor just above the typical-latency quantile, and recommends
//! parity-coded vs replicated redundancy from the observed reply-loss
//! rate. It is deliberately *state over pure functions*: the resolution
//! semantics above stay pure, the tuner only chooses their `threshold`
//! argument.

use std::collections::VecDeque;

use crate::metrics::{percentile_sorted, Intervals};

use super::Redundancy;

/// How a distributed layer completed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Outcome {
    /// All data shards arrived; completion at the slowest data arrival.
    AllData { t_ms: f64 },
    /// Parity substituted for exactly one data shard (failure *or*
    /// straggler): completion when n of n+1 results were in hand (gated by
    /// the threshold), recovery itself is a local subtraction (§5.2).
    Recovered { t_ms: f64, missing: usize },
    /// Unrecoverable: ≥ 1 shard missing and no usable parity.
    Lost,
}

impl Outcome {
    /// Completion time; ∞ when lost.
    pub fn t_ms(&self) -> f64 {
        match self {
            Outcome::AllData { t_ms } => *t_ms,
            Outcome::Recovered { t_ms, .. } => *t_ms,
            Outcome::Lost => f64::INFINITY,
        }
    }
}

/// Resolve a layer protected by (at most) one parity shard.
///
/// * `data`: simulated arrival time per data shard (∞ = never arrived).
/// * `parity`: arrival of the parity shard, if one was deployed.
/// * `threshold_ms`: straggler-mitigation gate — parity substitution may
///   not be *initiated* before this absolute time (paper §6.2: "a device
///   waits for a particular amount of time; adjusting this waiting
///   threshold treats our method as a solution to the straggler problem").
///   `0.0` = substitute as soon as any n of n+1 results are in.
pub fn resolve(data: &[f64], parity: Option<f64>, threshold_ms: f64) -> Outcome {
    assert!(!data.is_empty());
    // A NaN stamp is a corrupt arrival record (a mangled wall-clock
    // reading, an uninitialised slot): treat it as "never arrived".
    // `f64::max` silently *ignores* NaN, which would count the shard as
    // arrived, and `partial_cmp(..).unwrap()` on NaN panics mid-serve —
    // sanitising to ∞ keeps both folds and the total_cmp ordering sound.
    let sane = |t: f64| if t.is_nan() { f64::INFINITY } else { t };
    let t_all = data.iter().map(|&t| sane(t)).fold(f64::NEG_INFINITY, f64::max);

    let Some(t_parity) = parity.map(sane) else {
        return if t_all.is_finite() {
            Outcome::AllData { t_ms: t_all }
        } else {
            Outcome::Lost
        };
    };

    // Completion-by-substitution: drop the slowest data shard, finish at
    // max(parity, remaining data, threshold).
    let (slowest_idx, _) = data
        .iter()
        .enumerate()
        .max_by(|a, b| sane(*a.1).total_cmp(&sane(*b.1)))
        .unwrap();
    let t_rest = data
        .iter()
        .enumerate()
        .filter(|(i, _)| *i != slowest_idx)
        .map(|(_, t)| sane(*t))
        .fold(f64::NEG_INFINITY, f64::max)
        .max(f64::NEG_INFINITY);
    let t_rest = if data.len() == 1 { 0.0 } else { t_rest };
    // Earliest instant n of n+1 results are in hand.
    let t_sub = t_parity.max(t_rest);

    if t_all.is_finite() {
        // Straggler case: substitution may not be *initiated* before the
        // threshold, so it completes at max(t_sub, threshold); waiting for
        // the slow shard completes at t_all — take whichever is earlier.
        let gated = t_sub.max(threshold_ms);
        if t_all <= gated {
            Outcome::AllData { t_ms: t_all }
        } else {
            Outcome::Recovered { t_ms: gated, missing: slowest_idx }
        }
    } else if t_sub.is_finite() {
        // Failure case: the missing shard never arrives, substitution is
        // forced. A finite threshold still gates when the coordinator
        // gives up waiting; an infinite one means "recover as soon as n
        // results are in hand" (pure fault tolerance, no mitigation).
        let t = if threshold_ms.is_finite() { t_sub.max(threshold_ms) } else { t_sub };
        Outcome::Recovered { t_ms: t, missing: slowest_idx }
    } else {
        Outcome::Lost
    }
}

/// Resolve a 2MR (double-modular-redundancy) layer: every shard has two
/// replicas; a shard is ready at the *earlier* replica, the layer at the
/// slowest shard; lost if both replicas of any shard are lost.
pub fn resolve_2mr(primary: &[f64], replica: &[f64]) -> Outcome {
    assert_eq!(primary.len(), replica.len());
    let mut t = f64::NEG_INFINITY;
    for (p, r) in primary.iter().zip(replica) {
        let shard = p.min(*r);
        if !shard.is_finite() {
            return Outcome::Lost;
        }
        t = t.max(shard);
    }
    Outcome::AllData { t_ms: t }
}

/// Result of resolving a (multi-)parity layer: possibly several shards
/// recovered — at most one per parity group (Fig. 18).
#[derive(Debug, Clone, PartialEq)]
pub enum GroupedOutcome {
    /// Layer completed at `t_ms`; `missing` lists the data shards that
    /// must be reconstructed from their group parity (empty = all data).
    Ok { t_ms: f64, missing: Vec<usize> },
    /// ≥ 2 shards missing in one group — unrecoverable.
    Lost,
}

/// Resolve a Fig.-18 multi-parity layer: `groups[g]` lists the data-shard
/// indices covered by parity `g`. Each group must independently complete;
/// the layer completes at the slowest group. The single-parity scheme of
/// §5 is the one-group special case.
pub fn resolve_grouped(
    data: &[f64],
    parities: &[f64],
    groups: &[Vec<usize>],
    threshold_ms: f64,
) -> GroupedOutcome {
    assert_eq!(parities.len(), groups.len());
    let mut t = f64::NEG_INFINITY;
    let mut missing = Vec::new();
    for (g, cover) in groups.iter().enumerate() {
        let sub: Vec<f64> = cover.iter().map(|&i| data[i]).collect();
        match resolve(&sub, Some(parities[g]), threshold_ms) {
            Outcome::Lost => return GroupedOutcome::Lost,
            Outcome::AllData { t_ms } => t = t.max(t_ms),
            Outcome::Recovered { t_ms, missing: m } => {
                t = t.max(t_ms);
                missing.push(cover[m]);
            }
        }
    }
    GroupedOutcome::Ok { t_ms: t, missing }
}

// ---------------------------------------------------------------------
// Adaptive CDC policy (DESIGN.md §9)
// ---------------------------------------------------------------------

/// Tuning knobs of the [`AdaptivePolicy`].
#[derive(Debug, Clone)]
pub struct AdaptiveConfig {
    /// Sliding-window length (completions kept per device and globally).
    pub window: usize,
    /// Lower clamp of the straggler-gate factor (never substitute below
    /// this multiple of the expected service time).
    pub min_factor: f64,
    /// Upper clamp of the straggler-gate factor.
    pub max_factor: f64,
    /// Latency quantile the gate trails: with `q = 0.75` the gate sits
    /// just above the fastest three quarters of recent completions, so a
    /// persistently slow minority (a straggling device) falls outside it
    /// and gets substituted.
    pub quantile: f64,
    /// Safety margin multiplied onto the tracked quantile.
    pub margin: f64,
    /// Observed reply-loss rate above which replication (2MR) is
    /// recommended over single-parity CDC: one parity masks one loss per
    /// group, so a lossy fleet wants per-shard replicas despite the d×
    /// hardware cost.
    pub replication_drop_rate: f64,
    /// Gate factor used before the window has any samples.
    pub initial_factor: f64,
}

impl Default for AdaptiveConfig {
    fn default() -> AdaptiveConfig {
        AdaptiveConfig {
            window: 64,
            min_factor: 1.2,
            max_factor: 8.0,
            quantile: 0.75,
            margin: 1.5,
            replication_drop_rate: 0.15,
            initial_factor: 2.0,
        }
    }
}

/// Online straggler-gate tuner + redundancy chooser.
///
/// The serving engine feeds it one observation per shard completion —
/// `(device, dispatch time, arrival time, expected service time)` — and
/// reads back [`AdaptivePolicy::threshold_factor`] before each stage
/// resolution. Internally it keeps per-device sliding windows of
/// `(dispatch, arrival)` intervals (exposed as [`Intervals`] in the
/// [`PolicyReport`]) plus a global window of expected-normalised
/// latencies from which the gate factor is re-tuned after every
/// observation. Lost replies (`arrival = ∞`) feed the drop-rate estimate
/// behind [`AdaptivePolicy::recommend`].
#[derive(Debug, Clone)]
pub struct AdaptivePolicy {
    cfg: AdaptiveConfig,
    /// Per-device sliding window of (dispatch, arrival) pairs.
    device_windows: Vec<VecDeque<(f64, f64)>>,
    /// Global sliding window of expected-normalised latencies (FIFO).
    norm: VecDeque<f64>,
    /// The same multiset as `norm`, kept sorted incrementally so
    /// re-tuning is a binary search + `O(window)` shift per observation
    /// with no allocation at steady state (the serve hot path stays
    /// allocation-free once the windows are warm).
    sorted: Vec<f64>,
    /// Sliding window of reply outcomes (true = lost) — the drop-rate
    /// estimate must *recover* after a transient lossy phase, so it
    /// slides like the latency windows do.
    outcomes: VecDeque<bool>,
    /// Lost replies currently inside `outcomes`.
    window_drops: usize,
    observed: u64,
    drops: u64,
    stragglers: u64,
    factor: f64,
}

impl AdaptivePolicy {
    /// Fresh policy over `n_devices` devices (data + redundancy).
    pub fn new(cfg: AdaptiveConfig, n_devices: usize) -> AdaptivePolicy {
        let factor = cfg.initial_factor;
        AdaptivePolicy {
            device_windows: vec![VecDeque::new(); n_devices],
            norm: VecDeque::new(),
            sorted: Vec::new(),
            outcomes: VecDeque::new(),
            window_drops: 0,
            observed: 0,
            drops: 0,
            stragglers: 0,
            factor,
            cfg,
        }
    }

    /// Grow the per-device windows to cover at least `n` devices — live
    /// membership joins widen the fleet mid-session; existing windows
    /// are untouched (shrinking never happens: slots are not reused).
    pub fn grow(&mut self, n: usize) {
        if self.device_windows.len() < n {
            self.device_windows.resize_with(n, VecDeque::new);
        }
    }

    /// Feed one shard completion: `t_arrival_ms = ∞` records a lost
    /// reply; finite arrivals update the latency windows and re-tune the
    /// gate.
    pub fn observe(
        &mut self,
        device: usize,
        t_start_ms: f64,
        t_arrival_ms: f64,
        expected_ms: f64,
    ) {
        self.observed += 1;
        let lost = !t_arrival_ms.is_finite();
        if self.outcomes.len() >= self.cfg.window {
            if let Some(old) = self.outcomes.pop_front() {
                if old {
                    self.window_drops -= 1;
                }
            }
        }
        self.outcomes.push_back(lost);
        if lost {
            self.window_drops += 1;
            self.drops += 1;
            return;
        }
        let lat = (t_arrival_ms - t_start_ms).max(0.0);
        if let Some(w) = self.device_windows.get_mut(device) {
            if w.len() >= self.cfg.window {
                w.pop_front();
            }
            w.push_back((t_start_ms, t_arrival_ms));
        }
        let normalised = if expected_ms > 0.0 { lat / expected_ms } else { lat };
        if normalised > self.factor {
            self.stragglers += 1;
        }
        if self.norm.len() >= self.cfg.window {
            if let Some(old) = self.norm.pop_front() {
                // The evicted value is a bit-exact copy of a `sorted`
                // entry, so the partition point lands on it directly.
                let i = self.sorted.partition_point(|&x| x < old);
                if i < self.sorted.len() {
                    let _ = self.sorted.remove(i);
                }
            }
        }
        self.norm.push_back(normalised);
        let i = self.sorted.partition_point(|&x| x < normalised);
        self.sorted.insert(i, normalised);
        self.retune();
    }

    /// Feed one *batched* shard completion (DESIGN.md §10): the reply
    /// carries `members` requests, so the window receives one
    /// observation per member — each member really experienced that
    /// latency — against the batch-scaled expected service time. With
    /// `members == 1` this is exactly [`AdaptivePolicy::observe`].
    pub fn observe_batch(
        &mut self,
        device: usize,
        t_start_ms: f64,
        t_arrival_ms: f64,
        expected_ms: f64,
        members: usize,
    ) {
        for _ in 0..members.max(1) {
            self.observe(device, t_start_ms, t_arrival_ms, expected_ms);
        }
    }

    fn retune(&mut self) {
        if self.sorted.is_empty() {
            return;
        }
        let q = percentile_sorted(&self.sorted, self.cfg.quantile);
        self.factor = (q * self.cfg.margin).clamp(self.cfg.min_factor, self.cfg.max_factor);
    }

    /// The current straggler-gate factor (multiple of a stage's expected
    /// service time), replacing the static `SessionConfig::
    /// threshold_factor` while adaptive mode is on.
    pub fn threshold_factor(&self) -> f64 {
        self.factor
    }

    /// Fraction of replies lost within the sliding outcome window (so
    /// the estimate — and the recommendation built on it — recovers
    /// once a lossy phase ends).
    pub fn drop_rate(&self) -> f64 {
        if self.outcomes.is_empty() {
            0.0
        } else {
            self.window_drops as f64 / self.outcomes.len() as f64
        }
    }

    /// Redundancy the observed failure regime calls for: parity-coded CDC
    /// (one extra device, masks one loss per group) on a mostly-healthy
    /// fleet, replication (2MR — d extra devices, masks one loss *per
    /// shard*) once losses are frequent enough that a second concurrent
    /// loss per group becomes likely.
    pub fn recommend(&self) -> Redundancy {
        if self.drop_rate() > self.cfg.replication_drop_rate {
            Redundancy::TwoMr
        } else {
            Redundancy::Cdc
        }
    }

    /// One device's sliding window of (dispatch → arrival) completion
    /// intervals.
    pub fn device_window(&self, device: usize) -> Intervals {
        let mut iv = Intervals::new();
        if let Some(w) = self.device_windows.get(device) {
            for &(s, e) in w {
                iv.push(s, e);
            }
        }
        iv
    }

    /// Snapshot for `ServeReport::policy`.
    pub fn snapshot(&self) -> PolicyReport {
        PolicyReport {
            threshold_factor: self.factor,
            observed: self.observed,
            drops: self.drops,
            drop_rate: self.drop_rate(),
            stragglers: self.stragglers,
            recommended: self.recommend(),
            device_windows: (0..self.device_windows.len())
                .map(|d| self.device_window(d))
                .collect(),
        }
    }
}

/// What the adaptive policy learned over a serve run — surfaced as
/// `ServeReport::policy` so the straggler-gate/redundancy trade-off is
/// visible per run.
#[derive(Debug, Clone)]
pub struct PolicyReport {
    /// Gate factor in effect at the end of the run.
    pub threshold_factor: f64,
    /// Total shard completions observed over the run (incl. lost
    /// replies; lifetime counter).
    pub observed: u64,
    /// Lost replies observed over the run (lifetime counter).
    pub drops: u64,
    /// Lost fraction within the sliding outcome window (recovers after
    /// a transient lossy phase — this drives `recommended`).
    pub drop_rate: f64,
    /// Completions that exceeded the gate in effect when they arrived.
    pub stragglers: u64,
    /// Redundancy mode the observed regime calls for.
    pub recommended: Redundancy,
    /// Per-device sliding windows of (dispatch → arrival) intervals.
    pub device_windows: Vec<Intervals>,
}

#[cfg(test)]
mod tests {
    use super::*;
    const INF: f64 = f64::INFINITY;

    #[test]
    fn all_data_fast_path() {
        assert_eq!(
            resolve(&[10.0, 20.0], Some(100.0), 0.0),
            Outcome::AllData { t_ms: 20.0 }
        );
    }

    #[test]
    fn no_parity_failure_is_lost() {
        assert_eq!(resolve(&[10.0, INF], None, 0.0), Outcome::Lost);
        assert_eq!(resolve(&[10.0, 20.0], None, 0.0), Outcome::AllData { t_ms: 20.0 });
    }

    #[test]
    fn parity_replaces_failed_shard() {
        let o = resolve(&[10.0, INF, 30.0], Some(40.0), 0.0);
        assert_eq!(o, Outcome::Recovered { t_ms: 40.0, missing: 1 });
    }

    #[test]
    fn parity_beats_straggler() {
        // Shard 0 is a 500 ms straggler; parity at 25 ms lets the layer
        // complete at 30 ms (slowest of the n fastest).
        let o = resolve(&[500.0, 20.0, 30.0], Some(25.0), 0.0);
        assert_eq!(o, Outcome::Recovered { t_ms: 30.0, missing: 0 });
    }

    #[test]
    fn threshold_gates_substitution() {
        // Same straggler, but substitution may not start before 100 ms.
        let o = resolve(&[500.0, 20.0, 30.0], Some(25.0), 100.0);
        assert_eq!(o, Outcome::Recovered { t_ms: 100.0, missing: 0 });
        // A huge threshold means we wait for all data.
        let o = resolve(&[500.0, 20.0, 30.0], Some(25.0), 1000.0);
        assert_eq!(o, Outcome::AllData { t_ms: 500.0 });
    }

    #[test]
    fn two_failures_one_parity_lost() {
        assert_eq!(resolve(&[INF, INF, 10.0], Some(5.0), 0.0), Outcome::Lost);
    }

    #[test]
    fn single_shard_with_parity() {
        // d=1 + parity: parity alone can stand in.
        let o = resolve(&[INF], Some(42.0), 0.0);
        assert_eq!(o, Outcome::Recovered { t_ms: 42.0, missing: 0 });
    }

    #[test]
    fn parity_lost_degrades_gracefully() {
        assert_eq!(
            resolve(&[10.0, 20.0], Some(INF), 0.0),
            Outcome::AllData { t_ms: 20.0 }
        );
        assert_eq!(resolve(&[10.0, INF], Some(INF), 0.0), Outcome::Lost);
    }

    #[test]
    fn nan_stamps_resolve_as_lost_shards_not_panics() {
        const NAN: f64 = f64::NAN;
        // A corrupt (NaN) arrival is a missing shard: parity stands in.
        assert_eq!(
            resolve(&[10.0, NAN], Some(30.0), 0.0),
            Outcome::Recovered { t_ms: 30.0, missing: 1 }
        );
        // NaN + a genuinely lost shard exceeds one parity's budget.
        assert_eq!(resolve(&[NAN, INF, 5.0], Some(6.0), 0.0), Outcome::Lost);
        // A corrupt parity stamp degrades to all-data, like a lost parity.
        assert_eq!(
            resolve(&[1.0, 2.0], Some(NAN), 0.0),
            Outcome::AllData { t_ms: 2.0 }
        );
        // The grouped resolver inherits the same semantics.
        assert_eq!(
            resolve_grouped(&[NAN, 7.0], &[9.0], &[vec![0, 1]], 0.0),
            GroupedOutcome::Ok { t_ms: 9.0, missing: vec![0] }
        );
    }

    #[test]
    fn two_mr_first_response_wins() {
        let o = resolve_2mr(&[100.0, 30.0], &[20.0, INF]);
        assert_eq!(o, Outcome::AllData { t_ms: 30.0 });
        assert_eq!(resolve_2mr(&[INF, 30.0], &[INF, 10.0]), Outcome::Lost);
    }

    #[test]
    fn adaptive_gate_trails_typical_latency_and_flags_stragglers() {
        let mut p = AdaptivePolicy::new(AdaptiveConfig::default(), 4);
        assert_eq!(p.threshold_factor(), 2.0, "initial factor before samples");
        // Three fast devices at ~1× expected, one persistent 4× straggler.
        for round in 0..32 {
            let t0 = round as f64 * 100.0;
            for dev in 0..3 {
                p.observe(dev, t0, t0 + 10.0, 10.0);
            }
            p.observe(3, t0, t0 + 40.0, 10.0);
        }
        // Gate sits above the fast mode but well under the straggler: the
        // p75 of {1,1,1,4} traffic is ~1, × margin 1.5.
        let f = p.threshold_factor();
        assert!(f >= 1.2 && f < 4.0, "factor {f} should cut the 4× straggler");
        assert!(p.stragglers > 0, "persistent straggler must be flagged");
        assert_eq!(p.recommend(), Redundancy::Cdc, "no drops → parity suffices");
        let snap = p.snapshot();
        assert_eq!(snap.device_windows.len(), 4);
        assert_eq!(snap.device_windows[0].len(), 32);
        assert!((snap.threshold_factor - f).abs() < 1e-12);
    }

    #[test]
    fn adaptive_windows_slide_and_recover() {
        let cfg = AdaptiveConfig { window: 8, ..AdaptiveConfig::default() };
        let mut p = AdaptivePolicy::new(cfg, 1);
        // A slow early phase...
        for i in 0..8 {
            p.observe(0, i as f64, i as f64 + 60.0, 10.0); // 6× expected
        }
        let slow = p.threshold_factor();
        assert!(slow > 5.0, "gate chased the slow phase: {slow}");
        // ...then the device recovers; the window forgets the slow phase.
        for i in 8..16 {
            p.observe(0, i as f64, i as f64 + 10.0, 10.0);
        }
        let fast = p.threshold_factor();
        assert!(fast < slow, "gate must relax after recovery: {fast} vs {slow}");
        assert_eq!(p.device_window(0).len(), 8, "window is bounded");
    }

    #[test]
    fn observe_batch_feeds_one_observation_per_member() {
        let cfg = AdaptiveConfig { window: 64, ..AdaptiveConfig::default() };
        let mut a = AdaptivePolicy::new(cfg.clone(), 1);
        let mut b = AdaptivePolicy::new(cfg, 1);
        // One batched completion carrying 4 members ≡ the same
        // completion observed 4 times: same windows, same gate.
        a.observe_batch(0, 0.0, 12.0, 10.0, 4);
        for _ in 0..4 {
            b.observe(0, 0.0, 12.0, 10.0);
        }
        assert_eq!(a.observed, b.observed);
        assert_eq!(a.device_window(0).len(), 4);
        assert!((a.threshold_factor() - b.threshold_factor()).abs() < 1e-12);
        // A lost batched reply counts every member toward the drop rate.
        a.observe_batch(0, 0.0, f64::INFINITY, 10.0, 4);
        assert_eq!(a.snapshot().drops, 4);
    }

    #[test]
    fn adaptive_recommends_replication_under_heavy_loss() {
        let mut p = AdaptivePolicy::new(AdaptiveConfig::default(), 2);
        for i in 0..20 {
            p.observe(0, i as f64, i as f64 + 10.0, 10.0);
            // Device 1 loses 50% of its replies.
            let arr = if i % 2 == 0 { INF } else { i as f64 + 12.0 };
            p.observe(1, i as f64, arr, 10.0);
        }
        assert!(p.drop_rate() > 0.2, "drop rate {}", p.drop_rate());
        assert_eq!(p.recommend(), Redundancy::TwoMr);
        assert_eq!(p.snapshot().drops, 10);
        // The lossy phase ends: the windowed estimate recovers and the
        // recommendation reverts to the cheaper parity scheme.
        for i in 20..120 {
            p.observe(0, i as f64, i as f64 + 10.0, 10.0);
            p.observe(1, i as f64, i as f64 + 12.0, 10.0);
        }
        assert!(p.drop_rate() < 0.05, "windowed rate {}", p.drop_rate());
        assert_eq!(p.recommend(), Redundancy::Cdc);
        assert_eq!(p.snapshot().drops, 10, "lifetime counter keeps the history");
    }

    #[test]
    fn grouped_tolerates_one_failure_per_group() {
        let groups = vec![vec![0, 1], vec![2, 3]];
        // One failure in each group — recoverable (Fig. 18 bottom).
        let o = resolve_grouped(&[INF, 10.0, 20.0, INF], &[15.0, 25.0], &groups, 0.0);
        assert_eq!(
            o,
            GroupedOutcome::Ok { t_ms: 25.0, missing: vec![0, 3] }
        );
        // Two failures in one group — lost.
        let o = resolve_grouped(&[INF, INF, 20.0, 30.0], &[15.0, 25.0], &groups, 0.0);
        assert_eq!(o, GroupedOutcome::Lost);
        // No failures: all-data, no missing.
        let o = resolve_grouped(&[1.0, 2.0, 3.0, 4.0], &[9.0, 9.0], &groups, 100.0);
        assert_eq!(o, GroupedOutcome::Ok { t_ms: 4.0, missing: vec![] });
    }
}
