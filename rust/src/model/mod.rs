//! Model weights + local reference pipeline.
//!
//! Weights live in `artifacts/weights/<model>.bin` in matrix form (conv
//! filters pre-unrolled to (K, F²C) by the build path) and are loaded here
//! into [`Tensor`]s. The [`LocalPipeline`] runs a whole model on the local
//! PJRT runtime through the same d=1 artifacts the fleet uses — it is the
//! accuracy oracle for the Fig. 2 loss-injection experiment and the
//! correctness reference for the distributed coordinator.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::rng::Pcg32;
use crate::runtime::manifest::{LayerManifest, Manifest, ModelManifest};
use crate::runtime::Runtime;
use crate::tensor::Tensor;

/// Per-layer weight matrices of one model.
#[derive(Debug, Clone)]
pub struct Weights {
    /// layer name → (W (m,k), b (m,1)).
    by_layer: BTreeMap<String, (Tensor, Tensor)>,
}

impl Weights {
    /// Load a model's weights from its manifest entry.
    pub fn load(manifest: &Manifest, model: &ModelManifest) -> Result<Weights> {
        let raw = manifest.read_f32(&model.weights_file)?;
        let mut by_layer = BTreeMap::new();
        for layer in &model.layers {
            if !layer.is_weighted() {
                continue;
            }
            let (m, k) = layer.w_shape.ok_or_else(|| {
                Error::Artifact(format!("layer {} missing w_shape", layer.name))
            })?;
            let wo = layer.w_offset.unwrap() / 4;
            let bo = layer.b_offset.unwrap() / 4;
            let w = Tensor::new(vec![m, k], raw[wo..wo + m * k].to_vec())?;
            let b = Tensor::new(vec![m, 1], raw[bo..bo + m].to_vec())?;
            by_layer.insert(layer.name.clone(), (w, b));
        }
        Ok(Weights { by_layer })
    }

    /// Weight matrix of a layer.
    pub fn w(&self, layer: &str) -> Result<&Tensor> {
        self.by_layer
            .get(layer)
            .map(|(w, _)| w)
            .ok_or_else(|| Error::Config(format!("no weights for layer {layer:?}")))
    }

    /// Bias column of a layer.
    pub fn b(&self, layer: &str) -> Result<&Tensor> {
        self.by_layer
            .get(layer)
            .map(|(_, b)| b)
            .ok_or_else(|| Error::Config(format!("no weights for layer {layer:?}")))
    }
}

/// MAC count of one layer (cost model used for balanced assignment and the
/// fleet's service-time scaling).
pub fn layer_macs(layer: &LayerManifest) -> u64 {
    match layer.kind.as_str() {
        "fc" => (layer.m * layer.input_shape[0]) as u64,
        "conv" => {
            // Output spatial size *before* any fused pool.
            let (h, w) = (layer.input_shape[0], layer.input_shape[1]);
            let (oh, ow) = if layer.padding == "SAME" {
                (h.div_ceil(layer.s), w.div_ceil(layer.s))
            } else {
                ((h - layer.f) / layer.s + 1, (w - layer.f) / layer.s + 1)
            };
            (layer.k * layer.f * layer.f * layer.input_shape[2] * oh * ow) as u64
        }
        _ => 0,
    }
}

/// MACs of one shard when the layer is split `d` ways (uniform shards).
pub fn shard_macs(layer: &LayerManifest, d: usize) -> u64 {
    if d <= 1 {
        return layer_macs(layer);
    }
    let total = layer_macs(layer);
    let height = if layer.kind == "fc" { layer.m } else { layer.k };
    total * (height.div_ceil(d) as u64) / height as u64
}

/// Approximate request/response bytes for a shard task (f32 payloads) —
/// drives the network model's bandwidth term.
pub fn shard_io_bytes(layer: &LayerManifest, d: usize) -> (u64, u64) {
    let input: usize = layer.input_shape.iter().product();
    let out_height = layer.shard_height(d);
    let output = match layer.kind.as_str() {
        "fc" => out_height,
        "conv" => {
            let oh = layer.output_shape[0] * layer.pool.max(1);
            let ow = layer.output_shape[1] * layer.pool.max(1);
            oh * ow * out_height
        }
        _ => 0,
    };
    ((input * 4) as u64, (output * 4) as u64)
}

/// Local single-device executor over d=1 artifacts (+ rust epilogues).
pub struct LocalPipeline<'a> {
    pub runtime: &'a Runtime,
    pub manifest: &'a Manifest,
    pub model: &'a ModelManifest,
    pub weights: &'a Weights,
}

/// Where to inject activation loss for Fig. 2.
#[derive(Debug, Clone, Copy)]
pub struct LossInjection {
    /// Index into the model's weighted layers (0 = first conv/fc).
    pub layer_idx: usize,
    /// Fraction of that layer's output activations zeroed.
    pub fraction: f64,
}

impl<'a> LocalPipeline<'a> {
    /// Run the model on one input; optionally zero a fraction of one
    /// layer's output activations (the paper's Fig. 2 data-loss model).
    pub fn run(
        &self,
        x: &Tensor,
        loss: Option<LossInjection>,
        rng: &mut Pcg32,
    ) -> Result<Tensor> {
        let mut cur = if self.model.input_shape.len() == 1 {
            x.clone().reshape(vec![x.len(), 1])?
        } else {
            x.clone()
        };
        let mut weighted_idx = 0usize;
        for layer in &self.model.layers {
            match layer.kind.as_str() {
                "fc" | "conv" => {
                    let arts = layer.splits.get(&1).ok_or_else(|| {
                        Error::Config(format!("layer {} has no d=1 artifact", layer.name))
                    })?;
                    // Use the fused-activation flavor when available.
                    let (name, fused_relu) = match &arts.relu {
                        Some(r) => (r.as_str(), true),
                        None => (arts.lin.as_str(), false),
                    };
                    let w = self.weights.w(&layer.name)?;
                    let b = self.weights.b(&layer.name)?;
                    let mut out = self.runtime.execute(self.manifest, name, &[w, b, &cur])?;
                    if layer.relu && !fused_relu {
                        out.relu();
                    }
                    if layer.kind == "conv" && layer.pool > 0 {
                        out = out.maxpool(layer.pool, layer.pool)?;
                    }
                    if let Some(li) = loss {
                        if li.layer_idx == weighted_idx {
                            out.inject_loss(li.fraction, rng);
                        }
                    }
                    weighted_idx += 1;
                    cur = out;
                }
                "maxpool" => cur = cur.maxpool(layer.pool, layer.pool)?,
                "flatten" => cur = cur.flatten_col(),
                "gap" => cur = cur.gap()?,
                other => return Err(Error::Config(format!("unknown layer kind {other}"))),
            }
        }
        Ok(cur)
    }

    /// Classification accuracy over the manifest's eval set with optional
    /// loss injection — one Fig. 2 data point.
    pub fn accuracy(
        &self,
        images: &[Tensor],
        labels: &[i32],
        loss: Option<LossInjection>,
        rng: &mut Pcg32,
    ) -> Result<f64> {
        let mut correct = 0usize;
        for (img, &label) in images.iter().zip(labels) {
            let logits = self.run(img, loss, rng)?;
            if logits.argmax() == label as usize {
                correct += 1;
            }
        }
        Ok(correct as f64 / images.len() as f64)
    }
}

/// Load the Fig.-2 eval set as (images, labels).
pub fn load_eval_set(manifest: &Manifest) -> Result<(Vec<Tensor>, Vec<i32>)> {
    let ev = &manifest.eval_set;
    let raw = manifest.read_f32(&ev.images)?;
    let labels = manifest.read_i32(&ev.labels)?;
    let per: usize = ev.image_shape.iter().product();
    if raw.len() != per * ev.count || labels.len() != ev.count {
        return Err(Error::Artifact("eval set size mismatch".into()));
    }
    let images = raw
        .chunks_exact(per)
        .map(|c| Tensor::new(ev.image_shape.clone(), c.to_vec()))
        .collect::<Result<Vec<_>>>()?;
    Ok((images, labels))
}
