//! In-tree micro-benchmark harness (offline environment: no criterion).
//!
//! `cargo bench` targets use [`Bench`] for wall-clock measurements of the
//! hot paths (PJRT dispatch, CDC decode, merge) and the experiment drivers
//! reuse [`Timer`] for coarse phase timing. Reports mean/p50/p95/p99 over
//! a warmed-up sample set, criterion-style.

use std::time::Instant;

use crate::metrics::Summary;

/// One benchmark's configuration.
pub struct Bench {
    name: String,
    warmup_iters: usize,
    iters: usize,
}

impl Bench {
    /// Default: 10 warm-up + 100 measured iterations.
    pub fn new(name: &str) -> Bench {
        Bench { name: name.to_string(), warmup_iters: 10, iters: 100 }
    }

    /// Override iteration counts.
    pub fn iters(mut self, warmup: usize, measured: usize) -> Bench {
        self.warmup_iters = warmup;
        self.iters = measured;
        self
    }

    /// Run the closure repeatedly; returns (and prints) the summary of
    /// per-iteration wall-clock milliseconds.
    pub fn run<F: FnMut()>(self, mut f: F) -> Summary {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        let s = Summary::of(&samples);
        println!(
            "bench {:<40} mean={:>9.4}ms p50={:>9.4}ms p95={:>9.4}ms p99={:>9.4}ms (n={})",
            self.name, s.mean, s.p50, s.p95, s.p99, s.count
        );
        s
    }
}

/// Coarse phase timer for experiment drivers.
pub struct Timer {
    t0: Instant,
    label: String,
}

impl Timer {
    /// Start a labelled timer.
    pub fn start(label: &str) -> Timer {
        Timer { t0: Instant::now(), label: label.to_string() }
    }

    /// Elapsed milliseconds.
    pub fn ms(&self) -> f64 {
        self.t0.elapsed().as_secs_f64() * 1e3
    }

    /// Print and return elapsed ms.
    pub fn report(&self) -> f64 {
        let ms = self.ms();
        println!("[time] {}: {:.1} ms", self.label, ms);
        ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let s = Bench::new("noop").iters(2, 20).run(|| {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.count, 20);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start("t");
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.ms() >= 2.0);
    }
}
